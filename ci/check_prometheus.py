#!/usr/bin/env python3
"""Lint a Prometheus text-exposition page scraped from the admin plane.

The server_loadgen bench's traced cells scrape the admin `metrics` command
and write the raw page to rust/METRICS.prom; CI runs this linter over it so
a malformed exposition (a scrape a real Prometheus server would reject or
silently misparse) fails the build rather than surfacing months later on
someone's dashboard.

Checks (the subset of the text-format spec our exporter can violate):
  * every non-comment line is `name[{labels}] value` with a valid metric
    name, parseable float value, and well-formed label syntax;
  * every sample's base family (quantile samples and _sum/_count strip back
    to the family name) is declared by a preceding # TYPE line;
  * # TYPE lines name a known type and appear at most once per family;
  * # HELP appears at most once per family;
  * every series carries the innerq_ namespace prefix;
  * required families for a serving scrape are present (--require).

Usage:
    ci/check_prometheus.py rust/METRICS.prom \
        --require innerq_decode_steps --require innerq_stage_duration_us
"""

import argparse
import re
import sys

NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
LABELS_RE = re.compile(
    r'^\{[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\]|\\.)*"'
    r'(?:,[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\]|\\.)*")*\}$'
)
TYPES = {"counter", "gauge", "histogram", "summary", "untyped"}


def base_family(name, typed):
    """Strip summary/histogram suffixes back to a declared family name."""
    for suffix in ("_sum", "_count", "_bucket"):
        if name.endswith(suffix) and name[: -len(suffix)] in typed:
            return name[: -len(suffix)]
    return name


def lint(text, require):
    errors = []
    typed = {}   # family -> type
    helped = set()
    samples = []  # (lineno, name)

    for i, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            errors.append(f"line {i}: blank line (exporter never emits one)")
            continue
        if line.startswith("#"):
            parts = line.split(" ", 3)
            if len(parts) < 4 or parts[1] not in ("HELP", "TYPE"):
                errors.append(f"line {i}: malformed comment {line!r}")
                continue
            _, kw, family, rest = parts
            if not NAME_RE.match(family):
                errors.append(f"line {i}: bad metric name {family!r}")
                continue
            if kw == "TYPE":
                if rest not in TYPES:
                    errors.append(f"line {i}: unknown type {rest!r} for {family}")
                if family in typed:
                    errors.append(f"line {i}: duplicate # TYPE for {family}")
                typed[family] = rest
            else:
                if family in helped:
                    errors.append(f"line {i}: duplicate # HELP for {family}")
                helped.add(family)
            continue
        # Sample line: name[{labels}] value
        m = re.match(r"^([a-zA-Z_:][a-zA-Z0-9_:]*)(\{[^}]*\})?\s+(\S+)$", line)
        if not m:
            errors.append(f"line {i}: unparseable sample {line!r}")
            continue
        name, labels, value = m.groups()
        if labels and not LABELS_RE.match(labels):
            errors.append(f"line {i}: malformed labels {labels!r}")
        try:
            float(value)
        except ValueError:
            errors.append(f"line {i}: non-numeric value {value!r}")
        if not name.startswith("innerq_"):
            errors.append(f"line {i}: series {name} outside the innerq_ namespace")
        samples.append((i, name))

    for i, name in samples:
        if base_family(name, typed) not in typed:
            errors.append(f"line {i}: sample {name} has no # TYPE declaration")

    seen = {base_family(n, typed) for _, n in samples} | set(typed)
    for family in require:
        if family not in seen:
            errors.append(f"required family {family} missing from the page")

    return errors, len(samples), len(typed)


def main():
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("page", help="scraped exposition page (e.g. rust/METRICS.prom)")
    ap.add_argument("--require", action="append", default=[],
                    help="family that must be present (repeatable)")
    args = ap.parse_args()

    try:
        with open(args.page) as f:
            text = f.read()
    except OSError as e:
        print(f"[prom-lint] FAIL: cannot read {args.page}: {e}")
        return 1
    if not text.strip():
        print(f"[prom-lint] FAIL: {args.page} is empty — did the scrape run?")
        return 1

    errors, n_samples, n_families = lint(text, args.require)
    if errors:
        print(f"[prom-lint] FAIL: {len(errors)} problem(s) in {args.page}:")
        for e in errors:
            print(f"[prom-lint]   {e}")
        return 1
    print(f"[prom-lint] OK: {n_samples} samples across {n_families} typed "
          f"families in {args.page}.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
