#!/usr/bin/env python3
"""Seed ci/baselines/ from a downloaded CI bench-artifact set.

The authoring environment has no Rust toolchain, so trajectory baselines
cannot be produced locally — but every CI run uploads its BENCH_*.json
outputs as a workflow artifact (see .github/workflows/ci.yml, step
"upload bench artifacts"). This script turns one downloaded artifact set
into committed baselines, which makes `ci/check_bench_trajectory.py`
enforcing on the next run.

Usage:
    # 1. Download the artifact from a representative CI run:
    #      gh run download <run-id> -n bench-json -D /tmp/bench-json
    #    (or via the Actions UI: the "bench-json" artifact)
    # 2. Seed the baselines and commit:
    ci/seed_baselines.py /tmp/bench-json
    git add ci/baselines && git commit -m "Seed bench trajectory baselines"

Options:
    --force       overwrite baselines that already exist (refreshing the
                  floor after an intentional slowdown); default is to skip
                  them so an accidental re-run cannot silently move floors.
    --dry-run     report what would be copied without writing.
    --self-check  no artifact directory needed: prove the validator accepts
                  a minimal document for every bench family it knows about,
                  rejects malformed ones, and that the bench-baselines
                  workflow actually runs every family in KNOWN_BENCHES.
                  Guards against list drift — server_loadgen once existed
                  as a bench and a validator entry but was missing from the
                  workflow's bench list, so its floor never got seeded.

Each BENCH_*.json found in the artifact directory is validated (parses as
JSON, carries a recognized "bench" field and a non-empty "results" list)
before being copied to ci/baselines/<name>.
"""

import argparse
import json
import os
import shutil
import sys
import tempfile

KNOWN_BENCHES = {
    "kernel_throughput",
    "overload_tail",
    "offload_vs_recompute",
    "decode_scaling",
    "prefix_sharing",
    "server_loadgen",
    "fleet_scaling",
}


def validate(path):
    """Return an error string, or None if the file is a usable bench doc."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return f"unreadable JSON ({e})"
    bench = doc.get("bench")
    if bench not in KNOWN_BENCHES:
        return f"unrecognized bench field {bench!r}"
    results = doc.get("results")
    if not isinstance(results, list) or not results:
        return "empty or missing results list"
    return None


def _validate_doc(doc):
    """Run validate() on an in-memory document via a temp file."""
    fd, path = tempfile.mkstemp(suffix=".json")
    try:
        with os.fdopen(fd, "w") as f:
            if isinstance(doc, str):
                f.write(doc)  # deliberately malformed payloads arrive raw
            else:
                json.dump(doc, f)
        return validate(path)
    finally:
        os.unlink(path)


def self_check():
    """Exit-code-style check that the seeding machinery is self-consistent.

    Three properties, each of which has historically been able to drift
    independently of the others:
      1. validate() accepts a minimal well-formed doc for every family in
         KNOWN_BENCHES (so a real artifact of that family cannot be
         rejected on shape alone);
      2. validate() rejects malformed docs (unknown family, empty results,
         non-JSON) — the validator is actually validating;
      3. every KNOWN_BENCHES family appears as a `--bench <name>`
         invocation in .github/workflows/bench-baselines.yml, so the seed
         run produces an artifact for it. This is the check that would
         have caught server_loadgen never getting a committed floor.
    """
    failures = []

    for bench in sorted(KNOWN_BENCHES):
        err = _validate_doc({"bench": bench, "results": [{"throughput_rps": 1.0}]})
        status = "PASS" if err is None else f"FAIL ({err})"
        print(f"[self-check] validator accepts {bench}: {status}")
        if err is not None:
            failures.append(f"validator rejected well-formed {bench} doc: {err}")

    rejects = [
        ("unknown bench family", {"bench": "not_a_bench", "results": [{"x": 1}]}),
        ("empty results", {"bench": "kernel_throughput", "results": []}),
        ("missing results", {"bench": "kernel_throughput"}),
        ("non-JSON payload", "{not json"),
    ]
    for label, doc in rejects:
        err = _validate_doc(doc)
        status = "PASS" if err is not None else "FAIL (accepted)"
        print(f"[self-check] validator rejects {label}: {status}")
        if err is None:
            failures.append(f"validator accepted malformed doc ({label})")

    workflow = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "..", ".github", "workflows", "bench-baselines.yml")
    try:
        with open(workflow) as f:
            text = f.read()
    except OSError as e:
        failures.append(f"cannot read bench-baselines workflow: {e}")
        print(f"[self-check] workflow coverage: FAIL ({e})")
    else:
        for bench in sorted(KNOWN_BENCHES):
            present = f"--bench {bench}" in text
            status = "PASS" if present else "FAIL (not run by the seed workflow)"
            print(f"[self-check] workflow runs {bench}: {status}")
            if not present:
                failures.append(
                    f"{bench} is in KNOWN_BENCHES but bench-baselines.yml "
                    "never runs it — its floor can never be seeded")

    if failures:
        print(f"[self-check] FAIL: {len(failures)} problem(s):")
        for f in failures:
            print(f"[self-check]   - {f}")
        return 1
    print("[self-check] OK: validator and seed workflow cover every bench family.")
    return 0


def main():
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("artifact_dir", nargs="?",
                    help="directory holding downloaded BENCH_*.json files "
                         "(omit with --self-check)")
    ap.add_argument("--baselines", default=os.path.join(os.path.dirname(__file__), "baselines"),
                    help="destination directory (default: ci/baselines next to this script)")
    ap.add_argument("--force", action="store_true",
                    help="overwrite baselines that already exist")
    ap.add_argument("--dry-run", action="store_true",
                    help="report without copying")
    ap.add_argument("--self-check", action="store_true",
                    help="validate the validator + workflow bench list; no copying")
    args = ap.parse_args()

    if args.self_check:
        return self_check()
    if args.artifact_dir is None:
        ap.error("artifact_dir is required unless --self-check is given")

    if not os.path.isdir(args.artifact_dir):
        print(f"[seed] FAIL: {args.artifact_dir} is not a directory")
        return 1
    candidates = sorted(
        f for f in os.listdir(args.artifact_dir)
        if f.startswith("BENCH_") and f.endswith(".json")
    )
    if not candidates:
        print(f"[seed] FAIL: no BENCH_*.json in {args.artifact_dir} "
              "(did the artifact download into a subdirectory?)")
        return 1

    os.makedirs(args.baselines, exist_ok=True)
    seeded, would, skipped, bad = 0, 0, 0, 0
    for name in candidates:
        src = os.path.join(args.artifact_dir, name)
        dst = os.path.join(args.baselines, name)
        err = validate(src)
        if err:
            print(f"[seed] SKIP {name}: {err}")
            bad += 1
            continue
        if os.path.exists(dst) and not args.force:
            print(f"[seed] keep {name}: baseline already committed (use --force to refresh)")
            skipped += 1
            continue
        if args.dry_run:
            print(f"[seed] would copy {name} -> {dst}")
            would += 1
        else:
            shutil.copyfile(src, dst)
            print(f"[seed] seeded {name} -> {dst}")
            seeded += 1

    # "would seed" and "seeded" are reported separately: a dry run must not
    # claim files were written (the old summary lumped them together).
    if args.dry_run:
        print(f"[seed] done (dry run): {would} would be seeded, "
              f"{skipped} kept, {bad} invalid.")
    else:
        print(f"[seed] done: {seeded} seeded, {skipped} kept, {bad} invalid.")
    if seeded:
        print("[seed] commit ci/baselines/ to make the trajectory check enforcing.")
    return 0 if seeded or would or skipped else 1


if __name__ == "__main__":
    sys.exit(main())
