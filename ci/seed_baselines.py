#!/usr/bin/env python3
"""Seed ci/baselines/ from a downloaded CI bench-artifact set.

The authoring environment has no Rust toolchain, so trajectory baselines
cannot be produced locally — but every CI run uploads its BENCH_*.json
outputs as a workflow artifact (see .github/workflows/ci.yml, step
"upload bench artifacts"). This script turns one downloaded artifact set
into committed baselines, which makes `ci/check_bench_trajectory.py`
enforcing on the next run.

Usage:
    # 1. Download the artifact from a representative CI run:
    #      gh run download <run-id> -n bench-json -D /tmp/bench-json
    #    (or via the Actions UI: the "bench-json" artifact)
    # 2. Seed the baselines and commit:
    ci/seed_baselines.py /tmp/bench-json
    git add ci/baselines && git commit -m "Seed bench trajectory baselines"

Options:
    --force     overwrite baselines that already exist (refreshing the
                floor after an intentional slowdown); default is to skip
                them so an accidental re-run cannot silently move floors.
    --dry-run   report what would be copied without writing.

Each BENCH_*.json found in the artifact directory is validated (parses as
JSON, carries a recognized "bench" field and a non-empty "results" list)
before being copied to ci/baselines/<name>.
"""

import argparse
import json
import os
import shutil
import sys

KNOWN_BENCHES = {
    "kernel_throughput",
    "overload_tail",
    "offload_vs_recompute",
    "decode_scaling",
    "prefix_sharing",
    "server_loadgen",
}


def validate(path):
    """Return an error string, or None if the file is a usable bench doc."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return f"unreadable JSON ({e})"
    bench = doc.get("bench")
    if bench not in KNOWN_BENCHES:
        return f"unrecognized bench field {bench!r}"
    results = doc.get("results")
    if not isinstance(results, list) or not results:
        return "empty or missing results list"
    return None


def main():
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("artifact_dir", help="directory holding downloaded BENCH_*.json files")
    ap.add_argument("--baselines", default=os.path.join(os.path.dirname(__file__), "baselines"),
                    help="destination directory (default: ci/baselines next to this script)")
    ap.add_argument("--force", action="store_true",
                    help="overwrite baselines that already exist")
    ap.add_argument("--dry-run", action="store_true",
                    help="report without copying")
    args = ap.parse_args()

    if not os.path.isdir(args.artifact_dir):
        print(f"[seed] FAIL: {args.artifact_dir} is not a directory")
        return 1
    candidates = sorted(
        f for f in os.listdir(args.artifact_dir)
        if f.startswith("BENCH_") and f.endswith(".json")
    )
    if not candidates:
        print(f"[seed] FAIL: no BENCH_*.json in {args.artifact_dir} "
              "(did the artifact download into a subdirectory?)")
        return 1

    os.makedirs(args.baselines, exist_ok=True)
    seeded, skipped, bad = 0, 0, 0
    for name in candidates:
        src = os.path.join(args.artifact_dir, name)
        dst = os.path.join(args.baselines, name)
        err = validate(src)
        if err:
            print(f"[seed] SKIP {name}: {err}")
            bad += 1
            continue
        if os.path.exists(dst) and not args.force:
            print(f"[seed] keep {name}: baseline already committed (use --force to refresh)")
            skipped += 1
            continue
        if args.dry_run:
            print(f"[seed] would copy {name} -> {dst}")
        else:
            shutil.copyfile(src, dst)
            print(f"[seed] seeded {name} -> {dst}")
        seeded += 1

    print(f"[seed] done: {seeded} seeded, {skipped} kept, {bad} invalid.")
    if seeded and not args.dry_run:
        print("[seed] commit ci/baselines/ to make the trajectory check enforcing.")
    return 0 if seeded or skipped else 1


if __name__ == "__main__":
    sys.exit(main())
