#!/usr/bin/env python3
"""Cross-PR bench trajectory check.

Compares a freshly emitted bench JSON (BENCH_kernels.json from
`cargo bench --bench kernel_throughput`, BENCH_overload.json from
`cargo bench --bench overload_tail`, BENCH_offload.json from
`cargo bench --bench offload_vs_recompute`, BENCH_decode.json from
`cargo bench --bench decode_scaling`, BENCH_prefix.json from
`cargo bench --bench prefix_sharing`, BENCH_server.json from
`cargo bench --bench server_loadgen`, or BENCH_fleet.json from
`cargo bench --bench fleet_scaling`) against a committed baseline
snapshot and fails when throughput regresses by more than the threshold —
so CI catches "still bit-exact but 2x slower" changes, not just bit
mismatches.

Usage:
    ci/check_bench_trajectory.py CURRENT.json ci/baselines/BASELINE.json
        [--threshold 0.25] [--update]

Behavior:
  * baseline file absent  -> pass (exit 0) with instructions to seed it via
    --update; the check only becomes enforcing once a baseline is committed.
  * --update              -> overwrite the baseline with the current run and
    exit 0 (commit the result to move the trajectory floor).
  * regression > threshold in any cell shared by both files -> exit 1.

Cells are keyed per bench type:
  * kernel_throughput:    (kernel, isa, bits), metric tokens_per_s
    (wall-clock — the generous default threshold absorbs shared-runner
    noise; rows without an "isa" field predate the dispatch axis and are
    keyed as "scalar");
  * overload_tail:        (method, rate_rps, budget_bytes), metric
    throughput_rps (virtual-clock — deterministic, so any drift is real);
  * offload_vs_recompute: (method, preemption, rate_rps, budget_bytes),
    metric throughput_rps (virtual-clock, deterministic);
  * decode_scaling:       (pipeline, batch, workers), metric tokens_per_s
    (wall-clock; barrier-vs-overlap x worker-count x batch sweep);
  * prefix_sharing:       (family, method, prefix_share, budget_bytes),
    metric throughput_rps (virtual-clock, deterministic — multi-turn vs
    single-turn trace families with the CoW prefix store on/off);
  * server_loadgen:       (method, io_workers, rate_rps, traced), metric
    throughput_rps (wall-clock over real sockets through the staged server
    front end — arrival-paced, so the generous threshold absorbs runner
    noise; byte-identity vs the replay oracle is asserted in the bench
    itself before any timing is emitted). Rows without a "traced" field
    predate the tracing-overhead cells and key as untraced; the traced=True
    cells are the tracing-overhead guard;
  * fleet_scaling:        (policy, replicas, trace), metric throughput_rps
    (virtual-clock fleet replay — deterministic across worker and replica
    counts; the affinity-vs-round-robin locality contract is asserted in
    the bench itself before any cell is recorded).
"""

import argparse
import json
import os
import shutil
import sys


def load(path):
    with open(path) as f:
        return json.load(f)


def cells(doc):
    """Map cell key -> (metric_name, value) for a bench document."""
    bench = doc.get("bench", "?")
    out = {}
    for r in doc.get("results", []):
        if bench == "kernel_throughput":
            # The isa axis landed after the first baselines could have been
            # seeded; default old rows to "scalar" so pre-axis baselines
            # still share cells with current runs.
            key = (r["kernel"], r.get("isa", "scalar"), r["bits"])
            metric = "tokens_per_s"
        elif bench == "overload_tail":
            key = (r["method"], r["rate_rps"], r["budget_bytes"])
            metric = "throughput_rps"
        elif bench == "offload_vs_recompute":
            key = (r["method"], r["preemption"], r["rate_rps"], r["budget_bytes"])
            metric = "throughput_rps"
        elif bench == "decode_scaling":
            key = (r["pipeline"], r["batch"], r["workers"])
            metric = "tokens_per_s"
        elif bench == "prefix_sharing":
            key = (r["family"], r["method"], r["prefix_share"], r["budget_bytes"])
            metric = "throughput_rps"
        elif bench == "server_loadgen":
            # The traced axis landed with the tracing plane; older rows have
            # no "traced" field and key as untraced cells.
            key = (r["method"], r["io_workers"], r["rate_rps"],
                   bool(r.get("traced", False)))
            metric = "throughput_rps"
        elif bench == "fleet_scaling":
            key = (r["policy"], r["replicas"], r["trace"])
            metric = "throughput_rps"
        else:
            continue
        out[key] = (metric, float(r[metric]))
    return bench, out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="bench JSON emitted by this run")
    ap.add_argument("baseline", help="committed baseline snapshot")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max allowed fractional throughput drop (default 0.25)")
    ap.add_argument("--update", action="store_true",
                    help="overwrite the baseline with the current run")
    args = ap.parse_args()

    if not os.path.exists(args.current):
        print(f"[trajectory] FAIL: current bench output {args.current} missing "
              "(did the bench run?)")
        return 1

    if args.update:
        os.makedirs(os.path.dirname(args.baseline) or ".", exist_ok=True)
        shutil.copyfile(args.current, args.baseline)
        print(f"[trajectory] baseline updated: {args.baseline} <- {args.current}")
        print("[trajectory] commit the baseline to move the trajectory floor.")
        return 0

    cur_bench, cur = cells(load(args.current))
    if not os.path.exists(args.baseline):
        print(f"[trajectory] no baseline at {args.baseline} — passing.")
        print(f"[trajectory] current {cur_bench}: {len(cur)} cells. To make this "
              "check enforcing, seed the baseline on representative hardware:")
        print(f"[trajectory]   {sys.argv[0]} {args.current} {args.baseline} --update")
        return 0

    base_bench, base = cells(load(args.baseline))
    if base_bench != cur_bench:
        print(f"[trajectory] FAIL: baseline is {base_bench}, current is {cur_bench}")
        return 1

    shared = sorted(set(cur) & set(base), key=str)
    gone = sorted(set(base) - set(cur), key=str)
    if gone:
        print(f"[trajectory] WARN: {len(gone)} baseline cells missing from the "
              f"current run (renamed/removed?): {gone[:5]}")
    if not shared:
        print("[trajectory] FAIL: no cells shared with the baseline — "
              "refresh it with --update if the bench schema changed.")
        return 1

    failures = []
    for key in shared:
        metric, base_v = base[key]
        _, cur_v = cur[key]
        if base_v <= 0:
            continue
        drop = (base_v - cur_v) / base_v
        marker = ""
        if drop > args.threshold:
            failures.append(key)
            marker = "  <-- REGRESSION"
        print(f"[trajectory] {key}: {metric} {base_v:.3e} -> {cur_v:.3e} "
              f"({-drop * 100.0:+.1f}%){marker}")

    if failures:
        print(f"[trajectory] FAIL: {len(failures)}/{len(shared)} cells regressed "
              f"more than {args.threshold * 100:.0f}%: {failures}")
        print("[trajectory] if this slowdown is intentional (e.g. a correctness "
              "fix), refresh the baseline with --update and commit it.")
        return 1
    print(f"[trajectory] OK: {len(shared)} cells within "
          f"{args.threshold * 100:.0f}% of the {cur_bench} baseline.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
