#!/usr/bin/env python3
"""Calibrate replay CostModel coefficients from real CI bench numbers.

The virtual-clock replay harness (rust/src/workload/replay.rs) prices each
scheduler tick with a linear CostModel whose default coefficients are
hand-calibrated guesses. This script derives the coefficients that *can* be
measured from the wall-clock benches in a downloaded CI `bench-json`
artifact set and emits a partial-override JSON file that
`innerq serve-trace --cost-model PATH` loads (missing keys keep their
built-in defaults — the file only overrides what was actually measured).

Derivable today:
  * decode_step_us / decode_us_per_seq — from BENCH_decode.json
    (decode_scaling): for each batch size, the best tokens/s across
    pipeline x workers gives a per-step wall time
    `step_us = batch / tokens_per_s * 1e6`; a least-squares line over
    (batch, step_us) yields the fixed dispatch cost (intercept) and the
    marginal per-sequence cost (slope).

Not derivable yet (kept at defaults): tick_overhead_us,
prefill_us_per_token, offload/restore/prefix per-KiB costs — the benches
that exercise those paths run on the virtual clock, so they carry no
wall-clock signal. Extending a wall-clock bench over those paths is the
way to grow this file's coverage.

Usage:
    # After downloading a CI artifact set (see ci/seed_baselines.py):
    ci/calibrate_cost_model.py /tmp/bench-json -o ci/baselines/cost_model.json
    git add ci/baselines/cost_model.json && git commit -m "Calibrate replay cost model"
"""

import argparse
import json
import os
import sys


def fit_line(points):
    """Least-squares (intercept, slope) for [(x, y), ...]; None if degenerate."""
    n = len(points)
    if n < 2:
        return None
    sx = sum(p[0] for p in points)
    sy = sum(p[1] for p in points)
    sxx = sum(p[0] * p[0] for p in points)
    sxy = sum(p[0] * p[1] for p in points)
    denom = n * sxx - sx * sx
    if denom == 0:
        return None
    slope = (n * sxy - sx * sy) / denom
    intercept = (sy - slope * sx) / n
    return intercept, slope


def decode_coefficients(path):
    """(decode_step_us, decode_us_per_seq) from a BENCH_decode.json, or None."""
    with open(path) as f:
        doc = json.load(f)
    if doc.get("bench") != "decode_scaling":
        print(f"[calibrate] SKIP {path}: not a decode_scaling document")
        return None
    # Best (max) tokens/s per batch across pipeline x workers: the cost
    # model prices the *engine's* decode step, so the fastest configuration
    # is the one whose wall time reflects the work rather than the overhead
    # of a deliberately handicapped configuration.
    best = {}
    for r in doc.get("results", []):
        batch, tps = int(r["batch"]), float(r["tokens_per_s"])
        if tps > 0 and tps > best.get(batch, 0.0):
            best[batch] = tps
    points = [(b, b / tps * 1e6) for b, tps in sorted(best.items())]
    fit = fit_line(points)
    if fit is None:
        print(f"[calibrate] SKIP {path}: need >=2 batch sizes to fit a line "
              f"(got {len(points)})")
        return None
    intercept, slope = fit
    # Coefficients are u64 microseconds on the Rust side; clamp at 1 so a
    # noisy fit can never zero out a cost term entirely.
    step_us = max(1, round(intercept))
    per_seq_us = max(1, round(slope))
    for b, us in points:
        print(f"[calibrate]   batch {b:>3}: measured {us:10.1f} us/step, "
              f"model {step_us + per_seq_us * b:>8} us/step")
    return step_us, per_seq_us


def main():
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("artifact_dir", help="directory holding downloaded BENCH_*.json files")
    ap.add_argument("-o", "--out", default="ci/baselines/cost_model.json",
                    help="output path (default: ci/baselines/cost_model.json)")
    args = ap.parse_args()

    decode_path = os.path.join(args.artifact_dir, "BENCH_decode.json")
    if not os.path.exists(decode_path):
        print(f"[calibrate] FAIL: {decode_path} missing — run the decode_scaling "
              "bench (CI does, in the smoke step) and re-download the artifact.")
        return 1

    model = {}
    coeffs = decode_coefficients(decode_path)
    if coeffs:
        model["decode_step_us"], model["decode_us_per_seq"] = coeffs

    if not model:
        print("[calibrate] FAIL: no coefficients could be derived.")
        return 1

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(model, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"[calibrate] wrote {args.out}: {model}")
    print("[calibrate] remaining coefficients keep the built-in defaults; "
          "pass the file via `innerq serve-trace --cost-model`.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
