#!/usr/bin/env python3
"""Calibrate replay CostModel coefficients from real CI bench numbers.

The virtual-clock replay harness (rust/src/workload/replay.rs) prices each
scheduler tick with a linear CostModel whose default coefficients are
hand-calibrated guesses. This script derives the coefficients that *can* be
measured from the wall-clock benches in a downloaded CI `bench-json`
artifact set and emits a partial-override JSON file that
`innerq serve-trace --cost-model PATH` loads (missing keys keep their
built-in defaults — the file only overrides what was actually measured).

Derivable today:
  * decode_step_us / decode_us_per_seq — from BENCH_decode.json
    (decode_scaling): for each batch size, the best tokens/s across
    pipeline x workers gives a per-step wall time
    `step_us = batch / tokens_per_s * 1e6`; a least-squares line over
    (batch, step_us) yields the fixed dispatch cost (intercept) and the
    marginal per-sequence cost (slope).

Derivable from a Chrome trace (--from-trace TRACE.json, produced by
`innerq serve --trace-out` or the admin `trace <secs>` command):
  * prefill_us_per_token — least-squares slope over the private-prefill
    spans' (tokens, dur) points (shared-hit prefills skip the bulk work,
    so they are excluded from the fit);
  * offload_us_per_kib / restore_us_per_kib — slope over the snapshot /
    restore spans' (KiB, dur) points;
  * tick_overhead_us — the scheduler driver emits a `driver_tick` span
    around every tick; ticks that did no work (args.worked == 0) are pure
    driver overhead, so their median duration *is* the fixed per-tick
    cost. When a trace has no idle ticks (a saturated server), the
    minimum over all driver_tick spans bounds it from above.
When both an artifact dir and --from-trace are given, the two sources
override disjoint coefficient sets and compose into one file.

Not derivable yet (kept at its default): prefix_saving_us_per_kib — a
*counterfactual* saving; the trace records the hit's cost, not the
private prefill it avoided. Every other coefficient is now measurable.

Usage:
    # After downloading a CI artifact set (see ci/seed_baselines.py):
    ci/calibrate_cost_model.py /tmp/bench-json -o ci/baselines/cost_model.json
    # Or from a recorded serve trace (optionally alongside the artifacts):
    ci/calibrate_cost_model.py /tmp/bench-json --from-trace trace.json \
        -o ci/baselines/cost_model.json
    git add ci/baselines/cost_model.json && git commit -m "Calibrate replay cost model"
"""

import argparse
import json
import os
import sys


def fit_line(points):
    """Least-squares (intercept, slope) for [(x, y), ...]; None if degenerate."""
    n = len(points)
    if n < 2:
        return None
    sx = sum(p[0] for p in points)
    sy = sum(p[1] for p in points)
    sxx = sum(p[0] * p[0] for p in points)
    sxy = sum(p[0] * p[1] for p in points)
    denom = n * sxx - sx * sx
    if denom == 0:
        return None
    slope = (n * sxy - sx * sy) / denom
    intercept = (sy - slope * sx) / n
    return intercept, slope


def decode_coefficients(path):
    """(decode_step_us, decode_us_per_seq) from a BENCH_decode.json, or None."""
    with open(path) as f:
        doc = json.load(f)
    if doc.get("bench") != "decode_scaling":
        print(f"[calibrate] SKIP {path}: not a decode_scaling document")
        return None
    # Best (max) tokens/s per batch across pipeline x workers: the cost
    # model prices the *engine's* decode step, so the fastest configuration
    # is the one whose wall time reflects the work rather than the overhead
    # of a deliberately handicapped configuration.
    best = {}
    for r in doc.get("results", []):
        batch, tps = int(r["batch"]), float(r["tokens_per_s"])
        if tps > 0 and tps > best.get(batch, 0.0):
            best[batch] = tps
    points = [(b, b / tps * 1e6) for b, tps in sorted(best.items())]
    fit = fit_line(points)
    if fit is None:
        print(f"[calibrate] SKIP {path}: need >=2 batch sizes to fit a line "
              f"(got {len(points)})")
        return None
    intercept, slope = fit
    # Coefficients are u64 microseconds on the Rust side; clamp at 1 so a
    # noisy fit can never zero out a cost term entirely.
    step_us = max(1, round(intercept))
    per_seq_us = max(1, round(slope))
    for b, us in points:
        print(f"[calibrate]   batch {b:>3}: measured {us:10.1f} us/step, "
              f"model {step_us + per_seq_us * b:>8} us/step")
    return step_us, per_seq_us


def slope_us(points, label):
    """Per-unit cost from (units, dur_us) points: least-squares slope, or
    the aggregate-ratio fallback when the fit is degenerate (a single span,
    or all spans the same size). Returns a clamped u64-safe int, or None."""
    points = [(x, y) for x, y in points if x > 0]
    if not points:
        return None
    fit = fit_line(points)
    if fit is not None and fit[1] > 0:
        slope = fit[1]
        how = f"fit over {len(points)} spans"
    else:
        slope = sum(y for _, y in points) / sum(x for x, _ in points)
        how = f"aggregate ratio over {len(points)} spans"
    us = max(1, round(slope))
    print(f"[calibrate]   {label}: {us} us/unit ({how})")
    return us


def trace_coefficients(path):
    """Partial CostModel override dict from a Chrome trace JSON, or {}.

    Spans are matched by name (see rust/src/obs/mod.rs SpanKind::name):
    `prefill` spans with args.shared_bytes == 0 give prefill_us_per_token,
    `snapshot` / `restore` spans give offload/restore_us_per_kib, and
    `driver_tick` spans give tick_overhead_us (median of idle ticks —
    args.worked == 0 — or, absent any, the minimum over all ticks).
    """
    with open(path) as f:
        doc = json.load(f)
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        print(f"[calibrate] SKIP {path}: no traceEvents array (not a Chrome trace?)")
        return {}

    prefill, snapshot, restore = [], [], []
    idle_ticks, all_ticks = [], []
    for e in events:
        args = e.get("args", {})
        dur = float(e.get("dur", 0))
        name = e.get("name")
        if name == "prefill" and float(args.get("shared_bytes", 0)) == 0:
            prefill.append((float(args.get("tokens", 0)), dur))
        elif name == "snapshot":
            snapshot.append((float(args.get("bytes", 0)) / 1024.0, dur))
        elif name == "restore":
            restore.append((float(args.get("bytes", 0)) / 1024.0, dur))
        elif name == "driver_tick":
            all_ticks.append(dur)
            if float(args.get("worked", 0)) == 0:
                idle_ticks.append(dur)

    model = {}
    if idle_ticks:
        idle_ticks.sort()
        overhead = idle_ticks[len(idle_ticks) // 2]
        how = f"median of {len(idle_ticks)} idle driver_tick spans"
    elif all_ticks:
        overhead = min(all_ticks)
        how = (f"min of {len(all_ticks)} driver_tick spans "
               "(no idle ticks; upper bound)")
    else:
        overhead = None
        print("[calibrate]   no driver_tick spans; keeping the default "
              "tick_overhead_us")
    if overhead is not None:
        model["tick_overhead_us"] = max(1, round(overhead))
        print(f"[calibrate]   driver tick overhead: "
              f"{model['tick_overhead_us']} us ({how})")
    for key, label, points in [
        ("prefill_us_per_token", "prefill us/token (private spans)", prefill),
        ("offload_us_per_kib", "snapshot us/KiB", snapshot),
        ("restore_us_per_kib", "restore us/KiB", restore),
    ]:
        us = slope_us(points, label)
        if us is not None:
            model[key] = us
        else:
            print(f"[calibrate]   no usable spans for {key}; keeping the default")
    return model


def main():
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("artifact_dir", nargs="?",
                    help="directory holding downloaded BENCH_*.json files "
                         "(optional when --from-trace is given)")
    ap.add_argument("--from-trace", metavar="TRACE.json",
                    help="Chrome trace from --trace-out or the admin trace command; "
                         "adds prefill/offload/restore coefficients")
    ap.add_argument("-o", "--out", default="ci/baselines/cost_model.json",
                    help="output path (default: ci/baselines/cost_model.json)")
    args = ap.parse_args()

    if args.artifact_dir is None and args.from_trace is None:
        ap.error("need an artifact_dir, --from-trace, or both")

    model = {}
    if args.artifact_dir is not None:
        decode_path = os.path.join(args.artifact_dir, "BENCH_decode.json")
        if not os.path.exists(decode_path):
            print(f"[calibrate] FAIL: {decode_path} missing — run the decode_scaling "
                  "bench (CI does, in the smoke step) and re-download the artifact.")
            return 1
        coeffs = decode_coefficients(decode_path)
        if coeffs:
            model["decode_step_us"], model["decode_us_per_seq"] = coeffs

    if args.from_trace is not None:
        if not os.path.exists(args.from_trace):
            print(f"[calibrate] FAIL: trace file {args.from_trace} missing.")
            return 1
        model.update(trace_coefficients(args.from_trace))

    if not model:
        print("[calibrate] FAIL: no coefficients could be derived.")
        return 1

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(model, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"[calibrate] wrote {args.out}: {model}")
    print("[calibrate] remaining coefficients keep the built-in defaults; "
          "pass the file via `innerq serve-trace --cost-model`.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
