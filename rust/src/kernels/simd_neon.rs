//! Explicit NEON (aarch64) arms of the blocked fused dequant-GEMV kernels.
//!
//! Same contract as `simd_x86`: every function performs its scalar
//! counterpart's floating-point operations in the exact reference order —
//! separate `vmulq_f32` + `vaddq_f32`, never a fused `vfmaq` — so results
//! are bit-identical to the scalar arm on every input. The scalar kernels'
//! 16-lane split accumulators become four `float32x4_t` registers;
//! horizontal reductions spill lanes to a stack array and reuse the scalar
//! reduction. See `kernels/DESIGN.md` §SIMD.
//!
//! Callers (the `*_with_isa` wrappers) run the kernel guards and the shared
//! scalar preambles before dispatching here. NEON is mandatory on aarch64,
//! so this arm is the auto-detected default there; CI cross-checks it with
//! an `aarch64-unknown-linux-gnu` `cargo check`.

use super::gemv_inner::hsum16;
use crate::quant::packing::neon::unpack32_ps_neon;
use crate::quant::packing::packed_len;
use std::arch::aarch64::*;

/// One block of `rows.len() <= 4` key rows, NEON. Lane chunk `c` (lanes
/// `4c..4c+4` of the scalar `[f32; 16]` accumulator) computes
/// `a_c = q_c*b_c + q_{c+4}*b_{c+4}` (two muls + add, the reference split
/// accumulation), then `acc_c += scale * a_c`.
#[target_feature(enable = "neon")]
unsafe fn qk_inner_rows_neon(
    q: &[f32],
    qsum: &[f32],
    rows: &[&[u8]],
    srows: &[&[f32]],
    zrows: &[&[f32]],
    bits: u8,
    gbytes: usize,
    out: &mut [f32],
) {
    let groups = qsum.len();
    let nr = rows.len();
    debug_assert!(nr <= 4 && out.len() == nr);
    let mut acc = [[vdupq_n_f32(0.0); 4]; 4];
    let mut zterm = [0f32; 4];
    for g in 0..groups {
        let qp = q.as_ptr().add(g * 32);
        let mut qv = [vdupq_n_f32(0.0); 8];
        for (c, v) in qv.iter_mut().enumerate() {
            *v = vld1q_f32(qp.add(4 * c));
        }
        let qs = qsum[g];
        for r in 0..nr {
            let b = unpack32_ps_neon(&rows[r][g * gbytes..], bits);
            let s = vdupq_n_f32(srows[r][g]);
            for c in 0..4 {
                let a = vaddq_f32(vmulq_f32(qv[c], b[c]), vmulq_f32(qv[c + 4], b[c + 4]));
                acc[r][c] = vaddq_f32(acc[r][c], vmulq_f32(s, a));
            }
            zterm[r] += zrows[r][g] * qs;
        }
    }
    for r in 0..nr {
        let mut lanes = [0f32; 16];
        for c in 0..4 {
            vst1q_f32(lanes.as_mut_ptr().add(4 * c), acc[r][c]);
        }
        out[r] = hsum16(&lanes) + zterm[r];
    }
}

/// NEON arm of [`super::gemv_inner::qk_inner`]. `qsum` is the per-group
/// query prefix-sum plane computed by the dispatching wrapper.
///
/// # Safety
/// Requires NEON; the caller must have run `qk_guards` on these arguments.
#[target_feature(enable = "neon")]
pub unsafe fn qk_inner_neon(
    q: &[f32],
    qsum: &[f32],
    codes: &[u8],
    scales: &[f32],
    zeffs: &[f32],
    bits: u8,
    d_h: usize,
    out: &mut [f32],
) {
    let n = out.len();
    let groups = d_h / 32;
    let gbytes = packed_len(32, bits);
    let row_bytes = groups * gbytes;
    let mut j = 0usize;
    while j + 4 <= n {
        let rows: [&[u8]; 4] =
            std::array::from_fn(|r| &codes[(j + r) * row_bytes..(j + r + 1) * row_bytes]);
        let srows: [&[f32]; 4] =
            std::array::from_fn(|r| &scales[(j + r) * groups..(j + r + 1) * groups]);
        let zrows: [&[f32]; 4] =
            std::array::from_fn(|r| &zeffs[(j + r) * groups..(j + r + 1) * groups]);
        qk_inner_rows_neon(q, qsum, &rows, &srows, &zrows, bits, gbytes, &mut out[j..j + 4]);
        j += 4;
    }
    while j < n {
        qk_inner_rows_neon(
            q,
            qsum,
            &[&codes[j * row_bytes..(j + 1) * row_bytes]],
            &[&scales[j * groups..(j + 1) * groups]],
            &[&zeffs[j * groups..(j + 1) * groups]],
            bits,
            gbytes,
            &mut out[j..j + 1],
        );
        j += 1;
    }
}

/// NEON arm of [`super::gemv_inner::pv_inner_chunk`]. `psum` is the chunk's
/// softmax-weight sum, computed scalar by the wrapper.
///
/// # Safety
/// Requires NEON; the caller must have run `pv_guards` on these arguments.
#[target_feature(enable = "neon")]
pub unsafe fn pv_inner_chunk_neon(
    p: &[f32],
    psum: f32,
    chunk_codes: &[u8],
    scales: &[f32],
    zeffs: &[f32],
    bits: u8,
    d_h: usize,
    out: &mut [f32],
) {
    let gbytes = packed_len(32, bits);
    let row_bytes = (d_h / 32) * gbytes;
    let vpsum = vdupq_n_f32(psum);
    for g in 0..d_h / 32 {
        let mut acc = [vdupq_n_f32(0.0); 8];
        for (t, &w) in p.iter().enumerate() {
            let b = unpack32_ps_neon(&chunk_codes[t * row_bytes + g * gbytes..], bits);
            let vw = vdupq_n_f32(w);
            for (a, bj) in acc.iter_mut().zip(b) {
                *a = vaddq_f32(*a, vmulq_f32(vw, bj));
            }
        }
        let sp = scales.as_ptr().add(g * 32);
        let zp = zeffs.as_ptr().add(g * 32);
        let op = out.as_mut_ptr().add(g * 32);
        for (j, aj) in acc.into_iter().enumerate() {
            let s = vld1q_f32(sp.add(4 * j));
            let z = vld1q_f32(zp.add(4 * j));
            let o = vld1q_f32(op.add(4 * j));
            let r = vaddq_f32(o, vaddq_f32(vmulq_f32(s, aj), vmulq_f32(z, vpsum)));
            vst1q_f32(op.add(4 * j), r);
        }
    }
}

/// One block of `rows.len() <= 4` KIVI key rows, NEON. The two group halves
/// accumulate sequentially per the outer reference.
#[target_feature(enable = "neon")]
unsafe fn qk_outer_rows_neon(
    rows: &[&[u8]],
    qs_plane: &[f32],
    zacc: f32,
    bits: u8,
    gbytes: usize,
    d_h: usize,
    out: &mut [f32],
) {
    let nr = rows.len();
    debug_assert!(nr <= 4 && out.len() == nr);
    let mut acc = [[vdupq_n_f32(0.0); 4]; 4];
    for g in 0..d_h / 32 {
        let qp = qs_plane.as_ptr().add(g * 32);
        let mut qv = [vdupq_n_f32(0.0); 8];
        for (c, v) in qv.iter_mut().enumerate() {
            *v = vld1q_f32(qp.add(4 * c));
        }
        for r in 0..nr {
            let b = unpack32_ps_neon(&rows[r][g * gbytes..], bits);
            // Half 0 (lanes 0..16), then half 1 — chained adds as in the
            // scalar reference.
            for c in 0..4 {
                acc[r][c] = vaddq_f32(acc[r][c], vmulq_f32(qv[c], b[c]));
            }
            for c in 0..4 {
                acc[r][c] = vaddq_f32(acc[r][c], vmulq_f32(qv[c + 4], b[c + 4]));
            }
        }
    }
    for r in 0..nr {
        let mut lanes = [0f32; 16];
        for c in 0..4 {
            vst1q_f32(lanes.as_mut_ptr().add(4 * c), acc[r][c]);
        }
        out[r] = lanes.iter().sum::<f32>() + zacc;
    }
}

/// NEON arm of [`super::gemv_outer::qk_outer_chunk`]. `qs_plane`/`zacc` are
/// the hoisted `q_c*s_c` plane and zero term computed by the wrapper.
///
/// # Safety
/// Requires NEON; the caller must have run `qk_outer_guards` and filled
/// `qs_plane` for these arguments.
#[target_feature(enable = "neon")]
pub unsafe fn qk_outer_chunk_neon(
    chunk_codes: &[u8],
    qs_plane: &[f32],
    zacc: f32,
    bits: u8,
    d_h: usize,
    out: &mut [f32],
) {
    let n_rows = out.len();
    let gbytes = packed_len(32, bits);
    let row_bytes = (d_h / 32) * gbytes;
    let mut j = 0usize;
    while j + 4 <= n_rows {
        let rows: [&[u8]; 4] =
            std::array::from_fn(|r| &chunk_codes[(j + r) * row_bytes..(j + r + 1) * row_bytes]);
        qk_outer_rows_neon(&rows, qs_plane, zacc, bits, gbytes, d_h, &mut out[j..j + 4]);
        j += 4;
    }
    while j < n_rows {
        qk_outer_rows_neon(
            &[&chunk_codes[j * row_bytes..(j + 1) * row_bytes]],
            qs_plane,
            zacc,
            bits,
            gbytes,
            d_h,
            &mut out[j..j + 1],
        );
        j += 1;
    }
}
