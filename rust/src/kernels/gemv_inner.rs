//! InnerQ fused dequantize-GEMV (§4.4): quantization groups run along the
//! *inner* (reduction) dimension, so the kernel accumulates a group-partial
//! dot product over raw codes and applies the group's scale **once per
//! group** instead of once per element:
//!
//! `score_j = Σ_g [ s_{j,g} · (Σ_i q_i·code_i) + zeff_{j,g} · (Σ_i q_i) ]`
//!
//! The `Σ_i q_i` prefix sums are computed once per call, so asymmetric /
//! hybrid groups cost one extra FMA per group, not per element — this is the
//! data-reuse property the paper gets from inner-dimension grouping on GPU
//! (one scale load per compute tile) expressed in CPU-register form.

use crate::quant::packing::{packed_len, unpack32};

/// Key-cache scores (Eq. 3), InnerQ layout: per-token groups along `d_h`.
///
/// * `codes`: `n_tokens` rows, each `d_h/32` packed groups of 32 codes;
/// * `params`: `n_tokens * d_h/32` precomputed `(scale, zeff)` pairs,
///   row-major (see [`crate::kernels::zeff_params`]).
///
/// Writes `out[j] = q · dequant(K_j)` for each quantized token row.
pub fn qk_inner(
    q: &[f32],
    codes: &[u8],
    params: &[(f32, f32)],
    bits: u8,
    d_h: usize,
    out: &mut [f32],
) {
    // The guards are per-call (not per-element) and gate raw slice
    // arithmetic below, so they hold in release builds too: a short `codes`
    // or `params` slice must fail loudly, never read out of bounds.
    let n = out.len();
    assert_eq!(q.len(), d_h, "query length {} != d_h {d_h}", q.len());
    assert_eq!(d_h % 32, 0, "inner kernel requires G=32-aligned head dim");
    let groups = d_h / 32;
    let gbytes = packed_len(32, bits);
    let row_bytes = groups * gbytes;
    assert!(
        codes.len() >= n * row_bytes,
        "codes slice too short: {} < {} ({n} rows)",
        codes.len(),
        n * row_bytes
    );
    assert!(
        params.len() >= n * groups,
        "params slice too short: {} < {} ({n} rows)",
        params.len(),
        n * groups
    );

    // Per-group query prefix sums (for the zeff term), once per call. The
    // stack buffer covers d_h <= 2048; larger heads take one heap
    // allocation instead of corrupting (or aborting on) the fixed array.
    let mut qsum_stack = [0f32; 64];
    let mut qsum_heap = Vec::new();
    let qsum: &mut [f32] = if groups <= qsum_stack.len() {
        &mut qsum_stack[..groups]
    } else {
        qsum_heap.resize(groups, 0.0f32);
        &mut qsum_heap
    };
    for g in 0..groups {
        qsum[g] = q[g * 32..(g + 1) * 32].iter().sum();
    }

    let mut buf = [0u8; 32];
    for j in 0..n {
        let row = &codes[j * row_bytes..(j + 1) * row_bytes];
        let prow = &params[j * groups..(j + 1) * groups];
        // Row-level lane accumulator: each group's partial dot is scaled in
        // lane space (one vector multiply-add per group), so only ONE
        // horizontal reduction happens per token row — the CPU-register form
        // of "load the scale once per group and keep accumulating".
        let mut row_acc = [0f32; 16];
        let mut zterm = 0.0f32;
        for g in 0..groups {
            unpack32(&row[g * gbytes..], bits, &mut buf);
            let qg = &q[g * 32..(g + 1) * 32];
            // 16-lane split accumulation: breaks the strict-FP reduction
            // dependency chain so the loop vectorizes (one vcvt + vfma per
            // 16 codes on AVX-512).
            let mut acc = [0f32; 16];
            for half in 0..2 {
                let (qh, bh) = (&qg[half * 16..(half + 1) * 16], &buf[half * 16..(half + 1) * 16]);
                for i in 0..16 {
                    acc[i] += qh[i] * bh[i] as f32;
                }
            }
            let (s, z) = prow[g];
            for i in 0..16 {
                row_acc[i] += s * acc[i];
            }
            zterm += z * qsum[g];
        }
        out[j] = hsum16(&row_acc) + zterm;
    }
}

/// Pairwise horizontal sum of 16 lanes (vectorizer-friendly).
#[inline(always)]
fn hsum16(a: &[f32; 16]) -> f32 {
    let mut s8 = [0f32; 8];
    for i in 0..8 {
        s8[i] = a[i] + a[i + 8];
    }
    let s4 = [s8[0] + s8[4], s8[1] + s8[5], s8[2] + s8[6], s8[3] + s8[7]];
    (s4[0] + s4[2]) + (s4[1] + s4[3])
}

/// Value-cache context accumulation (Eq. 5), InnerQ layout: per-channel
/// groups along the token axis. One *chunk* covers 32 consecutive tokens.
///
/// Because the scale of channel `c` is constant across the chunk's tokens
/// (the defining property of inner grouping for V), the codes are stored
/// **token-major** and the kernel runs reduction-free: each token row is a
/// broadcast-`p[t]` vector FMA over channel lanes, and the per-channel scale
/// is applied once per chunk at the end. (The Pallas/TPU kernel keeps the
/// channel-major sublane layout — see DESIGN.md §Hardware-Adaptation.)
///
/// * `chunk_codes`: 32 token rows of packed `d_h` codes;
/// * `params`: `d_h` (scale, zeff) pairs (one per channel group);
/// * `p`: the 32 softmax weights for this chunk's tokens.
///
/// Accumulates `out[c] += Σ_t p[t] · dequant(V[t][c])`.
pub fn pv_inner_chunk(
    p: &[f32],
    chunk_codes: &[u8],
    params: &[(f32, f32)],
    bits: u8,
    d_h: usize,
    out: &mut [f32],
) {
    // Unconditional guards: these gate the raw slice math below and must
    // hold in release builds too (see qk_inner).
    assert_eq!(p.len(), 32, "value chunk needs exactly 32 weights");
    assert_eq!(out.len(), d_h, "out length {} != d_h {d_h}", out.len());
    assert_eq!(params.len(), d_h, "params length {} != d_h {d_h}", params.len());
    assert_eq!(d_h % 32, 0, "inner kernel requires G=32-aligned head dim");
    let gbytes = packed_len(32, bits);
    let row_bytes = (d_h / 32) * gbytes;
    assert!(
        chunk_codes.len() >= 32 * row_bytes,
        "chunk_codes slice too short: {} < {}",
        chunk_codes.len(),
        32 * row_bytes
    );
    let psum: f32 = p.iter().sum();

    // Unscaled accumulation: acc[c] = sum_t p[t] * code[t][c]. Stack
    // accumulator up to d_h = 512; one heap allocation beyond that.
    let mut acc_stack = [0f32; 512];
    let mut acc_heap = Vec::new();
    let acc: &mut [f32] = if d_h <= acc_stack.len() {
        &mut acc_stack[..d_h]
    } else {
        acc_heap.resize(d_h, 0.0f32);
        &mut acc_heap
    };
    let mut buf = [0u8; 32];
    for (t, &w) in p.iter().enumerate() {
        let row = &chunk_codes[t * row_bytes..(t + 1) * row_bytes];
        for g in 0..d_h / 32 {
            unpack32(&row[g * gbytes..], bits, &mut buf);
            let ag = &mut acc[g * 32..(g + 1) * 32];
            for i in 0..32 {
                ag[i] += w * buf[i] as f32;
            }
        }
    }
    // One scale application per channel per chunk (1/32 per code).
    for c in 0..d_h {
        let (s, z) = params[c];
        out[c] += s * acc[c] + z * psum;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::group::{quantize, Mode};
    use crate::quant::packing::pack;
    use crate::util::ptest::{check, normal_vec, PropCfg};

    use crate::quant::GroupParams;

    /// Quantize an n x d_h matrix in the InnerQ key layout.
    pub fn build_key_rows(
        vals: &[f32],
        d_h: usize,
        bits: u8,
        mode: Mode,
    ) -> (Vec<u8>, Vec<GroupParams>) {
        let mut codes = Vec::new();
        let mut params = Vec::new();
        for row in vals.chunks_exact(d_h) {
            for g in row.chunks_exact(32) {
                let mut raw = [0u8; 32];
                params.push(quantize(mode, g, bits, &mut raw));
                pack(&raw, bits, &mut codes);
            }
        }
        (codes, params)
    }

    /// Quantize 32 tokens x d_h values (token-major input) into one
    /// token-major InnerQ value chunk (groups run along tokens per channel).
    pub fn build_val_chunk(
        vals: &[f32],
        d_h: usize,
        bits: u8,
        mode: Mode,
    ) -> (Vec<u8>, Vec<GroupParams>) {
        assert_eq!(vals.len(), 32 * d_h);
        let mut params = Vec::new();
        let mut col = [0f32; 32];
        let mut ccodes = [0u8; 32];
        let mut raw = vec![0u8; 32 * d_h];
        for c in 0..d_h {
            for t in 0..32 {
                col[t] = vals[t * d_h + c];
            }
            params.push(quantize(mode, &col, bits, &mut ccodes));
            for t in 0..32 {
                raw[t * d_h + c] = ccodes[t];
            }
        }
        let mut codes = Vec::new();
        for t in 0..32 {
            pack(&raw[t * d_h..(t + 1) * d_h], bits, &mut codes);
        }
        (codes, params)
    }

    /// Reference: dequantize-then-dot, straight from the group math.
    fn qk_reference(
        q: &[f32],
        codes: &[u8],
        params: &[GroupParams],
        bits: u8,
        d_h: usize,
        n: usize,
    ) -> Vec<f32> {
        use crate::quant::group::dequantize;
        use crate::quant::packing::unpack;
        let groups = d_h / 32;
        let gbytes = packed_len(32, bits);
        let mut out = vec![0f32; n];
        for j in 0..n {
            let mut k = vec![0f32; d_h];
            for g in 0..groups {
                let mut raw = vec![0u8; 32];
                unpack(&codes[(j * groups + g) * gbytes..], bits, 32, &mut raw);
                dequantize(&raw, params[j * groups + g], bits, &mut k[g * 32..(g + 1) * 32]);
            }
            out[j] = q.iter().zip(&k).map(|(a, b)| a * b).sum();
        }
        out
    }

    #[test]
    fn qk_inner_matches_dequant_then_dot() {
        check("qk_inner == dequant+dot", PropCfg::default(), |rng, case| {
            let d_h = if case % 2 == 0 { 64 } else { 128 };
            let n = 1 + rng.next_range(40);
            let mode = *crate::util::ptest::choose(rng, &[Mode::Sym, Mode::Asym, Mode::Hybrid]);
            let bits = *crate::util::ptest::choose(rng, &[2u8, 3, 4]);
            let q = normal_vec(rng, d_h, 1.0, 0.0);
            let keys = normal_vec(rng, n * d_h, 1.0, 0.1);
            let (codes, params) = build_key_rows(&keys, d_h, bits, mode);
            let pf = crate::kernels::zeff_params(&params, bits);
            let mut out = vec![0f32; n];
            qk_inner(&q, &codes, &pf, bits, d_h, &mut out);
            let want = qk_reference(&q, &codes, &params, bits, d_h, n);
            for (a, b) in out.iter().zip(&want) {
                assert!((a - b).abs() < 1e-2 * b.abs().max(1.0), "{a} vs {b}");
            }
        });
    }

    #[test]
    fn qk_inner_close_to_unquantized_at_4_bits() {
        let mut rng = crate::util::rng::Rng::new(11);
        let d_h = 128;
        let n = 64;
        let q = normal_vec(&mut rng, d_h, 1.0, 0.0);
        let keys = normal_vec(&mut rng, n * d_h, 1.0, 0.0);
        let (codes, params) = build_key_rows(&keys, d_h, 4, Mode::Sym);
        let pf = crate::kernels::zeff_params(&params, 4);
        let mut out = vec![0f32; n];
        qk_inner(&q, &codes, &pf, 4, d_h, &mut out);
        let mut exact = vec![0f32; n];
        crate::kernels::gemv_fp::qk_fp(&q, &keys, d_h, &mut exact);
        // 4-bit sym: step = amax/7; dot error is a random walk over d_h terms.
        let rel = crate::util::stats::rel_l2(&out, &exact);
        assert!(rel < 0.12, "rel err {rel}");
    }

    #[test]
    fn pv_inner_matches_dequant_then_dot() {
        check("pv_inner == dequant+dot", PropCfg::default(), |rng, _| {
            let d_h = 64;
            let mode = *crate::util::ptest::choose(rng, &[Mode::Sym, Mode::Asym, Mode::Hybrid]);
            let bits = *crate::util::ptest::choose(rng, &[2u8, 3]);
            let vals = normal_vec(rng, 32 * d_h, 1.0, 0.1);
            let p = normal_vec(rng, 32, 0.3, 0.0);
            let (codes, params) = build_val_chunk(&vals, d_h, bits, mode);
            let pf = crate::kernels::zeff_params(&params, bits);
            let mut out = vec![0f32; d_h];
            pv_inner_chunk(&p, &codes, &pf, bits, d_h, &mut out);
            // reference: dequantize token rows (value = s*raw + zeff) and
            // accumulate with p
            use crate::quant::packing::unpack;
            let gbytes = packed_len(32, bits);
            let row_bytes = (d_h / 32) * gbytes;
            let mut want = vec![0f32; d_h];
            for t in 0..32 {
                let mut raw = vec![0u8; d_h];
                unpack(&codes[t * row_bytes..], bits, d_h, &mut raw);
                for c in 0..d_h {
                    let (s, z) = pf[c];
                    want[c] += p[t] * (s * raw[c] as f32 + z);
                }
            }
            for c in 0..d_h {
                assert!((out[c] - want[c]).abs() < 1e-3, "c={c}: {} vs {}", out[c], want[c]);
            }
        });
    }

    #[test]
    fn qk_inner_supports_heads_beyond_the_stack_buffer() {
        // d_h = 2176 -> 68 groups: exercises the heap fallback for the
        // per-group query sums (the fixed 64-group buffer used to make this
        // geometry a release-mode failure).
        let mut rng = crate::util::rng::Rng::new(41);
        let d_h = 2176;
        let n = 3;
        let q = normal_vec(&mut rng, d_h, 1.0, 0.0);
        let keys = normal_vec(&mut rng, n * d_h, 1.0, 0.0);
        let (codes, params) = build_key_rows(&keys, d_h, 4, Mode::Asym);
        let pf = crate::kernels::zeff_params(&params, 4);
        let mut out = vec![0f32; n];
        qk_inner(&q, &codes, &pf, 4, d_h, &mut out);
        let want = qk_reference(&q, &codes, &params, 4, d_h, n);
        for (a, b) in out.iter().zip(&want) {
            assert!((a - b).abs() < 1e-2 * b.abs().max(1.0), "{a} vs {b}");
        }
    }

    #[test]
    fn pv_inner_supports_heads_beyond_the_stack_buffer() {
        // d_h = 544 > 512: exercises the heap accumulator fallback.
        let mut rng = crate::util::rng::Rng::new(43);
        let d_h = 544;
        let vals = normal_vec(&mut rng, 32 * d_h, 1.0, 0.0);
        let p = normal_vec(&mut rng, 32, 0.2, 0.0);
        let (codes, params) = build_val_chunk(&vals, d_h, 3, Mode::Sym);
        let pf = crate::kernels::zeff_params(&params, 3);
        let mut out = vec![0f32; d_h];
        pv_inner_chunk(&p, &codes, &pf, 3, d_h, &mut out);
        let mut exact = vec![0f32; d_h];
        crate::kernels::gemv_fp::pv_fp(&p, &vals, d_h, &mut exact);
        assert!(
            crate::util::stats::rel_l2(&out, &exact) < 0.2,
            "rel {}",
            crate::util::stats::rel_l2(&out, &exact)
        );
    }

    #[test]
    #[should_panic(expected = "codes slice too short")]
    fn qk_inner_rejects_short_codes() {
        let q = vec![0f32; 64];
        let codes = vec![0u8; 10]; // far less than 2 rows of 2 groups
        let params = vec![(1.0f32, 0.0f32); 4];
        let mut out = vec![0f32; 2];
        qk_inner(&q, &codes, &params, 3, 64, &mut out);
    }

    #[test]
    #[should_panic(expected = "params slice too short")]
    fn qk_inner_rejects_short_params() {
        let q = vec![0f32; 64];
        let codes = vec![0u8; 2 * 2 * 12];
        let params = vec![(1.0f32, 0.0f32); 1];
        let mut out = vec![0f32; 2];
        qk_inner(&q, &codes, &params, 3, 64, &mut out);
    }

    #[test]
    #[should_panic(expected = "chunk_codes slice too short")]
    fn pv_inner_rejects_short_codes() {
        let p = vec![0f32; 32];
        let codes = vec![0u8; 16];
        let params = vec![(1.0f32, 0.0f32); 64];
        let mut out = vec![0f32; 64];
        pv_inner_chunk(&p, &codes, &params, 3, 64, &mut out);
    }

    #[test]
    fn value_chunk_transposes_correctly() {
        // Token t, channel c must land at channel-row c, position t.
        let d_h = 32;
        let mut vals = vec![0f32; 32 * d_h];
        vals[5 * d_h + 7] = 3.0; // token 5, channel 7
        let (codes, params) = build_val_chunk(&vals, d_h, 3, Mode::Sym);
        let pf = crate::kernels::zeff_params(&params, 3);
        let mut p = vec![0f32; 32];
        p[5] = 1.0;
        let mut out = vec![0f32; d_h];
        pv_inner_chunk(&p, &codes, &pf, 3, d_h, &mut out);
        assert!((out[7] - 3.0).abs() < 0.01, "out[7]={}", out[7]);
        assert!(out.iter().enumerate().all(|(c, &v)| c == 7 || v.abs() < 1e-4));
    }
}
