//! InnerQ fused dequantize-GEMV (§4.4): quantization groups run along the
//! *inner* (reduction) dimension, so the kernel accumulates a group-partial
//! dot product over raw codes and applies the group's scale **once per
//! group** instead of once per element:
//!
//! `score_j = Σ_g [ s_{j,g} · (Σ_i q_i·code_i) + zeff_{j,g} · (Σ_i q_i) ]`
//!
//! The `Σ_i q_i` prefix sums are computed once per call, so asymmetric /
//! hybrid groups cost one extra FMA per group, not per element — this is the
//! data-reuse property the paper gets from inner-dimension grouping on GPU
//! (one scale load per compute tile) expressed in CPU-register form.
//!
//! The hot kernels here are *blocked*: `qk_inner` processes 4 token rows per
//! pass so the query group and its prefix sum are loaded once per block and
//! the four rows' accumulator chains interleave in the OoO window, and
//! `pv_inner_chunk` walks group-major with a register-resident `[f32; 32]`
//! accumulator per channel group. Group params arrive as planar `scales[]` /
//! `zeffs[]` planes (see [`crate::kernels::zeff_planes`]); codes are
//! unpacked straight to f32 (`unpack32_f32`). Every inner loop is an
//! exact-trip-count chunk over fixed-size f32 arrays that rustc
//! autovectorizes — no `unsafe`, no nightly SIMD. The `*_ref` scalar
//! kernels are retained as the bit-exactness oracle: the blocked kernels
//! perform each row's floating-point operations in the reference order, so
//! results are bit-identical (asserted by the parity tests).
//!
//! Layout and blocking rationale: `kernels/DESIGN.md`.

use crate::quant::packing::{packed_len, unpack, unpack32_f32};

/// Per-call guards shared by the blocked and reference key kernels. The
/// guards are per-call (not per-element) and gate raw slice arithmetic, so
/// they hold in release builds too: a short `codes` or `scales`/`zeffs`
/// slice must fail loudly, never read out of bounds.
fn qk_guards(q: &[f32], codes: &[u8], scales: &[f32], zeffs: &[f32], bits: u8, d_h: usize, n: usize) {
    assert_eq!(q.len(), d_h, "query length {} != d_h {d_h}", q.len());
    assert_eq!(d_h % 32, 0, "inner kernel requires G=32-aligned head dim");
    let groups = d_h / 32;
    let row_bytes = groups * packed_len(32, bits);
    assert!(
        codes.len() >= n * row_bytes,
        "codes slice too short: {} < {} ({n} rows)",
        codes.len(),
        n * row_bytes
    );
    assert!(
        scales.len() >= n * groups,
        "scales slice too short: {} < {} ({n} rows)",
        scales.len(),
        n * groups
    );
    assert!(
        zeffs.len() >= n * groups,
        "zeffs slice too short: {} < {} ({n} rows)",
        zeffs.len(),
        n * groups
    );
}

/// Per-group query prefix sums (for the zeff term), once per call. The
/// stack buffer covers d_h <= 2048; larger heads take one heap allocation
/// instead of corrupting (or aborting on) the fixed array.
fn fill_qsum<'a>(
    q: &[f32],
    groups: usize,
    stack: &'a mut [f32; 64],
    heap: &'a mut Vec<f32>,
) -> &'a [f32] {
    let qsum: &mut [f32] = if groups <= stack.len() {
        &mut stack[..groups]
    } else {
        heap.resize(groups, 0.0f32);
        heap
    };
    for (g, s) in qsum.iter_mut().enumerate() {
        *s = q[g * 32..(g + 1) * 32].iter().sum();
    }
    qsum
}

/// One block of `R` token rows: the query group `qg` and prefix sum
/// `qsum[g]` are loaded once per block and reused across all `R` rows, and
/// the `R` independent accumulator chains give the OoO core parallel FMA
/// streams. Per row, the operation order is exactly the scalar reference's
/// (group-ascending, 16-lane split accumulation, one `hsum16` at the end),
/// so any `R` produces bit-identical scores.
#[inline(always)]
#[allow(clippy::too_many_arguments)] // internal: mirrors the kernel ABI plus block state
fn qk_inner_block<const R: usize>(
    q: &[f32],
    qsum: &[f32],
    rows: [&[u8]; R],
    srows: [&[f32]; R],
    zrows: [&[f32]; R],
    bits: u8,
    gbytes: usize,
    out: &mut [f32],
) {
    let groups = qsum.len();
    let mut row_acc = [[0f32; 16]; R];
    let mut zterm = [0f32; R];
    let mut buf = [0f32; 32];
    for g in 0..groups {
        let qg: &[f32; 32] = q[g * 32..(g + 1) * 32].try_into().unwrap();
        let qs = qsum[g];
        for r in 0..R {
            unpack32_f32(&rows[r][g * gbytes..], bits, &mut buf);
            // 16-lane split accumulation: breaks the strict-FP reduction
            // dependency chain so the loop vectorizes (one vfma per 16
            // codes on AVX-512).
            let mut acc = [0f32; 16];
            for i in 0..16 {
                acc[i] += qg[i] * buf[i];
            }
            for i in 0..16 {
                acc[i] += qg[16 + i] * buf[16 + i];
            }
            // Row-level lane accumulator: the group's partial dot is scaled
            // in lane space (one vector multiply-add per group), so only ONE
            // horizontal reduction happens per token row.
            let s = srows[r][g];
            for i in 0..16 {
                row_acc[r][i] += s * acc[i];
            }
            zterm[r] += zrows[r][g] * qs;
        }
    }
    for r in 0..R {
        out[r] = hsum16(&row_acc[r]) + zterm[r];
    }
}

/// Key-cache scores (Eq. 3), InnerQ layout: per-token groups along `d_h`.
///
/// * `codes`: `n_tokens` rows, each `d_h/32` packed groups of 32 codes;
/// * `scales` / `zeffs`: planar per-group parameter planes, `n_tokens *
///   d_h/32` f32 each, row-major (see [`crate::kernels::zeff_planes`]).
///
/// Writes `out[j] = q · dequant(K_j)` for each quantized token row.
/// Dispatches to the widest bit-identical ISA arm the host supports (see
/// [`crate::kernels::dispatch`]); every arm — scalar blocked, AVX2,
/// AVX-512, NEON — is bit-identical to [`qk_inner_ref`] for any row count.
pub fn qk_inner(
    q: &[f32],
    codes: &[u8],
    scales: &[f32],
    zeffs: &[f32],
    bits: u8,
    d_h: usize,
    out: &mut [f32],
) {
    qk_inner_with_isa(crate::kernels::dispatch::active(), q, codes, scales, zeffs, bits, d_h, out)
}

/// [`qk_inner`] pinned to a specific dispatch arm. The parity tests and the
/// kernel bench enumerate [`crate::kernels::dispatch::supported`] through
/// this entry point; production code goes through the dispatching wrapper.
///
/// # Panics
/// Panics (before any unsafe code runs) if `isa` names an arm this
/// host/build cannot execute, and on the same short-slice conditions as the
/// scalar kernel.
#[allow(clippy::too_many_arguments)] // kernel ABI plus the arm selector
pub fn qk_inner_with_isa(
    isa: crate::kernels::dispatch::Isa,
    q: &[f32],
    codes: &[u8],
    scales: &[f32],
    zeffs: &[f32],
    bits: u8,
    d_h: usize,
    out: &mut [f32],
) {
    use crate::kernels::dispatch::{is_supported, Isa};
    let n = out.len();
    qk_guards(q, codes, scales, zeffs, bits, d_h, n);
    assert!(is_supported(isa), "ISA '{isa}' not supported on this host/build");
    let groups = d_h / 32;

    // Shared scalar preamble: the per-group query prefix sums are computed
    // once, identically, for every arm.
    let mut qsum_stack = [0f32; 64];
    let mut qsum_heap = Vec::new();
    let qsum = fill_qsum(q, groups, &mut qsum_stack, &mut qsum_heap);

    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe {
            // SAFETY: guards validated the slices; is_supported checked AVX2.
            crate::kernels::simd_x86::qk_inner_avx2(q, qsum, codes, scales, zeffs, bits, d_h, out)
        },
        #[cfg(all(target_arch = "x86_64", innerq_avx512))]
        Isa::Avx512 => unsafe {
            // SAFETY: guards validated the slices; is_supported checked AVX-512F.
            crate::kernels::simd_x86::qk_inner_avx512(q, qsum, codes, scales, zeffs, bits, d_h, out)
        },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe {
            // SAFETY: guards validated the slices; is_supported checked NEON.
            crate::kernels::simd_neon::qk_inner_neon(q, qsum, codes, scales, zeffs, bits, d_h, out)
        },
        _ => qk_inner_scalar_body(q, qsum, codes, scales, zeffs, bits, d_h, out),
    }
}

/// The scalar (autovectorized) dispatch arm: the original blocked kernel
/// from PRs 2/5, minus the guards/qsum preamble hoisted into the wrapper.
#[allow(clippy::too_many_arguments)] // internal: kernel ABI plus the hoisted qsum plane
fn qk_inner_scalar_body(
    q: &[f32],
    qsum: &[f32],
    codes: &[u8],
    scales: &[f32],
    zeffs: &[f32],
    bits: u8,
    d_h: usize,
    out: &mut [f32],
) {
    let n = out.len();
    let groups = d_h / 32;
    let gbytes = packed_len(32, bits);
    let row_bytes = groups * gbytes;

    let mut j = 0usize;
    while j + 4 <= n {
        let rows: [&[u8]; 4] =
            std::array::from_fn(|r| &codes[(j + r) * row_bytes..(j + r + 1) * row_bytes]);
        let srows: [&[f32]; 4] =
            std::array::from_fn(|r| &scales[(j + r) * groups..(j + r + 1) * groups]);
        let zrows: [&[f32]; 4] =
            std::array::from_fn(|r| &zeffs[(j + r) * groups..(j + r + 1) * groups]);
        qk_inner_block::<4>(q, qsum, rows, srows, zrows, bits, gbytes, &mut out[j..j + 4]);
        j += 4;
    }
    // Tail rows (n % 4) go through the same block kernel one row at a time —
    // identical per-row op order, so the tail is bit-identical too.
    while j < n {
        qk_inner_block::<1>(
            q,
            qsum,
            [&codes[j * row_bytes..(j + 1) * row_bytes]],
            [&scales[j * groups..(j + 1) * groups]],
            [&zeffs[j * groups..(j + 1) * groups]],
            bits,
            gbytes,
            &mut out[j..j + 1],
        );
        j += 1;
    }
}

/// Scalar reference for [`qk_inner`]: one row at a time through the generic
/// bit-loop unpacker. Retained as the blocked kernel's bit-exactness oracle
/// (the parity tests assert `qk_inner == qk_inner_ref` exactly) and as the
/// readable form of the algorithm.
pub fn qk_inner_ref(
    q: &[f32],
    codes: &[u8],
    scales: &[f32],
    zeffs: &[f32],
    bits: u8,
    d_h: usize,
    out: &mut [f32],
) {
    let n = out.len();
    qk_guards(q, codes, scales, zeffs, bits, d_h, n);
    let groups = d_h / 32;
    let gbytes = packed_len(32, bits);
    let row_bytes = groups * gbytes;

    let mut qsum_stack = [0f32; 64];
    let mut qsum_heap = Vec::new();
    let qsum = fill_qsum(q, groups, &mut qsum_stack, &mut qsum_heap);

    let mut raw = [0u8; 32];
    for (j, o) in out.iter_mut().enumerate() {
        let row = &codes[j * row_bytes..(j + 1) * row_bytes];
        let mut row_acc = [0f32; 16];
        let mut zterm = 0.0f32;
        for g in 0..groups {
            unpack(&row[g * gbytes..], bits, 32, &mut raw);
            let qg = &q[g * 32..(g + 1) * 32];
            let mut acc = [0f32; 16];
            for i in 0..16 {
                acc[i] += qg[i] * raw[i] as f32;
            }
            for i in 0..16 {
                acc[i] += qg[16 + i] * raw[16 + i] as f32;
            }
            let s = scales[j * groups + g];
            for i in 0..16 {
                row_acc[i] += s * acc[i];
            }
            zterm += zeffs[j * groups + g] * qsum[g];
        }
        *o = hsum16(&row_acc) + zterm;
    }
}

/// Pairwise horizontal sum of 16 lanes (vectorizer-friendly). Shared with
/// the SIMD arms, which spill their accumulator lanes to a stack array and
/// reduce through this exact function so the reduction tree is identical by
/// construction.
#[inline(always)]
pub(crate) fn hsum16(a: &[f32; 16]) -> f32 {
    let mut s8 = [0f32; 8];
    for i in 0..8 {
        s8[i] = a[i] + a[i + 8];
    }
    let s4 = [s8[0] + s8[4], s8[1] + s8[5], s8[2] + s8[6], s8[3] + s8[7]];
    (s4[0] + s4[2]) + (s4[1] + s4[3])
}

/// Guards shared by the blocked and reference value kernels.
fn pv_guards(p: &[f32], chunk_codes: &[u8], scales: &[f32], zeffs: &[f32], bits: u8, d_h: usize, out: &[f32]) {
    assert_eq!(p.len(), 32, "value chunk needs exactly 32 weights");
    assert_eq!(out.len(), d_h, "out length {} != d_h {d_h}", out.len());
    assert_eq!(scales.len(), d_h, "scales length {} != d_h {d_h}", scales.len());
    assert_eq!(zeffs.len(), d_h, "zeffs length {} != d_h {d_h}", zeffs.len());
    assert_eq!(d_h % 32, 0, "inner kernel requires G=32-aligned head dim");
    let row_bytes = (d_h / 32) * packed_len(32, bits);
    assert!(
        chunk_codes.len() >= 32 * row_bytes,
        "chunk_codes slice too short: {} < {}",
        chunk_codes.len(),
        32 * row_bytes
    );
}

/// Value-cache context accumulation (Eq. 5), InnerQ layout: per-channel
/// groups along the token axis. One *chunk* covers 32 consecutive tokens.
///
/// Because the scale of channel `c` is constant across the chunk's tokens
/// (the defining property of inner grouping for V), the codes are stored
/// **token-major** and the kernel runs reduction-free: each token row is a
/// broadcast-`p[t]` vector FMA over channel lanes, and the per-channel scale
/// is applied once per chunk at the end.
///
/// Blocked form: walks group-major with a register-resident `[f32; 32]`
/// accumulator per channel group (no `d_h`-sized scratch at all), unpacking
/// 4 token rows per pass. Per channel, tokens still accumulate in ascending
/// order and the planar scale/zeff apply once at the end, so the result is
/// bit-identical to [`pv_inner_chunk_ref`].
///
/// * `chunk_codes`: 32 token rows of packed `d_h` codes;
/// * `scales` / `zeffs`: planar parameter planes, `d_h` f32 each (one per
///   channel group);
/// * `p`: the 32 softmax weights for this chunk's tokens.
///
/// Accumulates `out[c] += Σ_t p[t] · dequant(V[t][c])`. Dispatches to the
/// widest bit-identical ISA arm the host supports; every arm is
/// bit-identical to [`pv_inner_chunk_ref`].
pub fn pv_inner_chunk(
    p: &[f32],
    chunk_codes: &[u8],
    scales: &[f32],
    zeffs: &[f32],
    bits: u8,
    d_h: usize,
    out: &mut [f32],
) {
    pv_inner_chunk_with_isa(
        crate::kernels::dispatch::active(),
        p,
        chunk_codes,
        scales,
        zeffs,
        bits,
        d_h,
        out,
    )
}

/// [`pv_inner_chunk`] pinned to a specific dispatch arm (see
/// [`qk_inner_with_isa`] for the contract).
///
/// # Panics
/// Panics if `isa` is not supported on this host/build, and on the same
/// short-slice conditions as the scalar kernel.
#[allow(clippy::too_many_arguments)] // kernel ABI plus the arm selector
pub fn pv_inner_chunk_with_isa(
    isa: crate::kernels::dispatch::Isa,
    p: &[f32],
    chunk_codes: &[u8],
    scales: &[f32],
    zeffs: &[f32],
    bits: u8,
    d_h: usize,
    out: &mut [f32],
) {
    use crate::kernels::dispatch::{is_supported, Isa};
    pv_guards(p, chunk_codes, scales, zeffs, bits, d_h, out);
    assert!(is_supported(isa), "ISA '{isa}' not supported on this host/build");
    // Shared scalar preamble: the weight prefix sum for the zeff term,
    // computed once, identically, for every arm.
    let psum: f32 = p.iter().sum();
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe {
            // SAFETY: guards validated the slices; is_supported checked AVX2.
            crate::kernels::simd_x86::pv_inner_chunk_avx2(
                p, psum, chunk_codes, scales, zeffs, bits, d_h, out,
            )
        },
        #[cfg(all(target_arch = "x86_64", innerq_avx512))]
        Isa::Avx512 => unsafe {
            // SAFETY: guards validated the slices; is_supported checked AVX-512F.
            crate::kernels::simd_x86::pv_inner_chunk_avx512(
                p, psum, chunk_codes, scales, zeffs, bits, d_h, out,
            )
        },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe {
            // SAFETY: guards validated the slices; is_supported checked NEON.
            crate::kernels::simd_neon::pv_inner_chunk_neon(
                p, psum, chunk_codes, scales, zeffs, bits, d_h, out,
            )
        },
        _ => pv_inner_chunk_scalar_body(p, psum, chunk_codes, scales, zeffs, bits, d_h, out),
    }
}

/// The scalar (autovectorized) dispatch arm of [`pv_inner_chunk`].
#[allow(clippy::too_many_arguments)] // internal: kernel ABI plus the hoisted psum
fn pv_inner_chunk_scalar_body(
    p: &[f32],
    psum: f32,
    chunk_codes: &[u8],
    scales: &[f32],
    zeffs: &[f32],
    bits: u8,
    d_h: usize,
    out: &mut [f32],
) {
    let gbytes = packed_len(32, bits);
    let row_bytes = (d_h / 32) * gbytes;

    let mut buf = [[0f32; 32]; 4];
    for g in 0..d_h / 32 {
        // Unscaled accumulation for this channel group, entirely in
        // registers: accg[i] = Σ_t p[t] * code[t][g*32+i].
        let mut accg = [0f32; 32];
        for tb in 0..8 {
            // Unpack 4 token rows per pass, then apply their weights in
            // token order (the reference accumulation order per channel).
            for (r, b) in buf.iter_mut().enumerate() {
                let t = tb * 4 + r;
                unpack32_f32(&chunk_codes[t * row_bytes + g * gbytes..], bits, b);
            }
            for (r, b) in buf.iter().enumerate() {
                let w = p[tb * 4 + r];
                for i in 0..32 {
                    accg[i] += w * b[i];
                }
            }
        }
        // One scale application per channel per chunk (1/32 per code),
        // straight from the planar planes.
        let sg: &[f32; 32] = scales[g * 32..(g + 1) * 32].try_into().unwrap();
        let zg: &[f32; 32] = zeffs[g * 32..(g + 1) * 32].try_into().unwrap();
        let og = &mut out[g * 32..(g + 1) * 32];
        for i in 0..32 {
            og[i] += sg[i] * accg[i] + zg[i] * psum;
        }
    }
}

/// Scalar reference for [`pv_inner_chunk`]: token-major walk through the
/// generic unpacker with a `d_h`-sized accumulator. Retained as the blocked
/// kernel's bit-exactness oracle.
pub fn pv_inner_chunk_ref(
    p: &[f32],
    chunk_codes: &[u8],
    scales: &[f32],
    zeffs: &[f32],
    bits: u8,
    d_h: usize,
    out: &mut [f32],
) {
    pv_guards(p, chunk_codes, scales, zeffs, bits, d_h, out);
    let gbytes = packed_len(32, bits);
    let row_bytes = (d_h / 32) * gbytes;
    let psum: f32 = p.iter().sum();

    let mut acc = vec![0f32; d_h];
    let mut raw = [0u8; 32];
    for (t, &w) in p.iter().enumerate() {
        let row = &chunk_codes[t * row_bytes..(t + 1) * row_bytes];
        for g in 0..d_h / 32 {
            unpack(&row[g * gbytes..], bits, 32, &mut raw);
            let ag = &mut acc[g * 32..(g + 1) * 32];
            for i in 0..32 {
                ag[i] += w * raw[i] as f32;
            }
        }
    }
    for c in 0..d_h {
        out[c] += scales[c] * acc[c] + zeffs[c] * psum;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::group::{quantize, Mode};
    use crate::quant::packing::pack;
    use crate::util::ptest::{check, normal_vec, PropCfg};

    use crate::quant::GroupParams;

    /// Quantize an n x d_h matrix in the InnerQ key layout.
    pub fn build_key_rows(
        vals: &[f32],
        d_h: usize,
        bits: u8,
        mode: Mode,
    ) -> (Vec<u8>, Vec<GroupParams>) {
        let mut codes = Vec::new();
        let mut params = Vec::new();
        for row in vals.chunks_exact(d_h) {
            for g in row.chunks_exact(32) {
                let mut raw = [0u8; 32];
                params.push(quantize(mode, g, bits, &mut raw));
                pack(&raw, bits, &mut codes);
            }
        }
        (codes, params)
    }

    /// Quantize 32 tokens x d_h values (token-major input) into one
    /// token-major InnerQ value chunk (groups run along tokens per channel).
    pub fn build_val_chunk(
        vals: &[f32],
        d_h: usize,
        bits: u8,
        mode: Mode,
    ) -> (Vec<u8>, Vec<GroupParams>) {
        assert_eq!(vals.len(), 32 * d_h);
        let mut params = Vec::new();
        let mut col = [0f32; 32];
        let mut ccodes = [0u8; 32];
        let mut raw = vec![0u8; 32 * d_h];
        for c in 0..d_h {
            for t in 0..32 {
                col[t] = vals[t * d_h + c];
            }
            params.push(quantize(mode, &col, bits, &mut ccodes));
            for t in 0..32 {
                raw[t * d_h + c] = ccodes[t];
            }
        }
        let mut codes = Vec::new();
        for t in 0..32 {
            pack(&raw[t * d_h..(t + 1) * d_h], bits, &mut codes);
        }
        (codes, params)
    }

    /// Reference: dequantize-then-dot, straight from the group math.
    fn qk_reference(
        q: &[f32],
        codes: &[u8],
        params: &[GroupParams],
        bits: u8,
        d_h: usize,
        n: usize,
    ) -> Vec<f32> {
        use crate::quant::group::dequantize;
        let groups = d_h / 32;
        let gbytes = packed_len(32, bits);
        let mut out = vec![0f32; n];
        for j in 0..n {
            let mut k = vec![0f32; d_h];
            for g in 0..groups {
                let mut raw = vec![0u8; 32];
                unpack(&codes[(j * groups + g) * gbytes..], bits, 32, &mut raw);
                dequantize(&raw, params[j * groups + g], bits, &mut k[g * 32..(g + 1) * 32]);
            }
            out[j] = q.iter().zip(&k).map(|(a, b)| a * b).sum();
        }
        out
    }

    #[test]
    fn qk_inner_matches_dequant_then_dot() {
        check("qk_inner == dequant+dot", PropCfg::default(), |rng, case| {
            let d_h = if case % 2 == 0 { 64 } else { 128 };
            let n = 1 + rng.next_range(40);
            let mode = *crate::util::ptest::choose(rng, &[Mode::Sym, Mode::Asym, Mode::Hybrid]);
            let bits = *crate::util::ptest::choose(rng, &[2u8, 3, 4]);
            let q = normal_vec(rng, d_h, 1.0, 0.0);
            let keys = normal_vec(rng, n * d_h, 1.0, 0.1);
            let (codes, params) = build_key_rows(&keys, d_h, bits, mode);
            let (sc, ze) = crate::kernels::zeff_planes(&params, bits);
            let mut out = vec![0f32; n];
            qk_inner(&q, &codes, &sc, &ze, bits, d_h, &mut out);
            let want = qk_reference(&q, &codes, &params, bits, d_h, n);
            for (a, b) in out.iter().zip(&want) {
                assert!((a - b).abs() < 1e-2 * b.abs().max(1.0), "{a} vs {b}");
            }
        });
    }

    // NOTE: the blocked-vs-scalar-reference bit-identity contract (and the
    // fast-unpacker-vs-generic contract) lives in tests/kernel_parity.rs,
    // which enumerates the full bits x d_h x mode x tail-length matrix —
    // it is deliberately not duplicated here.

    #[test]
    fn qk_inner_close_to_unquantized_at_4_bits() {
        let mut rng = crate::util::rng::Rng::new(11);
        let d_h = 128;
        let n = 64;
        let q = normal_vec(&mut rng, d_h, 1.0, 0.0);
        let keys = normal_vec(&mut rng, n * d_h, 1.0, 0.0);
        let (codes, params) = build_key_rows(&keys, d_h, 4, Mode::Sym);
        let (sc, ze) = crate::kernels::zeff_planes(&params, 4);
        let mut out = vec![0f32; n];
        qk_inner(&q, &codes, &sc, &ze, 4, d_h, &mut out);
        let mut exact = vec![0f32; n];
        crate::kernels::gemv_fp::qk_fp(&q, &keys, d_h, &mut exact);
        // 4-bit sym: step = amax/7; dot error is a random walk over d_h terms.
        let rel = crate::util::stats::rel_l2(&out, &exact);
        assert!(rel < 0.12, "rel err {rel}");
    }

    #[test]
    fn pv_inner_matches_dequant_then_dot() {
        check("pv_inner == dequant+dot", PropCfg::default(), |rng, _| {
            let d_h = 64;
            let mode = *crate::util::ptest::choose(rng, &[Mode::Sym, Mode::Asym, Mode::Hybrid]);
            let bits = *crate::util::ptest::choose(rng, &[2u8, 3]);
            let vals = normal_vec(rng, 32 * d_h, 1.0, 0.1);
            let p = normal_vec(rng, 32, 0.3, 0.0);
            let (codes, params) = build_val_chunk(&vals, d_h, bits, mode);
            let (sc, ze) = crate::kernels::zeff_planes(&params, bits);
            let mut out = vec![0f32; d_h];
            pv_inner_chunk(&p, &codes, &sc, &ze, bits, d_h, &mut out);
            // reference: dequantize token rows (value = s*raw + zeff) and
            // accumulate with p
            let gbytes = packed_len(32, bits);
            let row_bytes = (d_h / 32) * gbytes;
            let mut want = vec![0f32; d_h];
            for t in 0..32 {
                let mut raw = vec![0u8; d_h];
                unpack(&codes[t * row_bytes..], bits, d_h, &mut raw);
                for c in 0..d_h {
                    want[c] += p[t] * (sc[c] * raw[c] as f32 + ze[c]);
                }
            }
            for c in 0..d_h {
                assert!((out[c] - want[c]).abs() < 1e-3, "c={c}: {} vs {}", out[c], want[c]);
            }
        });
    }

    #[test]
    fn qk_inner_supports_heads_beyond_the_stack_buffer() {
        // d_h = 2176 -> 68 groups: exercises the heap fallback for the
        // per-group query sums (the fixed 64-group buffer used to make this
        // geometry a release-mode failure).
        let mut rng = crate::util::rng::Rng::new(41);
        let d_h = 2176;
        let n = 3;
        let q = normal_vec(&mut rng, d_h, 1.0, 0.0);
        let keys = normal_vec(&mut rng, n * d_h, 1.0, 0.0);
        let (codes, params) = build_key_rows(&keys, d_h, 4, Mode::Asym);
        let (sc, ze) = crate::kernels::zeff_planes(&params, 4);
        let mut out = vec![0f32; n];
        qk_inner(&q, &codes, &sc, &ze, 4, d_h, &mut out);
        let want = qk_reference(&q, &codes, &params, 4, d_h, n);
        for (a, b) in out.iter().zip(&want) {
            assert!((a - b).abs() < 1e-2 * b.abs().max(1.0), "{a} vs {b}");
        }
    }

    #[test]
    fn pv_inner_supports_large_heads() {
        // d_h = 544: beyond the old 512-float stack accumulator; the blocked
        // kernel needs no d_h-sized scratch at all, but the geometry stays
        // covered.
        let mut rng = crate::util::rng::Rng::new(43);
        let d_h = 544;
        let vals = normal_vec(&mut rng, 32 * d_h, 1.0, 0.0);
        let p = normal_vec(&mut rng, 32, 0.2, 0.0);
        let (codes, params) = build_val_chunk(&vals, d_h, 3, Mode::Sym);
        let (sc, ze) = crate::kernels::zeff_planes(&params, 3);
        let mut out = vec![0f32; d_h];
        pv_inner_chunk(&p, &codes, &sc, &ze, 3, d_h, &mut out);
        let mut exact = vec![0f32; d_h];
        crate::kernels::gemv_fp::pv_fp(&p, &vals, d_h, &mut exact);
        assert!(
            crate::util::stats::rel_l2(&out, &exact) < 0.2,
            "rel {}",
            crate::util::stats::rel_l2(&out, &exact)
        );
    }

    #[test]
    #[should_panic(expected = "codes slice too short")]
    fn qk_inner_rejects_short_codes() {
        let q = vec![0f32; 64];
        let codes = vec![0u8; 10]; // far less than 2 rows of 2 groups
        let sc = vec![1.0f32; 4];
        let ze = vec![0.0f32; 4];
        let mut out = vec![0f32; 2];
        qk_inner(&q, &codes, &sc, &ze, 3, 64, &mut out);
    }

    #[test]
    #[should_panic(expected = "scales slice too short")]
    fn qk_inner_rejects_short_scales() {
        let q = vec![0f32; 64];
        let codes = vec![0u8; 2 * 2 * 12];
        let sc = vec![1.0f32; 1];
        let ze = vec![0.0f32; 4];
        let mut out = vec![0f32; 2];
        qk_inner(&q, &codes, &sc, &ze, 3, 64, &mut out);
    }

    #[test]
    #[should_panic(expected = "zeffs slice too short")]
    fn qk_inner_rejects_short_zeffs() {
        let q = vec![0f32; 64];
        let codes = vec![0u8; 2 * 2 * 12];
        let sc = vec![1.0f32; 4];
        let ze = vec![0.0f32; 1];
        let mut out = vec![0f32; 2];
        qk_inner(&q, &codes, &sc, &ze, 3, 64, &mut out);
    }

    #[test]
    #[should_panic(expected = "chunk_codes slice too short")]
    fn pv_inner_rejects_short_codes() {
        let p = vec![0f32; 32];
        let codes = vec![0u8; 16];
        let sc = vec![1.0f32; 64];
        let ze = vec![0.0f32; 64];
        let mut out = vec![0f32; 64];
        pv_inner_chunk(&p, &codes, &sc, &ze, 3, 64, &mut out);
    }

    #[test]
    fn value_chunk_transposes_correctly() {
        // Token t, channel c must land at channel-row c, position t.
        let d_h = 32;
        let mut vals = vec![0f32; 32 * d_h];
        vals[5 * d_h + 7] = 3.0; // token 5, channel 7
        let (codes, params) = build_val_chunk(&vals, d_h, 3, Mode::Sym);
        let (sc, ze) = crate::kernels::zeff_planes(&params, 3);
        let mut p = vec![0f32; 32];
        p[5] = 1.0;
        let mut out = vec![0f32; d_h];
        pv_inner_chunk(&p, &codes, &sc, &ze, 3, d_h, &mut out);
        assert!((out[7] - 3.0).abs() < 0.01, "out[7]={}", out[7]);
        assert!(out.iter().enumerate().all(|(c, &v)| c == 7 || v.abs() < 1e-4));
    }
}
