//! Non-quantized GEMV baseline ("Baseline (FP16)" rows of Table 4).
//!
//! Cache rows are f32 (the f32-compute stand-in for FP16 storage — see
//! DESIGN.md substitutions). These kernels set the baseline latency that the
//! quantized kernels' speedups are measured against.

/// Scores: `out[j] = q · keys[j]` for `n` rows of length `d_h`.
pub fn qk_fp(q: &[f32], keys: &[f32], d_h: usize, out: &mut [f32]) {
    debug_assert_eq!(q.len(), d_h);
    debug_assert_eq!(keys.len(), out.len() * d_h);
    for (o, row) in out.iter_mut().zip(keys.chunks_exact(d_h)) {
        // 16-lane split accumulation (one AVX-512 FMA per 16 elements).
        let mut acc = [0f32; 16];
        let mut i = 0;
        while i + 16 <= d_h {
            for j in 0..16 {
                acc[j] += q[i + j] * row[i + j];
            }
            i += 16;
        }
        let mut tail = 0.0f32;
        while i < d_h {
            tail += q[i] * row[i];
            i += 1;
        }
        *o = acc.iter().sum::<f32>() + tail;
    }
}

/// Context accumulation: `out[c] += sum_t p[t] * vals[t][c]`.
/// `vals` is `p.len()` rows of `d_h`, row-major (token-major, as a
/// non-quantized cache stores them).
pub fn pv_fp(p: &[f32], vals: &[f32], d_h: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), d_h);
    debug_assert_eq!(vals.len(), p.len() * d_h);
    for (&w, row) in p.iter().zip(vals.chunks_exact(d_h)) {
        if w == 0.0 {
            continue;
        }
        for (o, &v) in out.iter_mut().zip(row) {
            *o += w * v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ptest::{check, normal_vec, PropCfg};

    #[test]
    fn qk_matches_naive() {
        check("qk_fp == naive", PropCfg::default(), |rng, _| {
            let d_h = 64;
            let n = 1 + rng.next_range(50);
            let q = normal_vec(rng, d_h, 1.0, 0.0);
            let keys = normal_vec(rng, n * d_h, 1.0, 0.0);
            let mut out = vec![0f32; n];
            qk_fp(&q, &keys, d_h, &mut out);
            for j in 0..n {
                let want: f32 =
                    (0..d_h).map(|c| q[c] * keys[j * d_h + c]).sum();
                assert!((out[j] - want).abs() < 1e-3);
            }
        });
    }

    #[test]
    fn pv_matches_naive_and_accumulates() {
        check("pv_fp == naive", PropCfg::default(), |rng, _| {
            let d_h = 64;
            let n = 1 + rng.next_range(50);
            let p = normal_vec(rng, n, 1.0, 0.0);
            let vals = normal_vec(rng, n * d_h, 1.0, 0.0);
            let mut out = vec![1.0f32; d_h]; // nonzero: verify +=
            pv_fp(&p, &vals, d_h, &mut out);
            for c in 0..d_h {
                let want: f32 =
                    1.0 + (0..n).map(|t| p[t] * vals[t * d_h + c]).sum::<f32>();
                assert!((out[c] - want).abs() < 1e-3);
            }
        });
    }

    #[test]
    fn odd_dh_tail_handled() {
        let d_h = 7;
        let q = vec![1.0f32; d_h];
        let keys: Vec<f32> = (0..d_h).map(|i| i as f32).collect();
        let mut out = vec![0f32; 1];
        qk_fp(&q, &keys, d_h, &mut out);
        assert_eq!(out[0], 21.0);
    }
}
