//! Fused dequantize-GEMV kernels — the paper's hardware contribution
//! (§4.4), as the native CPU hot path.
//!
//! Decode-phase attention is two GEMVs per head: scores `S = q·K^T`
//! (Eq. 3) and context `o = P·V` (Eq. 5). Each kernel here fuses
//! dequantization into the multiply so codes never materialize in memory:
//!
//! * [`gemv_fp`] — FP16-equivalent baseline (f32 rows, no quantization);
//! * [`gemv_inner`] — InnerQ layout: groups along the *reduction* axis, so a
//!   group's partial dot product accumulates first and its scale applies
//!   once per 32 elements;
//! * [`gemv_outer`] — KIVI layout: groups along the *output* axis, requiring
//!   a per-channel scale vector to be combined with the query for every
//!   32-token chunk;
//! * [`gemv_turbo`] — TurboQuant: rotated basis + codebook lookups;
//! * [`quant_step`] — per-decode-step quantization kernels following each
//!   method's eviction pattern (Table 5);
//! * [`softmax`] / merge helpers used by the attention layer.
//!
//! The hot blocked kernels (`qk_inner`, `pv_inner_chunk`, `qk_outer_chunk`)
//! exist in several instruction-set arms — scalar/autovectorized plus
//! explicit AVX2, AVX-512 (x86_64) and NEON (aarch64) variants in
//! [`simd_x86`] / [`simd_neon`] — selected at runtime by [`dispatch`]
//! (overridable via `--isa` / `INNERQ_ISA`). Every arm is bit-identical to
//! the retained `*_ref` scalar oracles: the SIMD code uses separate
//! multiply + add (no FMA contraction) and reuses the scalar reduction
//! trees, so ISA selection is purely a throughput choice and the
//! decode-pipeline/prefix-sharing byte-identity contracts hold under every
//! arm. Rationale and lane layouts: `kernels/DESIGN.md`.

pub mod dispatch;
pub mod gemv_fp;
pub mod gemv_inner;
pub mod gemv_outer;
pub mod gemv_turbo;
pub mod quant_step;
#[cfg(target_arch = "aarch64")]
pub mod simd_neon;
#[cfg(target_arch = "x86_64")]
pub mod simd_x86;
pub mod softmax;

/// Effective zero term for a group: dequant is
/// `s*(code - bias) = s*code - s*bias` for symmetric groups and
/// `s*code + z` for asymmetric ones — i.e. always `s*code + zeff` with
/// `zeff = -s*bias` (sym) or `z` (asym). Precomputing `zeff` makes every
/// kernel branch-free over the hybrid mask.
#[inline(always)]
pub fn zeff(p: crate::quant::GroupParams, bits: u8) -> (f32, f32) {
    let s = p.scale_f32();
    let z = if p.is_asym() {
        p.zero_f32()
    } else {
        -s * crate::quant::group::sym_bias(bits) as f32
    };
    (s, z)
}

/// Precompute *planar* `scales[]` / `zeffs[]` f32 planes for a params slice.
/// Segments cache these shadows at quantize time so the GEMV hot loops do no
/// f16 conversion or mode branching (a GPU kernel widens __half scales
/// in-register for free; on CPU the conversion is real work, so it is
/// hoisted here). The planes are SoA rather than AoS `(scale, zeff)` pairs:
/// a contiguous f32 plane loads as whole vector registers in the blocked
/// kernels, where interleaved pairs would need a stride-2 gather that
/// defeats autovectorization (see kernels/DESIGN.md).
pub fn zeff_planes(params: &[crate::quant::GroupParams], bits: u8) -> (Vec<f32>, Vec<f32>) {
    let mut scales = Vec::with_capacity(params.len());
    let mut zeffs = Vec::with_capacity(params.len());
    for &p in params {
        let (s, z) = zeff(p, bits);
        scales.push(s);
        zeffs.push(z);
    }
    (scales, zeffs)
}
