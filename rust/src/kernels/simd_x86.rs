//! Explicit AVX2 / AVX-512 arms of the blocked fused dequant-GEMV kernels.
//!
//! Every function here recomputes its scalar counterpart's floating-point
//! operations **in the exact reference order** — separate vector multiply +
//! add, never an FMA — so the results are bit-identical to the scalar arm
//! (and therefore to the `*_ref` oracles) on every input. The lane mapping
//! is mechanical: the scalar kernels' 16-lane split accumulators become two
//! `__m256` (AVX2) or one `__m512` (AVX-512) register(s); horizontal
//! reductions spill the lanes to a stack array and reuse the *scalar*
//! reduction (`hsum16` or sequential `iter().sum()`), which keeps the
//! reduction tree identical by construction. See `kernels/DESIGN.md` §SIMD.
//!
//! Functions take pre-validated inputs: the safe `*_with_isa` wrappers in
//! [`super::gemv_inner`] / [`super::gemv_outer`] run the kernel guards and
//! the shared scalar preambles (query prefix sums, the hoisted `q·s` plane)
//! before dispatching here. The AVX-512 arm compiles only with rustc >= 1.89
//! (`innerq_avx512` cfg emitted by `build.rs`).

use super::gemv_inner::hsum16;
use crate::quant::packing::packed_len;
use crate::quant::packing::x86::unpack32_ps_avx2;
#[cfg(innerq_avx512)]
use crate::quant::packing::x86::unpack32_ps_avx512;
use std::arch::x86_64::*;

// ---------------------------------------------------------------------------
// AVX2
// ---------------------------------------------------------------------------

/// One block of `rows.len() <= 4` key rows, AVX2. The scalar block's
/// `[f32; 16]` accumulator is lanes `acc_lo` (0..8) + `acc_hi` (8..16);
/// per group: `a = q[0..16]*b[0..16] + q[16..32]*b[16..32]` elementwise
/// (two muls + one add, the reference's split accumulation), then
/// `acc += scale * a` (mul + add, no FMA).
#[target_feature(enable = "avx2")]
unsafe fn qk_inner_rows_avx2(
    q: &[f32],
    qsum: &[f32],
    rows: &[&[u8]],
    srows: &[&[f32]],
    zrows: &[&[f32]],
    bits: u8,
    gbytes: usize,
    out: &mut [f32],
) {
    let groups = qsum.len();
    let nr = rows.len();
    debug_assert!(nr <= 4 && out.len() == nr);
    let mut acc_lo = [_mm256_setzero_ps(); 4];
    let mut acc_hi = [_mm256_setzero_ps(); 4];
    let mut zterm = [0f32; 4];
    for g in 0..groups {
        let qp = q.as_ptr().add(g * 32);
        let q0 = _mm256_loadu_ps(qp);
        let q1 = _mm256_loadu_ps(qp.add(8));
        let q2 = _mm256_loadu_ps(qp.add(16));
        let q3 = _mm256_loadu_ps(qp.add(24));
        let qs = qsum[g];
        for r in 0..nr {
            let [b0, b1, b2, b3] = unpack32_ps_avx2(&rows[r][g * gbytes..], bits);
            let a_lo = _mm256_add_ps(_mm256_mul_ps(q0, b0), _mm256_mul_ps(q2, b2));
            let a_hi = _mm256_add_ps(_mm256_mul_ps(q1, b1), _mm256_mul_ps(q3, b3));
            let s = _mm256_set1_ps(srows[r][g]);
            acc_lo[r] = _mm256_add_ps(acc_lo[r], _mm256_mul_ps(s, a_lo));
            acc_hi[r] = _mm256_add_ps(acc_hi[r], _mm256_mul_ps(s, a_hi));
            zterm[r] += zrows[r][g] * qs;
        }
    }
    for r in 0..nr {
        let mut lanes = [0f32; 16];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc_lo[r]);
        _mm256_storeu_ps(lanes.as_mut_ptr().add(8), acc_hi[r]);
        out[r] = hsum16(&lanes) + zterm[r];
    }
}

/// AVX2 arm of [`super::gemv_inner::qk_inner`]. `qsum` is the per-group
/// query prefix-sum plane computed by the dispatching wrapper.
///
/// # Safety
/// Requires AVX2; the caller must have run `qk_guards` (slice lengths) on
/// these exact arguments.
#[target_feature(enable = "avx2")]
pub unsafe fn qk_inner_avx2(
    q: &[f32],
    qsum: &[f32],
    codes: &[u8],
    scales: &[f32],
    zeffs: &[f32],
    bits: u8,
    d_h: usize,
    out: &mut [f32],
) {
    let n = out.len();
    let groups = d_h / 32;
    let gbytes = packed_len(32, bits);
    let row_bytes = groups * gbytes;
    let mut j = 0usize;
    while j + 4 <= n {
        let rows: [&[u8]; 4] =
            std::array::from_fn(|r| &codes[(j + r) * row_bytes..(j + r + 1) * row_bytes]);
        let srows: [&[f32]; 4] =
            std::array::from_fn(|r| &scales[(j + r) * groups..(j + r + 1) * groups]);
        let zrows: [&[f32]; 4] =
            std::array::from_fn(|r| &zeffs[(j + r) * groups..(j + r + 1) * groups]);
        qk_inner_rows_avx2(q, qsum, &rows, &srows, &zrows, bits, gbytes, &mut out[j..j + 4]);
        j += 4;
    }
    while j < n {
        qk_inner_rows_avx2(
            q,
            qsum,
            &[&codes[j * row_bytes..(j + 1) * row_bytes]],
            &[&scales[j * groups..(j + 1) * groups]],
            &[&zeffs[j * groups..(j + 1) * groups]],
            bits,
            gbytes,
            &mut out[j..j + 1],
        );
        j += 1;
    }
}

/// AVX2 arm of [`super::gemv_inner::pv_inner_chunk`]. `psum` is the chunk's
/// softmax-weight sum, computed scalar by the wrapper (identical for every
/// arm).
///
/// # Safety
/// Requires AVX2; the caller must have run `pv_guards` on these arguments.
#[target_feature(enable = "avx2")]
pub unsafe fn pv_inner_chunk_avx2(
    p: &[f32],
    psum: f32,
    chunk_codes: &[u8],
    scales: &[f32],
    zeffs: &[f32],
    bits: u8,
    d_h: usize,
    out: &mut [f32],
) {
    let gbytes = packed_len(32, bits);
    let row_bytes = (d_h / 32) * gbytes;
    let vpsum = _mm256_set1_ps(psum);
    for g in 0..d_h / 32 {
        // Register-resident unscaled accumulator for this channel group;
        // tokens accumulate in ascending order (the reference order).
        let mut acc = [_mm256_setzero_ps(); 4];
        for (t, &w) in p.iter().enumerate() {
            let b = unpack32_ps_avx2(&chunk_codes[t * row_bytes + g * gbytes..], bits);
            let vw = _mm256_set1_ps(w);
            for (a, bj) in acc.iter_mut().zip(b) {
                *a = _mm256_add_ps(*a, _mm256_mul_ps(vw, bj));
            }
        }
        // Epilogue matches `og[i] += sg[i]*accg[i] + zg[i]*psum` exactly:
        // two muls, inner add, outer add.
        let sp = scales.as_ptr().add(g * 32);
        let zp = zeffs.as_ptr().add(g * 32);
        let op = out.as_mut_ptr().add(g * 32);
        for (j, aj) in acc.into_iter().enumerate() {
            let s = _mm256_loadu_ps(sp.add(8 * j));
            let z = _mm256_loadu_ps(zp.add(8 * j));
            let o = _mm256_loadu_ps(op.add(8 * j));
            let r =
                _mm256_add_ps(o, _mm256_add_ps(_mm256_mul_ps(s, aj), _mm256_mul_ps(z, vpsum)));
            _mm256_storeu_ps(op.add(8 * j), r);
        }
    }
}

/// One block of `rows.len() <= 4` KIVI key rows, AVX2. The two halves of
/// each group accumulate **sequentially** (half 0's add retires before half
/// 1's), mirroring the scalar reference's chained adds.
#[target_feature(enable = "avx2")]
unsafe fn qk_outer_rows_avx2(
    rows: &[&[u8]],
    qs_plane: &[f32],
    zacc: f32,
    bits: u8,
    gbytes: usize,
    d_h: usize,
    out: &mut [f32],
) {
    let nr = rows.len();
    debug_assert!(nr <= 4 && out.len() == nr);
    let mut acc_lo = [_mm256_setzero_ps(); 4];
    let mut acc_hi = [_mm256_setzero_ps(); 4];
    for g in 0..d_h / 32 {
        let qp = qs_plane.as_ptr().add(g * 32);
        let q0 = _mm256_loadu_ps(qp);
        let q1 = _mm256_loadu_ps(qp.add(8));
        let q2 = _mm256_loadu_ps(qp.add(16));
        let q3 = _mm256_loadu_ps(qp.add(24));
        for r in 0..nr {
            let [b0, b1, b2, b3] = unpack32_ps_avx2(&rows[r][g * gbytes..], bits);
            acc_lo[r] = _mm256_add_ps(acc_lo[r], _mm256_mul_ps(q0, b0));
            acc_hi[r] = _mm256_add_ps(acc_hi[r], _mm256_mul_ps(q1, b1));
            acc_lo[r] = _mm256_add_ps(acc_lo[r], _mm256_mul_ps(q2, b2));
            acc_hi[r] = _mm256_add_ps(acc_hi[r], _mm256_mul_ps(q3, b3));
        }
    }
    for r in 0..nr {
        let mut lanes = [0f32; 16];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc_lo[r]);
        _mm256_storeu_ps(lanes.as_mut_ptr().add(8), acc_hi[r]);
        // The outer reference reduces sequentially (`iter().sum()`), not
        // pairwise — reuse exactly that.
        out[r] = lanes.iter().sum::<f32>() + zacc;
    }
}

/// AVX2 arm of [`super::gemv_outer::qk_outer_chunk`]. `qs_plane`/`zacc` are
/// the hoisted `q_c*s_c` plane and zero term computed by the wrapper.
///
/// # Safety
/// Requires AVX2; the caller must have run `qk_outer_guards` and filled
/// `qs_plane` for these arguments.
#[target_feature(enable = "avx2")]
pub unsafe fn qk_outer_chunk_avx2(
    chunk_codes: &[u8],
    qs_plane: &[f32],
    zacc: f32,
    bits: u8,
    d_h: usize,
    out: &mut [f32],
) {
    let n_rows = out.len();
    let gbytes = packed_len(32, bits);
    let row_bytes = (d_h / 32) * gbytes;
    let mut j = 0usize;
    while j + 4 <= n_rows {
        let rows: [&[u8]; 4] =
            std::array::from_fn(|r| &chunk_codes[(j + r) * row_bytes..(j + r + 1) * row_bytes]);
        qk_outer_rows_avx2(&rows, qs_plane, zacc, bits, gbytes, d_h, &mut out[j..j + 4]);
        j += 4;
    }
    while j < n_rows {
        qk_outer_rows_avx2(
            &[&chunk_codes[j * row_bytes..(j + 1) * row_bytes]],
            qs_plane,
            zacc,
            bits,
            gbytes,
            d_h,
            &mut out[j..j + 1],
        );
        j += 1;
    }
}

// ---------------------------------------------------------------------------
// AVX-512 (rustc >= 1.89 only; see build.rs)
// ---------------------------------------------------------------------------

/// One block of `rows.len() <= 4` key rows, AVX-512: the full 16-lane
/// accumulator is one `__m512` per row.
#[cfg(innerq_avx512)]
#[target_feature(enable = "avx512f")]
unsafe fn qk_inner_rows_avx512(
    q: &[f32],
    qsum: &[f32],
    rows: &[&[u8]],
    srows: &[&[f32]],
    zrows: &[&[f32]],
    bits: u8,
    gbytes: usize,
    out: &mut [f32],
) {
    let groups = qsum.len();
    let nr = rows.len();
    debug_assert!(nr <= 4 && out.len() == nr);
    let mut acc = [_mm512_setzero_ps(); 4];
    let mut zterm = [0f32; 4];
    for g in 0..groups {
        let qp = q.as_ptr().add(g * 32);
        let q_lo = _mm512_loadu_ps(qp);
        let q_hi = _mm512_loadu_ps(qp.add(16));
        let qs = qsum[g];
        for r in 0..nr {
            let [b_lo, b_hi] = unpack32_ps_avx512(&rows[r][g * gbytes..], bits);
            let a = _mm512_add_ps(_mm512_mul_ps(q_lo, b_lo), _mm512_mul_ps(q_hi, b_hi));
            let s = _mm512_set1_ps(srows[r][g]);
            acc[r] = _mm512_add_ps(acc[r], _mm512_mul_ps(s, a));
            zterm[r] += zrows[r][g] * qs;
        }
    }
    for r in 0..nr {
        let mut lanes = [0f32; 16];
        _mm512_storeu_ps(lanes.as_mut_ptr(), acc[r]);
        out[r] = hsum16(&lanes) + zterm[r];
    }
}

/// AVX-512 arm of [`super::gemv_inner::qk_inner`].
///
/// # Safety
/// Requires AVX-512F; the caller must have run `qk_guards` on these
/// arguments.
#[cfg(innerq_avx512)]
#[target_feature(enable = "avx512f")]
pub unsafe fn qk_inner_avx512(
    q: &[f32],
    qsum: &[f32],
    codes: &[u8],
    scales: &[f32],
    zeffs: &[f32],
    bits: u8,
    d_h: usize,
    out: &mut [f32],
) {
    let n = out.len();
    let groups = d_h / 32;
    let gbytes = packed_len(32, bits);
    let row_bytes = groups * gbytes;
    let mut j = 0usize;
    while j + 4 <= n {
        let rows: [&[u8]; 4] =
            std::array::from_fn(|r| &codes[(j + r) * row_bytes..(j + r + 1) * row_bytes]);
        let srows: [&[f32]; 4] =
            std::array::from_fn(|r| &scales[(j + r) * groups..(j + r + 1) * groups]);
        let zrows: [&[f32]; 4] =
            std::array::from_fn(|r| &zeffs[(j + r) * groups..(j + r + 1) * groups]);
        qk_inner_rows_avx512(q, qsum, &rows, &srows, &zrows, bits, gbytes, &mut out[j..j + 4]);
        j += 4;
    }
    while j < n {
        qk_inner_rows_avx512(
            q,
            qsum,
            &[&codes[j * row_bytes..(j + 1) * row_bytes]],
            &[&scales[j * groups..(j + 1) * groups]],
            &[&zeffs[j * groups..(j + 1) * groups]],
            bits,
            gbytes,
            &mut out[j..j + 1],
        );
        j += 1;
    }
}

/// AVX-512 arm of [`super::gemv_inner::pv_inner_chunk`].
///
/// # Safety
/// Requires AVX-512F; the caller must have run `pv_guards` on these
/// arguments.
#[cfg(innerq_avx512)]
#[target_feature(enable = "avx512f")]
pub unsafe fn pv_inner_chunk_avx512(
    p: &[f32],
    psum: f32,
    chunk_codes: &[u8],
    scales: &[f32],
    zeffs: &[f32],
    bits: u8,
    d_h: usize,
    out: &mut [f32],
) {
    let gbytes = packed_len(32, bits);
    let row_bytes = (d_h / 32) * gbytes;
    let vpsum = _mm512_set1_ps(psum);
    for g in 0..d_h / 32 {
        let mut acc = [_mm512_setzero_ps(); 2];
        for (t, &w) in p.iter().enumerate() {
            let b = unpack32_ps_avx512(&chunk_codes[t * row_bytes + g * gbytes..], bits);
            let vw = _mm512_set1_ps(w);
            for (a, bj) in acc.iter_mut().zip(b) {
                *a = _mm512_add_ps(*a, _mm512_mul_ps(vw, bj));
            }
        }
        let sp = scales.as_ptr().add(g * 32);
        let zp = zeffs.as_ptr().add(g * 32);
        let op = out.as_mut_ptr().add(g * 32);
        for (j, aj) in acc.into_iter().enumerate() {
            let s = _mm512_loadu_ps(sp.add(16 * j));
            let z = _mm512_loadu_ps(zp.add(16 * j));
            let o = _mm512_loadu_ps(op.add(16 * j));
            let r =
                _mm512_add_ps(o, _mm512_add_ps(_mm512_mul_ps(s, aj), _mm512_mul_ps(z, vpsum)));
            _mm512_storeu_ps(op.add(16 * j), r);
        }
    }
}

/// One block of `rows.len() <= 4` KIVI key rows, AVX-512. Halves accumulate
/// sequentially per the outer reference.
#[cfg(innerq_avx512)]
#[target_feature(enable = "avx512f")]
unsafe fn qk_outer_rows_avx512(
    rows: &[&[u8]],
    qs_plane: &[f32],
    zacc: f32,
    bits: u8,
    gbytes: usize,
    d_h: usize,
    out: &mut [f32],
) {
    let nr = rows.len();
    debug_assert!(nr <= 4 && out.len() == nr);
    let mut acc = [_mm512_setzero_ps(); 4];
    for g in 0..d_h / 32 {
        let qp = qs_plane.as_ptr().add(g * 32);
        let q_lo = _mm512_loadu_ps(qp);
        let q_hi = _mm512_loadu_ps(qp.add(16));
        for r in 0..nr {
            let [b_lo, b_hi] = unpack32_ps_avx512(&rows[r][g * gbytes..], bits);
            acc[r] = _mm512_add_ps(acc[r], _mm512_mul_ps(q_lo, b_lo));
            acc[r] = _mm512_add_ps(acc[r], _mm512_mul_ps(q_hi, b_hi));
        }
    }
    for r in 0..nr {
        let mut lanes = [0f32; 16];
        _mm512_storeu_ps(lanes.as_mut_ptr(), acc[r]);
        out[r] = lanes.iter().sum::<f32>() + zacc;
    }
}

/// AVX-512 arm of [`super::gemv_outer::qk_outer_chunk`].
///
/// # Safety
/// Requires AVX-512F; the caller must have run `qk_outer_guards` and filled
/// `qs_plane` for these arguments.
#[cfg(innerq_avx512)]
#[target_feature(enable = "avx512f")]
pub unsafe fn qk_outer_chunk_avx512(
    chunk_codes: &[u8],
    qs_plane: &[f32],
    zacc: f32,
    bits: u8,
    d_h: usize,
    out: &mut [f32],
) {
    let n_rows = out.len();
    let gbytes = packed_len(32, bits);
    let row_bytes = (d_h / 32) * gbytes;
    let mut j = 0usize;
    while j + 4 <= n_rows {
        let rows: [&[u8]; 4] =
            std::array::from_fn(|r| &chunk_codes[(j + r) * row_bytes..(j + r + 1) * row_bytes]);
        qk_outer_rows_avx512(&rows, qs_plane, zacc, bits, gbytes, d_h, &mut out[j..j + 4]);
        j += 4;
    }
    while j < n_rows {
        qk_outer_rows_avx512(
            &[&chunk_codes[j * row_bytes..(j + 1) * row_bytes]],
            qs_plane,
            zacc,
            bits,
            gbytes,
            d_h,
            &mut out[j..j + 1],
        );
        j += 1;
    }
}
