//! KIVI-layout fused dequantize-GEMV: quantization groups run along the
//! *outer* (output) dimension.
//!
//! For the key cache this means per-channel groups spanning 32 tokens: every
//! dot product `q·K_j` needs a *different* scale for each of the `d_h`
//! channels. The kernel hoists what it can — `q_c·s_c` and the zero term are
//! precomputed per 32-token chunk — but that hoisted vector is `d_h` wide
//! (vs. `d_h/32` scales in the inner layout) and must be re-materialized for
//! every chunk. On a GPU the same structure shows up as per-lane scale loads
//! with no reuse across the warp (§4.4, Fig. 1a); on CPU it shows up as the
//! extra `qs`/`zs` buffer traffic and per-chunk setup measured in Table 4.
//!
//! The key kernel is *blocked* like `gemv_inner::qk_inner`: 4 token rows per
//! pass, with the hoisted `q_c·s_c` plane and the zero term loaded once per
//! block and the four rows' accumulator chains interleaving in the OoO
//! window. Per row the floating-point operation order is exactly the
//! retained scalar reference's ([`qk_outer_chunk_ref`]), so the blocked
//! kernel is bit-identical for any row count — asserted across the full
//! bits × d_h × mode × tail-length matrix in `tests/kernel_parity.rs` and
//! before every timing run in `benches/kernel_throughput.rs`. Layout and
//! blocking rationale: `kernels/DESIGN.md`.

use crate::quant::packing::{packed_len, unpack32_f32};

/// Shared per-call guards for the blocked and reference key kernels.
fn qk_outer_guards(
    q: &[f32],
    chunk_codes: &[u8],
    scales: &[f32],
    zeffs: &[f32],
    bits: u8,
    d_h: usize,
    scratch: &[f32],
    n_rows: usize,
) {
    debug_assert!(n_rows <= 32);
    debug_assert_eq!(q.len(), d_h);
    debug_assert_eq!(scales.len(), d_h);
    debug_assert_eq!(zeffs.len(), d_h);
    debug_assert!(scratch.len() >= d_h);
    let row_bytes = (d_h / 32) * packed_len(32, bits);
    debug_assert!(chunk_codes.len() >= n_rows * row_bytes);
    let _ = (q, chunk_codes, scales, zeffs, scratch);
}

/// One block of `R` token rows against the hoisted `q_c·s_c` plane. Per row
/// the operation order is exactly the scalar reference's (group-ascending,
/// 16-lane split accumulation over the two halves, sequential lane sum at
/// the end), so any `R` produces bit-identical scores.
#[inline(always)]
fn qk_outer_block<const R: usize>(
    rows: [&[u8]; R],
    qs_plane: &[f32],
    zacc: f32,
    bits: u8,
    gbytes: usize,
    d_h: usize,
    out: &mut [f32],
) {
    let mut row_acc = [[0f32; 16]; R];
    let mut buf = [0f32; 32];
    for g in 0..d_h / 32 {
        let qs = &qs_plane[g * 32..(g + 1) * 32];
        for r in 0..R {
            unpack32_f32(&rows[r][g * gbytes..], bits, &mut buf);
            for half in 0..2 {
                let (qh, bh) =
                    (&qs[half * 16..(half + 1) * 16], &buf[half * 16..(half + 1) * 16]);
                for i in 0..16 {
                    row_acc[r][i] += qh[i] * bh[i];
                }
            }
        }
    }
    for r in 0..R {
        out[r] = row_acc[r].iter().sum::<f32>() + zacc;
    }
}

/// Key-cache scores, KIVI layout. One chunk = 32 consecutive tokens:
///
/// * `chunk_codes`: 32 token rows × `d_h` codes, packed row-major;
/// * `scales` / `zeffs`: planar per-channel parameter planes, `d_h` f32 each
///   (channel `c` shared by the chunk's tokens);
/// * `out`: scores for the chunk's `n_rows` tokens (≤ 32; tail chunks are
///   shorter only transiently during bulk prefill quantization).
///
/// `scratch` must hold `d_h` f32; it carries the hoisted `q_c·s_c` products.
/// Dispatches to the widest bit-identical ISA arm the host supports (see
/// [`crate::kernels::dispatch`]); every arm is blocked 4 rows per pass and
/// bit-identical to [`qk_outer_chunk_ref`] for any row count.
#[allow(clippy::too_many_arguments)] // kernel ABI: planar planes are separate planes by design
pub fn qk_outer_chunk(
    q: &[f32],
    chunk_codes: &[u8],
    scales: &[f32],
    zeffs: &[f32],
    bits: u8,
    d_h: usize,
    scratch: &mut [f32],
    out: &mut [f32],
) {
    qk_outer_chunk_with_isa(
        crate::kernels::dispatch::active(),
        q,
        chunk_codes,
        scales,
        zeffs,
        bits,
        d_h,
        scratch,
        out,
    )
}

/// [`qk_outer_chunk`] pinned to a specific dispatch arm. The parity tests
/// and the kernel bench enumerate [`crate::kernels::dispatch::supported`]
/// through this entry point; production code uses the dispatching wrapper.
///
/// # Panics
/// Panics if `isa` is not supported on this host/build.
#[allow(clippy::too_many_arguments)] // kernel ABI plus the arm selector
pub fn qk_outer_chunk_with_isa(
    isa: crate::kernels::dispatch::Isa,
    q: &[f32],
    chunk_codes: &[u8],
    scales: &[f32],
    zeffs: &[f32],
    bits: u8,
    d_h: usize,
    scratch: &mut [f32],
    out: &mut [f32],
) {
    use crate::kernels::dispatch::{is_supported, Isa};
    let n_rows = out.len();
    qk_outer_guards(q, chunk_codes, scales, zeffs, bits, d_h, scratch, n_rows);
    assert!(is_supported(isa), "ISA '{isa}' not supported on this host/build");

    // Shared scalar preamble for every arm: hoist per-channel scale/zero
    // into query space once per chunk — one pass over d_h, straight
    // multiplies over contiguous planes (no pair deinterleave). The plane
    // is then loaded once per 4-row block.
    let mut zacc = 0.0f32;
    for c in 0..d_h {
        scratch[c] = q[c] * scales[c];
        zacc += q[c] * zeffs[c];
    }

    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe {
            // SAFETY: guards validated the slices; is_supported checked AVX2.
            crate::kernels::simd_x86::qk_outer_chunk_avx2(chunk_codes, scratch, zacc, bits, d_h, out)
        },
        #[cfg(all(target_arch = "x86_64", innerq_avx512))]
        Isa::Avx512 => unsafe {
            // SAFETY: guards validated the slices; is_supported checked AVX-512F.
            crate::kernels::simd_x86::qk_outer_chunk_avx512(chunk_codes, scratch, zacc, bits, d_h, out)
        },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe {
            // SAFETY: guards validated the slices; is_supported checked NEON.
            crate::kernels::simd_neon::qk_outer_chunk_neon(chunk_codes, scratch, zacc, bits, d_h, out)
        },
        _ => qk_outer_chunk_scalar_body(chunk_codes, scratch, zacc, bits, d_h, out),
    }
}

/// The scalar (autovectorized) dispatch arm: the original blocked kernel,
/// minus the guards/hoist preamble lifted into the wrapper.
fn qk_outer_chunk_scalar_body(
    chunk_codes: &[u8],
    qs_plane: &[f32],
    zacc: f32,
    bits: u8,
    d_h: usize,
    out: &mut [f32],
) {
    let n_rows = out.len();
    let gbytes = packed_len(32, bits);
    let row_bytes = (d_h / 32) * gbytes;

    let mut j = 0usize;
    while j + 4 <= n_rows {
        let rows: [&[u8]; 4] =
            std::array::from_fn(|r| &chunk_codes[(j + r) * row_bytes..(j + r + 1) * row_bytes]);
        qk_outer_block::<4>(rows, qs_plane, zacc, bits, gbytes, d_h, &mut out[j..j + 4]);
        j += 4;
    }
    // Tail rows (n_rows % 4) go through the same block kernel one row at a
    // time — identical per-row op order, so the tail is bit-identical too.
    while j < n_rows {
        qk_outer_block::<1>(
            [&chunk_codes[j * row_bytes..(j + 1) * row_bytes]],
            qs_plane,
            zacc,
            bits,
            gbytes,
            d_h,
            &mut out[j..j + 1],
        );
        j += 1;
    }
}

/// Scalar reference for [`qk_outer_chunk`]: one row at a time. Retained as
/// the blocked kernel's bit-exactness oracle (the parity tests assert
/// `qk_outer_chunk == qk_outer_chunk_ref` exactly) and as the pre-blocking
/// production shape, so the kernel bench's baseline comparison stays
/// honest.
#[allow(clippy::too_many_arguments)] // kernel ABI mirrors the blocked entry point
pub fn qk_outer_chunk_ref(
    q: &[f32],
    chunk_codes: &[u8],
    scales: &[f32],
    zeffs: &[f32],
    bits: u8,
    d_h: usize,
    scratch: &mut [f32],
    out: &mut [f32],
) {
    let n_rows = out.len();
    qk_outer_guards(q, chunk_codes, scales, zeffs, bits, d_h, scratch, n_rows);
    let gbytes = packed_len(32, bits);
    let row_bytes = (d_h / 32) * gbytes;

    let mut zacc = 0.0f32;
    for c in 0..d_h {
        scratch[c] = q[c] * scales[c];
        zacc += q[c] * zeffs[c];
    }

    let mut buf = [0f32; 32];
    for (j, o) in out.iter_mut().enumerate() {
        let row = &chunk_codes[j * row_bytes..(j + 1) * row_bytes];
        // 16-lane split accumulation (see gemv_inner): vectorizable FMA.
        let mut acc = [0f32; 16];
        for g in 0..d_h / 32 {
            unpack32_f32(&row[g * gbytes..], bits, &mut buf);
            let qs = &scratch[g * 32..(g + 1) * 32];
            for half in 0..2 {
                let (qh, bh) =
                    (&qs[half * 16..(half + 1) * 16], &buf[half * 16..(half + 1) * 16]);
                for i in 0..16 {
                    acc[i] += qh[i] * bh[i];
                }
            }
        }
        *o = acc.iter().sum::<f32>() + zacc;
    }
}

/// Value-cache context accumulation, KIVI layout: per-token groups along the
/// channel axis. One call processes one token row (KIVI quantizes values one
/// token at a time):
///
/// * `row_codes`: `d_h` packed codes for this token;
/// * `scales` / `zeffs`: planar planes, `d_h/32` f32 each, for this token's
///   channel groups;
/// * `w`: this token's softmax weight.
///
/// Accumulates `out[c] += w * dequant(V[t][c])`.
pub fn pv_outer_row(
    w: f32,
    row_codes: &[u8],
    scales: &[f32],
    zeffs: &[f32],
    bits: u8,
    d_h: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), d_h);
    debug_assert_eq!(scales.len(), d_h / 32);
    debug_assert_eq!(zeffs.len(), d_h / 32);
    let gbytes = packed_len(32, bits);
    let mut buf = [0f32; 32];
    for g in 0..d_h / 32 {
        unpack32_f32(&row_codes[g * gbytes..], bits, &mut buf);
        let (a, b) = (w * scales[g], w * zeffs[g]);
        let og = &mut out[g * 32..(g + 1) * 32];
        for i in 0..32 {
            og[i] += a * buf[i] + b;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::group::{dequantize, quantize, Mode};
    use crate::quant::GroupParams;
    use crate::quant::packing::{pack, unpack};
    use crate::util::ptest::{check, choose, normal_vec, PropCfg};

    /// Build one KIVI key chunk from 32 tokens x d_h values (token-major):
    /// groups run along the token axis per channel; codes stay token-major.
    pub fn build_key_chunk(
        vals: &[f32],
        d_h: usize,
        bits: u8,
        mode: Mode,
    ) -> (Vec<u8>, Vec<GroupParams>) {
        assert_eq!(vals.len(), 32 * d_h);
        let mut params = vec![GroupParams::default(); d_h];
        let mut raw = vec![0u8; 32 * d_h]; // token-major raw codes
        let mut col = [0f32; 32];
        let mut ccodes = [0u8; 32];
        for c in 0..d_h {
            for t in 0..32 {
                col[t] = vals[t * d_h + c];
            }
            params[c] = quantize(mode, &col, bits, &mut ccodes);
            for t in 0..32 {
                raw[t * d_h + c] = ccodes[t];
            }
        }
        let mut codes = Vec::new();
        for t in 0..32 {
            pack(&raw[t * d_h..(t + 1) * d_h], bits, &mut codes);
        }
        (codes, params)
    }

    /// Build one KIVI value row: per-token groups along channels.
    pub fn build_val_row(
        row: &[f32],
        bits: u8,
        mode: Mode,
    ) -> (Vec<u8>, Vec<GroupParams>) {
        let mut codes = Vec::new();
        let mut params = Vec::new();
        for g in row.chunks_exact(32) {
            let mut raw = [0u8; 32];
            params.push(quantize(mode, g, bits, &mut raw));
            pack(&raw, bits, &mut codes);
        }
        (codes, params)
    }

    #[test]
    fn qk_outer_matches_dequant_then_dot() {
        check("qk_outer == dequant+dot", PropCfg::default(), |rng, _| {
            let d_h = 128;
            let bits = *choose(rng, &[2u8, 3, 4]);
            let mode = *choose(rng, &[Mode::Sym, Mode::Asym]);
            let q = normal_vec(rng, d_h, 1.0, 0.0);
            let keys = normal_vec(rng, 32 * d_h, 1.0, 0.1);
            let (codes, params) = build_key_chunk(&keys, d_h, bits, mode);
            let (sc, ze) = crate::kernels::zeff_planes(&params, bits);
            let mut scratch = vec![0f32; d_h];
            let mut out = vec![0f32; 32];
            qk_outer_chunk(&q, &codes, &sc, &ze, bits, d_h, &mut scratch, &mut out);
            // reference: per token, dequantize channel-wise and dot
            let gbytes = packed_len(32, bits);
            for j in 0..32 {
                let mut raw = vec![0u8; d_h];
                for g in 0..d_h / 32 {
                    unpack(
                        &codes[j * (d_h / 32) * gbytes + g * gbytes..],
                        bits,
                        32,
                        &mut raw[g * 32..(g + 1) * 32],
                    );
                }
                let want: f32 = (0..d_h)
                    .map(|c| {
                        let mut v = [0f32];
                        dequantize(&raw[c..c + 1], params[c], bits, &mut v);
                        q[c] * v[0]
                    })
                    .sum();
                assert!(
                    (out[j] - want).abs() < 2e-2 * want.abs().max(1.0),
                    "j={j}: {} vs {want}",
                    out[j]
                );
            }
        });
    }

    #[test]
    fn kivi_isolates_channel_outliers() {
        // The motivating KIVI property: a persistent channel outlier stays in
        // one (channel) group and does not blow up other channels' scales.
        let d_h = 64;
        let mut keys = vec![0.1f32; 32 * d_h];
        for t in 0..32 {
            keys[t * d_h + 3] = 50.0; // hot channel 3
        }
        let (codes, params) = build_key_chunk(&keys, d_h, 2, Mode::Asym);
        // channel 7's group must still resolve 0.1 well
        let gbytes = packed_len(32, 2);
        let mut raw = vec![0u8; d_h];
        unpack(&codes[0..], 2, 32, &mut raw[0..32]);
        unpack(&codes[gbytes..], 2, 32, &mut raw[32..64]);
        let mut v = [0f32];
        dequantize(&raw[7..8], params[7], 2, &mut v);
        assert!((v[0] - 0.1).abs() < 1e-3, "channel 7 dequant {}", v[0]);
    }

    #[test]
    fn pv_outer_matches_dequant_then_dot() {
        check("pv_outer == dequant+dot", PropCfg::default(), |rng, _| {
            let d_h = 64;
            let bits = *choose(rng, &[2u8, 3]);
            let row = normal_vec(rng, d_h, 1.0, 0.1);
            let w = rng.next_f32();
            let (codes, params) = build_val_row(&row, bits, Mode::Asym);
            let (sc, ze) = crate::kernels::zeff_planes(&params, bits);
            let mut out = vec![0f32; d_h];
            pv_outer_row(w, &codes, &sc, &ze, bits, d_h, &mut out);
            let gbytes = packed_len(32, bits);
            for g in 0..d_h / 32 {
                let mut raw = vec![0u8; 32];
                unpack(&codes[g * gbytes..], bits, 32, &mut raw);
                let mut deq = vec![0f32; 32];
                dequantize(&raw, params[g], bits, &mut deq);
                for i in 0..32 {
                    let want = w * deq[i];
                    assert!((out[g * 32 + i] - want).abs() < 1e-4);
                }
            }
        });
    }
}
