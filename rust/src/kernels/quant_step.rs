//! Per-decode-step quantization kernels (Table 5).
//!
//! Tokens evicted from the high-precision windows are quantized on the decode
//! path; the *cadence* is set by the grouping axis (§5.3): InnerQ quantizes
//! one key token every step and 32 value tokens every 32 steps; KIVI is
//! mirrored; TurboQuant quantizes one key and one value every step. These
//! free functions perform exactly one method's per-step quantization work so
//! the Table-5 bench can measure it in isolation (amortized per step).

use crate::cache::segments::{
    InnerKeySegment, InnerValSegment, OuterKeySegment, OuterValSegment, TurboKeySegment,
    TurboValSegment,
};

/// InnerQ per-step key work: quantize 1 token.
pub fn step_inner_key(seg: &mut InnerKeySegment, k: &[f32]) {
    seg.append_token(k);
}

/// InnerQ per-32-step value work: quantize a 32-token chunk.
pub fn step_inner_val(seg: &mut InnerValSegment, vs: &[f32]) {
    seg.append_chunk(vs);
}

/// KIVI per-32-step key work: quantize a 32-token chunk.
pub fn step_outer_key(seg: &mut OuterKeySegment, ks: &[f32]) {
    seg.append_chunk(ks);
}

/// KIVI per-step value work: quantize 1 token.
pub fn step_outer_val(seg: &mut OuterValSegment, v: &[f32]) {
    seg.append_token(v);
}

/// TurboQuant per-step work: rotate + codebook-quantize 1 token.
pub fn step_turbo_key(seg: &mut TurboKeySegment, k: &[f32]) {
    seg.append_token(k);
}

/// TurboQuant per-step value work: rotate + codebook-quantize 1 token.
pub fn step_turbo_val(seg: &mut TurboValSegment, v: &[f32]) {
    seg.append_token(v);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::group::Mode;
    use crate::util::ptest::normal_vec;
    use crate::util::rng::Rng;

    #[test]
    fn cadence_amortization_identity() {
        // One 32-token chunk == 32 amortized steps: segment lengths agree.
        let d_h = 128;
        let mut rng = Rng::new(1);
        let mut ik = InnerKeySegment::new(d_h, 3, Mode::Sym);
        let mut iv = InnerValSegment::new(d_h, 3, Mode::Sym);
        let toks = normal_vec(&mut rng, 32 * d_h, 1.0, 0.0);
        for t in 0..32 {
            step_inner_key(&mut ik, &toks[t * d_h..(t + 1) * d_h]);
        }
        step_inner_val(&mut iv, &toks);
        assert_eq!(ik.len(), 32);
        assert_eq!(iv.len(), 32);
    }

    #[test]
    fn turbo_steps_append_single_tokens() {
        let d_h = 128;
        let mut rng = Rng::new(2);
        let mut tk = TurboKeySegment::new(d_h, 4, 42);
        let mut tv = TurboValSegment::new(d_h, 3, 43);
        for _ in 0..5 {
            let k = normal_vec(&mut rng, d_h, 1.0, 0.0);
            step_turbo_key(&mut tk, &k);
            step_turbo_val(&mut tv, &k);
        }
        assert_eq!(tk.len(), 5);
        assert_eq!(tv.len(), 5);
    }

    #[test]
    fn kivi_steps_mirror_innerq() {
        let d_h = 128;
        let mut rng = Rng::new(3);
        let mut ok = OuterKeySegment::new(d_h, 2, Mode::Asym);
        let mut ov = OuterValSegment::new(d_h, 2, Mode::Asym);
        let toks = normal_vec(&mut rng, 32 * d_h, 1.0, 0.0);
        step_outer_key(&mut ok, &toks);
        for t in 0..32 {
            step_outer_val(&mut ov, &toks[t * d_h..(t + 1) * d_h]);
        }
        assert_eq!(ok.len(), 32);
        assert_eq!(ov.len(), 32);
    }
}
