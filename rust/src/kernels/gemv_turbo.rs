//! TurboQuant fused dequantize-GEMV: scores and context are computed in the
//! *rotated* basis (the rotation is orthogonal, so `<q,k> = <Rq,Rk>`), and
//! every dequantized element comes from a codebook lookup. The lookup is the
//! latency cost the paper measures against InnerQ's multiply-only dequant
//! (§5.3: "the codebook lookup requires multiple accesses to CUDA shared
//! memory"); on CPU it is an in-register table index that still breaks the
//! pure-FMA pipeline.

use crate::quant::packing::{packed_len, unpack32};
use crate::quant::turbo::TurboToken;

/// Key-cache scores: `out[j] = norm_j * Σ_c q_rot[c] * CB[code_{j,c}]`.
/// `q_rot` must already be rotated with the segment's rotation.
pub fn qk_turbo(
    q_rot: &[f32],
    tokens: &[TurboToken],
    codebook: &[f32],
    bits: u8,
    d_h: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), tokens.len());
    debug_assert_eq!(d_h % 32, 0);
    let gbytes = packed_len(32, bits);
    let mut buf = [0u8; 32];
    for (o, tok) in out.iter_mut().zip(tokens) {
        // split accumulators (see gemv_inner); the codebook gather itself
        // stays a per-element lookup — that is TurboQuant's structural cost.
        let mut acc = [0f32; 16];
        for g in 0..d_h / 32 {
            unpack32(&tok.codes[g * gbytes..], bits, &mut buf);
            let qg = &q_rot[g * 32..(g + 1) * 32];
            for half in 0..2 {
                let (qh, bh) =
                    (&qg[half * 16..(half + 1) * 16], &buf[half * 16..(half + 1) * 16]);
                for i in 0..16 {
                    acc[i] += qh[i] * codebook[bh[i] as usize];
                }
            }
        }
        *o = acc.iter().sum::<f32>() * tok.norm;
    }
}

/// Value-cache accumulation in the rotated basis:
/// `out_rot[c] += Σ_t p[t] * norm_t * CB[code_{t,c}]`.
/// The caller un-rotates `out_rot` once per decode step (see
/// `cache::segments::TurboValSegment::finalize`).
pub fn pv_turbo(
    p: &[f32],
    tokens: &[TurboToken],
    codebook: &[f32],
    bits: u8,
    d_h: usize,
    out_rot: &mut [f32],
) {
    debug_assert_eq!(p.len(), tokens.len());
    debug_assert_eq!(out_rot.len(), d_h);
    let gbytes = packed_len(32, bits);
    let mut buf = [0u8; 32];
    for (&w, tok) in p.iter().zip(tokens) {
        let a = w * tok.norm;
        if a == 0.0 {
            continue;
        }
        for g in 0..d_h / 32 {
            unpack32(&tok.codes[g * gbytes..], bits, &mut buf);
            let og = &mut out_rot[g * 32..(g + 1) * 32];
            for i in 0..32 {
                og[i] += a * codebook[buf[i] as usize];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::turbo::{codebook, dequantize_rotated, quantize_token, Rotation};
    use crate::util::ptest::{check, normal_vec, PropCfg};

    #[test]
    fn qk_turbo_matches_dequant_then_dot() {
        check("qk_turbo == dequant+dot", PropCfg::default(), |rng, _| {
            let d_h = 128;
            let bits = 4u8;
            let rot = Rotation::new(d_h, 42);
            let n = 1 + rng.next_range(20);
            let keys: Vec<Vec<f32>> = (0..n).map(|_| normal_vec(rng, d_h, 1.0, 0.05)).collect();
            let tokens: Vec<_> = keys.iter().map(|k| quantize_token(&rot, k, bits)).collect();
            let q = normal_vec(rng, d_h, 1.0, 0.0);
            let mut q_rot = q.clone();
            rot.apply(&mut q_rot);
            let mut out = vec![0f32; n];
            qk_turbo(&q_rot, &tokens, codebook(bits), bits, d_h, &mut out);
            for j in 0..n {
                let mut deq = vec![0f32; d_h];
                dequantize_rotated(&tokens[j], bits, d_h, &mut deq);
                let want: f32 = q_rot.iter().zip(&deq).map(|(a, b)| a * b).sum();
                assert!((out[j] - want).abs() < 1e-3 * want.abs().max(1.0));
            }
        });
    }

    #[test]
    fn scores_approximate_unquantized_dot() {
        // End-to-end: quantized rotated scores track the true q·k.
        let mut rng = crate::util::rng::Rng::new(7);
        let d_h = 128;
        let rot = Rotation::new(d_h, 42);
        let n = 128;
        let keys: Vec<Vec<f32>> = (0..n).map(|_| normal_vec(&mut rng, d_h, 1.0, 0.0)).collect();
        let tokens: Vec<_> = keys.iter().map(|k| quantize_token(&rot, k, 4)).collect();
        let q = normal_vec(&mut rng, d_h, 1.0, 0.0);
        let mut q_rot = q.clone();
        rot.apply(&mut q_rot);
        let mut out = vec![0f32; n];
        qk_turbo(&q_rot, &tokens, codebook(4), 4, d_h, &mut out);
        let want: Vec<f32> = keys
            .iter()
            .map(|k| q.iter().zip(k).map(|(a, b)| a * b).sum())
            .collect();
        // Vector-level relative error (per-score relative error is undefined
        // near zero-mean dot products).
        let rel = crate::util::stats::rel_l2(&out, &want);
        assert!(rel < 0.2, "rel l2 {rel}");
    }

    #[test]
    fn pv_turbo_unrotation_recovers_context() {
        // Accumulate in rotated space, un-rotate, compare to true P·V.
        let mut rng = crate::util::rng::Rng::new(8);
        let d_h = 64;
        let rot = Rotation::new(d_h, 43);
        let n = 32;
        let vals: Vec<Vec<f32>> = (0..n).map(|_| normal_vec(&mut rng, d_h, 1.0, 0.0)).collect();
        let tokens: Vec<_> = vals.iter().map(|v| quantize_token(&rot, v, 3)).collect();
        let p: Vec<f32> = {
            let raw = normal_vec(&mut rng, n, 1.0, 0.0);
            // NaN-safe max (total_cmp from NEG_INFINITY, non-finite guard),
            // matching the sampling fixes: a f32::MIN seed silently corrupts
            // the softmax if any input is -inf/NaN.
            let m = raw
                .iter()
                .filter(|v| !v.is_nan())
                .fold(f32::NEG_INFINITY, |a, &b| if b.total_cmp(&a).is_gt() { b } else { a });
            assert!(m.is_finite(), "softmax max must be finite");
            let e: Vec<f32> = raw.iter().map(|&v| (v - m).exp()).collect();
            let s: f32 = e.iter().sum();
            e.iter().map(|v| v / s).collect()
        };
        let mut out_rot = vec![0f32; d_h];
        pv_turbo(&p, &tokens, codebook(3), 3, d_h, &mut out_rot);
        // un-rotate: R = H D, R^{-1} = D H (H symmetric orthonormal)
        crate::quant::turbo::fwht(&mut out_rot);
        for (v, &s) in out_rot.iter_mut().zip(&rot.signs) {
            *v *= s;
        }
        let mut want = vec![0f32; d_h];
        for t in 0..n {
            for c in 0..d_h {
                want[c] += p[t] * vals[t][c];
            }
        }
        let rel = crate::util::stats::rel_l2(&out_rot, &want);
        assert!(rel < 0.2, "rel {rel}");
    }
}
