//! Numerically stable softmax over decode scores, plus the scale-and-merge
//! helpers the attention layer uses to combine the quantized segment with
//! the high-precision windows (Fig. 2: "computed separately and then
//! merged").

/// In-place stable softmax: `x[i] = exp(x[i]*scale - max) / Σ`.
/// `scale` is the attention temperature `1/sqrt(d_h)`.
pub fn softmax_scaled(x: &mut [f32], scale: f32) {
    if x.is_empty() {
        return;
    }
    let mut m = f32::NEG_INFINITY;
    for v in x.iter_mut() {
        *v *= scale;
        m = m.max(*v);
    }
    let mut sum = 0.0f32;
    for v in x.iter_mut() {
        *v = (*v - m).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in x.iter_mut() {
        *v *= inv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sums_to_one() {
        let mut x = vec![1.0f32, 2.0, 3.0, -1.0];
        softmax_scaled(&mut x, 0.5);
        let s: f32 = x.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(x.windows(2).all(|w| w[0] <= w[1] || w[1] < w[0])); // finite
    }

    #[test]
    fn stable_for_large_scores() {
        let mut x = vec![1000.0f32, 999.0, 0.0];
        softmax_scaled(&mut x, 1.0);
        assert!(x.iter().all(|v| v.is_finite()));
        assert!(x[0] > x[1] && x[1] > x[2]);
    }

    #[test]
    fn single_element_is_one() {
        let mut x = vec![42.0f32];
        softmax_scaled(&mut x, 0.125);
        assert_eq!(x[0], 1.0);
    }

    #[test]
    fn matches_reference_formula() {
        let src = [0.5f32, -0.25, 1.75, 0.0, 2.0];
        let scale = 0.125;
        let mut x = src.to_vec();
        softmax_scaled(&mut x, scale);
        let exps: Vec<f32> = src.iter().map(|v| (v * scale).exp()).collect();
        let s: f32 = exps.iter().sum();
        for (a, e) in x.iter().zip(&exps) {
            assert!((a - e / s).abs() < 1e-6);
        }
    }
}
