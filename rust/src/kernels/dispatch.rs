//! Runtime ISA dispatch for the fused dequantize-GEMV kernels.
//!
//! The blocked kernels in [`super::gemv_inner`] / [`super::gemv_outer`] exist
//! in several instruction-set arms: the portable scalar form (the
//! autovectorizer-shaped code from PRs 2/5) plus explicit `std::arch`
//! variants — AVX2 and AVX-512 on x86_64, NEON on aarch64. This module picks
//! the arm:
//!
//! 1. **Explicit override** — [`set_active`] (wired to the `--isa` CLI flag)
//!    pins an arm process-wide. Passing `None` returns to automatic mode.
//! 2. **Environment** — `INNERQ_ISA={auto,scalar,avx2,avx512,neon}` selects
//!    an arm when no explicit override is set; CI uses this to run the test
//!    suites once per arm without recompiling. An unsupported value warns on
//!    stderr and falls back to auto-detection.
//! 3. **Auto-detection** — the widest arm the host supports, probed once via
//!    `is_x86_feature_detected!` / `is_aarch64_feature_detected!` and cached.
//!
//! Every arm is **bit-identical** to the scalar reference (the SIMD kernels
//! use separate multiply + add, never FMA — see `kernels/DESIGN.md`), so arm
//! selection is purely a throughput choice: switching arms mid-process is
//! safe and cannot change any result, which is what lets the decode-pipeline
//! tests assert byte-identical logits/snapshots across arms in-process.
//!
//! The AVX-512 arm additionally requires a toolchain with stable AVX-512
//! intrinsics (rustc >= 1.89); `build.rs` probes this and gates the arm
//! behind the `innerq_avx512` cfg, so older compilers silently lack it (it
//! then reports as unsupported, exactly like missing hardware).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// One dispatchable instruction-set arm of the blocked kernels.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Isa {
    /// Portable scalar/autovectorized arm — always available, the baseline
    /// the SIMD arms are bit-compared against.
    Scalar,
    /// x86_64 AVX2: 8-lane f32 vectors, `vpsrlvd`-based group unpack.
    Avx2,
    /// x86_64 AVX-512F: 16-lane f32 vectors. Needs rustc >= 1.89 at build
    /// time (`innerq_avx512` cfg) and `avx512f` at run time.
    Avx512,
    /// aarch64 NEON: 4-lane f32 vectors (mandatory on aarch64, so this is
    /// the auto-detected arm there).
    Neon,
}

impl Isa {
    /// Every arm the dispatcher knows about, widest last.
    pub const ALL: [Isa; 4] = [Isa::Scalar, Isa::Avx2, Isa::Avx512, Isa::Neon];

    /// Stable lower-case name, matching the `--isa` / `INNERQ_ISA` spelling.
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Avx512 => "avx512",
            Isa::Neon => "neon",
        }
    }

    /// Parse a `--isa` / `INNERQ_ISA` value. `Ok(None)` means `auto`
    /// (detect); `Err` carries a message listing the accepted spellings.
    pub fn parse(s: &str) -> Result<Option<Isa>, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" => Ok(None),
            "scalar" => Ok(Some(Isa::Scalar)),
            "avx2" => Ok(Some(Isa::Avx2)),
            "avx512" => Ok(Some(Isa::Avx512)),
            "neon" => Ok(Some(Isa::Neon)),
            other => Err(format!(
                "unknown ISA '{other}' (expected auto, scalar, avx2, avx512, or neon)"
            )),
        }
    }
}

impl std::fmt::Display for Isa {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Sentinel for "no explicit override" in [`ACTIVE`].
const UNSET: u8 = u8::MAX;

/// Process-wide explicit override (from `--isa` / [`set_active`]). An
/// `AtomicU8` rather than a `OnceLock` so tests can switch arms in-process;
/// relaxed ordering is enough because every arm computes identical bytes —
/// a racing reader merely runs a different-speed kernel.
static ACTIVE: AtomicU8 = AtomicU8::new(UNSET);

fn isa_from_u8(v: u8) -> Option<Isa> {
    match v {
        0 => Some(Isa::Scalar),
        1 => Some(Isa::Avx2),
        2 => Some(Isa::Avx512),
        3 => Some(Isa::Neon),
        _ => None,
    }
}

fn isa_to_u8(isa: Isa) -> u8 {
    match isa {
        Isa::Scalar => 0,
        Isa::Avx2 => 1,
        Isa::Avx512 => 2,
        Isa::Neon => 3,
    }
}

/// Is `isa` usable on this host *and* this build? (Hardware support probed
/// via the std feature-detection macros; build support via the
/// `innerq_avx512` cfg for the AVX-512 arm.)
pub fn is_supported(isa: Isa) -> bool {
    match isa {
        Isa::Scalar => true,
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => std::arch::is_x86_feature_detected!("avx2"),
        #[cfg(all(target_arch = "x86_64", innerq_avx512))]
        Isa::Avx512 => std::arch::is_x86_feature_detected!("avx512f"),
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => std::arch::is_aarch64_feature_detected!("neon"),
        #[allow(unreachable_patterns)]
        _ => false,
    }
}

/// All arms usable on this host, widest last. Always contains
/// [`Isa::Scalar`]; the parity tests and the kernel bench enumerate this to
/// cover every arm the CI machine can actually execute.
pub fn supported() -> Vec<Isa> {
    Isa::ALL.iter().copied().filter(|&i| is_supported(i)).collect()
}

/// The widest arm this host supports, probed once and cached.
pub fn detected() -> Isa {
    static DETECTED: OnceLock<Isa> = OnceLock::new();
    *DETECTED.get_or_init(|| {
        #[cfg(all(target_arch = "x86_64", innerq_avx512))]
        {
            if std::arch::is_x86_feature_detected!("avx512f") {
                return Isa::Avx512;
            }
        }
        #[cfg(target_arch = "x86_64")]
        {
            if std::arch::is_x86_feature_detected!("avx2") {
                return Isa::Avx2;
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            if std::arch::is_aarch64_feature_detected!("neon") {
                return Isa::Neon;
            }
        }
        Isa::Scalar
    })
}

/// The `INNERQ_ISA` environment override, read once. Unsupported or
/// malformed values warn on stderr and yield `None` (auto-detect) so a test
/// run never silently executes a different arm than it printed.
fn env_override() -> Option<Isa> {
    static ENV: OnceLock<Option<Isa>> = OnceLock::new();
    *ENV.get_or_init(|| {
        let raw = std::env::var("INNERQ_ISA").ok()?;
        match Isa::parse(&raw) {
            Ok(None) => None,
            Ok(Some(isa)) => {
                if is_supported(isa) {
                    Some(isa)
                } else {
                    eprintln!(
                        "INNERQ_ISA={raw}: arm not supported on this host (supported: {}); using auto-detection",
                        supported().iter().map(|i| i.name()).collect::<Vec<_>>().join(", ")
                    );
                    None
                }
            }
            Err(e) => {
                eprintln!("INNERQ_ISA: {e}; using auto-detection");
                None
            }
        }
    })
}

/// The arm the dispatching kernel wrappers run right now: explicit override
/// if set, else `INNERQ_ISA`, else [`detected`]. One relaxed atomic load on
/// the fast path.
pub fn active() -> Isa {
    match isa_from_u8(ACTIVE.load(Ordering::Relaxed)) {
        Some(isa) => isa,
        None => env_override().unwrap_or_else(detected),
    }
}

/// Pin the active arm process-wide (`Some`) or return to automatic selection
/// (`None`, the `--isa auto` spelling). Errs without changing state when the
/// requested arm is not supported on this host/build.
pub fn set_active(sel: Option<Isa>) -> Result<(), String> {
    match sel {
        None => {
            ACTIVE.store(UNSET, Ordering::Relaxed);
            Ok(())
        }
        Some(isa) => {
            if is_supported(isa) {
                ACTIVE.store(isa_to_u8(isa), Ordering::Relaxed);
                Ok(())
            } else {
                Err(format!(
                    "ISA '{isa}' not supported on this host/build (supported: {})",
                    supported().iter().map(|i| i.name()).collect::<Vec<_>>().join(", ")
                ))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Serializes the tests that mutate or observe the process-wide ACTIVE
    /// override — the test harness runs them on parallel threads.
    static ACTIVE_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    #[test]
    fn parse_round_trips_every_name() {
        for isa in Isa::ALL {
            assert_eq!(Isa::parse(isa.name()), Ok(Some(isa)));
        }
        assert_eq!(Isa::parse("auto"), Ok(None));
        assert_eq!(Isa::parse("AVX2"), Ok(Some(Isa::Avx2)));
        assert!(Isa::parse("sse9").is_err());
    }

    #[test]
    fn scalar_is_always_supported_and_detection_is_supported() {
        assert!(is_supported(Isa::Scalar));
        let sup = supported();
        assert!(sup.contains(&Isa::Scalar));
        assert!(sup.contains(&detected()), "detected arm must be in supported()");
    }

    #[test]
    fn set_active_pins_and_clears() {
        let _g = ACTIVE_LOCK.lock().unwrap();
        set_active(Some(Isa::Scalar)).unwrap();
        assert_eq!(active(), Isa::Scalar);
        set_active(None).unwrap();
        // Back to env/auto — whatever that is, it must be a supported arm.
        assert!(is_supported(active()));
    }

    #[test]
    fn set_active_rejects_unsupported_arms() {
        // At most one of avx2/neon is supportable per target_arch, so at
        // least one of the two must be rejected (and leave state untouched).
        let _g = ACTIVE_LOCK.lock().unwrap();
        let before = active();
        let rejected = [Isa::Avx2, Isa::Avx512, Isa::Neon]
            .into_iter()
            .filter(|&i| !is_supported(i))
            .collect::<Vec<_>>();
        for isa in &rejected {
            assert!(set_active(Some(*isa)).is_err());
        }
        assert_eq!(active(), before);
    }
}
