//! Small self-contained utilities (no external deps available offline).
pub mod fakemodel;
pub mod fp16;
pub mod json;
pub mod ptest;
pub mod ring;
pub mod rng;
pub mod spsc;
pub mod stats;
pub mod threadpool;
