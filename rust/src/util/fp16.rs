//! Software IEEE-754 binary16 conversion.
//!
//! The paper stores scale factors and zero-points as FP16 (Table 3 budgets
//! 0.5 bits of overhead per quantized number at group size 32). No `half`
//! crate is available offline, so we implement the two conversions directly.
//! Compute stays in f32; only the *stored* representation is f16, exactly as
//! a CUDA kernel would load `__half` scales and widen them.

/// Convert an f32 to the nearest IEEE binary16 bit pattern (round-to-nearest-even).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x007f_ffff;

    if exp == 0xff {
        // Inf / NaN
        let nan = if mant != 0 { 0x0200 } else { 0 };
        return sign | 0x7c00 | nan | ((mant >> 13) as u16);
    }
    // Re-bias exponent: f32 bias 127 -> f16 bias 15
    let unbiased = exp - 127;
    if unbiased > 15 {
        return sign | 0x7c00; // overflow -> inf
    }
    if unbiased >= -14 {
        // Normal range
        let mut e16 = (unbiased + 15) as u32;
        let mut m16 = mant >> 13;
        // round to nearest even on the 13 dropped bits
        let rem = mant & 0x1fff;
        if rem > 0x1000 || (rem == 0x1000 && (m16 & 1) == 1) {
            m16 += 1;
            if m16 == 0x400 {
                m16 = 0;
                e16 += 1;
                if e16 >= 31 {
                    return sign | 0x7c00;
                }
            }
        }
        return sign | ((e16 as u16) << 10) | (m16 as u16);
    }
    // Subnormal f16
    if unbiased < -25 {
        return sign; // underflow to zero
    }
    let full = mant | 0x0080_0000; // implicit bit
    let shift = (-14 - unbiased) as u32 + 13;
    let mut m16 = full >> shift;
    let rem = full & ((1u32 << shift) - 1);
    let half = 1u32 << (shift - 1);
    if rem > half || (rem == half && (m16 & 1) == 1) {
        m16 += 1;
    }
    sign | (m16 as u16)
}

/// Convert an IEEE binary16 bit pattern to f32.
#[inline(always)]
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x3ff) as u32;
    let bits = if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // subnormal: normalize
            let mut e = 127 - 15 - 10;
            let mut m = mant;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            m &= 0x3ff;
            sign | (((e + 10) as u32) << 23) | (m << 13)
        }
    } else if exp == 31 {
        sign | 0x7f80_0000 | (mant << 13)
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// Round-trip an f32 through f16 precision (what a stored scale loses).
#[inline(always)]
pub fn f16_round(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_small_values() {
        for &v in &[0.0f32, 1.0, -1.0, 0.5, 2.0, 1.5, 0.25, -0.375, 65504.0] {
            assert_eq!(f16_bits_to_f32(f32_to_f16_bits(v)), v, "value {v}");
        }
    }

    #[test]
    fn overflow_to_inf() {
        assert!(f16_bits_to_f32(f32_to_f16_bits(1e6)).is_infinite());
        assert!(f16_bits_to_f32(f32_to_f16_bits(-1e6)).is_infinite());
    }

    #[test]
    fn subnormals_round_trip() {
        let tiny = 6.0e-8f32; // within f16 subnormal range
        let rt = f16_bits_to_f32(f32_to_f16_bits(tiny));
        assert!((rt - tiny).abs() < 6.0e-8);
    }

    #[test]
    fn relative_error_bounded() {
        // f16 has 11 bits of significand => rel err <= 2^-11 for normals.
        let mut x = 1.1754944e-2f32;
        while x < 1.0e4 {
            let rt = f16_round(x);
            assert!(((rt - x) / x).abs() <= 1.0 / 2048.0 + 1e-7, "x={x} rt={rt}");
            x *= 1.37;
        }
    }

    #[test]
    fn sign_bit_is_msb() {
        // The hybrid mask repurposes the sign bit of stored scales (paper §4.1.2):
        // verify setting the MSB flips the sign and nothing else.
        let s = f32_to_f16_bits(0.123);
        let neg = s | 0x8000;
        assert_eq!(f16_bits_to_f32(neg), -f16_bits_to_f32(s));
    }

    #[test]
    fn nan_preserved() {
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
    }
}
