//! Bounded lock-free single-producer single-consumer queues.
//!
//! The staged server front end (`server/`) moves work between its IO-worker
//! threads and the scheduler driver exclusively over pairs of these queues —
//! one direction per queue, one producer and one consumer per side, in the
//! style of pelikan's `queues/spsc`. Restricting each queue to exactly one
//! producer thread and one consumer thread is what makes a mutex-free ring
//! correct with only two atomics:
//!
//! * `head` (next write slot) is written only by the producer and read with
//!   `Acquire` by the consumer;
//! * `tail` (next read slot) is written only by the consumer and read with
//!   `Acquire` by the producer.
//!
//! Both indices increase monotonically and are masked into the power-of-two
//! ring on access, so full (`head - tail == capacity`) and empty
//! (`head == tail`) are unambiguous without a wasted slot.
//!
//! The queue is *bounded*: `try_push` refuses (returning the item) when the
//! ring is full, which is the backpressure signal the server stages rely on —
//! a slow consumer stalls its producer instead of growing an unbounded
//! buffer. Blocking helpers are deliberately not provided here; callers spin
//! with their own stop-flag checks so shutdown can never deadlock on a queue.

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Shared ring state. Owned jointly by one [`Producer`] and one [`Consumer`]
/// through an `Arc`; dropped (including any items still queued) when the
/// second half goes away.
struct Inner<T> {
    buf: Box<[UnsafeCell<MaybeUninit<T>>]>,
    mask: usize,
    /// Next slot the producer will write. Producer-owned; consumer reads.
    head: AtomicUsize,
    /// Next slot the consumer will read. Consumer-owned; producer reads.
    tail: AtomicUsize,
}

// The UnsafeCell slots are only ever touched by the single producer (writes
// at `head`) and the single consumer (reads at `tail`), never concurrently
// for the same slot: a slot becomes consumer-visible only via the Release
// store of `head`, and reusable only via the Release store of `tail`.
unsafe impl<T: Send> Send for Inner<T> {}
unsafe impl<T: Send> Sync for Inner<T> {}

impl<T> Drop for Inner<T> {
    fn drop(&mut self) {
        // Only one thread can be here (last Arc owner); plain loads suffice.
        let head = *self.head.get_mut();
        let mut tail = *self.tail.get_mut();
        while tail != head {
            let slot = &self.buf[tail & self.mask];
            unsafe { (*slot.get()).assume_init_drop() };
            tail = tail.wrapping_add(1);
        }
    }
}

/// The sending half of an SPSC queue. `Send` but not `Clone`: exactly one
/// thread may push.
pub struct Producer<T> {
    inner: Arc<Inner<T>>,
}

/// The receiving half of an SPSC queue. `Send` but not `Clone`: exactly one
/// thread may pop.
pub struct Consumer<T> {
    inner: Arc<Inner<T>>,
}

/// Create a bounded SPSC queue holding at least `capacity` items (rounded up
/// to the next power of two, minimum 2) and split it into its two halves.
pub fn channel<T: Send>(capacity: usize) -> (Producer<T>, Consumer<T>) {
    let cap = capacity.max(2).next_power_of_two();
    let buf: Box<[UnsafeCell<MaybeUninit<T>>]> =
        (0..cap).map(|_| UnsafeCell::new(MaybeUninit::uninit())).collect();
    let inner = Arc::new(Inner {
        buf,
        mask: cap - 1,
        head: AtomicUsize::new(0),
        tail: AtomicUsize::new(0),
    });
    (Producer { inner: inner.clone() }, Consumer { inner })
}

impl<T> Producer<T> {
    /// Push `item`, or return it in `Err` if the ring is full.
    pub fn try_push(&mut self, item: T) -> Result<(), T> {
        let inner = &*self.inner;
        let head = inner.head.load(Ordering::Relaxed);
        let tail = inner.tail.load(Ordering::Acquire);
        if head.wrapping_sub(tail) > inner.mask {
            return Err(item);
        }
        let slot = &inner.buf[head & inner.mask];
        unsafe { (*slot.get()).write(item) };
        inner.head.store(head.wrapping_add(1), Ordering::Release);
        Ok(())
    }

    /// Number of items currently queued (racy by nature; exact only when the
    /// consumer is quiescent).
    pub fn len(&self) -> usize {
        let head = self.inner.head.load(Ordering::Relaxed);
        let tail = self.inner.tail.load(Ordering::Acquire);
        head.wrapping_sub(tail)
    }

    /// True when no items are queued (same caveat as [`Producer::len`]).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Ring capacity in items.
    pub fn capacity(&self) -> usize {
        self.inner.mask + 1
    }
}

impl<T> Consumer<T> {
    /// Pop the oldest item, or `None` if the ring is empty.
    pub fn try_pop(&mut self) -> Option<T> {
        let inner = &*self.inner;
        let tail = inner.tail.load(Ordering::Relaxed);
        let head = inner.head.load(Ordering::Acquire);
        if tail == head {
            return None;
        }
        let slot = &inner.buf[tail & inner.mask];
        let item = unsafe { (*slot.get()).assume_init_read() };
        inner.tail.store(tail.wrapping_add(1), Ordering::Release);
        Some(item)
    }

    /// Number of items currently queued (racy by nature; exact only when the
    /// producer is quiescent).
    pub fn len(&self) -> usize {
        let tail = self.inner.tail.load(Ordering::Relaxed);
        let head = self.inner.head.load(Ordering::Acquire);
        head.wrapping_sub(tail)
    }

    /// True when no items are queued (same caveat as [`Consumer::len`]).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Ring capacity in items.
    pub fn capacity(&self) -> usize {
        self.inner.mask + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::thread;

    #[test]
    fn fifo_order_and_capacity() {
        let (mut tx, mut rx) = channel::<u32>(4);
        assert_eq!(tx.capacity(), 4);
        for i in 0..4 {
            tx.try_push(i).unwrap();
        }
        // Full: the rejected item comes back.
        assert_eq!(tx.try_push(99), Err(99));
        for i in 0..4 {
            assert_eq!(rx.try_pop(), Some(i));
        }
        assert_eq!(rx.try_pop(), None);
        // Wrap-around keeps FIFO order.
        for round in 0..10u32 {
            tx.try_push(round).unwrap();
            tx.try_push(round + 100).unwrap();
            assert_eq!(rx.try_pop(), Some(round));
            assert_eq!(rx.try_pop(), Some(round + 100));
        }
    }

    #[test]
    fn capacity_rounds_up_to_power_of_two() {
        let (tx, _rx) = channel::<u8>(5);
        assert_eq!(tx.capacity(), 8);
        let (tx, _rx) = channel::<u8>(0);
        assert_eq!(tx.capacity(), 2);
    }

    #[test]
    fn cross_thread_transfer_preserves_order() {
        let (mut tx, mut rx) = channel::<usize>(8);
        const N: usize = 10_000;
        let producer = thread::spawn(move || {
            for i in 0..N {
                let mut item = i;
                loop {
                    match tx.try_push(item) {
                        Ok(()) => break,
                        Err(back) => {
                            item = back;
                            thread::yield_now();
                        }
                    }
                }
            }
        });
        let mut next = 0usize;
        while next < N {
            match rx.try_pop() {
                Some(v) => {
                    assert_eq!(v, next);
                    next += 1;
                }
                None => thread::yield_now(),
            }
        }
        producer.join().unwrap();
        assert_eq!(rx.try_pop(), None);
    }

    #[test]
    fn queued_items_drop_with_the_ring() {
        static DROPS: AtomicUsize = AtomicUsize::new(0);
        struct Counted;
        impl Drop for Counted {
            fn drop(&mut self) {
                DROPS.fetch_add(1, Ordering::SeqCst);
            }
        }
        let (mut tx, mut rx) = channel::<Counted>(4);
        tx.try_push(Counted).unwrap();
        tx.try_push(Counted).unwrap();
        tx.try_push(Counted).unwrap();
        drop(rx.try_pop()); // one dropped by consumption
        drop(tx);
        drop(rx); // two dropped with the ring
        assert_eq!(DROPS.load(Ordering::SeqCst), 3);
    }
}
