//! Timing and summary-statistics helpers for the bench harnesses.
//!
//! criterion is not available offline; the bench targets are plain binaries
//! (`harness = false`) built on these helpers: warmup + N timed reps,
//! mean / median / p95, matching the paper's protocol ("10 warm-up
//! iterations, averaged over 100 measured runs").

use std::time::Instant;

#[derive(Debug, Clone, Copy)]
pub struct Summary {
    pub mean_us: f64,
    pub median_us: f64,
    pub p95_us: f64,
    pub min_us: f64,
    pub reps: usize,
}

impl Summary {
    pub fn from_us(mut samples: Vec<f64>) -> Summary {
        assert!(!samples.is_empty());
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        Summary {
            mean_us: mean,
            median_us: samples[n / 2],
            p95_us: samples[(n as f64 * 0.95) as usize % n],
            min_us: samples[0],
            reps: n,
        }
    }
}

/// Run `f` with `warmup` untimed and `reps` timed iterations, returning
/// per-iteration microsecond samples. A `black_box`-style sink prevents the
/// optimizer from deleting the work: callers should return a value that
/// depends on the computation.
pub fn time_us<R, F: FnMut() -> R>(warmup: usize, reps: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        sink(f());
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        sink(f());
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    Summary::from_us(samples)
}

/// Opaque value sink (std::hint::black_box is stable since 1.66).
#[inline]
pub fn sink<T>(v: T) -> T {
    std::hint::black_box(v)
}

/// Mean of a slice.
pub fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f32>() / xs.len() as f32
}

/// Max absolute difference between two slices.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

/// Relative L2 error ||a-b|| / (||b|| + eps).
pub fn rel_l2(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    let num: f32 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
    let den: f32 = b.iter().map(|y| y * y).sum();
    (num / (den + 1e-12)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_orders() {
        let s = Summary::from_us(vec![3.0, 1.0, 2.0, 10.0]);
        assert_eq!(s.min_us, 1.0);
        assert!(s.mean_us > s.min_us);
        assert!(s.p95_us >= s.median_us);
    }

    #[test]
    fn rel_l2_zero_for_equal() {
        let a = [1.0f32, -2.0, 3.0];
        assert!(rel_l2(&a, &a) < 1e-6);
    }

    #[test]
    fn timing_runs() {
        let s = time_us(2, 5, || (0..1000).map(|i| i as f64).sum::<f64>());
        assert_eq!(s.reps, 5);
        assert!(s.min_us >= 0.0);
    }
}
