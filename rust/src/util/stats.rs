//! Timing and summary-statistics helpers for the bench harnesses.
//!
//! criterion is not available offline; the bench targets are plain binaries
//! (`harness = false`) built on these helpers: warmup + N timed reps,
//! mean / median / p95, matching the paper's protocol ("10 warm-up
//! iterations, averaged over 100 measured runs").

use std::time::Instant;

/// Wall-clock timing summary over the measured repetitions of one bench
/// cell (all values in microseconds).
#[derive(Debug, Clone, Copy)]
pub struct Summary {
    /// Arithmetic mean.
    pub mean_us: f64,
    /// Median (50th percentile).
    pub median_us: f64,
    /// 95th percentile.
    pub p95_us: f64,
    /// Fastest repetition.
    pub min_us: f64,
    /// Number of measured repetitions.
    pub reps: usize,
}

impl Summary {
    /// Summarize raw per-repetition microsecond samples.
    pub fn from_us(mut samples: Vec<f64>) -> Summary {
        assert!(!samples.is_empty());
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        Summary {
            mean_us: mean,
            median_us: samples[n / 2],
            p95_us: samples[(n as f64 * 0.95) as usize % n],
            min_us: samples[0],
            reps: n,
        }
    }
}

/// Run `f` with `warmup` untimed and `reps` timed iterations, returning
/// per-iteration microsecond samples. A `black_box`-style sink prevents the
/// optimizer from deleting the work: callers should return a value that
/// depends on the computation.
pub fn time_us<R, F: FnMut() -> R>(warmup: usize, reps: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        sink(f());
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        sink(f());
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    Summary::from_us(samples)
}

/// Opaque value sink (std::hint::black_box is stable since 1.66).
#[inline]
pub fn sink<T>(v: T) -> T {
    std::hint::black_box(v)
}

/// Tail summary of a latency distribution, in integer microseconds.
/// All fields are exact order statistics (nearest-rank), so two runs that
/// record the same samples produce bit-identical summaries — the overload
/// harness relies on this for its byte-identity determinism contract.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Percentiles {
    /// Number of recorded samples.
    pub count: usize,
    /// Arithmetic mean, rounded down to whole microseconds.
    pub mean_us: u64,
    /// Median (50th percentile).
    pub p50_us: u64,
    /// 90th percentile.
    pub p90_us: u64,
    /// 99th percentile.
    pub p99_us: u64,
    /// Largest sample.
    pub max_us: u64,
}

/// Exact percentile histogram over microsecond latencies. Stores raw
/// samples (serving traces are at most tens of thousands of requests), so
/// percentiles are exact rather than bucket-approximated, and summaries are
/// deterministic for the replay byte-identity contract.
#[derive(Debug, Clone, Default)]
pub struct LatencyHistogram {
    samples: Vec<u64>,
}

impl LatencyHistogram {
    /// An empty histogram.
    pub fn new() -> LatencyHistogram {
        LatencyHistogram { samples: Vec::new() }
    }

    /// Record one latency sample in microseconds.
    pub fn record(&mut self, us: u64) {
        self.samples.push(us);
    }

    /// Number of recorded samples.
    pub fn count(&self) -> usize {
        self.samples.len()
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Fold another histogram's samples into this one.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        self.samples.extend_from_slice(&other.samples);
    }

    /// Sum of all recorded samples (saturating) — the `_sum` series of a
    /// Prometheus summary.
    pub fn sum_us(&self) -> u64 {
        self.samples.iter().fold(0u64, |acc, &v| acc.saturating_add(v))
    }

    /// Exact nearest-rank percentile (`p` in [0, 100]); 0 when empty.
    pub fn percentile(&self, p: f64) -> u64 {
        if self.samples.is_empty() {
            return 0;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_unstable();
        let n = sorted.len();
        let rank = ((p / 100.0 * n as f64).ceil() as usize).clamp(1, n);
        sorted[rank - 1]
    }

    /// p50/p90/p99/max/mean summary (zeros when empty).
    pub fn summary(&self) -> Percentiles {
        if self.samples.is_empty() {
            return Percentiles::default();
        }
        let mut sorted = self.samples.clone();
        sorted.sort_unstable();
        let n = sorted.len();
        let rank = |p: f64| {
            let r = ((p / 100.0 * n as f64).ceil() as usize).clamp(1, n);
            sorted[r - 1]
        };
        let sum: u128 = sorted.iter().map(|&v| v as u128).sum();
        Percentiles {
            count: n,
            mean_us: (sum / n as u128) as u64,
            p50_us: rank(50.0),
            p90_us: rank(90.0),
            p99_us: rank(99.0),
            max_us: sorted[n - 1],
        }
    }
}

/// Mean of a slice.
pub fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f32>() / xs.len() as f32
}

/// Max absolute difference between two slices.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

/// Relative L2 error ||a-b|| / (||b|| + eps).
pub fn rel_l2(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    let num: f32 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
    let den: f32 = b.iter().map(|y| y * y).sum();
    (num / (den + 1e-12)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_orders() {
        let s = Summary::from_us(vec![3.0, 1.0, 2.0, 10.0]);
        assert_eq!(s.min_us, 1.0);
        assert!(s.mean_us > s.min_us);
        assert!(s.p95_us >= s.median_us);
    }

    #[test]
    fn rel_l2_zero_for_equal() {
        let a = [1.0f32, -2.0, 3.0];
        assert!(rel_l2(&a, &a) < 1e-6);
    }

    #[test]
    fn latency_histogram_exact_percentiles() {
        let mut h = LatencyHistogram::new();
        for v in 1..=100u64 {
            h.record(v);
        }
        let s = h.summary();
        assert_eq!(s.count, 100);
        assert_eq!(s.p50_us, 50);
        assert_eq!(s.p90_us, 90);
        assert_eq!(s.p99_us, 99);
        assert_eq!(s.max_us, 100);
        assert_eq!(s.mean_us, 50); // floor(50.5)
        assert_eq!(h.percentile(100.0), 100);
        assert_eq!(h.percentile(0.0), 1);
    }

    #[test]
    fn latency_histogram_empty_and_merge() {
        let empty = LatencyHistogram::new();
        assert_eq!(empty.summary(), Percentiles::default());
        assert_eq!(empty.percentile(99.0), 0);
        let mut a = LatencyHistogram::new();
        a.record(5);
        let mut b = LatencyHistogram::new();
        b.record(15);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.summary().max_us, 15);
    }

    #[test]
    fn timing_runs() {
        let s = time_us(2, 5, || (0..1000).map(|i| i as f64).sum::<f64>());
        assert_eq!(s.reps, 5);
        assert!(s.min_us >= 0.0);
    }
}
