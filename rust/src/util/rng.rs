//! Deterministic pseudo-randomness for tests, benches, and synthetic
//! workloads (no `rand` crate in the offline vendor set).
//!
//! Everything downstream that must be reproducible — property tests, trace
//! generation, the replay harness's byte-identity contract — seeds one of
//! these explicitly, so a failure always comes with a replayable seed.

/// Deterministic xoshiro256** PRNG (no rand crate offline).
pub struct Rng(
    /// The four xoshiro256** state words.
    pub [u64; 4],
);
impl Rng {
    /// Seed the generator (state expanded from `seed` via splitmix64).
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion
        let mut s = seed;
        let mut next = || {
            s = s.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = s;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng([next(), next(), next(), next()])
    }
    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.0;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }
    /// Uniform in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }
    /// Uniform in [0, 1) at f64 resolution (53 mantissa bits) — used by the
    /// arrival-process samplers, where f32 grid effects would distort
    /// exponential inter-arrival tails.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }
    /// Standard normal via Box-Muller.
    pub fn next_normal(&mut self) -> f32 {
        let u1 = (self.next_f32() + 1e-9).min(1.0);
        let u2 = self.next_f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }
    /// Uniform integer in `[0, n)` (modulo bias is irrelevant at the
    /// `n` values used here).
    pub fn next_range(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }
}
