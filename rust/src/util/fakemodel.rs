//! Synthetic AOT artifacts for tests and benches.
//!
//! The real artifacts come from `make artifacts` (Python/JAX lowering) and
//! are absent in CI, which used to leave the scheduler, server, and engine
//! decode loop untestable. This module writes a *fake* artifact directory —
//! a manifest plus HLO-text stages the vendored interpreter can execute —
//! whose model is degenerate on purpose: every stage returns constants, and
//! the head stage emits logits peaked at one configurable token. That makes
//! generation deterministic (`peak` repeated until `max_new_tokens`, or an
//! immediate stop if `peak == '.'`) while still driving the full pipeline:
//! prefill bucketing, cache append/attend across layers and KV heads, the
//! decode batcher, and the worker-pool fan-out.
//!
//! Production code never calls this; it lives in `util` (not `#[cfg(test)]`)
//! so integration tests and benches can share it.

use crate::workload::corpus::CHARSET;
use std::path::PathBuf;

// Model geometry of the fake artifacts: small, but multi-layer / multi-head
// so the decode fan-out is exercised, and d_h = 32 so quantized segments
// (one 32-wide group per row) engage for real.

/// Vocabulary size (BOS + the 24-character corpus charset).
pub const VOCAB: usize = 25;
/// Model (residual stream) width.
pub const D_MODEL: usize = 8;
/// Transformer layer count.
pub const N_LAYERS: usize = 2;
/// Query head count.
pub const N_Q: usize = 4;
/// KV head count (2 query heads share each KV head).
pub const N_KV: usize = 2;
/// Attention head dimension (exactly one 32-wide quantization group).
pub const D_H: usize = 32;
/// Decode batch buckets baked into the fake manifest.
pub const DECODE_BATCHES: [usize; 3] = [1, 2, 4];
/// Prefill length buckets baked into the fake manifest.
pub const PREFILL_BUCKETS: [usize; 2] = [64, 128];

/// Build a fake artifact directory under the system temp dir. `tag` keeps
/// concurrent tests apart; `peak` is the character whose token the head
/// stage always argmaxes to (use `'.'` for an immediate stop).
pub fn write_fake_artifacts(tag: &str, peak: char) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "innerq_fake_{tag}_{}_{peak_code}",
        std::process::id(),
        peak_code = token_for(peak)
    ));
    std::fs::create_dir_all(&dir).expect("create fake artifact dir");

    let mut artifacts = Vec::new();
    let mut write_stage = |key: String, text: String| {
        let file = format!("{key}.hlo.txt");
        std::fs::write(dir.join(&file), text).expect("write fake stage");
        artifacts.push((key, file));
    };

    for bb in DECODE_BATCHES {
        write_stage(format!("embed_b{bb}"), embed_hlo(bb));
        for l in 0..N_LAYERS {
            write_stage(format!("qkv_l{l}_b{bb}"), qkv_hlo(bb));
            write_stage(format!("out_l{l}_b{bb}"), out_hlo(bb));
        }
        write_stage(format!("head_b{bb}"), head_hlo(bb, peak));
    }
    for bucket in PREFILL_BUCKETS {
        write_stage(format!("prefill_l{bucket}"), prefill_hlo(bucket, peak));
    }

    let artifact_entries: Vec<String> = artifacts
        .iter()
        .map(|(k, f)| format!("\"{k}\":\"{f}\""))
        .collect();
    let manifest = format!(
        concat!(
            "{{\"model\":{{\"vocab\":{vocab},\"d_model\":{dm},\"n_layers\":{nl},",
            "\"n_q_heads\":{nq},\"n_kv_heads\":{nkv},\"d_h\":{dh},\"d_ff\":16,",
            "\"rope_theta\":10000.0}},",
            "\"charset\":\"{charset}\",\"bos\":0,",
            "\"decode_batches\":[1,2,4],\"prefill_buckets\":[64,128],",
            "\"quant_attn_tokens\":0,",
            "\"artifacts\":{{{arts}}},\"final_train_loss\":0.5}}"
        ),
        vocab = VOCAB,
        dm = D_MODEL,
        nl = N_LAYERS,
        nq = N_Q,
        nkv = N_KV,
        dh = D_H,
        charset = CHARSET,
        arts = artifact_entries.join(",")
    );
    std::fs::write(dir.join("manifest.json"), manifest).expect("write fake manifest");
    dir
}

/// Token id of a charset character (1-based; 0 is BOS).
pub fn token_for(c: char) -> i32 {
    CHARSET
        .chars()
        .position(|x| x == c)
        .map(|i| i as i32 + 1)
        .expect("peak char must be in the model charset")
}

/// `{0, 0, ..., 5, ..., 0}` logits vector with the peak at `peak`'s token.
fn logit_vector(peak: char) -> String {
    let peak_tok = token_for(peak) as usize;
    let vals: Vec<String> = (0..VOCAB)
        .map(|i| if i == peak_tok { "5".to_string() } else { "0".to_string() })
        .collect();
    format!("{{{}}}", vals.join(", "))
}

fn embed_hlo(bb: usize) -> String {
    format!(
        "HloModule embed_b{bb}\n\n\
         ENTRY main {{\n\
         \x20 tok = s32[{bb}]{{0}} parameter(0)\n\
         \x20 c = f32[] constant(0.25)\n\
         \x20 h = f32[{bb},{D_MODEL}]{{1,0}} broadcast(c), dimensions={{}}\n\
         \x20 ROOT t = (f32[{bb},{D_MODEL}]{{1,0}}) tuple(h)\n\
         }}\n"
    )
}

fn qkv_hlo(bb: usize) -> String {
    format!(
        "HloModule qkv_b{bb}\n\n\
         ENTRY main {{\n\
         \x20 h = f32[{bb},{D_MODEL}]{{1,0}} parameter(0)\n\
         \x20 pos = s32[{bb}]{{0}} parameter(1)\n\
         \x20 cq = f32[] constant(0.125)\n\
         \x20 q = f32[{bb},{N_Q},{D_H}]{{2,1,0}} broadcast(cq), dimensions={{}}\n\
         \x20 ck = f32[] constant(0.5)\n\
         \x20 k = f32[{bb},{N_KV},{D_H}]{{2,1,0}} broadcast(ck), dimensions={{}}\n\
         \x20 cv = f32[] constant(0.25)\n\
         \x20 v = f32[{bb},{N_KV},{D_H}]{{2,1,0}} broadcast(cv), dimensions={{}}\n\
         \x20 ROOT t = (f32[{bb},{N_Q},{D_H}]{{2,1,0}}) tuple(q, k, v)\n\
         }}\n"
    )
}

fn out_hlo(bb: usize) -> String {
    let q_dim = N_Q * D_H;
    format!(
        "HloModule out_b{bb}\n\n\
         ENTRY main {{\n\
         \x20 h = f32[{bb},{D_MODEL}]{{1,0}} parameter(0)\n\
         \x20 ctx = f32[{bb},{q_dim}]{{1,0}} parameter(1)\n\
         \x20 ROOT t = (f32[{bb},{D_MODEL}]{{1,0}}) tuple(h)\n\
         }}\n"
    )
}

fn head_hlo(bb: usize, peak: char) -> String {
    let logits = logit_vector(peak);
    format!(
        "HloModule head_b{bb}\n\n\
         ENTRY main {{\n\
         \x20 h = f32[{bb},{D_MODEL}]{{1,0}} parameter(0)\n\
         \x20 l = f32[{VOCAB}]{{0}} constant({logits})\n\
         \x20 lg = f32[{bb},{VOCAB}]{{1,0}} broadcast(l), dimensions={{1}}\n\
         \x20 ROOT t = (f32[{bb},{VOCAB}]{{1,0}}) tuple(lg)\n\
         }}\n"
    )
}

fn prefill_hlo(bucket: usize, peak: char) -> String {
    let logits = logit_vector(peak);
    format!(
        "HloModule prefill_l{bucket}\n\n\
         ENTRY main {{\n\
         \x20 tok = s32[1,{bucket}]{{1,0}} parameter(0)\n\
         \x20 l = f32[{VOCAB}]{{0}} constant({logits})\n\
         \x20 lg = f32[{bucket},{VOCAB}]{{1,0}} broadcast(l), dimensions={{1}}\n\
         \x20 ck = f32[] constant(0.5)\n\
         \x20 ks = f32[{N_LAYERS},{bucket},{N_KV},{D_H}]{{3,2,1,0}} broadcast(ck), dimensions={{}}\n\
         \x20 cv = f32[] constant(0.25)\n\
         \x20 vs = f32[{N_LAYERS},{bucket},{N_KV},{D_H}]{{3,2,1,0}} broadcast(cv), dimensions={{}}\n\
         \x20 ROOT t = (f32[{bucket},{VOCAB}]{{1,0}}) tuple(lg, ks, vs)\n\
         }}\n"
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{Manifest, Stage};

    #[test]
    fn fake_stages_load_and_execute() {
        let dir = write_fake_artifacts("fakemodel_unit", '7');
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.model.d_h, D_H);
        assert_eq!(m.model.heads_per_kv(), N_Q / N_KV);

        let head = Stage::load("head_b1", &m.path("head_b1").unwrap()).unwrap();
        let out = head
            .run(&[crate::runtime::executable::In::F32(
                &vec![0.0; D_MODEL],
                &[1, D_MODEL as i64],
            )])
            .unwrap();
        let logits = out.f32(0).unwrap();
        assert_eq!(logits.len(), VOCAB);
        let peak = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0;
        assert_eq!(peak as i32, token_for('7'));
    }

    #[test]
    fn all_fake_stages_compile() {
        let dir = write_fake_artifacts("fakemodel_all", '.');
        let m = Manifest::load(&dir).unwrap();
        for key in m.artifacts.keys() {
            Stage::load(key, &m.path(key).unwrap())
                .unwrap_or_else(|e| panic!("stage {key}: {e}"));
        }
    }
}
