//! Persistent scoped worker pool for the decode attention fan-out.
//!
//! `Engine::decode_step` turns every (sequence, KV head) pair into one job;
//! jobs only *read* their head's cache and write a disjoint slice of the
//! context buffer, so they parallelize without synchronization beyond the
//! queue. The pool is std-only (no rayon/crossbeam offline) and built for
//! exactly that shape of work:
//!
//! * **Scoped jobs.** [`ThreadPool::run`] accepts non-`'static` closures and
//!   blocks until every submitted job has finished, so borrows of the
//!   engine's per-step buffers are sound (see the safety comment in `run`).
//! * **Driver participation.** `workers = N` means N threads total: the pool
//!   spawns `N - 1` helpers and the *calling* thread drains the queue too.
//!   With `workers = 1` no threads exist and `run` degenerates to an inline
//!   `for` loop — bit-identical to the old serial path, zero overhead.
//! * **Per-worker scratch.** Each executing thread owns one scratch arena
//!   (the `Vec<f32>` passed to every job), replacing the old per-`Sequence`
//!   scratch so concurrent jobs never share growable buffers.
//!
//! Determinism: the pool adds no reductions of its own. Each job's output
//! slice is disjoint and its internal FP reduction order is unchanged, so
//! results are byte-identical across worker counts.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;

/// One unit of attention work. Receives the executing thread's scratch
/// arena; must not panic across `run` calls it wants to survive (a panicking
/// job is contained and re-raised on the driver once the batch drains).
pub type Job<'a> = Box<dyn FnOnce(&mut Vec<f32>) + Send + 'a>;

type StaticJob = Box<dyn FnOnce(&mut Vec<f32>) + Send + 'static>;

struct State {
    queue: VecDeque<StaticJob>,
    /// Jobs submitted but not yet finished (queued + currently running).
    pending: usize,
    /// A job panicked since the last completed batch.
    panicked: bool,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// Wakes workers when work arrives or shutdown is requested.
    work: Condvar,
    /// Wakes the driver when `pending` may have reached zero.
    done: Condvar,
}

impl Shared {
    /// Poison-tolerant lock: a panicked job never holds the lock (execution
    /// happens outside the critical section), so recovered state is
    /// consistent.
    fn lock(&self) -> MutexGuard<'_, State> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }
}

pub struct ThreadPool {
    shared: Arc<Shared>,
    threads: Vec<JoinHandle<()>>,
    /// Scratch arena for jobs executed on the driver thread.
    driver_scratch: Mutex<Vec<f32>>,
}

impl ThreadPool {
    /// A pool with `workers` total executing threads (the driver counts as
    /// one). `workers <= 1` spawns nothing and runs jobs inline.
    pub fn new(workers: usize) -> ThreadPool {
        let workers = workers.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                queue: VecDeque::new(),
                pending: 0,
                panicked: false,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let threads = (1..workers)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("innerq-attn-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn attention worker")
            })
            .collect();
        ThreadPool { shared, threads, driver_scratch: Mutex::new(Vec::new()) }
    }

    /// Total executing threads, including the driver.
    pub fn workers(&self) -> usize {
        self.threads.len() + 1
    }

    /// Execute every job, blocking until all are done. Jobs may borrow
    /// caller-local data (`'a` need not be `'static`). Panics if any job
    /// panicked, after the whole batch has drained.
    ///
    /// One driver at a time: concurrent `run` calls from different threads
    /// would interleave batches (jobs all still run exactly once, but each
    /// caller waits for the union to finish).
    pub fn run<'a>(&self, jobs: Vec<Job<'a>>) {
        if jobs.is_empty() {
            return;
        }
        let mut scratch = self
            .driver_scratch
            .lock()
            .unwrap_or_else(|e| e.into_inner());

        // Serial fast path: no helper threads, no queue, no atomics.
        if self.threads.is_empty() {
            for job in jobs {
                job(&mut scratch);
            }
            return;
        }

        // SAFETY: the lifetime of every job is erased to 'static so it can
        // sit in the shared queue, but no job outlives this call: the wait
        // loop below does not return until `pending` — which counts every
        // job submitted here — is back to zero, and jobs are consumed
        // exactly once (popped then invoked). Borrows captured by the jobs
        // therefore remain live for as long as any job can run.
        let jobs: Vec<StaticJob> = jobs
            .into_iter()
            .map(|j| unsafe { std::mem::transmute::<Job<'a>, StaticJob>(j) })
            .collect();
        {
            let mut st = self.shared.lock();
            st.pending += jobs.len();
            st.queue.extend(jobs);
        }
        self.shared.work.notify_all();

        // The driver drains the queue alongside the workers...
        loop {
            let job = self.shared.lock().queue.pop_front();
            match job {
                Some(job) => execute(&self.shared, job, &mut scratch),
                None => break,
            }
        }
        // ...then waits for in-flight stragglers.
        let mut st = self.shared.lock();
        while st.pending > 0 {
            st = match self.shared.done.wait(st) {
                Ok(g) => g,
                Err(e) => e.into_inner(),
            };
        }
        let panicked = st.panicked;
        st.panicked = false;
        drop(st);
        drop(scratch);
        if panicked {
            panic!("threadpool: an attention job panicked (see worker stderr)");
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.lock().shutdown = true;
        self.shared.work.notify_all();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Run one job outside any lock, then account for its completion.
fn execute(shared: &Shared, job: StaticJob, scratch: &mut Vec<f32>) {
    let result = catch_unwind(AssertUnwindSafe(|| job(scratch)));
    let mut st = shared.lock();
    if result.is_err() {
        st.panicked = true;
    }
    st.pending -= 1;
    if st.pending == 0 {
        shared.done.notify_all();
    }
}

fn worker_loop(shared: &Shared) {
    let mut scratch: Vec<f32> = Vec::new();
    loop {
        let job = {
            let mut st = shared.lock();
            loop {
                if let Some(j) = st.queue.pop_front() {
                    break Some(j);
                }
                if st.shutdown {
                    break None;
                }
                st = match shared.work.wait(st) {
                    Ok(g) => g,
                    Err(e) => e.into_inner(),
                };
            }
        };
        match job {
            Some(j) => execute(shared, j, &mut scratch),
            None => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fill_disjoint(pool: &ThreadPool, n_jobs: usize, chunk: usize) -> Vec<f32> {
        let mut data = vec![0f32; n_jobs * chunk];
        {
            let mut jobs: Vec<Job> = Vec::with_capacity(n_jobs);
            for (j, out) in data.chunks_mut(chunk).enumerate() {
                jobs.push(Box::new(move |scratch: &mut Vec<f32>| {
                    scratch.clear();
                    scratch.resize(chunk, j as f32);
                    for (o, s) in out.iter_mut().zip(scratch.iter()) {
                        *o = *s + 1.0;
                    }
                }));
            }
            pool.run(jobs);
        }
        data
    }

    #[test]
    fn disjoint_writes_all_workers() {
        let want = fill_disjoint(&ThreadPool::new(1), 64, 7);
        for workers in [2usize, 4, 8] {
            let got = fill_disjoint(&ThreadPool::new(workers), 64, 7);
            assert_eq!(got, want, "workers={workers}");
        }
        for (j, c) in want.chunks(7).enumerate() {
            assert!(c.iter().all(|&v| v == j as f32 + 1.0));
        }
    }

    #[test]
    fn pool_is_reusable_across_batches() {
        let pool = ThreadPool::new(4);
        for round in 0..20 {
            let n = 1 + round % 13;
            let out = fill_disjoint(&pool, n, 3);
            assert_eq!(out.len(), n * 3);
        }
        assert_eq!(pool.workers(), 4);
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let pool = ThreadPool::new(4);
        pool.run(Vec::new());
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let pool = ThreadPool::new(3);
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let mut jobs: Vec<Job> = Vec::new();
            for i in 0..8 {
                jobs.push(Box::new(move |_s: &mut Vec<f32>| {
                    if i == 5 {
                        panic!("job 5 exploded");
                    }
                }));
            }
            pool.run(jobs);
        }));
        assert!(result.is_err(), "panic must reach the driver");
        // The pool keeps working after a contained panic.
        let out = fill_disjoint(&pool, 10, 4);
        assert_eq!(out.len(), 40);
    }

    #[test]
    fn single_worker_runs_inline() {
        let pool = ThreadPool::new(1);
        assert_eq!(pool.workers(), 1);
        let caller = std::thread::current().id();
        let mut seen = None;
        {
            let seen_ref = &mut seen;
            let jobs: Vec<Job> = vec![Box::new(move |_s: &mut Vec<f32>| {
                *seen_ref = Some(std::thread::current().id());
            })];
            pool.run(jobs);
        }
        assert_eq!(seen, Some(caller), "workers=1 must execute on the driver");
    }
}
