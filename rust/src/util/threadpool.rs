//! Persistent scoped worker pool with dependency-aware graph execution.
//!
//! The pool runs the engine's per-(sequence, KV head) cache work. It grew up
//! in two steps:
//!
//! * PR 1 added flat [`ThreadPool::run`]: submit a batch of independent
//!   jobs, block until all finish. That shape fits the prefill
//!   bulk-quantization fan-out and the barrier-mode attention fan-out.
//! * This PR adds [`ThreadPool::run_graph`]: jobs are grouped into *stages*
//!   with explicit predecessor edges, and a stage's jobs become runnable the
//!   moment every predecessor stage has fully completed — no global barrier
//!   between stages. `Engine::decode_step` uses it to emit one whole decode
//!   step as a task graph (PJRT driver stages chained between per-layer
//!   cache-work fan-outs), and the decode-scaling bench uses it to overlap
//!   layers outright. [`ThreadPool::run`] is now a thin wrapper over a
//!   single-stage graph, so `prefill_fanout` callers are untouched.
//!
//! Design points, in the order they matter:
//!
//! * **Scoped jobs.** Both entry points accept non-`'static` closures and
//!   block until every submitted job has finished, so borrows of the
//!   caller's per-step buffers are sound (see the safety comment in
//!   `submit_erased`).
//! * **Driver participation.** `workers = N` means N executing threads
//!   total: the pool spawns `N - 1` helpers and the *calling* thread drains
//!   work too. With `workers = 1` nothing is spawned and both entry points
//!   degenerate to an inline loop in stage order — bit-identical to the
//!   serial path with zero pool overhead.
//! * **Driver-only stages.** A [`Stage`] marked `driver_only` runs its jobs
//!   exclusively on the calling thread. The engine needs this because PJRT
//!   clients are thread-local: the qkv/out/head model stages may sit *in*
//!   the decode graph, but must still execute on the driver.
//! * **Per-worker deques.** Runnable jobs are distributed round-robin over
//!   one deque per executing thread; a thread pops its own deque from the
//!   front and steals from the back of others when it runs dry. All deques
//!   live under the pool's single state mutex (jobs here are coarse —
//!   microseconds of attention math — so queue transfer cost is noise; the
//!   deques exist to keep a stage's jobs spread across workers instead of
//!   contending on one queue head).
//! * **Per-worker scratch.** Each executing thread owns one scratch arena
//!   (the `Vec<f32>` passed to every job), so concurrent jobs never share
//!   growable buffers.
//!
//! Determinism: the pool adds no reductions of its own, and stage edges only
//! *constrain* order. Each job's output is disjoint from its siblings' and
//! its internal FP order is fixed, so results are byte-identical across
//! worker counts and across graph vs. flat submission of the same work.
//!
//! Panics: a panicking job is contained; the rest of the batch still drains
//! (successor stages included) and the panic is re-raised on the driver once
//! everything has settled, leaving the pool reusable.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;

/// One unit of work. Receives the executing thread's scratch arena; must not
/// panic across `run` calls it wants to survive (a panicking job is
/// contained and re-raised on the driver once the batch drains).
pub type Job<'a> = Box<dyn FnOnce(&mut Vec<f32>) + Send + 'a>;

type StaticJob = Box<dyn FnOnce(&mut Vec<f32>) + Send + 'static>;

/// One node of a [`ThreadPool::run_graph`] dependency graph: a set of jobs
/// that become runnable when every predecessor stage has fully completed.
/// Stages must be listed in topological order — each `deps` entry must index
/// an *earlier* stage — which makes cycles unrepresentable.
pub struct Stage<'a> {
    /// Indices of stages that must fully complete before any job of this
    /// stage may run. Every entry must be smaller than this stage's own
    /// index (checked at submission).
    pub deps: Vec<usize>,
    /// The stage's jobs. A stage may be empty; it completes as soon as its
    /// predecessors do (useful as a join point).
    pub jobs: Vec<Job<'a>>,
    /// Run this stage's jobs only on the calling (driver) thread. Used for
    /// work bound to thread-local state, e.g. PJRT model stages.
    pub driver_only: bool,
}

impl<'a> Stage<'a> {
    /// A worker-eligible stage.
    pub fn new(deps: Vec<usize>, jobs: Vec<Job<'a>>) -> Stage<'a> {
        Stage { deps, jobs, driver_only: false }
    }

    /// A stage whose jobs run only on the calling thread.
    pub fn driver_only(deps: Vec<usize>, jobs: Vec<Job<'a>>) -> Stage<'a> {
        Stage { deps, jobs, driver_only: true }
    }
}

/// A queued job together with the graph stage it belongs to.
struct Tagged {
    stage: usize,
    job: StaticJob,
}

/// Bookkeeping for the graph currently in flight (one at a time).
struct GraphState {
    /// Uncompleted jobs per stage (runnable or running).
    jobs_left: Vec<usize>,
    /// Predecessor stages not yet completed, per stage.
    preds_left: Vec<usize>,
    /// Dependent stages per stage (reverse edges).
    succs: Vec<Vec<usize>>,
    /// Jobs of stages whose predecessors have not all completed yet.
    parked: Vec<Vec<Tagged>>,
    /// Stages whose jobs are driver-only.
    driver_only: Vec<bool>,
}

struct State {
    /// One runnable-job deque per executing thread (slot 0 = driver).
    /// Threads pop their own slot from the front and steal from the back of
    /// the others.
    queues: Vec<VecDeque<Tagged>>,
    /// Runnable jobs of driver-only stages; workers never touch this.
    driver_queue: VecDeque<Tagged>,
    /// Round-robin cursor for distributing newly runnable jobs.
    rr: usize,
    /// Graph bookkeeping for the batch in flight, if any.
    graph: Option<GraphState>,
    /// Jobs submitted but not yet finished (queued + currently running).
    pending: usize,
    /// A job panicked since the last completed batch.
    panicked: bool,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// Wakes executing threads when work arrives, a stage unlocks, the
    /// batch drains, or shutdown is requested.
    work: Condvar,
}

impl Shared {
    /// Poison-tolerant lock: a panicked job never holds the lock (execution
    /// happens outside the critical section), so recovered state is
    /// consistent.
    fn lock(&self) -> MutexGuard<'_, State> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// The pool itself; see the module docs for semantics.
pub struct ThreadPool {
    shared: Arc<Shared>,
    threads: Vec<JoinHandle<()>>,
    /// Scratch arena for jobs executed on the driver thread.
    driver_scratch: Mutex<Vec<f32>>,
}

impl ThreadPool {
    /// A pool with `workers` total executing threads (the driver counts as
    /// one). `workers <= 1` spawns nothing and runs jobs inline.
    pub fn new(workers: usize) -> ThreadPool {
        let workers = workers.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                queues: (0..workers).map(|_| VecDeque::new()).collect(),
                driver_queue: VecDeque::new(),
                rr: 0,
                graph: None,
                pending: 0,
                panicked: false,
                shutdown: false,
            }),
            work: Condvar::new(),
        });
        let threads = (1..workers)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("innerq-attn-{i}"))
                    .spawn(move || worker_loop(&shared, i))
                    .expect("spawn attention worker")
            })
            .collect();
        ThreadPool { shared, threads, driver_scratch: Mutex::new(Vec::new()) }
    }

    /// Total executing threads, including the driver.
    pub fn workers(&self) -> usize {
        self.threads.len() + 1
    }

    /// Execute every job of a flat batch, blocking until all are done —
    /// a single-stage graph. Jobs may borrow caller-local data (`'a` need
    /// not be `'static`). Panics if any job panicked, after the whole batch
    /// has drained.
    ///
    /// One driver at a time: `run` / `run_graph` must not be called
    /// concurrently from different threads (the pool tracks one batch).
    pub fn run<'a>(&self, jobs: Vec<Job<'a>>) {
        if jobs.is_empty() {
            return;
        }
        self.run_graph(vec![Stage::new(Vec::new(), jobs)]);
    }

    /// Execute a stage graph, blocking until every job of every stage has
    /// finished. Stage `deps` must point at earlier stages (topological
    /// order; asserted), so the graph is acyclic by construction. A stage's
    /// jobs become runnable the moment the last job of its last unfinished
    /// predecessor completes — there is no global barrier, so independent
    /// stages overlap freely. `driver_only` stages execute exclusively on
    /// the calling thread.
    ///
    /// Determinism: edges only constrain order; with disjoint job outputs
    /// the result is byte-identical to any serialization of the same jobs.
    pub fn run_graph<'a>(&self, stages: Vec<Stage<'a>>) {
        for (s, stage) in stages.iter().enumerate() {
            for &d in &stage.deps {
                assert!(d < s, "stage {s} depends on stage {d}: deps must point backwards");
            }
        }
        if stages.iter().all(|s| s.jobs.is_empty()) {
            return; // nothing to execute; empty stages carry no effects
        }
        let mut scratch = self
            .driver_scratch
            .lock()
            .unwrap_or_else(|e| e.into_inner());

        // Serial fast path: no helper threads, no queue, no graph state.
        // Topological (index) order satisfies every dependency.
        if self.threads.is_empty() {
            for stage in stages {
                for job in stage.jobs {
                    job(&mut scratch);
                }
            }
            return;
        }

        self.submit_erased(stages);

        // The driver executes driver-only jobs (only it may), drains its own
        // deque, steals from workers, and sleeps when the graph is waiting
        // on in-flight jobs to unlock the next stage.
        loop {
            let job = {
                let mut st = self.shared.lock();
                loop {
                    if let Some(t) = pop_job(&mut st, 0, true) {
                        break Some(t);
                    }
                    if st.pending == 0 {
                        break None;
                    }
                    st = match self.shared.work.wait(st) {
                        Ok(g) => g,
                        Err(e) => e.into_inner(),
                    };
                }
            };
            match job {
                Some(t) => execute(&self.shared, t, &mut scratch),
                None => break,
            }
        }
        let mut st = self.shared.lock();
        debug_assert_eq!(st.pending, 0);
        debug_assert!(st.graph.is_none(), "graph state must clear when the batch drains");
        let panicked = st.panicked;
        st.panicked = false;
        drop(st);
        drop(scratch);
        if panicked {
            panic!("threadpool: a job panicked (see worker stderr)");
        }
    }

    /// Erase job lifetimes and install the graph into the shared state,
    /// enqueueing every initially runnable stage.
    fn submit_erased<'a>(&self, stages: Vec<Stage<'a>>) {
        let n = stages.len();
        let mut jobs_left = Vec::with_capacity(n);
        let mut preds_left = Vec::with_capacity(n);
        let mut succs: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut parked: Vec<Vec<Tagged>> = Vec::with_capacity(n);
        let mut driver_only = Vec::with_capacity(n);
        let mut total = 0usize;
        for (s, stage) in stages.into_iter().enumerate() {
            jobs_left.push(stage.jobs.len());
            preds_left.push(stage.deps.len());
            for &d in &stage.deps {
                succs[d].push(s);
            }
            driver_only.push(stage.driver_only);
            total += stage.jobs.len();
            // SAFETY: every job's lifetime is erased to 'static so it can
            // sit in the shared queues, but no job outlives the enclosing
            // `run_graph` call: its wait loop does not return until
            // `pending` — which counts every job submitted here — is back
            // to zero, and jobs are consumed exactly once (popped then
            // invoked). Borrows captured by the jobs therefore remain live
            // for as long as any job can run.
            parked.push(
                stage
                    .jobs
                    .into_iter()
                    .map(|j| Tagged {
                        stage: s,
                        job: unsafe { std::mem::transmute::<Job<'a>, StaticJob>(j) },
                    })
                    .collect(),
            );
        }
        let mut st = self.shared.lock();
        assert!(
            st.graph.is_none() && st.pending == 0,
            "one batch at a time: a previous run/run_graph is still in flight"
        );
        st.pending = total;
        st.graph = Some(GraphState { jobs_left, preds_left, succs, parked, driver_only });
        // Release (and cascade through) every stage with no predecessors.
        let roots: Vec<usize> = {
            let g = st.graph.as_ref().unwrap();
            (0..n).filter(|&s| g.preds_left[s] == 0).collect()
        };
        for s in roots {
            release_stage(&mut st, s);
        }
        drop(st);
        self.shared.work.notify_all();
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.lock().shutdown = true;
        self.shared.work.notify_all();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Move a now-unlocked stage's jobs into the run queues; if the stage is
/// empty it completes immediately, cascading into its successors.
fn release_stage(st: &mut State, stage: usize) {
    let mut ready: Vec<usize> = vec![stage];
    while let Some(s) = ready.pop() {
        let (jobs, driver) = {
            let g = st.graph.as_mut().expect("graph in flight");
            (std::mem::take(&mut g.parked[s]), g.driver_only[s])
        };
        if jobs.is_empty() {
            // Empty stage: completes the moment it unlocks.
            let g = st.graph.as_mut().expect("graph in flight");
            let succs = g.succs[s].clone();
            for t in succs {
                g.preds_left[t] -= 1;
                if g.preds_left[t] == 0 {
                    ready.push(t);
                }
            }
            continue;
        }
        if driver {
            st.driver_queue.extend(jobs);
        } else {
            let n = st.queues.len();
            for t in jobs {
                let slot = st.rr % n;
                st.rr = st.rr.wrapping_add(1);
                st.queues[slot].push_back(t);
            }
        }
    }
}

/// Take the next runnable job for executing-thread `slot`: the driver queue
/// first (driver only), then the thread's own deque front, then steal from
/// the back of the other deques.
fn pop_job(st: &mut State, slot: usize, is_driver: bool) -> Option<Tagged> {
    if is_driver {
        if let Some(t) = st.driver_queue.pop_front() {
            return Some(t);
        }
    }
    if let Some(t) = st.queues[slot].pop_front() {
        return Some(t);
    }
    let n = st.queues.len();
    for d in 1..n {
        let s = (slot + d) % n;
        if let Some(t) = st.queues[s].pop_back() {
            return Some(t);
        }
    }
    None
}

/// Run one job outside any lock, then account for its completion: stage
/// bookkeeping (possibly unlocking successors) and the pending count.
fn execute(shared: &Shared, t: Tagged, scratch: &mut Vec<f32>) {
    let Tagged { stage, job } = t;
    let result = catch_unwind(AssertUnwindSafe(|| job(scratch)));
    let mut st = shared.lock();
    if result.is_err() {
        st.panicked = true;
    }
    let mut unlocked: Vec<usize> = Vec::new();
    if let Some(g) = st.graph.as_mut() {
        g.jobs_left[stage] -= 1;
        if g.jobs_left[stage] == 0 {
            let succs = g.succs[stage].clone();
            for t in succs {
                g.preds_left[t] -= 1;
                if g.preds_left[t] == 0 {
                    unlocked.push(t);
                }
            }
        }
    }
    for s in unlocked {
        release_stage(&mut st, s);
    }
    st.pending -= 1;
    if st.pending == 0 {
        st.graph = None;
    }
    drop(st);
    // Wake peers for newly runnable jobs and the driver for batch drain.
    // Notifying unconditionally is cheap relative to job granularity and
    // keeps the wake-up logic unmissable.
    shared.work.notify_all();
}

fn worker_loop(shared: &Shared, slot: usize) {
    let mut scratch: Vec<f32> = Vec::new();
    loop {
        let job = {
            let mut st = shared.lock();
            loop {
                if let Some(t) = pop_job(&mut st, slot, false) {
                    break Some(t);
                }
                if st.shutdown {
                    break None;
                }
                st = match shared.work.wait(st) {
                    Ok(g) => g,
                    Err(e) => e.into_inner(),
                };
            }
        };
        match job {
            Some(t) => execute(shared, t, &mut scratch),
            None => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn fill_disjoint(pool: &ThreadPool, n_jobs: usize, chunk: usize) -> Vec<f32> {
        let mut data = vec![0f32; n_jobs * chunk];
        {
            let mut jobs: Vec<Job> = Vec::with_capacity(n_jobs);
            for (j, out) in data.chunks_mut(chunk).enumerate() {
                jobs.push(Box::new(move |scratch: &mut Vec<f32>| {
                    scratch.clear();
                    scratch.resize(chunk, j as f32);
                    for (o, s) in out.iter_mut().zip(scratch.iter()) {
                        *o = *s + 1.0;
                    }
                }));
            }
            pool.run(jobs);
        }
        data
    }

    #[test]
    fn disjoint_writes_all_workers() {
        let want = fill_disjoint(&ThreadPool::new(1), 64, 7);
        for workers in [2usize, 4, 8] {
            let got = fill_disjoint(&ThreadPool::new(workers), 64, 7);
            assert_eq!(got, want, "workers={workers}");
        }
        for (j, c) in want.chunks(7).enumerate() {
            assert!(c.iter().all(|&v| v == j as f32 + 1.0));
        }
    }

    #[test]
    fn pool_is_reusable_across_batches() {
        let pool = ThreadPool::new(4);
        for round in 0..20 {
            let n = 1 + round % 13;
            let out = fill_disjoint(&pool, n, 3);
            assert_eq!(out.len(), n * 3);
        }
        assert_eq!(pool.workers(), 4);
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let pool = ThreadPool::new(4);
        pool.run(Vec::new());
        pool.run_graph(Vec::new());
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let pool = ThreadPool::new(3);
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let mut jobs: Vec<Job> = Vec::new();
            for i in 0..8 {
                jobs.push(Box::new(move |_s: &mut Vec<f32>| {
                    if i == 5 {
                        panic!("job 5 exploded");
                    }
                }));
            }
            pool.run(jobs);
        }));
        assert!(result.is_err(), "panic must reach the driver");
        // The pool keeps working after a contained panic.
        let out = fill_disjoint(&pool, 10, 4);
        assert_eq!(out.len(), 40);
    }

    #[test]
    fn single_worker_runs_inline() {
        let pool = ThreadPool::new(1);
        assert_eq!(pool.workers(), 1);
        let caller = std::thread::current().id();
        let mut seen = None;
        {
            let seen_ref = &mut seen;
            let jobs: Vec<Job> = vec![Box::new(move |_s: &mut Vec<f32>| {
                *seen_ref = Some(std::thread::current().id());
            })];
            pool.run(jobs);
        }
        assert_eq!(seen, Some(caller), "workers=1 must execute on the driver");
    }

    /// Build a chain graph `append -> attend` per lane, the decode shape:
    /// stage 2i writes lane i, stage 2i+1 (dep on 2i) reads it and derives.
    /// Any execution respecting the edges yields the same buffer. The lanes
    /// communicate through raw pointers because the producer and consumer
    /// are separate closures; the graph edge (synchronized through the pool
    /// mutex) provides the happens-before that makes this sound.
    fn run_chain(pool: &ThreadPool, lanes: usize) -> Vec<f32> {
        let mut data = vec![0f32; lanes * 2];
        let base = SendMut(data.as_mut_ptr());
        {
            let mut stages: Vec<Stage> = Vec::with_capacity(lanes * 2);
            for i in 0..lanes {
                stages.push(Stage::new(
                    Vec::new(),
                    vec![Box::new(move |_s: &mut Vec<f32>| unsafe {
                        *base.0.add(i * 2) = (i + 1) as f32;
                    })],
                ));
                let dep = stages.len() - 1;
                stages.push(Stage::new(
                    vec![dep],
                    vec![Box::new(move |_s: &mut Vec<f32>| unsafe {
                        let a = *base.0.add(i * 2);
                        *base.0.add(i * 2 + 1) = a * 10.0;
                    })],
                ));
            }
            pool.run_graph(stages);
        }
        data
    }

    #[derive(Clone, Copy)]
    struct SendMut(*mut f32);
    unsafe impl Send for SendMut {}

    #[test]
    fn graph_edges_order_dependent_stages() {
        let want = run_chain(&ThreadPool::new(1), 32);
        for lane in 0..32 {
            assert_eq!(want[lane * 2], (lane + 1) as f32);
            assert_eq!(want[lane * 2 + 1], (lane + 1) as f32 * 10.0);
        }
        for workers in [2usize, 4, 8] {
            let got = run_chain(&ThreadPool::new(workers), 32);
            assert_eq!(got, want, "workers={workers}");
        }
    }

    #[test]
    fn driver_only_stages_run_on_the_caller() {
        let pool = ThreadPool::new(4);
        let caller = std::thread::current().id();
        let hits = AtomicUsize::new(0);
        {
            let hits = &hits;
            let mut stages: Vec<Stage> = Vec::new();
            // A fan-out feeding a driver-only join, three times over.
            for round in 0..3 {
                let dep = if round == 0 { Vec::new() } else { vec![round * 2 - 1] };
                let fan: Vec<Job> = (0..16)
                    .map(|_| {
                        let j: Job = Box::new(move |_s: &mut Vec<f32>| {
                            std::hint::black_box(0u64);
                        });
                        j
                    })
                    .collect();
                stages.push(Stage::new(dep, fan));
                let fan_idx = stages.len() - 1;
                stages.push(Stage::driver_only(
                    vec![fan_idx],
                    vec![Box::new(move |_s: &mut Vec<f32>| {
                        assert_eq!(
                            std::thread::current().id(),
                            caller,
                            "driver-only stage ran on a worker"
                        );
                        hits.fetch_add(1, Ordering::SeqCst);
                    })],
                ));
            }
            pool.run_graph(stages);
        }
        assert_eq!(hits.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn empty_stages_cascade_as_join_points() {
        let pool = ThreadPool::new(3);
        let flag = AtomicUsize::new(0);
        {
            let flag = &flag;
            let stages: Vec<Stage> = vec![
                Stage::new(
                    Vec::new(),
                    vec![Box::new(move |_s: &mut Vec<f32>| {
                        flag.fetch_add(1, Ordering::SeqCst);
                    })],
                ),
                // Empty join stage.
                Stage::new(vec![0], Vec::new()),
                // Depends on the empty stage.
                Stage::new(
                    vec![1],
                    vec![Box::new(move |_s: &mut Vec<f32>| {
                        assert_eq!(flag.load(Ordering::SeqCst), 1);
                        flag.fetch_add(10, Ordering::SeqCst);
                    })],
                ),
            ];
            pool.run_graph(stages);
        }
        assert_eq!(flag.load(Ordering::SeqCst), 11);
    }

    #[test]
    #[should_panic(expected = "deps must point backwards")]
    fn forward_deps_are_rejected() {
        let pool = ThreadPool::new(1);
        let stages: Vec<Stage> = vec![
            Stage::new(vec![1], Vec::new()),
            Stage::new(Vec::new(), Vec::new()),
        ];
        pool.run_graph(stages);
    }

    #[test]
    fn graph_matches_flat_run_bit_for_bit() {
        // The same disjoint-write workload submitted flat and as a
        // many-stage graph must produce identical buffers.
        let pool = ThreadPool::new(4);
        let flat = fill_disjoint(&pool, 24, 5);
        let mut data = vec![0f32; 24 * 5];
        {
            let mut stages: Vec<Stage> = Vec::new();
            for (j, out) in data.chunks_mut(5).enumerate() {
                let deps = if j % 3 == 0 || stages.is_empty() {
                    Vec::new()
                } else {
                    vec![stages.len() - 1]
                };
                stages.push(Stage::new(
                    deps,
                    vec![Box::new(move |scratch: &mut Vec<f32>| {
                        scratch.clear();
                        scratch.resize(5, j as f32);
                        for (o, s) in out.iter_mut().zip(scratch.iter()) {
                            *o = *s + 1.0;
                        }
                    })],
                ));
            }
            pool.run_graph(stages);
        }
        assert_eq!(data, flat);
    }
}
