//! Tiny property-testing harness.
//!
//! proptest is not in the offline vendor set, so invariants are checked with
//! this seeded-random harness: `check` runs a property over `cases` randomly
//! generated inputs and, on failure, retries with a simple halving shrink of
//! the size parameter to report a small counterexample. Deterministic per
//! seed, so failures are reproducible.

use super::rng::Rng;

/// Property-check configuration: the base seed and how many random cases
/// to run.
pub struct PropCfg {
    /// Base seed; each case derives its own RNG from it, so any failure
    /// reproduces from (seed, case index).
    pub seed: u64,
    /// Number of random cases to generate.
    pub cases: usize,
}

impl Default for PropCfg {
    fn default() -> Self {
        PropCfg { seed: 0x1a2b3c4d, cases: 64 }
    }
}

/// Run `prop(rng, case_index)` for `cfg.cases` cases. The property panics on
/// violation (use assert!); we re-raise with the seed and case for repro.
pub fn check<F: FnMut(&mut Rng, usize)>(name: &str, cfg: PropCfg, mut prop: F) {
    for case in 0..cfg.cases {
        let mut rng = Rng::new(cfg.seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng, case);
        }));
        if let Err(e) = result {
            panic!(
                "property '{name}' failed at case {case} (seed {:#x}): {:?}",
                cfg.seed, e
            );
        }
    }
}

/// Random f32 vector with normal entries scaled by `scale`, occasionally
/// spiked with an outlier (mirrors the KV-cache channel-outlier structure the
/// paper targets).
pub fn normal_vec(rng: &mut Rng, n: usize, scale: f32, outlier_prob: f32) -> Vec<f32> {
    (0..n)
        .map(|_| {
            let v = rng.next_normal() * scale;
            if rng.next_f32() < outlier_prob {
                v * 8.0
            } else {
                v
            }
        })
        .collect()
}

/// Pick a random element of a slice.
pub fn choose<'a, T>(rng: &mut Rng, xs: &'a [T]) -> &'a T {
    &xs[rng.next_range(xs.len())]
}
