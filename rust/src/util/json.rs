//! Minimal JSON parser/emitter.
//!
//! serde is not available in the offline vendor set, so we carry a small,
//! dependency-free JSON module. It is used for: the artifact manifest written
//! by `python/compile/aot.py`, golden cross-layer test vectors, run configs,
//! and the TCP server protocol. It supports the full JSON data model; numbers
//! are parsed as f64.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// One JSON value. Objects use a `BTreeMap`, so emission order is sorted
/// and deterministic — the replay/bench byte-identity contracts depend on
/// that.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (parsed/emitted as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object with sorted keys.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    /// The numeric value truncated to usize, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    /// The boolean value, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    /// The key/value map, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access; returns Null for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
    /// Array of f32 convenience (golden vectors).
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_f64()).map(|v| v as f32).collect())
    }

    /// Parse a complete JSON document (trailing bytes are an error).
    /// Nesting deeper than [`MAX_DEPTH`] is rejected with an error rather
    /// than recursing — network-facing callers (the TCP server) parse
    /// attacker-controlled lines, and a `[[[[…` bomb must not overflow the
    /// reader thread's stack.
    pub fn parse(s: &str) -> Result<Json, String> {
        let b = s.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(b, &mut pos, 0)?;
        skip_ws(b, &mut pos);
        if pos != b.len() {
            return Err(format!("trailing data at byte {pos}"));
        }
        Ok(v)
    }

    /// Emit compact JSON (sorted object keys; deterministic).
    pub fn dump(&self) -> String {
        let mut out = String::new();
        emit(self, &mut out);
        out
    }

    /// Build an object from (key, value) pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    /// Build a numeric array from f64 values.
    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }
    /// Build a numeric array from f32 values (widened to f64).
    pub fn arr_f32(v: &[f32]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }
    /// Build a string value.
    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

/// Maximum container nesting depth [`Json::parse`] accepts. Deep enough for
/// any document this codebase emits, shallow enough that parsing stays well
/// inside a default thread stack.
pub const MAX_DEPTH: usize = 128;

fn parse_value(b: &[u8], pos: &mut usize, depth: usize) -> Result<Json, String> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        return Err("unexpected end of input".into());
    }
    if depth > MAX_DEPTH {
        return Err(format!("nesting deeper than {MAX_DEPTH} levels"));
    }
    match b[*pos] {
        b'{' => parse_obj(b, pos, depth),
        b'[' => parse_arr(b, pos, depth),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => lit(b, pos, "true", Json::Bool(true)),
        b'f' => lit(b, pos, "false", Json::Bool(false)),
        b'n' => lit(b, pos, "null", Json::Null),
        _ => parse_num(b, pos),
    }
}

fn lit(b: &[u8], pos: &mut usize, word: &str, v: Json) -> Result<Json, String> {
    if b[*pos..].starts_with(word.as_bytes()) {
        *pos += word.len();
        Ok(v)
    } else {
        Err(format!("bad literal at byte {}", *pos))
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    std::str::from_utf8(&b[start..*pos])
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .map(Json::Num)
        .ok_or_else(|| format!("bad number at byte {start}"))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    debug_assert_eq!(b[*pos], b'"');
    *pos += 1;
    let mut out = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                if *pos >= b.len() {
                    break;
                }
                match b[*pos] {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        if *pos + 4 >= b.len() {
                            return Err("bad \\u escape".into());
                        }
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])
                            .map_err(|_| "bad \\u escape")?;
                        let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                        out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    c => return Err(format!("bad escape \\{}", c as char)),
                }
                *pos += 1;
            }
            _ => {
                // consume one UTF-8 scalar
                let s = &b[*pos..];
                let len = utf8_len(s[0]);
                let chunk = std::str::from_utf8(&s[..len.min(s.len())])
                    .map_err(|_| "invalid utf-8")?;
                out.push_str(chunk);
                *pos += len;
            }
        }
    }
    Err("unterminated string".into())
}

fn utf8_len(b0: u8) -> usize {
    match b0 {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

fn parse_arr(b: &[u8], pos: &mut usize, depth: usize) -> Result<Json, String> {
    *pos += 1; // '['
    let mut out = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b']' {
        *pos += 1;
        return Ok(Json::Arr(out));
    }
    loop {
        out.push(parse_value(b, pos, depth + 1)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(out));
            }
            _ => return Err(format!("expected ',' or ']' at byte {}", *pos)),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize, depth: usize) -> Result<Json, String> {
    *pos += 1; // '{'
    let mut out = BTreeMap::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b'}' {
        *pos += 1;
        return Ok(Json::Obj(out));
    }
    loop {
        skip_ws(b, pos);
        if *pos >= b.len() || b[*pos] != b'"' {
            return Err(format!("expected key at byte {}", *pos));
        }
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b':') {
            return Err(format!("expected ':' at byte {}", *pos));
        }
        *pos += 1;
        let val = parse_value(b, pos, depth + 1)?;
        out.insert(key, val);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(out));
            }
            _ => return Err(format!("expected ',' or '}}' at byte {}", *pos)),
        }
    }
}

fn emit(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(true) => out.push_str("true"),
        Json::Bool(false) => out.push_str("false"),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{n}");
            }
        }
        Json::Str(s) => emit_str(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, v) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                emit(v, out);
            }
            out.push(']');
        }
        Json::Obj(o) => {
            out.push('{');
            for (i, (k, v)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                emit_str(k, out);
                out.push(':');
                emit(v, out);
            }
            out.push('}');
        }
    }
}

fn emit_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_basic() {
        let src = r#"{"a":[1,2.5,-3],"b":"hi\nthere","c":null,"d":true,"e":{"x":0}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, v2);
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("b").as_str().unwrap(), "hi\nthere");
        assert_eq!(v.get("d").as_bool(), Some(true));
    }

    #[test]
    fn numbers() {
        for s in ["0", "-0.5", "1e3", "2.5E-2", "123456789"] {
            let v = Json::parse(s).unwrap();
            assert!((v.as_f64().unwrap() - s.parse::<f64>().unwrap()).abs() < 1e-12);
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nope").is_err());
        assert!(Json::parse("[1] x").is_err());
    }

    #[test]
    fn depth_guard_rejects_nesting_bombs_without_overflow() {
        // Well under the limit: fine.
        let ok = format!("{}1{}", "[".repeat(64), "]".repeat(64));
        assert!(Json::parse(&ok).is_ok());
        // A pathological bomb errors instead of blowing the stack.
        for open in ["[", "{\"k\":"] {
            let close = if open == "[" { "]" } else { "}" };
            let bomb = format!("{}1{}", open.repeat(50_000), close.repeat(50_000));
            let err = Json::parse(&bomb).unwrap_err();
            assert!(err.contains("nesting"), "unexpected error: {err}");
        }
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
    }

    #[test]
    fn nested_missing_get_is_null() {
        let v = Json::parse(r#"{"a":{"b":1}}"#).unwrap();
        assert_eq!(v.get("a").get("b").as_f64(), Some(1.0));
        assert_eq!(v.get("z").get("q"), &Json::Null);
    }
}
