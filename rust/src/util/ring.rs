//! Bounded drop-oldest event rings for the tracing plane (`crate::obs`).
//!
//! Same family as [`crate::util::spsc`] — a fixed-capacity power-of-two
//! ring, no allocation after construction, no locks — but tuned for
//! telemetry rather than work handoff, which flips two contracts:
//!
//! * **The producer never waits and never fails.** A full ring overwrites
//!   the oldest event (drop-oldest), and any contention on a slot (the
//!   consumer is mid-copy) drops the *new* event instead of spinning. A
//!   traced worker thread therefore pays a bounded handful of atomic ops
//!   per event and can never block on the observer — the tracing plane's
//!   "never perturbs the data path" contract.
//! * **Losing events is legal and counted.** Every event that was pushed
//!   but will never be popped (overwritten before the consumer got there,
//!   or dropped on slot contention) increments a lost counter the consumer
//!   drains with [`EventRing::take_lost`], so the flight recorder can
//!   report exactly how much it missed instead of silently lying.
//!
//! Unlike `spsc`, producer and consumer may race on the *same* slot (the
//! producer laps the consumer), so slot handoff cannot ride on the head and
//! tail indices alone. Each slot carries its own sequence word:
//!
//! * an idle slot holds the sequence of the event it contains
//!   (`2 * index + 2` for global event index `index` — strictly increasing
//!   per slot across generations, never 0 and never `LOCKED`, so there is
//!   no ABA);
//! * either side claims a slot by CASing that word to [`LOCKED`]; whoever
//!   loses the race walks away (the producer drops the event, the consumer
//!   skips the slot), so no thread ever spins on a slot;
//! * the producer publishes a written event by storing the new sequence
//!   with `Release`; the consumer's claiming CAS is `Acquire`, so the copy
//!   it takes is fully ordered after the write.
//!
//! The consumer side is *externally serialized* (the flight recorder drains
//! behind a mutex); the implementation stays memory-safe under concurrent
//! pops — the CAS claim still excludes — but two concurrent drainers would
//! steal events from each other.

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicU64, Ordering};

/// Slot-claim marker. Sequence values are `2 * index + 2`, which can never
/// reach `u64::MAX`, so the marker is unambiguous.
const LOCKED: u64 = u64::MAX;

/// One ring slot: the sequence word that arbitrates ownership plus the
/// payload it guards.
struct Slot<T> {
    seq: AtomicU64,
    val: UnsafeCell<MaybeUninit<T>>,
}

/// A bounded, drop-oldest, never-blocking event ring. `T: Copy` keeps both
/// sides trivial: a lost event is simply never read, so there is nothing to
/// drop.
pub struct EventRing<T> {
    slots: Box<[Slot<T>]>,
    mask: u64,
    /// Next event index the producer will write. Producer-owned.
    head: AtomicU64,
    /// Next event index the consumer will read. Consumer-owned; lives in
    /// the ring so drains need no external cursor state.
    tail: AtomicU64,
    /// Events pushed that will never be popped: dropped on slot contention
    /// (producer side) plus overwritten before consumption (consumer side).
    lost: AtomicU64,
}

// Safety: every slot access is gated by winning a CAS of the slot's `seq`
// to LOCKED, so no two threads ever touch a slot's payload concurrently;
// payloads are `Copy` (no drop obligations) and only published via
// Release/Acquire pairs on `seq`.
unsafe impl<T: Copy + Send> Send for EventRing<T> {}
unsafe impl<T: Copy + Send> Sync for EventRing<T> {}

impl<T: Copy> EventRing<T> {
    /// A ring holding at least `capacity` events (rounded up to the next
    /// power of two, minimum 2).
    pub fn new(capacity: usize) -> EventRing<T> {
        let cap = capacity.max(2).next_power_of_two();
        let slots: Box<[Slot<T>]> = (0..cap)
            .map(|_| Slot {
                seq: AtomicU64::new(0),
                val: UnsafeCell::new(MaybeUninit::uninit()),
            })
            .collect();
        EventRing {
            slots,
            mask: cap as u64 - 1,
            head: AtomicU64::new(0),
            tail: AtomicU64::new(0),
            lost: AtomicU64::new(0),
        }
    }

    /// Ring capacity in events.
    pub fn capacity(&self) -> usize {
        self.mask as usize + 1
    }

    /// Sequence word an idle slot holds once event `index` has been written
    /// into it. Strictly increasing per slot, never 0 (the empty marker)
    /// and never [`LOCKED`].
    #[inline]
    fn seq_of(index: u64) -> u64 {
        2 * index + 2
    }

    /// Push an event; never blocks. A full ring overwrites the oldest
    /// event; losing the slot race to the consumer drops this event. Both
    /// forms of loss are tallied for [`EventRing::take_lost`].
    pub fn push(&self, item: T) {
        let head = self.head.load(Ordering::Relaxed);
        let slot = &self.slots[(head & self.mask) as usize];
        let cur = slot.seq.load(Ordering::Relaxed);
        if cur == LOCKED
            || slot
                .seq
                .compare_exchange(cur, LOCKED, Ordering::Acquire, Ordering::Relaxed)
                .is_err()
        {
            // The consumer holds (or just claimed) this slot — drop rather
            // than wait. The event it is copying out still gets delivered.
            self.lost.fetch_add(1, Ordering::Relaxed);
            return;
        }
        unsafe { (*slot.val.get()).write(item) };
        slot.seq.store(Self::seq_of(head), Ordering::Release);
        self.head.store(head + 1, Ordering::Release);
    }

    /// Pop the oldest available event, or `None` when drained. Consumers
    /// must be externally serialized (see the module docs). Events the
    /// producer overwrote before we arrived are skipped and counted lost.
    pub fn pop(&self) -> Option<T> {
        let head = self.head.load(Ordering::Acquire);
        let mut tail = self.tail.load(Ordering::Relaxed);
        loop {
            if tail == head {
                self.tail.store(tail, Ordering::Relaxed);
                return None;
            }
            // The producer lapped us: everything older than head - cap is
            // already overwritten. Jump the cursor and tally the loss.
            let cap = self.mask + 1;
            if head - tail > cap {
                let skipped = head - tail - cap;
                self.lost.fetch_add(skipped, Ordering::Relaxed);
                tail = head - cap;
            }
            let slot = &self.slots[(tail & self.mask) as usize];
            let want = Self::seq_of(tail);
            if slot
                .seq
                .compare_exchange(want, LOCKED, Ordering::Acquire, Ordering::Relaxed)
                .is_err()
            {
                // Overwritten (a newer generation, or mid-overwrite) —
                // this event is gone; move on.
                self.lost.fetch_add(1, Ordering::Relaxed);
                tail += 1;
                continue;
            }
            let item = unsafe { (*slot.val.get()).assume_init_read() };
            // Restore the sequence so the producer's next overwrite of this
            // slot sees the value it expects.
            slot.seq.store(want, Ordering::Release);
            self.tail.store(tail + 1, Ordering::Relaxed);
            return Some(item);
        }
    }

    /// Events lost so far (dropped on contention or overwritten unread)
    /// since the last [`EventRing::take_lost`].
    pub fn lost(&self) -> u64 {
        self.lost.load(Ordering::Relaxed)
    }

    /// Drain and reset the lost-event counter.
    pub fn take_lost(&self) -> u64 {
        self.lost.swap(0, Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::thread;

    #[test]
    fn push_pop_fifo() {
        let ring = EventRing::<u64>::new(8);
        assert_eq!(ring.pop(), None);
        for i in 0..5 {
            ring.push(i);
        }
        for i in 0..5 {
            assert_eq!(ring.pop(), Some(i));
        }
        assert_eq!(ring.pop(), None);
        assert_eq!(ring.lost(), 0);
    }

    #[test]
    fn overflow_drops_oldest_and_counts_lost() {
        let ring = EventRing::<u64>::new(4);
        assert_eq!(ring.capacity(), 4);
        for i in 0..7 {
            ring.push(i);
        }
        // The four newest survive; the three oldest were overwritten.
        for i in 3..7 {
            assert_eq!(ring.pop(), Some(i));
        }
        assert_eq!(ring.pop(), None);
        assert_eq!(ring.take_lost(), 3);
        assert_eq!(ring.lost(), 0);
    }

    #[test]
    fn wraps_across_many_generations() {
        let ring = EventRing::<u64>::new(4);
        for round in 0..100u64 {
            ring.push(round);
            assert_eq!(ring.pop(), Some(round));
        }
        assert_eq!(ring.lost(), 0);
    }

    #[test]
    fn capacity_rounds_up() {
        assert_eq!(EventRing::<u8>::new(5).capacity(), 8);
        assert_eq!(EventRing::<u8>::new(0).capacity(), 2);
    }

    #[test]
    fn concurrent_producer_consumer_conserves_events() {
        const N: u64 = 200_000;
        let ring = Arc::new(EventRing::<u64>::new(64));
        let prod = {
            let ring = ring.clone();
            thread::spawn(move || {
                for i in 0..N {
                    ring.push(i);
                }
            })
        };
        let mut popped = 0u64;
        let mut last: Option<u64> = None;
        loop {
            match ring.pop() {
                Some(v) => {
                    // Single producer pushing an increasing sequence: pops
                    // must be a strictly increasing subsequence.
                    if let Some(prev) = last {
                        assert!(v > prev, "out of order: {prev} then {v}");
                    }
                    last = Some(v);
                    popped += 1;
                }
                None => {
                    if prod.is_finished() {
                        // Final drain after the producer stopped.
                        while let Some(v) = ring.pop() {
                            if let Some(prev) = last {
                                assert!(v > prev);
                            }
                            last = Some(v);
                            popped += 1;
                        }
                        break;
                    }
                    thread::yield_now();
                }
            }
        }
        prod.join().unwrap();
        // Every pushed event was either delivered or counted lost.
        assert_eq!(popped + ring.lost(), N);
    }
}
