//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the request path.
//!
//! The interchange is HLO *text* (`HloModuleProto::from_text_file`), not the
//! serialized proto — see /opt/xla-example/README.md for the 64-bit-id
//! incompatibility this sidesteps.

pub mod artifacts;
pub mod executable;

pub use artifacts::Manifest;
pub use executable::{Stage, StageOutput};

use anyhow::Result;

thread_local! {
    // PjRtClient is Rc-based (not Send); the engine and all executables live
    // on the scheduler thread, so a thread-local client is the right scope.
    static CLIENT: std::cell::RefCell<Option<xla::PjRtClient>> =
        const { std::cell::RefCell::new(None) };
}

/// The per-thread PJRT CPU client (a cheap Rc clone).
pub fn client() -> Result<xla::PjRtClient> {
    CLIENT.with(|slot| {
        let mut slot = slot.borrow_mut();
        if slot.is_none() {
            *slot = Some(
                xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu client: {e:?}"))?,
            );
        }
        Ok(slot.as_ref().unwrap().clone())
    })
}
