//! Loaded PJRT executables: HLO text → compile once → execute many.
//!
//! Every stage was lowered with `return_tuple=True`, so outputs always
//! arrive as one tuple literal; `StageOutput` indexes into its parts.

use anyhow::{anyhow, Context, Result};
use std::path::Path;

/// Convert the xla crate's error into anyhow (it is not Sync).
macro_rules! xerr {
    ($e:expr, $what:expr) => {
        $e.map_err(|e| anyhow!("{}: {e:?}", $what))
    };
}

/// Typed input tensor for a stage call: flat data plus its dimensions.
pub enum In<'a> {
    /// f32 tensor (data, dims).
    F32(&'a [f32], &'a [i64]),
    /// i32 tensor (data, dims).
    I32(&'a [i32], &'a [i64]),
}

impl In<'_> {
    fn literal(&self) -> Result<xla::Literal> {
        match self {
            In::F32(data, dims) => {
                xerr!(xla::Literal::vec1(data).reshape(dims), "reshape f32 input")
            }
            In::I32(data, dims) => {
                xerr!(xla::Literal::vec1(data).reshape(dims), "reshape i32 input")
            }
        }
    }
}

/// One compiled decode/prefill stage.
pub struct Stage {
    /// Stage name, used in error messages.
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

impl Stage {
    /// Load an HLO-text artifact and compile it on the shared CPU client.
    pub fn load(name: &str, path: &Path) -> Result<Stage> {
        let client = super::client()?;
        let proto = xerr!(
            xla::HloModuleProto::from_text_file(path.to_str().context("utf8 path")?),
            format!("parse hlo text {path:?}")
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = xerr!(client.compile(&comp), format!("compile {name}"))?;
        Ok(Stage { name: name.to_string(), exe })
    }

    /// Execute with the given inputs; returns the decomposed output tuple.
    pub fn run(&self, inputs: &[In]) -> Result<StageOutput> {
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|i| i.literal()).collect::<Result<_>>()?;
        let result = xerr!(self.exe.execute::<xla::Literal>(&literals), format!("execute {}", self.name))?;
        let lit = xerr!(result[0][0].to_literal_sync(), "fetch result")?;
        let parts = xerr!(lit.to_tuple(), "decompose tuple")?;
        Ok(StageOutput { parts })
    }
}

/// Decomposed stage outputs.
pub struct StageOutput {
    /// The output tuple's elements, in lowering order.
    pub parts: Vec<xla::Literal>,
}

impl StageOutput {
    /// Output `i` flattened to f32.
    pub fn f32(&self, i: usize) -> Result<Vec<f32>> {
        xerr!(self.parts[i].to_vec::<f32>(), format!("output {i} as f32"))
    }
    /// Number of outputs in the tuple.
    pub fn len(&self) -> usize {
        self.parts.len()
    }
    /// Whether the stage returned an empty tuple.
    pub fn is_empty(&self) -> bool {
        self.parts.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build-free smoke: compile a tiny HLO module from text and run it.
    /// Exercises the full load→compile→execute→tuple path without needing
    /// `make artifacts`.
    #[test]
    fn hlo_text_round_trip() {
        let hlo = r#"
HloModule tiny, entry_computation_layout={(f32[4]{0}, f32[4]{0})->(f32[4]{0})}

ENTRY main {
  x = f32[4]{0} parameter(0)
  y = f32[4]{0} parameter(1)
  s = f32[4]{0} add(x, y)
  ROOT t = (f32[4]{0}) tuple(s)
}
"#;
        let dir = std::env::temp_dir().join("innerq_rt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tiny.hlo.txt");
        std::fs::write(&path, hlo).unwrap();
        let stage = Stage::load("tiny", &path).expect("load");
        let out = stage
            .run(&[
                In::F32(&[1.0, 2.0, 3.0, 4.0], &[4]),
                In::F32(&[10.0, 20.0, 30.0, 40.0], &[4]),
            ])
            .expect("run");
        assert_eq!(out.len(), 1);
        assert_eq!(out.f32(0).unwrap(), vec![11.0, 22.0, 33.0, 44.0]);
    }

    #[test]
    fn missing_artifact_errors_cleanly() {
        let err = Stage::load("nope", Path::new("/nonexistent/x.hlo.txt"));
        assert!(err.is_err());
    }
}
