//! Artifact manifest: the contract between `python/compile/aot.py` (writer)
//! and the Rust runtime (reader). Carries the model dimensions, tokenizer
//! charset, available decode batch sizes / prefill buckets, and the artifact
//! file names.

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

/// Model geometry as exported by the compile step.
#[derive(Debug, Clone)]
pub struct ModelDims {
    /// Vocabulary size (charset length + BOS/PAD).
    pub vocab: usize,
    /// Residual-stream width.
    pub d_model: usize,
    /// Transformer layer count.
    pub n_layers: usize,
    /// Query head count.
    pub n_q_heads: usize,
    /// KV head count (GQA: divides `n_q_heads`).
    pub n_kv_heads: usize,
    /// Per-head dimension.
    pub d_h: usize,
    /// Feed-forward hidden width.
    pub d_ff: usize,
    /// RoPE base frequency.
    pub rope_theta: f64,
}

impl ModelDims {
    /// Total query projection width (`n_q_heads * d_h`).
    pub fn q_dim(&self) -> usize {
        self.n_q_heads * self.d_h
    }
    /// Query heads served by each KV head (GQA fan-in).
    pub fn heads_per_kv(&self) -> usize {
        self.n_q_heads / self.n_kv_heads
    }
}

/// Parsed `manifest.json`: everything the runtime needs to load and drive
/// the exported stages.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Artifact directory (resolves the relative names in `artifacts`).
    pub dir: PathBuf,
    /// Model geometry.
    pub model: ModelDims,
    /// Tokenizer charset; char `i` maps to token `i + 1` (0 is BOS/PAD).
    pub charset: String,
    /// BOS/PAD token id.
    pub bos: i32,
    /// Exported decode batch sizes, ascending.
    pub decode_batches: Vec<usize>,
    /// Exported prefill sequence buckets, ascending.
    pub prefill_buckets: Vec<usize>,
    /// Context length the quantized-attention stages were lowered for.
    pub quant_attn_tokens: usize,
    /// Stage key → artifact file name, relative to `dir`.
    pub artifacts: std::collections::BTreeMap<String, String>,
    /// Final training loss recorded by the compile step (NaN if absent).
    pub final_train_loss: f64,
}

impl Manifest {
    /// Read and validate `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}; run `make artifacts` first"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let m = j.get("model");
        let model = ModelDims {
            vocab: m.get("vocab").as_usize().context("vocab")?,
            d_model: m.get("d_model").as_usize().context("d_model")?,
            n_layers: m.get("n_layers").as_usize().context("n_layers")?,
            n_q_heads: m.get("n_q_heads").as_usize().context("n_q_heads")?,
            n_kv_heads: m.get("n_kv_heads").as_usize().context("n_kv_heads")?,
            d_h: m.get("d_h").as_usize().context("d_h")?,
            d_ff: m.get("d_ff").as_usize().context("d_ff")?,
            rope_theta: m.get("rope_theta").as_f64().unwrap_or(10000.0),
        };
        let list_usize = |key: &str| -> Result<Vec<usize>> {
            j.get(key)
                .as_arr()
                .with_context(|| key.to_string())?
                .iter()
                .map(|v| v.as_usize().with_context(|| key.to_string()))
                .collect()
        };
        let artifacts = j
            .get("artifacts")
            .as_obj()
            .context("artifacts")?
            .iter()
            .map(|(k, v)| (k.clone(), v.as_str().unwrap_or_default().to_string()))
            .collect();
        Ok(Manifest {
            model,
            charset: j.get("charset").as_str().context("charset")?.to_string(),
            bos: j.get("bos").as_f64().unwrap_or(0.0) as i32,
            decode_batches: list_usize("decode_batches")?,
            prefill_buckets: list_usize("prefill_buckets")?,
            quant_attn_tokens: j.get("quant_attn_tokens").as_usize().unwrap_or(0),
            artifacts,
            final_train_loss: j.get("final_train_loss").as_f64().unwrap_or(f64::NAN),
            dir,
        })
    }

    /// Absolute path of a named artifact.
    pub fn path(&self, key: &str) -> Result<PathBuf> {
        let name = self
            .artifacts
            .get(key)
            .ok_or_else(|| anyhow!("artifact '{key}' not in manifest"))?;
        Ok(self.dir.join(name))
    }

    /// Smallest prefill bucket that fits `len` tokens.
    pub fn prefill_bucket(&self, len: usize) -> Result<usize> {
        self.prefill_buckets
            .iter()
            .copied()
            .find(|&b| b >= len)
            .ok_or_else(|| anyhow!("prompt of {len} tokens exceeds largest prefill bucket"))
    }

    /// Smallest exported decode batch that fits `n` sequences.
    pub fn decode_batch(&self, n: usize) -> Result<usize> {
        self.decode_batches
            .iter()
            .copied()
            .find(|&b| b >= n)
            .ok_or_else(|| anyhow!("batch of {n} exceeds largest decode batch"))
    }

    /// Tokenize with the manifest charset (token 0 = BOS/PAD).
    pub fn encode(&self, text: &str) -> Result<Vec<i32>> {
        text.chars()
            .map(|c| {
                self.charset
                    .chars()
                    .position(|x| x == c)
                    .map(|i| i as i32 + 1)
                    .ok_or_else(|| anyhow!("char {c:?} not in model charset"))
            })
            .collect()
    }

    /// Detokenize, skipping BOS/PAD and out-of-charset ids.
    pub fn decode_text(&self, tokens: &[i32]) -> String {
        let chars: Vec<char> = self.charset.chars().collect();
        tokens
            .iter()
            .filter(|&&t| t > 0 && (t as usize) <= chars.len())
            .map(|&t| chars[t as usize - 1])
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_manifest(dir: &Path) {
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"model":{"vocab":25,"d_model":128,"n_layers":3,"n_q_heads":4,
                "n_kv_heads":2,"d_h":32,"d_ff":256,"rope_theta":10000.0},
               "charset":"abcdefghij0123456789=;?.","bos":0,
               "decode_batches":[1,2,4,8],"prefill_buckets":[64,128],
               "quant_attn_tokens":512,
               "artifacts":{"embed_b1":"decode_embed_b1.hlo.txt"},
               "final_train_loss":1.25}"#,
        )
        .unwrap();
    }

    #[test]
    fn manifest_round_trip() {
        let dir = std::env::temp_dir().join("innerq_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        fake_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.model.d_h, 32);
        assert_eq!(m.model.heads_per_kv(), 2);
        assert_eq!(m.prefill_bucket(65).unwrap(), 128);
        assert!(m.prefill_bucket(1000).is_err());
        assert_eq!(m.decode_batch(3).unwrap(), 4);
        assert!(m.path("embed_b1").unwrap().ends_with("decode_embed_b1.hlo.txt"));
        assert!(m.path("nope").is_err());
    }

    #[test]
    fn tokenizer_round_trip() {
        let dir = std::env::temp_dir().join("innerq_manifest_test2");
        std::fs::create_dir_all(&dir).unwrap();
        fake_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        let toks = m.encode("a7=13;?a7=13.").unwrap();
        assert_eq!(m.decode_text(&toks), "a7=13;?a7=13.");
        assert!(m.encode("Z").is_err());
    }
}
