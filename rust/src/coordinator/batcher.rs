//! Dynamic decode batcher: groups live sequences into the exported batch
//! buckets each step (continuous batching à la Orca/vLLM, sized to the
//! decode executables AOT-compiled per bucket).

/// Decide the decode batch for this step.
///
/// * `live`: ids of sequences currently in the decode phase;
/// * `buckets`: available executable batch sizes (ascending);
/// * returns at most `max(buckets)` ids, preferring the oldest sequences
///   (FIFO fairness; the rest run next step).
pub fn plan_decode_batch(live: &[u64], buckets: &[usize]) -> Vec<u64> {
    if live.is_empty() || buckets.is_empty() {
        return Vec::new();
    }
    let cap = *buckets.last().unwrap();
    live.iter().copied().take(cap).collect()
}

/// Pick the bucket an n-sequence batch compiles into (smallest fit).
pub fn bucket_for(n: usize, buckets: &[usize]) -> Option<usize> {
    buckets.iter().copied().find(|&b| b >= n)
}

/// Padding waste of running `n` sequences in bucket `b` (fraction of compute
/// spent on padding rows) — exported to metrics to guide bucket choices.
pub fn padding_waste(n: usize, b: usize) -> f64 {
    if b == 0 {
        return 0.0;
    }
    (b - n) as f64 / b as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_and_capacity() {
        let live: Vec<u64> = (0..10).collect();
        let batch = plan_decode_batch(&live, &[1, 2, 4, 8]);
        assert_eq!(batch, (0..8).collect::<Vec<u64>>());
    }

    #[test]
    fn bucket_selection() {
        assert_eq!(bucket_for(1, &[1, 2, 4, 8]), Some(1));
        assert_eq!(bucket_for(3, &[1, 2, 4, 8]), Some(4));
        assert_eq!(bucket_for(9, &[1, 2, 4, 8]), None);
    }

    #[test]
    fn waste_accounting() {
        assert_eq!(padding_waste(3, 4), 0.25);
        assert_eq!(padding_waste(4, 4), 0.0);
    }

    #[test]
    fn empty_inputs() {
        assert!(plan_decode_batch(&[], &[1, 2]).is_empty());
        assert!(plan_decode_batch(&[1], &[]).is_empty());
    }
}
