//! Prefill/decode scheduler: admission via the cache pool, pluggable
//! admission/preemption policy (FIFO by default, priority-and-deadline-aware
//! under [`Policy::Slo`]), and continuous decode batching. Synchronous loop
//! on the driver thread; the per-step attention fan-out inside
//! `Engine::decode_step` runs on the engine's worker pool (`--workers N`).
//!
//! ## Clocks
//!
//! The scheduler never reads a wall clock itself: deadlines are evaluated
//! against a *virtual* clock advanced by the driver via
//! [`Scheduler::set_now`]. The trace-replay harness advances it from a
//! deterministic cost model (so replays are byte-identical), while the TCP
//! server advances it from wall-clock elapsed time. `Completion::ttft_us`
//! and `total_us` remain wall-clock measurements for live serving.
//!
//! ## Policies
//!
//! * [`Policy::Fifo`] (default) — admit in submission order; under cache
//!   pressure, preempt only strictly-younger live work, otherwise the head
//!   parks. This reproduces the pre-SLO scheduler ordering exactly.
//! * [`Policy::Slo`] — admit the most urgent queued request first, ordered
//!   by (priority class, deadline, submission time); under pressure, preempt
//!   live work of a *strictly lower* priority class (least important,
//!   youngest first). Priority inversion cannot occur: a class never
//!   preempts itself or anything more important.
//!
//! Both policies admit greedily — as many prefills per tick as the cache
//! budget allows — so a burst or ramp of arrivals does not serialize
//! admission one request per tick. Requests carrying a deadline are failed
//! terminally (reservation released) once the virtual clock passes it.

use crate::cache::{Admission, CachePool};
use crate::coordinator::batcher;
use crate::coordinator::engine::{Engine, Sequence};
use crate::coordinator::request::{Completion, Request, SchedEvent, StepMetrics};
use crate::util::rng::Rng;
use anyhow::Result;
use std::collections::VecDeque;
use std::time::Instant;

/// Admission/preemption policy. See the module docs for the exact rules.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Policy {
    /// Strict submission order; strictly-younger-only preemption.
    #[default]
    Fifo,
    /// Priority- and deadline-aware admission; cross-class preemption.
    Slo,
}

impl Policy {
    /// Parse a policy from its CLI name (`fifo` / `slo`).
    pub fn parse(s: &str) -> Option<Policy> {
        match s {
            "fifo" => Some(Policy::Fifo),
            "slo" => Some(Policy::Slo),
            _ => None,
        }
    }
}

/// A queued request plus the virtual time it was first submitted (preserved
/// across preemptions so deadlines are relative to *first* submission).
struct Queued {
    req: Request,
    submitted_us: u64,
}

impl Queued {
    /// Absolute virtual deadline, if the request carries one.
    fn deadline_abs(&self) -> Option<u64> {
        self.req.deadline_us.map(|d| self.submitted_us.saturating_add(d))
    }
}

struct Live {
    req: Request,
    submitted_us: u64,
    seq: Sequence,
    generated: Vec<i32>,
    next_token: i32,
    ttft_us: Option<u64>,
}

impl Live {
    fn deadline_abs(&self) -> Option<u64> {
        self.req.deadline_us.map(|d| self.submitted_us.saturating_add(d))
    }
}

/// Outcome of one admission attempt (see [`Scheduler::admit`]).
enum AdmitStep {
    /// The candidate reached a terminal or live state, or pressure was
    /// relieved — try to admit again this tick.
    Progress,
    /// The candidate must wait for live work to finish; stop admitting.
    Parked,
}

/// The serving scheduler: one instance owns the engine, the cache pool, the
/// admission queue, and the live decode batch. Drive it with
/// [`Scheduler::tick`] (one admission + decode round) or
/// [`Scheduler::run_to_completion`].
pub struct Scheduler {
    /// The decode engine (PJRT stages + quantized-cache attention).
    pub engine: Engine,
    /// Cross-sequence cache byte accounting and admission control.
    pub pool: CachePool,
    queue: VecDeque<Queued>,
    live: Vec<Live>,
    /// Terminal states accumulated since the last drain.
    pub done: Vec<Completion>,
    /// Monotonic counters across all ticks.
    pub metrics: StepMetrics,
    /// State-transition stream for the replay harness; empty unless enabled
    /// via [`Scheduler::record_events`].
    pub events: Vec<SchedEvent>,
    policy: Policy,
    record: bool,
    now_us: u64,
    stop_token: i32,
    rng: Rng,
}

impl Scheduler {
    /// A FIFO scheduler over `engine` with a cache budget of
    /// `cache_budget_bytes` across all live sequences.
    pub fn new(engine: Engine, cache_budget_bytes: usize) -> Scheduler {
        // '.' ends a document in the corpus grammar.
        let stop_token = engine
            .manifest
            .charset
            .chars()
            .position(|c| c == '.')
            .map(|i| i as i32 + 1)
            .unwrap_or(-1);
        Scheduler {
            engine,
            pool: CachePool::new(cache_budget_bytes),
            queue: VecDeque::new(),
            live: Vec::new(),
            done: Vec::new(),
            metrics: StepMetrics::default(),
            events: Vec::new(),
            policy: Policy::Fifo,
            record: false,
            now_us: 0,
            stop_token,
            rng: Rng::new(0xd1ce),
        }
    }

    /// Resize the engine's attention worker pool (1 = serial baseline).
    pub fn set_workers(&mut self, workers: usize) {
        self.engine.set_workers(workers);
    }

    /// Switch the admission/preemption policy (default [`Policy::Fifo`]).
    pub fn set_policy(&mut self, policy: Policy) {
        self.policy = policy;
    }

    /// The active admission/preemption policy.
    pub fn policy(&self) -> Policy {
        self.policy
    }

    /// Enable or disable [`SchedEvent`] recording into
    /// [`Scheduler::events`]. Off by default so a long-running server does
    /// not accumulate an unbounded log; the replay driver turns it on and
    /// drains with [`Scheduler::take_events`] every tick.
    pub fn record_events(&mut self, on: bool) {
        self.record = on;
        if !on {
            self.events.clear();
        }
    }

    /// Drain and return the recorded events.
    pub fn take_events(&mut self) -> Vec<SchedEvent> {
        std::mem::take(&mut self.events)
    }

    fn event(&mut self, ev: SchedEvent) {
        if self.record {
            self.events.push(ev);
        }
    }

    /// Advance the virtual clock (monotonic; earlier values are ignored).
    /// Deadlines are evaluated against this clock at every tick.
    pub fn set_now(&mut self, now_us: u64) {
        self.now_us = self.now_us.max(now_us);
    }

    /// Current virtual time in microseconds.
    pub fn now_us(&self) -> u64 {
        self.now_us
    }

    /// Enqueue a request for admission. Its deadline (if any) starts
    /// counting from the current virtual time.
    pub fn submit(&mut self, req: Request) {
        let now = self.now_us;
        self.submit_at(req, now);
    }

    /// Enqueue with an explicit submission timestamp — the replay driver
    /// passes the trace arrival time, so a request's deadline counts from
    /// when it *arrived*, not from the end of whatever long tick was in
    /// flight when the driver ingested it (keeping deadline accounting
    /// consistent with TTFT, which is also measured from arrival).
    pub fn submit_at(&mut self, req: Request, submitted_us: u64) {
        self.event(SchedEvent::Submitted { id: req.id });
        self.queue.push_back(Queued { req, submitted_us });
    }

    /// Requests not yet in a terminal state (queued + live).
    pub fn pending(&self) -> usize {
        self.queue.len() + self.live.len()
    }

    /// Estimated steady-state cache bytes for a prompt plus its generation
    /// budget: FP16 high-precision windows plus the quantized middle at the
    /// method's bit-widths (packed codes + per-group parameters). For
    /// unquantized methods, or sequences that fit inside the windows, this
    /// is the FP16 upper bound. A method that compresses harder therefore
    /// admits more concurrent sequences out of the same budget — the
    /// serving-side payoff the overload harness measures.
    fn estimate_bytes(&self, req: &Request) -> usize {
        let d = &self.engine.manifest.model;
        let cfg = &self.engine.cfg;
        let n = req.prompt.len() + req.max_new_tokens;
        let window = cfg.w_sink + cfg.w_recent;
        let (n_fp, n_q) = if cfg.is_quantized() && n > window {
            (window, n - window)
        } else {
            (n, 0)
        };
        // Per (layer, KV head): K and V rows at 2 bytes/element in the
        // windows; packed codes plus ~8 bytes of f32 params per 32-element
        // group for each of K and V in the quantized middle.
        let fp = 4 * n_fp * d.d_h;
        let codes = n_q * d.d_h * (cfg.key_bits as usize + cfg.val_bits as usize) / 8;
        let params = n_q * (d.d_h / 32).max(1) * 16;
        (fp + codes + params) * d.n_kv_heads * d.n_layers
    }

    /// Fail every queued or live request whose absolute deadline has passed.
    /// Live casualties release their cache reservation, so an expired
    /// stragglers' budget immediately becomes admissible headroom.
    fn expire_deadlines(&mut self) {
        let now = self.now_us;
        let mut expired: Vec<(Request, bool)> = Vec::new();
        let mut i = 0;
        while i < self.queue.len() {
            if self.queue[i].deadline_abs().map_or(false, |d| d <= now) {
                let q = self.queue.remove(i).unwrap();
                expired.push((q.req, true));
            } else {
                i += 1;
            }
        }
        let mut j = 0;
        while j < self.live.len() {
            if self.live[j].deadline_abs().map_or(false, |d| d <= now) {
                let l = self.live.remove(j);
                self.pool.release(l.req.id);
                expired.push((l.req, false));
            } else {
                j += 1;
            }
        }
        for (req, queued) in expired {
            self.metrics.expired += 1;
            self.event(SchedEvent::Expired { id: req.id, queued });
            self.done.push(Completion::failed(&req, "deadline exceeded"));
        }
    }

    /// Index of the next admission candidate, or None when the queue is
    /// empty. FIFO: the head. SLO: most urgent by (priority class, absolute
    /// deadline, first-submission time, id).
    fn next_candidate(&self) -> Option<usize> {
        match self.policy {
            Policy::Fifo => (!self.queue.is_empty()).then_some(0),
            Policy::Slo => (0..self.queue.len()).min_by_key(|&i| {
                let q = &self.queue[i];
                (
                    q.req.priority,
                    q.deadline_abs().unwrap_or(u64::MAX),
                    q.submitted_us,
                    q.req.id,
                )
            }),
        }
    }

    /// Release every cache-pool reservation without a live owner (left
    /// behind by a crashed prefill, or injected by tests), so admission can
    /// never live-lock on a stale id. Returns how many were dropped.
    fn release_stale_reservations(&mut self) -> usize {
        let stale: Vec<u64> = self
            .pool
            .ids()
            .filter(|id| !self.live.iter().any(|l| l.req.id == *id))
            .collect();
        for id in &stale {
            self.pool.release(*id);
        }
        self.metrics.stale_reservations += stale.len() as u64;
        stale.len()
    }

    /// Pick a preemption victim for `candidate` under the active policy, or
    /// None when nothing is eligible. FIFO: the youngest live sequence, and
    /// only if strictly younger than the candidate. SLO: the least-important
    /// live sequence of a *strictly lower* priority class, youngest first.
    fn pick_victim(&self, candidate: &Request) -> Option<usize> {
        match self.policy {
            Policy::Fifo => self
                .live
                .iter()
                .enumerate()
                .max_by_key(|(_, l)| l.req.id)
                .filter(|(_, l)| l.req.id > candidate.id)
                .map(|(i, _)| i),
            Policy::Slo => self
                .live
                .iter()
                .enumerate()
                .filter(|(_, l)| l.req.priority > candidate.priority)
                .max_by_key(|(_, l)| (l.req.priority, l.req.id))
                .map(|(i, _)| i),
        }
    }

    /// One admission attempt for the queue entry at `cidx`.
    fn try_admit(&mut self, cidx: usize) -> Result<AdmitStep> {
        let est = self.estimate_bytes(&self.queue[cidx].req);
        let id = self.queue[cidx].req.id;
        match self.pool.admit(id, est) {
            Admission::Admitted => {
                let q = self.queue.remove(cidx).unwrap();
                self.prefill_into_live(q);
                Ok(AdmitStep::Progress)
            }
            Admission::TooLarge => {
                let q = self.queue.remove(cidx).unwrap();
                self.metrics.rejected += 1;
                self.event(SchedEvent::Rejected { id: q.req.id });
                self.done.push(Completion::failed(
                    &q.req,
                    "request exceeds the cache budget outright",
                ));
                Ok(AdmitStep::Progress)
            }
            Admission::Pressure => {
                if self.release_stale_reservations() > 0 {
                    return Ok(AdmitStep::Progress);
                }
                if let Some(vidx) = self.pick_victim(&self.queue[cidx].req) {
                    // Recompute-style preemption: the victim's cache is
                    // dropped, its generated tokens are discarded, and it
                    // goes back to the queue (keeping its original
                    // submission time, so its deadline keeps counting).
                    let l = self.live.swap_remove(vidx);
                    self.pool.release(l.req.id);
                    self.metrics.preemptions += 1;
                    self.event(SchedEvent::Preempted { id: l.req.id });
                    self.queue.push_back(Queued { req: l.req, submitted_us: l.submitted_us });
                    return Ok(AdmitStep::Progress);
                }
                if self.live.is_empty() {
                    // Nothing to wait for and nothing to evict: the estimate
                    // cannot be satisfied — reject instead of spinning.
                    let q = self.queue.remove(cidx).unwrap();
                    self.metrics.rejected += 1;
                    self.event(SchedEvent::Rejected { id: q.req.id });
                    self.done.push(Completion::failed(
                        &q.req,
                        "cache pressure with nothing to preempt",
                    ));
                    return Ok(AdmitStep::Progress);
                }
                Ok(AdmitStep::Parked)
            }
        }
    }

    /// Run the admitted request's prefill and move it into the live batch
    /// (or fail it, giving its reservation back).
    fn prefill_into_live(&mut self, q: Queued) {
        let Queued { req, submitted_us } = q;
        // A bad prompt (or a failing prefill) must fail the request, not
        // the scheduler — and must give its reservation back.
        let prompt = match self.engine.manifest.encode(&req.prompt) {
            Ok(p) => p,
            Err(e) => {
                self.pool.release(req.id);
                self.metrics.rejected += 1;
                self.event(SchedEvent::Rejected { id: req.id });
                self.done.push(Completion::failed(&req, e.to_string()));
                return;
            }
        };
        let t0 = Instant::now();
        let seq = match self.engine.prefill(&prompt) {
            Ok(s) => s,
            Err(e) => {
                self.pool.release(req.id);
                self.metrics.rejected += 1;
                self.event(SchedEvent::Rejected { id: req.id });
                self.done.push(Completion::failed(&req, e.to_string()));
                return;
            }
        };
        self.metrics.prefill_tokens += prompt.len() as u64;
        self.event(SchedEvent::Admitted { id: req.id, prefill_tokens: prompt.len() });
        let next = self.sample(&seq.last_logits, req.temperature);
        self.live.push(Live {
            ttft_us: Some(t0.elapsed().as_micros() as u64),
            req,
            submitted_us,
            seq,
            generated: Vec::new(),
            next_token: next,
        });
    }

    /// Admit greedily: keep admitting the policy's next candidate until the
    /// queue drains or a candidate parks under pressure. Every iteration
    /// either retires a queue entry (admitted / rejected) or strictly
    /// shrinks pool state (stale release, preemption), so this terminates.
    fn admit(&mut self) -> Result<()> {
        loop {
            let Some(cidx) = self.next_candidate() else { return Ok(()) };
            match self.try_admit(cidx)? {
                AdmitStep::Progress => continue,
                AdmitStep::Parked => return Ok(()),
            }
        }
    }

    /// One scheduler tick: expire deadlines, admit as many prefills as the
    /// cache budget allows, then one decode step over the live batch.
    /// Returns false when idle.
    pub fn tick(&mut self) -> Result<bool> {
        if self.queue.is_empty() && self.live.is_empty() {
            return Ok(false);
        }
        self.expire_deadlines();
        self.admit()?;

        // --- decode step ---
        if !self.live.is_empty() {
            let ids: Vec<u64> = self.live.iter().map(|l| l.req.id).collect();
            let batch = batcher::plan_decode_batch(&ids, &self.engine.manifest.decode_batches);
            let mut idxs: Vec<usize> = batch
                .iter()
                .map(|id| self.live.iter().position(|l| l.req.id == *id).unwrap())
                .collect();
            idxs.sort_unstable();
            let tokens: Vec<i32> = idxs.iter().map(|&i| self.live[i].next_token).collect();
            // split_at_mut dance: collect &mut Sequence for the batch
            let mut seqs: Vec<&mut Sequence> = Vec::with_capacity(idxs.len());
            let mut rest: &mut [Live] = &mut self.live;
            let mut consumed = 0usize;
            for &i in &idxs {
                let (_, tail) = rest.split_at_mut(i - consumed);
                let (item, tail2) = tail.split_at_mut(1);
                seqs.push(&mut item[0].seq);
                rest = tail2;
                consumed = i + 1;
            }
            self.engine.decode_step(&mut seqs, &tokens)?;
            drop(seqs);
            let d = &self.engine.manifest.model;
            self.metrics.decode_steps += 1;
            self.metrics.batched_seqs += idxs.len() as u64;
            self.metrics.attn_jobs += (idxs.len() * d.n_kv_heads * d.n_layers) as u64;

            // post-step: record generated tokens, sample next, finish. The
            // stop token terminates the sequence but is *excluded* from the
            // completion text and count.
            let mut finished = Vec::new();
            for &i in &idxs {
                let l = &mut self.live[i];
                let is_stop = l.next_token == self.stop_token;
                if !is_stop {
                    l.generated.push(l.next_token);
                }
                self.pool.update(l.req.id, l.seq.cache_bytes());
                let done = is_stop || l.generated.len() >= l.req.max_new_tokens;
                if done {
                    finished.push(i);
                } else {
                    l.next_token = Self::sample_with(
                        &mut self.rng,
                        &l.seq.last_logits,
                        l.req.temperature,
                    );
                }
            }
            // Emit completions in live (admission) order, then remove in
            // descending index order so swap_remove cannot invalidate a
            // pending index.
            finished.sort_unstable();
            for &i in &finished {
                let c = {
                    let l = &self.live[i];
                    Completion {
                        id: l.req.id,
                        text: self.engine.manifest.decode_text(&l.generated),
                        n_prompt: l.req.prompt.len(),
                        n_generated: l.generated.len(),
                        ttft_us: l.ttft_us.unwrap_or(0),
                        total_us: l.req.arrived.elapsed().as_micros() as u64,
                        error: None,
                    }
                };
                self.event(SchedEvent::Finished { id: c.id, n_generated: c.n_generated });
                self.done.push(c);
            }
            for &i in finished.iter().rev() {
                let l = self.live.swap_remove(i);
                self.pool.release(l.req.id);
            }
        }
        Ok(true)
    }

    fn sample(&mut self, logits: &[f32], temperature: Option<f32>) -> i32 {
        Self::sample_with(&mut self.rng, logits, temperature)
    }

    fn sample_with(rng: &mut Rng, logits: &[f32], temperature: Option<f32>) -> i32 {
        match temperature {
            None => Engine::argmax(logits),
            Some(t) => {
                let t = t.max(1e-3);
                // Non-finite logits carry zero probability mass (a NaN here
                // must not poison the whole distribution).
                let m = logits
                    .iter()
                    .filter(|v| v.is_finite())
                    .fold(f32::NEG_INFINITY, |a, &b| a.max(b));
                if !m.is_finite() {
                    return Engine::argmax(logits);
                }
                let ps: Vec<f32> = logits
                    .iter()
                    .map(|&v| if v.is_finite() { ((v - m) / t).exp() } else { 0.0 })
                    .collect();
                let sum: f32 = ps.iter().sum();
                let mut u = rng.next_f32() * sum;
                for (i, &p) in ps.iter().enumerate() {
                    u -= p;
                    if u <= 0.0 {
                        return i as i32;
                    }
                }
                (ps.len() - 1) as i32
            }
        }
    }

    /// Drain the queue and all live sequences to completion.
    pub fn run_to_completion(&mut self) -> Result<Vec<Completion>> {
        while self.tick()? {}
        Ok(std::mem::take(&mut self.done))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn temperature_sampling_survives_nan_logits() {
        let mut rng = Rng::new(1);
        let logits = [1.0f32, f32::NAN, 0.5, f32::NEG_INFINITY];
        for _ in 0..50 {
            let t = Scheduler::sample_with(&mut rng, &logits, Some(0.7));
            assert!(t == 0 || t == 2, "sampled NaN/-inf token {t}");
        }
        // All-NaN falls back to argmax's index-0 default.
        assert_eq!(
            Scheduler::sample_with(&mut rng, &[f32::NAN, f32::NAN], Some(1.0)),
            0
        );
    }

    #[test]
    fn policy_parses_cli_names() {
        assert_eq!(Policy::parse("fifo"), Some(Policy::Fifo));
        assert_eq!(Policy::parse("slo"), Some(Policy::Slo));
        assert_eq!(Policy::parse("edf"), None);
        assert_eq!(Policy::default(), Policy::Fifo);
    }
}
