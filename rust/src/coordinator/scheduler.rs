//! Prefill/decode scheduler: admission via the cache pool, FIFO prefill, and
//! continuous decode batching. Single-worker synchronous loop (the testbed
//! is one CPU core; the router generalizes across workers).

use crate::cache::{Admission, CachePool};
use crate::coordinator::batcher;
use crate::coordinator::engine::{Engine, Sequence};
use crate::coordinator::request::{Completion, Request, StepMetrics};
use crate::util::rng::Rng;
use anyhow::Result;
use std::collections::VecDeque;
use std::time::Instant;

struct Live {
    req: Request,
    seq: Sequence,
    generated: Vec<i32>,
    next_token: i32,
    ttft_us: Option<u64>,
}

pub struct Scheduler {
    pub engine: Engine,
    pub pool: CachePool,
    queue: VecDeque<Request>,
    live: Vec<Live>,
    pub done: Vec<Completion>,
    pub metrics: StepMetrics,
    stop_token: i32,
    rng: Rng,
}

impl Scheduler {
    pub fn new(engine: Engine, cache_budget_bytes: usize) -> Scheduler {
        // '.' ends a document in the corpus grammar.
        let stop_token = engine
            .manifest
            .charset
            .chars()
            .position(|c| c == '.')
            .map(|i| i as i32 + 1)
            .unwrap_or(-1);
        Scheduler {
            engine,
            pool: CachePool::new(cache_budget_bytes),
            queue: VecDeque::new(),
            live: Vec::new(),
            done: Vec::new(),
            metrics: StepMetrics::default(),
            stop_token,
            rng: Rng::new(0xd1ce),
        }
    }

    pub fn submit(&mut self, req: Request) {
        self.queue.push_back(req);
    }

    pub fn pending(&self) -> usize {
        self.queue.len() + self.live.len()
    }

    /// Estimated cache bytes for a prompt + its generation budget.
    fn estimate_bytes(&self, req: &Request) -> usize {
        let d = &self.engine.manifest.model;
        let n = req.prompt.len() + req.max_new_tokens;
        // FP16-equivalent upper bound across layers/heads, both K and V.
        2 * 2 * n * d.d_h * d.n_kv_heads * d.n_layers
    }

    /// One scheduler tick: admit at most one prefill, then one decode step
    /// over the live batch. Returns false when idle.
    pub fn tick(&mut self) -> Result<bool> {
        if self.queue.is_empty() && self.live.is_empty() {
            return Ok(false);
        }
        // --- admission / prefill ---
        if let Some(req) = self.queue.front() {
            let est = self.estimate_bytes(req);
            match self.pool.admit(req.id, est) {
                Admission::Admitted => {
                    let req = self.queue.pop_front().unwrap();
                    let prompt = self.engine.manifest.encode(&req.prompt)?;
                    let t0 = Instant::now();
                    let seq = self.engine.prefill(&prompt)?;
                    self.metrics.prefill_tokens += prompt.len() as u64;
                    let next = self.sample(&seq.last_logits, req.temperature);
                    self.live.push(Live {
                        ttft_us: Some(t0.elapsed().as_micros() as u64),
                        req,
                        seq,
                        generated: Vec::new(),
                        next_token: next,
                    });
                }
                Admission::Pressure => {
                    // Preempt the youngest live sequence (recompute-style):
                    // push its request back to the queue and drop its cache.
                    if let Some(victim) = self.pool.youngest() {
                        if let Some(idx) = self.live.iter().position(|l| l.req.id == victim) {
                            let l = self.live.swap_remove(idx);
                            self.pool.release(victim);
                            self.metrics.preemptions += 1;
                            self.queue.push_back(l.req);
                        }
                    }
                }
                Admission::TooLarge => {
                    let req = self.queue.pop_front().unwrap();
                    self.done.push(Completion {
                        id: req.id,
                        text: String::new(),
                        n_prompt: req.prompt.len(),
                        n_generated: 0,
                        ttft_us: 0,
                        total_us: 0,
                    });
                }
            }
        }

        // --- decode step ---
        if !self.live.is_empty() {
            let ids: Vec<u64> = self.live.iter().map(|l| l.req.id).collect();
            let batch = batcher::plan_decode_batch(&ids, &self.engine.manifest.decode_batches);
            let mut idxs: Vec<usize> = batch
                .iter()
                .map(|id| self.live.iter().position(|l| l.req.id == *id).unwrap())
                .collect();
            idxs.sort_unstable();
            let tokens: Vec<i32> = idxs.iter().map(|&i| self.live[i].next_token).collect();
            // split_at_mut dance: collect &mut Sequence for the batch
            let mut seqs: Vec<&mut Sequence> = Vec::with_capacity(idxs.len());
            let mut rest: &mut [Live] = &mut self.live;
            let mut consumed = 0usize;
            for &i in &idxs {
                let (_, tail) = rest.split_at_mut(i - consumed);
                let (item, tail2) = tail.split_at_mut(1);
                seqs.push(&mut item[0].seq);
                rest = tail2;
                consumed = i + 1;
            }
            self.engine.decode_step(&mut seqs, &tokens)?;
            drop(seqs);
            self.metrics.decode_steps += 1;
            self.metrics.batched_seqs += idxs.len() as u64;

            // post-step: record generated tokens, sample next, finish.
            let mut finished = Vec::new();
            for &i in &idxs {
                let l = &mut self.live[i];
                l.generated.push(l.next_token);
                self.pool.update(l.req.id, l.seq.cache_bytes());
                let done = l.next_token == self.stop_token
                    || l.generated.len() >= l.req.max_new_tokens;
                if done {
                    finished.push(i);
                } else {
                    l.next_token = Self::sample_with(
                        &mut self.rng,
                        &l.seq.last_logits,
                        l.req.temperature,
                    );
                }
            }
            finished.sort_unstable_by(|a, b| b.cmp(a));
            for i in finished {
                let l = self.live.swap_remove(i);
                self.pool.release(l.req.id);
                self.done.push(Completion {
                    id: l.req.id,
                    text: self.engine.manifest.decode_text(&l.generated),
                    n_prompt: l.req.prompt.len(),
                    n_generated: l.generated.len(),
                    ttft_us: l.ttft_us.unwrap_or(0),
                    total_us: l.req.arrived.elapsed().as_micros() as u64,
                });
            }
        }
        Ok(true)
    }

    fn sample(&mut self, logits: &[f32], temperature: Option<f32>) -> i32 {
        Self::sample_with(&mut self.rng, logits, temperature)
    }

    fn sample_with(rng: &mut Rng, logits: &[f32], temperature: Option<f32>) -> i32 {
        match temperature {
            None => Engine::argmax(logits),
            Some(t) => {
                let t = t.max(1e-3);
                let m = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
                let ps: Vec<f32> = logits.iter().map(|&v| ((v - m) / t).exp()).collect();
                let sum: f32 = ps.iter().sum();
                let mut u = rng.next_f32() * sum;
                for (i, &p) in ps.iter().enumerate() {
                    u -= p;
                    if u <= 0.0 {
                        return i as i32;
                    }
                }
                (ps.len() - 1) as i32
            }
        }
    }

    /// Drain the queue and all live sequences to completion.
    pub fn run_to_completion(&mut self) -> Result<Vec<Completion>> {
        while self.tick()? {}
        Ok(std::mem::take(&mut self.done))
    }
}
