//! Prefill/decode scheduler: admission via the cache pool, FIFO prefill, and
//! continuous decode batching. Synchronous loop on the driver thread; the
//! per-step attention fan-out inside `Engine::decode_step` runs on the
//! engine's worker pool (`--workers N`).

use crate::cache::{Admission, CachePool};
use crate::coordinator::batcher;
use crate::coordinator::engine::{Engine, Sequence};
use crate::coordinator::request::{Completion, Request, StepMetrics};
use crate::util::rng::Rng;
use anyhow::Result;
use std::collections::VecDeque;
use std::time::Instant;

struct Live {
    req: Request,
    seq: Sequence,
    generated: Vec<i32>,
    next_token: i32,
    ttft_us: Option<u64>,
}

pub struct Scheduler {
    pub engine: Engine,
    pub pool: CachePool,
    queue: VecDeque<Request>,
    live: Vec<Live>,
    pub done: Vec<Completion>,
    pub metrics: StepMetrics,
    stop_token: i32,
    rng: Rng,
}

impl Scheduler {
    pub fn new(engine: Engine, cache_budget_bytes: usize) -> Scheduler {
        // '.' ends a document in the corpus grammar.
        let stop_token = engine
            .manifest
            .charset
            .chars()
            .position(|c| c == '.')
            .map(|i| i as i32 + 1)
            .unwrap_or(-1);
        Scheduler {
            engine,
            pool: CachePool::new(cache_budget_bytes),
            queue: VecDeque::new(),
            live: Vec::new(),
            done: Vec::new(),
            metrics: StepMetrics::default(),
            stop_token,
            rng: Rng::new(0xd1ce),
        }
    }

    /// Resize the engine's attention worker pool (1 = serial baseline).
    pub fn set_workers(&mut self, workers: usize) {
        self.engine.set_workers(workers);
    }

    pub fn submit(&mut self, req: Request) {
        self.queue.push_back(req);
    }

    pub fn pending(&self) -> usize {
        self.queue.len() + self.live.len()
    }

    /// Estimated cache bytes for a prompt + its generation budget.
    fn estimate_bytes(&self, req: &Request) -> usize {
        let d = &self.engine.manifest.model;
        let n = req.prompt.len() + req.max_new_tokens;
        // FP16-equivalent upper bound across layers/heads, both K and V.
        2 * 2 * n * d.d_h * d.n_kv_heads * d.n_layers
    }

    /// Admit the queue head if the cache pool allows it.
    fn admit_head(&mut self) -> Result<()> {
        let Some(req) = self.queue.front() else { return Ok(()) };
        let est = self.estimate_bytes(req);
        match self.pool.admit(req.id, est) {
            Admission::Admitted => {
                let req = self.queue.pop_front().unwrap();
                // A bad prompt (or a failing prefill) must fail the request,
                // not the scheduler — and must give its reservation back.
                let prompt = match self.engine.manifest.encode(&req.prompt) {
                    Ok(p) => p,
                    Err(e) => {
                        self.pool.release(req.id);
                        self.metrics.rejected += 1;
                        self.done.push(Completion::failed(&req, e.to_string()));
                        return Ok(());
                    }
                };
                let t0 = Instant::now();
                let seq = match self.engine.prefill(&prompt) {
                    Ok(s) => s,
                    Err(e) => {
                        self.pool.release(req.id);
                        self.metrics.rejected += 1;
                        self.done.push(Completion::failed(&req, e.to_string()));
                        return Ok(());
                    }
                };
                self.metrics.prefill_tokens += prompt.len() as u64;
                let next = self.sample(&seq.last_logits, req.temperature);
                self.live.push(Live {
                    ttft_us: Some(t0.elapsed().as_micros() as u64),
                    req,
                    seq,
                    generated: Vec::new(),
                    next_token: next,
                });
            }
            Admission::Pressure => {
                // Preempt strictly-younger live work (recompute-style): the
                // request goes back to the queue and its cache is dropped.
                // Reservations without a live owner (e.g. left behind by a
                // crashed prefill) are released on the way, so admission can
                // never live-lock on a stale id. If all live work is older
                // than the head, the head parks and waits — preempting older
                // work would just thrash prefills back and forth.
                let head_id = req.id;
                let mut progressed = false;
                while let Some(victim) = self.pool.youngest() {
                    match self.live.iter().position(|l| l.req.id == victim) {
                        None => {
                            self.pool.release(victim);
                            self.metrics.stale_reservations += 1;
                            progressed = true;
                        }
                        Some(idx) if victim > head_id => {
                            let l = self.live.swap_remove(idx);
                            self.pool.release(victim);
                            self.metrics.preemptions += 1;
                            self.queue.push_back(l.req);
                            progressed = true;
                            break;
                        }
                        Some(_) => break, // oldest work keeps running
                    }
                }
                if !progressed && self.live.is_empty() {
                    // Nothing to wait for and nothing to evict: the estimate
                    // cannot be satisfied — reject instead of spinning.
                    let req = self.queue.pop_front().unwrap();
                    self.metrics.rejected += 1;
                    self.done.push(Completion::failed(
                        &req,
                        "cache pressure with nothing to preempt",
                    ));
                }
            }
            Admission::TooLarge => {
                let req = self.queue.pop_front().unwrap();
                self.metrics.rejected += 1;
                self.done.push(Completion::failed(
                    &req,
                    "request exceeds the cache budget outright",
                ));
            }
        }
        Ok(())
    }

    /// One scheduler tick: admit at most one prefill, then one decode step
    /// over the live batch. Returns false when idle.
    pub fn tick(&mut self) -> Result<bool> {
        if self.queue.is_empty() && self.live.is_empty() {
            return Ok(false);
        }
        self.admit_head()?;

        // --- decode step ---
        if !self.live.is_empty() {
            let ids: Vec<u64> = self.live.iter().map(|l| l.req.id).collect();
            let batch = batcher::plan_decode_batch(&ids, &self.engine.manifest.decode_batches);
            let mut idxs: Vec<usize> = batch
                .iter()
                .map(|id| self.live.iter().position(|l| l.req.id == *id).unwrap())
                .collect();
            idxs.sort_unstable();
            let tokens: Vec<i32> = idxs.iter().map(|&i| self.live[i].next_token).collect();
            // split_at_mut dance: collect &mut Sequence for the batch
            let mut seqs: Vec<&mut Sequence> = Vec::with_capacity(idxs.len());
            let mut rest: &mut [Live] = &mut self.live;
            let mut consumed = 0usize;
            for &i in &idxs {
                let (_, tail) = rest.split_at_mut(i - consumed);
                let (item, tail2) = tail.split_at_mut(1);
                seqs.push(&mut item[0].seq);
                rest = tail2;
                consumed = i + 1;
            }
            self.engine.decode_step(&mut seqs, &tokens)?;
            drop(seqs);
            let d = &self.engine.manifest.model;
            self.metrics.decode_steps += 1;
            self.metrics.batched_seqs += idxs.len() as u64;
            self.metrics.attn_jobs += (idxs.len() * d.n_kv_heads * d.n_layers) as u64;

            // post-step: record generated tokens, sample next, finish. The
            // stop token terminates the sequence but is *excluded* from the
            // completion text and count.
            let mut finished = Vec::new();
            for &i in &idxs {
                let l = &mut self.live[i];
                let is_stop = l.next_token == self.stop_token;
                if !is_stop {
                    l.generated.push(l.next_token);
                }
                self.pool.update(l.req.id, l.seq.cache_bytes());
                let done = is_stop || l.generated.len() >= l.req.max_new_tokens;
                if done {
                    finished.push(i);
                } else {
                    l.next_token = Self::sample_with(
                        &mut self.rng,
                        &l.seq.last_logits,
                        l.req.temperature,
                    );
                }
            }
            finished.sort_unstable_by(|a, b| b.cmp(a));
            for i in finished {
                let l = self.live.swap_remove(i);
                self.pool.release(l.req.id);
                self.done.push(Completion {
                    id: l.req.id,
                    text: self.engine.manifest.decode_text(&l.generated),
                    n_prompt: l.req.prompt.len(),
                    n_generated: l.generated.len(),
                    ttft_us: l.ttft_us.unwrap_or(0),
                    total_us: l.req.arrived.elapsed().as_micros() as u64,
                    error: None,
                });
            }
        }
        Ok(true)
    }

    fn sample(&mut self, logits: &[f32], temperature: Option<f32>) -> i32 {
        Self::sample_with(&mut self.rng, logits, temperature)
    }

    fn sample_with(rng: &mut Rng, logits: &[f32], temperature: Option<f32>) -> i32 {
        match temperature {
            None => Engine::argmax(logits),
            Some(t) => {
                let t = t.max(1e-3);
                // Non-finite logits carry zero probability mass (a NaN here
                // must not poison the whole distribution).
                let m = logits
                    .iter()
                    .filter(|v| v.is_finite())
                    .fold(f32::NEG_INFINITY, |a, &b| a.max(b));
                if !m.is_finite() {
                    return Engine::argmax(logits);
                }
                let ps: Vec<f32> = logits
                    .iter()
                    .map(|&v| if v.is_finite() { ((v - m) / t).exp() } else { 0.0 })
                    .collect();
                let sum: f32 = ps.iter().sum();
                let mut u = rng.next_f32() * sum;
                for (i, &p) in ps.iter().enumerate() {
                    u -= p;
                    if u <= 0.0 {
                        return i as i32;
                    }
                }
                (ps.len() - 1) as i32
            }
        }
    }

    /// Drain the queue and all live sequences to completion.
    pub fn run_to_completion(&mut self) -> Result<Vec<Completion>> {
        while self.tick()? {}
        Ok(std::mem::take(&mut self.done))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn temperature_sampling_survives_nan_logits() {
        let mut rng = Rng::new(1);
        let logits = [1.0f32, f32::NAN, 0.5, f32::NEG_INFINITY];
        for _ in 0..50 {
            let t = Scheduler::sample_with(&mut rng, &logits, Some(0.7));
            assert!(t == 0 || t == 2, "sampled NaN/-inf token {t}");
        }
        // All-NaN falls back to argmax's index-0 default.
        assert_eq!(
            Scheduler::sample_with(&mut rng, &[f32::NAN, f32::NAN], Some(1.0)),
            0
        );
    }
}
