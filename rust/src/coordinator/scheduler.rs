//! Prefill/decode scheduler: admission via the cache pool, pluggable
//! admission/preemption policy (FIFO by default, priority-and-deadline-aware
//! under [`Policy::Slo`]), and continuous decode batching. Synchronous loop
//! on the driver thread; the per-step attention fan-out inside
//! `Engine::decode_step` runs on the engine's worker pool (`--workers N`).
//!
//! ## Clocks
//!
//! The scheduler never reads a wall clock itself: deadlines are evaluated
//! against a *virtual* clock advanced by the driver via
//! [`Scheduler::set_now`]. The trace-replay harness advances it from a
//! deterministic cost model (so replays are byte-identical), while the TCP
//! server advances it from wall-clock elapsed time. `Completion::ttft_us`
//! and `total_us` remain wall-clock measurements for live serving.
//!
//! ## Policies
//!
//! * [`Policy::Fifo`] (default) — admit in submission order; under cache
//!   pressure, preempt only strictly-younger live work, otherwise the head
//!   parks. This reproduces the pre-SLO scheduler ordering exactly.
//! * [`Policy::Slo`] — admit the most urgent queued request first, ordered
//!   by (priority class, deadline, submission time); under pressure, preempt
//!   live work of a *strictly lower* priority class (least important,
//!   youngest first). Priority inversion cannot occur: a class never
//!   preempts itself or anything more important. When the most urgent
//!   candidate parks, a *bounded* number of strictly-smaller, strictly
//!   lower-class requests may bypass it ([`Scheduler::set_bypass_limit`]),
//!   so spare budget is not wasted but the head cannot starve.
//!
//! Both policies admit greedily — as many prefills per tick as the cache
//! budget allows — so a burst or ramp of arrivals does not serialize
//! admission one request per tick, and both preempt only when evicting the
//! policy's eligible victims can actually fit the candidate (a preemption
//! that would leave the candidate parked anyway destroys work for
//! nothing). Requests carrying a deadline are failed terminally
//! (reservation released) once the virtual clock passes it.
//!
//! ## Preemption modes
//!
//! What happens to a preemption victim is orthogonal to who gets picked:
//!
//! * [`Preemption::Recompute`] (default) — the victim's cache is dropped
//!   and its generated tokens discarded; it re-queues and will re-prefill
//!   from scratch (vLLM-style recompute preemption).
//! * [`Preemption::Offload`] — the victim's full sequence (token history,
//!   last logits, every quantized `HeadCache`) is serialized bit-exactly
//!   (`cache::store::snapshot`) into the segcache-style warm tier
//!   ([`Scheduler::tier`]) and the victim keeps a warm-tier residency
//!   instead of a cache-pool reservation. Readmission *restores* the
//!   snapshot — cheap deserialization, no re-prefill — and resumes decoding
//!   bit-identically to a never-offloaded run. If the tier refuses the
//!   snapshot (budget, or only more-important residents in the way) the
//!   victim falls back to recompute; if its snapshot is evicted while warm
//!   (terminal "dropped" state), readmission falls back to a re-prefill and
//!   emits [`SchedEvent::OffloadLost`].

use crate::cache::store::{
    prefix_base_hash, restore_sequence_frames_with, snapshot_sequence_frames_by_ref,
    snapshot_sequence_frames_on, FrameKind, PrefixStore, WarmTier, DEFAULT_SEG_BYTES,
};
use crate::cache::{Admission, CachePool};
use crate::coordinator::batcher;
use crate::coordinator::engine::{Engine, PipelineMode, PrefixOutcome, Sequence};
use crate::coordinator::request::{Completion, Priority, Request, SchedEvent, StepMetrics};
use crate::obs;
use crate::util::rng::Rng;
use anyhow::{anyhow, Result};
use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Admission/preemption policy. See the module docs for the exact rules.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Policy {
    /// Strict submission order; strictly-younger-only preemption.
    #[default]
    Fifo,
    /// Priority- and deadline-aware admission; cross-class preemption.
    Slo,
}

impl Policy {
    /// Parse a policy from its CLI name (`fifo` / `slo`).
    pub fn parse(s: &str) -> Option<Policy> {
        match s {
            "fifo" => Some(Policy::Fifo),
            "slo" => Some(Policy::Slo),
            _ => None,
        }
    }
}

/// What happens to a preemption victim's cache (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Preemption {
    /// Drop the cache and discard generated tokens; re-prefill on
    /// readmission.
    #[default]
    Recompute,
    /// Snapshot the full sequence into the warm tier; restore (no
    /// re-prefill) on readmission.
    Offload,
}

impl Preemption {
    /// Parse a preemption mode from its CLI name (`recompute` / `offload`).
    pub fn parse(s: &str) -> Option<Preemption> {
        match s {
            "recompute" => Some(Preemption::Recompute),
            "offload" => Some(Preemption::Offload),
            _ => None,
        }
    }

    /// Stable CLI/report name.
    pub fn name(self) -> &'static str {
        match self {
            Preemption::Recompute => "recompute",
            Preemption::Offload => "offload",
        }
    }
}

/// A queued request plus the virtual time it was first submitted (preserved
/// across preemptions so deadlines are relative to *first* submission).
struct Queued {
    req: Request,
    submitted_us: u64,
}

impl Queued {
    /// Absolute virtual deadline, if the request carries one.
    fn deadline_abs(&self) -> Option<u64> {
        self.req.deadline_us.map(|d| self.submitted_us.saturating_add(d))
    }
}

struct Live {
    req: Request,
    submitted_us: u64,
    seq: Sequence,
    generated: Vec<i32>,
    next_token: i32,
    ttft_us: Option<u64>,
}

impl Live {
    fn deadline_abs(&self) -> Option<u64> {
        self.req.deadline_us.map(|d| self.submitted_us.saturating_add(d))
    }
}

/// An offload-preempted request: its decode progress stays here (small) and
/// its serialized cache lives in the warm tier keyed by `req.id` (bulky).
struct Warm {
    req: Request,
    submitted_us: u64,
    generated: Vec<i32>,
    next_token: i32,
    ttft_us: Option<u64>,
}

/// An offloaded request's scheduler-side bookkeeping, detached from its
/// scheduler for cross-replica migration ([`Scheduler::export_warm`] /
/// [`Scheduler::import_warm`]). The bulky part — the serialized snapshot
/// frames — stays in the source warm tier; the fleet router moves those
/// separately as a byte copy (`coordinator::fleet`).
pub struct WarmExport {
    /// The offloaded request itself.
    pub req: Request,
    /// Virtual time of first submission (deadlines count from here).
    pub submitted_us: u64,
    /// Tokens decoded before preemption.
    pub generated: Vec<i32>,
    /// The sampled-but-not-yet-fed token decode resumes with.
    pub next_token: i32,
    /// Wall-clock time to first token, if the request got that far.
    pub ttft_us: Option<u64>,
}

impl Warm {
    fn deadline_abs(&self) -> Option<u64> {
        self.req.deadline_us.map(|d| self.submitted_us.saturating_add(d))
    }
}

/// An admission candidate: a fresh (or recompute-preempted) queue entry, or
/// an offloaded sequence awaiting restoration from the warm tier.
#[derive(Debug, Clone, Copy)]
enum Candidate {
    Queued(usize),
    Warm(usize),
}

/// Outcome of one admission attempt (see [`Scheduler::admit`]).
enum AdmitStep {
    /// The candidate reached a terminal or live state, or pressure was
    /// relieved — try to admit again this tick.
    Progress,
    /// The candidate must wait for live work to finish; stop admitting.
    Parked,
}

/// The prefix-store pins one live or warm sequence holds: enough to
/// release the whole `(layer, head)` image grid when the sequence retires,
/// and to write snapshot frames by reference while it is offloaded.
struct PrefixHandle {
    /// Content hash of `(MethodConfig, prefix tokens)`.
    base: u64,
    /// Grid dimensions of the pinned image set.
    n_layers: usize,
    n_heads: usize,
}

/// The serving scheduler: one instance owns the engine, the cache pool, the
/// warm tier, the prefix store, the admission queue, and the live decode
/// batch. Drive it with [`Scheduler::tick`] (one admission + decode round)
/// or [`Scheduler::run_to_completion`].
pub struct Scheduler {
    /// The decode engine (PJRT stages + quantized-cache attention).
    pub engine: Engine,
    /// Cross-sequence cache byte accounting and admission control.
    pub pool: CachePool,
    /// Warm tier holding offload-preempted sequence snapshots
    /// ([`Preemption::Offload`]); unused under recompute preemption.
    pub tier: WarmTier,
    /// Content-addressed store of shared quantized prefix images. Consulted
    /// at admission (incremental byte accounting) and prefill (borrow
    /// instead of quantize) when [`Scheduler::set_prefix_share`] is on.
    pub prefix_store: PrefixStore,
    prefix_share: bool,
    /// Pins held per live/warm request id; see [`PrefixHandle`].
    prefix_refs: BTreeMap<u64, PrefixHandle>,
    queue: VecDeque<Queued>,
    live: Vec<Live>,
    warm: Vec<Warm>,
    /// Terminal states accumulated since the last drain.
    pub done: Vec<Completion>,
    /// Monotonic counters across all ticks.
    pub metrics: StepMetrics,
    /// State-transition stream for the replay harness; empty unless enabled
    /// via [`Scheduler::record_events`].
    pub events: Vec<SchedEvent>,
    /// Tracing flight recorder ([`crate::obs`]): every tick drains the
    /// per-thread span rings into it (no-op while tracing is off). Shared
    /// so the admin plane can lock it for `metrics`/`trace` replies without
    /// touching the data path.
    pub obs: Arc<Mutex<obs::recorder::Recorder>>,
    policy: Policy,
    preemption: Preemption,
    /// Bypass admissions granted past each parked head, keyed by head id so
    /// an interleaved more-urgent head cannot reset another head's count.
    /// Entries are pruned when the head leaves the pending pools.
    bypass_used: BTreeMap<u64, u32>,
    bypass_limit: u32,
    record: bool,
    /// Per-tick token progress `(request id, token)` for streaming
    /// consumers; empty unless enabled via [`Scheduler::record_progress`].
    progress: Vec<(u64, i32)>,
    progress_on: bool,
    now_us: u64,
    stop_token: i32,
    rng: Rng,
    /// Static replica annotation for driver spans when this scheduler is one
    /// of a fleet ([`Scheduler::set_replica`]); None for a lone scheduler.
    replica_tag: Option<&'static str>,
}

/// How much larger the default warm-tier budget is than the cache budget:
/// snapshots live in host memory, which is roughly an order of magnitude
/// more plentiful than the device-side cache budget they were evicted from.
const DEFAULT_WARM_FACTOR: usize = 8;

/// Default cap on how many smaller lower-class requests may bypass one
/// parked head over that head's lifetime (SLO policy only).
const DEFAULT_BYPASS_LIMIT: u32 = 4;

/// Default prefix-store budget as a multiple of the cache budget. Images
/// are quantized middles only (no fp windows), so one cache budget's worth
/// of store holds many distinct prefixes.
const DEFAULT_PREFIX_FACTOR: usize = 1;

impl Scheduler {
    /// A FIFO scheduler over `engine` with a cache budget of
    /// `cache_budget_bytes` across all live sequences. The warm tier
    /// defaults to `8x` that budget (host-side memory; see
    /// [`Scheduler::set_warm_budget`]).
    pub fn new(engine: Engine, cache_budget_bytes: usize) -> Scheduler {
        // '.' ends a document in the corpus grammar.
        let stop_token = engine
            .manifest
            .charset
            .chars()
            .position(|c| c == '.')
            .map(|i| i as i32 + 1)
            .unwrap_or(-1);
        Scheduler {
            engine,
            pool: CachePool::new(cache_budget_bytes),
            tier: WarmTier::new(
                cache_budget_bytes.saturating_mul(DEFAULT_WARM_FACTOR),
                DEFAULT_SEG_BYTES,
            ),
            prefix_store: PrefixStore::new(
                cache_budget_bytes.saturating_mul(DEFAULT_PREFIX_FACTOR),
            ),
            prefix_share: true,
            prefix_refs: BTreeMap::new(),
            queue: VecDeque::new(),
            live: Vec::new(),
            warm: Vec::new(),
            done: Vec::new(),
            metrics: StepMetrics::default(),
            events: Vec::new(),
            obs: Arc::new(Mutex::new(obs::recorder::Recorder::new())),
            policy: Policy::Fifo,
            preemption: Preemption::Recompute,
            bypass_used: BTreeMap::new(),
            bypass_limit: DEFAULT_BYPASS_LIMIT,
            record: false,
            progress: Vec::new(),
            progress_on: false,
            now_us: 0,
            stop_token,
            rng: Rng::new(0xd1ce),
            replica_tag: None,
        }
    }

    /// Tag this scheduler as fleet replica `idx`: its driver-tick spans
    /// carry the replica tag so a fleet trace separates per-replica
    /// timelines. Tagging never changes scheduling behavior.
    pub fn set_replica(&mut self, idx: usize) {
        self.replica_tag = Some(obs::replica_tag(idx));
    }

    /// Resize the engine's attention worker pool (1 = serial baseline).
    pub fn set_workers(&mut self, workers: usize) {
        self.engine.set_workers(workers);
    }

    /// Switch the engine's decode-step execution mode (default
    /// [`PipelineMode::Overlap`]; `barrier` retains the phase-barriered
    /// oracle path).
    pub fn set_pipeline(&mut self, mode: PipelineMode) {
        self.engine.set_pipeline(mode);
    }

    /// Switch the admission/preemption policy (default [`Policy::Fifo`]).
    pub fn set_policy(&mut self, policy: Policy) {
        self.policy = policy;
    }

    /// The active admission/preemption policy.
    pub fn policy(&self) -> Policy {
        self.policy
    }

    /// Switch the preemption mode (default [`Preemption::Recompute`]).
    pub fn set_preemption(&mut self, mode: Preemption) {
        self.preemption = mode;
    }

    /// The active preemption mode.
    pub fn preemption(&self) -> Preemption {
        self.preemption
    }

    /// Replace the warm tier with one of `budget_bytes` capacity. Call
    /// before serving: any resident snapshots are discarded (their owners
    /// fall back to re-prefill via the offload-lost path).
    pub fn set_warm_budget(&mut self, budget_bytes: usize) {
        self.tier = WarmTier::new(budget_bytes, DEFAULT_SEG_BYTES);
    }

    /// Enable or disable prefix sharing (default on). Off, requests with a
    /// declared prefix still quantize under the split-norm numerics contract
    /// (so outputs are byte-identical either way) but never touch the store:
    /// every sequence owns private copies and admission charges full bytes.
    pub fn set_prefix_share(&mut self, on: bool) {
        self.prefix_share = on;
    }

    /// Whether prefix sharing is enabled.
    pub fn prefix_share(&self) -> bool {
        self.prefix_share
    }

    /// Replace the prefix store with one of `budget_bytes` capacity. Call
    /// before serving: resident images (and any pins) are discarded, so live
    /// borrowers would leak pins if swapped mid-flight.
    pub fn set_prefix_budget(&mut self, budget_bytes: usize) {
        self.prefix_store = PrefixStore::new(budget_bytes);
        self.prefix_refs.clear();
    }

    /// Cap on SLO small-request bypass admissions per parked head (0
    /// disables bypass; default 4). The count is tracked per head id and
    /// persists until the head itself is admitted or fails, so no request
    /// can be bypassed more than this many times while it waits — the
    /// starvation bound.
    pub fn set_bypass_limit(&mut self, limit: u32) {
        self.bypass_limit = limit;
    }

    /// Enable or disable [`SchedEvent`] recording into
    /// [`Scheduler::events`]. Off by default so a long-running server does
    /// not accumulate an unbounded log; the replay driver turns it on and
    /// drains with [`Scheduler::take_events`] every tick.
    pub fn record_events(&mut self, on: bool) {
        self.record = on;
        if !on {
            self.events.clear();
        }
    }

    /// Drain and return the recorded events.
    pub fn take_events(&mut self) -> Vec<SchedEvent> {
        std::mem::take(&mut self.events)
    }

    /// Enable or disable per-token progress recording (off by default). On,
    /// every decode tick appends `(request id, token)` for each token a live
    /// sequence just committed; the TCP server drains this with
    /// [`Scheduler::take_progress`] to stream tokens to clients as they are
    /// produced. Tokens are recorded in live-batch order, so the stream per
    /// request is exactly its completion text's token sequence.
    pub fn record_progress(&mut self, on: bool) {
        self.progress_on = on;
        if !on {
            self.progress.clear();
        }
    }

    /// Drain and return the recorded per-token progress.
    pub fn take_progress(&mut self) -> Vec<(u64, i32)> {
        std::mem::take(&mut self.progress)
    }

    /// Number of requests currently holding prefix-store pins (live or
    /// offloaded borrowers). Exposed for the admin stats plane and the
    /// cancellation tests: after every borrower retires this must be 0.
    pub fn prefix_pins(&self) -> usize {
        self.prefix_refs.len()
    }

    /// Cancel a pending request (client disconnected): remove it from
    /// whichever pool holds it — admission queue, live decode batch, or warm
    /// tier — and release every hold it owns: its [`CachePool`] reservation,
    /// its warm-tier residency, its prefix-store pins, and its bypass
    /// bookkeeping. Terminal; no [`Completion`] is pushed (there is no one
    /// left to read it). Returns false when `id` is not pending (already
    /// finished, failed, or never submitted) — the normal race between a
    /// disconnect and a completion, harmless on either side.
    pub fn cancel(&mut self, id: u64) -> bool {
        let (req, generated) = if let Some(i) = self.queue.iter().position(|q| q.req.id == id) {
            (self.queue.remove(i).unwrap().req, 0)
        } else if let Some(i) = self.live.iter().position(|l| l.req.id == id) {
            // `remove`, not `swap_remove`: the live batch's order is the
            // admission order completions are emitted in, and a cancellation
            // must not reshuffle the surviving sequences.
            let l = self.live.remove(i);
            self.pool.release(id);
            (l.req, l.generated.len())
        } else if let Some(i) = self.warm.iter().position(|w| w.req.id == id) {
            let w = self.warm.remove(i);
            self.tier.remove(id);
            (w.req, w.generated.len())
        } else {
            return false;
        };
        self.bypass_used.remove(&id);
        self.release_prefix(id);
        self.metrics.cancelled += 1;
        self.event(SchedEvent::Cancelled { id });
        self.request_span(&req, generated, "cancelled");
        true
    }

    /// Emit the whole-request lifecycle span — arrival instant to now,
    /// tagged with the terminal outcome (matching the replay harness's
    /// outcome names, plus `cancelled`). One per request, at its single
    /// terminal transition; no-op while tracing is off.
    fn request_span(&self, req: &Request, generated: usize, outcome: &'static str) {
        if !obs::enabled() {
            return;
        }
        let start = obs::epoch_us_of(req.arrived);
        obs::mark(
            obs::SpanKind::Request,
            req.id,
            start,
            obs::now_us().max(start),
            req.priority.level() as u64,
            generated as u64,
            Some(outcome),
        );
    }

    fn event(&mut self, ev: SchedEvent) {
        if self.record {
            self.events.push(ev);
        }
    }

    /// Advance the virtual clock (monotonic; earlier values are ignored).
    /// Deadlines are evaluated against this clock at every tick.
    pub fn set_now(&mut self, now_us: u64) {
        self.now_us = self.now_us.max(now_us);
    }

    /// Current virtual time in microseconds.
    pub fn now_us(&self) -> u64 {
        self.now_us
    }

    /// Enqueue a request for admission. Its deadline (if any) starts
    /// counting from the current virtual time.
    pub fn submit(&mut self, req: Request) {
        let now = self.now_us;
        self.submit_at(req, now);
    }

    /// Enqueue with an explicit submission timestamp — the replay driver
    /// passes the trace arrival time, so a request's deadline counts from
    /// when it *arrived*, not from the end of whatever long tick was in
    /// flight when the driver ingested it (keeping deadline accounting
    /// consistent with TTFT, which is also measured from arrival).
    pub fn submit_at(&mut self, req: Request, submitted_us: u64) {
        self.event(SchedEvent::Submitted { id: req.id });
        self.queue.push_back(Queued { req, submitted_us });
    }

    /// Requests not yet in a terminal state (queued + live + offloaded).
    pub fn pending(&self) -> usize {
        self.queue.len() + self.live.len() + self.warm.len()
    }

    /// Estimated steady-state cache bytes for a prompt plus its generation
    /// budget: FP16 high-precision windows plus the quantized middle at the
    /// method's bit-widths (packed codes + per-group parameters). For
    /// unquantized methods, or sequences that fit inside the windows, this
    /// is the FP16 upper bound. A method that compresses harder therefore
    /// admits more concurrent sequences out of the same budget — the
    /// serving-side payoff the overload harness measures.
    fn estimate_bytes(&self, req: &Request) -> usize {
        let d = &self.engine.manifest.model;
        let cfg = &self.engine.cfg;
        let n = req.prompt.len() + req.max_new_tokens;
        let window = cfg.w_sink + cfg.w_recent;
        let (n_fp, n_q) = if cfg.is_quantized() && n > window {
            (window, n - window)
        } else {
            (n, 0)
        };
        // Per (layer, KV head): K and V rows at 2 bytes/element in the
        // windows; packed codes plus ~8 bytes of f32 params per 32-element
        // group for each of K and V in the quantized middle.
        let fp = 4 * n_fp * d.d_h;
        let codes = n_q * d.d_h * (cfg.key_bits as usize + cfg.val_bits as usize) / 8;
        let params = n_q * (d.d_h / 32).max(1) * 16;
        let full = (fp + codes + params) * d.n_kv_heads * d.n_layers;
        // When sharing is on and the request's whole prefix image set is
        // already resident, those quantized bytes will be borrowed, not
        // owned — admission charges only the incremental bytes, which is
        // where prefix sharing buys concurrency.
        full.saturating_sub(self.probed_shared_bytes(req))
    }

    /// Bytes a prospective admission would borrow from the prefix store
    /// instead of owning: the request's full `(layer, head)` image set if
    /// (and only if) every image is resident. 0 when sharing is off, no
    /// prefix is declared, the prompt does not encode, or any image is
    /// missing (partial sets quantize privately and publish).
    fn probed_shared_bytes(&self, req: &Request) -> usize {
        if !self.prefix_share || req.prefix_len == 0 {
            return 0;
        }
        let Ok(tokens) = self.engine.manifest.encode(&req.prompt) else {
            return 0;
        };
        if req.prefix_len > tokens.len() {
            return 0;
        }
        let base = prefix_base_hash(&self.engine.cfg, &tokens[..req.prefix_len]);
        let d = &self.engine.manifest.model;
        self.prefix_store.probe_set(base, d.n_layers, d.n_kv_heads).unwrap_or(0)
    }

    /// Bytes `req` would borrow from this scheduler's prefix store if it
    /// were admitted here right now (0 when sharing is off, no prefix is
    /// declared, or any image of the set is missing). Read-only; exposed for
    /// the fleet router's affinity scoring (`coordinator::fleet`).
    pub fn probe_prefix_bytes(&self, req: &Request) -> usize {
        self.probed_shared_bytes(req)
    }

    /// Whether this scheduler holds the offloaded (warm) bookkeeping for
    /// request `id`. The snapshot frames themselves live in
    /// [`Scheduler::tier`]; a fleet affinity router treats either as
    /// residency.
    pub fn holds_warm(&self, id: u64) -> bool {
        self.warm.iter().any(|w| w.req.id == id)
    }

    /// Detach the offloaded request `id`'s scheduler-side bookkeeping for
    /// migration to another replica. The snapshot frames stay in this
    /// scheduler's warm tier — the fleet moves them separately as a byte
    /// copy. Refuses (None, state untouched) when `id` is not offloaded
    /// here, or when it snapshotted *by reference* into this replica's
    /// prefix store: by-ref frames carry image hashes whose pins are local
    /// to this replica, so they cannot be resolved anywhere else.
    pub fn export_warm(&mut self, id: u64) -> Option<WarmExport> {
        if self.prefix_refs.contains_key(&id) {
            return None;
        }
        let i = self.warm.iter().position(|w| w.req.id == id)?;
        let w = self.warm.remove(i);
        self.bypass_used.remove(&id);
        Some(WarmExport {
            req: w.req,
            submitted_us: w.submitted_us,
            generated: w.generated,
            next_token: w.next_token,
            ttft_us: w.ttft_us,
        })
    }

    /// Adopt an offloaded request exported from another replica
    /// ([`Scheduler::export_warm`]). The caller must have moved the
    /// request's snapshot frames into this scheduler's warm tier first;
    /// without them, readmission degrades to the offload-lost re-prefill
    /// path (correct, but the migration bought nothing).
    pub fn import_warm(&mut self, e: WarmExport) {
        self.warm.push(Warm {
            req: e.req,
            submitted_us: e.submitted_us,
            generated: e.generated,
            next_token: e.next_token,
            ttft_us: e.ttft_us,
        });
    }

    /// Release the prefix-store pins a retiring request holds (no-op for
    /// requests that never borrowed — or no longer borrow — shared images).
    fn release_prefix(&mut self, id: u64) {
        if let Some(h) = self.prefix_refs.remove(&id) {
            self.prefix_store.release_set(h.base, h.n_layers, h.n_heads);
        }
    }

    /// Fail every queued, live, or offloaded request whose absolute deadline
    /// has passed. Live casualties release their cache reservation and warm
    /// casualties their tier residency, so an expired straggler's budget
    /// immediately becomes admissible headroom.
    fn expire_deadlines(&mut self) {
        let now = self.now_us;
        let mut expired: Vec<(Request, bool)> = Vec::new();
        let mut i = 0;
        while i < self.queue.len() {
            if self.queue[i].deadline_abs().map_or(false, |d| d <= now) {
                let q = self.queue.remove(i).unwrap();
                expired.push((q.req, true));
            } else {
                i += 1;
            }
        }
        let mut j = 0;
        while j < self.live.len() {
            if self.live[j].deadline_abs().map_or(false, |d| d <= now) {
                let l = self.live.remove(j);
                self.pool.release(l.req.id);
                expired.push((l.req, false));
            } else {
                j += 1;
            }
        }
        let mut k = 0;
        while k < self.warm.len() {
            if self.warm[k].deadline_abs().map_or(false, |d| d <= now) {
                let w = self.warm.remove(k);
                self.tier.remove(w.req.id);
                expired.push((w.req, false));
            } else {
                k += 1;
            }
        }
        for (req, queued) in expired {
            self.bypass_used.remove(&req.id);
            self.release_prefix(req.id);
            self.metrics.expired += 1;
            self.event(SchedEvent::Expired { id: req.id, queued });
            self.request_span(&req, 0, "expired");
            self.done.push(Completion::failed(&req, "deadline exceeded"));
        }
    }

    fn candidate_req(&self, c: Candidate) -> &Request {
        match c {
            Candidate::Queued(i) => &self.queue[i].req,
            Candidate::Warm(i) => &self.warm[i].req,
        }
    }

    /// SLO urgency key: (priority class, absolute deadline, first-submission
    /// time, id) — lower is more urgent.
    fn candidate_key(&self, c: Candidate) -> (Priority, u64, u64, u64) {
        match c {
            Candidate::Queued(i) => {
                let q = &self.queue[i];
                (q.req.priority, q.deadline_abs().unwrap_or(u64::MAX), q.submitted_us, q.req.id)
            }
            Candidate::Warm(i) => {
                let w = &self.warm[i];
                (w.req.priority, w.deadline_abs().unwrap_or(u64::MAX), w.submitted_us, w.req.id)
            }
        }
    }

    /// The next admission candidate, or None when both the queue and the
    /// warm list are empty. FIFO: the oldest (lowest id) of the queue head
    /// and the oldest warm entry — offloaded work predates the arrivals that
    /// displaced it, so it readmits first. SLO: most urgent across both
    /// pools by (priority class, absolute deadline, first-submission time,
    /// id).
    fn next_candidate(&self) -> Option<Candidate> {
        match self.policy {
            Policy::Fifo => {
                let q = (!self.queue.is_empty()).then_some(Candidate::Queued(0));
                let w = self
                    .warm
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, w)| w.req.id)
                    .map(|(i, _)| Candidate::Warm(i));
                match (q, w) {
                    (Some(Candidate::Queued(qi)), Some(Candidate::Warm(wi))) => {
                        if self.warm[wi].req.id < self.queue[qi].req.id {
                            Some(Candidate::Warm(wi))
                        } else {
                            Some(Candidate::Queued(qi))
                        }
                    }
                    (q, w) => q.or(w),
                }
            }
            Policy::Slo => (0..self.queue.len())
                .map(Candidate::Queued)
                .chain((0..self.warm.len()).map(Candidate::Warm))
                .min_by_key(|&c| self.candidate_key(c)),
        }
    }

    /// Release every cache-pool reservation without a live owner (left
    /// behind by a crashed prefill, or injected by tests), so admission can
    /// never live-lock on a stale id. Returns how many were dropped.
    fn release_stale_reservations(&mut self) -> usize {
        let stale: Vec<u64> = self
            .pool
            .ids()
            .filter(|id| !self.live.iter().any(|l| l.req.id == *id))
            .collect();
        for id in &stale {
            self.pool.release(*id);
        }
        self.metrics.stale_reservations += stale.len() as u64;
        stale.len()
    }

    /// Bytes the policy could free for `candidate` by preempting *every*
    /// eligible victim (their current reservations). Preemption is only
    /// worth its cost when `free + preemptible >= estimate` — otherwise the
    /// candidate would still park afterwards and the victims' progress
    /// (or snapshots) would have been destroyed for nothing.
    fn preemptible_bytes(&self, candidate: &Request) -> usize {
        let eligible = |l: &Live| match self.policy {
            Policy::Fifo => l.req.id > candidate.id,
            Policy::Slo => l.req.priority > candidate.priority,
        };
        self.live
            .iter()
            .filter(|l| eligible(l))
            .filter_map(|l| self.pool.reserved(l.req.id))
            .sum()
    }

    /// Pick a preemption victim for `candidate` under the active policy, or
    /// None when nothing is eligible. FIFO: the youngest live sequence, and
    /// only if strictly younger than the candidate. SLO: the least-important
    /// live sequence of a *strictly lower* priority class, youngest first.
    fn pick_victim(&self, candidate: &Request) -> Option<usize> {
        match self.policy {
            Policy::Fifo => self
                .live
                .iter()
                .enumerate()
                .max_by_key(|(_, l)| l.req.id)
                .filter(|(_, l)| l.req.id > candidate.id)
                .map(|(i, _)| i),
            Policy::Slo => self
                .live
                .iter()
                .enumerate()
                .filter(|(_, l)| l.req.priority > candidate.priority)
                .max_by_key(|(_, l)| (l.req.priority, l.req.id))
                .map(|(i, _)| i),
        }
    }

    /// Evict the live sequence at `vidx` under the active preemption mode:
    /// offload snapshots it into the warm tier (falling back to recompute if
    /// the tier refuses); recompute discards its cache and re-queues it.
    ///
    /// Offload serializes the victim as per-layer frames *on the engine's
    /// worker pool* (serialization is read-only over the victim's caches),
    /// so the driver no longer encodes the whole image inside the admission
    /// loop. The fp-window frames are marked droppable when the victim has
    /// no decoded appends (their rows are then recomputable from a prefill
    /// pass), letting the tier hold a partial residency under pressure —
    /// and letting a tight `--warm-budget` store just the quantized cores
    /// instead of refusing. `offload_bytes` accounts only what the tier
    /// actually stored, so warm accounting matches partial residencies.
    fn preempt_victim(&mut self, vidx: usize) {
        let l = self.live.swap_remove(vidx);
        self.pool.release(l.req.id);
        self.metrics.preemptions += 1;
        if self.preemption == Preemption::Offload && self.tier.may_accept(l.req.priority.level()) {
            // A sequence borrowing shared prefix images snapshots *by
            // reference*: its core frames carry the images' content hashes
            // instead of their bytes (the pins stay held across the warm
            // residency, so restore always resolves). Private sequences use
            // the parallel inline serializer.
            let t_snap = obs::start();
            let frames = match self.prefix_refs.get(&l.req.id) {
                Some(h) => snapshot_sequence_frames_by_ref(&l.seq, h.base),
                None => snapshot_sequence_frames_on(&l.seq, self.engine.pool()),
            };
            let windows_droppable = l.seq.len() == l.seq.n_prefill;
            let win_kind = if windows_droppable {
                FrameKind::Droppable
            } else {
                FrameKind::Required
            };
            let mut parts: Vec<(&[u8], FrameKind)> =
                Vec::with_capacity(1 + 2 * frames.layers.len());
            parts.push((frames.meta.as_slice(), FrameKind::Required));
            for lf in &frames.layers {
                parts.push((lf.core.as_slice(), FrameKind::Required));
                parts.push((lf.windows.as_slice(), win_kind));
            }
            if let Some(receipt) =
                self.tier.insert_frames(l.req.id, l.req.priority.level(), &parts)
            {
                self.metrics.offloads += 1;
                self.metrics.offload_bytes += receipt.stored_bytes as u64;
                self.metrics.window_frames_dropped += receipt.dropped_frames as u64;
                self.event(SchedEvent::Offloaded { id: l.req.id, bytes: receipt.stored_bytes });
                obs::span(
                    obs::SpanKind::Snapshot,
                    l.req.id,
                    t_snap,
                    receipt.stored_bytes as u64,
                    0,
                );
                self.warm.push(Warm {
                    req: l.req,
                    submitted_us: l.submitted_us,
                    generated: l.generated,
                    next_token: l.next_token,
                    ttft_us: l.ttft_us,
                });
                return;
            }
            // The tier could not hold even the required frames (over its
            // budget, or only more-important residents in the way):
            // recompute-style fallback.
        }
        // Recompute drops the cache, shared borrows included.
        self.release_prefix(l.req.id);
        self.event(SchedEvent::Preempted { id: l.req.id });
        self.queue.push_back(Queued { req: l.req, submitted_us: l.submitted_us });
    }

    /// Pull a candidate out of its pending pool, releasing any warm-tier
    /// residency and its bypass-count entry. Used when the candidate moves
    /// to live or to a terminal state.
    fn remove_candidate(&mut self, c: Candidate) -> Request {
        let req = match c {
            Candidate::Queued(i) => self.queue.remove(i).unwrap().req,
            Candidate::Warm(i) => {
                let w = self.warm.remove(i);
                self.tier.remove(w.req.id);
                // The warm residency dies with its snapshot, so its
                // prefix pins go too.
                self.release_prefix(w.req.id);
                w.req
            }
        };
        self.bypass_used.remove(&req.id);
        req
    }

    /// Reject `c` terminally with `reason`.
    fn reject_candidate(&mut self, c: Candidate, reason: &str) {
        let req = self.remove_candidate(c);
        self.metrics.rejected += 1;
        self.event(SchedEvent::Rejected { id: req.id });
        self.request_span(&req, 0, "rejected");
        self.done.push(Completion::failed(&req, reason));
    }

    /// One admission attempt for `c`. The caller has picked `c` as the most
    /// urgent candidate; this resolves it against the cache pool.
    fn try_admit(&mut self, c: Candidate) -> Result<AdmitStep> {
        let (id, est) = {
            let r = self.candidate_req(c);
            (r.id, self.estimate_bytes(r))
        };
        match self.pool.admit(id, est) {
            Admission::Admitted => {
                match c {
                    Candidate::Queued(i) => {
                        let q = self.queue.remove(i).unwrap();
                        self.bypass_used.remove(&q.req.id);
                        self.prefill_into_live(q);
                    }
                    Candidate::Warm(i) => {
                        let w = self.warm.remove(i);
                        self.bypass_used.remove(&w.req.id);
                        self.restore_into_live(w);
                    }
                }
                Ok(AdmitStep::Progress)
            }
            Admission::AlreadyReserved => {
                if self.live.iter().any(|l| l.req.id == id) {
                    // A caller submitted a duplicate of a live sequence's id.
                    // Releasing here would destroy the live reservation, so
                    // reject the duplicate instead.
                    self.reject_candidate(c, "duplicate of a live request id");
                } else {
                    // No live owner: the reservation is stale. Drop it and
                    // retry the candidate.
                    self.pool.release(id);
                    self.metrics.stale_reservations += 1;
                }
                Ok(AdmitStep::Progress)
            }
            Admission::TooLarge => {
                self.reject_candidate(c, "request exceeds the cache budget outright");
                Ok(AdmitStep::Progress)
            }
            Admission::Pressure => {
                if self.release_stale_reservations() > 0 {
                    return Ok(AdmitStep::Progress);
                }
                // Preempt only when evicting eligible victims can actually
                // fit the candidate; a preemption that still leaves it
                // parked would destroy the victims' work for nothing (and
                // would evict bypass guests pointlessly the tick after they
                // were admitted).
                let would_fit =
                    self.pool.free_bytes() + self.preemptible_bytes(self.candidate_req(c)) >= est;
                let victim = would_fit.then(|| self.pick_victim(self.candidate_req(c))).flatten();
                if let Some(vidx) = victim {
                    self.preempt_victim(vidx);
                    return Ok(AdmitStep::Progress);
                }
                if self.live.is_empty() {
                    // Nothing to wait for and nothing to evict: the estimate
                    // cannot be satisfied — reject instead of spinning.
                    self.reject_candidate(c, "cache pressure with nothing to preempt");
                    return Ok(AdmitStep::Progress);
                }
                Ok(AdmitStep::Parked)
            }
        }
    }

    /// Run the admitted request's prefill and move it into the live batch
    /// (or fail it, giving its reservation back).
    fn prefill_into_live(&mut self, q: Queued) {
        let Queued { req, submitted_us } = q;
        // A bad prompt (or a failing prefill) must fail the request, not
        // the scheduler — and must give its reservation back.
        let prompt = match self.engine.manifest.encode(&req.prompt) {
            Ok(p) => p,
            Err(e) => {
                self.pool.release(req.id);
                self.metrics.rejected += 1;
                self.event(SchedEvent::Rejected { id: req.id });
                self.request_span(&req, 0, "rejected");
                self.done.push(Completion::failed(&req, e.to_string()));
                return;
            }
        };
        // Queue-residency span: arrival to the start of this prefill.
        if obs::enabled() {
            let arr = obs::epoch_us_of(req.arrived);
            obs::mark(
                obs::SpanKind::Queued,
                req.id,
                arr,
                obs::now_us().max(arr),
                req.priority.level() as u64,
                0,
                None,
            );
        }
        let t0 = Instant::now();
        let t_prefill = obs::start();
        let store = self.prefix_share.then_some(&mut self.prefix_store);
        let (seq, outcome) = match self.engine.prefill_shared(&prompt, req.prefix_len, store) {
            Ok(r) => r,
            Err(e) => {
                self.pool.release(req.id);
                self.metrics.rejected += 1;
                self.event(SchedEvent::Rejected { id: req.id });
                self.request_span(&req, 0, "rejected");
                self.done.push(Completion::failed(&req, e.to_string()));
                return;
            }
        };
        let d = &self.engine.manifest.model;
        let (n_layers, n_heads) = (d.n_layers, d.n_kv_heads);
        let mut shared_bytes = 0u64;
        match outcome {
            PrefixOutcome::Private => {}
            PrefixOutcome::Published { base, .. } => {
                self.prefix_refs.insert(req.id, PrefixHandle { base, n_layers, n_heads });
            }
            PrefixOutcome::Hit { base, bytes } => {
                self.prefix_refs.insert(req.id, PrefixHandle { base, n_layers, n_heads });
                self.metrics.prefix_hits += 1;
                self.metrics.prefix_bytes_shared += bytes as u64;
                self.event(SchedEvent::PrefixHit { id: req.id, bytes });
                shared_bytes = bytes as u64;
            }
        }
        obs::span(obs::SpanKind::Prefill, req.id, t_prefill, prompt.len() as u64, shared_bytes);
        self.metrics.prefill_tokens += prompt.len() as u64;
        self.event(SchedEvent::Admitted { id: req.id, prefill_tokens: prompt.len() });
        let next = self.sample(&seq.last_logits, req.temperature);
        self.live.push(Live {
            ttft_us: Some(t0.elapsed().as_micros() as u64),
            req,
            submitted_us,
            seq,
            generated: Vec::new(),
            next_token: next,
        });
    }

    /// Readmit an offloaded request: deserialize its per-layer snapshot
    /// frames from the warm tier back into a live sequence (no re-prefill,
    /// decode progress preserved). A *partial* residency — window frames
    /// evicted under pressure while the request waited — restores the
    /// quantized cores bit-exactly and recomputes only the fp windows
    /// (`Engine::rebuild_windows`); decoding then continues bit-identically
    /// to a never-offloaded run. A fully missing snapshot — the resident
    /// evicted whole — falls back to a recompute-style re-prefill with the
    /// generated tokens discarded. The caller has already reserved cache
    /// budget under `w.req.id`.
    fn restore_into_live(&mut self, w: Warm) {
        let t_restore = obs::start();
        let Some(taken) = self.tier.take_frames(w.req.id) else {
            // Dropped from the warm tier (terminal for the snapshot):
            // recompute-style readmission under the reservation we hold.
            // Any prefix pins die with the snapshot *before* the re-prefill,
            // which may acquire fresh ones under the same id.
            self.release_prefix(w.req.id);
            self.metrics.offload_lost += 1;
            self.event(SchedEvent::OffloadLost { id: w.req.id });
            self.prefill_into_live(Queued { req: w.req, submitted_us: w.submitted_us });
            return;
        };
        // Frame layout written by `preempt_victim`:
        // [meta, core_0, windows_0, core_1, windows_1, ...]. Required
        // frames only vanish via whole-resident eviction (handled above),
        // so a hole in them is corruption, not capacity.
        let restored = (|| -> Result<(Sequence, Vec<usize>, usize)> {
            let n = taken.frames.len();
            if n == 0 || (n - 1) % 2 != 0 {
                return Err(anyhow!("malformed snapshot frame set ({n} frames)"));
            }
            let meta = taken.frames[0]
                .as_deref()
                .ok_or_else(|| anyhow!("sequence meta frame missing"))?;
            let mut bytes = meta.len();
            let mut layers: Vec<(&[u8], Option<&[u8]>)> = Vec::with_capacity((n - 1) / 2);
            for pair in taken.frames[1..].chunks(2) {
                let core = pair[0]
                    .as_deref()
                    .ok_or_else(|| anyhow!("layer core frame missing"))?;
                let win = pair[1].as_deref();
                bytes += core.len() + win.map_or(0, |p| p.len());
                layers.push((core, win));
            }
            // By-ref core frames (shared-prefix sequences) resolve their
            // image hashes against the store; the pins held across the warm
            // residency guarantee the images are still there.
            let store = &self.prefix_store;
            let (seq, missing) =
                restore_sequence_frames_with(meta, &layers, &|e| store.image(e))?;
            Ok((seq, missing, bytes))
        })();
        match restored {
            Ok((mut seq, missing, bytes)) => {
                if !missing.is_empty() {
                    if let Err(e) = self.engine.rebuild_windows(&mut seq, &missing) {
                        self.pool.release(w.req.id);
                        self.release_prefix(w.req.id);
                        self.metrics.rejected += 1;
                        self.event(SchedEvent::Rejected { id: w.req.id });
                        self.request_span(&w.req, 0, "rejected");
                        self.done.push(Completion::failed(
                            &w.req,
                            format!("window rebuild failed: {e}"),
                        ));
                        return;
                    }
                    self.metrics.window_rebuilds += missing.len() as u64;
                    // The rebuild ran one real prefill pass over the
                    // sequence's tokens; account it as prefill work so the
                    // replay cost model prices a degraded restore honestly
                    // (core restore + model pass) instead of treating it as
                    // a free full restore.
                    self.metrics.prefill_tokens += seq.n_prefill as u64;
                }
                self.metrics.restores += 1;
                self.metrics.restore_bytes += bytes as u64;
                self.event(SchedEvent::Restored { id: w.req.id, bytes });
                obs::span(obs::SpanKind::Restore, w.req.id, t_restore, bytes as u64, 0);
                self.live.push(Live {
                    req: w.req,
                    submitted_us: w.submitted_us,
                    seq,
                    generated: w.generated,
                    next_token: w.next_token,
                    ttft_us: w.ttft_us,
                });
            }
            Err(e) => {
                // A snapshot that fails to deserialize is a bug, not a
                // capacity condition; fail the request, keep serving.
                self.pool.release(w.req.id);
                self.release_prefix(w.req.id);
                self.metrics.rejected += 1;
                self.event(SchedEvent::Rejected { id: w.req.id });
                self.request_span(&w.req, 0, "rejected");
                self.done
                    .push(Completion::failed(&w.req, format!("snapshot restore failed: {e}")));
            }
        }
    }

    /// SLO small-request bypass: when the most urgent candidate parks under
    /// pressure, admit one strictly-smaller request of a *strictly lower*
    /// priority class that fits the free budget as-is (no preemption), at
    /// most [`Scheduler::set_bypass_limit`] times per head — so spare budget
    /// is used without letting a stream of small requests starve the head.
    /// Returns whether a bypass admission happened.
    fn try_bypass(&mut self, head_id: u64, head_est: usize, head_pri: Priority) -> bool {
        if self.policy != Policy::Slo || self.bypass_limit == 0 {
            return false;
        }
        let used = self.bypass_used.get(&head_id).copied().unwrap_or(0);
        if used >= self.bypass_limit {
            return false;
        }
        let free = self.pool.free_bytes();
        let mut best: Option<(usize, usize, u64)> = None; // (queue idx, est, id)
        for i in 0..self.queue.len() {
            let q = &self.queue[i];
            if q.req.id == head_id || q.req.priority <= head_pri {
                continue;
            }
            let est = self.estimate_bytes(&q.req);
            if est >= head_est || est > free {
                continue;
            }
            if best.map_or(true, |(_, be, bi)| (est, q.req.id) < (be, bi)) {
                best = Some((i, est, q.req.id));
            }
        }
        let Some((i, est, id)) = best else { return false };
        match self.pool.admit(id, est) {
            Admission::Admitted => {
                let q = self.queue.remove(i).unwrap();
                self.metrics.bypass_admissions += 1;
                self.bypass_used.insert(head_id, used + 1);
                self.prefill_into_live(q);
                true
            }
            // est <= free makes anything else unreachable; refuse rather
            // than loop if accounting ever drifts.
            _ => false,
        }
    }

    /// Admit greedily: keep admitting the policy's next candidate until the
    /// pools drain or a candidate parks under pressure (after which the SLO
    /// policy may still slip a bounded number of smaller lower-class
    /// requests past the parked head). Every iteration either retires a
    /// candidate (admitted / restored / rejected) or strictly shrinks pool
    /// state (stale release, preemption), so this terminates.
    fn admit(&mut self) -> Result<()> {
        loop {
            let Some(c) = self.next_candidate() else { return Ok(()) };
            let (head_id, head_est, head_pri) = {
                let r = self.candidate_req(c);
                (r.id, self.estimate_bytes(r), r.priority)
            };
            match self.try_admit(c)? {
                AdmitStep::Progress => continue,
                AdmitStep::Parked => {
                    if self.try_bypass(head_id, head_est, head_pri) {
                        continue;
                    }
                    return Ok(());
                }
            }
        }
    }

    /// One scheduler tick: expire deadlines, admit as many prefills as the
    /// cache budget allows, then one decode step over the live batch.
    /// Returns false when idle.
    pub fn tick(&mut self) -> Result<bool> {
        // Whole-tick span, idle ticks included: an idle tick (`worked == 0`)
        // does nothing but run the loop machinery, so its duration is a pure
        // sample of the driver's per-tick overhead — what the replay cost
        // model's `tick_overhead_us` coefficient prices, and what
        // ci/calibrate_cost_model.py --from-trace fits from these spans.
        let t_tick = obs::start();
        let live_at_entry = self.live.len() as u64;
        // Drain the tracing rings into the flight recorder once per tick
        // (the tracing plane's drain cadence). `try_lock`: an admin `trace`
        // reply holding the recorder must never stall the driver.
        if obs::enabled() {
            if let Ok(mut rec) = self.obs.try_lock() {
                rec.drain();
            }
        }
        if self.queue.is_empty() && self.live.is_empty() && self.warm.is_empty() {
            self.driver_tick_span(t_tick, live_at_entry, 0);
            return Ok(false);
        }
        self.expire_deadlines();
        self.admit()?;

        // --- decode step ---
        if !self.live.is_empty() {
            let ids: Vec<u64> = self.live.iter().map(|l| l.req.id).collect();
            let batch = batcher::plan_decode_batch(&ids, &self.engine.manifest.decode_batches);
            let mut idxs: Vec<usize> = batch
                .iter()
                .map(|id| self.live.iter().position(|l| l.req.id == *id).unwrap())
                .collect();
            idxs.sort_unstable();
            let tokens: Vec<i32> = idxs.iter().map(|&i| self.live[i].next_token).collect();
            // split_at_mut dance: collect &mut Sequence for the batch
            let mut seqs: Vec<&mut Sequence> = Vec::with_capacity(idxs.len());
            let mut rest: &mut [Live] = &mut self.live;
            let mut consumed = 0usize;
            for &i in &idxs {
                let (_, tail) = rest.split_at_mut(i - consumed);
                let (item, tail2) = tail.split_at_mut(1);
                seqs.push(&mut item[0].seq);
                rest = tail2;
                consumed = i + 1;
            }
            let t_step = obs::start();
            self.engine.decode_step(&mut seqs, &tokens)?;
            drop(seqs);
            let d = &self.engine.manifest.model;
            self.metrics.decode_steps += 1;
            self.metrics.batched_seqs += idxs.len() as u64;
            self.metrics.attn_jobs += (idxs.len() * d.n_kv_heads * d.n_layers) as u64;
            obs::span(
                obs::SpanKind::DecodeStep,
                self.metrics.decode_steps,
                t_step,
                idxs.len() as u64,
                0,
            );

            // post-step: record generated tokens, sample next, finish. The
            // stop token terminates the sequence but is *excluded* from the
            // completion text and count.
            let mut finished = Vec::new();
            for &i in &idxs {
                let l = &mut self.live[i];
                let is_stop = l.next_token == self.stop_token;
                if !is_stop {
                    l.generated.push(l.next_token);
                    if self.progress_on {
                        self.progress.push((l.req.id, l.next_token));
                    }
                }
                let resized = self.pool.resize(l.req.id, l.seq.cache_bytes());
                debug_assert!(resized, "live sequence {} lost its pool reservation", l.req.id);
                let done = is_stop || l.generated.len() >= l.req.max_new_tokens;
                if done {
                    finished.push(i);
                } else {
                    l.next_token = Self::sample_with(
                        &mut self.rng,
                        &l.seq.last_logits,
                        l.req.temperature,
                    );
                }
            }
            // Emit completions in live (admission) order, then remove in
            // descending index order so swap_remove cannot invalidate a
            // pending index.
            finished.sort_unstable();
            for &i in &finished {
                let c = {
                    let l = &self.live[i];
                    Completion {
                        id: l.req.id,
                        text: self.engine.manifest.decode_text(&l.generated),
                        n_prompt: l.req.prompt.len(),
                        n_generated: l.generated.len(),
                        ttft_us: l.ttft_us.unwrap_or(0),
                        total_us: l.req.arrived.elapsed().as_micros() as u64,
                        error: None,
                    }
                };
                self.event(SchedEvent::Finished { id: c.id, n_generated: c.n_generated });
                self.request_span(&self.live[i].req, c.n_generated, "ok");
                self.done.push(c);
            }
            for &i in finished.iter().rev() {
                let l = self.live.swap_remove(i);
                self.pool.release(l.req.id);
                self.release_prefix(l.req.id);
            }
        }
        self.driver_tick_span(t_tick, live_at_entry, 1);
        Ok(true)
    }

    /// Close the whole-tick span opened at the top of [`Scheduler::tick`],
    /// tagged with this scheduler's replica when it is part of a fleet.
    fn driver_tick_span(&self, t0: u64, live_at_entry: u64, worked: u64) {
        match self.replica_tag {
            Some(tag) => obs::span_tag(
                obs::SpanKind::DriverTick,
                live_at_entry,
                t0,
                live_at_entry,
                worked,
                tag,
            ),
            None => {
                obs::span(obs::SpanKind::DriverTick, live_at_entry, t0, live_at_entry, worked)
            }
        }
    }

    fn sample(&mut self, logits: &[f32], temperature: Option<f32>) -> i32 {
        Self::sample_with(&mut self.rng, logits, temperature)
    }

    fn sample_with(rng: &mut Rng, logits: &[f32], temperature: Option<f32>) -> i32 {
        match temperature {
            None => Engine::argmax(logits),
            Some(t) => {
                let t = t.max(1e-3);
                // Non-finite logits carry zero probability mass (a NaN here
                // must not poison the whole distribution).
                let m = logits
                    .iter()
                    .filter(|v| v.is_finite())
                    .fold(f32::NEG_INFINITY, |a, &b| a.max(b));
                if !m.is_finite() {
                    return Engine::argmax(logits);
                }
                let ps: Vec<f32> = logits
                    .iter()
                    .map(|&v| if v.is_finite() { ((v - m) / t).exp() } else { 0.0 })
                    .collect();
                let sum: f32 = ps.iter().sum();
                let mut u = rng.next_f32() * sum;
                for (i, &p) in ps.iter().enumerate() {
                    u -= p;
                    if u <= 0.0 {
                        return i as i32;
                    }
                }
                (ps.len() - 1) as i32
            }
        }
    }

    /// Drain the queue and all live sequences to completion.
    pub fn run_to_completion(&mut self) -> Result<Vec<Completion>> {
        while self.tick()? {}
        Ok(std::mem::take(&mut self.done))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn temperature_sampling_survives_nan_logits() {
        let mut rng = Rng::new(1);
        let logits = [1.0f32, f32::NAN, 0.5, f32::NEG_INFINITY];
        for _ in 0..50 {
            let t = Scheduler::sample_with(&mut rng, &logits, Some(0.7));
            assert!(t == 0 || t == 2, "sampled NaN/-inf token {t}");
        }
        // All-NaN falls back to argmax's index-0 default.
        assert_eq!(
            Scheduler::sample_with(&mut rng, &[f32::NAN, f32::NAN], Some(1.0)),
            0
        );
    }

    #[test]
    fn policy_parses_cli_names() {
        assert_eq!(Policy::parse("fifo"), Some(Policy::Fifo));
        assert_eq!(Policy::parse("slo"), Some(Policy::Slo));
        assert_eq!(Policy::parse("edf"), None);
        assert_eq!(Policy::default(), Policy::Fifo);
    }

    #[test]
    fn preemption_parses_cli_names() {
        assert_eq!(Preemption::parse("recompute"), Some(Preemption::Recompute));
        assert_eq!(Preemption::parse("offload"), Some(Preemption::Offload));
        assert_eq!(Preemption::parse("swap"), None);
        assert_eq!(Preemption::default(), Preemption::Recompute);
        assert_eq!(Preemption::Offload.name(), "offload");
    }
}
