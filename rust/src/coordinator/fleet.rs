//! Data-parallel engine replicas behind a cache-affinity router.
//!
//! One [`Scheduler`] — one engine, one worker pool, one cache budget — is
//! the hard ceiling on aggregate throughput. A [`Fleet`] runs N full
//! scheduler replicas side by side, each owning its *own* `CachePool`
//! budget, warm tier, and prefix store, and places every incoming request
//! on exactly one replica via a pluggable [`RouterPolicy`]:
//!
//! * [`RoundRobin`] — strict rotation; the load-spreading baseline.
//! * [`LeastLoaded`] — fewest pending requests wins (ties to the lowest
//!   index), so bursts spread by occupancy instead of arrival order.
//! * [`Affinity`] — placement locality as a latency optimization. A
//!   replica already holding the request's offload snapshot (warm-tier
//!   residency) wins outright; otherwise the replica whose prefix store
//!   would serve the largest shared-prefix image set
//!   ([`Scheduler::probe_prefix_bytes`], the rolling prefix hash from the
//!   content-addressed store) wins; otherwise fall back to least-loaded.
//!   Landing a multi-turn or readmitted request where its bytes already
//!   live skips a full re-prefill — routing *is* the optimization.
//!
//! ## Migration is a byte copy
//!
//! When affinity and load conflict — the snapshot-holding replica is
//! overloaded past [`Affinity::migrate_headroom`] — the router may *move*
//! the offloaded request instead of following it: the snapshot frames are
//! copied verbatim between warm tiers (the PR 4/5 snapshot byte format is
//! purely value-based, so the bytes mean the same thing on any replica
//! with the same `MethodConfig`) and the scheduler-side bookkeeping is
//! re-homed via [`Scheduler::export_warm`] / [`Scheduler::import_warm`].
//! [`Fleet::try_migrate`] asserts byte-identity of the destination
//! residency against the source frames. Two cases refuse to migrate and
//! fall back to following the snapshot: by-reference snapshots (their
//! core frames carry prefix-image hashes pinned in the *source* replica's
//! store) and partial residencies (dropped window frames cannot carry
//! their frame kind across the copy).
//!
//! ## Determinism
//!
//! Routing reads only deterministic replica state (pending counts, tier
//! residency, prefix probes) and policy-local counters — never a clock —
//! so for a fixed trace, policy, and replica count, placement is exact and
//! the fleet replay harness (`workload::replay::replay_fleet`) is
//! byte-identical across worker counts.

use crate::cache::store::FrameKind;
use crate::coordinator::request::{Completion, Request, StepMetrics};
use crate::coordinator::scheduler::Scheduler;
use anyhow::Result;

/// Where the router put a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Placement {
    /// Replica index the request should run on.
    pub replica: usize,
    /// When `Some(src)`, the request's offload snapshot lives on `src` but
    /// load says it should run on [`Placement::replica`]: the fleet should
    /// migrate the snapshot (and fall back to `src` if migration refuses).
    pub migrate_from: Option<usize>,
}

impl Placement {
    /// A plain placement with no migration.
    pub fn on(replica: usize) -> Placement {
        Placement { replica, migrate_from: None }
    }
}

/// A pluggable placement policy. `place` may mutate policy-local state
/// (e.g. the round-robin cursor) but must be a deterministic function of
/// that state and the replicas' observable state — the fleet replay
/// determinism contract depends on it.
pub trait RouterPolicy {
    /// Stable CLI/report name.
    fn name(&self) -> &'static str;
    /// Choose a replica for `req` given the current replica states.
    fn place(&mut self, req: &Request, replicas: &[Scheduler]) -> Placement;
}

/// Index of the least-loaded replica by pending count, ties to the lowest
/// index. The shared fallback of every shipped policy.
fn least_loaded_of(replicas: &[Scheduler]) -> usize {
    replicas
        .iter()
        .enumerate()
        .min_by_key(|(i, s)| (s.pending(), *i))
        .map(|(i, _)| i)
        .expect("a fleet has at least one replica")
}

/// Strict rotation over replica indices.
#[derive(Debug, Default)]
pub struct RoundRobin {
    next: usize,
}

impl RouterPolicy for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn place(&mut self, _req: &Request, replicas: &[Scheduler]) -> Placement {
        let r = self.next % replicas.len();
        self.next = self.next.wrapping_add(1);
        Placement::on(r)
    }
}

/// Fewest pending (queued + live + offloaded) requests wins.
#[derive(Debug, Default)]
pub struct LeastLoaded;

impl RouterPolicy for LeastLoaded {
    fn name(&self) -> &'static str {
        "least-loaded"
    }

    fn place(&mut self, _req: &Request, replicas: &[Scheduler]) -> Placement {
        Placement::on(least_loaded_of(replicas))
    }
}

/// Cache-affinity placement: snapshot residency, then prefix-store
/// residency, then load (see the module docs for the full decision flow).
#[derive(Debug)]
pub struct Affinity {
    /// How many pending requests the snapshot holder may exceed the
    /// least-loaded replica by before the router migrates the snapshot to
    /// the least-loaded replica instead of following it. Affinity is worth
    /// some queueing (a restore is far cheaper than a re-prefill), but not
    /// unbounded head-of-line blocking.
    pub migrate_headroom: usize,
}

impl Default for Affinity {
    fn default() -> Self {
        Affinity { migrate_headroom: 4 }
    }
}

impl RouterPolicy for Affinity {
    fn name(&self) -> &'static str {
        "affinity"
    }

    fn place(&mut self, req: &Request, replicas: &[Scheduler]) -> Placement {
        // 1. Snapshot residency: the replica holding this request's
        //    offloaded frames (or its warm bookkeeping) serves a readmit
        //    with a restore instead of a re-prefill.
        if let Some(h) =
            replicas.iter().position(|s| s.tier.contains(req.id) || s.holds_warm(req.id))
        {
            let least = least_loaded_of(replicas);
            if replicas[h].pending() > replicas[least].pending() + self.migrate_headroom {
                return Placement { replica: least, migrate_from: Some(h) };
            }
            return Placement::on(h);
        }
        // 2. Prefix residency: the replica whose store would lend the most
        //    shared-prefix bytes (first index wins ties).
        let mut best: Option<(usize, usize)> = None; // (bytes, replica)
        for (i, s) in replicas.iter().enumerate() {
            let bytes = s.probe_prefix_bytes(req);
            if bytes > 0 && best.map_or(true, |(b, _)| bytes > b) {
                best = Some((bytes, i));
            }
        }
        if let Some((_, i)) = best {
            return Placement::on(i);
        }
        // 3. No locality signal: spread by load.
        Placement::on(least_loaded_of(replicas))
    }
}

/// Parse a router policy from its CLI name
/// (`round-robin` / `least-loaded` / `affinity`).
pub fn parse_router(name: &str) -> Option<Box<dyn RouterPolicy + Send>> {
    match name {
        "round-robin" => Some(Box::new(RoundRobin::default())),
        "least-loaded" => Some(Box::new(LeastLoaded)),
        "affinity" => Some(Box::new(Affinity::default())),
        _ => None,
    }
}

/// N scheduler replicas behind one router. The fleet owns placement and
/// cross-replica migration; each replica's admission, preemption, and
/// decode stay entirely replica-local.
pub struct Fleet {
    replicas: Vec<Scheduler>,
    router: Box<dyn RouterPolicy + Send>,
    /// Snapshots moved between warm tiers by the router.
    pub migrations: u64,
    /// Bytes those migrations copied.
    pub migrated_bytes: u64,
}

impl Fleet {
    /// A fleet over `replicas` (each with its own engine, pools, and
    /// budgets — build and configure them first) routed by `router`.
    /// Replica indices are fixed at construction; each scheduler's driver
    /// spans are tagged with its replica ([`Scheduler::set_replica`]).
    pub fn new(mut replicas: Vec<Scheduler>, router: Box<dyn RouterPolicy + Send>) -> Fleet {
        assert!(!replicas.is_empty(), "a fleet needs at least one replica");
        for (i, s) in replicas.iter_mut().enumerate() {
            s.set_replica(i);
        }
        // One shared flight recorder: every replica drains the global span
        // lanes into it, so a single trace export sees the whole fleet
        // (replica tags keep the spans apart).
        let obs = replicas[0].obs.clone();
        for s in replicas.iter_mut().skip(1) {
            s.obs = obs.clone();
        }
        Fleet { replicas, router, migrations: 0, migrated_bytes: 0 }
    }

    /// Number of replicas.
    pub fn n(&self) -> usize {
        self.replicas.len()
    }

    /// The active router policy's name.
    pub fn router_name(&self) -> &'static str {
        self.router.name()
    }

    /// Replica `i`, read-only.
    pub fn replica(&self, i: usize) -> &Scheduler {
        &self.replicas[i]
    }

    /// Replica `i`, mutable (tests and the replay driver tick replicas
    /// individually; live serving uses [`Fleet::tick`]).
    pub fn replica_mut(&mut self, i: usize) -> &mut Scheduler {
        &mut self.replicas[i]
    }

    /// All replicas, read-only.
    pub fn replicas(&self) -> &[Scheduler] {
        &self.replicas
    }

    /// Ask the router where it would place `req`, mutating only
    /// policy-local state (the round-robin cursor advances). Exposed for
    /// tests; [`Fleet::submit_at`] is route + migrate + enqueue.
    pub fn route(&mut self, req: &Request) -> Placement {
        self.router.place(req, &self.replicas)
    }

    /// Route `req` and enqueue it on the chosen replica with an explicit
    /// submission timestamp (the replay driver passes the trace arrival
    /// time). When the router asks for a migration that then refuses —
    /// by-ref snapshot, partial residency, destination tier full — the
    /// request follows its snapshot to the holder instead. Returns the
    /// replica index the request landed on.
    pub fn submit_at(&mut self, req: Request, submitted_us: u64) -> usize {
        let p = self.route(&req);
        let dest = match p.migrate_from {
            Some(src) if self.try_migrate(req.id, src, p.replica) => p.replica,
            Some(src) => src,
            None => p.replica,
        };
        self.replicas[dest].submit_at(req, submitted_us);
        dest
    }

    /// Route `req` and enqueue it at the destination replica's current
    /// virtual time (deadlines count from the clock of whichever replica
    /// the request lands on). Returns the replica index.
    pub fn submit(&mut self, req: Request) -> usize {
        let p = self.route(&req);
        let dest = match p.migrate_from {
            Some(src) if self.try_migrate(req.id, src, p.replica) => p.replica,
            Some(src) => src,
            None => p.replica,
        };
        let now = self.replicas[dest].now_us();
        self.replicas[dest].submit_at(req, now);
        dest
    }

    /// Move the offloaded request `id`'s snapshot from replica `src`'s warm
    /// tier to replica `dst`'s as a byte copy, re-homing its scheduler-side
    /// bookkeeping. Asserts the destination residency is byte-identical to
    /// the source frames. Returns false — with all state exactly as it was
    /// — when the snapshot is not fully resident on `src`, snapshots by
    /// reference into `src`'s prefix store, is not offloaded on `src` at
    /// all, or `dst`'s tier refuses the bytes.
    pub fn try_migrate(&mut self, id: u64, src: usize, dst: usize) -> bool {
        if src == dst || src >= self.replicas.len() || dst >= self.replicas.len() {
            return false;
        }
        // A partial residency has lost droppable window frames; the taken
        // bytes no longer carry their frame kinds, so a faithful re-insert
        // on either side would silently promote them to required. Refuse —
        // the holder can still restore locally via its window-rebuild path.
        if !self.replicas[src].tier.contains(id) || self.replicas[src].tier.is_partial(id) {
            return false;
        }
        let Some(entry) = self.replicas[src].export_warm(id) else {
            return false;
        };
        let taken = match self.replicas[src].tier.take_frames(id) {
            Some(t) if t.is_full() => t,
            // contains + !is_partial above make this unreachable; restore
            // the bookkeeping rather than panic if accounting ever drifts.
            _ => {
                self.replicas[src].import_warm(entry);
                return false;
            }
        };
        let frames: Vec<Vec<u8>> =
            taken.frames.into_iter().map(|f| f.unwrap_or_default()).collect();
        let class = entry.req.priority.level();
        let parts: Vec<(&[u8], FrameKind)> =
            frames.iter().map(|f| (f.as_slice(), FrameKind::Required)).collect();
        if self.replicas[dst].tier.insert_frames(id, class, &parts).is_some() {
            // The router's whole claim is that migration is a byte copy:
            // prove it on every migration, not just in tests.
            let image: Vec<u8> = frames.concat();
            let copied = self.replicas[dst]
                .tier
                .peek(id)
                .expect("migrated resident must be readable");
            assert_eq!(copied, image, "cross-replica migration corrupted snapshot bytes");
            self.replicas[dst].import_warm(entry);
            self.migrations += 1;
            self.migrated_bytes += image.len() as u64;
            true
        } else {
            // Destination refused (budget / more-important residents): put
            // the frames and bookkeeping back where they were.
            let restored = self.replicas[src].tier.insert_frames(id, class, &parts).is_some();
            debug_assert!(restored, "source tier refused bytes it just held");
            self.replicas[src].import_warm(entry);
            false
        }
    }

    /// Advance every replica's virtual clock (monotonic per replica).
    pub fn set_now(&mut self, now_us: u64) {
        for s in &mut self.replicas {
            s.set_now(now_us);
        }
    }

    /// One tick of every replica, in index order. Returns how many replicas
    /// did work.
    pub fn tick(&mut self) -> Result<usize> {
        let mut worked = 0;
        for s in &mut self.replicas {
            if s.tick()? {
                worked += 1;
            }
        }
        Ok(worked)
    }

    /// Requests pending across all replicas.
    pub fn pending(&self) -> usize {
        self.replicas.iter().map(|s| s.pending()).sum()
    }

    /// Drain every replica's completed requests.
    pub fn drain_done(&mut self) -> Vec<Completion> {
        let mut out = Vec::new();
        for s in &mut self.replicas {
            out.append(&mut s.done);
        }
        out
    }

    /// Tick every replica until the whole fleet is idle, then return every
    /// completion sorted by request id (cross-replica completion order is
    /// not meaningful; id order is deterministic).
    pub fn run_to_completion(&mut self) -> Result<Vec<Completion>> {
        while self.tick()? > 0 {}
        let mut done = self.drain_done();
        done.sort_by_key(|c| c.id);
        Ok(done)
    }

    /// Sum of every replica's scheduler counters.
    pub fn aggregate_metrics(&self) -> StepMetrics {
        let mut m = StepMetrics::default();
        for s in &self.replicas {
            m.absorb(&s.metrics);
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_router_knows_the_cli_names() {
        for (name, expect) in [
            ("round-robin", "round-robin"),
            ("least-loaded", "least-loaded"),
            ("affinity", "affinity"),
        ] {
            assert_eq!(parse_router(name).unwrap().name(), expect);
        }
        assert!(parse_router("random").is_none());
        assert!(parse_router("roundrobin").is_none());
    }
}
