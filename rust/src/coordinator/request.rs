//! Request lifecycle for the serving coordinator.

use std::time::Instant;

/// A generation request as submitted by a client.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: String,
    pub max_new_tokens: usize,
    /// Greedy when None; otherwise softmax temperature.
    pub temperature: Option<f32>,
    pub arrived: Instant,
}

/// Terminal states.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    pub text: String,
    pub n_prompt: usize,
    pub n_generated: usize,
    /// Time-to-first-token and total latency, in microseconds.
    pub ttft_us: u64,
    pub total_us: u64,
}

/// Scheduler-visible request state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Queued,
    Prefilling,
    Decoding,
    Finished,
    Failed,
}

#[derive(Debug, Clone, Copy, Default)]
pub struct StepMetrics {
    pub prefill_tokens: u64,
    pub decode_steps: u64,
    pub batched_seqs: u64,
    pub preemptions: u64,
}
