//! Request lifecycle for the serving coordinator: the client-visible
//! [`Request`] / [`Completion`] pair, SLO metadata ([`Priority`], deadlines),
//! scheduler counters ([`StepMetrics`]), and the replayable event stream
//! ([`SchedEvent`]) the trace harness uses to reconstruct per-request
//! timelines on a virtual clock.

use std::time::Instant;

/// Scheduling priority class of a request, ordered most-important-first
/// (`Interactive < Standard < Batch` under `Ord`).
///
/// Under [`crate::coordinator::scheduler::Policy::Slo`] a pending request may
/// preempt live work of a *strictly lower* class; classes never preempt
/// within themselves, so priority inversion cannot occur. Under the default
/// FIFO policy the class is carried but ignored.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    /// Latency-sensitive foreground traffic (chat turns, autocompletions).
    Interactive,
    /// The default class for unlabeled traffic.
    Standard,
    /// Throughput-oriented background work (evals, batch summarization).
    Batch,
}

impl Default for Priority {
    fn default() -> Self {
        Priority::Standard
    }
}

impl Priority {
    /// All classes, most important first.
    pub const ALL: [Priority; 3] = [Priority::Interactive, Priority::Standard, Priority::Batch];

    /// Stable wire/CLI name of the class.
    pub fn name(self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Standard => "standard",
            Priority::Batch => "batch",
        }
    }

    /// Parse a class from its [`Priority::name`] or numeric level
    /// (`0`/`1`/`2`, most important first), as accepted in request JSON.
    pub fn parse(s: &str) -> Option<Priority> {
        match s {
            "interactive" | "0" => Some(Priority::Interactive),
            "standard" | "1" => Some(Priority::Standard),
            "batch" | "2" => Some(Priority::Batch),
            _ => None,
        }
    }

    /// Numeric level (0 = most important); stable across releases.
    pub fn level(self) -> u8 {
        match self {
            Priority::Interactive => 0,
            Priority::Standard => 1,
            Priority::Batch => 2,
        }
    }
}

/// A generation request as submitted by a client.
#[derive(Debug, Clone)]
pub struct Request {
    /// Unique, monotonically assigned id; lower ids are older.
    pub id: u64,
    /// The prompt text (must encode under the model charset).
    pub prompt: String,
    /// Generation budget; decoding stops here or at the stop token.
    pub max_new_tokens: usize,
    /// Greedy when None; otherwise softmax temperature.
    pub temperature: Option<f32>,
    /// Wall-clock arrival, kept for the live server's latency accounting.
    /// The trace harness measures on the scheduler's virtual clock instead.
    pub arrived: Instant,
    /// Scheduling class; [`Priority::Standard`] for unlabeled traffic.
    pub priority: Priority,
    /// Optional end-to-end deadline in virtual microseconds, *relative to
    /// submission*. The scheduler fails the request (releasing its cache
    /// reservation) once its absolute deadline passes; `None` never expires.
    pub deadline_us: Option<u64>,
    /// Length in tokens of the prompt's shareable prefix (a system prompt
    /// or earlier conversation turns repeated across requests). 0 disables
    /// sharing for this request. When prefix sharing is enabled, the
    /// scheduler probes the prefix store for these tokens' quantized images
    /// and charges only the incremental bytes on a hit; numerics are
    /// unchanged either way (the per-channel key norm is computed over the
    /// prefix rows whenever this is non-zero — see
    /// `HeadCache::from_prefill_split_norm`).
    pub prefix_len: usize,
}

impl Request {
    /// A greedy, standard-priority, deadline-free request — the common case;
    /// override fields on the returned value for anything else.
    pub fn new(id: u64, prompt: impl Into<String>, max_new_tokens: usize) -> Request {
        Request {
            id,
            prompt: prompt.into(),
            max_new_tokens,
            temperature: None,
            arrived: Instant::now(),
            priority: Priority::Standard,
            deadline_us: None,
            prefix_len: 0,
        }
    }
}

/// Terminal states.
#[derive(Debug, Clone)]
pub struct Completion {
    /// Id of the originating [`Request`].
    pub id: u64,
    /// The generated text (empty on failure; excludes the stop token).
    pub text: String,
    /// Prompt length in characters/tokens.
    pub n_prompt: usize,
    /// Number of generated tokens (stop token excluded).
    pub n_generated: usize,
    /// Time-to-first-token and total latency, in microseconds.
    pub ttft_us: u64,
    /// End-to-end wall-clock latency in microseconds.
    pub total_us: u64,
    /// Why the request failed, if it did (rejected, unencodable prompt,
    /// prefill failure, expired deadline) — `None` for a normal completion.
    pub error: Option<String>,
}

impl Completion {
    /// A failed terminal state: empty text, zero progress, the reason kept.
    pub fn failed(req: &Request, reason: impl Into<String>) -> Completion {
        Completion {
            id: req.id,
            text: String::new(),
            n_prompt: req.prompt.len(),
            n_generated: 0,
            ttft_us: 0,
            total_us: req.arrived.elapsed().as_micros() as u64,
            error: Some(reason.into()),
        }
    }
}

/// Scheduler-visible request state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Waiting in the admission queue.
    Queued,
    /// Running its prompt through a prefill executable.
    Prefilling,
    /// In the continuous decode batch.
    Decoding,
    /// Completed normally.
    Finished,
    /// Terminated with an error (see [`Completion::error`]).
    Failed,
}

/// One scheduler state transition, recorded when event recording is enabled
/// (see [`crate::coordinator::Scheduler::record_events`]). The trace-replay
/// driver drains these each tick and stamps them with virtual time; the
/// per-request timeline (admission, first token, preemptions, terminal
/// state) is reconstructed entirely from this stream, which is deterministic
/// for a fixed trace and therefore byte-comparable across runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedEvent {
    /// Entered the admission queue.
    Submitted {
        /// Request id.
        id: u64,
    },
    /// Prefill completed and the first token was sampled; the request joins
    /// the decode batch. TTFT is the tick in which this event fires.
    Admitted {
        /// Request id.
        id: u64,
        /// Prompt tokens prefilled (after any recompute preemption, the
        /// request prefills again and a second `Admitted` fires).
        prefill_tokens: usize,
    },
    /// Evicted from the decode batch under cache pressure and returned to
    /// the queue (recompute-style: generated tokens are discarded).
    Preempted {
        /// Request id.
        id: u64,
    },
    /// Evicted from the decode batch under cache pressure with its full
    /// cache snapshotted into the warm tier (offload-style: generated
    /// tokens and quantized state survive; see `cache::store`).
    Offloaded {
        /// Request id.
        id: u64,
        /// Serialized snapshot size in bytes.
        bytes: usize,
    },
    /// Readmitted from the warm tier: the snapshot was deserialized back
    /// into a live sequence without re-running prefill.
    Restored {
        /// Request id.
        id: u64,
        /// Serialized snapshot size in bytes.
        bytes: usize,
    },
    /// Readmission found the snapshot gone (evicted from the warm tier —
    /// terminal for the snapshot); the request falls back to
    /// recompute-style readmission and re-prefills.
    OffloadLost {
        /// Request id.
        id: u64,
    },
    /// Admission found every quantized prefix image for the request's
    /// shareable prefix resident in the prefix store: the sequence borrows
    /// them and its cache reservation covers only the incremental bytes.
    PrefixHit {
        /// Request id.
        id: u64,
        /// Shared bytes the sequence borrows instead of owning.
        bytes: usize,
    },
    /// Failed terminally before completing (rejected, unencodable,
    /// over-budget, or prefill failure).
    Rejected {
        /// Request id.
        id: u64,
    },
    /// Deadline passed; terminal failure with the reservation released.
    Expired {
        /// Request id.
        id: u64,
        /// True if it expired while still queued (never held cache).
        queued: bool,
    },
    /// Completed normally.
    Finished {
        /// Request id.
        id: u64,
        /// Tokens generated (stop token excluded).
        n_generated: usize,
    },
    /// Cancelled by the submitter (client disconnect in the TCP server)
    /// before completing. Terminal: the cache reservation, any warm-tier
    /// residency, and any prefix-store pins were all released, and no
    /// [`Completion`] is pushed.
    Cancelled {
        /// Request id.
        id: u64,
    },
}

impl SchedEvent {
    /// The request id this event concerns.
    pub fn id(&self) -> u64 {
        match *self {
            SchedEvent::Submitted { id }
            | SchedEvent::Admitted { id, .. }
            | SchedEvent::Preempted { id }
            | SchedEvent::Offloaded { id, .. }
            | SchedEvent::Restored { id, .. }
            | SchedEvent::OffloadLost { id }
            | SchedEvent::PrefixHit { id, .. }
            | SchedEvent::Rejected { id }
            | SchedEvent::Expired { id, .. }
            | SchedEvent::Finished { id, .. }
            | SchedEvent::Cancelled { id } => id,
        }
    }
}

/// Monotonic scheduler counters, updated every tick.
#[derive(Debug, Clone, Copy, Default)]
pub struct StepMetrics {
    /// Prompt tokens run through prefill executables (recomputation after a
    /// preemption counts again, as does the prefill pass behind a
    /// partial-restore window rebuild).
    pub prefill_tokens: u64,
    /// Decode steps executed (one per tick with live work).
    pub decode_steps: u64,
    /// Sequences decoded, summed over steps.
    pub batched_seqs: u64,
    /// Live sequences evicted back to the queue under cache pressure.
    pub preemptions: u64,
    /// Attention jobs fanned out to the worker pool (one per sequence x
    /// KV head x layer per decode step).
    pub attn_jobs: u64,
    /// Cache-pool reservations found without a live owner and released.
    pub stale_reservations: u64,
    /// Requests terminated without generation (unencodable, over budget,
    /// unsatisfiable under pressure, prefill failure).
    pub rejected: u64,
    /// Requests failed terminally because their deadline passed.
    pub expired: u64,
    /// Requests cancelled by the submitter (client disconnect) — terminal,
    /// with every cache/tier/prefix hold released and no completion pushed.
    pub cancelled: u64,
    /// Preemption victims whose cache was snapshotted into the warm tier
    /// instead of being discarded (a subset of `preemptions`).
    pub offloads: u64,
    /// Serialized snapshot bytes written to the warm tier.
    pub offload_bytes: u64,
    /// Offloaded sequences readmitted by deserializing their snapshot
    /// (no re-prefill).
    pub restores: u64,
    /// Serialized snapshot bytes read back from the warm tier.
    pub restore_bytes: u64,
    /// Readmissions that found their snapshot evicted from the warm tier
    /// and fell back to a recompute-style re-prefill.
    pub offload_lost: u64,
    /// Droppable fp-window frames skipped at offload time because only the
    /// required frames fit the warm budget (partial residency from birth).
    pub window_frames_dropped: u64,
    /// Layers whose fp windows were recomputed at restore time because
    /// their window frames had been evicted from (or never stored in) the
    /// warm tier.
    pub window_rebuilds: u64,
    /// Smaller lower-priority requests admitted past a parked queue head
    /// under the SLO policy's bounded bypass.
    pub bypass_admissions: u64,
    /// Admissions that borrowed every prefix image from the prefix store
    /// instead of quantizing the prefix privately.
    pub prefix_hits: u64,
    /// Quantized bytes borrowed from the prefix store at admission, summed
    /// over prefix hits — bytes the cache pool did *not* have to reserve.
    pub prefix_bytes_shared: u64,
}

impl StepMetrics {
    /// Add every counter of `other` into `self` — the fleet aggregate over
    /// per-replica schedulers (`coordinator::fleet`). Field-by-field so a
    /// newly added counter cannot be silently dropped from the aggregate.
    pub fn absorb(&mut self, other: &StepMetrics) {
        let StepMetrics {
            prefill_tokens,
            decode_steps,
            batched_seqs,
            preemptions,
            attn_jobs,
            stale_reservations,
            rejected,
            expired,
            cancelled,
            offloads,
            offload_bytes,
            restores,
            restore_bytes,
            offload_lost,
            window_frames_dropped,
            window_rebuilds,
            bypass_admissions,
            prefix_hits,
            prefix_bytes_shared,
        } = *other;
        self.prefill_tokens += prefill_tokens;
        self.decode_steps += decode_steps;
        self.batched_seqs += batched_seqs;
        self.preemptions += preemptions;
        self.attn_jobs += attn_jobs;
        self.stale_reservations += stale_reservations;
        self.rejected += rejected;
        self.expired += expired;
        self.cancelled += cancelled;
        self.offloads += offloads;
        self.offload_bytes += offload_bytes;
        self.restores += restores;
        self.restore_bytes += restore_bytes;
        self.offload_lost += offload_lost;
        self.window_frames_dropped += window_frames_dropped;
        self.window_rebuilds += window_rebuilds;
        self.bypass_admissions += bypass_admissions;
        self.prefix_hits += prefix_hits;
        self.prefix_bytes_shared += prefix_bytes_shared;
    }
}
