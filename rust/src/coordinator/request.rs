//! Request lifecycle for the serving coordinator.

use std::time::Instant;

/// A generation request as submitted by a client.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: String,
    pub max_new_tokens: usize,
    /// Greedy when None; otherwise softmax temperature.
    pub temperature: Option<f32>,
    pub arrived: Instant,
}

/// Terminal states.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: u64,
    pub text: String,
    pub n_prompt: usize,
    pub n_generated: usize,
    /// Time-to-first-token and total latency, in microseconds.
    pub ttft_us: u64,
    pub total_us: u64,
    /// Why the request failed, if it did (rejected, unencodable prompt,
    /// prefill failure) — `None` for a normal completion.
    pub error: Option<String>,
}

impl Completion {
    /// A failed terminal state: empty text, zero progress, the reason kept.
    pub fn failed(req: &Request, reason: impl Into<String>) -> Completion {
        Completion {
            id: req.id,
            text: String::new(),
            n_prompt: req.prompt.len(),
            n_generated: 0,
            ttft_us: 0,
            total_us: req.arrived.elapsed().as_micros() as u64,
            error: Some(reason.into()),
        }
    }
}

/// Scheduler-visible request state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Queued,
    Prefilling,
    Decoding,
    Finished,
    Failed,
}

#[derive(Debug, Clone, Copy, Default)]
pub struct StepMetrics {
    pub prefill_tokens: u64,
    pub decode_steps: u64,
    pub batched_seqs: u64,
    pub preemptions: u64,
    /// Attention jobs fanned out to the worker pool (one per sequence x
    /// KV head x layer per decode step).
    pub attn_jobs: u64,
    /// Cache-pool reservations found without a live owner and released.
    pub stale_reservations: u64,
    /// Requests terminated without generation (unencodable, over budget,
    /// unsatisfiable under pressure, prefill failure).
    pub rejected: u64,
}
