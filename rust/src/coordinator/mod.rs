//! Layer-3 serving coordinator: the decode engine (PJRT stages + Rust
//! quantized-cache attention), the dynamic batcher, the prefill/decode
//! scheduler with cache-pressure preemption and SLO-aware policies, and
//! request plumbing.

pub mod batcher;
pub mod engine;
pub mod fleet;
pub mod request;
pub mod scheduler;

pub use engine::{Engine, PipelineMode, PrefixOutcome, Sequence};
pub use fleet::{parse_router, Affinity, Fleet, LeastLoaded, Placement, RoundRobin, RouterPolicy};
pub use request::{Completion, Phase, Priority, Request, SchedEvent, StepMetrics};
pub use scheduler::{Policy, Preemption, Scheduler, WarmExport};
