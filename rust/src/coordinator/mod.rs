//! Layer-3 serving coordinator: the decode engine (PJRT stages + Rust
//! quantized-cache attention), the dynamic batcher, the prefill/decode
//! scheduler with cache-pressure preemption, and request plumbing.

pub mod batcher;
pub mod engine;
pub mod request;
pub mod scheduler;

pub use engine::{Engine, Sequence};
pub use request::{Completion, Phase, Request, StepMetrics};
pub use scheduler::Scheduler;
