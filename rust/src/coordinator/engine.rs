//! The decode engine: drives the AOT-compiled model stages through PJRT and
//! owns the quantized KV cache between the QKV and output stages.
//!
//! One decode step for a batch of sequences, as a task graph:
//!
//! ```text
//!   embed(tokens) ──▶ qkv(0) ──▶ {head jobs layer 0} ──▶ out(0) ──▶ qkv(1) ──▶ …
//!    [driver]        [driver]    one fused append+attend   [driver]
//!                                job per (sequence, head)
//!                                          …  ──▶ out(L-1) ──▶ head ──▶ logits
//! ```
//!
//! PJRT stages are **driver-only** graph nodes (the PJRT client is
//! thread-local); the per-(sequence, KV head) cache work between them fans
//! out across the worker pool. Each head job *fuses* the step's append
//! (quantize-on-evict included) with its attention, so one head's
//! quantization spike overlaps every other head's attention instead of
//! serializing on the driver — the old per-layer double barrier (serial
//! appends, then a barriered attention fan-out) is gone. Under
//! [`PipelineMode::Overlap`] (the default) the whole step is emitted up
//! front through `ThreadPool::run_graph`; [`PipelineMode::Barrier`] retains
//! the original phase-barriered loop as the bit-exactness oracle —
//! `tests/decode_pipeline.rs` asserts both modes produce byte-identical
//! logits and cache bytes at every worker count.
//!
//! A note on cross-layer overlap: layer `l+1`'s K/V only exist after
//! `qkv(l+1)`, which consumes `out(l)`, which needs every layer-`l`
//! attention output — the transformer's own data dependency. So inside the
//! *engine* the graph's cross-layer edges are always tight; the overlap the
//! graph buys here is within a layer (append ∥ attend across heads, with
//! the driver stealing head jobs while it waits). The decode-scaling bench,
//! whose per-layer inputs are precomputed, emits the same graph *without*
//! the PJRT chain and shows the full cross-layer pipelining headroom.
//!
//! Python never runs here; the executables were compiled from
//! `artifacts/*.hlo.txt` at engine start.

use crate::cache::store::{prefix_base_hash, PrefixImage, PrefixStore};
use crate::cache::{attention_fanout, head_step, HeadCache, LayerCache};
use crate::kernels::dispatch;
use crate::obs;
use crate::quant::MethodConfig;
use crate::runtime::executable::{In, Stage as PjrtStage};
use crate::runtime::Manifest;
use crate::util::threadpool::{Job, Stage, ThreadPool};
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::sync::{Arc, Mutex, MutexGuard, RwLock};

/// Decode-step execution mode; see the module docs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PipelineMode {
    /// The original phase-barriered loop: per layer, all appends serially
    /// on the driver, then an attention fan-out behind a pool barrier.
    /// Retained as the bit-exactness oracle for the pipelined path.
    Barrier,
    /// Emit the whole decode step as one dependency graph of fused
    /// append+attend jobs chained between driver-only PJRT stages.
    #[default]
    Overlap,
}

impl PipelineMode {
    /// Parse a mode from its CLI name (`barrier` / `overlap`).
    pub fn parse(s: &str) -> Option<PipelineMode> {
        match s {
            "barrier" => Some(PipelineMode::Barrier),
            "overlap" => Some(PipelineMode::Overlap),
            _ => None,
        }
    }

    /// Stable CLI/report name.
    pub fn name(self) -> &'static str {
        match self {
            PipelineMode::Barrier => "barrier",
            PipelineMode::Overlap => "overlap",
        }
    }
}

/// How [`Engine::prefill_shared`] resolved a request's shareable prefix.
/// `Published` and `Hit` leave the sequence *borrowing* refcount-pinned
/// images out of the [`PrefixStore`]; the caller owns their release when the
/// sequence retires (finishes, expires, or is recompute-preempted).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrefixOutcome {
    /// No sharing happened: the request declared no prefix, no store was
    /// supplied, or the store refused the insert under budget pressure (in
    /// which case the prefix was materialized into private copies — the
    /// sequence owns every byte and nothing needs releasing).
    Private,
    /// Store miss: the prefix was quantized here and its images published;
    /// the sequence borrows them so later requests can hit.
    Published {
        /// Content hash of `(MethodConfig, prefix tokens)`.
        base: u64,
        /// Total quantized bytes of the published image set.
        bytes: usize,
    },
    /// Store hit: every `(layer, head)` image was already resident; the
    /// sequence borrows them and only the unshared tail was quantized.
    Hit {
        /// Content hash of `(MethodConfig, prefix tokens)`.
        base: u64,
        /// Total quantized bytes borrowed instead of owned — the incremental
        /// savings the scheduler's admission accounting credits.
        bytes: usize,
    },
}

/// Gather one `(layer, head)`'s token-major K/V rows out of the bucketed
/// prefill tensors (layout `(n_layers, bucket, n_kv, d_h)` per tensor).
fn gather_rows(
    ks: &[f32],
    vs: &[f32],
    bucket: usize,
    n_kv: usize,
    d_h: usize,
    n: usize,
    l: usize,
    h: usize,
) -> (Vec<f32>, Vec<f32>) {
    let mut k_rows = Vec::with_capacity(n * d_h);
    let mut v_rows = Vec::with_capacity(n * d_h);
    for t in 0..n {
        let base = ((l * bucket + t) * n_kv + h) * d_h;
        k_rows.extend_from_slice(&ks[base..base + d_h]);
        v_rows.extend_from_slice(&vs[base..base + d_h]);
    }
    (k_rows, v_rows)
}

/// One live sequence: token history + one [`LayerCache`] per layer.
/// Attention scratch lives with the pool workers, not the sequence, so
/// disjoint heads of the same sequence can attend concurrently.
pub struct Sequence {
    /// Engine-assigned sequence id.
    pub id: u64,
    /// Full token history (prompt + generated).
    pub tokens: Vec<i32>,
    /// Per-layer quantized caches; [`LayerCache`] is the ownership unit for
    /// pipelined decode and per-layer snapshot frames.
    pub caches: Vec<LayerCache>,
    /// Tokens that went through prefill (the prompt length).
    pub n_prefill: usize,
    /// Logits of the most recent step, for sampling the next token.
    pub last_logits: Vec<f32>,
}

impl Sequence {
    /// Total cache bytes across layers/heads (for the pool).
    pub fn cache_bytes(&self) -> usize {
        self.caches.iter().map(|l| l.bytes()).sum()
    }
    /// Total tokens in the sequence.
    pub fn len(&self) -> usize {
        self.tokens.len()
    }
    /// True before any token has been appended.
    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }
    /// Split-borrow accessor: the per-layer caches as one mutable slice, so
    /// callers can carve disjoint `&mut LayerCache` (and from those,
    /// `&mut HeadCache`) handles for concurrent in-flight work.
    pub fn layers_mut(&mut self) -> &mut [LayerCache] {
        &mut self.caches
    }
}

/// Poison-tolerant mutex lock (a panicked pool job must not wedge the
/// engine; state written under the lock is only read after the graph joins).
fn lockm<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// The model engine for one quantization method.
pub struct Engine {
    /// The loaded artifact manifest (model dims, stages, charset).
    pub manifest: Manifest,
    /// The quantization method configuration for every cache.
    pub cfg: MethodConfig,
    stages: HashMap<String, PjrtStage>,
    pool: ThreadPool,
    pipeline: PipelineMode,
    next_id: std::sync::atomic::AtomicU64,
}

impl Engine {
    /// Load and compile every decode stage eagerly (prefill buckets lazily
    /// would also work, but eager keeps decode latency deterministic).
    /// Starts with one worker (serial attention); see [`Engine::set_workers`].
    pub fn new(manifest: Manifest, cfg: MethodConfig) -> Result<Engine> {
        let mut stages = HashMap::new();
        for (key, _) in manifest.artifacts.iter() {
            let stage = PjrtStage::load(key, &manifest.path(key)?)?;
            stages.insert(key.clone(), stage);
        }
        Ok(Engine {
            manifest,
            cfg,
            stages,
            pool: ThreadPool::new(1),
            pipeline: PipelineMode::default(),
            next_id: 0.into(),
        })
    }

    /// Resize the attention worker pool to `workers` total threads (the
    /// driver counts as one; 1 = the serial baseline).
    pub fn set_workers(&mut self, workers: usize) {
        if workers.max(1) != self.pool.workers() {
            self.pool = ThreadPool::new(workers);
        }
    }

    /// Current attention worker-pool size.
    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    /// The engine's worker pool, shared with cache-adjacent fan-outs owned
    /// by the coordinator (e.g. offload snapshot serialization, which is
    /// read-only over a victim's caches).
    pub fn pool(&self) -> &ThreadPool {
        &self.pool
    }

    /// Switch the decode-step execution mode (default
    /// [`PipelineMode::Overlap`]).
    pub fn set_pipeline(&mut self, mode: PipelineMode) {
        self.pipeline = mode;
    }

    /// The active decode-step execution mode.
    pub fn pipeline(&self) -> PipelineMode {
        self.pipeline
    }

    fn stage(&self, key: &str) -> Result<&PjrtStage> {
        self.stages.get(key).with_context(|| format!("stage '{key}' not loaded"))
    }

    /// Run the bucketed prefill executable for `prompt`, returning
    /// `(logits, ks, vs, bucket)` — logits `(bucket, vocab)`, K/V tensors
    /// `(n_layers, bucket, n_kv, d_h)`.
    fn run_prefill_stage(&self, prompt: &[i32]) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, usize)> {
        let bucket = self.manifest.prefill_bucket(prompt.len())?;
        let mut padded = prompt.to_vec();
        padded.resize(bucket, self.manifest.bos);
        let out = self
            .stage(&format!("prefill_l{bucket}"))?
            .run(&[In::I32(&padded, &[1, bucket as i64])])?;
        Ok((out.f32(0)?, out.f32(1)?, out.f32(2)?, bucket))
    }

    /// Run prefill for a prompt; returns an initialized sequence whose
    /// caches follow Eq. (15) (sink / bulk-quantized middle / recent).
    pub fn prefill(&self, prompt: &[i32]) -> Result<Sequence> {
        let dims = &self.manifest.model;
        let (logits, ks, vs, bucket) = self.run_prefill_stage(prompt)?;

        let n = prompt.len();
        let (n_l, n_kv, d_h) = (dims.n_layers, dims.n_kv_heads, dims.d_h);
        // Fan the bulk quantization out across the worker pool: one job per
        // (layer, KV head), built by the shared `cache::prefill_fanout` so
        // the engine and the determinism test share one job shape. Each job
        // gathers its head's strided token-major rows out of the shared
        // prefill tensors *inside* the job (layout (L, n_kv, d_h) per
        // layer), so peak extra memory is one head copy per in-flight
        // worker, not a duplicate of the whole prompt KV. Quantization
        // dominates prefill cache setup and each head is independent, so
        // results are byte-identical at any worker count.
        let (ks_ref, vs_ref): (&[f32], &[f32]) = (&ks, &vs);
        let gathers: Vec<_> = (0..n_l * n_kv)
            .map(|idx| {
                let (l, h) = (idx / n_kv, idx % n_kv);
                move || gather_rows(ks_ref, vs_ref, bucket, n_kv, d_h, n, l, h)
            })
            .collect();
        let mut slots: Vec<Option<HeadCache>> = (0..n_l * n_kv).map(|_| None).collect();
        self.pool.run(crate::cache::prefill_fanout(self.cfg, d_h, gathers, &mut slots));
        Ok(self.assemble_sequence(prompt, slots, &logits))
    }

    /// Collect filled per-(layer, head) slots into a [`Sequence`] (the shared
    /// tail of every prefill flavor).
    fn assemble_sequence(
        &self,
        prompt: &[i32],
        slots: Vec<Option<HeadCache>>,
        logits: &[f32],
    ) -> Sequence {
        let dims = &self.manifest.model;
        let (n_l, n_kv) = (dims.n_layers, dims.n_kv_heads);
        let mut caches = Vec::with_capacity(n_l);
        let mut slot_iter = slots.into_iter();
        for _ in 0..n_l {
            let heads: Vec<HeadCache> = slot_iter
                .by_ref()
                .take(n_kv)
                .map(|s| s.expect("prefill job filled its slot"))
                .collect();
            caches.push(LayerCache::from_heads(heads));
        }
        let vstart = (prompt.len() - 1) * dims.vocab;
        Sequence {
            id: self.next_id.fetch_add(1, std::sync::atomic::Ordering::Relaxed),
            tokens: prompt.to_vec(),
            caches,
            n_prefill: prompt.len(),
            last_logits: logits[vstart..vstart + dims.vocab].to_vec(),
        }
    }

    /// Prefill with shared-prefix resolution: the first `prefix_len` tokens
    /// are a shareable prefix whose quantized images may already live in
    /// `store`.
    ///
    /// Three paths, all byte-identical in logits and (merged) cache state:
    ///
    /// * **no store / no prefix** — private quantization. With a non-zero
    ///   `prefix_len` the per-channel key norm is still computed over the
    ///   prefix rows only ([`HeadCache::from_prefill_split_norm`]): the
    ///   numerics contract is a function of the *request*, never of whether
    ///   sharing is enabled, so toggling `--prefix-share` cannot change a
    ///   single output byte.
    /// * **store hit** — every `(layer, head)` image is borrowed
    ///   (refcount-pinned) and only the unshared tail is quantized
    ///   ([`HeadCache::from_shared_prefix`]).
    /// * **store miss** — the prefix is quantized once, split off as
    ///   immutable images ([`HeadCache::split_off_prefix`]) and published;
    ///   if the store refuses (budget pressure) the images are merged back
    ///   into private copies so a sequence holds shared state iff the store
    ///   tracks it.
    pub fn prefill_shared(
        &self,
        prompt: &[i32],
        prefix_len: usize,
        store: Option<&mut PrefixStore>,
    ) -> Result<(Sequence, PrefixOutcome)> {
        let n = prompt.len();
        if prefix_len == 0 || prefix_len > n {
            return Ok((self.prefill(prompt)?, PrefixOutcome::Private));
        }
        let dims = &self.manifest.model;
        let (logits, ks, vs, bucket) = self.run_prefill_stage(prompt)?;
        let (n_l, n_kv, d_h) = (dims.n_layers, dims.n_kv_heads, dims.d_h);
        let cfg = self.cfg;
        let (ks_ref, vs_ref): (&[f32], &[f32]) = (&ks, &vs);

        let mut slots: Vec<Option<HeadCache>> = (0..n_l * n_kv).map(|_| None).collect();
        let mut outcome = PrefixOutcome::Private;

        match store {
            None => {
                // Sharing disabled but a prefix declared: split-norm private
                // quantization (see the method docs on why the norm split
                // must not depend on the sharing toggle).
                let jobs: Vec<Job> = slots
                    .iter_mut()
                    .enumerate()
                    .map(|(idx, slot)| {
                        let job: Job = Box::new(move |_scratch: &mut Vec<f32>| {
                            let (l, h) = (idx / n_kv, idx % n_kv);
                            let (k_rows, v_rows) =
                                gather_rows(ks_ref, vs_ref, bucket, n_kv, d_h, n, l, h);
                            *slot = Some(HeadCache::from_prefill_split_norm(
                                cfg, d_h, &k_rows, &v_rows, prefix_len,
                            ));
                        });
                        job
                    })
                    .collect();
                self.pool.run(jobs);
            }
            Some(st) => {
                let base = prefix_base_hash(&cfg, &prompt[..prefix_len]);
                let t_probe = obs::start();
                if let Some(images) = st.acquire_set(base, n_l, n_kv) {
                    // Hit: borrow every image; quantize only the tail.
                    let bytes: usize = images.iter().flatten().map(|i| i.bytes()).sum();
                    obs::span(obs::SpanKind::PrefixProbe, base, t_probe, bytes as u64, 1);
                    let flat: Vec<Arc<PrefixImage>> = images.into_iter().flatten().collect();
                    let jobs: Vec<Job> = flat
                        .into_iter()
                        .zip(slots.iter_mut())
                        .enumerate()
                        .map(|(idx, (img, slot))| {
                            let job: Job = Box::new(move |_scratch: &mut Vec<f32>| {
                                let (l, h) = (idx / n_kv, idx % n_kv);
                                let (k_rows, v_rows) =
                                    gather_rows(ks_ref, vs_ref, bucket, n_kv, d_h, n, l, h);
                                debug_assert_eq!(img.prefix_len, prefix_len);
                                *slot = Some(HeadCache::from_shared_prefix(
                                    cfg,
                                    d_h,
                                    &k_rows,
                                    &v_rows,
                                    prefix_len,
                                    img.qk.clone(),
                                    img.qv.clone(),
                                    img.norm.clone(),
                                ));
                            });
                            job
                        })
                        .collect();
                    self.pool.run(jobs);
                    outcome = PrefixOutcome::Hit { base, bytes };
                } else {
                    // Miss: quantize the prefix once per (layer, head), fork
                    // it off as an immutable image, then continue with the
                    // tail — the exact append cadence of the unified build,
                    // so the merged state is byte-identical to it.
                    let mut pairs: Vec<Option<(HeadCache, PrefixImage)>> =
                        (0..n_l * n_kv).map(|_| None).collect();
                    let jobs: Vec<Job> = pairs
                        .iter_mut()
                        .enumerate()
                        .map(|(idx, slot)| {
                            let job: Job = Box::new(move |_scratch: &mut Vec<f32>| {
                                let (l, h) = (idx / n_kv, idx % n_kv);
                                let (k_rows, v_rows) =
                                    gather_rows(ks_ref, vs_ref, bucket, n_kv, d_h, n, l, h);
                                let pb = prefix_len * d_h;
                                let mut hc = HeadCache::from_prefill_split_norm(
                                    cfg,
                                    d_h,
                                    &k_rows[..pb],
                                    &v_rows[..pb],
                                    prefix_len,
                                );
                                let (qk, qv) = hc.split_off_prefix();
                                let img = PrefixImage {
                                    d_h,
                                    prefix_len,
                                    qk,
                                    qv,
                                    norm: hc.norm.clone(),
                                };
                                for (k, v) in k_rows[pb..]
                                    .chunks_exact(d_h)
                                    .zip(v_rows[pb..].chunks_exact(d_h))
                                {
                                    hc.append(k, v);
                                }
                                *slot = Some((hc, img));
                            });
                            job
                        })
                        .collect();
                    self.pool.run(jobs);
                    let mut images: Vec<Vec<PrefixImage>> =
                        (0..n_l).map(|_| Vec::with_capacity(n_kv)).collect();
                    for (idx, pair) in pairs.into_iter().enumerate() {
                        let (hc, img) = pair.expect("prefill job filled its slot");
                        slots[idx] = Some(hc);
                        images[idx / n_kv].push(img);
                    }
                    let bytes: usize = images.iter().flatten().map(|i| i.bytes()).sum();
                    let t_pub = obs::start();
                    if st.insert_set(base, images).is_some() {
                        obs::span(obs::SpanKind::PrefixProbe, base, t_pub, bytes as u64, 2);
                        outcome = PrefixOutcome::Published { base, bytes };
                    } else {
                        obs::span(obs::SpanKind::PrefixProbe, base, t_pub, 0, 0);
                        // The store refused (budget pressure / pinned
                        // residents): materialize private copies so the
                        // invariant holds — a sequence holds shared Arcs
                        // iff the store tracks and pins them.
                        for slot in slots.iter_mut() {
                            if let Some(hc) = slot {
                                *hc = hc.merged();
                            }
                        }
                    }
                }
            }
        }
        Ok((self.assemble_sequence(prompt, slots, &logits), outcome))
    }

    /// Rebuild the fp sink/recent windows of the given `layers` of a
    /// restored sequence whose window frames were evicted from the warm
    /// tier, by re-running the prefill stage over the sequence's tokens and
    /// replaying each head's window dynamics (the quantized middle is left
    /// untouched — that is the whole point of per-layer frames).
    ///
    /// Only valid for sequences with no decoded appends (`len() ==
    /// n_prefill`): decoded rows cannot be recomputed without the cache
    /// state that produced them. The scheduler only marks window frames
    /// droppable under that condition.
    pub fn rebuild_windows(&self, seq: &mut Sequence, layers: &[usize]) -> Result<()> {
        if layers.is_empty() {
            return Ok(());
        }
        if seq.len() != seq.n_prefill {
            return Err(anyhow!(
                "window rebuild requires a prefill-only sequence ({} tokens, {} prefilled)",
                seq.len(),
                seq.n_prefill
            ));
        }
        let dims = &self.manifest.model;
        let (n_kv, d_h) = (dims.n_kv_heads, dims.d_h);
        let n = seq.n_prefill;
        let toks = seq.tokens.clone();
        let (_logits, ks, vs, bucket) = self.run_prefill_stage(&toks)?;
        for &l in layers {
            if l >= seq.caches.len() {
                return Err(anyhow!("window rebuild: layer {l} out of range"));
            }
            for hk in 0..n_kv {
                let mut k_rows = Vec::with_capacity(n * d_h);
                let mut v_rows = Vec::with_capacity(n * d_h);
                for t in 0..n {
                    let base = ((l * bucket + t) * n_kv + hk) * d_h;
                    k_rows.extend_from_slice(&ks[base..base + d_h]);
                    v_rows.extend_from_slice(&vs[base..base + d_h]);
                }
                seq.caches[l].head_mut(hk).rebuild_windows(&k_rows, &v_rows);
            }
        }
        Ok(())
    }

    /// One batched decode step: appends `next_tokens[i]` to each sequence
    /// and computes its logits. Sequences may have different lengths. The
    /// execution shape is the active [`PipelineMode`]; both modes are
    /// byte-identical at any worker count.
    pub fn decode_step(&self, seqs: &mut [&mut Sequence], next_tokens: &[i32]) -> Result<()> {
        assert_eq!(seqs.len(), next_tokens.len());
        let dims = self.manifest.model.clone();
        let nb = seqs.len();
        let bb = self.manifest.decode_batch(nb)?; // padded batch bucket

        let mut tokens = vec![self.manifest.bos; bb];
        let mut positions = vec![0i32; bb];
        for (i, s) in seqs.iter().enumerate() {
            tokens[i] = next_tokens[i];
            positions[i] = s.tokens.len() as i32; // position of the new token
        }

        let h = self
            .stage(&format!("embed_b{bb}"))?
            .run(&[In::I32(&tokens, &[bb as i64])])?
            .f32(0)?; // (bb, d_model)

        let logits = match self.pipeline {
            PipelineMode::Barrier => self.decode_layers_barrier(seqs, h, &positions, bb)?,
            PipelineMode::Overlap => self.decode_layers_overlap(seqs, h, &positions, bb)?,
        };

        for (i, s) in seqs.iter_mut().enumerate() {
            s.tokens.push(next_tokens[i]);
            let vb = i * dims.vocab;
            s.last_logits = logits[vb..vb + dims.vocab].to_vec();
        }
        Ok(())
    }

    /// The original phase-barriered decode loop: per layer, run qkv, append
    /// every head's K/V serially on the driver, fan the attention out with
    /// a full pool barrier, then run the output stage. Kept verbatim as the
    /// oracle for [`PipelineMode::Overlap`].
    fn decode_layers_barrier(
        &self,
        seqs: &mut [&mut Sequence],
        mut h: Vec<f32>,
        positions: &[i32],
        bb: usize,
    ) -> Result<Vec<f32>> {
        let dims = &self.manifest.model;
        let rep = dims.heads_per_kv();
        let (d_h, q_dim) = (dims.d_h, dims.q_dim());
        let n_kv = dims.n_kv_heads;
        for l in 0..dims.n_layers {
            let t_qkv = obs::start();
            let out = self.stage(&format!("qkv_l{l}_b{bb}"))?.run(&[
                In::F32(&h, &[bb as i64, dims.d_model as i64]),
                In::I32(positions, &[bb as i64]),
            ])?;
            let q = out.f32(0)?; // (bb, n_q, d_h)
            let k = out.f32(1)?; // (bb, n_kv, d_h)
            let v = out.f32(2)?;
            obs::span(obs::SpanKind::StageQkv, l as u64, t_qkv, l as u64, bb as u64);

            // Append this step's K/V on the driver — the only cache mutation.
            for (i, s) in seqs.iter_mut().enumerate() {
                for hk in 0..n_kv {
                    let kb = (i * n_kv + hk) * d_h;
                    s.caches[l].head_mut(hk).append(&k[kb..kb + d_h], &v[kb..kb + d_h]);
                }
            }

            // Fan the attention out across the pool: one job per
            // (sequence, KV head), each owning the contiguous rep*d_h slice
            // of ctx its query heads write (see `cache::attention_fanout`
            // for the shared job shape). Slices are disjoint by
            // construction, so write-back is deterministic and matches the
            // serial loop exactly.
            let mut ctx = vec![0f32; bb * q_dim];
            {
                let heads = seqs.iter().flat_map(|s| s.caches[l].heads().iter());
                self.pool.run(attention_fanout(heads, &q, &mut ctx, rep, d_h));
            }

            let t_out = obs::start();
            h = self
                .stage(&format!("out_l{l}_b{bb}"))?
                .run(&[
                    In::F32(&h, &[bb as i64, dims.d_model as i64]),
                    In::F32(&ctx, &[bb as i64, q_dim as i64]),
                ])?
                .f32(0)?;
            obs::span(obs::SpanKind::StageOut, l as u64, t_out, l as u64, bb as u64);
        }

        let t_head = obs::start();
        let logits = self
            .stage(&format!("head_b{bb}"))?
            .run(&[In::F32(&h, &[bb as i64, dims.d_model as i64])])?
            .f32(0)?; // (bb, vocab)
        obs::span(obs::SpanKind::StageHead, 0, t_head, dims.n_layers as u64, bb as u64);
        Ok(logits)
    }

    /// Pipelined decode: emit the whole step as one dependency graph —
    /// driver-only PJRT stages chained between per-layer fan-outs of fused
    /// append+attend head jobs (see the module docs for the stage diagram).
    ///
    /// Stage results flow between driver nodes through mutex-guarded slots
    /// (`h`, per-layer qkv outputs, per-layer context buffers); head jobs
    /// read their layer's qkv tensors through a shared `RwLock` (concurrent
    /// readers) and copy their finished `rep*d_h` context slice into the
    /// layer's buffer under a short-lived lock. Copies are disjoint and
    /// each head's FP order matches the barrier path exactly, so the step
    /// is bit-identical to [`Engine::decode_layers_barrier`] at any worker
    /// count. A PJRT error is parked in an error slot; downstream driver
    /// stages and head jobs turn into no-ops, the graph drains, and the
    /// error is returned once joined (the same partially-appended state the
    /// barrier path leaves on a mid-loop error).
    fn decode_layers_overlap(
        &self,
        seqs: &mut [&mut Sequence],
        h: Vec<f32>,
        positions: &[i32],
        bb: usize,
    ) -> Result<Vec<f32>> {
        let dims = self.manifest.model.clone();
        let rep = dims.heads_per_kv();
        let (d_h, q_dim, d_model) = (dims.d_h, dims.q_dim(), dims.d_model);
        let n_kv = dims.n_kv_heads;
        let n_l = dims.n_layers;

        // Disjoint &mut handles for every (layer, seq-major head): layer
        // l's jobs and any other layer's jobs may be in flight together
        // without aliasing — this is what the LayerCache ownership split
        // buys over the old monolithic Vec<Vec<HeadCache>>.
        let mut layer_heads: Vec<Vec<&mut HeadCache>> =
            (0..n_l).map(|_| Vec::with_capacity(seqs.len() * n_kv)).collect();
        for s in seqs.iter_mut() {
            for (l, lc) in s.layers_mut().iter_mut().enumerate() {
                for hc in lc.heads_mut().iter_mut() {
                    layer_heads[l].push(hc);
                }
            }
        }

        /// One layer's qkv outputs, written by the layer's driver stage and
        /// read concurrently by its head jobs. Empty until produced (or on
        /// an upstream error, which turns the readers into no-ops).
        #[derive(Default)]
        struct LayerQkv {
            q: Vec<f32>,
            k: Vec<f32>,
            v: Vec<f32>,
        }
        let qkv: Vec<RwLock<LayerQkv>> = (0..n_l).map(|_| RwLock::new(LayerQkv::default())).collect();
        let ctx: Vec<Mutex<Vec<f32>>> =
            (0..n_l).map(|_| Mutex::new(vec![0f32; bb * q_dim])).collect();
        let hbuf: Mutex<Vec<f32>> = Mutex::new(h);
        let logits_slot: Mutex<Vec<f32>> = Mutex::new(Vec::new());
        let err: Mutex<Option<anyhow::Error>> = Mutex::new(None);

        let mut stages: Vec<Stage> = Vec::with_capacity(3 * n_l + 1);
        for (l, heads) in layer_heads.into_iter().enumerate() {
            // --- qkv(l): driver-only; dep on out(l-1) ---
            let deps = if l == 0 { Vec::new() } else { vec![3 * l - 1] };
            let (qkv_ref, err_ref, hbuf_ref) = (&qkv, &err, &hbuf);
            let qkv_job: Job = Box::new(move |_scratch: &mut Vec<f32>| {
                    if lockm(err_ref).is_some() {
                        return;
                    }
                    let t_qkv = obs::start();
                    // Driver stages run strictly sequentially, so holding
                    // the h guard across the PJRT call is uncontended and
                    // avoids cloning the hidden state every stage.
                    let hv = lockm(hbuf_ref);
                    let res = (|| -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
                        let out = self.stage(&format!("qkv_l{l}_b{bb}"))?.run(&[
                            In::F32(&hv, &[bb as i64, d_model as i64]),
                            In::I32(positions, &[bb as i64]),
                        ])?;
                        Ok((out.f32(0)?, out.f32(1)?, out.f32(2)?))
                    })();
                    drop(hv);
                    match res {
                        Ok((q, k, v)) => {
                            let mut w = qkv_ref[l].write().unwrap_or_else(|e| e.into_inner());
                            w.q = q;
                            w.k = k;
                            w.v = v;
                        }
                        Err(e) => *lockm(err_ref) = Some(e),
                    }
                    obs::span(obs::SpanKind::StageQkv, l as u64, t_qkv, l as u64, bb as u64);
                });
            stages.push(Stage::driver_only(deps, vec![qkv_job]));

            // --- head jobs: fused append+attend, dep on qkv(l) ---
            let mut jobs: Vec<Job> = Vec::with_capacity(heads.len());
            for (c, head) in heads.into_iter().enumerate() {
                let (qkv_ref, ctx_ref) = (&qkv, &ctx);
                jobs.push(Box::new(move |scratch: &mut Vec<f32>| {
                    let inp = qkv_ref[l].read().unwrap_or_else(|e| e.into_inner());
                    if inp.q.is_empty() {
                        return; // upstream stage failed; drain as a no-op
                    }
                    let t_job = obs::start();
                    let mut out = vec![0f32; rep * d_h];
                    head_step(
                        head,
                        &inp.k[c * d_h..(c + 1) * d_h],
                        &inp.v[c * d_h..(c + 1) * d_h],
                        &inp.q[c * rep * d_h..(c + 1) * rep * d_h],
                        &mut out,
                        scratch,
                    );
                    drop(inp);
                    // Disjoint copy into the layer's context buffer; order
                    // across heads is irrelevant to the final bytes.
                    let mut cx = lockm(&ctx_ref[l]);
                    cx[c * rep * d_h..(c + 1) * rep * d_h].copy_from_slice(&out);
                    drop(cx);
                    obs::span_tag(
                        obs::SpanKind::AttnJob,
                        (c / n_kv) as u64,
                        t_job,
                        l as u64,
                        (c % n_kv) as u64,
                        dispatch::active().name(),
                    );
                }));
            }
            stages.push(Stage::new(vec![3 * l], jobs));

            // --- out(l): driver-only; dep on the layer's head jobs ---
            let (ctx_ref, err_ref, hbuf_ref) = (&ctx, &err, &hbuf);
            let out_job: Job = Box::new(move |_scratch: &mut Vec<f32>| {
                    if lockm(err_ref).is_some() {
                        return;
                    }
                    let t_out = obs::start();
                    let cx = std::mem::take(&mut *lockm(&ctx_ref[l]));
                    let mut hv = lockm(hbuf_ref);
                    let res = (|| -> Result<Vec<f32>> {
                        self.stage(&format!("out_l{l}_b{bb}"))?
                            .run(&[
                                In::F32(&hv, &[bb as i64, d_model as i64]),
                                In::F32(&cx, &[bb as i64, q_dim as i64]),
                            ])?
                            .f32(0)
                    })();
                    match res {
                        Ok(newh) => *hv = newh,
                        Err(e) => {
                            drop(hv);
                            *lockm(err_ref) = Some(e);
                        }
                    }
                    obs::span(obs::SpanKind::StageOut, l as u64, t_out, l as u64, bb as u64);
                });
            stages.push(Stage::driver_only(vec![3 * l + 1], vec![out_job]));
        }

        // --- head: driver-only; dep on out(L-1) ---
        {
            let (err_ref, hbuf_ref, logits_ref) = (&err, &hbuf, &logits_slot);
            let head_job: Job = Box::new(move |_scratch: &mut Vec<f32>| {
                    if lockm(err_ref).is_some() {
                        return;
                    }
                    let t_head = obs::start();
                    let hv = lockm(hbuf_ref);
                    let res = (|| -> Result<Vec<f32>> {
                        self.stage(&format!("head_b{bb}"))?
                            .run(&[In::F32(&hv, &[bb as i64, d_model as i64])])?
                            .f32(0)
                    })();
                    drop(hv);
                    match res {
                        Ok(lg) => *lockm(logits_ref) = lg,
                        Err(e) => *lockm(err_ref) = Some(e),
                    }
                    obs::span(obs::SpanKind::StageHead, 0, t_head, n_l as u64, bb as u64);
                });
            stages.push(Stage::driver_only(vec![3 * n_l - 1], vec![head_job]));
        }

        self.pool.run_graph(stages);

        if let Some(e) = lockm(&err).take() {
            return Err(e);
        }
        Ok(std::mem::take(&mut *lockm(&logits_slot)))
    }

    /// Start a sequence from a single BOS token without a prefill executable
    /// (pure-decode mode; used by tests and the quality harness when the
    /// prompt should go through the *decode* cache path token by token).
    pub fn start_empty(&self) -> Sequence {
        let dims = &self.manifest.model;
        let caches = (0..dims.n_layers)
            .map(|_| LayerCache::new(self.cfg, dims.d_h, dims.n_kv_heads))
            .collect();
        Sequence {
            id: self.next_id.fetch_add(1, std::sync::atomic::Ordering::Relaxed),
            tokens: Vec::new(),
            caches,
            n_prefill: 0,
            last_logits: Vec::new(),
        }
    }

    /// Greedy next token from a sequence's last logits. NaN-safe: NaN logits
    /// are skipped (a NaN must never panic the scheduler), and ties resolve
    /// to the lowest index via the `total_cmp` total order.
    pub fn argmax(logits: &[f32]) -> i32 {
        logits
            .iter()
            .enumerate()
            .filter(|(_, v)| !v.is_nan())
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i as i32)
            .unwrap_or(0)
    }

    /// Log-softmax probability of `token` under `logits`. Guards empty
    /// input, out-of-range tokens, and non-finite logits (returns -inf
    /// rather than poisoning downstream NLL sums with NaN).
    pub fn log_prob(logits: &[f32], token: i32) -> f32 {
        if token < 0 || token as usize >= logits.len() {
            return f32::NEG_INFINITY;
        }
        let m = logits
            .iter()
            .filter(|v| !v.is_nan())
            .fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        if !m.is_finite() {
            return f32::NEG_INFINITY;
        }
        let lse = m
            + logits
                .iter()
                .map(|&v| if v.is_nan() { 0.0 } else { (v - m).exp() })
                .sum::<f32>()
                .ln();
        let v = logits[token as usize];
        if v.is_nan() {
            return f32::NEG_INFINITY;
        }
        v - lse
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_ignores_nan_and_survives_all_nan() {
        assert_eq!(Engine::argmax(&[0.5, f32::NAN, 2.0, 1.0]), 2);
        assert_eq!(Engine::argmax(&[f32::NAN, f32::NAN]), 0);
        assert_eq!(Engine::argmax(&[]), 0);
        assert_eq!(Engine::argmax(&[f32::NEG_INFINITY, -1.0]), 1);
    }

    #[test]
    fn log_prob_guards_bad_inputs() {
        assert_eq!(Engine::log_prob(&[], 0), f32::NEG_INFINITY);
        assert_eq!(Engine::log_prob(&[1.0, 2.0], 5), f32::NEG_INFINITY);
        assert_eq!(Engine::log_prob(&[1.0, 2.0], -1), f32::NEG_INFINITY);
        let lp = Engine::log_prob(&[1.0, f32::NAN, 2.0], 2);
        assert!(lp.is_finite() && lp < 0.0);
        assert_eq!(Engine::log_prob(&[1.0, f32::NAN, 2.0], 1), f32::NEG_INFINITY);
        assert_eq!(
            Engine::log_prob(&[f32::NAN, f32::NAN], 0),
            f32::NEG_INFINITY
        );
    }

    #[test]
    fn log_prob_matches_softmax_on_clean_input() {
        let logits = [0.1f32, 1.4, -0.7, 2.0];
        let sum: f32 = logits.iter().map(|v| v.exp()).sum();
        for (t, &v) in logits.iter().enumerate() {
            let want = (v.exp() / sum).ln();
            let got = Engine::log_prob(&logits, t as i32);
            assert!((got - want).abs() < 1e-5, "token {t}: {got} vs {want}");
        }
    }

    #[test]
    fn pipeline_mode_parses_cli_names() {
        assert_eq!(PipelineMode::parse("barrier"), Some(PipelineMode::Barrier));
        assert_eq!(PipelineMode::parse("overlap"), Some(PipelineMode::Overlap));
        assert_eq!(PipelineMode::parse("async"), None);
        assert_eq!(PipelineMode::default(), PipelineMode::Overlap);
        assert_eq!(PipelineMode::Overlap.name(), "overlap");
    }

    #[test]
    fn engine_is_shareable_with_the_pool() {
        // The overlap graph captures `&Engine` inside Send jobs (driver-only
        // stages run PJRT on the driver, but the closure type must still be
        // Send). Pin the auto-trait requirement at compile time so a future
        // non-Sync PJRT binding fails here, with this note, not deep inside
        // the graph builder: such a binding needs the driver stages to stop
        // capturing &Engine (e.g. a driver-local stage table) before the
        // vendored stand-in can be swapped out.
        fn assert_sync<T: Sync>() {}
        assert_sync::<Engine>();
    }
}
