//! The decode engine: drives the AOT-compiled model stages through PJRT and
//! owns the quantized KV cache between the QKV and output stages.
//!
//! One decode step for a batch of sequences:
//!
//! ```text
//!   embed(tokens) -> h
//!   for each layer:  qkv(h, pos) -> q,k,v       [PJRT]
//!                    cache.append(k, v)          [Rust, per seq/KV head]
//!                    ctx = attend(q)             [Rust fused kernels]
//!                    h = out(h, ctx)             [PJRT]
//!   logits = head(h)                             [PJRT]
//! ```
//!
//! Python never runs here; the executables were compiled from
//! `artifacts/*.hlo.txt` at engine start.

use crate::cache::HeadCache;
use crate::quant::MethodConfig;
use crate::runtime::executable::{In, Stage};
use crate::runtime::Manifest;
use anyhow::{Context, Result};
use std::collections::HashMap;

/// One live sequence: token history + per-layer, per-KV-head caches.
pub struct Sequence {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub caches: Vec<Vec<HeadCache>>, // [layer][kv_head]
    pub n_prefill: usize,
    pub last_logits: Vec<f32>,
    scratch: Vec<f32>,
}

impl Sequence {
    /// Total cache bytes across layers/heads (for the pool).
    pub fn cache_bytes(&self) -> usize {
        self.caches.iter().flatten().map(|c| c.bytes()).sum()
    }
    pub fn len(&self) -> usize {
        self.tokens.len()
    }
}

/// The model engine for one quantization method.
pub struct Engine {
    pub manifest: Manifest,
    pub cfg: MethodConfig,
    stages: HashMap<String, Stage>,
    next_id: std::sync::atomic::AtomicU64,
}

impl Engine {
    /// Load and compile every decode stage eagerly (prefill buckets lazily
    /// would also work, but eager keeps decode latency deterministic).
    pub fn new(manifest: Manifest, cfg: MethodConfig) -> Result<Engine> {
        let mut stages = HashMap::new();
        for (key, _) in manifest.artifacts.iter() {
            let stage = Stage::load(key, &manifest.path(key)?)?;
            stages.insert(key.clone(), stage);
        }
        Ok(Engine { manifest, cfg, stages, next_id: 0.into() })
    }

    fn stage(&self, key: &str) -> Result<&Stage> {
        self.stages.get(key).with_context(|| format!("stage '{key}' not loaded"))
    }

    /// Run prefill for a prompt; returns an initialized sequence whose
    /// caches follow Eq. (15) (sink / bulk-quantized middle / recent).
    pub fn prefill(&self, prompt: &[i32]) -> Result<Sequence> {
        let dims = &self.manifest.model;
        let bucket = self.manifest.prefill_bucket(prompt.len())?;
        let mut padded = prompt.to_vec();
        padded.resize(bucket, self.manifest.bos);
        let out = self.stage(&format!("prefill_l{bucket}"))?.run(&[In::I32(
            &padded,
            &[1, bucket as i64],
        )])?;
        let logits = out.f32(0)?; // (bucket, vocab)
        let ks = out.f32(1)?; // (n_layers, bucket, n_kv, d_h)
        let vs = out.f32(2)?;

        let n = prompt.len();
        let (n_l, n_kv, d_h) = (dims.n_layers, dims.n_kv_heads, dims.d_h);
        let mut caches = Vec::with_capacity(n_l);
        for l in 0..n_l {
            let mut heads = Vec::with_capacity(n_kv);
            for h in 0..n_kv {
                // gather this head's rows: layout (L, n_kv, d_h) per layer
                let mut k_rows = Vec::with_capacity(n * d_h);
                let mut v_rows = Vec::with_capacity(n * d_h);
                for t in 0..n {
                    let base = ((l * bucket + t) * n_kv + h) * d_h;
                    k_rows.extend_from_slice(&ks[base..base + d_h]);
                    v_rows.extend_from_slice(&vs[base..base + d_h]);
                }
                heads.push(HeadCache::from_prefill(self.cfg, d_h, &k_rows, &v_rows));
            }
            caches.push(heads);
        }
        let vstart = (n - 1) * dims.vocab;
        Ok(Sequence {
            id: self.next_id.fetch_add(1, std::sync::atomic::Ordering::Relaxed),
            tokens: prompt.to_vec(),
            caches,
            n_prefill: n,
            last_logits: logits[vstart..vstart + dims.vocab].to_vec(),
            scratch: Vec::new(),
        })
    }

    /// One batched decode step: appends `next_tokens[i]` to each sequence
    /// and computes its logits. Sequences may have different lengths.
    pub fn decode_step(&self, seqs: &mut [&mut Sequence], next_tokens: &[i32]) -> Result<()> {
        assert_eq!(seqs.len(), next_tokens.len());
        let dims = self.manifest.model.clone();
        let nb = seqs.len();
        let bb = self.manifest.decode_batch(nb)?; // padded batch bucket

        let mut tokens = vec![self.manifest.bos; bb];
        let mut positions = vec![0i32; bb];
        for (i, s) in seqs.iter().enumerate() {
            tokens[i] = next_tokens[i];
            positions[i] = s.tokens.len() as i32; // position of the new token
        }

        let mut h = self
            .stage(&format!("embed_b{bb}"))?
            .run(&[In::I32(&tokens, &[bb as i64])])?
            .f32(0)?; // (bb, d_model)

        let rep = dims.heads_per_kv();
        let (d_h, q_dim) = (dims.d_h, dims.q_dim());
        for l in 0..dims.n_layers {
            let out = self.stage(&format!("qkv_l{l}_b{bb}"))?.run(&[
                In::F32(&h, &[bb as i64, dims.d_model as i64]),
                In::I32(&positions, &[bb as i64]),
            ])?;
            let q = out.f32(0)?; // (bb, n_q, d_h)
            let k = out.f32(1)?; // (bb, n_kv, d_h)
            let v = out.f32(2)?;

            // Rust-owned quantized attention per sequence / head.
            let mut ctx = vec![0f32; bb * q_dim];
            for (i, s) in seqs.iter_mut().enumerate() {
                for hk in 0..dims.n_kv_heads {
                    let kb = (i * dims.n_kv_heads + hk) * d_h;
                    let cache = &mut s.caches[l][hk];
                    cache.append(&k[kb..kb + d_h], &v[kb..kb + d_h]);
                    for r in 0..rep {
                        let hq = hk * rep + r;
                        let qb = (i * dims.n_q_heads + hq) * d_h;
                        let ob = i * q_dim + hq * d_h;
                        let mut scratch = std::mem::take(&mut s.scratch);
                        cache.attend(
                            &q[qb..qb + d_h],
                            &mut ctx[ob..ob + d_h],
                            &mut scratch,
                        );
                        s.scratch = scratch;
                    }
                }
            }

            h = self
                .stage(&format!("out_l{l}_b{bb}"))?
                .run(&[
                    In::F32(&h, &[bb as i64, dims.d_model as i64]),
                    In::F32(&ctx, &[bb as i64, q_dim as i64]),
                ])?
                .f32(0)?;
        }

        let logits = self
            .stage(&format!("head_b{bb}"))?
            .run(&[In::F32(&h, &[bb as i64, dims.d_model as i64])])?
            .f32(0)?; // (bb, vocab)

        for (i, s) in seqs.iter_mut().enumerate() {
            s.tokens.push(next_tokens[i]);
            let vb = i * dims.vocab;
            s.last_logits = logits[vb..vb + dims.vocab].to_vec();
        }
        Ok(())
    }

    /// Start a sequence from a single BOS token without a prefill executable
    /// (pure-decode mode; used by tests and the quality harness when the
    /// prompt should go through the *decode* cache path token by token).
    pub fn start_empty(&self) -> Sequence {
        let dims = &self.manifest.model;
        let caches = (0..dims.n_layers)
            .map(|_| (0..dims.n_kv_heads).map(|_| HeadCache::new(self.cfg, dims.d_h)).collect())
            .collect();
        Sequence {
            id: self.next_id.fetch_add(1, std::sync::atomic::Ordering::Relaxed),
            tokens: Vec::new(),
            caches,
            n_prefill: 0,
            last_logits: Vec::new(),
            scratch: Vec::new(),
        }
    }

    /// Greedy next token from a sequence's last logits.
    pub fn argmax(logits: &[f32]) -> i32 {
        logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i as i32)
            .unwrap_or(0)
    }

    /// Log-softmax probability of `token` under `logits`.
    pub fn log_prob(logits: &[f32], token: i32) -> f32 {
        let m = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let lse = m + logits.iter().map(|&v| (v - m).exp()).sum::<f32>().ln();
        logits[token as usize] - lse
    }
}
