//! The decode engine: drives the AOT-compiled model stages through PJRT and
//! owns the quantized KV cache between the QKV and output stages.
//!
//! One decode step for a batch of sequences:
//!
//! ```text
//!   embed(tokens) -> h
//!   for each layer:  qkv(h, pos) -> q,k,v       [PJRT]
//!                    cache.append(k, v)          [Rust, driver thread]
//!                    ctx = attend(q)             [Rust fused kernels,
//!                                                 worker pool fan-out]
//!                    h = out(h, ctx)             [PJRT]
//!   logits = head(h)                             [PJRT]
//! ```
//!
//! PJRT stages stay on the driver thread (the PJRT client is thread-local);
//! the attention fan-out between them is where decode spends its time once
//! dequantization is cheap (§4.4), so it runs on the worker pool: each
//! (sequence, KV head) pair is one job that reads its `HeadCache` immutably
//! and owns a disjoint `rep * d_h` slice of the context buffer. Jobs carry
//! no cross-job reductions and their internal FP order matches the serial
//! loop, so completions are byte-identical for any worker count, and
//! `workers = 1` executes inline with zero pool overhead.
//!
//! Python never runs here; the executables were compiled from
//! `artifacts/*.hlo.txt` at engine start.

use crate::cache::{attention_fanout, HeadCache};
use crate::quant::MethodConfig;
use crate::runtime::executable::{In, Stage};
use crate::runtime::Manifest;
use crate::util::threadpool::ThreadPool;
use anyhow::{Context, Result};
use std::collections::HashMap;

/// One live sequence: token history + per-layer, per-KV-head caches.
/// Attention scratch lives with the pool workers, not the sequence, so
/// disjoint heads of the same sequence can attend concurrently.
pub struct Sequence {
    /// Engine-assigned sequence id.
    pub id: u64,
    /// Full token history (prompt + generated).
    pub tokens: Vec<i32>,
    /// Per-layer, per-KV-head quantized caches, indexed `[layer][kv_head]`.
    pub caches: Vec<Vec<HeadCache>>, // [layer][kv_head]
    /// Tokens that went through prefill (the prompt length).
    pub n_prefill: usize,
    /// Logits of the most recent step, for sampling the next token.
    pub last_logits: Vec<f32>,
}

impl Sequence {
    /// Total cache bytes across layers/heads (for the pool).
    pub fn cache_bytes(&self) -> usize {
        self.caches.iter().flatten().map(|c| c.bytes()).sum()
    }
    /// Total tokens in the sequence.
    pub fn len(&self) -> usize {
        self.tokens.len()
    }
    /// True before any token has been appended.
    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }
}

/// The model engine for one quantization method.
pub struct Engine {
    /// The loaded artifact manifest (model dims, stages, charset).
    pub manifest: Manifest,
    /// The quantization method configuration for every cache.
    pub cfg: MethodConfig,
    stages: HashMap<String, Stage>,
    pool: ThreadPool,
    next_id: std::sync::atomic::AtomicU64,
}

impl Engine {
    /// Load and compile every decode stage eagerly (prefill buckets lazily
    /// would also work, but eager keeps decode latency deterministic).
    /// Starts with one worker (serial attention); see [`Engine::set_workers`].
    pub fn new(manifest: Manifest, cfg: MethodConfig) -> Result<Engine> {
        let mut stages = HashMap::new();
        for (key, _) in manifest.artifacts.iter() {
            let stage = Stage::load(key, &manifest.path(key)?)?;
            stages.insert(key.clone(), stage);
        }
        Ok(Engine {
            manifest,
            cfg,
            stages,
            pool: ThreadPool::new(1),
            next_id: 0.into(),
        })
    }

    /// Resize the attention worker pool to `workers` total threads (the
    /// driver counts as one; 1 = the serial baseline).
    pub fn set_workers(&mut self, workers: usize) {
        if workers.max(1) != self.pool.workers() {
            self.pool = ThreadPool::new(workers);
        }
    }

    /// Current attention worker-pool size.
    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    fn stage(&self, key: &str) -> Result<&Stage> {
        self.stages.get(key).with_context(|| format!("stage '{key}' not loaded"))
    }

    /// Run prefill for a prompt; returns an initialized sequence whose
    /// caches follow Eq. (15) (sink / bulk-quantized middle / recent).
    pub fn prefill(&self, prompt: &[i32]) -> Result<Sequence> {
        let dims = &self.manifest.model;
        let bucket = self.manifest.prefill_bucket(prompt.len())?;
        let mut padded = prompt.to_vec();
        padded.resize(bucket, self.manifest.bos);
        let out = self.stage(&format!("prefill_l{bucket}"))?.run(&[In::I32(
            &padded,
            &[1, bucket as i64],
        )])?;
        let logits = out.f32(0)?; // (bucket, vocab)
        let ks = out.f32(1)?; // (n_layers, bucket, n_kv, d_h)
        let vs = out.f32(2)?;

        let n = prompt.len();
        let (n_l, n_kv, d_h) = (dims.n_layers, dims.n_kv_heads, dims.d_h);
        // Fan the bulk quantization out across the worker pool: one job per
        // (layer, KV head), built by the shared `cache::prefill_fanout` so
        // the engine and the determinism test share one job shape. Each job
        // gathers its head's strided token-major rows out of the shared
        // prefill tensors *inside* the job (layout (L, n_kv, d_h) per
        // layer), so peak extra memory is one head copy per in-flight
        // worker, not a duplicate of the whole prompt KV. Quantization
        // dominates prefill cache setup and each head is independent, so
        // this closes the "prefill is still serial on the driver" ROADMAP
        // item with byte-identical results at any worker count.
        let (ks_ref, vs_ref): (&[f32], &[f32]) = (&ks, &vs);
        let gathers: Vec<_> = (0..n_l * n_kv)
            .map(|idx| {
                let (l, h) = (idx / n_kv, idx % n_kv);
                move || {
                    let mut k_rows = Vec::with_capacity(n * d_h);
                    let mut v_rows = Vec::with_capacity(n * d_h);
                    for t in 0..n {
                        let base = ((l * bucket + t) * n_kv + h) * d_h;
                        k_rows.extend_from_slice(&ks_ref[base..base + d_h]);
                        v_rows.extend_from_slice(&vs_ref[base..base + d_h]);
                    }
                    (k_rows, v_rows)
                }
            })
            .collect();
        let mut slots: Vec<Option<HeadCache>> = (0..n_l * n_kv).map(|_| None).collect();
        self.pool.run(crate::cache::prefill_fanout(self.cfg, d_h, gathers, &mut slots));
        let mut caches = Vec::with_capacity(n_l);
        let mut slot_iter = slots.into_iter();
        for _ in 0..n_l {
            let heads: Vec<HeadCache> = slot_iter
                .by_ref()
                .take(n_kv)
                .map(|s| s.expect("prefill job filled its slot"))
                .collect();
            caches.push(heads);
        }
        let vstart = (n - 1) * dims.vocab;
        Ok(Sequence {
            id: self.next_id.fetch_add(1, std::sync::atomic::Ordering::Relaxed),
            tokens: prompt.to_vec(),
            caches,
            n_prefill: n,
            last_logits: logits[vstart..vstart + dims.vocab].to_vec(),
        })
    }

    /// One batched decode step: appends `next_tokens[i]` to each sequence
    /// and computes its logits. Sequences may have different lengths.
    pub fn decode_step(&self, seqs: &mut [&mut Sequence], next_tokens: &[i32]) -> Result<()> {
        assert_eq!(seqs.len(), next_tokens.len());
        let dims = self.manifest.model.clone();
        let nb = seqs.len();
        let bb = self.manifest.decode_batch(nb)?; // padded batch bucket

        let mut tokens = vec![self.manifest.bos; bb];
        let mut positions = vec![0i32; bb];
        for (i, s) in seqs.iter().enumerate() {
            tokens[i] = next_tokens[i];
            positions[i] = s.tokens.len() as i32; // position of the new token
        }

        let mut h = self
            .stage(&format!("embed_b{bb}"))?
            .run(&[In::I32(&tokens, &[bb as i64])])?
            .f32(0)?; // (bb, d_model)

        let rep = dims.heads_per_kv();
        let (d_h, q_dim) = (dims.d_h, dims.q_dim());
        let n_kv = dims.n_kv_heads;
        for l in 0..dims.n_layers {
            let out = self.stage(&format!("qkv_l{l}_b{bb}"))?.run(&[
                In::F32(&h, &[bb as i64, dims.d_model as i64]),
                In::I32(&positions, &[bb as i64]),
            ])?;
            let q = out.f32(0)?; // (bb, n_q, d_h)
            let k = out.f32(1)?; // (bb, n_kv, d_h)
            let v = out.f32(2)?;

            // Append this step's K/V on the driver — the only cache mutation.
            for (i, s) in seqs.iter_mut().enumerate() {
                for hk in 0..n_kv {
                    let kb = (i * n_kv + hk) * d_h;
                    s.caches[l][hk].append(&k[kb..kb + d_h], &v[kb..kb + d_h]);
                }
            }

            // Fan the attention out across the pool: one job per
            // (sequence, KV head), each owning the contiguous rep*d_h slice
            // of ctx its query heads write (see `cache::attention_fanout`
            // for the shared job shape). Slices are disjoint by
            // construction, so write-back is deterministic and matches the
            // serial loop exactly.
            let mut ctx = vec![0f32; bb * q_dim];
            {
                let heads = seqs.iter().flat_map(|s| s.caches[l].iter());
                self.pool.run(attention_fanout(heads, &q, &mut ctx, rep, d_h));
            }

            h = self
                .stage(&format!("out_l{l}_b{bb}"))?
                .run(&[
                    In::F32(&h, &[bb as i64, dims.d_model as i64]),
                    In::F32(&ctx, &[bb as i64, q_dim as i64]),
                ])?
                .f32(0)?;
        }

        let logits = self
            .stage(&format!("head_b{bb}"))?
            .run(&[In::F32(&h, &[bb as i64, dims.d_model as i64])])?
            .f32(0)?; // (bb, vocab)

        for (i, s) in seqs.iter_mut().enumerate() {
            s.tokens.push(next_tokens[i]);
            let vb = i * dims.vocab;
            s.last_logits = logits[vb..vb + dims.vocab].to_vec();
        }
        Ok(())
    }

    /// Start a sequence from a single BOS token without a prefill executable
    /// (pure-decode mode; used by tests and the quality harness when the
    /// prompt should go through the *decode* cache path token by token).
    pub fn start_empty(&self) -> Sequence {
        let dims = &self.manifest.model;
        let caches = (0..dims.n_layers)
            .map(|_| (0..dims.n_kv_heads).map(|_| HeadCache::new(self.cfg, dims.d_h)).collect())
            .collect();
        Sequence {
            id: self.next_id.fetch_add(1, std::sync::atomic::Ordering::Relaxed),
            tokens: Vec::new(),
            caches,
            n_prefill: 0,
            last_logits: Vec::new(),
        }
    }

    /// Greedy next token from a sequence's last logits. NaN-safe: NaN logits
    /// are skipped (a NaN must never panic the scheduler), and ties resolve
    /// to the lowest index via the `total_cmp` total order.
    pub fn argmax(logits: &[f32]) -> i32 {
        logits
            .iter()
            .enumerate()
            .filter(|(_, v)| !v.is_nan())
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i as i32)
            .unwrap_or(0)
    }

    /// Log-softmax probability of `token` under `logits`. Guards empty
    /// input, out-of-range tokens, and non-finite logits (returns -inf
    /// rather than poisoning downstream NLL sums with NaN).
    pub fn log_prob(logits: &[f32], token: i32) -> f32 {
        if token < 0 || token as usize >= logits.len() {
            return f32::NEG_INFINITY;
        }
        let m = logits
            .iter()
            .filter(|v| !v.is_nan())
            .fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        if !m.is_finite() {
            return f32::NEG_INFINITY;
        }
        let lse = m
            + logits
                .iter()
                .map(|&v| if v.is_nan() { 0.0 } else { (v - m).exp() })
                .sum::<f32>()
                .ln();
        let v = logits[token as usize];
        if v.is_nan() {
            return f32::NEG_INFINITY;
        }
        v - lse
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_ignores_nan_and_survives_all_nan() {
        assert_eq!(Engine::argmax(&[0.5, f32::NAN, 2.0, 1.0]), 2);
        assert_eq!(Engine::argmax(&[f32::NAN, f32::NAN]), 0);
        assert_eq!(Engine::argmax(&[]), 0);
        assert_eq!(Engine::argmax(&[f32::NEG_INFINITY, -1.0]), 1);
    }

    #[test]
    fn log_prob_guards_bad_inputs() {
        assert_eq!(Engine::log_prob(&[], 0), f32::NEG_INFINITY);
        assert_eq!(Engine::log_prob(&[1.0, 2.0], 5), f32::NEG_INFINITY);
        assert_eq!(Engine::log_prob(&[1.0, 2.0], -1), f32::NEG_INFINITY);
        let lp = Engine::log_prob(&[1.0, f32::NAN, 2.0], 2);
        assert!(lp.is_finite() && lp < 0.0);
        assert_eq!(Engine::log_prob(&[1.0, f32::NAN, 2.0], 1), f32::NEG_INFINITY);
        assert_eq!(
            Engine::log_prob(&[f32::NAN, f32::NAN], 0),
            f32::NEG_INFINITY
        );
    }

    #[test]
    fn log_prob_matches_softmax_on_clean_input() {
        let logits = [0.1f32, 1.4, -0.7, 2.0];
        let sum: f32 = logits.iter().map(|v| v.exp()).sum();
        for (t, &v) in logits.iter().enumerate() {
            let want = (v.exp() / sum).ln();
            let got = Engine::log_prob(&logits, t as i32);
            assert!((got - want).abs() < 1e-5, "token {t}: {got} vs {want}");
        }
    }
}
