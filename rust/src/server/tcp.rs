//! Thread-based TCP serving front-end over the scheduler.

use crate::coordinator::request::Request;
use crate::coordinator::Scheduler;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

struct Inbound {
    req: Request,
    conn: TcpStream,
}

/// Serve until `stop` flips true (tests) or forever (CLI). Binds `addr`,
/// returns the bound address via the callback before blocking.
pub fn serve(
    mut sched: Scheduler,
    addr: &str,
    stop: Arc<AtomicBool>,
    on_bound: impl FnOnce(std::net::SocketAddr),
) -> Result<()> {
    let listener = TcpListener::bind(addr).context("bind")?;
    listener.set_nonblocking(true)?;
    on_bound(listener.local_addr()?);
    let (tx, rx) = mpsc::channel::<Inbound>();
    let next_id = Arc::new(AtomicU64::new(1));

    // Acceptor + reader threads.
    let stop_acc = stop.clone();
    let acceptor = std::thread::spawn(move || {
        while !stop_acc.load(Ordering::Relaxed) {
            match listener.accept() {
                Ok((conn, _)) => {
                    let tx = tx.clone();
                    let next_id = next_id.clone();
                    std::thread::spawn(move || {
                        let reader = BufReader::new(conn.try_clone().unwrap());
                        for line in reader.lines().map_while(|l| l.ok()) {
                            if line.trim().is_empty() {
                                continue;
                            }
                            if let Ok(j) = Json::parse(&line) {
                                let req = Request {
                                    id: next_id.fetch_add(1, Ordering::Relaxed),
                                    prompt: j.get("prompt").as_str().unwrap_or("").to_string(),
                                    max_new_tokens: j
                                        .get("max_new_tokens")
                                        .as_usize()
                                        .unwrap_or(32),
                                    temperature: j.get("temperature").as_f64().map(|t| t as f32),
                                    arrived: Instant::now(),
                                };
                                let _ = tx.send(Inbound {
                                    req,
                                    conn: conn.try_clone().unwrap(),
                                });
                            }
                        }
                    });
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(_) => break,
            }
        }
    });

    // Scheduler loop (owns the engine; single worker).
    let mut conns: std::collections::HashMap<u64, TcpStream> = Default::default();
    while !stop.load(Ordering::Relaxed) {
        // ingest
        while let Ok(inb) = rx.try_recv() {
            conns.insert(inb.req.id, inb.conn);
            sched.submit(inb.req);
        }
        let worked = sched.tick()?;
        // flush completions
        for c in sched.done.drain(..) {
            if let Some(mut conn) = conns.remove(&c.id) {
                let line = Json::obj(vec![
                    ("id", Json::Num(c.id as f64)),
                    ("text", Json::str(&c.text)),
                    ("n_generated", Json::Num(c.n_generated as f64)),
                    ("ttft_us", Json::Num(c.ttft_us as f64)),
                    ("total_us", Json::Num(c.total_us as f64)),
                ])
                .dump();
                let _ = writeln!(conn, "{line}");
            }
        }
        if !worked {
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
    }
    let _ = acceptor.join();
    Ok(())
}

/// Minimal blocking client for examples and tests.
pub struct Client {
    conn: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Client> {
        let conn = TcpStream::connect(addr)?;
        let reader = BufReader::new(conn.try_clone()?);
        Ok(Client { conn, reader })
    }

    /// Send one generation request and block for its completion.
    pub fn generate(&mut self, prompt: &str, max_new_tokens: usize) -> Result<Json> {
        let req = Json::obj(vec![
            ("prompt", Json::str(prompt)),
            ("max_new_tokens", Json::Num(max_new_tokens as f64)),
        ]);
        writeln!(self.conn, "{}", req.dump())?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Json::parse(&line).map_err(|e| anyhow::anyhow!("bad response: {e}"))
    }
}
