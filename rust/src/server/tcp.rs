//! Staged TCP serving front end over the scheduler.
//!
//! Dataflow (see `ARCHITECTURE.md` for the full diagram):
//!
//! ```text
//! listener ──round-robin──▶ IO worker 0..N ──SPSC──▶ driver (scheduler)
//!                              ▲                        │
//!                              └───────SPSC─────────────┘
//! admin listener ──▶ admin conns (read-only stats snapshot)
//! ```
//!
//! One listener thread accepts data-plane sockets and deals them
//! round-robin to N IO workers ([`super::io_worker`]) that poll
//! non-blocking sockets and parse the protocol incrementally
//! ([`super::conn`]); each worker exchanges work with the driver over a
//! bounded SPSC queue pair ([`crate::util::spsc`]). The driver — this
//! module — owns the [`Scheduler`]: it assigns request ids, advances the
//! virtual clock from wall time, ticks, streams per-token output for
//! `"stream": true` requests, routes completion lines back to the owning
//! worker, and cancels everything a disconnected client still had pending
//! ([`Scheduler::cancel`] — reservation, warm-tier residency, and prefix
//! pins all release mid-decode). A second admin listener
//! ([`super::admin`]) exports live counters without ever touching the data
//! plane.
//!
//! The driver actually owns a [`Fleet`] of scheduler replicas:
//! [`serve_with`] wraps its single scheduler in a one-replica fleet, and
//! [`serve_fleet`] serves N data-parallel replicas behind a
//! [`crate::coordinator::fleet::RouterPolicy`]. Either way there is exactly
//! one driver thread — placement is a routing decision, not a concurrency
//! one — and the admin snapshot sums replica counters under the same
//! names a single-replica server exports, plus per-replica gauges.

use crate::coordinator::fleet::{Fleet, RoundRobin};
use crate::coordinator::request::{Priority, Request, StepMetrics};
use crate::coordinator::Scheduler;
use crate::server::admin::{admin_loop, SharedSnapshot};
use crate::server::conn::read_line_capped;
use crate::server::io_worker::{io_worker_loop, Outbound, ToDriver};
use crate::util::json::Json;
use crate::util::spsc::{self, Consumer, Producer};
use crate::util::stats::LatencyHistogram;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Staged front-end shape knobs.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Number of IO-worker threads polling data-plane sockets (≥ 1).
    pub io_workers: usize,
    /// Bind address for the admin/metrics listener; `None` disables the
    /// admin plane.
    pub admin_addr: Option<String>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { io_workers: 2, admin_addr: None }
    }
}

/// Addresses the server actually bound, reported through `serve_with`'s
/// callback before the driver loop starts.
#[derive(Debug, Clone, Copy)]
pub struct Bound {
    /// The data-plane address.
    pub data: SocketAddr,
    /// The admin-plane address, when configured.
    pub admin: Option<SocketAddr>,
}

/// Per-request routing state held by the driver while the request is
/// pending.
struct Route {
    worker: usize,
    conn_id: u64,
    replica: usize,
    stream: bool,
    tag: Option<String>,
}

/// Queue capacities. Small enough to bound memory per stage, large enough
/// that a tick's worth of completions never blocks the driver in practice.
const INTAKE_CAP: usize = 64;
const DRIVER_CAP: usize = 512;

/// Serve until `stop` flips true (tests) or forever (CLI), with the default
/// front-end shape (2 IO workers, no admin plane). Binds `addr`, reports
/// the bound address via the callback before blocking. Kept as the
/// compatibility entry point; [`serve_with`] exposes the staged knobs.
pub fn serve(
    sched: Scheduler,
    addr: &str,
    stop: Arc<AtomicBool>,
    on_bound: impl FnOnce(SocketAddr),
) -> Result<()> {
    serve_with(sched, addr, ServerConfig::default(), stop, |b| on_bound(b.data))
}

/// Serve with an explicit front-end shape. Binds the data listener at
/// `addr` (and the admin listener at `cfg.admin_addr`, if set), reports the
/// bound addresses via `on_bound`, then runs the driver loop on the calling
/// thread until `stop` flips true. Every stage thread is joined before
/// returning. Internally a one-replica [`serve_fleet`] — round-robin over
/// one replica always places on replica 0.
pub fn serve_with(
    sched: Scheduler,
    addr: &str,
    cfg: ServerConfig,
    stop: Arc<AtomicBool>,
    on_bound: impl FnOnce(Bound),
) -> Result<()> {
    serve_fleet(
        Fleet::new(vec![sched], Box::new(RoundRobin::default())),
        addr,
        cfg,
        stop,
        on_bound,
    )
}

/// Serve a data-parallel [`Fleet`]: each incoming request is placed on one
/// replica by the fleet's router, runs there end to end, and streams back
/// through the same staged front end. One driver thread ticks every
/// replica each iteration; all replicas drain their spans into one shared
/// flight recorder (replica-tagged), so the admin `trace` command sees the
/// whole fleet.
pub fn serve_fleet(
    mut fleet: Fleet,
    addr: &str,
    cfg: ServerConfig,
    stop: Arc<AtomicBool>,
    on_bound: impl FnOnce(Bound),
) -> Result<()> {
    let n_workers = cfg.io_workers.max(1);
    let listener = TcpListener::bind(addr).context("bind")?;
    listener.set_nonblocking(true)?;
    let admin_listener = match &cfg.admin_addr {
        Some(a) => {
            let l = TcpListener::bind(a).context("bind admin")?;
            l.set_nonblocking(true)?;
            Some(l)
        }
        None => None,
    };
    on_bound(Bound {
        data: listener.local_addr()?,
        admin: match &admin_listener {
            Some(l) => Some(l.local_addr()?),
            None => None,
        },
    });

    // One SPSC pair per worker (worker→driver, driver→worker) plus a
    // listener→worker intake queue. Each queue has exactly one producer
    // thread and one consumer thread, which is what makes SPSC legal here.
    let mut intake_tx: Vec<Producer<(u64, TcpStream)>> = Vec::new();
    let mut from_workers: Vec<Consumer<ToDriver>> = Vec::new();
    let mut to_workers: Vec<Producer<Outbound>> = Vec::new();
    let mut conn_gauges: Vec<Arc<AtomicUsize>> = Vec::new();
    let mut worker_handles = Vec::new();
    for _ in 0..n_workers {
        let (itx, irx) = spsc::channel::<(u64, TcpStream)>(INTAKE_CAP);
        let (dtx, drx) = spsc::channel::<ToDriver>(DRIVER_CAP);
        let (wtx, wrx) = spsc::channel::<Outbound>(DRIVER_CAP);
        intake_tx.push(itx);
        from_workers.push(drx);
        to_workers.push(wtx);
        let gauge = Arc::new(AtomicUsize::new(0));
        conn_gauges.push(gauge.clone());
        let stop_w = stop.clone();
        worker_handles
            .push(std::thread::spawn(move || io_worker_loop(irx, dtx, wrx, stop_w, gauge)));
    }

    // Listener thread: accept and deal out connections round-robin.
    let stop_acc = stop.clone();
    let acceptor = std::thread::spawn(move || {
        let mut next_conn = 1u64;
        let mut turn = 0usize;
        while !stop_acc.load(Ordering::Relaxed) {
            match listener.accept() {
                Ok((conn, _)) => {
                    let mut msg = (next_conn, conn);
                    next_conn += 1;
                    loop {
                        match intake_tx[turn].try_push(msg) {
                            Ok(()) => break,
                            Err(back) => {
                                if stop_acc.load(Ordering::Relaxed) {
                                    return;
                                }
                                msg = back;
                                std::thread::yield_now();
                            }
                        }
                    }
                    turn = (turn + 1) % intake_tx.len();
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(_) => break,
            }
        }
    });

    // Admin plane: its connections read the driver-refreshed snapshot, and
    // `metrics`/`trace` additionally read the fleet's flight recorder —
    // `Fleet::new` pointed every replica at one shared recorder, so replica
    // 0's handle sees the whole fleet (the driver only ever try-locks it,
    // so a slow admin read delays observability, never decoding).
    let snapshot: SharedSnapshot = Arc::new(Mutex::new(Vec::new()));
    let admin_handle = admin_listener.map(|l| {
        let snap = snapshot.clone();
        let recorder = fleet.replica(0).obs.clone();
        let stop_a = stop.clone();
        std::thread::spawn(move || admin_loop(l, snap, recorder, stop_a))
    });

    // Driver loop (owns every replica's engine; decode attention fans out
    // over each engine's own worker pool). Replica virtual clocks are
    // advanced from wall-clock elapsed time so request deadlines expire in
    // live serving exactly as they would in a replay.
    for i in 0..fleet.n() {
        fleet.replica_mut(i).record_progress(true);
    }
    let started = Instant::now();
    let mut routes: HashMap<u64, Route> = HashMap::new();
    let mut next_req = 1u64;
    let mut ttft_hist = LatencyHistogram::new();
    let mut e2e_hist = LatencyHistogram::new();
    let mut pending: Vec<(usize, ToDriver)> = Vec::new();
    let mut stats_generation = 0u64;
    while !stop.load(Ordering::Relaxed) {
        fleet.set_now(started.elapsed().as_micros() as u64);
        let mut busy = false;

        // Ingest: messages parked by a full outbound queue first, then the
        // live worker queues, in worker order (deterministic for one
        // worker; arrival-interleaved for several, like any real server).
        for (w, msg) in std::mem::take(&mut pending) {
            busy = true;
            handle_msg(&mut fleet, &mut routes, &mut next_req, w, msg);
        }
        for w in 0..n_workers {
            while let Some(msg) = from_workers[w].try_pop() {
                busy = true;
                handle_msg(&mut fleet, &mut routes, &mut next_req, w, msg);
            }
        }

        busy |= fleet.tick()? > 0;

        // Stream per-token lines for requests that opted in.
        for i in 0..fleet.n() {
            let progress = fleet.replica_mut(i).take_progress();
            for (id, tok) in progress {
                let Some(r) = routes.get(&id) else { continue };
                if !r.stream {
                    continue;
                }
                let text = fleet.replica(i).engine.manifest.decode_text(&[tok]);
                let mut fields =
                    vec![("id", Json::Num(id as f64)), ("token", Json::str(&text))];
                if let Some(tag) = &r.tag {
                    fields.push(("tag", Json::str(tag)));
                }
                let (worker, conn_id) = (r.worker, r.conn_id);
                send_to_worker(
                    &mut to_workers,
                    &mut from_workers,
                    &mut pending,
                    &stop,
                    worker,
                    Outbound { conn_id, line: Json::obj(fields).dump() },
                );
            }
        }

        // Flush completions (including failed ones, which carry `error`).
        let done: Vec<_> = fleet.drain_done();
        for c in done {
            let Some(r) = routes.remove(&c.id) else { continue };
            if c.error.is_none() {
                ttft_hist.record(c.ttft_us);
                e2e_hist.record(c.total_us);
            }
            let mut fields = vec![
                ("id", Json::Num(c.id as f64)),
                ("text", Json::str(&c.text)),
                ("n_generated", Json::Num(c.n_generated as f64)),
                ("ttft_us", Json::Num(c.ttft_us as f64)),
                ("total_us", Json::Num(c.total_us as f64)),
            ];
            if let Some(tag) = &r.tag {
                fields.push(("tag", Json::str(tag)));
            }
            if let Some(err) = &c.error {
                fields.push(("error", Json::str(err)));
            }
            send_to_worker(
                &mut to_workers,
                &mut from_workers,
                &mut pending,
                &stop,
                r.worker,
                Outbound { conn_id: r.conn_id, line: Json::obj(fields).dump() },
            );
        }

        // Refresh the admin snapshot (cheap: a few dozen counters).
        {
            stats_generation += 1;
            let mut snap = snapshot.lock().unwrap_or_else(|e| e.into_inner());
            *snap = build_snapshot(
                &fleet,
                &ttft_hist,
                &e2e_hist,
                started,
                &conn_gauges,
                stats_generation,
            );
        }

        if !busy {
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
    }

    let _ = acceptor.join();
    for h in worker_handles {
        let _ = h.join();
    }
    if let Some(h) = admin_handle {
        let _ = h.join();
    }
    Ok(())
}

/// Apply one worker message to the fleet: assign an id, route, and submit,
/// or cancel everything a vanished connection still had pending (on
/// whichever replica each request was placed).
fn handle_msg(
    fleet: &mut Fleet,
    routes: &mut HashMap<u64, Route>,
    next_req: &mut u64,
    worker: usize,
    msg: ToDriver,
) {
    match msg {
        ToDriver::Submit { conn_id, spec } => {
            let id = *next_req;
            *next_req += 1;
            let mut req = Request::new(id, spec.prompt, spec.max_new_tokens);
            req.temperature = spec.temperature;
            req.priority = spec.priority;
            req.deadline_us = spec.deadline_us;
            req.prefix_len = spec.prefix_len;
            let (stream, tag) = (spec.stream, spec.tag);
            let replica = fleet.submit(req);
            routes.insert(id, Route { worker, conn_id, replica, stream, tag });
        }
        ToDriver::Disconnect { conn_id } => {
            let doomed: Vec<(u64, usize)> = routes
                .iter()
                .filter(|(_, r)| r.worker == worker && r.conn_id == conn_id)
                .map(|(&id, r)| (id, r.replica))
                .collect();
            for (id, replica) in doomed {
                fleet.replica_mut(replica).cancel(id);
                routes.remove(&id);
            }
        }
    }
}

/// Push a response line to a worker, spinning on a full queue. While
/// spinning, keep draining the worker→driver queues into `pending` — the
/// workers spin-push toward us the same way, and someone has to keep
/// consuming for either side to make progress. Parked messages are replayed
/// at the top of the next driver iteration.
fn send_to_worker(
    to_workers: &mut [Producer<Outbound>],
    from_workers: &mut [Consumer<ToDriver>],
    pending: &mut Vec<(usize, ToDriver)>,
    stop: &AtomicBool,
    worker: usize,
    msg: Outbound,
) {
    let mut msg = msg;
    loop {
        match to_workers[worker].try_push(msg) {
            Ok(()) => return,
            Err(back) => {
                if stop.load(Ordering::Relaxed) {
                    return;
                }
                msg = back;
                for (w, rx) in from_workers.iter_mut().enumerate() {
                    while let Some(m) = rx.try_pop() {
                        pending.push((w, m));
                    }
                }
                std::thread::yield_now();
            }
        }
    }
}

/// Assemble the admin `stats` snapshot: scheduler step counters, cache-pool
/// occupancy, warm-tier and prefix-store counters, and live latency
/// percentiles. Every value is a u64; counters are monotonic, gauges (pool
/// bytes, residents, pins) are instantaneous. The layout is append-only:
/// existing names never change meaning or order, new fields only go on the
/// end (scrapers index by name, goldens diff by prefix).
///
/// Scheduler-level counters are *summed across replicas* under the exact
/// names a single-replica server has always exported, so scrapers don't
/// care how many replicas sit behind the address; the appended fleet block
/// (`fleet_replicas`, per-replica `replica{i}_pending` gauges, migration
/// counters) is where replica structure shows.
fn build_snapshot(
    fleet: &Fleet,
    ttft: &LatencyHistogram,
    e2e: &LatencyHistogram,
    started: Instant,
    conn_gauges: &[Arc<AtomicUsize>],
    generation: u64,
) -> Vec<(String, u64)> {
    let replicas = fleet.replicas();
    let sum = |f: fn(&Scheduler) -> u64| -> u64 { replicas.iter().map(f).sum() };
    let mut m = StepMetrics::default();
    for s in replicas {
        m.absorb(&s.metrics);
    }
    let mut out: Vec<(String, u64)> = Vec::with_capacity(64 + replicas.len());
    let mut push = |name: &str, v: u64| out.push((name.to_string(), v));
    push("uptime_us", started.elapsed().as_micros() as u64);
    push("pending", sum(|s| s.pending() as u64));
    // StepMetrics (monotonic).
    push("prefill_tokens", m.prefill_tokens);
    push("decode_steps", m.decode_steps);
    push("batched_seqs", m.batched_seqs);
    push("preemptions", m.preemptions);
    push("attn_jobs", m.attn_jobs);
    push("stale_reservations", m.stale_reservations);
    push("rejected", m.rejected);
    push("expired", m.expired);
    push("cancelled", m.cancelled);
    push("offloads", m.offloads);
    push("offload_bytes", m.offload_bytes);
    push("restores", m.restores);
    push("restore_bytes", m.restore_bytes);
    push("offload_lost", m.offload_lost);
    push("window_frames_dropped", m.window_frames_dropped);
    push("window_rebuilds", m.window_rebuilds);
    push("bypass_admissions", m.bypass_admissions);
    push("prefix_hits", m.prefix_hits);
    push("prefix_bytes_shared", m.prefix_bytes_shared);
    // Cache pool (gauges).
    push("pool_used_bytes", sum(|s| s.pool.used_bytes() as u64));
    push("pool_free_bytes", sum(|s| s.pool.free_bytes() as u64));
    push("pool_reserved", sum(|s| s.pool.n_reserved() as u64));
    // Warm tier.
    push("tier_residents", sum(|s| s.tier.n_residents() as u64));
    push("tier_resident_bytes", sum(|s| s.tier.resident_bytes() as u64));
    push("tier_inserts", sum(|s| s.tier.stats.inserts));
    push("tier_hits", sum(|s| s.tier.stats.hits));
    push("tier_evictions", sum(|s| s.tier.stats.evictions));
    push("tier_evicted_bytes", sum(|s| s.tier.stats.evicted_bytes));
    // Prefix store.
    push("prefix_images", sum(|s| s.prefix_store.n_images() as u64));
    push("prefix_resident_bytes", sum(|s| s.prefix_store.resident_bytes() as u64));
    push("prefix_pinned_images", sum(|s| s.prefix_store.pinned_images() as u64));
    push("prefix_pins", sum(|s| s.prefix_pins() as u64));
    push("prefix_store_hits", sum(|s| s.prefix_store.stats.hits));
    push("prefix_store_inserts", sum(|s| s.prefix_store.stats.inserts));
    push("prefix_store_released", sum(|s| s.prefix_store.stats.released));
    // Latency percentiles over completed requests (live histograms).
    let t = ttft.summary();
    push("ttft_count", t.count as u64);
    push("ttft_mean_us", t.mean_us);
    push("ttft_p50_us", t.p50_us);
    push("ttft_p90_us", t.p90_us);
    push("ttft_p99_us", t.p99_us);
    push("ttft_max_us", t.max_us);
    let e = e2e.summary();
    push("e2e_count", e.count as u64);
    push("e2e_mean_us", e.mean_us);
    push("e2e_p50_us", e.p50_us);
    push("e2e_p90_us", e.p90_us);
    push("e2e_p99_us", e.p99_us);
    push("e2e_max_us", e.max_us);
    // Appended fields only below this line (see the doc comment).
    push("uptime_secs", started.elapsed().as_secs());
    for (w, gauge) in conn_gauges.iter().enumerate() {
        push(&format!("io_conns_{w}"), gauge.load(Ordering::Relaxed) as u64);
    }
    push("stats_generation", generation);
    // Fleet block: structure gauges a single-replica server also exports
    // (with fleet_replicas = 1), so dashboards need one query shape.
    push("fleet_replicas", replicas.len() as u64);
    push("fleet_migrations", fleet.migrations);
    push("fleet_migrated_bytes", fleet.migrated_bytes);
    for (i, s) in replicas.iter().enumerate() {
        push(&format!("replica{i}_pending"), s.pending() as u64);
    }
    out
}

/// Minimal blocking client for examples and tests.
pub struct Client {
    conn: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    /// Connect to a serving endpoint (as reported by [`serve`]'s `on_bound`
    /// callback).
    pub fn connect(addr: std::net::SocketAddr) -> Result<Client> {
        let conn = TcpStream::connect(addr)?;
        let reader = BufReader::new(conn.try_clone()?);
        Ok(Client { conn, reader })
    }

    /// Send one generation request and block for its completion.
    pub fn generate(&mut self, prompt: &str, max_new_tokens: usize) -> Result<Json> {
        let req = Json::obj(vec![
            ("prompt", Json::str(prompt)),
            ("max_new_tokens", Json::Num(max_new_tokens as f64)),
        ]);
        self.send_line(&req.dump())
    }

    /// Send one generation request with explicit SLO fields (priority
    /// class, optional relative deadline in milliseconds) and block for its
    /// completion.
    pub fn generate_with(
        &mut self,
        prompt: &str,
        max_new_tokens: usize,
        priority: Priority,
        deadline_ms: Option<f64>,
    ) -> Result<Json> {
        let mut fields = vec![
            ("prompt", Json::str(prompt)),
            ("max_new_tokens", Json::Num(max_new_tokens as f64)),
            ("priority", Json::str(priority.name())),
        ];
        if let Some(ms) = deadline_ms {
            fields.push(("deadline_ms", Json::Num(ms)));
        }
        self.send_line(&Json::obj(fields).dump())
    }

    /// Send one raw protocol line and block for one response line (lets
    /// tests exercise the malformed-request path).
    pub fn send_line(&mut self, line: &str) -> Result<Json> {
        writeln!(self.conn, "{line}")?;
        let mut resp = String::new();
        self.reader.read_line(&mut resp)?;
        Json::parse(&resp).map_err(|e| anyhow::anyhow!("bad response: {e}"))
    }
}

/// Minimal blocking admin-plane client for tests: send one command line,
/// read the reply (multi-line for `stats`, terminated by `END`).
pub struct AdminClient {
    conn: TcpStream,
    reader: BufReader<TcpStream>,
}

impl AdminClient {
    /// Connect to an admin endpoint (as reported by [`serve_with`]'s
    /// `on_bound` callback).
    pub fn connect(addr: std::net::SocketAddr) -> Result<AdminClient> {
        let conn = TcpStream::connect(addr)?;
        let reader = BufReader::new(conn.try_clone()?);
        Ok(AdminClient { conn, reader })
    }

    /// Send one command line and read exactly one reply line.
    pub fn command(&mut self, cmd: &str) -> Result<String> {
        writeln!(self.conn, "{cmd}")?;
        self.read_reply_line()
    }

    /// Send `stats` and parse the `STAT name value` lines up to `END` into
    /// an ordered list.
    pub fn stats(&mut self) -> Result<Vec<(String, u64)>> {
        writeln!(self.conn, "stats")?;
        let mut out = Vec::new();
        loop {
            let line = self.read_reply_line()?;
            if line == "END" {
                return Ok(out);
            }
            let mut parts = line.splitn(3, ' ');
            let (kw, name, value) =
                (parts.next().unwrap_or(""), parts.next(), parts.next());
            let (Some(name), Some(value)) = (name, value) else {
                anyhow::bail!("malformed stats line: {line:?}");
            };
            if kw != "STAT" {
                anyhow::bail!("expected STAT, got: {line:?}");
            }
            out.push((name.to_string(), value.parse::<u64>().context("stat value")?));
        }
    }

    /// Send `metrics` and read the Prometheus text-exposition page up to
    /// (excluding) the `END` terminator.
    pub fn metrics(&mut self) -> Result<String> {
        writeln!(self.conn, "metrics")?;
        let mut page = String::new();
        loop {
            let line = self.read_reply_line()?;
            if line == "END" {
                return Ok(page);
            }
            page.push_str(&line);
            page.push('\n');
        }
    }

    /// Read one reply line (CRLF or LF terminated, terminator stripped).
    fn read_reply_line(&mut self) -> Result<String> {
        match read_line_capped(&mut self.reader)? {
            super::conn::LineRead::Line(bytes) => {
                let s = String::from_utf8_lossy(&bytes);
                Ok(s.trim_end_matches('\r').to_string())
            }
            super::conn::LineRead::TooLong => anyhow::bail!("admin reply line too long"),
            super::conn::LineRead::Eof => anyhow::bail!("admin connection closed"),
        }
    }
}
