//! Thread-based TCP serving front-end over the scheduler.
//!
//! Failure handling rules (clients must never hang on a silent drop, and a
//! hostile line must never poison scheduler state — every rejection happens
//! before anything is submitted):
//! * malformed request lines — truncated JSON, non-UTF8 bytes, nesting
//!   bombs (see [`crate::util::json::MAX_DEPTH`]) — get an `{"error": ...}`
//!   response line instead of being discarded;
//! * request lines longer than [`MAX_LINE_BYTES`] are answered in-band and
//!   drained without buffering, so an unbounded line cannot exhaust memory;
//! * stream-clone failures are answered (best effort) and close the reader
//!   instead of panicking the thread;
//! * failed completions (rejected / unencodable prompts) carry an `error`
//!   field in their response line.
//!
//! Each connection has ONE writer handle, shared behind a mutex between the
//! per-connection reader thread (error replies) and the scheduler loop
//! (completion lines), so a pipelining client can never observe two
//! response lines interleaved mid-line.

use crate::coordinator::request::{Priority, Request};
use crate::coordinator::Scheduler;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// The per-connection write half, shared by the reader thread and the
/// scheduler loop.
type SharedConn = Arc<Mutex<TcpStream>>;

struct Inbound {
    req: Request,
    conn: SharedConn,
}

/// One `{"error": ...}` protocol line.
fn error_line(msg: &str) -> String {
    Json::obj(vec![("error", Json::str(msg))]).dump()
}

/// Hard cap on one request line. Far above any legitimate request at the
/// supported prompt sizes; far below anything that could pressure memory.
pub const MAX_LINE_BYTES: usize = 256 * 1024;

/// One read from the capped line reader.
enum LineRead {
    /// A complete newline-terminated (or EOF-terminated) line within the cap.
    Line(Vec<u8>),
    /// The line exceeded [`MAX_LINE_BYTES`]; its remainder was drained
    /// (without buffering) so the connection is resynchronized at the next
    /// newline.
    TooLong,
    /// Clean end of stream.
    Eof,
}

/// Read one `\n`-terminated line, holding at most [`MAX_LINE_BYTES`] + one
/// buffer of it in memory. Unlike [`BufRead::read_until`], an over-long line
/// is discarded as it streams past instead of being accumulated.
fn read_line_capped(r: &mut impl BufRead) -> std::io::Result<LineRead> {
    let mut buf: Vec<u8> = Vec::new();
    let mut over = false;
    loop {
        let available = r.fill_buf()?;
        if available.is_empty() {
            return Ok(match (over, buf.is_empty()) {
                (true, _) => LineRead::TooLong,
                (false, true) => LineRead::Eof,
                (false, false) => LineRead::Line(buf),
            });
        }
        let nl = available.iter().position(|&b| b == b'\n');
        let take = nl.unwrap_or(available.len());
        if !over {
            buf.extend_from_slice(&available[..take]);
            if buf.len() > MAX_LINE_BYTES {
                over = true;
                buf.clear();
            }
        }
        r.consume(take + usize::from(nl.is_some()));
        if nl.is_some() {
            return Ok(if over { LineRead::TooLong } else { LineRead::Line(buf) });
        }
    }
}

/// Write one response line while holding the connection's write lock, so
/// concurrent writers cannot interleave bytes within a line.
fn write_line(conn: &SharedConn, line: &str) {
    let mut guard = conn.lock().unwrap_or_else(|e| e.into_inner());
    let _ = writeln!(guard, "{line}");
}

/// Per-connection reader: parse newline-delimited JSON requests and feed
/// them to the scheduler channel. Every rejected line is answered in-band.
fn reader_loop(conn: TcpStream, tx: mpsc::Sender<Inbound>, next_id: Arc<AtomicU64>) {
    let mut reader = match conn.try_clone() {
        Ok(c) => BufReader::new(c),
        Err(e) => {
            // Can't read without a second handle; tell the client and bail
            // rather than leaving it waiting on a dead connection.
            let writer: SharedConn = Arc::new(Mutex::new(conn));
            write_line(&writer, &error_line(&format!("connection setup failed: {e}")));
            return;
        }
    };
    let writer: SharedConn = Arc::new(Mutex::new(conn));
    loop {
        let bytes = match read_line_capped(&mut reader) {
            Ok(LineRead::Line(b)) => b,
            Ok(LineRead::TooLong) => {
                write_line(
                    &writer,
                    &error_line(&format!("request line exceeds {MAX_LINE_BYTES} bytes")),
                );
                continue;
            }
            Ok(LineRead::Eof) | Err(_) => return,
        };
        // Reject non-UTF8 in-band; `BufRead::lines` would have dropped the
        // line silently and left the client hanging.
        let line = match String::from_utf8(bytes) {
            Ok(s) => s,
            Err(_) => {
                write_line(&writer, &error_line("request line is not valid UTF-8"));
                continue;
            }
        };
        if line.trim().is_empty() {
            continue;
        }
        let j = match Json::parse(&line) {
            Ok(j) => j,
            Err(e) => {
                write_line(&writer, &error_line(&format!("bad request JSON: {e}")));
                continue;
            }
        };
        let prompt = j.get("prompt").as_str().unwrap_or("").to_string();
        if prompt.is_empty() {
            write_line(
                &writer,
                &error_line("request needs a non-empty string field 'prompt'"),
            );
            continue;
        }
        // Optional SLO fields: "priority" (name or numeric level; unknown
        // values get an in-band error so a typo'd class cannot silently run
        // at the wrong priority) and "deadline_ms" (relative, must be > 0).
        let priority = match j.get("priority") {
            Json::Null => Priority::Standard,
            Json::Str(s) => match Priority::parse(s) {
                Some(p) => p,
                None => {
                    write_line(
                        &writer,
                        &error_line(&format!(
                            "unknown priority '{s}' (one of: interactive, standard, batch)"
                        )),
                    );
                    continue;
                }
            },
            Json::Num(n) => {
                let parsed = (n.fract() == 0.0)
                    .then(|| format!("{}", *n as i64))
                    .and_then(|s| Priority::parse(&s));
                match parsed {
                    Some(p) => p,
                    None => {
                        write_line(
                            &writer,
                            &error_line("numeric priority must be 0, 1, or 2"),
                        );
                        continue;
                    }
                }
            }
            _ => {
                write_line(&writer, &error_line("priority must be a string or number"));
                continue;
            }
        };
        let deadline_us = match j.get("deadline_ms") {
            Json::Null => None,
            Json::Num(ms) if ms.is_finite() && *ms > 0.0 => Some((*ms * 1e3) as u64),
            _ => {
                // Same contract as priority: a bad SLO field gets an
                // in-band error instead of silently running unenforced.
                write_line(
                    &writer,
                    &error_line("deadline_ms must be a positive number of milliseconds"),
                );
                continue;
            }
        };
        let mut req = Request::new(
            next_id.fetch_add(1, Ordering::Relaxed),
            prompt,
            j.get("max_new_tokens").as_usize().unwrap_or(32),
        );
        req.temperature = j.get("temperature").as_f64().map(|t| t as f32);
        req.priority = priority;
        req.deadline_us = deadline_us;
        if tx.send(Inbound { req, conn: writer.clone() }).is_err() {
            write_line(&writer, &error_line("server is shutting down"));
            return;
        }
    }
}

/// Serve until `stop` flips true (tests) or forever (CLI). Binds `addr`,
/// returns the bound address via the callback before blocking.
pub fn serve(
    mut sched: Scheduler,
    addr: &str,
    stop: Arc<AtomicBool>,
    on_bound: impl FnOnce(std::net::SocketAddr),
) -> Result<()> {
    let listener = TcpListener::bind(addr).context("bind")?;
    listener.set_nonblocking(true)?;
    on_bound(listener.local_addr()?);
    let (tx, rx) = mpsc::channel::<Inbound>();
    let next_id = Arc::new(AtomicU64::new(1));

    // Acceptor + reader threads.
    let stop_acc = stop.clone();
    let acceptor = std::thread::spawn(move || {
        while !stop_acc.load(Ordering::Relaxed) {
            match listener.accept() {
                Ok((conn, _)) => {
                    let tx = tx.clone();
                    let next_id = next_id.clone();
                    std::thread::spawn(move || reader_loop(conn, tx, next_id));
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(_) => break,
            }
        }
    });

    // Scheduler loop (owns the engine; decode attention fans out over the
    // engine's worker pool). The scheduler's virtual clock is advanced from
    // wall-clock elapsed time so request deadlines expire in live serving
    // exactly as they would in a replay.
    let started = Instant::now();
    let mut conns: std::collections::HashMap<u64, SharedConn> = Default::default();
    while !stop.load(Ordering::Relaxed) {
        sched.set_now(started.elapsed().as_micros() as u64);
        // ingest
        while let Ok(inb) = rx.try_recv() {
            conns.insert(inb.req.id, inb.conn);
            sched.submit(inb.req);
        }
        let worked = sched.tick()?;
        // flush completions (including failed ones, which carry `error`)
        for c in sched.done.drain(..) {
            if let Some(conn) = conns.remove(&c.id) {
                let mut fields = vec![
                    ("id", Json::Num(c.id as f64)),
                    ("text", Json::str(&c.text)),
                    ("n_generated", Json::Num(c.n_generated as f64)),
                    ("ttft_us", Json::Num(c.ttft_us as f64)),
                    ("total_us", Json::Num(c.total_us as f64)),
                ];
                if let Some(err) = &c.error {
                    fields.push(("error", Json::str(err)));
                }
                write_line(&conn, &Json::obj(fields).dump());
            }
        }
        if !worked {
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
    }
    let _ = acceptor.join();
    Ok(())
}

/// Minimal blocking client for examples and tests.
pub struct Client {
    conn: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    /// Connect to a serving endpoint (as reported by [`serve`]'s `on_bound`
    /// callback).
    pub fn connect(addr: std::net::SocketAddr) -> Result<Client> {
        let conn = TcpStream::connect(addr)?;
        let reader = BufReader::new(conn.try_clone()?);
        Ok(Client { conn, reader })
    }

    /// Send one generation request and block for its completion.
    pub fn generate(&mut self, prompt: &str, max_new_tokens: usize) -> Result<Json> {
        let req = Json::obj(vec![
            ("prompt", Json::str(prompt)),
            ("max_new_tokens", Json::Num(max_new_tokens as f64)),
        ]);
        self.send_line(&req.dump())
    }

    /// Send one generation request with explicit SLO fields (priority
    /// class, optional relative deadline in milliseconds) and block for its
    /// completion.
    pub fn generate_with(
        &mut self,
        prompt: &str,
        max_new_tokens: usize,
        priority: Priority,
        deadline_ms: Option<f64>,
    ) -> Result<Json> {
        let mut fields = vec![
            ("prompt", Json::str(prompt)),
            ("max_new_tokens", Json::Num(max_new_tokens as f64)),
            ("priority", Json::str(priority.name())),
        ];
        if let Some(ms) = deadline_ms {
            fields.push(("deadline_ms", Json::Num(ms)));
        }
        self.send_line(&Json::obj(fields).dump())
    }

    /// Send one raw protocol line and block for one response line (lets
    /// tests exercise the malformed-request path).
    pub fn send_line(&mut self, line: &str) -> Result<Json> {
        writeln!(self.conn, "{line}")?;
        let mut resp = String::new();
        self.reader.read_line(&mut resp)?;
        Json::parse(&resp).map_err(|e| anyhow::anyhow!("bad response: {e}"))
    }
}
