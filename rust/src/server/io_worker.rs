//! IO-worker stage of the staged server: non-blocking socket polling,
//! incremental protocol parsing, and buffered writes.
//!
//! Each worker owns a disjoint set of connections (the listener deals them
//! out round-robin) and exchanges work with the scheduler driver over one
//! SPSC queue pair: parsed requests and disconnect notices go up
//! ([`ToDriver`]), response lines come down ([`Outbound`]). Protocol errors
//! never reach the driver — the worker answers them in-band itself, so a
//! garbage flood is absorbed entirely in this stage and cannot poison (or
//! even wake) the scheduler.
//!
//! A client disconnect — EOF, reset, or a failed write — retires the
//! connection and sends [`ToDriver::Disconnect`]; the driver cancels every
//! request the connection still has pending, releasing its cache
//! reservation, warm-tier residency, and prefix pins mid-decode.

use crate::obs;
use crate::server::conn::{error_line, parse_request_line, LineAssembler, LineEvent, LineOutcome, RequestSpec};
use crate::util::spsc::{Consumer, Producer};
use std::collections::HashMap;
use std::io::{ErrorKind, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Work flowing from an IO worker up to the scheduler driver.
pub(crate) enum ToDriver {
    /// A validated request from `conn_id`, ready for id assignment and
    /// submission.
    Submit {
        /// Worker-scoped connection id.
        conn_id: u64,
        /// The parsed request.
        spec: Box<RequestSpec>,
    },
    /// `conn_id` is gone (EOF, reset, or write failure): cancel everything
    /// it still has pending.
    Disconnect {
        /// Worker-scoped connection id.
        conn_id: u64,
    },
}

/// One response line flowing from the driver down to an IO worker.
pub(crate) struct Outbound {
    /// Destination connection.
    pub conn_id: u64,
    /// The response line (newline appended by the worker).
    pub line: String,
}

/// Per-connection state owned by one worker.
struct Conn {
    stream: TcpStream,
    asm: LineAssembler,
    /// Bytes queued for write; drained as the socket accepts them.
    out: Vec<u8>,
}

impl Conn {
    fn queue_line(&mut self, line: &str) {
        self.out.extend_from_slice(line.as_bytes());
        self.out.push(b'\n');
    }
}

/// Cap on bytes read from one connection per poll round, so one firehose
/// client cannot starve its siblings on the same worker.
const READ_QUANTUM: usize = 64 * 1024;

/// Push to the driver, spinning until there is room. The driver drains its
/// inbound queues every loop iteration, so this terminates unless the
/// server is shutting down — in which case the stop flag breaks the spin.
fn push_to_driver(tx: &mut Producer<ToDriver>, stop: &AtomicBool, msg: ToDriver) -> bool {
    let mut msg = msg;
    loop {
        match tx.try_push(msg) {
            Ok(()) => return true,
            Err(back) => {
                if stop.load(Ordering::Relaxed) {
                    return false;
                }
                msg = back;
                std::thread::yield_now();
            }
        }
    }
}

/// The worker thread body. Runs until `stop` flips true. `conn_gauge`
/// mirrors the worker's live-connection count for the admin `stats` plane
/// (written with relaxed ordering — it is a monitoring gauge, not a
/// synchronization point).
pub(crate) fn io_worker_loop(
    mut intake: Consumer<(u64, TcpStream)>,
    mut to_driver: Producer<ToDriver>,
    mut from_driver: Consumer<Outbound>,
    stop: Arc<AtomicBool>,
    conn_gauge: Arc<AtomicUsize>,
) {
    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut dead: Vec<u64> = Vec::new();
    let mut events: Vec<LineEvent> = Vec::new();
    let mut buf = [0u8; 4096];
    while !stop.load(Ordering::Relaxed) {
        let mut busy = false;

        // New connections from the listener.
        while let Some((conn_id, stream)) = intake.try_pop() {
            busy = true;
            if stream.set_nonblocking(true).is_err() {
                continue; // already closed; nothing was submitted for it
            }
            let _ = stream.set_nodelay(true);
            conns.insert(conn_id, Conn { stream, asm: LineAssembler::new(), out: Vec::new() });
            conn_gauge.store(conns.len(), Ordering::Relaxed);
        }

        // Response lines from the driver.
        while let Some(ob) = from_driver.try_pop() {
            busy = true;
            if let Some(c) = conns.get_mut(&ob.conn_id) {
                c.queue_line(&ob.line);
            }
            // A line for a connection that already disconnected is dropped:
            // the driver races its completion against our Disconnect notice,
            // and there is no one left to read it.
        }

        // Poll every connection: read available bytes, parse incrementally,
        // flush buffered writes.
        for (&conn_id, c) in conns.iter_mut() {
            // -- reads --
            let mut taken = 0usize;
            loop {
                match c.stream.read(&mut buf) {
                    Ok(0) => {
                        dead.push(conn_id);
                        break;
                    }
                    Ok(n) => {
                        busy = true;
                        c.asm.feed(&buf[..n], &mut events);
                        for ev in events.drain(..) {
                            match ev {
                                LineEvent::TooLong => c.queue_line(&error_line(&format!(
                                    "request line exceeds {} bytes",
                                    super::conn::MAX_LINE_BYTES
                                ))),
                                LineEvent::Line(bytes) => {
                                    let t_in = obs::start();
                                    let n_bytes = bytes.len() as u64;
                                    match parse_request_line(&bytes) {
                                        LineOutcome::Ignore => {}
                                        LineOutcome::Error(msg) => c.queue_line(&error_line(&msg)),
                                        LineOutcome::Request(spec) => {
                                            if !push_to_driver(
                                                &mut to_driver,
                                                &stop,
                                                ToDriver::Submit { conn_id, spec },
                                            ) {
                                                c.queue_line(&error_line("server is shutting down"));
                                            }
                                            obs::span(
                                                obs::SpanKind::Ingress,
                                                conn_id,
                                                t_in,
                                                conn_id,
                                                n_bytes,
                                            );
                                        }
                                    }
                                }
                            }
                        }
                        taken += n;
                        if taken >= READ_QUANTUM {
                            break;
                        }
                    }
                    Err(ref e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(ref e) if e.kind() == ErrorKind::Interrupted => {}
                    Err(_) => {
                        dead.push(conn_id);
                        break;
                    }
                }
            }
            // -- writes --
            let t_out = if c.out.is_empty() { 0 } else { obs::start() };
            let mut written = 0usize;
            while written < c.out.len() {
                match c.stream.write(&c.out[written..]) {
                    Ok(0) => {
                        dead.push(conn_id);
                        break;
                    }
                    Ok(n) => {
                        busy = true;
                        written += n;
                    }
                    Err(ref e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(ref e) if e.kind() == ErrorKind::Interrupted => {}
                    Err(_) => {
                        dead.push(conn_id);
                        break;
                    }
                }
            }
            if written > 0 {
                c.out.drain(..written);
                obs::span(obs::SpanKind::Egress, conn_id, t_out, conn_id, written as u64);
            }
        }

        // Retire dead connections and tell the driver to cancel their work.
        if !dead.is_empty() {
            dead.sort_unstable();
            dead.dedup();
            for conn_id in dead.drain(..) {
                conns.remove(&conn_id);
                push_to_driver(&mut to_driver, &stop, ToDriver::Disconnect { conn_id });
            }
            conn_gauge.store(conns.len(), Ordering::Relaxed);
        }

        if !busy {
            std::thread::sleep(Duration::from_millis(1));
        }
    }
}
