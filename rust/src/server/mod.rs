//! TCP line-protocol server: newline-delimited JSON requests/responses.
//!
//! tokio is not in the offline vendor set, so the server is thread-based:
//! one acceptor, one scheduler thread owning the engine (the testbed is a
//! single core; the scheduler loop *is* the worker), per-connection reader
//! threads feeding an mpsc channel.
//!
//! Protocol (one JSON object per line):
//!   -> {"prompt": "a=13;?a=", "max_new_tokens": 8}
//!   <- {"id": 3, "text": "13;", "n_generated": 3, "ttft_us": ..., "total_us": ...}
//!
//! Failures are answered in-band, never silently dropped: malformed lines
//! get {"error": ...} immediately, and failed completions (rejected or
//! unencodable requests) carry an "error" field on the completion line.

pub mod tcp;

pub use tcp::{serve, Client, MAX_LINE_BYTES};
