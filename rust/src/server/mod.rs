//! Staged TCP line-protocol server: newline-delimited JSON over
//! non-blocking sockets, plus an admin/metrics plane.
//!
//! tokio is not in the offline vendor set, so the stages are plain threads
//! in the pelikan mold: one listener dealing sockets round-robin to N IO
//! workers ([`io_worker`]) that poll non-blocking sockets and parse the
//! protocol incrementally ([`conn`]), bounded SPSC queue pairs
//! ([`crate::util::spsc`]) into the scheduler driver ([`tcp::serve_with`]),
//! and a separate admin listener ([`admin`]) exporting live counters.
//!
//! Protocol (one JSON object per line):
//!   -> {"prompt": "a=13;?a=", "max_new_tokens": 8}
//!   <- {"id": 3, "text": "13;", "n_generated": 3, "ttft_us": ..., "total_us": ...}
//!
//! Optional request fields: "priority", "deadline_ms", "temperature",
//! "prefix_len", "tag" (echoed on every response line for the request), and
//! "stream" — true streams {"id": ..., "token": ...} lines as tokens are
//! produced, before the final completion line.
//!
//! Failures are answered in-band, never silently dropped: malformed lines
//! get {"error": ...} immediately, and failed completions (rejected or
//! unencodable requests) carry an "error" field on the completion line. A
//! client disconnect cancels everything the connection still had pending,
//! releasing its cache reservation, warm-tier residency, and prefix pins
//! mid-decode ([`crate::coordinator::Scheduler::cancel`]).

pub mod conn;
pub mod tcp;

mod admin;
mod io_worker;

pub use conn::{fuzz_protocol_bytes, MAX_LINE_BYTES};
pub use tcp::{serve, serve_fleet, serve_with, AdminClient, Bound, Client, ServerConfig};
