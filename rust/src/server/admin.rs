//! Admin/metrics plane: a second listener on its own port speaking a small
//! line-oriented text protocol, memcached-stats style.
//!
//! Commands (one per line, case-sensitive):
//! * `stats`   → one `STAT <name> <value>` line per counter, then `END`.
//! * `metrics` → the same snapshot plus live stage-latency summaries as a
//!   Prometheus text-exposition page, then `END`.
//! * `trace <secs>` → arm tracing ([`crate::obs`]) for a 1–60 s window,
//!   then reply with one line of Chrome trace-event JSON covering it.
//! * `version` → `VERSION <crate version>`.
//! * `quit`    → closes this admin connection.
//! * anything else → `ERROR unknown command '<cmd>'` (blank lines ignored).
//!
//! The plane is strictly read-only over the data path: the scheduler driver
//! refreshes a snapshot ([`AdminSnapshot`]) behind a mutex once per loop,
//! and admin connections only ever format that snapshot. A malformed admin
//! command — or a thousand of them — cannot touch the scheduler, the cache,
//! or any data-plane connection. `trace` is the one deliberate exception:
//! it flips the process-wide tracing flag for its window, which makes the
//! driver drain span rings into the flight recorder — observational state
//! only, never scheduling state (decode output stays byte-identical).

use crate::obs;
use crate::obs::recorder::Recorder;
use std::io::{ErrorKind, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// The driver-refreshed stats snapshot: ordered `(name, value)` pairs,
/// formatted on demand by admin connections.
pub(crate) type AdminSnapshot = Vec<(String, u64)>;

/// Shared handle to the latest snapshot.
pub(crate) type SharedSnapshot = Arc<Mutex<AdminSnapshot>>;

/// Write `buf` fully over a non-blocking socket, sleeping through
/// `WouldBlock` (admin responses are small; this cannot livelock a data
/// connection because the admin plane runs on its own threads).
fn write_all_nb(stream: &mut TcpStream, mut buf: &[u8], stop: &AtomicBool) -> std::io::Result<()> {
    while !buf.is_empty() {
        if stop.load(Ordering::Relaxed) {
            return Err(std::io::Error::from(ErrorKind::Interrupted));
        }
        match stream.write(buf) {
            Ok(0) => return Err(std::io::Error::from(ErrorKind::WriteZero)),
            Ok(n) => buf = &buf[n..],
            Err(ref e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(1));
            }
            Err(ref e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// Run the `trace <secs>` window: arm tracing, wait it out (checking
/// `stop` so shutdown is never delayed), then drain everything the window
/// produced and export it as one line of Chrome trace JSON.
fn run_trace_window(secs: u64, recorder: &Mutex<Recorder>, stop: &AtomicBool) -> String {
    let guard = obs::TraceGuard::arm();
    let deadline = std::time::Instant::now() + Duration::from_secs(secs);
    while std::time::Instant::now() < deadline && !stop.load(Ordering::Relaxed) {
        std::thread::sleep(Duration::from_millis(100));
    }
    // Keep the guard alive through the final drain so the driver cannot
    // observe a disabled plane while ring events from the window are still
    // in flight.
    let mut rec = recorder.lock().unwrap_or_else(|e| e.into_inner());
    rec.drain();
    let json = rec.chrome_trace(Some(secs.saturating_mul(1_000_000))).dump();
    drop(rec);
    drop(guard);
    format!("{json}\r\n")
}

/// Serve one admin connection until `quit`, EOF, error, or server stop.
fn admin_conn_loop(
    mut stream: TcpStream,
    snapshot: SharedSnapshot,
    recorder: Arc<Mutex<Recorder>>,
    stop: Arc<AtomicBool>,
) {
    use crate::server::conn::{LineAssembler, LineEvent};
    if stream.set_nonblocking(true).is_err() {
        return;
    }
    let mut asm = LineAssembler::new();
    let mut events = Vec::new();
    let mut buf = [0u8; 1024];
    while !stop.load(Ordering::Relaxed) {
        match std::io::Read::read(&mut stream, &mut buf) {
            Ok(0) => return,
            Ok(n) => asm.feed(&buf[..n], &mut events),
            Err(ref e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
                continue;
            }
            Err(ref e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => return,
        }
        for ev in events.drain(..) {
            let reply = match ev {
                LineEvent::TooLong => "ERROR line too long\r\n".to_string(),
                LineEvent::Line(bytes) => {
                    let cmd = String::from_utf8_lossy(&bytes).trim().to_string();
                    match cmd.as_str() {
                        "" => continue,
                        "quit" => return,
                        "version" => format!("VERSION {}\r\n", env!("CARGO_PKG_VERSION")),
                        "stats" => {
                            let snap = snapshot.lock().unwrap_or_else(|e| e.into_inner());
                            let mut out = String::new();
                            for (name, value) in snap.iter() {
                                out.push_str(&format!("STAT {name} {value}\r\n"));
                            }
                            out.push_str("END\r\n");
                            out
                        }
                        "metrics" => {
                            let snap =
                                snapshot.lock().unwrap_or_else(|e| e.into_inner()).clone();
                            let rec = recorder.lock().unwrap_or_else(|e| e.into_inner());
                            let mut out = crate::obs::export::prometheus(&rec, &snap);
                            drop(rec);
                            out.push_str("END\r\n");
                            out
                        }
                        other => match other.strip_prefix("trace ") {
                            Some(arg) => match arg.trim().parse::<u64>() {
                                Ok(secs @ 1..=60) => run_trace_window(secs, &recorder, &stop),
                                _ => {
                                    "ERROR trace window must be 1..=60 seconds\r\n".to_string()
                                }
                            },
                            None => format!("ERROR unknown command '{other}'\r\n"),
                        },
                    }
                }
            };
            if write_all_nb(&mut stream, reply.as_bytes(), &stop).is_err() {
                return;
            }
        }
    }
}

/// The admin listener thread body: accept connections (non-blocking, like
/// the data-plane listener) and serve each on its own thread. All
/// connection threads are joined before this returns, so a stopped server
/// leaves nothing running.
pub(crate) fn admin_loop(
    listener: TcpListener,
    snapshot: SharedSnapshot,
    recorder: Arc<Mutex<Recorder>>,
    stop: Arc<AtomicBool>,
) {
    let mut handles = Vec::new();
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _)) => {
                let snapshot = snapshot.clone();
                let recorder = recorder.clone();
                let stop = stop.clone();
                handles.push(std::thread::spawn(move || {
                    admin_conn_loop(stream, snapshot, recorder, stop)
                }));
            }
            Err(ref e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => break,
        }
    }
    for h in handles {
        let _ = h.join();
    }
}
