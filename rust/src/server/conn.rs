//! Pure per-connection protocol layer: incremental line assembly and
//! request-line validation.
//!
//! This module owns everything about the wire protocol that does not touch a
//! socket, so the IO workers ([`crate::server`]'s staged pipeline) and the
//! fuzz harness exercise *the same* code: [`LineAssembler`] turns arbitrary
//! read chunks into complete protocol lines under the [`MAX_LINE_BYTES`]
//! cap (over-long lines are drained, never buffered), and
//! [`parse_request_line`] validates one line into a [`RequestSpec`] or the
//! exact in-band error message the client gets back.
//!
//! Failure handling rules (clients must never hang on a silent drop, and a
//! hostile line must never poison scheduler state — every rejection happens
//! before anything is submitted):
//! * malformed request lines — truncated JSON, non-UTF8 bytes, nesting
//!   bombs (see [`crate::util::json::MAX_DEPTH`]) — get an `{"error": ...}`
//!   response line instead of being discarded;
//! * request lines longer than [`MAX_LINE_BYTES`] are answered in-band and
//!   drained without buffering, so an unbounded line cannot exhaust memory;
//! * failed completions (rejected / unencodable prompts) carry an `error`
//!   field in their response line.
//!
//! [`fuzz_protocol_bytes`] is the `cargo fuzz`-compatible entry point over
//! this whole layer (see `tests/protocol_robustness.rs`).

use crate::coordinator::request::Priority;
use crate::util::json::Json;
use std::io::BufRead;

/// Hard cap on one request line. Far above any legitimate request at the
/// supported prompt sizes; far below anything that could pressure memory.
pub const MAX_LINE_BYTES: usize = 256 * 1024;

/// One `{"error": ...}` protocol line.
pub(crate) fn error_line(msg: &str) -> String {
    Json::obj(vec![("error", Json::str(msg))]).dump()
}

/// One complete protocol line recovered from the byte stream.
#[derive(Debug, PartialEq, Eq)]
pub enum LineEvent {
    /// A complete newline-terminated line within the cap (newline excluded).
    Line(Vec<u8>),
    /// The line exceeded [`MAX_LINE_BYTES`]; its bytes were discarded as
    /// they streamed past and the connection is resynchronized at the
    /// newline that ended it.
    TooLong,
}

/// Incremental newline-delimited framing over arbitrary read chunks.
///
/// Non-blocking sockets hand the IO workers whatever bytes are available —
/// half a line, three lines and a fragment, one byte. `feed` consumes each
/// chunk and emits a [`LineEvent`] per completed line; partial lines carry
/// over to the next chunk. Memory is bounded: at most [`MAX_LINE_BYTES`] of
/// partial line is ever buffered, and an over-long line switches to drain
/// mode (count, don't store) until its terminating newline.
#[derive(Debug, Default)]
pub struct LineAssembler {
    buf: Vec<u8>,
    over: bool,
}

impl LineAssembler {
    /// A fresh assembler (no partial line).
    pub fn new() -> LineAssembler {
        LineAssembler::default()
    }

    /// Consume one read chunk, appending one event per completed line to
    /// `out`.
    pub fn feed(&mut self, chunk: &[u8], out: &mut Vec<LineEvent>) {
        let mut rest = chunk;
        while let Some(nl) = rest.iter().position(|&b| b == b'\n') {
            let (head, tail) = rest.split_at(nl);
            rest = &tail[1..];
            if self.over || self.buf.len() + head.len() > MAX_LINE_BYTES {
                self.buf.clear();
                self.over = false;
                out.push(LineEvent::TooLong);
            } else if self.buf.is_empty() {
                out.push(LineEvent::Line(head.to_vec()));
            } else {
                self.buf.extend_from_slice(head);
                out.push(LineEvent::Line(std::mem::take(&mut self.buf)));
            }
        }
        if !self.over {
            if self.buf.len() + rest.len() > MAX_LINE_BYTES {
                self.buf.clear();
                self.over = true;
            } else {
                self.buf.extend_from_slice(rest);
            }
        }
    }

    /// Bytes of partial line currently buffered (bounded by
    /// [`MAX_LINE_BYTES`] by construction).
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }
}

/// One validated generation request, as parsed off the wire. The driver
/// turns this into a [`crate::coordinator::request::Request`] when it
/// assigns the server-side id.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestSpec {
    /// The prompt text (non-empty).
    pub prompt: String,
    /// Generation budget (default 32).
    pub max_new_tokens: usize,
    /// Sampling temperature; `None` (the default) is greedy argmax.
    pub temperature: Option<f32>,
    /// Priority class (default [`Priority::Standard`]).
    pub priority: Priority,
    /// Relative deadline in virtual microseconds, if any.
    pub deadline_us: Option<u64>,
    /// Declared shareable prompt prefix in tokens (0 = none).
    pub prefix_len: usize,
    /// Stream tokens as they are produced (`{"id":…,"token":…}` lines
    /// before the final completion line). Off by default so the one
    /// request line → one response line contract holds for plain clients.
    pub stream: bool,
    /// Opaque client tag echoed on every response line for this request,
    /// so pipelining clients can match completions to requests without
    /// depending on server-assigned ids.
    pub tag: Option<String>,
}

/// Outcome of validating one complete protocol line.
#[derive(Debug, PartialEq)]
pub enum LineOutcome {
    /// Blank line: ignored, no response.
    Ignore,
    /// Rejected; the string is the in-band error message.
    Error(String),
    /// A valid request, ready to submit.
    Request(Box<RequestSpec>),
}

/// Validate one raw protocol line (as framed by [`LineAssembler`]) into a
/// request, a blank-line ignore, or the exact in-band error message.
pub fn parse_request_line(bytes: &[u8]) -> LineOutcome {
    // Reject non-UTF8 in-band; `BufRead::lines` would have dropped the
    // line silently and left the client hanging.
    let Ok(line) = std::str::from_utf8(bytes) else {
        return LineOutcome::Error("request line is not valid UTF-8".into());
    };
    if line.trim().is_empty() {
        return LineOutcome::Ignore;
    }
    let j = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => return LineOutcome::Error(format!("bad request JSON: {e}")),
    };
    let prompt = j.get("prompt").as_str().unwrap_or("").to_string();
    if prompt.is_empty() {
        return LineOutcome::Error("request needs a non-empty string field 'prompt'".into());
    }
    // Optional SLO fields: "priority" (name or numeric level; unknown
    // values get an in-band error so a typo'd class cannot silently run
    // at the wrong priority) and "deadline_ms" (relative, must be > 0).
    let priority = match j.get("priority") {
        Json::Null => Priority::Standard,
        Json::Str(s) => match Priority::parse(s) {
            Some(p) => p,
            None => {
                return LineOutcome::Error(format!(
                    "unknown priority '{s}' (one of: interactive, standard, batch)"
                ))
            }
        },
        Json::Num(n) => {
            let parsed = (n.fract() == 0.0)
                .then(|| format!("{}", *n as i64))
                .and_then(|s| Priority::parse(&s));
            match parsed {
                Some(p) => p,
                None => return LineOutcome::Error("numeric priority must be 0, 1, or 2".into()),
            }
        }
        _ => return LineOutcome::Error("priority must be a string or number".into()),
    };
    let deadline_us = match j.get("deadline_ms") {
        Json::Null => None,
        Json::Num(ms) if ms.is_finite() && *ms > 0.0 => Some((*ms * 1e3) as u64),
        _ => {
            // Same contract as priority: a bad SLO field gets an in-band
            // error instead of silently running unenforced.
            return LineOutcome::Error(
                "deadline_ms must be a positive number of milliseconds".into(),
            );
        }
    };
    let prefix_len = match j.get("prefix_len") {
        Json::Null => 0,
        Json::Num(n) if n.is_finite() && *n >= 0.0 && n.fract() == 0.0 => *n as usize,
        _ => return LineOutcome::Error("prefix_len must be a non-negative integer".into()),
    };
    let stream = match j.get("stream") {
        Json::Null => false,
        Json::Bool(b) => *b,
        _ => return LineOutcome::Error("stream must be a boolean".into()),
    };
    let tag = match j.get("tag") {
        Json::Null => None,
        Json::Str(s) => Some(s.clone()),
        _ => return LineOutcome::Error("tag must be a string".into()),
    };
    LineOutcome::Request(Box::new(RequestSpec {
        prompt,
        max_new_tokens: j.get("max_new_tokens").as_usize().unwrap_or(32),
        temperature: j.get("temperature").as_f64().map(|t| t as f32),
        priority,
        deadline_us,
        prefix_len,
        stream,
        tag,
    }))
}

/// `cargo fuzz`-compatible entry over the whole pure protocol layer: frame
/// `data` through a [`LineAssembler`] (in several chunkings, including
/// byte-at-a-time for short inputs, to hit split-across-read-boundary
/// paths) and validate every framed line. Must never panic, and buffered
/// partial-line memory must stay under the cap. Wire it up as
/// `fuzz_target!(|data: &[u8]| innerq::server::fuzz_protocol_bytes(data));`.
pub fn fuzz_protocol_bytes(data: &[u8]) {
    let chunk_sizes: &[usize] = if data.len() <= 4096 { &[1, 7, 4096] } else { &[4096] };
    for &sz in chunk_sizes {
        let mut asm = LineAssembler::new();
        let mut events = Vec::new();
        for chunk in data.chunks(sz.max(1)) {
            asm.feed(chunk, &mut events);
            assert!(asm.buffered() <= MAX_LINE_BYTES, "assembler buffer over cap");
        }
        for ev in events.drain(..) {
            if let LineEvent::Line(bytes) = ev {
                assert!(bytes.len() <= MAX_LINE_BYTES, "framed line over cap");
                // Must classify without panicking, whatever the bytes.
                let _ = parse_request_line(&bytes);
            }
        }
    }
}

/// One read from the capped blocking line reader (admin plane and tests).
pub(crate) enum LineRead {
    /// A complete newline-terminated (or EOF-terminated) line within the cap.
    Line(Vec<u8>),
    /// The line exceeded [`MAX_LINE_BYTES`]; its remainder was drained
    /// (without buffering) so the connection is resynchronized at the next
    /// newline.
    TooLong,
    /// Clean end of stream.
    Eof,
}

/// Read one `\n`-terminated line from a blocking reader, holding at most
/// [`MAX_LINE_BYTES`] + one buffer of it in memory. Unlike
/// [`BufRead::read_until`], an over-long line is discarded as it streams
/// past instead of being accumulated. (The data plane uses the non-blocking
/// [`LineAssembler`] instead; this serves the blocking admin plane.)
pub(crate) fn read_line_capped(r: &mut impl BufRead) -> std::io::Result<LineRead> {
    let mut buf: Vec<u8> = Vec::new();
    let mut over = false;
    loop {
        let available = r.fill_buf()?;
        if available.is_empty() {
            return Ok(match (over, buf.is_empty()) {
                (true, _) => LineRead::TooLong,
                (false, true) => LineRead::Eof,
                (false, false) => LineRead::Line(buf),
            });
        }
        let nl = available.iter().position(|&b| b == b'\n');
        let take = nl.unwrap_or(available.len());
        if !over {
            buf.extend_from_slice(&available[..take]);
            if buf.len() > MAX_LINE_BYTES {
                over = true;
                buf.clear();
            }
        }
        r.consume(take + usize::from(nl.is_some()));
        if nl.is_some() {
            return Ok(if over { LineRead::TooLong } else { LineRead::Line(buf) });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn feed_all(asm: &mut LineAssembler, chunks: &[&[u8]]) -> Vec<LineEvent> {
        let mut out = Vec::new();
        for c in chunks {
            asm.feed(c, &mut out);
        }
        out
    }

    #[test]
    fn assembler_reframes_lines_split_across_chunks() {
        let mut asm = LineAssembler::new();
        let evs = feed_all(&mut asm, &[b"hel", b"lo\nwo", b"rld\n"]);
        assert_eq!(
            evs,
            vec![LineEvent::Line(b"hello".to_vec()), LineEvent::Line(b"world".to_vec())]
        );
        assert_eq!(asm.buffered(), 0);
    }

    #[test]
    fn assembler_drains_overlong_lines_without_buffering() {
        let mut asm = LineAssembler::new();
        let big = vec![b'x'; MAX_LINE_BYTES + 10];
        let mut evs = Vec::new();
        for chunk in big.chunks(4096) {
            asm.feed(chunk, &mut evs);
            assert!(asm.buffered() <= MAX_LINE_BYTES);
        }
        assert!(evs.is_empty());
        asm.feed(b"\nok\n", &mut evs);
        assert_eq!(evs, vec![LineEvent::TooLong, LineEvent::Line(b"ok".to_vec())]);
    }

    #[test]
    fn assembler_handles_many_lines_in_one_chunk() {
        let mut asm = LineAssembler::new();
        let mut evs = Vec::new();
        asm.feed(b"a\n\nb\n", &mut evs);
        assert_eq!(
            evs,
            vec![
                LineEvent::Line(b"a".to_vec()),
                LineEvent::Line(b"".to_vec()),
                LineEvent::Line(b"b".to_vec())
            ]
        );
    }

    #[test]
    fn parse_rejects_hostile_lines_with_stable_messages() {
        let err = |b: &[u8]| match parse_request_line(b) {
            LineOutcome::Error(m) => m,
            other => panic!("expected error, got {other:?}"),
        };
        assert!(err(b"\xff\xfe").contains("UTF-8"));
        assert!(err(b"{\"prompt\": \"a=1").contains("bad request JSON"));
        assert!(err(b"{}").contains("'prompt'"));
        assert!(err(br#"{"prompt": "x", "priority": "urgent"}"#).contains("unknown priority"));
        assert!(err(br#"{"prompt": "x", "priority": 1.5}"#).contains("0, 1, or 2"));
        assert!(err(br#"{"prompt": "x", "deadline_ms": -1}"#).contains("deadline_ms"));
        assert!(err(br#"{"prompt": "x", "prefix_len": -3}"#).contains("prefix_len"));
        assert!(err(br#"{"prompt": "x", "stream": 1}"#).contains("stream"));
        assert!(err(br#"{"prompt": "x", "tag": 7}"#).contains("tag"));
        assert_eq!(parse_request_line(b"   "), LineOutcome::Ignore);
    }

    #[test]
    fn parse_accepts_a_full_request() {
        let line = br#"{"prompt": "a=1;?a=", "max_new_tokens": 4, "priority": "interactive",
                        "deadline_ms": 250, "stream": true, "tag": "t1", "prefix_len": 2}"#;
        match parse_request_line(line) {
            LineOutcome::Request(spec) => {
                assert_eq!(spec.prompt, "a=1;?a=");
                assert_eq!(spec.max_new_tokens, 4);
                assert_eq!(spec.priority, Priority::Interactive);
                assert_eq!(spec.deadline_us, Some(250_000));
                assert!(spec.stream);
                assert_eq!(spec.tag.as_deref(), Some("t1"));
                assert_eq!(spec.prefix_len, 2);
            }
            other => panic!("expected request, got {other:?}"),
        }
    }

    #[test]
    fn fuzz_entry_is_panic_free_on_hostile_corpus() {
        fuzz_protocol_bytes(b"");
        fuzz_protocol_bytes(b"\n\n\n");
        fuzz_protocol_bytes(b"\xff\xfe\x00\n{\"prompt\"");
        fuzz_protocol_bytes(&[b'['; 4096]);
        let mut long = vec![b'z'; MAX_LINE_BYTES + 100];
        long.push(b'\n');
        fuzz_protocol_bytes(&long);
    }
}
