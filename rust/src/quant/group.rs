//! Group-wise quantizers: symmetric (Eq. 13), asymmetric (Eq. 10–12) and
//! hybrid (Eq. 14, §4.1.2) per-group mode selection.
//!
//! Scales and zero-points are *stored* as IEEE f16 bit patterns; the hybrid
//! mask `M` is encoded in the sign bit of the stored scale, exactly as the
//! paper does ("since scale factors are strictly positive, we repurpose their
//! sign bit"). Symmetric codes are stored with a `+qmax` bias so the packed
//! representation is unsigned; see DESIGN.md for the Eq. (13) clarification.

use crate::util::fp16::{f16_bits_to_f32, f16_round, f32_to_f16_bits};

/// Per-group quantization mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Symmetric around zero: one scale per group, no zero-point (Eq. 13).
    Sym,
    /// Asymmetric min/max range: scale plus zero-point (Eq. 10–12).
    Asym,
    /// Choose Sym or Asym per group by reconstruction error (§4.1.2).
    Hybrid,
}

impl Mode {
    /// Parse a mode from its CLI name (`sym` / `asym` / `hybrid`).
    pub fn parse(s: &str) -> Option<Mode> {
        match s {
            "sym" => Some(Mode::Sym),
            "asym" => Some(Mode::Asym),
            "hybrid" => Some(Mode::Hybrid),
            _ => None,
        }
    }
}

/// Stored per-group parameters. `scale` is f16 bits with the sign bit used as
/// the asymmetric-mode flag; `zero` is f16 bits (0 for symmetric groups —
/// still *stored* in hybrid/asym segments to keep the layout dense, per
/// §4.1.2 / Table 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct GroupParams {
    /// f16 bit pattern of the positive scale; sign bit is the asym flag M.
    pub scale: u16,
    /// f16 bit pattern of the zero-point (0 for symmetric groups).
    pub zero: u16,
}

impl GroupParams {
    /// True if this group was quantized asymmetrically (mask bit M).
    #[inline(always)]
    pub fn is_asym(self) -> bool {
        self.scale & 0x8000 != 0
    }
    /// Positive scale factor as f32.
    #[inline(always)]
    pub fn scale_f32(self) -> f32 {
        f16_bits_to_f32(self.scale & 0x7fff)
    }
    /// Zero-point as f32 (0.0 for symmetric groups).
    #[inline(always)]
    pub fn zero_f32(self) -> f32 {
        f16_bits_to_f32(self.zero)
    }
}

/// Symmetric bias: codes are stored as `clamp(round(v/s), -qmax, qmax) + qmax`
/// so raw codes span [0, 2*qmax] ⊂ [0, 2^b-1].
#[inline(always)]
pub const fn sym_bias(bits: u8) -> i32 {
    (1 << (bits - 1)) - 1
}

/// Quantize one group symmetrically. Raw (biased) codes go to `codes`.
pub fn quantize_sym(vals: &[f32], bits: u8, codes: &mut [u8]) -> GroupParams {
    debug_assert_eq!(vals.len(), codes.len());
    let qmax = sym_bias(bits); // 2^(b-1)-1
    let amax = vals.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    let mut s = if amax > 0.0 { amax / qmax as f32 } else { 1.0 };
    s = f16_round(s).max(f32::MIN_POSITIVE);
    let inv = 1.0 / s;
    for (c, &v) in codes.iter_mut().zip(vals) {
        let q = (v * inv).round_ties_even() as i32;
        *c = (q.clamp(-qmax, qmax) + qmax) as u8;
    }
    GroupParams { scale: f32_to_f16_bits(s) & 0x7fff, zero: 0 }
}

/// Quantize one group asymmetrically (Eq. 10–12). Codes are unsigned.
pub fn quantize_asym(vals: &[f32], bits: u8, codes: &mut [u8]) -> GroupParams {
    debug_assert_eq!(vals.len(), codes.len());
    let levels = ((1u32 << bits) - 1) as f32;
    let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
    for &v in vals {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    let z = f16_round(lo);
    let mut s = if hi > lo { (hi - z) / levels } else { 1.0 };
    s = f16_round(s).max(f32::MIN_POSITIVE);
    let inv = 1.0 / s;
    let maxc = (1u16 << bits) - 1;
    for (c, &v) in codes.iter_mut().zip(vals) {
        let q = ((v - z) * inv).round_ties_even() as i32;
        *c = q.clamp(0, maxc as i32) as u8;
    }
    GroupParams {
        scale: (f32_to_f16_bits(s) & 0x7fff) | 0x8000, // sign bit = asym flag
        zero: f32_to_f16_bits(z),
    }
}

/// Dequantize one raw code given its group parameters.
#[inline(always)]
pub fn dequant_code(raw: u8, p: GroupParams, bits: u8) -> f32 {
    if p.is_asym() {
        p.scale_f32() * raw as f32 + p.zero_f32()
    } else {
        p.scale_f32() * (raw as i32 - sym_bias(bits)) as f32
    }
}

/// Sum of squared reconstruction error for a candidate encoding.
fn sq_err(vals: &[f32], codes: &[u8], p: GroupParams, bits: u8) -> f32 {
    vals.iter()
        .zip(codes)
        .map(|(&v, &c)| {
            let d = dequant_code(c, p, bits) - v;
            d * d
        })
        .sum()
}

/// Hybrid quantization (§4.1.2): encode with both modes, keep the one with
/// the lower reconstruction error. Returns the chosen params (mask in scale
/// sign bit) and writes the chosen codes.
pub fn quantize_hybrid(vals: &[f32], bits: u8, codes: &mut [u8]) -> GroupParams {
    let mut sym_codes = vec![0u8; vals.len()];
    let p_sym = quantize_sym(vals, bits, &mut sym_codes);
    let mut asym_codes = vec![0u8; vals.len()];
    let p_asym = quantize_asym(vals, bits, &mut asym_codes);
    let e_sym = sq_err(vals, &sym_codes, p_sym, bits);
    let e_asym = sq_err(vals, &asym_codes, p_asym, bits);
    // Ties favour symmetric (no zero-point load on the hot path).
    if e_asym < e_sym {
        codes.copy_from_slice(&asym_codes);
        p_asym
    } else {
        codes.copy_from_slice(&sym_codes);
        p_sym
    }
}

/// Quantize one group with the given mode.
pub fn quantize(mode: Mode, vals: &[f32], bits: u8, codes: &mut [u8]) -> GroupParams {
    match mode {
        Mode::Sym => quantize_sym(vals, bits, codes),
        Mode::Asym => quantize_asym(vals, bits, codes),
        Mode::Hybrid => quantize_hybrid(vals, bits, codes),
    }
}

/// Dequantize a whole group into `out`.
pub fn dequantize(codes: &[u8], p: GroupParams, bits: u8, out: &mut [f32]) {
    if p.is_asym() {
        let (s, z) = (p.scale_f32(), p.zero_f32());
        for (o, &c) in out.iter_mut().zip(codes) {
            *o = s * c as f32 + z;
        }
    } else {
        let s = p.scale_f32();
        let bias = sym_bias(bits);
        for (o, &c) in out.iter_mut().zip(codes) {
            *o = s * (c as i32 - bias) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ptest::{check, normal_vec, PropCfg};
    use crate::util::rng::Rng;

    fn rt_err(mode: Mode, vals: &[f32], bits: u8) -> f32 {
        let mut codes = vec![0u8; vals.len()];
        let p = quantize(mode, vals, bits, &mut codes);
        let mut out = vec![0f32; vals.len()];
        dequantize(&codes, p, bits, &mut out);
        vals.iter().zip(&out).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max)
    }

    #[test]
    fn sym_error_bounded_by_half_step() {
        let mut rng = Rng::new(1);
        for bits in [2u8, 3, 4] {
            for _ in 0..50 {
                let vals = normal_vec(&mut rng, 32, 1.0, 0.05);
                let amax = vals.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                let step = amax / sym_bias(bits) as f32;
                // half a step plus f16 scale rounding slack
                assert!(rt_err(Mode::Sym, &vals, bits) <= 0.5 * step * 1.01 + 1e-6);
            }
        }
    }

    #[test]
    fn asym_error_bounded_by_step() {
        let mut rng = Rng::new(2);
        for bits in [2u8, 3, 4] {
            for _ in 0..50 {
                let vals = normal_vec(&mut rng, 32, 1.0, 0.05);
                let (lo, hi) = vals
                    .iter()
                    .fold((f32::INFINITY, f32::NEG_INFINITY), |(l, h), &v| (l.min(v), h.max(v)));
                let step = (hi - lo) / ((1 << bits) - 1) as f32;
                // half-step, plus slack for f16 rounding of z and s
                assert!(
                    rt_err(Mode::Asym, &vals, bits) <= 0.5 * step + 0.01 * (hi - lo) + 1e-6
                );
            }
        }
    }

    #[test]
    fn hybrid_never_worse_than_either_mode() {
        check("hybrid<=min(sym,asym)", PropCfg::default(), |rng, _| {
            let n = 32;
            // Mix of distributions: centered, shifted-positive, outlier-heavy.
            let shift = (rng.next_f32() - 0.3) * 4.0;
            let mut vals = normal_vec(rng, n, 1.0, 0.1);
            for v in &mut vals {
                *v += shift;
            }
            for bits in [2u8, 3] {
                let sq = |mode| {
                    let mut codes = vec![0u8; n];
                    let p = quantize(mode, &vals, bits, &mut codes);
                    let mut out = vec![0f32; n];
                    dequantize(&codes, p, bits, &mut out);
                    vals.iter().zip(&out).map(|(a, b)| (a - b) * (a - b)).sum::<f32>()
                };
                let (es, ea, eh) = (sq(Mode::Sym), sq(Mode::Asym), sq(Mode::Hybrid));
                assert!(eh <= es.min(ea) + 1e-5, "bits={bits} eh={eh} es={es} ea={ea}");
            }
        });
    }

    #[test]
    fn hybrid_prefers_asym_for_shifted_groups() {
        // An all-positive, narrow-range group wastes the sign range under
        // symmetric quantization — the exact motivating case in §4.1.2.
        let vals: Vec<f32> = (0..32).map(|i| 5.0 + 0.01 * i as f32).collect();
        let mut codes = vec![0u8; 32];
        let p = quantize_hybrid(&vals, 2, &mut codes);
        assert!(p.is_asym());
    }

    #[test]
    fn hybrid_prefers_sym_for_zero_centered_spiky_groups() {
        // Near-zero mass with symmetric outliers: the symmetric grid hits the
        // zeros and the ±amax spikes exactly, while the asymmetric grid
        // (anchored at the minimum) cannot represent 0 — the distribution
        // shape under which hybrid overwhelmingly picks symmetric (§6.2).
        let mut vals = vec![0.0f32; 32];
        vals[5] = 2.0;
        vals[20] = -2.0;
        let mut codes = vec![0u8; 32];
        let p = quantize_hybrid(&vals, 3, &mut codes);
        assert!(!p.is_asym());
    }

    #[test]
    fn mask_lives_in_scale_sign_bit() {
        let vals = vec![1.0f32; 32];
        let mut codes = vec![0u8; 32];
        let pa = quantize_asym(&vals, 3, &mut codes);
        let ps = quantize_sym(&vals, 3, &mut codes);
        assert!(pa.scale & 0x8000 != 0);
        assert!(ps.scale & 0x8000 == 0);
        assert!(pa.scale_f32() > 0.0, "magnitude must ignore the mask bit");
    }

    #[test]
    fn all_zero_group_is_exact() {
        let vals = vec![0.0f32; 32];
        for mode in [Mode::Sym, Mode::Asym, Mode::Hybrid] {
            assert_eq!(rt_err(mode, &vals, 3), 0.0, "{mode:?}");
        }
    }

    #[test]
    fn constant_group_asym_is_exact() {
        let vals = vec![3.25f32; 32]; // representable in f16
        assert!(rt_err(Mode::Asym, &vals, 2) < 1e-6);
        assert!(rt_err(Mode::Hybrid, &vals, 2) < 1e-6);
    }

    #[test]
    fn codes_fit_bit_width() {
        check("codes < 2^b", PropCfg { seed: 99, cases: 40 }, |rng, _| {
            let vals = normal_vec(rng, 32, 2.0, 0.2);
            for bits in [2u8, 3, 4] {
                for mode in [Mode::Sym, Mode::Asym, Mode::Hybrid] {
                    let mut codes = vec![0u8; 32];
                    quantize(mode, &vals, bits, &mut codes);
                    assert!(codes.iter().all(|&c| (c as u16) < (1 << bits)));
                }
            }
        });
    }
}
