//! Physical b-bit code packing.
//!
//! A quantization group of `G` codes (b ∈ {2,3,4} bits each) is stored as a
//! little-endian bitstream of `G*b/8` bytes. With the paper's G=32 this is
//! 8 / 12 / 16 bytes per group — small enough that the fused GEMV kernels
//! unpack a whole group with two u64 loads and shifts, never touching memory
//! for intermediates.
//!
//! Codes here are *raw* (unsigned, already biased for symmetric mode); the
//! signed/zero-point interpretation lives in [`crate::quant::group`].

/// Bytes needed to pack `n` codes of `bits` bits.
#[inline]
pub const fn packed_len(n: usize, bits: u8) -> usize {
    (n * bits as usize + 7) / 8
}

/// Pack `codes` (each < 2^bits) into a little-endian bitstream appended to `out`.
pub fn pack(codes: &[u8], bits: u8, out: &mut Vec<u8>) {
    debug_assert!(matches!(bits, 1..=8));
    let start = out.len();
    out.resize(start + packed_len(codes.len(), bits), 0);
    let dst = &mut out[start..];
    let b = bits as usize;
    for (i, &c) in codes.iter().enumerate() {
        debug_assert!((c as u16) < (1u16 << bits), "code {c} out of range for {bits} bits");
        let bitpos = i * b;
        let byte = bitpos / 8;
        let off = bitpos % 8;
        let v = (c as u16) << off;
        dst[byte] |= (v & 0xff) as u8;
        if off + b > 8 {
            dst[byte + 1] |= (v >> 8) as u8;
        }
    }
}

/// Unpack `n` codes from a little-endian bitstream (generic path).
pub fn unpack(bytes: &[u8], bits: u8, n: usize, out: &mut [u8]) {
    debug_assert!(out.len() >= n);
    let b = bits as usize;
    for (i, o) in out.iter_mut().enumerate().take(n) {
        let bitpos = i * b;
        let byte = bitpos / 8;
        let off = bitpos % 8;
        let mut v = bytes[byte] as u16 >> off;
        if off + b > 8 {
            v |= (bytes[byte + 1] as u16) << (8 - off);
        }
        *o = (v & ((1u16 << bits) - 1)) as u8;
    }
}

/// Fast path: unpack one 32-code group of 2-bit codes (8 bytes).
#[inline(always)]
pub fn unpack32_b2(bytes: &[u8], out: &mut [u8; 32]) {
    debug_assert!(bytes.len() >= 8);
    let w = u64::from_le_bytes(bytes[..8].try_into().unwrap());
    for i in 0..32 {
        out[i] = ((w >> (2 * i)) & 0x3) as u8;
    }
}

/// Fast path: unpack one 32-code group of 3-bit codes (12 bytes).
///
/// Two *overlapping* u64 loads eliminate the bit-63 straddle: codes 0..=10
/// live entirely in bytes[0..8] and codes 11..=31 in bytes[4..12] (bit 33
/// onward), so both loops are branchless constant-shift extracts.
#[inline(always)]
pub fn unpack32_b3(bytes: &[u8], out: &mut [u8; 32]) {
    debug_assert!(bytes.len() >= 12);
    let lo = u64::from_le_bytes(bytes[..8].try_into().unwrap());
    let hi = u64::from_le_bytes(bytes[4..12].try_into().unwrap());
    for i in 0..11 {
        out[i] = ((lo >> (3 * i)) & 0x7) as u8;
    }
    for i in 11..32 {
        out[i] = ((hi >> (3 * i - 32)) & 0x7) as u8;
    }
}

/// Fast path: unpack one 32-code group of 4-bit codes (16 bytes).
#[inline(always)]
pub fn unpack32_b4(bytes: &[u8], out: &mut [u8; 32]) {
    debug_assert!(bytes.len() >= 16);
    for (j, chunk) in bytes[..16].chunks_exact(8).enumerate() {
        let w = u64::from_le_bytes(chunk.try_into().unwrap());
        for i in 0..16 {
            out[16 * j + i] = ((w >> (4 * i)) & 0xf) as u8;
        }
    }
}

/// Dispatch the 32-wide fast unpack by bit-width.
#[inline(always)]
pub fn unpack32(bytes: &[u8], bits: u8, out: &mut [u8; 32]) {
    match bits {
        2 => unpack32_b2(bytes, out),
        3 => unpack32_b3(bytes, out),
        4 => unpack32_b4(bytes, out),
        _ => unpack(bytes, bits, 32, out),
    }
}

// ---------------------------------------------------------------------------
// f32-producing fast paths for the fused GEMV kernels.
//
// The blocked kernels multiply codes straight into f32 accumulators, so the
// u8 bounce buffer of `unpack32` is pure overhead there: every group would
// pay a store-to-[u8;32] + reload + widen before the first FMA. These
// variants extract with the same two-u64-load scheme and convert in the same
// exact-trip-count loop, producing a `[f32; 32]` the dot-product loops
// consume directly. The u64→f32 path is exact (codes < 16), so kernels built
// on these are bit-identical to ones built on the u8 unpackers.
// ---------------------------------------------------------------------------

/// Shift tables for the 3-bit path: code `i` lives at bit `3*i` of the
/// 12-byte group. Codes 0..=10 fit in the low u64 (bits 0..33); codes
/// 11..=31 are read from the overlapping high u64 loaded at byte 4 (their
/// shifts are `3*i - 32`). Const tables keep both loops exact-trip-count
/// with table-driven shifts instead of per-iteration shift arithmetic.
const B3_SHIFT_LO: [u32; 11] = [0, 3, 6, 9, 12, 15, 18, 21, 24, 27, 30];
const B3_SHIFT_HI: [u32; 21] = [
    1, 4, 7, 10, 13, 16, 19, 22, 25, 28, 31, 34, 37, 40, 43, 46, 49, 52, 55, 58, 61,
];

/// Fast path: unpack one 32-code group of 2-bit codes (8 bytes) to f32.
#[inline(always)]
pub fn unpack32_b2_f32(bytes: &[u8], out: &mut [f32; 32]) {
    debug_assert!(bytes.len() >= 8);
    let w = u64::from_le_bytes(bytes[..8].try_into().unwrap());
    for i in 0..32 {
        out[i] = ((w >> (2 * i)) & 0x3) as f32;
    }
}

/// Fast path: unpack one 32-code group of 3-bit codes (12 bytes) to f32.
#[inline(always)]
pub fn unpack32_b3_f32(bytes: &[u8], out: &mut [f32; 32]) {
    debug_assert!(bytes.len() >= 12);
    let lo = u64::from_le_bytes(bytes[..8].try_into().unwrap());
    let hi = u64::from_le_bytes(bytes[4..12].try_into().unwrap());
    for i in 0..11 {
        out[i] = ((lo >> B3_SHIFT_LO[i]) & 0x7) as f32;
    }
    for i in 0..21 {
        out[11 + i] = ((hi >> B3_SHIFT_HI[i]) & 0x7) as f32;
    }
}

/// Fast path: unpack one 32-code group of 4-bit codes (16 bytes) to f32.
#[inline(always)]
pub fn unpack32_b4_f32(bytes: &[u8], out: &mut [f32; 32]) {
    debug_assert!(bytes.len() >= 16);
    let lo = u64::from_le_bytes(bytes[..8].try_into().unwrap());
    let hi = u64::from_le_bytes(bytes[8..16].try_into().unwrap());
    for i in 0..16 {
        out[i] = ((lo >> (4 * i)) & 0xf) as f32;
    }
    for i in 0..16 {
        out[16 + i] = ((hi >> (4 * i)) & 0xf) as f32;
    }
}

/// Dispatch the 32-wide f32 fast unpack by bit-width. The generic (bit-loop)
/// path is kept as the reference for other widths.
#[inline(always)]
pub fn unpack32_f32(bytes: &[u8], bits: u8, out: &mut [f32; 32]) {
    match bits {
        2 => unpack32_b2_f32(bytes, out),
        3 => unpack32_b3_f32(bytes, out),
        4 => unpack32_b4_f32(bytes, out),
        _ => {
            let mut raw = [0u8; 32];
            unpack(bytes, bits, 32, &mut raw);
            for i in 0..32 {
                out[i] = raw[i] as f32;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Explicit SIMD variants of the 32-wide f32 unpackers.
//
// These are the per-ISA arms behind `kernels::dispatch`: same bitstream, same
// output values, different extraction machinery. All of them are exact — the
// integer extraction is identical to the scalar path and the int→f32 convert
// is exact for codes < 16 — so kernels built on them stay bit-identical to
// the scalar reference. The register-returning `unpack32_ps_*` forms are what
// the SIMD GEMV kernels consume (codes go straight from packed bytes to
// vector registers, no [f32; 32] bounce); the store forms mirror
// `unpack32_b{2,3,4}_f32` for the parity tests and the unpacker benches.
//
// The 3-bit group (12 bytes) has no lane-aligned container: code `i` lives at
// bit `3*i`, straddling byte boundaries. The SIMD arms load, per lane, the
// u32 container at byte offset `B3_GOFF[i] = min(3*i/8, 8)` and shift right
// by `B3_GSH[i] = 3*i - 8*B3_GOFF[i]`. Clamping the offset to 8 keeps every
// 4-byte load inside the group's exact 12 bytes (the kernels hand out
// exact-length trailing slices — asserted by
// `unpackers_handle_exact_length_group_slices`), at the cost of shifts up to
// 29 for the last eight codes (29 + 3 = 32, still within the container).
// ---------------------------------------------------------------------------

/// Per-code u32-container byte offsets for the SIMD 3-bit unpack (see the
/// section comment above): `min(3*i/8, 8)`, so offset+4 never exceeds 12.
#[allow(dead_code)] // only read by the cfg(target_arch)-gated SIMD modules
pub(crate) const B3_GOFF: [i32; 32] = [
    0, 0, 0, 1, 1, 1, 2, 2, 3, 3, 3, 4, 4, 4, 5, 5, 6, 6, 6, 7, 7, 7, 8, 8, 8, 8, 8, 8, 8, 8, 8,
    8,
];
/// Right-shift of code `i` within its clamped container: `3*i - 8*B3_GOFF[i]`
/// (max 29, so the 3 payload bits always fit the u32).
#[allow(dead_code)]
pub(crate) const B3_GSH: [i32; 32] = [
    0, 3, 6, 1, 4, 7, 2, 5, 0, 3, 6, 1, 4, 7, 2, 5, 0, 3, 6, 1, 4, 7, 2, 5, 8, 11, 14, 17, 20,
    23, 26, 29,
];

/// x86_64 SIMD unpacker arms (AVX2 always compiled on x86_64; AVX-512 only
/// when the toolchain has stable AVX-512 intrinsics — `innerq_avx512` cfg
/// from `build.rs`). Callers must have verified the CPU feature (see
/// [`crate::kernels::dispatch`]) and that `bytes` covers the packed group.
#[cfg(target_arch = "x86_64")]
pub mod x86 {
    use super::{unpack32_f32, B3_GOFF, B3_GSH};
    use std::arch::x86_64::*;

    /// Unpack one 32-code group straight into four 8-lane f32 vectors
    /// (lanes `8k..8k+8` in `out[k]`), AVX2.
    ///
    /// * b2 (8 bytes): the two u32 words each hold 16 codes; broadcast +
    ///   per-lane `vpsrlvd` + mask, one word per two output vectors.
    /// * b3 (12 bytes): per-lane u32 gather at the clamped [`B3_GOFF`]
    ///   offsets, then `vpsrlvd` by [`B3_GSH`].
    /// * b4 (16 bytes): four u32 words of 8 codes each; broadcast + shift.
    /// * other widths: scalar fallback through [`unpack32_f32`].
    ///
    /// # Safety
    /// Requires AVX2 and `bytes.len() >= packed_len(32, bits)`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn unpack32_ps_avx2(bytes: &[u8], bits: u8) -> [__m256; 4] {
        match bits {
            2 => {
                debug_assert!(bytes.len() >= 8);
                let w0 = _mm256_set1_epi32(u32::from_le_bytes(bytes[0..4].try_into().unwrap()) as i32);
                let w1 = _mm256_set1_epi32(u32::from_le_bytes(bytes[4..8].try_into().unwrap()) as i32);
                let sh_lo = _mm256_setr_epi32(0, 2, 4, 6, 8, 10, 12, 14);
                let sh_hi = _mm256_setr_epi32(16, 18, 20, 22, 24, 26, 28, 30);
                let m = _mm256_set1_epi32(0x3);
                [
                    _mm256_cvtepi32_ps(_mm256_and_si256(_mm256_srlv_epi32(w0, sh_lo), m)),
                    _mm256_cvtepi32_ps(_mm256_and_si256(_mm256_srlv_epi32(w0, sh_hi), m)),
                    _mm256_cvtepi32_ps(_mm256_and_si256(_mm256_srlv_epi32(w1, sh_lo), m)),
                    _mm256_cvtepi32_ps(_mm256_and_si256(_mm256_srlv_epi32(w1, sh_hi), m)),
                ]
            }
            3 => {
                debug_assert!(bytes.len() >= 12);
                let base = bytes.as_ptr() as *const i32;
                let m = _mm256_set1_epi32(0x7);
                let mut out = [_mm256_setzero_ps(); 4];
                for (k, o) in out.iter_mut().enumerate() {
                    let off =
                        _mm256_loadu_si256(B3_GOFF.as_ptr().add(8 * k) as *const __m256i);
                    let sh = _mm256_loadu_si256(B3_GSH.as_ptr().add(8 * k) as *const __m256i);
                    let g = _mm256_i32gather_epi32::<1>(base, off);
                    *o = _mm256_cvtepi32_ps(_mm256_and_si256(_mm256_srlv_epi32(g, sh), m));
                }
                out
            }
            4 => {
                debug_assert!(bytes.len() >= 16);
                let sh = _mm256_setr_epi32(0, 4, 8, 12, 16, 20, 24, 28);
                let m = _mm256_set1_epi32(0xf);
                let mut out = [_mm256_setzero_ps(); 4];
                for (k, o) in out.iter_mut().enumerate() {
                    let w = _mm256_set1_epi32(
                        u32::from_le_bytes(bytes[4 * k..4 * k + 4].try_into().unwrap()) as i32,
                    );
                    *o = _mm256_cvtepi32_ps(_mm256_and_si256(_mm256_srlv_epi32(w, sh), m));
                }
                out
            }
            _ => {
                let mut buf = [0f32; 32];
                unpack32_f32(bytes, bits, &mut buf);
                [
                    _mm256_loadu_ps(buf.as_ptr()),
                    _mm256_loadu_ps(buf.as_ptr().add(8)),
                    _mm256_loadu_ps(buf.as_ptr().add(16)),
                    _mm256_loadu_ps(buf.as_ptr().add(24)),
                ]
            }
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn store4(v: [__m256; 4], out: &mut [f32; 32]) {
        for (k, vk) in v.into_iter().enumerate() {
            _mm256_storeu_ps(out.as_mut_ptr().add(8 * k), vk);
        }
    }

    /// AVX2 arm of [`super::unpack32_b2_f32`] (store form, for parity tests
    /// and benches).
    ///
    /// # Safety
    /// Requires AVX2 and `bytes.len() >= 8`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn unpack32_b2_f32_avx2(bytes: &[u8], out: &mut [f32; 32]) {
        store4(unpack32_ps_avx2(bytes, 2), out);
    }

    /// AVX2 arm of [`super::unpack32_b3_f32`].
    ///
    /// # Safety
    /// Requires AVX2 and `bytes.len() >= 12`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn unpack32_b3_f32_avx2(bytes: &[u8], out: &mut [f32; 32]) {
        store4(unpack32_ps_avx2(bytes, 3), out);
    }

    /// AVX2 arm of [`super::unpack32_b4_f32`].
    ///
    /// # Safety
    /// Requires AVX2 and `bytes.len() >= 16`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn unpack32_b4_f32_avx2(bytes: &[u8], out: &mut [f32; 32]) {
        store4(unpack32_ps_avx2(bytes, 4), out);
    }

    /// Unpack one 32-code group into two 16-lane f32 vectors (lanes
    /// `16k..16k+16` in `out[k]`), AVX-512F. Same extraction schemes as the
    /// AVX2 arm at twice the width; b4 selects its per-lane u32 word with
    /// `vpermd` over the broadcast 16-byte group instead of two broadcasts.
    ///
    /// # Safety
    /// Requires AVX-512F and `bytes.len() >= packed_len(32, bits)`.
    #[cfg(innerq_avx512)]
    #[target_feature(enable = "avx512f")]
    pub unsafe fn unpack32_ps_avx512(bytes: &[u8], bits: u8) -> [__m512; 2] {
        match bits {
            2 => {
                debug_assert!(bytes.len() >= 8);
                let w0 = _mm512_set1_epi32(u32::from_le_bytes(bytes[0..4].try_into().unwrap()) as i32);
                let w1 = _mm512_set1_epi32(u32::from_le_bytes(bytes[4..8].try_into().unwrap()) as i32);
                let sh = _mm512_setr_epi32(0, 2, 4, 6, 8, 10, 12, 14, 16, 18, 20, 22, 24, 26, 28, 30);
                let m = _mm512_set1_epi32(0x3);
                [
                    _mm512_cvtepi32_ps(_mm512_and_epi32(_mm512_srlv_epi32(w0, sh), m)),
                    _mm512_cvtepi32_ps(_mm512_and_epi32(_mm512_srlv_epi32(w1, sh), m)),
                ]
            }
            3 => {
                debug_assert!(bytes.len() >= 12);
                let m = _mm512_set1_epi32(0x7);
                let mut out = [_mm512_setzero_ps(); 2];
                for (k, o) in out.iter_mut().enumerate() {
                    let off = _mm512_loadu_epi32(B3_GOFF.as_ptr().add(16 * k));
                    let sh = _mm512_loadu_epi32(B3_GSH.as_ptr().add(16 * k));
                    let g = _mm512_i32gather_epi32::<1>(off, bytes.as_ptr());
                    *o = _mm512_cvtepi32_ps(_mm512_and_epi32(_mm512_srlv_epi32(g, sh), m));
                }
                out
            }
            4 => {
                debug_assert!(bytes.len() >= 16);
                let grp = _mm512_broadcast_i32x4(_mm_loadu_si128(bytes.as_ptr() as *const __m128i));
                let idx_lo = _mm512_setr_epi32(0, 0, 0, 0, 0, 0, 0, 0, 1, 1, 1, 1, 1, 1, 1, 1);
                let idx_hi = _mm512_setr_epi32(2, 2, 2, 2, 2, 2, 2, 2, 3, 3, 3, 3, 3, 3, 3, 3);
                let sh = _mm512_setr_epi32(0, 4, 8, 12, 16, 20, 24, 28, 0, 4, 8, 12, 16, 20, 24, 28);
                let m = _mm512_set1_epi32(0xf);
                [
                    _mm512_cvtepi32_ps(_mm512_and_epi32(
                        _mm512_srlv_epi32(_mm512_permutexvar_epi32(idx_lo, grp), sh),
                        m,
                    )),
                    _mm512_cvtepi32_ps(_mm512_and_epi32(
                        _mm512_srlv_epi32(_mm512_permutexvar_epi32(idx_hi, grp), sh),
                        m,
                    )),
                ]
            }
            _ => {
                let mut buf = [0f32; 32];
                unpack32_f32(bytes, bits, &mut buf);
                [_mm512_loadu_ps(buf.as_ptr()), _mm512_loadu_ps(buf.as_ptr().add(16))]
            }
        }
    }

    #[cfg(innerq_avx512)]
    #[target_feature(enable = "avx512f")]
    unsafe fn store2(v: [__m512; 2], out: &mut [f32; 32]) {
        _mm512_storeu_ps(out.as_mut_ptr(), v[0]);
        _mm512_storeu_ps(out.as_mut_ptr().add(16), v[1]);
    }

    /// AVX-512 arm of [`super::unpack32_b2_f32`].
    ///
    /// # Safety
    /// Requires AVX-512F and `bytes.len() >= 8`.
    #[cfg(innerq_avx512)]
    #[target_feature(enable = "avx512f")]
    pub unsafe fn unpack32_b2_f32_avx512(bytes: &[u8], out: &mut [f32; 32]) {
        store2(unpack32_ps_avx512(bytes, 2), out);
    }

    /// AVX-512 arm of [`super::unpack32_b3_f32`].
    ///
    /// # Safety
    /// Requires AVX-512F and `bytes.len() >= 12`.
    #[cfg(innerq_avx512)]
    #[target_feature(enable = "avx512f")]
    pub unsafe fn unpack32_b3_f32_avx512(bytes: &[u8], out: &mut [f32; 32]) {
        store2(unpack32_ps_avx512(bytes, 3), out);
    }

    /// AVX-512 arm of [`super::unpack32_b4_f32`].
    ///
    /// # Safety
    /// Requires AVX-512F and `bytes.len() >= 16`.
    #[cfg(innerq_avx512)]
    #[target_feature(enable = "avx512f")]
    pub unsafe fn unpack32_b4_f32_avx512(bytes: &[u8], out: &mut [f32; 32]) {
        store2(unpack32_ps_avx512(bytes, 4), out);
    }
}

/// aarch64 NEON unpacker arms. NEON has no per-lane gather, so the 3-bit arm
/// extracts its clamped u32 containers with scalar loads and vectorizes only
/// the mask + convert; the 2/4-bit arms are full-width `vshl`-by-negative
/// (i.e. per-lane right shift) on broadcast words.
#[cfg(target_arch = "aarch64")]
pub mod neon {
    use super::{unpack32_f32, B3_GOFF, B3_GSH};
    use std::arch::aarch64::*;

    /// Per-4-lane negative shift vectors (vshl by a negative count is a
    /// right shift) for the 2-bit arm: lane `4k+j` shifts by `8k + 2j`
    /// within its 16-code u32 word.
    const NSH2: [[i32; 4]; 4] = [
        [0, -2, -4, -6],
        [-8, -10, -12, -14],
        [-16, -18, -20, -22],
        [-24, -26, -28, -30],
    ];
    /// Negative shifts for the 4-bit arm: lane `4k+j` shifts by
    /// `16*(k%2) + 4j` within its 8-code u32 word.
    const NSH4: [[i32; 4]; 2] = [[0, -4, -8, -12], [-16, -20, -24, -28]];

    /// Unpack one 32-code group into eight 4-lane f32 vectors (lanes
    /// `4k..4k+4` in `out[k]`), NEON.
    ///
    /// # Safety
    /// Requires NEON and `bytes.len() >= packed_len(32, bits)`.
    #[target_feature(enable = "neon")]
    pub unsafe fn unpack32_ps_neon(bytes: &[u8], bits: u8) -> [float32x4_t; 8] {
        let mut out = [vdupq_n_f32(0.0); 8];
        match bits {
            2 => {
                debug_assert!(bytes.len() >= 8);
                let w = u64::from_le_bytes(bytes[..8].try_into().unwrap());
                let lo = vdupq_n_u32(w as u32);
                let hi = vdupq_n_u32((w >> 32) as u32);
                let m = vdupq_n_u32(0x3);
                for k in 0..4 {
                    let sh = vld1q_s32(NSH2[k].as_ptr());
                    out[k] = vcvtq_f32_u32(vandq_u32(vshlq_u32(lo, sh), m));
                    out[k + 4] = vcvtq_f32_u32(vandq_u32(vshlq_u32(hi, sh), m));
                }
            }
            3 => {
                debug_assert!(bytes.len() >= 12);
                for (k, o) in out.iter_mut().enumerate() {
                    let mut lanes = [0u32; 4];
                    for (j, l) in lanes.iter_mut().enumerate() {
                        let i = 4 * k + j;
                        let c = B3_GOFF[i] as usize;
                        let w = u32::from_le_bytes(bytes[c..c + 4].try_into().unwrap());
                        *l = (w >> B3_GSH[i]) & 0x7;
                    }
                    *o = vcvtq_f32_u32(vld1q_u32(lanes.as_ptr()));
                }
            }
            4 => {
                debug_assert!(bytes.len() >= 16);
                let m = vdupq_n_u32(0xf);
                for (k, o) in out.iter_mut().enumerate() {
                    let w = u32::from_le_bytes(bytes[4 * (k / 2)..4 * (k / 2) + 4].try_into().unwrap());
                    let sh = vld1q_s32(NSH4[k % 2].as_ptr());
                    *o = vcvtq_f32_u32(vandq_u32(vshlq_u32(vdupq_n_u32(w), sh), m));
                }
            }
            _ => {
                let mut buf = [0f32; 32];
                unpack32_f32(bytes, bits, &mut buf);
                for (k, o) in out.iter_mut().enumerate() {
                    *o = vld1q_f32(buf.as_ptr().add(4 * k));
                }
            }
        }
        out
    }

    #[target_feature(enable = "neon")]
    unsafe fn store8(v: [float32x4_t; 8], out: &mut [f32; 32]) {
        for (k, vk) in v.into_iter().enumerate() {
            vst1q_f32(out.as_mut_ptr().add(4 * k), vk);
        }
    }

    /// NEON arm of [`super::unpack32_b2_f32`].
    ///
    /// # Safety
    /// Requires NEON and `bytes.len() >= 8`.
    #[target_feature(enable = "neon")]
    pub unsafe fn unpack32_b2_f32_neon(bytes: &[u8], out: &mut [f32; 32]) {
        store8(unpack32_ps_neon(bytes, 2), out);
    }

    /// NEON arm of [`super::unpack32_b3_f32`].
    ///
    /// # Safety
    /// Requires NEON and `bytes.len() >= 12`.
    #[target_feature(enable = "neon")]
    pub unsafe fn unpack32_b3_f32_neon(bytes: &[u8], out: &mut [f32; 32]) {
        store8(unpack32_ps_neon(bytes, 3), out);
    }

    /// NEON arm of [`super::unpack32_b4_f32`].
    ///
    /// # Safety
    /// Requires NEON and `bytes.len() >= 16`.
    #[target_feature(enable = "neon")]
    pub unsafe fn unpack32_b4_f32_neon(bytes: &[u8], out: &mut [f32; 32]) {
        store8(unpack32_ps_neon(bytes, 4), out);
    }
}

/// Dispatch-arm store-form f32 unpack: the `isa`-selected variant of
/// [`unpack32_f32`]. This is the enumeration surface the parity tests and
/// the unpacker bench walk; the SIMD GEMV kernels call the
/// register-returning forms directly.
///
/// Falls back to the scalar path when the requested arm is not compiled for
/// this target (the dispatch layer never *selects* such an arm; this keeps
/// the function total for test harnesses that enumerate `Isa::ALL`).
///
/// # Panics
/// Panics if `isa` names an arm the host CPU cannot execute (same contract
/// as the kernel `*_with_isa` entry points).
pub fn unpack32_f32_isa(isa: crate::kernels::dispatch::Isa, bytes: &[u8], bits: u8, out: &mut [f32; 32]) {
    use crate::kernels::dispatch::{is_supported, Isa};
    assert!(is_supported(isa), "ISA '{isa}' not supported on this host/build");
    match isa {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => unsafe {
            match bits {
                2 => x86::unpack32_b2_f32_avx2(bytes, out),
                3 => x86::unpack32_b3_f32_avx2(bytes, out),
                4 => x86::unpack32_b4_f32_avx2(bytes, out),
                _ => unpack32_f32(bytes, bits, out),
            }
        },
        #[cfg(all(target_arch = "x86_64", innerq_avx512))]
        Isa::Avx512 => unsafe {
            match bits {
                2 => x86::unpack32_b2_f32_avx512(bytes, out),
                3 => x86::unpack32_b3_f32_avx512(bytes, out),
                4 => x86::unpack32_b4_f32_avx512(bytes, out),
                _ => unpack32_f32(bytes, bits, out),
            }
        },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe {
            match bits {
                2 => neon::unpack32_b2_f32_neon(bytes, out),
                3 => neon::unpack32_b3_f32_neon(bytes, out),
                4 => neon::unpack32_b4_f32_neon(bytes, out),
                _ => unpack32_f32(bytes, bits, out),
            }
        },
        _ => unpack32_f32(bytes, bits, out),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn round_trip_generic() {
        let mut rng = Rng::new(7);
        for bits in 1..=8u8 {
            for n in [1usize, 5, 31, 32, 33, 100] {
                let codes: Vec<u8> =
                    (0..n).map(|_| (rng.next_u64() & ((1 << bits) - 1)) as u8).collect();
                let mut packed = Vec::new();
                pack(&codes, bits, &mut packed);
                assert_eq!(packed.len(), packed_len(n, bits));
                let mut out = vec![0u8; n];
                unpack(&packed, bits, n, &mut out);
                assert_eq!(codes, out, "bits={bits} n={n}");
            }
        }
    }

    #[test]
    fn fast_paths_match_generic() {
        let mut rng = Rng::new(13);
        for bits in [2u8, 3, 4] {
            for _ in 0..200 {
                let codes: Vec<u8> =
                    (0..32).map(|_| (rng.next_u64() & ((1 << bits) - 1)) as u8).collect();
                let mut packed = Vec::new();
                pack(&codes, bits, &mut packed);
                let mut fast = [0u8; 32];
                unpack32(&packed, bits, &mut fast);
                assert_eq!(&codes[..], &fast[..], "bits={bits}");
            }
        }
    }

    // NOTE: exhaustive f32-vs-generic unpacker parity (all widths, random
    // codes) lives in tests/kernel_parity.rs; only the shift-table edge
    // cases are pinned here.
    #[test]
    fn f32_paths_cover_extreme_codes() {
        // All-zeros and all-max groups hit every shift-table entry.
        for bits in [2u8, 3, 4] {
            let max = (1u16 << bits) as u8 - 1;
            for fill in [0u8, max] {
                let codes = vec![fill; 32];
                let mut packed = Vec::new();
                pack(&codes, bits, &mut packed);
                let mut fast = [0f32; 32];
                unpack32_f32(&packed, bits, &mut fast);
                assert!(fast.iter().all(|&f| f == fill as f32), "bits={bits} fill={fill}");
            }
        }
    }

    #[test]
    fn append_packs_are_independent() {
        // Packing two groups back-to-back into one Vec must not interleave.
        let g1: Vec<u8> = (0..32).map(|i| (i % 8) as u8).collect();
        let g2: Vec<u8> = (0..32).map(|i| (7 - i % 8) as u8).collect();
        let mut buf = Vec::new();
        pack(&g1, 3, &mut buf);
        let off = buf.len();
        pack(&g2, 3, &mut buf);
        let mut o1 = [0u8; 32];
        let mut o2 = [0u8; 32];
        unpack32_b3(&buf[..off], &mut o1);
        unpack32_b3(&buf[off..], &mut o2);
        assert_eq!(&g1[..], &o1[..]);
        assert_eq!(&g2[..], &o2[..]);
    }

    #[test]
    fn packed_len_matches_paper_group_bytes() {
        // G=32: 2-bit -> 8B, 3-bit -> 12B, 4-bit -> 16B.
        assert_eq!(packed_len(32, 2), 8);
        assert_eq!(packed_len(32, 3), 12);
        assert_eq!(packed_len(32, 4), 16);
    }
}
