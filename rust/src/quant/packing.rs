//! Physical b-bit code packing.
//!
//! A quantization group of `G` codes (b ∈ {2,3,4} bits each) is stored as a
//! little-endian bitstream of `G*b/8` bytes. With the paper's G=32 this is
//! 8 / 12 / 16 bytes per group — small enough that the fused GEMV kernels
//! unpack a whole group with two u64 loads and shifts, never touching memory
//! for intermediates.
//!
//! Codes here are *raw* (unsigned, already biased for symmetric mode); the
//! signed/zero-point interpretation lives in [`crate::quant::group`].

/// Bytes needed to pack `n` codes of `bits` bits.
#[inline]
pub const fn packed_len(n: usize, bits: u8) -> usize {
    (n * bits as usize + 7) / 8
}

/// Pack `codes` (each < 2^bits) into a little-endian bitstream appended to `out`.
pub fn pack(codes: &[u8], bits: u8, out: &mut Vec<u8>) {
    debug_assert!(matches!(bits, 1..=8));
    let start = out.len();
    out.resize(start + packed_len(codes.len(), bits), 0);
    let dst = &mut out[start..];
    let b = bits as usize;
    for (i, &c) in codes.iter().enumerate() {
        debug_assert!((c as u16) < (1u16 << bits), "code {c} out of range for {bits} bits");
        let bitpos = i * b;
        let byte = bitpos / 8;
        let off = bitpos % 8;
        let v = (c as u16) << off;
        dst[byte] |= (v & 0xff) as u8;
        if off + b > 8 {
            dst[byte + 1] |= (v >> 8) as u8;
        }
    }
}

/// Unpack `n` codes from a little-endian bitstream (generic path).
pub fn unpack(bytes: &[u8], bits: u8, n: usize, out: &mut [u8]) {
    debug_assert!(out.len() >= n);
    let b = bits as usize;
    for (i, o) in out.iter_mut().enumerate().take(n) {
        let bitpos = i * b;
        let byte = bitpos / 8;
        let off = bitpos % 8;
        let mut v = bytes[byte] as u16 >> off;
        if off + b > 8 {
            v |= (bytes[byte + 1] as u16) << (8 - off);
        }
        *o = (v & ((1u16 << bits) - 1)) as u8;
    }
}

/// Fast path: unpack one 32-code group of 2-bit codes (8 bytes).
#[inline(always)]
pub fn unpack32_b2(bytes: &[u8], out: &mut [u8; 32]) {
    debug_assert!(bytes.len() >= 8);
    let w = u64::from_le_bytes(bytes[..8].try_into().unwrap());
    for i in 0..32 {
        out[i] = ((w >> (2 * i)) & 0x3) as u8;
    }
}

/// Fast path: unpack one 32-code group of 3-bit codes (12 bytes).
///
/// Two *overlapping* u64 loads eliminate the bit-63 straddle: codes 0..=10
/// live entirely in bytes[0..8] and codes 11..=31 in bytes[4..12] (bit 33
/// onward), so both loops are branchless constant-shift extracts.
#[inline(always)]
pub fn unpack32_b3(bytes: &[u8], out: &mut [u8; 32]) {
    debug_assert!(bytes.len() >= 12);
    let lo = u64::from_le_bytes(bytes[..8].try_into().unwrap());
    let hi = u64::from_le_bytes(bytes[4..12].try_into().unwrap());
    for i in 0..11 {
        out[i] = ((lo >> (3 * i)) & 0x7) as u8;
    }
    for i in 11..32 {
        out[i] = ((hi >> (3 * i - 32)) & 0x7) as u8;
    }
}

/// Fast path: unpack one 32-code group of 4-bit codes (16 bytes).
#[inline(always)]
pub fn unpack32_b4(bytes: &[u8], out: &mut [u8; 32]) {
    debug_assert!(bytes.len() >= 16);
    for (j, chunk) in bytes[..16].chunks_exact(8).enumerate() {
        let w = u64::from_le_bytes(chunk.try_into().unwrap());
        for i in 0..16 {
            out[16 * j + i] = ((w >> (4 * i)) & 0xf) as u8;
        }
    }
}

/// Dispatch the 32-wide fast unpack by bit-width.
#[inline(always)]
pub fn unpack32(bytes: &[u8], bits: u8, out: &mut [u8; 32]) {
    match bits {
        2 => unpack32_b2(bytes, out),
        3 => unpack32_b3(bytes, out),
        4 => unpack32_b4(bytes, out),
        _ => unpack(bytes, bits, 32, out),
    }
}

// ---------------------------------------------------------------------------
// f32-producing fast paths for the fused GEMV kernels.
//
// The blocked kernels multiply codes straight into f32 accumulators, so the
// u8 bounce buffer of `unpack32` is pure overhead there: every group would
// pay a store-to-[u8;32] + reload + widen before the first FMA. These
// variants extract with the same two-u64-load scheme and convert in the same
// exact-trip-count loop, producing a `[f32; 32]` the dot-product loops
// consume directly. The u64→f32 path is exact (codes < 16), so kernels built
// on these are bit-identical to ones built on the u8 unpackers.
// ---------------------------------------------------------------------------

/// Shift tables for the 3-bit path: code `i` lives at bit `3*i` of the
/// 12-byte group. Codes 0..=10 fit in the low u64 (bits 0..33); codes
/// 11..=31 are read from the overlapping high u64 loaded at byte 4 (their
/// shifts are `3*i - 32`). Const tables keep both loops exact-trip-count
/// with table-driven shifts instead of per-iteration shift arithmetic.
const B3_SHIFT_LO: [u32; 11] = [0, 3, 6, 9, 12, 15, 18, 21, 24, 27, 30];
const B3_SHIFT_HI: [u32; 21] = [
    1, 4, 7, 10, 13, 16, 19, 22, 25, 28, 31, 34, 37, 40, 43, 46, 49, 52, 55, 58, 61,
];

/// Fast path: unpack one 32-code group of 2-bit codes (8 bytes) to f32.
#[inline(always)]
pub fn unpack32_b2_f32(bytes: &[u8], out: &mut [f32; 32]) {
    debug_assert!(bytes.len() >= 8);
    let w = u64::from_le_bytes(bytes[..8].try_into().unwrap());
    for i in 0..32 {
        out[i] = ((w >> (2 * i)) & 0x3) as f32;
    }
}

/// Fast path: unpack one 32-code group of 3-bit codes (12 bytes) to f32.
#[inline(always)]
pub fn unpack32_b3_f32(bytes: &[u8], out: &mut [f32; 32]) {
    debug_assert!(bytes.len() >= 12);
    let lo = u64::from_le_bytes(bytes[..8].try_into().unwrap());
    let hi = u64::from_le_bytes(bytes[4..12].try_into().unwrap());
    for i in 0..11 {
        out[i] = ((lo >> B3_SHIFT_LO[i]) & 0x7) as f32;
    }
    for i in 0..21 {
        out[11 + i] = ((hi >> B3_SHIFT_HI[i]) & 0x7) as f32;
    }
}

/// Fast path: unpack one 32-code group of 4-bit codes (16 bytes) to f32.
#[inline(always)]
pub fn unpack32_b4_f32(bytes: &[u8], out: &mut [f32; 32]) {
    debug_assert!(bytes.len() >= 16);
    let lo = u64::from_le_bytes(bytes[..8].try_into().unwrap());
    let hi = u64::from_le_bytes(bytes[8..16].try_into().unwrap());
    for i in 0..16 {
        out[i] = ((lo >> (4 * i)) & 0xf) as f32;
    }
    for i in 0..16 {
        out[16 + i] = ((hi >> (4 * i)) & 0xf) as f32;
    }
}

/// Dispatch the 32-wide f32 fast unpack by bit-width. The generic (bit-loop)
/// path is kept as the reference for other widths.
#[inline(always)]
pub fn unpack32_f32(bytes: &[u8], bits: u8, out: &mut [f32; 32]) {
    match bits {
        2 => unpack32_b2_f32(bytes, out),
        3 => unpack32_b3_f32(bytes, out),
        4 => unpack32_b4_f32(bytes, out),
        _ => {
            let mut raw = [0u8; 32];
            unpack(bytes, bits, 32, &mut raw);
            for i in 0..32 {
                out[i] = raw[i] as f32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn round_trip_generic() {
        let mut rng = Rng::new(7);
        for bits in 1..=8u8 {
            for n in [1usize, 5, 31, 32, 33, 100] {
                let codes: Vec<u8> =
                    (0..n).map(|_| (rng.next_u64() & ((1 << bits) - 1)) as u8).collect();
                let mut packed = Vec::new();
                pack(&codes, bits, &mut packed);
                assert_eq!(packed.len(), packed_len(n, bits));
                let mut out = vec![0u8; n];
                unpack(&packed, bits, n, &mut out);
                assert_eq!(codes, out, "bits={bits} n={n}");
            }
        }
    }

    #[test]
    fn fast_paths_match_generic() {
        let mut rng = Rng::new(13);
        for bits in [2u8, 3, 4] {
            for _ in 0..200 {
                let codes: Vec<u8> =
                    (0..32).map(|_| (rng.next_u64() & ((1 << bits) - 1)) as u8).collect();
                let mut packed = Vec::new();
                pack(&codes, bits, &mut packed);
                let mut fast = [0u8; 32];
                unpack32(&packed, bits, &mut fast);
                assert_eq!(&codes[..], &fast[..], "bits={bits}");
            }
        }
    }

    // NOTE: exhaustive f32-vs-generic unpacker parity (all widths, random
    // codes) lives in tests/kernel_parity.rs; only the shift-table edge
    // cases are pinned here.
    #[test]
    fn f32_paths_cover_extreme_codes() {
        // All-zeros and all-max groups hit every shift-table entry.
        for bits in [2u8, 3, 4] {
            let max = (1u16 << bits) as u8 - 1;
            for fill in [0u8, max] {
                let codes = vec![fill; 32];
                let mut packed = Vec::new();
                pack(&codes, bits, &mut packed);
                let mut fast = [0f32; 32];
                unpack32_f32(&packed, bits, &mut fast);
                assert!(fast.iter().all(|&f| f == fill as f32), "bits={bits} fill={fill}");
            }
        }
    }

    #[test]
    fn append_packs_are_independent() {
        // Packing two groups back-to-back into one Vec must not interleave.
        let g1: Vec<u8> = (0..32).map(|i| (i % 8) as u8).collect();
        let g2: Vec<u8> = (0..32).map(|i| (7 - i % 8) as u8).collect();
        let mut buf = Vec::new();
        pack(&g1, 3, &mut buf);
        let off = buf.len();
        pack(&g2, 3, &mut buf);
        let mut o1 = [0u8; 32];
        let mut o2 = [0u8; 32];
        unpack32_b3(&buf[..off], &mut o1);
        unpack32_b3(&buf[off..], &mut o2);
        assert_eq!(&g1[..], &o1[..]);
        assert_eq!(&g2[..], &o2[..]);
    }

    #[test]
    fn packed_len_matches_paper_group_bytes() {
        // G=32: 2-bit -> 8B, 3-bit -> 12B, 4-bit -> 16B.
        assert_eq!(packed_len(32, 2), 8);
        assert_eq!(packed_len(32, 3), 12);
        assert_eq!(packed_len(32, 4), 16);
    }
}
