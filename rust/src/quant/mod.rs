//! Core quantization library: grouping layouts, group quantizers
//! (symmetric / asymmetric / hybrid), physical bit packing, per-channel key
//! normalization, the TurboQuant baseline, and effective-bit-width
//! accounting (Table 3).

pub mod bitwidth;
pub mod group;
pub mod norm;
pub mod packing;
pub mod turbo;

pub use group::{GroupParams, Mode};

/// Which axis quantization groups run along, relative to the decode GEMV.
///
/// `Inner` groups run along the reduction dimension (InnerQ: per-token groups
/// for K, per-channel groups for V) so one scale covers a contiguous run of
/// the dot product. `Outer` groups run along the output dimension (KIVI:
/// per-channel groups for K, per-token groups for V).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Grouping {
    /// Groups along the GEMV reduction axis (InnerQ).
    Inner,
    /// Groups along the GEMV output axis (KIVI).
    Outer,
}

/// The methods evaluated in the paper (Tables 1–7).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum QuantMethod {
    /// Unquantized FP16-storage baseline.
    BaselineFp16,
    /// KIVI: 2-bit asymmetric, outer grouping, no sink window.
    Kivi,
    /// KIVI plus the 32-token attention-sink window.
    KiviSink,
    /// TurboQuant: random rotation + Lloyd–Max codebooks (4-bit K / 3-bit V).
    TurboQuant,
    /// InnerQ: 3-bit symmetric, inner grouping, key norm (§4.4).
    InnerQBase,
    /// InnerQ with 2-bit hybrid-mode values (§4.1.2).
    InnerQHybrid,
    /// InnerQ with 2-bit symmetric values (smallest footprint).
    InnerQSmall,
}

impl QuantMethod {
    /// Every method, in the paper's table order.
    pub const ALL: [QuantMethod; 7] = [
        QuantMethod::BaselineFp16,
        QuantMethod::Kivi,
        QuantMethod::KiviSink,
        QuantMethod::TurboQuant,
        QuantMethod::InnerQBase,
        QuantMethod::InnerQHybrid,
        QuantMethod::InnerQSmall,
    ];

    /// Stable CLI/report name of the method.
    pub fn name(self) -> &'static str {
        match self {
            QuantMethod::BaselineFp16 => "baseline_fp16",
            QuantMethod::Kivi => "kivi",
            QuantMethod::KiviSink => "kivi_sink",
            QuantMethod::TurboQuant => "turboquant",
            QuantMethod::InnerQBase => "innerq_base",
            QuantMethod::InnerQHybrid => "innerq_hybrid",
            QuantMethod::InnerQSmall => "innerq_small",
        }
    }

    /// Parse a method from its [`QuantMethod::name`].
    pub fn parse(s: &str) -> Option<QuantMethod> {
        QuantMethod::ALL.iter().copied().find(|m| m.name() == s)
    }

    /// The per-method configuration used throughout the paper's evaluation
    /// (§5.1: G=32, total high-precision window 128; InnerQ/KIVI_Sink split
    /// it 32 sink + 96 recent, KIVI keeps all 128 recent).
    pub fn config(self) -> MethodConfig {
        let base = MethodConfig {
            method: self,
            group_size: 32,
            w_sink: 32,
            w_recent: 96,
            key_bits: 3,
            val_bits: 3,
            key_mode: Mode::Sym,
            val_mode: Mode::Sym,
            key_grouping: Grouping::Inner,
            val_grouping: Grouping::Inner,
            key_norm: true,
            turbo: false,
        };
        match self {
            QuantMethod::BaselineFp16 => MethodConfig {
                key_bits: 16,
                val_bits: 16,
                key_norm: false,
                w_sink: 0,
                w_recent: 0,
                ..base
            },
            QuantMethod::Kivi => MethodConfig {
                key_bits: 2,
                val_bits: 2,
                key_mode: Mode::Asym,
                val_mode: Mode::Asym,
                key_grouping: Grouping::Outer,
                val_grouping: Grouping::Outer,
                key_norm: false,
                w_sink: 0,
                w_recent: 128,
                ..base
            },
            QuantMethod::KiviSink => MethodConfig {
                key_bits: 2,
                val_bits: 2,
                key_mode: Mode::Asym,
                val_mode: Mode::Asym,
                key_grouping: Grouping::Outer,
                val_grouping: Grouping::Outer,
                key_norm: false,
                ..base
            },
            QuantMethod::TurboQuant => MethodConfig {
                key_bits: 4,
                val_bits: 3,
                key_norm: false,
                turbo: true,
                w_sink: 0,
                w_recent: 128,
                ..base
            },
            QuantMethod::InnerQBase => base,
            QuantMethod::InnerQHybrid => {
                MethodConfig { val_bits: 2, val_mode: Mode::Hybrid, ..base }
            }
            QuantMethod::InnerQSmall => MethodConfig { val_bits: 2, ..base },
        }
    }
}

/// Full quantization configuration for one run. Produced by
/// [`QuantMethod::config`] for the paper's setups; the ablation harnesses
/// (Table 7, Fig. 5) construct modified copies directly.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MethodConfig {
    /// The named method this configuration was derived from.
    pub method: QuantMethod,
    /// Quantization group size G (the paper evaluates G=32 throughout).
    pub group_size: usize,
    /// First `w_sink` tokens kept in high precision (attention sinks, §4.2).
    pub w_sink: usize,
    /// Most recent `w_recent` tokens kept in high precision.
    pub w_recent: usize,
    /// Key-cache bit-width per code.
    pub key_bits: u8,
    /// Value-cache bit-width per code.
    pub val_bits: u8,
    /// Key group quantization mode (symmetric / asymmetric / hybrid).
    pub key_mode: Mode,
    /// Value group quantization mode.
    pub val_mode: Mode,
    /// Which axis key groups run along (see [`Grouping`]).
    pub key_grouping: Grouping,
    /// Which axis value groups run along.
    pub val_grouping: Grouping,
    /// Per-channel normalization of K (§4.3) — InnerQ variants only.
    pub key_norm: bool,
    /// TurboQuant pipeline (rotation + codebook) instead of uniform groups.
    pub turbo: bool,
}

impl MethodConfig {
    /// False only for the FP16 baseline (no quantized segments at all).
    pub fn is_quantized(&self) -> bool {
        self.method != QuantMethod::BaselineFp16
    }
    /// Whether the stored key segment carries zero-points.
    pub fn key_has_zeros(&self) -> bool {
        !self.turbo && matches!(self.key_mode, Mode::Asym | Mode::Hybrid)
    }
    /// Whether the stored value segment carries zero-points.
    pub fn val_has_zeros(&self) -> bool {
        !self.turbo && matches!(self.val_mode, Mode::Asym | Mode::Hybrid)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_names_round_trip() {
        for m in QuantMethod::ALL {
            assert_eq!(QuantMethod::parse(m.name()), Some(m));
        }
        assert_eq!(QuantMethod::parse("nope"), None);
    }

    #[test]
    fn paper_configs() {
        // §5.1: KIVI w_sink=0/w_recent=128; KIVI_Sink & InnerQ 32/96.
        assert_eq!(QuantMethod::Kivi.config().w_sink, 0);
        assert_eq!(QuantMethod::Kivi.config().w_recent, 128);
        assert_eq!(QuantMethod::KiviSink.config().w_sink, 32);
        assert_eq!(QuantMethod::InnerQBase.config().w_recent, 96);
        // §4.4: K is 3-bit symmetric in all InnerQ variants.
        for m in [QuantMethod::InnerQBase, QuantMethod::InnerQHybrid, QuantMethod::InnerQSmall] {
            let c = m.config();
            assert_eq!(c.key_bits, 3);
            assert_eq!(c.key_mode, Mode::Sym);
            assert_eq!(c.key_grouping, Grouping::Inner);
            assert!(c.key_norm);
        }
        assert_eq!(QuantMethod::InnerQHybrid.config().val_mode, Mode::Hybrid);
        assert_eq!(QuantMethod::InnerQHybrid.config().val_bits, 2);
        assert_eq!(QuantMethod::InnerQSmall.config().val_mode, Mode::Sym);
        // TurboQuant: 4-bit K / 3-bit V (§5.1).
        let t = QuantMethod::TurboQuant.config();
        assert!(t.turbo);
        assert_eq!((t.key_bits, t.val_bits), (4, 3));
    }

    #[test]
    fn zero_point_presence() {
        assert!(QuantMethod::Kivi.config().key_has_zeros());
        assert!(!QuantMethod::InnerQBase.config().key_has_zeros());
        assert!(!QuantMethod::InnerQBase.config().val_has_zeros());
        assert!(QuantMethod::InnerQHybrid.config().val_has_zeros());
        assert!(!QuantMethod::TurboQuant.config().key_has_zeros());
    }
}
