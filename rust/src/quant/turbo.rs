//! TurboQuant baseline (Zandieh et al., ICLR'26): data-oblivious vector
//! quantization — a random rotation concentrates coordinates, which are then
//! quantized with a precomputed *non-uniform* optimal scalar quantizer.
//!
//! We implement the MSE variant the paper compares against: a randomized
//! fast Walsh–Hadamard rotation (H·D, D = random ±1 diagonal; orthogonal, so
//! inner products are preserved and the *query* can be rotated once per step
//! instead of dequantizing into the original basis), per-token norm scaling
//! to a ~unit-variance coordinate distribution, and Lloyd–Max codebooks for
//! the standard normal at 3 and 4 bits. Effective bit-widths follow the
//! paper's Table 3 accounting: 4-bit keys, 3-bit values, +0.25 bits of f32
//! norm overhead per number.

use crate::quant::packing;

/// Lloyd–Max (minimum-MSE) quantizer levels for N(0,1), 8 levels (3-bit).
/// Max (1960), symmetric: levels listed from most negative to most positive.
pub const GAUSS_CODEBOOK_3B: [f32; 8] = [
    -2.1520, -1.3439, -0.7560, -0.2451, 0.2451, 0.7560, 1.3439, 2.1520,
];

/// Lloyd–Max quantizer levels for N(0,1), 16 levels (4-bit).
pub const GAUSS_CODEBOOK_4B: [f32; 16] = [
    -2.7326, -2.0690, -1.6181, -1.2562, -0.9423, -0.6568, -0.3880, -0.1284,
    0.1284, 0.3880, 0.6568, 0.9423, 1.2562, 1.6181, 2.0690, 2.7326,
];

/// The Lloyd–Max codebook for `bits` ∈ {3, 4}; panics otherwise.
pub fn codebook(bits: u8) -> &'static [f32] {
    match bits {
        3 => &GAUSS_CODEBOOK_3B,
        4 => &GAUSS_CODEBOOK_4B,
        _ => panic!("turbo codebooks exist for 3 and 4 bits only"),
    }
}

/// In-place fast Walsh–Hadamard transform; `x.len()` must be a power of two.
/// Normalized by 1/sqrt(n) so the transform is orthonormal.
pub fn fwht(x: &mut [f32]) {
    let n = x.len();
    assert!(n.is_power_of_two(), "FWHT needs power-of-two length");
    let mut h = 1;
    while h < n {
        for i in (0..n).step_by(h * 2) {
            for j in i..i + h {
                let (a, b) = (x[j], x[j + h]);
                x[j] = a + b;
                x[j + h] = a - b;
            }
        }
        h *= 2;
    }
    let s = 1.0 / (n as f32).sqrt();
    for v in x {
        *v *= s;
    }
}

/// The fixed random rotation R = H·D for one head dimension.
#[derive(Debug, Clone, PartialEq)]
pub struct Rotation {
    /// Random ±1 signs (diagonal D), derived deterministically from a seed so
    /// Rust and the Python reference use the same rotation.
    pub signs: Vec<f32>,
}

impl Rotation {
    /// Derive the ±1 diagonal for head dimension `d_h` (a power of two)
    /// deterministically from `seed`.
    pub fn new(d_h: usize, seed: u64) -> Rotation {
        assert!(d_h.is_power_of_two());
        let mut rng = crate::util::rng::Rng::new(seed);
        let signs = (0..d_h)
            .map(|_| if rng.next_u64() & 1 == 0 { 1.0 } else { -1.0 })
            .collect();
        Rotation { signs }
    }

    /// y = H·D·x (orthonormal).
    pub fn apply(&self, x: &mut [f32]) {
        for (v, &s) in x.iter_mut().zip(&self.signs) {
            *v *= s;
        }
        fwht(x);
    }
}

/// One TurboQuant-encoded token vector: packed codebook indices plus an f32
/// per-token norm (the "channel norm" budget line in Table 3).
#[derive(Debug, Clone, PartialEq)]
pub struct TurboToken {
    /// Packed `bits`-bit codebook indices, `d_h` of them.
    pub codes: Vec<u8>,
    /// Per-token scale: rotated coordinates / norm ≈ N(0,1).
    pub norm: f32,
}

/// Quantize one already-rotated vector.
pub fn quantize_rotated(rot: &[f32], bits: u8) -> TurboToken {
    let d = rot.len();
    let cb = codebook(bits);
    // Scale so coordinates are ~unit variance: rms of the rotated vector.
    let rms = (rot.iter().map(|v| v * v).sum::<f32>() / d as f32).sqrt();
    let norm = if rms > 1e-12 { rms } else { 1.0 };
    let inv = 1.0 / norm;
    let mut idx = vec![0u8; d];
    for (i, &v) in rot.iter().enumerate() {
        idx[i] = nearest_code(cb, v * inv);
    }
    let mut codes = Vec::with_capacity(packing::packed_len(d, bits));
    packing::pack(&idx, bits, &mut codes);
    TurboToken { codes, norm }
}

/// Rotate (with `rotation`) then quantize one token vector.
pub fn quantize_token(rotation: &Rotation, vals: &[f32], bits: u8) -> TurboToken {
    let mut x = vals.to_vec();
    rotation.apply(&mut x);
    quantize_rotated(&x, bits)
}

/// Dequantize into the *rotated* basis (scores/outputs are computed there;
/// the rotation is orthogonal so no un-rotation is needed for dot products).
pub fn dequantize_rotated(tok: &TurboToken, bits: u8, d_h: usize, out: &mut [f32]) {
    let cb = codebook(bits);
    let mut idx = vec![0u8; d_h];
    packing::unpack(&tok.codes, bits, d_h, &mut idx);
    for (o, &i) in out.iter_mut().zip(&idx) {
        *o = cb[i as usize] * tok.norm;
    }
}

/// Binary search the (sorted) codebook for the nearest level.
#[inline]
fn nearest_code(cb: &[f32], v: f32) -> u8 {
    // midpoints are the decision thresholds of a Lloyd-Max quantizer
    let mut lo = 0usize;
    let mut hi = cb.len() - 1;
    while lo < hi {
        let mid = (lo + hi) / 2;
        let threshold = 0.5 * (cb[mid] + cb[mid + 1]);
        if v <= threshold {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    lo as u8
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ptest::{check, normal_vec, PropCfg};
    use crate::util::rng::Rng;

    #[test]
    fn fwht_is_orthonormal() {
        // Applying the normalized FWHT twice is the identity.
        let mut rng = Rng::new(3);
        let orig = normal_vec(&mut rng, 128, 1.0, 0.0);
        let mut x = orig.clone();
        fwht(&mut x);
        fwht(&mut x);
        for (a, b) in orig.iter().zip(&x) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn rotation_preserves_inner_products() {
        check("rotation preserves <q,k>", PropCfg::default(), |rng, _| {
            let d = 128;
            let rot = Rotation::new(d, 42);
            let q = normal_vec(rng, d, 1.0, 0.0);
            let k = normal_vec(rng, d, 1.0, 0.0);
            let dot0: f32 = q.iter().zip(&k).map(|(a, b)| a * b).sum();
            let (mut qr, mut kr) = (q.clone(), k.clone());
            rot.apply(&mut qr);
            rot.apply(&mut kr);
            let dot1: f32 = qr.iter().zip(&kr).map(|(a, b)| a * b).sum();
            assert!((dot0 - dot1).abs() < 1e-2 * dot0.abs().max(1.0));
        });
    }

    #[test]
    fn nearest_code_matches_linear_scan() {
        let mut rng = Rng::new(9);
        for bits in [3u8, 4] {
            let cb = codebook(bits);
            for _ in 0..500 {
                let v = rng.next_normal() * 2.0;
                let fast = nearest_code(cb, v) as usize;
                let slow = cb
                    .iter()
                    .enumerate()
                    .min_by(|(_, a), (_, b)| {
                        (v - **a).abs().partial_cmp(&(v - **b).abs()).unwrap()
                    })
                    .unwrap()
                    .0;
                assert!(
                    (cb[fast] - v).abs() <= (cb[slow] - v).abs() + 1e-6,
                    "v={v} fast={fast} slow={slow}"
                );
            }
        }
    }

    #[test]
    fn codebooks_are_near_lloyd_max_fixed_points() {
        // One Lloyd iteration over a dense Gaussian sample should barely move
        // the hardcoded levels (they are the Max-1960 optima).
        for bits in [3u8, 4] {
            let cb = codebook(bits).to_vec();
            let mut sums = vec![0.0f64; cb.len()];
            let mut cnts = vec![0.0f64; cb.len()];
            let n = 200_000;
            let mut rng = Rng::new(2024);
            for _ in 0..n {
                let v = rng.next_normal();
                let i = nearest_code(&cb, v) as usize;
                sums[i] += v as f64;
                cnts[i] += 1.0;
            }
            for i in 0..cb.len() {
                if cnts[i] > 100.0 {
                    let centroid = (sums[i] / cnts[i]) as f32;
                    assert!(
                        (centroid - cb[i]).abs() < 0.05,
                        "bits={bits} level {i}: centroid {centroid} vs {}",
                        cb[i]
                    );
                }
            }
        }
    }

    #[test]
    fn round_trip_error_reasonable() {
        // 4-bit Lloyd-Max on N(0,1) has MSE ~0.0095 (distortion-rate); check
        // our end-to-end token path is in that ballpark (rotation + rms norm).
        let mut rng = Rng::new(5);
        let d = 128;
        let rot = Rotation::new(d, 42);
        let mut total = 0.0f64;
        let mut count = 0usize;
        for _ in 0..50 {
            let vals = normal_vec(&mut rng, d, 1.0, 0.05);
            let tok = quantize_token(&rot, &vals, 4);
            let mut deq = vec![0f32; d];
            dequantize_rotated(&tok, 4, d, &mut deq);
            let mut rotated = vals.clone();
            rot.apply(&mut rotated);
            for (a, b) in rotated.iter().zip(&deq) {
                total += ((a - b) * (a - b)) as f64;
                count += 1;
            }
        }
        let var: f64 = 1.0; // roughly unit-variance inputs
        let mse = total / count as f64;
        assert!(mse / var < 0.05, "4-bit turbo MSE too high: {mse}");
    }
}
