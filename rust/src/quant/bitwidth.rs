//! Effective bit-width accounting (Table 3).
//!
//! Per-number overheads: an FP16 scale (or zero-point) shared by a group of
//! G=32 contributes 16/32 = 0.5 bits; TurboQuant's FP32 channel norms shared
//! across a head dimension of 128 contribute 32/128 = 0.25 bits. The hybrid
//! variant stores its zero-point matrix densely even though the mask M is
//! ~99% sparse (§5.2), so it budgets the full 0.5 bits.

use super::{MethodConfig, QuantMethod};

/// Bit-width breakdown for one cache (key or value).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CacheBits {
    /// Bits per number spent on the integer codes themselves.
    pub integer: f64,
    /// Amortized per-number bits of the f16 group scales.
    pub scale_overhead: f64,
    /// Amortized per-number bits of the f16 zero-points (0 if absent).
    pub zero_overhead: f64,
    /// Amortized per-number bits of TurboQuant's f32 per-token norms.
    pub norm_overhead: f64,
}

impl CacheBits {
    /// Effective bits per number: codes plus all amortized overheads.
    pub fn total(&self) -> f64 {
        self.integer + self.scale_overhead + self.zero_overhead + self.norm_overhead
    }
}

/// Full Table-3 row for a method.
#[derive(Debug, Clone, Copy)]
pub struct BitWidthRow {
    /// The method this row describes.
    pub method: QuantMethod,
    /// Key-cache breakdown.
    pub key: CacheBits,
    /// Value-cache breakdown.
    pub val: CacheBits,
}

impl BitWidthRow {
    /// Per-number effective bit-width (key and value averaged, as Table 3).
    pub fn effective(&self) -> f64 {
        0.5 * (self.key.total() + self.val.total())
    }
}

/// Compute the Table-3 accounting for a method at head dimension `d_h`.
pub fn bit_width(cfg: &MethodConfig, d_h: usize) -> BitWidthRow {
    let g = cfg.group_size as f64;
    let cache = |bits: u8, has_zeros: bool| -> CacheBits {
        if cfg.turbo {
            CacheBits {
                integer: bits as f64,
                scale_overhead: 0.0,
                zero_overhead: 0.0,
                // FP32 channel norms amortized over the head dimension.
                norm_overhead: 32.0 / d_h as f64,
            }
        } else if !cfg.is_quantized() {
            CacheBits { integer: 16.0, scale_overhead: 0.0, zero_overhead: 0.0, norm_overhead: 0.0 }
        } else {
            CacheBits {
                integer: bits as f64,
                scale_overhead: 16.0 / g,
                zero_overhead: if has_zeros { 16.0 / g } else { 0.0 },
                norm_overhead: 0.0,
            }
        }
    };
    BitWidthRow {
        method: cfg.method,
        key: cache(cfg.key_bits, cfg.key_has_zeros()),
        val: cache(cfg.val_bits, cfg.val_has_zeros()),
    }
}

/// All Table-3 rows at the paper's reference dimensions (G=32, d_h=128).
pub fn table3() -> Vec<BitWidthRow> {
    [
        QuantMethod::Kivi,
        QuantMethod::TurboQuant,
        QuantMethod::InnerQBase,
        QuantMethod::InnerQHybrid,
        QuantMethod::InnerQSmall,
    ]
    .iter()
    .map(|m| bit_width(&m.config(), 128))
    .collect()
}

/// Bytes needed to store a `n_tokens x d_h` cache at this effective width
/// (used by the cache pool for memory accounting).
pub fn cache_bytes(bits_per_number: f64, n_tokens: usize, d_h: usize) -> usize {
    ((bits_per_number * (n_tokens * d_h) as f64) / 8.0).ceil() as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(m: QuantMethod) -> BitWidthRow {
        bit_width(&m.config(), 128)
    }

    #[test]
    fn table3_matches_paper_exactly() {
        // Paper Table 3, bottom row: KIVI 3, TurboQuant 3.75, InnerQ_Base 3.5,
        // InnerQ_Hybrid 3.25, InnerQ_Small 3.
        assert_eq!(row(QuantMethod::Kivi).effective(), 3.0);
        assert_eq!(row(QuantMethod::TurboQuant).effective(), 3.75);
        assert_eq!(row(QuantMethod::InnerQBase).effective(), 3.5);
        assert_eq!(row(QuantMethod::InnerQHybrid).effective(), 3.25);
        assert_eq!(row(QuantMethod::InnerQSmall).effective(), 3.0);
    }

    #[test]
    fn table3_component_cells() {
        // Spot-check individual cells of Table 3.
        let kivi = row(QuantMethod::Kivi);
        assert_eq!(kivi.key.integer, 2.0);
        assert_eq!(kivi.key.scale_overhead, 0.5);
        assert_eq!(kivi.key.zero_overhead, 0.5);
        let turbo = row(QuantMethod::TurboQuant);
        assert_eq!(turbo.key.integer, 4.0);
        assert_eq!(turbo.key.norm_overhead, 0.25);
        assert_eq!(turbo.val.integer, 3.0);
        let hybrid = row(QuantMethod::InnerQHybrid);
        assert_eq!(hybrid.val.integer, 2.0);
        assert_eq!(hybrid.val.zero_overhead, 0.5, "dense zero-points budgeted");
        let base = row(QuantMethod::InnerQBase);
        assert_eq!(base.key.zero_overhead, 0.0, "symmetric keys carry no zeros");
    }

    #[test]
    fn baseline_is_16_bits() {
        assert_eq!(row(QuantMethod::BaselineFp16).effective(), 16.0);
    }

    #[test]
    fn cache_bytes_scaling() {
        // 4096 tokens x 128 ch at 3.5 bits = 4096*128*3.5/8 bytes.
        assert_eq!(cache_bytes(3.5, 4096, 128), 229_376);
        // FP16 is exactly 2 bytes per number.
        assert_eq!(cache_bytes(16.0, 10, 128), 2560);
    }
}
