//! Per-channel normalization of the key cache (§4.3).
//!
//! `norm_k = sqrt(max |K[:, :, k]|)` is computed once at the end of prefill.
//! The paper folds the vector into `W_Q` and `W_K`; our weights are baked
//! into AOT-compiled HLO artifacts, so we apply the mathematically identical
//! fold at the cache boundary instead: keys are divided by `norm` when they
//! enter the cache and queries are multiplied by `norm` before the score
//! GEMV. Cost is O(d_h) per token — the same "hidden during decode" property
//! (the projection GEMM it would otherwise be folded into is O(d·d_h)).

/// Per-channel normalization vector for one KV head.
#[derive(Debug, Clone, PartialEq)]
pub struct ChannelNorm {
    /// `norm_k`, multiplied into the query on the score side.
    pub scale: Vec<f32>,
    /// `1/norm_k`, multiplied into keys as they enter the cache.
    pub inv_scale: Vec<f32>,
}

impl ChannelNorm {
    /// Identity normalization (used when the method disables key norm).
    pub fn identity(d_h: usize) -> ChannelNorm {
        ChannelNorm { scale: vec![1.0; d_h], inv_scale: vec![1.0; d_h] }
    }

    /// Compute from the prefill keys of one head: `keys` is `n_tokens` rows
    /// of `d_h` channels, flattened row-major.
    pub fn from_prefill_keys(keys: &[f32], d_h: usize) -> ChannelNorm {
        assert_eq!(keys.len() % d_h, 0, "keys must be n_tokens x d_h");
        let mut amax = vec![0.0f32; d_h];
        for row in keys.chunks_exact(d_h) {
            for (m, &v) in amax.iter_mut().zip(row) {
                *m = m.max(v.abs());
            }
        }
        let scale: Vec<f32> = amax
            .iter()
            .map(|&m| if m > 1e-12 { m.sqrt() } else { 1.0 })
            .collect();
        let inv_scale = scale.iter().map(|&s| 1.0 / s).collect();
        ChannelNorm { scale, inv_scale }
    }

    /// Normalize a key row in place (cache-insertion side).
    #[inline]
    pub fn apply_key(&self, k: &mut [f32]) {
        for (v, &s) in k.iter_mut().zip(&self.inv_scale) {
            *v *= s;
        }
    }

    /// Fold into a query row in place (score side).
    #[inline]
    pub fn apply_query(&self, q: &mut [f32]) {
        for (v, &s) in q.iter_mut().zip(&self.scale) {
            *v *= s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ptest::{check, normal_vec, PropCfg};

    #[test]
    fn dot_product_preserved_exactly() {
        // q·k == (q*norm)·(k/norm): the fold must not change attention scores.
        check("norm preserves scores", PropCfg::default(), |rng, _| {
            let d_h = 64;
            let n = 8;
            let mut keys = Vec::new();
            for _ in 0..n {
                keys.extend(normal_vec(rng, d_h, 1.0, 0.1));
            }
            let norm = ChannelNorm::from_prefill_keys(&keys, d_h);
            let mut q = normal_vec(rng, d_h, 1.0, 0.0);
            let k_orig: Vec<f32> = keys[..d_h].to_vec();
            let dot0: f32 = q.iter().zip(&k_orig).map(|(a, b)| a * b).sum();
            let mut k = k_orig.clone();
            norm.apply_key(&mut k);
            norm.apply_query(&mut q);
            let dot1: f32 = q.iter().zip(&k).map(|(a, b)| a * b).sum();
            assert!((dot0 - dot1).abs() <= 1e-3 * dot0.abs().max(1.0));
        });
    }

    #[test]
    fn shrinks_outlier_channels() {
        // A channel with amax 16 gets norm 4: its cached magnitude drops to
        // amax/norm = sqrt(amax), compressing the group dynamic range.
        let d_h = 4;
        let keys = vec![
            16.0, 1.0, 0.5, 0.25, //
            -8.0, -1.0, 0.5, 0.25,
        ];
        let norm = ChannelNorm::from_prefill_keys(&keys, d_h);
        assert!((norm.scale[0] - 4.0).abs() < 1e-6);
        let mut k = vec![16.0, 1.0, 0.5, 0.25];
        norm.apply_key(&mut k);
        assert!((k[0] - 4.0).abs() < 1e-6);
        // max normalized magnitude across channels is sqrt(amax_c)
        assert!(k.iter().all(|v| v.abs() <= 4.0 + 1e-6));
    }

    #[test]
    fn zero_channel_uses_unit_norm() {
        let keys = vec![0.0f32; 8]; // 2 tokens x 4 channels, all zero
        let norm = ChannelNorm::from_prefill_keys(&keys, 4);
        assert!(norm.scale.iter().all(|&s| s == 1.0));
    }
}
