//! Quantized cache segments: the packed stores behind `K̂_cache` / `V̂_cache`
//! (Eq. 8), one layout per method family.
//!
//! Each segment owns its packed codes + group parameters and exposes the
//! fused-kernel entry points. Token order inside a segment is global
//! generation order: the cache manager guarantees tokens are appended
//! oldest-first as they are evicted from the recent window (§4.2).

use crate::kernels::{gemv_inner, gemv_outer, gemv_turbo};
use crate::quant::group::{quantize, Mode};
use crate::quant::packing::{pack, packed_len};
use crate::quant::turbo::{codebook, quantize_token, Rotation, TurboToken};
use crate::quant::GroupParams;

/// Plain f32 rows — the BaselineFp16 "segment" (no quantization).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FpSegment {
    /// Head dimension.
    pub d_h: usize,
    /// Token-major f32 rows (oldest first).
    pub rows: Vec<f32>,
}

impl FpSegment {
    /// An empty segment for head dimension `d_h`.
    pub fn new(d_h: usize) -> FpSegment {
        FpSegment { d_h, rows: Vec::new() }
    }
    /// Tokens stored.
    pub fn len(&self) -> usize {
        self.rows.len() / self.d_h
    }
    /// True when no tokens are stored.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }
    /// Append one token row.
    pub fn append_token(&mut self, row: &[f32]) {
        debug_assert_eq!(row.len(), self.d_h);
        self.rows.extend_from_slice(row);
    }
    /// FP16-storage-equivalent bytes held (2 bytes per number).
    pub fn bytes(&self) -> usize {
        // FP16 storage equivalent: 2 bytes per number (DESIGN.md).
        self.rows.len() * 2
    }
    /// Append every token of `other` after this segment's tokens. Because
    /// each token row is stored independently, the result is byte-identical
    /// to having appended `other`'s rows directly (the shared-prefix
    /// materialization path relies on this).
    pub fn extend_from(&mut self, other: &FpSegment) {
        debug_assert_eq!(self.d_h, other.d_h);
        self.rows.extend_from_slice(&other.rows);
    }
}

/// InnerQ key segment: per-token groups along `d_h` (§4.4).
#[derive(Debug, Clone, PartialEq)]
pub struct InnerKeySegment {
    /// Head dimension.
    pub d_h: usize,
    /// Quantization bit-width per code.
    pub bits: u8,
    /// Group quantization mode (symmetric / asymmetric / hybrid).
    pub mode: Mode,
    /// Packed quantization codes, token-major append order.
    pub codes: Vec<u8>,
    /// Per-group quantization parameters, in append order.
    pub params: Vec<GroupParams>,
    /// Planar runtime shadows of `params` — separate `scales[]` / `zeffs[]`
    /// f32 planes materialized at quantization time, so the GEMV hot loop
    /// does no f16 widening and loads contiguous vector-width runs instead
    /// of deinterleaving AoS pairs (see kernels::zeff_planes / DESIGN.md).
    pub scales: Vec<f32>,
    /// Planar effective-zero plane paired with `scales` (see above).
    pub zeffs: Vec<f32>,
    pub(crate) n_tokens: usize,
}

impl InnerKeySegment {
    /// An empty segment for head dimension `d_h`.
    pub fn new(d_h: usize, bits: u8, mode: Mode) -> Self {
        assert_eq!(d_h % 32, 0);
        InnerKeySegment {
            d_h,
            bits,
            mode,
            codes: Vec::new(),
            params: Vec::new(),
            scales: Vec::new(),
            zeffs: Vec::new(),
            n_tokens: 0,
        }
    }
    /// Tokens stored.
    pub fn len(&self) -> usize {
        self.n_tokens
    }
    /// Quantize and append one key token (InnerQ quantizes one key per step).
    pub fn append_token(&mut self, k: &[f32]) {
        debug_assert_eq!(k.len(), self.d_h);
        let mut raw = [0u8; 32];
        for g in k.chunks_exact(32) {
            let p = quantize(self.mode, g, self.bits, &mut raw);
            self.params.push(p);
            let (s, z) = crate::kernels::zeff(p, self.bits);
            self.scales.push(s);
            self.zeffs.push(z);
            pack(&raw, self.bits, &mut self.codes);
        }
        self.n_tokens += 1;
    }
    /// Fused dequant-GEMV scores for all quantized tokens.
    pub fn scores(&self, q: &[f32], out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.n_tokens);
        gemv_inner::qk_inner(q, &self.codes, &self.scales, &self.zeffs, self.bits, self.d_h, out);
    }
    /// Packed payload bytes (codes + 4-byte group parameters).
    pub fn bytes(&self) -> usize {
        self.codes.len() + self.params.len() * 4
    }
    /// Append every token of `other` after this segment's tokens. Each
    /// token quantizes independently under inner grouping, so the merged
    /// planes are byte-identical to a single segment built from the
    /// concatenated row history.
    pub fn extend_from(&mut self, other: &InnerKeySegment) {
        debug_assert_eq!((self.d_h, self.bits, self.mode), (other.d_h, other.bits, other.mode));
        self.codes.extend_from_slice(&other.codes);
        self.params.extend_from_slice(&other.params);
        self.scales.extend_from_slice(&other.scales);
        self.zeffs.extend_from_slice(&other.zeffs);
        self.n_tokens += other.n_tokens;
    }
}

/// InnerQ value segment: per-channel groups along the token axis, stored as
/// channel-major chunks of 32 tokens (§4.4).
#[derive(Debug, Clone, PartialEq)]
pub struct InnerValSegment {
    /// Head dimension.
    pub d_h: usize,
    /// Quantization bit-width per code.
    pub bits: u8,
    /// Group quantization mode (symmetric / asymmetric / hybrid).
    pub mode: Mode,
    /// Per chunk: `d_h` packed 32-code groups (channel-major).
    pub codes: Vec<u8>,
    /// Per chunk: `d_h` group params.
    pub params: Vec<GroupParams>,
    /// Planar runtime shadows of `params` (see [`InnerKeySegment`]).
    pub scales: Vec<f32>,
    /// Planar effective-zero plane paired with `scales` (see above).
    pub zeffs: Vec<f32>,
    pub(crate) n_chunks: usize,
}

impl InnerValSegment {
    /// An empty segment for head dimension `d_h`.
    pub fn new(d_h: usize, bits: u8, mode: Mode) -> Self {
        InnerValSegment {
            d_h,
            bits,
            mode,
            codes: Vec::new(),
            params: Vec::new(),
            scales: Vec::new(),
            zeffs: Vec::new(),
            n_chunks: 0,
        }
    }
    /// Tokens stored.
    pub fn len(&self) -> usize {
        self.n_chunks * 32
    }
    /// Quantize and append 32 tokens (token-major input `32 x d_h`).
    /// Group statistics run along the token axis per channel (inner
    /// grouping); the packed codes stay token-major so the CPU value kernel
    /// is reduction-free (see `gemv_inner::pv_inner_chunk`).
    pub fn append_chunk(&mut self, vs: &[f32]) {
        debug_assert_eq!(vs.len(), 32 * self.d_h);
        let mut col = [0f32; 32];
        let mut ccodes = [0u8; 32];
        let mut raw = vec![0u8; 32 * self.d_h]; // token-major raw codes
        for c in 0..self.d_h {
            for t in 0..32 {
                col[t] = vs[t * self.d_h + c];
            }
            let p = quantize(self.mode, &col, self.bits, &mut ccodes);
            self.params.push(p);
            let (s, z) = crate::kernels::zeff(p, self.bits);
            self.scales.push(s);
            self.zeffs.push(z);
            for t in 0..32 {
                raw[t * self.d_h + c] = ccodes[t];
            }
        }
        for t in 0..32 {
            pack(&raw[t * self.d_h..(t + 1) * self.d_h], self.bits, &mut self.codes);
        }
        self.n_chunks += 1;
    }
    /// `out[c] += Σ_t p[t]·dequant(V[t][c])` over all chunks.
    pub fn accumulate(&self, p: &[f32], out: &mut [f32]) {
        debug_assert_eq!(p.len(), self.len());
        let chunk_bytes = 32 * (self.d_h / 32) * packed_len(32, self.bits);
        for k in 0..self.n_chunks {
            gemv_inner::pv_inner_chunk(
                &p[k * 32..(k + 1) * 32],
                &self.codes[k * chunk_bytes..],
                &self.scales[k * self.d_h..(k + 1) * self.d_h],
                &self.zeffs[k * self.d_h..(k + 1) * self.d_h],
                self.bits,
                self.d_h,
                out,
            );
        }
    }
    /// Packed payload bytes (codes + 4-byte group parameters).
    pub fn bytes(&self) -> usize {
        self.codes.len() + self.params.len() * 4
    }
    /// Append every chunk of `other` after this segment's chunks. Chunks
    /// quantize independently, so the merge is byte-identical to a single
    /// segment built from the concatenated chunk history.
    pub fn extend_from(&mut self, other: &InnerValSegment) {
        debug_assert_eq!((self.d_h, self.bits, self.mode), (other.d_h, other.bits, other.mode));
        self.codes.extend_from_slice(&other.codes);
        self.params.extend_from_slice(&other.params);
        self.scales.extend_from_slice(&other.scales);
        self.zeffs.extend_from_slice(&other.zeffs);
        self.n_chunks += other.n_chunks;
    }
}

/// KIVI key segment: per-channel groups along the token axis, stored as
/// token-major chunks of 32 tokens.
#[derive(Debug, Clone, PartialEq)]
pub struct OuterKeySegment {
    /// Head dimension.
    pub d_h: usize,
    /// Quantization bit-width per code.
    pub bits: u8,
    /// Group quantization mode (symmetric / asymmetric / hybrid).
    pub mode: Mode,
    /// Per chunk: 32 token rows of packed `d_h` codes.
    pub codes: Vec<u8>,
    /// Per chunk: `d_h` group params (one per channel).
    pub params: Vec<GroupParams>,
    /// Planar runtime shadows of `params` (see [`InnerKeySegment`]).
    pub scales: Vec<f32>,
    /// Planar effective-zero plane paired with `scales` (see above).
    pub zeffs: Vec<f32>,
    pub(crate) n_chunks: usize,
}

impl OuterKeySegment {
    /// An empty segment for head dimension `d_h`.
    pub fn new(d_h: usize, bits: u8, mode: Mode) -> Self {
        assert_eq!(d_h % 32, 0);
        OuterKeySegment {
            d_h,
            bits,
            mode,
            codes: Vec::new(),
            params: Vec::new(),
            scales: Vec::new(),
            zeffs: Vec::new(),
            n_chunks: 0,
        }
    }
    /// Tokens stored.
    pub fn len(&self) -> usize {
        self.n_chunks * 32
    }
    /// Quantize and append 32 key tokens (KIVI evicts keys in groups of G).
    pub fn append_chunk(&mut self, ks: &[f32]) {
        debug_assert_eq!(ks.len(), 32 * self.d_h);
        let mut col = [0f32; 32];
        let mut ccodes = [0u8; 32];
        let mut raw = vec![0u8; 32 * self.d_h];
        for c in 0..self.d_h {
            for t in 0..32 {
                col[t] = ks[t * self.d_h + c];
            }
            let p = quantize(self.mode, &col, self.bits, &mut ccodes);
            self.params.push(p);
            let (s, z) = crate::kernels::zeff(p, self.bits);
            self.scales.push(s);
            self.zeffs.push(z);
            for t in 0..32 {
                raw[t * self.d_h + c] = ccodes[t];
            }
        }
        for t in 0..32 {
            pack(&raw[t * self.d_h..(t + 1) * self.d_h], self.bits, &mut self.codes);
        }
        self.n_chunks += 1;
    }
    /// Fused scores over all chunks; `scratch` holds `d_h` f32.
    pub fn scores(&self, q: &[f32], scratch: &mut [f32], out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.len());
        let row_bytes = (self.d_h / 32) * packed_len(32, self.bits);
        let chunk_bytes = 32 * row_bytes;
        for k in 0..self.n_chunks {
            gemv_outer::qk_outer_chunk(
                q,
                &self.codes[k * chunk_bytes..],
                &self.scales[k * self.d_h..(k + 1) * self.d_h],
                &self.zeffs[k * self.d_h..(k + 1) * self.d_h],
                self.bits,
                self.d_h,
                scratch,
                &mut out[k * 32..(k + 1) * 32],
            );
        }
    }
    /// Packed payload bytes (codes + 4-byte group parameters).
    pub fn bytes(&self) -> usize {
        self.codes.len() + self.params.len() * 4
    }
    /// Append every chunk of `other` after this segment's chunks (see
    /// [`InnerValSegment::extend_from`]).
    pub fn extend_from(&mut self, other: &OuterKeySegment) {
        debug_assert_eq!((self.d_h, self.bits, self.mode), (other.d_h, other.bits, other.mode));
        self.codes.extend_from_slice(&other.codes);
        self.params.extend_from_slice(&other.params);
        self.scales.extend_from_slice(&other.scales);
        self.zeffs.extend_from_slice(&other.zeffs);
        self.n_chunks += other.n_chunks;
    }
}

/// KIVI value segment: per-token groups along channels, one row per token.
#[derive(Debug, Clone, PartialEq)]
pub struct OuterValSegment {
    /// Head dimension.
    pub d_h: usize,
    /// Quantization bit-width per code.
    pub bits: u8,
    /// Group quantization mode (symmetric / asymmetric / hybrid).
    pub mode: Mode,
    /// Packed quantization codes, token-major append order.
    pub codes: Vec<u8>,
    /// Per-group quantization parameters, in append order.
    pub params: Vec<GroupParams>,
    /// Planar runtime shadows of `params` (see [`InnerKeySegment`]).
    pub scales: Vec<f32>,
    /// Planar effective-zero plane paired with `scales` (see above).
    pub zeffs: Vec<f32>,
    pub(crate) n_tokens: usize,
}

impl OuterValSegment {
    /// An empty segment for head dimension `d_h`.
    pub fn new(d_h: usize, bits: u8, mode: Mode) -> Self {
        assert_eq!(d_h % 32, 0);
        OuterValSegment {
            d_h,
            bits,
            mode,
            codes: Vec::new(),
            params: Vec::new(),
            scales: Vec::new(),
            zeffs: Vec::new(),
            n_tokens: 0,
        }
    }
    /// Tokens stored.
    pub fn len(&self) -> usize {
        self.n_tokens
    }
    /// Quantize and append one value token (KIVI quantizes one value/step).
    pub fn append_token(&mut self, v: &[f32]) {
        debug_assert_eq!(v.len(), self.d_h);
        let mut raw = [0u8; 32];
        for g in v.chunks_exact(32) {
            let p = quantize(self.mode, g, self.bits, &mut raw);
            self.params.push(p);
            let (s, z) = crate::kernels::zeff(p, self.bits);
            self.scales.push(s);
            self.zeffs.push(z);
            pack(&raw, self.bits, &mut self.codes);
        }
        self.n_tokens += 1;
    }
    /// `out[c] += sum_t p[t] * dequant(V[t][c])` over stored tokens.
    pub fn accumulate(&self, p: &[f32], out: &mut [f32]) {
        debug_assert_eq!(p.len(), self.n_tokens);
        let groups = self.d_h / 32;
        let row_bytes = groups * packed_len(32, self.bits);
        for (t, &w) in p.iter().enumerate() {
            gemv_outer::pv_outer_row(
                w,
                &self.codes[t * row_bytes..],
                &self.scales[t * groups..(t + 1) * groups],
                &self.zeffs[t * groups..(t + 1) * groups],
                self.bits,
                self.d_h,
                out,
            );
        }
    }
    /// Packed payload bytes (codes + 4-byte group parameters).
    pub fn bytes(&self) -> usize {
        self.codes.len() + self.params.len() * 4
    }
    /// Append every token of `other` after this segment's tokens (see
    /// [`InnerKeySegment::extend_from`]).
    pub fn extend_from(&mut self, other: &OuterValSegment) {
        debug_assert_eq!((self.d_h, self.bits, self.mode), (other.d_h, other.bits, other.mode));
        self.codes.extend_from_slice(&other.codes);
        self.params.extend_from_slice(&other.params);
        self.scales.extend_from_slice(&other.scales);
        self.zeffs.extend_from_slice(&other.zeffs);
        self.n_tokens += other.n_tokens;
    }
}

/// TurboQuant key segment: rotated codebook-coded tokens.
#[derive(Debug, Clone, PartialEq)]
pub struct TurboKeySegment {
    /// Head dimension.
    pub d_h: usize,
    /// Quantization bit-width per code.
    pub bits: u8,
    /// Data-oblivious random rotation shared by all tokens.
    pub rotation: Rotation,
    /// Codebook-coded tokens, in append order.
    pub tokens: Vec<TurboToken>,
}

impl TurboKeySegment {
    /// An empty segment for head dimension `d_h`.
    pub fn new(d_h: usize, bits: u8, seed: u64) -> Self {
        TurboKeySegment { d_h, bits, rotation: Rotation::new(d_h, seed), tokens: Vec::new() }
    }
    /// Tokens stored.
    pub fn len(&self) -> usize {
        self.tokens.len()
    }
    /// Rotate, codebook-quantize, and append one key token.
    pub fn append_token(&mut self, k: &[f32]) {
        self.tokens.push(quantize_token(&self.rotation, k, self.bits));
    }
    /// Rotate the query once, then codebook-GEMV over all tokens.
    pub fn scores(&self, q: &[f32], out: &mut [f32]) {
        let mut q_rot = q.to_vec();
        self.rotation.apply(&mut q_rot);
        gemv_turbo::qk_turbo(&q_rot, &self.tokens, codebook(self.bits), self.bits, self.d_h, out);
    }
    /// Packed payload bytes (codes + 4-byte group parameters).
    pub fn bytes(&self) -> usize {
        self.tokens.iter().map(|t| t.codes.len() + 4).sum()
    }
    /// Append every token of `other` after this segment's tokens. The
    /// rotation is data-oblivious and seed-fixed, so both segments share it
    /// and per-token codes concatenate byte-identically.
    pub fn extend_from(&mut self, other: &TurboKeySegment) {
        debug_assert_eq!((self.d_h, self.bits), (other.d_h, other.bits));
        debug_assert_eq!(self.rotation, other.rotation);
        self.tokens.extend_from_slice(&other.tokens);
    }
}

/// TurboQuant value segment: accumulates in the rotated basis; `finalize`
/// un-rotates the context contribution once per decode step.
#[derive(Debug, Clone, PartialEq)]
pub struct TurboValSegment {
    /// Head dimension.
    pub d_h: usize,
    /// Quantization bit-width per code.
    pub bits: u8,
    /// Data-oblivious random rotation shared by all tokens.
    pub rotation: Rotation,
    /// Codebook-coded tokens, in append order.
    pub tokens: Vec<TurboToken>,
}

impl TurboValSegment {
    /// An empty segment for head dimension `d_h`.
    pub fn new(d_h: usize, bits: u8, seed: u64) -> Self {
        TurboValSegment { d_h, bits, rotation: Rotation::new(d_h, seed), tokens: Vec::new() }
    }
    /// Tokens stored.
    pub fn len(&self) -> usize {
        self.tokens.len()
    }
    /// Rotate, codebook-quantize, and append one value token.
    pub fn append_token(&mut self, v: &[f32]) {
        self.tokens.push(quantize_token(&self.rotation, v, self.bits));
    }
    /// Accumulate `Σ p_t·R(v_t)` into `out_rot` (rotated basis).
    pub fn accumulate_rotated(&self, p: &[f32], out_rot: &mut [f32]) {
        gemv_turbo::pv_turbo(p, &self.tokens, codebook(self.bits), self.bits, self.d_h, out_rot);
    }
    /// Un-rotate a rotated-basis accumulation and add it into `out`.
    pub fn finalize_into(&self, mut acc_rot: Vec<f32>, out: &mut [f32]) {
        crate::quant::turbo::fwht(&mut acc_rot);
        for ((o, v), &s) in out.iter_mut().zip(&acc_rot).zip(&self.rotation.signs) {
            *o += v * s;
        }
    }
    /// Packed payload bytes (codes + 4-byte group parameters).
    pub fn bytes(&self) -> usize {
        self.tokens.iter().map(|t| t.codes.len() + 4).sum()
    }
    /// Append every token of `other` after this segment's tokens (see
    /// [`TurboKeySegment::extend_from`]).
    pub fn extend_from(&mut self, other: &TurboValSegment) {
        debug_assert_eq!((self.d_h, self.bits), (other.d_h, other.bits));
        debug_assert_eq!(self.rotation, other.rotation);
        self.tokens.extend_from_slice(&other.tokens);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ptest::normal_vec;
    use crate::util::rng::Rng;
    use crate::util::stats::rel_l2;

    #[test]
    fn inner_key_segment_append_and_score() {
        let mut rng = Rng::new(21);
        let d_h = 64;
        let mut seg = InnerKeySegment::new(d_h, 3, Mode::Sym);
        let keys = normal_vec(&mut rng, 10 * d_h, 1.0, 0.0);
        for row in keys.chunks_exact(d_h) {
            seg.append_token(row);
        }
        assert_eq!(seg.len(), 10);
        let q = normal_vec(&mut rng, d_h, 1.0, 0.0);
        let mut out = vec![0f32; 10];
        seg.scores(&q, &mut out);
        let mut exact = vec![0f32; 10];
        crate::kernels::gemv_fp::qk_fp(&q, &keys, d_h, &mut exact);
        assert!(rel_l2(&out, &exact) < 0.15);
    }

    #[test]
    fn inner_val_segment_round_trip() {
        let mut rng = Rng::new(22);
        let d_h = 64;
        let mut seg = InnerValSegment::new(d_h, 3, Mode::Sym);
        let vals = normal_vec(&mut rng, 64 * d_h, 1.0, 0.0);
        seg.append_chunk(&vals[..32 * d_h]);
        seg.append_chunk(&vals[32 * d_h..]);
        assert_eq!(seg.len(), 64);
        let p: Vec<f32> = (0..64).map(|_| rng.next_f32() / 64.0).collect();
        let mut out = vec![0f32; d_h];
        seg.accumulate(&p, &mut out);
        let mut exact = vec![0f32; d_h];
        crate::kernels::gemv_fp::pv_fp(&p, &vals, d_h, &mut exact);
        // 3-bit symmetric with near-uniform positive weights: honest error is
        // ~step/sqrt(12) relative to the data scale.
        assert!(rel_l2(&out, &exact) < 0.3, "rel {}", rel_l2(&out, &exact));
    }

    #[test]
    fn outer_key_segment_matches_fp_shape() {
        let mut rng = Rng::new(23);
        let d_h = 128;
        let mut seg = OuterKeySegment::new(d_h, 4, Mode::Asym);
        let keys = normal_vec(&mut rng, 32 * d_h, 1.0, 0.0);
        seg.append_chunk(&keys);
        let q = normal_vec(&mut rng, d_h, 1.0, 0.0);
        let mut scratch = vec![0f32; d_h];
        let mut out = vec![0f32; 32];
        seg.scores(&q, &mut scratch, &mut out);
        let mut exact = vec![0f32; 32];
        crate::kernels::gemv_fp::qk_fp(&q, &keys, d_h, &mut exact);
        assert!(rel_l2(&out, &exact) < 0.1);
    }

    #[test]
    fn outer_val_segment_matches_fp_shape() {
        let mut rng = Rng::new(24);
        let d_h = 64;
        let mut seg = OuterValSegment::new(d_h, 4, Mode::Asym);
        let vals = normal_vec(&mut rng, 20 * d_h, 1.0, 0.0);
        for row in vals.chunks_exact(d_h) {
            seg.append_token(row);
        }
        let p: Vec<f32> = (0..20).map(|_| rng.next_f32() / 20.0).collect();
        let mut out = vec![0f32; d_h];
        seg.accumulate(&p, &mut out);
        let mut exact = vec![0f32; d_h];
        crate::kernels::gemv_fp::pv_fp(&p, &vals, d_h, &mut exact);
        assert!(rel_l2(&out, &exact) < 0.12);
    }

    #[test]
    fn turbo_segments_round_trip() {
        let mut rng = Rng::new(25);
        let d_h = 128;
        let mut ks = TurboKeySegment::new(d_h, 4, 42);
        let mut vs = TurboValSegment::new(d_h, 3, 43);
        let keys = normal_vec(&mut rng, 16 * d_h, 1.0, 0.0);
        let vals = normal_vec(&mut rng, 16 * d_h, 1.0, 0.0);
        for (k, v) in keys.chunks_exact(d_h).zip(vals.chunks_exact(d_h)) {
            ks.append_token(k);
            vs.append_token(v);
        }
        let q = normal_vec(&mut rng, d_h, 1.0, 0.0);
        let mut out = vec![0f32; 16];
        ks.scores(&q, &mut out);
        let mut exact = vec![0f32; 16];
        crate::kernels::gemv_fp::qk_fp(&q, &keys, d_h, &mut exact);
        assert!(rel_l2(&out, &exact) < 0.25, "turbo key rel {}", rel_l2(&out, &exact));

        let p: Vec<f32> = (0..16).map(|_| 1.0 / 16.0).collect();
        let acc = vec![0f32; d_h];
        let mut ctx = vec![0f32; d_h];
        let mut acc = acc;
        vs.accumulate_rotated(&p, &mut acc);
        vs.finalize_into(acc, &mut ctx);
        let mut exact_ctx = vec![0f32; d_h];
        crate::kernels::gemv_fp::pv_fp(&p, &vals, d_h, &mut exact_ctx);
        assert!(rel_l2(&ctx, &exact_ctx) < 0.25, "turbo val rel {}", rel_l2(&ctx, &exact_ctx));
    }

    #[test]
    fn split_then_extend_matches_unified_build() {
        // The shared-prefix split relies on appends being position
        // independent: building a segment in two halves and merging must be
        // byte-identical to one pass over the concatenated history.
        let d_h = 64;
        let mut rng = Rng::new(77);
        let rows = normal_vec(&mut rng, 128 * d_h, 1.0, 0.02);
        let half = 64 * d_h;

        let mut unified = InnerKeySegment::new(d_h, 3, Mode::Hybrid);
        let mut a = InnerKeySegment::new(d_h, 3, Mode::Hybrid);
        let mut b = InnerKeySegment::new(d_h, 3, Mode::Hybrid);
        for r in rows.chunks_exact(d_h) {
            unified.append_token(r);
        }
        for r in rows[..half].chunks_exact(d_h) {
            a.append_token(r);
        }
        for r in rows[half..].chunks_exact(d_h) {
            b.append_token(r);
        }
        a.extend_from(&b);
        assert_eq!(a, unified, "inner key split/merge diverged");

        let mut unified = InnerValSegment::new(d_h, 2, Mode::Asym);
        let mut a = InnerValSegment::new(d_h, 2, Mode::Asym);
        for chunk in rows.chunks_exact(32 * d_h) {
            unified.append_chunk(chunk);
        }
        a.append_chunk(&rows[..32 * d_h]);
        a.append_chunk(&rows[32 * d_h..64 * d_h]);
        let mut b = InnerValSegment::new(d_h, 2, Mode::Asym);
        b.append_chunk(&rows[64 * d_h..96 * d_h]);
        b.append_chunk(&rows[96 * d_h..]);
        a.extend_from(&b);
        assert_eq!(a, unified, "inner val split/merge diverged");

        let mut unified = OuterKeySegment::new(d_h, 2, Mode::Asym);
        let mut a = OuterKeySegment::new(d_h, 2, Mode::Asym);
        let mut b = OuterKeySegment::new(d_h, 2, Mode::Asym);
        for chunk in rows.chunks_exact(32 * d_h) {
            unified.append_chunk(chunk);
        }
        a.append_chunk(&rows[..32 * d_h]);
        b.append_chunk(&rows[32 * d_h..64 * d_h]);
        b.append_chunk(&rows[64 * d_h..96 * d_h]);
        b.append_chunk(&rows[96 * d_h..]);
        a.extend_from(&b);
        assert_eq!(a, unified, "outer key split/merge diverged");

        let mut unified = OuterValSegment::new(d_h, 3, Mode::Sym);
        let mut a = OuterValSegment::new(d_h, 3, Mode::Sym);
        let mut b = OuterValSegment::new(d_h, 3, Mode::Sym);
        for (t, r) in rows.chunks_exact(d_h).enumerate() {
            unified.append_token(r);
            if t < 50 {
                a.append_token(r);
            } else {
                b.append_token(r);
            }
        }
        a.extend_from(&b);
        assert_eq!(a, unified, "outer val split/merge diverged");

        let mut unified = TurboKeySegment::new(d_h, 4, 42);
        let mut a = TurboKeySegment::new(d_h, 4, 42);
        let mut b = TurboKeySegment::new(d_h, 4, 42);
        for (t, r) in rows.chunks_exact(d_h).enumerate() {
            unified.append_token(r);
            if t < 13 {
                a.append_token(r);
            } else {
                b.append_token(r);
            }
        }
        a.extend_from(&b);
        assert_eq!(a, unified, "turbo key split/merge diverged");
    }

    #[test]
    fn segment_bytes_track_bit_width() {
        // 3-bit inner key: 12 bytes codes + 4 bytes params per 32 channels.
        let mut seg = InnerKeySegment::new(128, 3, Mode::Sym);
        seg.append_token(&vec![0.5f32; 128]);
        assert_eq!(seg.bytes(), 4 * 12 + 4 * 4); // 4 groups
        let mut kivi = OuterValSegment::new(128, 2, Mode::Asym);
        kivi.append_token(&vec![0.5f32; 128]);
        assert_eq!(kivi.bytes(), 4 * 8 + 4 * 4);
    }
}
