//! Quantized KV-cache subsystem: high-precision windows (§4.2), packed
//! quantized segments (§4.4), the per-head manager with method-specific
//! eviction, and the cross-sequence memory pool.

pub mod manager;
pub mod pool;
pub mod segments;
pub mod window;

pub use manager::{attention_fanout, prefill_fanout, HeadCache, KeySegment, ValSegment};
pub use pool::{Admission, CachePool};
