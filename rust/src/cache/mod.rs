//! Quantized KV-cache subsystem: high-precision windows (§4.2), packed
//! quantized segments (§4.4), the per-head manager with method-specific
//! eviction, the per-layer ownership unit behind pipelined decode
//! ([`layer`]), the cross-sequence memory pool, and the tiered snapshot
//! store behind offload preemption ([`store`]).

pub mod layer;
pub mod manager;
pub mod pool;
pub mod segments;
pub mod store;
pub mod window;

pub use layer::{head_step, step_fanout, LayerCache};
pub use manager::{attention_fanout, prefill_fanout, HeadCache, KeySegment, ValSegment};
pub use pool::{Admission, CachePool};
pub use store::{PrefixImage, PrefixStore, PrefixStoreStats, TierStats, WarmTier};
