//! Segcache-style warm tier for offloaded cache snapshots.
//!
//! Modeled on pelikan's segcache storage shape: the tier owns a bounded pool
//! of fixed-size segments, hands them out from a free list, and returns them
//! to the free list when a resident leaves — so long-running serving reuses
//! the same allocations instead of fragmenting the heap with
//! snapshot-sized `Vec`s. A resident (one preempted sequence's serialized
//! snapshot, see [`super::snapshot`]) spans however many pooled segments its
//! payload needs; the final segment is partially filled and the resident
//! remembers its exact byte length.
//!
//! Eviction is LRU-with-priority: when an insert needs segments the pool
//! cannot supply, the tier evicts the least-important (highest priority
//! class value), least-recently-touched resident — but never one *more*
//! important than the inserting class, in which case the insert itself is
//! refused and the caller falls back to recompute-style preemption. Eviction
//! is terminal: the snapshot is gone, and the scheduler discovers that as a
//! miss at restore time (its recompute fallback). All bookkeeping is
//! deterministic (`BTreeMap` iteration, an internal logical clock), so
//! replays that route through the tier stay byte-identical.

use std::collections::BTreeMap;

/// Default pooled segment size. Snapshots of typical preempted sequences run
/// tens of KiB, so 16 KiB keeps per-resident waste (< one segment) small
/// while still amortizing allocation.
pub const DEFAULT_SEG_BYTES: usize = 16 * 1024;

/// Monotonic warm-tier counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TierStats {
    /// Snapshots stored successfully.
    pub inserts: u64,
    /// Inserts refused (payload over budget, or only more-important
    /// residents were in the way).
    pub insert_rejected: u64,
    /// Successful takes (restores).
    pub hits: u64,
    /// Takes of ids not resident (never stored, or evicted).
    pub misses: u64,
    /// Residents evicted to make room for an insert (terminal).
    pub evictions: u64,
    /// Payload bytes destroyed by those evictions.
    pub evicted_bytes: u64,
}

#[derive(Debug)]
struct Resident {
    /// Pool segment indices holding the payload, in order.
    segs: Vec<u32>,
    /// Exact payload length (the last segment is partially filled).
    len: usize,
    /// Priority class level of the owning request (0 = most important).
    class: u8,
    /// Last-touched stamp from the tier's logical clock (LRU order).
    stamp: u64,
}

/// Fixed-segment warm store for offloaded sequence snapshots.
#[derive(Debug)]
pub struct WarmTier {
    seg_bytes: usize,
    max_segs: usize,
    /// Allocated pool segments; grows on demand up to `max_segs` and is
    /// never shrunk — retired segments go to `free` for reuse.
    segments: Vec<Box<[u8]>>,
    free: Vec<u32>,
    residents: BTreeMap<u64, Resident>,
    clock: u64,
    /// Hit/miss/eviction counters.
    pub stats: TierStats,
}

impl WarmTier {
    /// A tier holding at most `budget_bytes` of pooled segments of
    /// `seg_bytes` each (clamped to a 256-byte minimum). A budget smaller
    /// than one segment yields a zero-capacity tier that refuses every
    /// insert — the scheduler then behaves exactly like recompute mode.
    pub fn new(budget_bytes: usize, seg_bytes: usize) -> WarmTier {
        let seg_bytes = seg_bytes.max(256);
        WarmTier {
            seg_bytes,
            max_segs: budget_bytes / seg_bytes,
            segments: Vec::new(),
            free: Vec::new(),
            residents: BTreeMap::new(),
            clock: 0,
            stats: TierStats::default(),
        }
    }

    /// Pooled segment size in bytes.
    pub fn seg_bytes(&self) -> usize {
        self.seg_bytes
    }

    /// Total pool capacity in bytes (`max_segs * seg_bytes`).
    pub fn budget_bytes(&self) -> usize {
        self.max_segs * self.seg_bytes
    }

    /// Number of snapshots currently resident.
    pub fn n_residents(&self) -> usize {
        self.residents.len()
    }

    /// True if `id` has a resident snapshot.
    pub fn contains(&self, id: u64) -> bool {
        self.residents.contains_key(&id)
    }

    /// Resident ids in ascending order.
    pub fn resident_ids(&self) -> impl Iterator<Item = u64> + '_ {
        self.residents.keys().copied()
    }

    /// Exact payload bytes resident (excludes final-segment slack).
    pub fn resident_bytes(&self) -> usize {
        self.residents.values().map(|r| r.len).sum()
    }

    /// Pool bytes held by residents, counting final-segment slack.
    pub fn reserved_bytes(&self) -> usize {
        (self.segments.len() - self.free.len()) * self.seg_bytes
    }

    fn segs_for(&self, len: usize) -> usize {
        ((len + self.seg_bytes - 1) / self.seg_bytes).max(1)
    }

    fn available_segs(&self) -> usize {
        self.free.len() + (self.max_segs - self.segments.len())
    }

    /// Store `payload` for request `id` at priority-class level `class`
    /// (0 = most important). Replaces any previous resident for `id`.
    /// Returns false — leaving the tier unchanged apart from counters, any
    /// previous resident for `id` included — when the payload exceeds the
    /// whole pool or eviction cannot free enough room without destroying a
    /// more-important resident.
    pub fn insert(&mut self, id: u64, class: u8, payload: &[u8]) -> bool {
        let need = self.segs_for(payload.len());
        // Feasibility before any mutation: the segments a replacement would
        // free plus everything evictable at this class must cover the need,
        // otherwise refuse with the tier untouched.
        let replaced_segs = self.residents.get(&id).map_or(0, |r| r.segs.len());
        let evictable_segs: usize = self
            .residents
            .iter()
            .filter(|(&rid, r)| rid != id && r.class >= class)
            .map(|(_, r)| r.segs.len())
            .sum();
        if need > self.max_segs
            || self.available_segs() + replaced_segs + evictable_segs < need
        {
            self.stats.insert_rejected += 1;
            return false;
        }
        self.remove(id);
        while self.available_segs() < need {
            // Least-important class first, then least recently touched; the
            // id tiebreak keeps the choice total (and so deterministic). The
            // feasibility check above guarantees a victim exists.
            let victim = self
                .residents
                .iter()
                .filter(|(_, r)| r.class >= class)
                .max_by_key(|(&vid, r)| (r.class, std::cmp::Reverse(r.stamp), std::cmp::Reverse(vid)))
                .map(|(&vid, _)| vid);
            match victim {
                Some(vid) => self.evict(vid),
                None => {
                    debug_assert!(false, "insert feasibility check admitted an unfillable need");
                    self.stats.insert_rejected += 1;
                    return false;
                }
            }
        }
        let mut segs = Vec::with_capacity(need);
        for chunk in 0..need {
            let si = match self.free.pop() {
                Some(si) => si,
                None => {
                    let si = self.segments.len() as u32;
                    self.segments.push(vec![0u8; self.seg_bytes].into_boxed_slice());
                    si
                }
            };
            let lo = chunk * self.seg_bytes;
            let hi = (lo + self.seg_bytes).min(payload.len());
            self.segments[si as usize][..hi - lo].copy_from_slice(&payload[lo..hi]);
            segs.push(si);
        }
        self.clock += 1;
        let stamp = self.clock;
        self.residents.insert(id, Resident { segs, len: payload.len(), class, stamp });
        self.stats.inserts += 1;
        true
    }

    fn evict(&mut self, id: u64) {
        if let Some(r) = self.residents.remove(&id) {
            self.stats.evictions += 1;
            self.stats.evicted_bytes += r.len as u64;
            self.free.extend(r.segs);
        }
    }

    /// Drop a resident without reading it (deadline expiry, request
    /// cancellation). Not counted as an eviction. Returns whether `id` was
    /// resident.
    pub fn remove(&mut self, id: u64) -> bool {
        match self.residents.remove(&id) {
            Some(r) => {
                self.free.extend(r.segs);
                true
            }
            None => false,
        }
    }

    fn assemble(&self, r: &Resident) -> Vec<u8> {
        let mut out = Vec::with_capacity(r.len);
        let mut left = r.len;
        for &si in &r.segs {
            let take = left.min(self.seg_bytes);
            out.extend_from_slice(&self.segments[si as usize][..take]);
            left -= take;
        }
        debug_assert_eq!(left, 0);
        out
    }

    /// Cheap pre-check for [`WarmTier::insert`]: false when the tier has no
    /// capacity at all, or every pooled segment is held by strictly
    /// more-important residents — an insert at `class` cannot possibly
    /// succeed, so callers can skip building the payload (the scheduler
    /// checks this before serializing a preemption victim).
    pub fn may_accept(&self, class: u8) -> bool {
        if self.max_segs == 0 {
            return false;
        }
        self.available_segs() > 0 || self.residents.values().any(|r| r.class >= class)
    }

    /// Read a resident's payload and remove it, returning its segments to
    /// the free list — the restore path.
    pub fn take(&mut self, id: u64) -> Option<Vec<u8>> {
        match self.residents.remove(&id) {
            Some(r) => {
                let out = self.assemble(&r);
                self.free.extend(r.segs);
                self.stats.hits += 1;
                Some(out)
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn payload(len: usize, fill: u8) -> Vec<u8> {
        (0..len).map(|i| fill.wrapping_add(i as u8)).collect()
    }

    fn tier(n_segs: usize) -> WarmTier {
        WarmTier::new(n_segs * 1024, 1024)
    }

    #[test]
    fn insert_take_round_trip_across_segment_boundaries() {
        let mut t = tier(8);
        for len in [0usize, 1, 1023, 1024, 1025, 3 * 1024 + 17] {
            let p = payload(len, 7);
            assert!(t.insert(42, 1, &p), "len {len}");
            assert!(t.contains(42));
            assert_eq!(t.take(42), Some(p), "len {len}");
            assert!(!t.contains(42));
        }
        assert_eq!(t.stats.hits, 6);
        assert_eq!(t.stats.misses, 0);
    }

    #[test]
    fn free_list_reuses_segments_instead_of_growing() {
        let mut t = tier(4);
        for round in 0..10 {
            let p = payload(3 * 1024, round);
            assert!(t.insert(round as u64, 1, &p));
            assert_eq!(t.take(round as u64), Some(p));
        }
        assert!(t.segments.len() <= 4, "pool grew past its budget: {}", t.segments.len());
        assert_eq!(t.reserved_bytes(), 0);
    }

    #[test]
    fn lru_eviction_within_a_class() {
        let mut t = tier(4); // 4 segments of 1 KiB
        assert!(t.insert(1, 1, &payload(2 * 1024, 1))); // 2 segs
        assert!(t.insert(2, 1, &payload(2 * 1024, 2))); // 2 segs, pool full
        // Re-inserting 1 (replacement) refreshes its recency stamp.
        assert!(t.insert(1, 1, &payload(2 * 1024, 1)));
        assert!(t.insert(3, 1, &payload(1024, 3))); // must evict LRU = 2
        assert!(t.contains(1) && !t.contains(2) && t.contains(3));
        assert_eq!(t.stats.evictions, 1);
        assert_eq!(t.stats.evicted_bytes, 2 * 1024);
        assert_eq!(t.take(2), None);
        assert_eq!(t.stats.misses, 1);
    }

    #[test]
    fn may_accept_screens_doomed_inserts() {
        assert!(!WarmTier::new(0, 1024).may_accept(0));
        let mut t = tier(2);
        assert!(t.may_accept(2), "empty tier accepts any class");
        assert!(t.insert(1, 0, &payload(2 * 1024, 1))); // interactive fills it
        assert!(!t.may_accept(2), "batch cannot displace interactive");
        assert!(t.may_accept(0), "equal class can displace via LRU");
        t.remove(1);
        assert!(t.may_accept(2));
    }

    #[test]
    fn lower_importance_residents_evict_first() {
        let mut t = tier(4);
        assert!(t.insert(10, 0, &payload(2 * 1024, 1))); // interactive
        assert!(t.insert(20, 2, &payload(2 * 1024, 2))); // batch
        // A standard-class insert evicts the batch resident, not interactive.
        assert!(t.insert(30, 1, &payload(2 * 1024, 3)));
        assert!(t.contains(10) && !t.contains(20) && t.contains(30));
    }

    #[test]
    fn insert_never_destroys_more_important_residents() {
        let mut t = tier(2);
        assert!(t.insert(1, 0, &payload(2 * 1024, 1))); // fills the pool
        // A batch-class snapshot cannot displace interactive state.
        assert!(!t.insert(2, 2, &payload(1024, 2)));
        assert!(t.contains(1) && !t.contains(2));
        assert_eq!(t.stats.insert_rejected, 1);
        assert_eq!(t.stats.evictions, 0);
    }

    #[test]
    fn oversized_and_zero_budget_inserts_are_refused() {
        let mut t = tier(2);
        assert!(!t.insert(1, 0, &payload(3 * 1024, 1)));
        let mut none = WarmTier::new(0, 1024);
        assert!(!none.insert(1, 0, &payload(1, 1)));
        assert_eq!(none.budget_bytes(), 0);
    }

    #[test]
    fn failed_replacement_keeps_the_old_resident() {
        let mut t = tier(2);
        assert!(t.insert(7, 1, &payload(1024, 3)));
        // Replacement too big for the whole pool: refused, original intact.
        assert!(!t.insert(7, 1, &payload(3 * 1024, 4)));
        assert_eq!(t.take(7), Some(payload(1024, 3)));
        // Replacement blocked by a more-important resident: same guarantee.
        let mut t = tier(2);
        assert!(t.insert(1, 0, &payload(1024, 1))); // interactive, 1 seg
        assert!(t.insert(7, 2, &payload(1024, 2))); // batch, 1 seg — pool full
        assert!(!t.insert(7, 2, &payload(2 * 1024, 9)), "would need to evict id 1");
        assert_eq!(t.take(7), Some(payload(1024, 2)), "old snapshot must survive");
    }

    #[test]
    fn replacing_an_id_keeps_one_resident() {
        let mut t = tier(4);
        assert!(t.insert(5, 1, &payload(1024, 1)));
        assert!(t.insert(5, 1, &payload(2048, 9)));
        assert_eq!(t.n_residents(), 1);
        assert_eq!(t.take(5), Some(payload(2048, 9)));
        assert_eq!(t.reserved_bytes(), 0);
    }
}
