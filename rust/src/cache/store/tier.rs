//! Segcache-style warm tier for offloaded cache snapshots.
//!
//! Modeled on pelikan's segcache storage shape: the tier owns a bounded pool
//! of fixed-size segments, hands them out from a free list, and returns them
//! to the free list when a resident leaves — so long-running serving reuses
//! the same allocations instead of fragmenting the heap with
//! snapshot-sized `Vec`s. A resident (one preempted sequence's serialized
//! snapshot, see [`super::snapshot`]) is a list of *frames* — in practice
//! the meta frame plus one core/windows pair per layer — each spanning
//! however many pooled segments its payload needs; the final segment of a
//! frame is partially filled and the frame remembers its exact byte length.
//!
//! Eviction is LRU-with-priority, refined to frame granularity: when an
//! insert needs segments the pool cannot supply, the tier first drops
//! *droppable* frames (the fp-window frames, which dominate snapshot bytes
//! and are recomputable for prefill-only sequences) of the least-important,
//! least-recently-touched eligible resident — leaving a *partial* resident
//! whose quantized cores survive — and only evicts whole residents once no
//! droppable frame is left. It never destroys state of a resident *more*
//! important than the inserting class; in that case the insert itself is
//! refused and the caller falls back to recompute-style preemption. An
//! insert that cannot fit in full may itself degrade: its own droppable
//! frames are skipped rather than refusing outright, so `--warm-budget`
//! admission reserves only what actually fits instead of all-or-nothing.
//! Whole-resident eviction is terminal; a dropped *frame* surfaces at
//! restore time as a partial take (the scheduler rebuilds the windows). All
//! bookkeeping is deterministic (`BTreeMap` iteration, an internal logical
//! clock), so replays that route through the tier stay byte-identical.
//!
//! Residents may additionally be *pinned* by a reference count
//! ([`WarmTier::retain`] / [`WarmTier::release`]): while `refs > 0` the
//! resident is invisible to eviction — neither its droppable frames nor the
//! resident itself may be destroyed to make room, regardless of class. The
//! prefix store ([`super::prefix`]) uses this to keep shared prefix images
//! alive exactly as long as any live sequence borrows them; once released
//! back to zero they rejoin the ordinary LRU order (evict-last, since a
//! release refreshes nothing — their last `retain` stamp decides).

use crate::obs;
use std::collections::BTreeMap;

/// Default pooled segment size. Snapshots of typical preempted sequences run
/// tens of KiB, so 16 KiB keeps per-resident waste (< one segment per
/// frame) small while still amortizing allocation.
pub const DEFAULT_SEG_BYTES: usize = 16 * 1024;

/// How a frame behaves under tier pressure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameKind {
    /// Must stay resident for the snapshot to restore at all (meta frame,
    /// per-layer quantized cores). Only whole-resident eviction removes it.
    Required,
    /// May be dropped under pressure, leaving a partial resident (the
    /// fp-window frames, recomputable by the engine).
    Droppable,
}

/// Monotonic warm-tier counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TierStats {
    /// Snapshots stored successfully (full or degraded).
    pub inserts: u64,
    /// Inserts refused (required frames over budget, or only more-important
    /// residents were in the way).
    pub insert_rejected: u64,
    /// Droppable frames skipped at insert time because only the required
    /// frames fit.
    pub insert_dropped_frames: u64,
    /// Successful takes (restores), partial takes included.
    pub hits: u64,
    /// Takes that came back with at least one frame missing.
    pub partial_hits: u64,
    /// Takes of ids not resident (never stored, or evicted).
    pub misses: u64,
    /// Residents evicted whole to make room for an insert (terminal).
    pub evictions: u64,
    /// Payload bytes destroyed by whole-resident evictions.
    pub evicted_bytes: u64,
    /// Individual droppable frames evicted from surviving residents.
    pub frame_evictions: u64,
    /// Payload bytes destroyed by those frame evictions.
    pub evicted_frame_bytes: u64,
}

/// One stored frame of a resident.
#[derive(Debug)]
struct FrameSlot {
    /// Pool segment indices holding the payload, in order; empty once the
    /// frame has been dropped.
    segs: Vec<u32>,
    /// Exact payload length (the last segment is partially filled).
    len: usize,
    /// Whether pressure may drop this frame individually.
    droppable: bool,
    /// False once dropped (at insert time or by frame eviction).
    present: bool,
}

#[derive(Debug)]
struct Resident {
    frames: Vec<FrameSlot>,
    /// Priority class level of the owning request (0 = most important).
    class: u8,
    /// Last-touched stamp from the tier's logical clock (LRU order).
    stamp: u64,
    /// Pin count: while non-zero the resident is exempt from eviction and
    /// frame drops (see [`WarmTier::retain`]).
    refs: u32,
}

impl Resident {
    fn present_segs(&self) -> usize {
        self.frames.iter().filter(|f| f.present).map(|f| f.segs.len()).sum()
    }
    fn present_bytes(&self) -> usize {
        self.frames.iter().filter(|f| f.present).map(|f| f.len).sum()
    }
    fn has_droppable(&self) -> bool {
        self.frames.iter().any(|f| f.present && f.droppable)
    }
}

/// Outcome of a successful [`WarmTier::insert_frames`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InsertReceipt {
    /// Payload bytes actually stored (degraded inserts store less than was
    /// offered).
    pub stored_bytes: usize,
    /// Droppable frames skipped because only the required set fit.
    pub dropped_frames: usize,
}

/// Frames handed back by [`WarmTier::take_frames`], in insertion order.
/// `None` entries were dropped under pressure while the resident waited.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TakenFrames {
    /// One entry per inserted frame; `None` = dropped.
    pub frames: Vec<Option<Vec<u8>>>,
}

impl TakenFrames {
    /// True when every frame survived.
    pub fn is_full(&self) -> bool {
        self.frames.iter().all(|f| f.is_some())
    }
}

/// Fixed-segment warm store for offloaded sequence snapshots.
#[derive(Debug)]
pub struct WarmTier {
    seg_bytes: usize,
    max_segs: usize,
    /// Allocated pool segments; grows on demand up to `max_segs` and is
    /// never shrunk — retired segments go to `free` for reuse.
    segments: Vec<Box<[u8]>>,
    free: Vec<u32>,
    residents: BTreeMap<u64, Resident>,
    clock: u64,
    /// Hit/miss/eviction counters.
    pub stats: TierStats,
}

impl WarmTier {
    /// A tier holding at most `budget_bytes` of pooled segments of
    /// `seg_bytes` each (clamped to a 256-byte minimum). A budget smaller
    /// than one segment yields a zero-capacity tier that refuses every
    /// insert — the scheduler then behaves exactly like recompute mode.
    pub fn new(budget_bytes: usize, seg_bytes: usize) -> WarmTier {
        let seg_bytes = seg_bytes.max(256);
        WarmTier {
            seg_bytes,
            max_segs: budget_bytes / seg_bytes,
            segments: Vec::new(),
            free: Vec::new(),
            residents: BTreeMap::new(),
            clock: 0,
            stats: TierStats::default(),
        }
    }

    /// Pooled segment size in bytes.
    pub fn seg_bytes(&self) -> usize {
        self.seg_bytes
    }

    /// Total pool capacity in bytes (`max_segs * seg_bytes`).
    pub fn budget_bytes(&self) -> usize {
        self.max_segs * self.seg_bytes
    }

    /// Number of snapshots currently resident (partial residents included).
    pub fn n_residents(&self) -> usize {
        self.residents.len()
    }

    /// True if `id` has a resident snapshot (possibly partial).
    pub fn contains(&self, id: u64) -> bool {
        self.residents.contains_key(&id)
    }

    /// True if `id` is resident with at least one frame dropped.
    pub fn is_partial(&self, id: u64) -> bool {
        self.residents
            .get(&id)
            .map_or(false, |r| r.frames.iter().any(|f| !f.present))
    }

    /// Resident ids in ascending order.
    pub fn resident_ids(&self) -> impl Iterator<Item = u64> + '_ {
        self.residents.keys().copied()
    }

    /// Exact payload bytes resident (excludes final-segment slack and
    /// dropped frames) — the number `--warm-budget` accounting should use,
    /// since partial residents really do hold fewer bytes.
    pub fn resident_bytes(&self) -> usize {
        self.residents.values().map(|r| r.present_bytes()).sum()
    }

    /// Exact payload bytes one resident holds right now (`None` when not
    /// resident). Partial residents report only their surviving frames.
    pub fn resident_bytes_of(&self, id: u64) -> Option<usize> {
        self.residents.get(&id).map(|r| r.present_bytes())
    }

    /// Pool bytes held by residents, counting final-segment slack.
    pub fn reserved_bytes(&self) -> usize {
        (self.segments.len() - self.free.len()) * self.seg_bytes
    }

    fn segs_for(&self, len: usize) -> usize {
        ((len + self.seg_bytes - 1) / self.seg_bytes).max(1)
    }

    fn available_segs(&self) -> usize {
        self.free.len() + (self.max_segs - self.segments.len())
    }

    /// Store `payload` for request `id` at priority-class level `class` as
    /// one required frame. Single-frame form of [`WarmTier::insert_frames`]
    /// with the same contract: `Some(receipt)` reporting the bytes actually
    /// stored, `None` when the insert was refused with the tier unchanged —
    /// so callers account stored bytes identically on both paths.
    pub fn insert(&mut self, id: u64, class: u8, payload: &[u8]) -> Option<InsertReceipt> {
        self.insert_frames(id, class, &[(payload, FrameKind::Required)])
    }

    /// Store a multi-frame snapshot for request `id` at priority-class
    /// level `class` (0 = most important), replacing any previous resident
    /// for `id`. Returns what was stored, or `None` — leaving the tier
    /// unchanged apart from counters, any previous resident for `id`
    /// included — when even the required frames exceed the pool or eviction
    /// cannot free enough room without destroying a more-important
    /// resident's state. When everything cannot fit but the required frames
    /// can, the insert *degrades*: droppable frames are skipped and counted
    /// in the receipt, so admission reserves only what actually fits.
    pub fn insert_frames(
        &mut self,
        id: u64,
        class: u8,
        frames: &[(&[u8], FrameKind)],
    ) -> Option<InsertReceipt> {
        let t_insert = obs::start();
        let segs_of = |p: &[u8]| self.segs_for(p.len());
        let need_full: usize = frames.iter().map(|(p, _)| segs_of(p)).sum();
        let need_required: usize = frames
            .iter()
            .filter(|(_, k)| *k == FrameKind::Required)
            .map(|(p, _)| segs_of(p))
            .sum();
        // Feasibility before any mutation: the segments a replacement would
        // free plus everything evictable at this class must cover at least
        // the required frames, otherwise refuse with the tier untouched.
        let replaced_segs = self.residents.get(&id).map_or(0, |r| r.present_segs());
        let evictable_segs: usize = self
            .residents
            .iter()
            .filter(|(&rid, r)| rid != id && r.class >= class && r.refs == 0)
            .map(|(_, r)| r.present_segs())
            .sum();
        let headroom = self.available_segs() + replaced_segs + evictable_segs;
        if need_required > self.max_segs || headroom < need_required {
            self.stats.insert_rejected += 1;
            return None;
        }
        let store_all = need_full <= self.max_segs && headroom >= need_full;
        let need = if store_all { need_full } else { need_required };
        self.remove(id);
        if !self.free_up(need, class) {
            debug_assert!(false, "insert feasibility check admitted an unfillable need");
            self.stats.insert_rejected += 1;
            return None;
        }
        let mut slots = Vec::with_capacity(frames.len());
        let mut stored_bytes = 0usize;
        let mut dropped = 0usize;
        for (payload, kind) in frames {
            let droppable = *kind == FrameKind::Droppable;
            if droppable && !store_all {
                dropped += 1;
                slots.push(FrameSlot { segs: Vec::new(), len: 0, droppable, present: false });
                continue;
            }
            let n_segs = self.segs_for(payload.len());
            let mut segs = Vec::with_capacity(n_segs);
            for chunk in 0..n_segs {
                let si = match self.free.pop() {
                    Some(si) => si,
                    None => {
                        let si = self.segments.len() as u32;
                        self.segments.push(vec![0u8; self.seg_bytes].into_boxed_slice());
                        si
                    }
                };
                let lo = chunk * self.seg_bytes;
                let hi = (lo + self.seg_bytes).min(payload.len());
                self.segments[si as usize][..hi - lo].copy_from_slice(&payload[lo..hi]);
                segs.push(si);
            }
            stored_bytes += payload.len();
            slots.push(FrameSlot { segs, len: payload.len(), droppable, present: true });
        }
        self.clock += 1;
        let stamp = self.clock;
        self.residents.insert(id, Resident { frames: slots, class, stamp, refs: 0 });
        self.stats.inserts += 1;
        self.stats.insert_dropped_frames += dropped as u64;
        obs::span(obs::SpanKind::TierInsert, id, t_insert, stored_bytes as u64, dropped as u64);
        Some(InsertReceipt { stored_bytes, dropped_frames: dropped })
    }

    /// Free pooled segments until at least `need` are available, destroying
    /// only state of residents whose class is `>= class` (never anything
    /// more important): droppable frames first — least-important,
    /// least-recently-touched resident, last droppable frame first — then
    /// whole residents in the same order. Returns false if the target
    /// cannot be met (callers precheck, so this is a defensive rail).
    fn free_up(&mut self, need: usize, class: u8) -> bool {
        while self.available_segs() < need {
            // Pass 1: drop one droppable frame. Ordering mirrors resident
            // eviction: highest class value (least important) first, then
            // least recently touched, then smallest id — total, so
            // deterministic.
            let frame_victim = self
                .residents
                .iter()
                .filter(|(_, r)| r.class >= class && r.refs == 0 && r.has_droppable())
                .max_by_key(|(&vid, r)| (r.class, std::cmp::Reverse(r.stamp), std::cmp::Reverse(vid)))
                .map(|(&vid, _)| vid);
            if let Some(vid) = frame_victim {
                self.drop_one_frame(vid);
                continue;
            }
            // Pass 2: evict a whole resident.
            let victim = self
                .residents
                .iter()
                .filter(|(_, r)| r.class >= class && r.refs == 0)
                .max_by_key(|(&vid, r)| (r.class, std::cmp::Reverse(r.stamp), std::cmp::Reverse(vid)))
                .map(|(&vid, _)| vid);
            match victim {
                Some(vid) => self.evict(vid),
                None => return false,
            }
        }
        true
    }

    /// Drop the last present droppable frame of `id` (later layers' windows
    /// go first), returning its segments to the free list.
    fn drop_one_frame(&mut self, id: u64) {
        if let Some(r) = self.residents.get_mut(&id) {
            if let Some(f) = r.frames.iter_mut().rev().find(|f| f.present && f.droppable) {
                f.present = false;
                self.stats.frame_evictions += 1;
                self.stats.evicted_frame_bytes += f.len as u64;
                self.free.extend(std::mem::take(&mut f.segs));
                f.len = 0;
            }
        }
    }

    fn evict(&mut self, id: u64) {
        if let Some(r) = self.residents.remove(&id) {
            self.stats.evictions += 1;
            self.stats.evicted_bytes += r.present_bytes() as u64;
            for f in r.frames {
                self.free.extend(f.segs);
            }
        }
    }

    /// Drop a resident without reading it (deadline expiry, request
    /// cancellation). Not counted as an eviction. Returns whether `id` was
    /// resident.
    pub fn remove(&mut self, id: u64) -> bool {
        match self.residents.remove(&id) {
            Some(r) => {
                for f in r.frames {
                    self.free.extend(f.segs);
                }
                true
            }
            None => false,
        }
    }

    fn assemble(&self, f: &FrameSlot) -> Vec<u8> {
        let mut out = Vec::with_capacity(f.len);
        let mut left = f.len;
        for &si in &f.segs {
            let take = left.min(self.seg_bytes);
            out.extend_from_slice(&self.segments[si as usize][..take]);
            left -= take;
        }
        debug_assert_eq!(left, 0);
        out
    }

    /// Cheap pre-check for [`WarmTier::insert_frames`]: false when the tier
    /// has no capacity at all, or every pooled segment is held by strictly
    /// more-important (or pinned) residents — an insert at `class` cannot
    /// possibly succeed, so callers can skip building the payload (the
    /// scheduler checks this before serializing a preemption victim).
    pub fn may_accept(&self, class: u8) -> bool {
        if self.max_segs == 0 {
            return false;
        }
        self.available_segs() > 0
            || self.residents.values().any(|r| r.class >= class && r.refs == 0)
    }

    /// Pin `id` against eviction, incrementing its reference count and
    /// refreshing its LRU stamp. Returns false when `id` is not resident.
    pub fn retain(&mut self, id: u64) -> bool {
        self.clock += 1;
        let stamp = self.clock;
        match self.residents.get_mut(&id) {
            Some(r) => {
                r.refs += 1;
                r.stamp = stamp;
                true
            }
            None => false,
        }
    }

    /// Drop one pin on `id` (saturating at zero). A resident back at zero
    /// refs rejoins ordinary LRU eviction order with the stamp of its last
    /// retain. Returns false when `id` is not resident.
    pub fn release(&mut self, id: u64) -> bool {
        match self.residents.get_mut(&id) {
            Some(r) => {
                r.refs = r.refs.saturating_sub(1);
                true
            }
            None => false,
        }
    }

    /// Current pin count of `id` (0 when not resident).
    pub fn refs(&self, id: u64) -> u32 {
        self.residents.get(&id).map_or(0, |r| r.refs)
    }

    /// Copy out a resident's whole payload without removing it — the
    /// shared-read path (prefix images are borrowed, not consumed). Returns
    /// `None` when `id` is not resident or any frame was dropped. Does not
    /// count as a hit or refresh recency; pair with [`WarmTier::retain`]
    /// when the caller keeps the bytes live.
    pub fn peek(&self, id: u64) -> Option<Vec<u8>> {
        let r = self.residents.get(&id)?;
        if r.frames.iter().any(|f| !f.present) {
            return None;
        }
        let mut out = Vec::with_capacity(r.present_bytes());
        for f in &r.frames {
            out.extend_from_slice(&self.assemble(f));
        }
        Some(out)
    }

    /// Read a resident's payload and remove it, returning its segments to
    /// the free list — the whole-payload restore path. Returns `None` (a
    /// miss) when `id` is not resident *or* when any of its frames was
    /// dropped (a concatenation with holes would be garbage); frame-aware
    /// callers use [`WarmTier::take_frames`] instead, which can act on a
    /// partial resident.
    pub fn take(&mut self, id: u64) -> Option<Vec<u8>> {
        if self.is_partial(id) {
            self.remove(id);
            self.stats.misses += 1;
            return None;
        }
        self.take_frames(id).map(|t| {
            let mut out = Vec::new();
            for f in t.frames.into_iter().flatten() {
                out.extend_from_slice(&f);
            }
            out
        })
    }

    /// Read a resident's frames and remove it, returning its segments to
    /// the free list — the frame-aware restore path. Dropped frames come
    /// back as `None`; the take still counts as a (partial) hit because the
    /// surviving frames spare real recompute work.
    pub fn take_frames(&mut self, id: u64) -> Option<TakenFrames> {
        let t_take = obs::start();
        match self.residents.remove(&id) {
            Some(r) => {
                let bytes = r.present_bytes();
                let mut frames = Vec::with_capacity(r.frames.len());
                let mut partial = false;
                for f in &r.frames {
                    if f.present {
                        frames.push(Some(self.assemble(f)));
                    } else {
                        frames.push(None);
                        partial = true;
                    }
                }
                for f in r.frames {
                    self.free.extend(f.segs);
                }
                self.stats.hits += 1;
                if partial {
                    self.stats.partial_hits += 1;
                }
                obs::span(obs::SpanKind::TierTake, id, t_take, bytes as u64, partial as u64);
                Some(TakenFrames { frames })
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn payload(len: usize, fill: u8) -> Vec<u8> {
        (0..len).map(|i| fill.wrapping_add(i as u8)).collect()
    }

    fn tier(n_segs: usize) -> WarmTier {
        WarmTier::new(n_segs * 1024, 1024)
    }

    #[test]
    fn insert_take_round_trip_across_segment_boundaries() {
        let mut t = tier(8);
        for len in [0usize, 1, 1023, 1024, 1025, 3 * 1024 + 17] {
            let p = payload(len, 7);
            assert!(t.insert(42, 1, &p).is_some(), "len {len}");
            assert!(t.contains(42));
            assert_eq!(t.take(42), Some(p), "len {len}");
            assert!(!t.contains(42));
        }
        assert_eq!(t.stats.hits, 6);
        assert_eq!(t.stats.misses, 0);
    }

    #[test]
    fn free_list_reuses_segments_instead_of_growing() {
        let mut t = tier(4);
        for round in 0..10 {
            let p = payload(3 * 1024, round);
            assert!(t.insert(round as u64, 1, &p).is_some());
            assert_eq!(t.take(round as u64), Some(p));
        }
        assert!(t.segments.len() <= 4, "pool grew past its budget: {}", t.segments.len());
        assert_eq!(t.reserved_bytes(), 0);
    }

    #[test]
    fn lru_eviction_within_a_class() {
        let mut t = tier(4); // 4 segments of 1 KiB
        assert!(t.insert(1, 1, &payload(2 * 1024, 1)).is_some()); // 2 segs
        assert!(t.insert(2, 1, &payload(2 * 1024, 2)).is_some()); // 2 segs, pool full
        // Re-inserting 1 (replacement) refreshes its recency stamp.
        assert!(t.insert(1, 1, &payload(2 * 1024, 1)).is_some());
        assert!(t.insert(3, 1, &payload(1024, 3)).is_some()); // must evict LRU = 2
        assert!(t.contains(1) && !t.contains(2) && t.contains(3));
        assert_eq!(t.stats.evictions, 1);
        assert_eq!(t.stats.evicted_bytes, 2 * 1024);
        assert_eq!(t.take(2), None);
        assert_eq!(t.stats.misses, 1);
    }

    #[test]
    fn may_accept_screens_doomed_inserts() {
        assert!(!WarmTier::new(0, 1024).may_accept(0));
        let mut t = tier(2);
        assert!(t.may_accept(2), "empty tier accepts any class");
        assert!(t.insert(1, 0, &payload(2 * 1024, 1)).is_some()); // interactive fills it
        assert!(!t.may_accept(2), "batch cannot displace interactive");
        assert!(t.may_accept(0), "equal class can displace via LRU");
        t.remove(1);
        assert!(t.may_accept(2));
    }

    #[test]
    fn lower_importance_residents_evict_first() {
        let mut t = tier(4);
        assert!(t.insert(10, 0, &payload(2 * 1024, 1)).is_some()); // interactive
        assert!(t.insert(20, 2, &payload(2 * 1024, 2)).is_some()); // batch
        // A standard-class insert evicts the batch resident, not interactive.
        assert!(t.insert(30, 1, &payload(2 * 1024, 3)).is_some());
        assert!(t.contains(10) && !t.contains(20) && t.contains(30));
    }

    #[test]
    fn insert_never_destroys_more_important_residents() {
        let mut t = tier(2);
        assert!(t.insert(1, 0, &payload(2 * 1024, 1)).is_some()); // fills the pool
        // A batch-class snapshot cannot displace interactive state.
        assert!(t.insert(2, 2, &payload(1024, 2)).is_none());
        assert!(t.contains(1) && !t.contains(2));
        assert_eq!(t.stats.insert_rejected, 1);
        assert_eq!(t.stats.evictions, 0);
    }

    #[test]
    fn oversized_and_zero_budget_inserts_are_refused() {
        let mut t = tier(2);
        assert!(t.insert(1, 0, &payload(3 * 1024, 1)).is_none());
        let mut none = WarmTier::new(0, 1024);
        assert!(none.insert(1, 0, &payload(1, 1)).is_none());
        assert_eq!(none.budget_bytes(), 0);
    }

    #[test]
    fn failed_replacement_keeps_the_old_resident() {
        let mut t = tier(2);
        assert!(t.insert(7, 1, &payload(1024, 3)).is_some());
        // Replacement too big for the whole pool: refused, original intact.
        assert!(t.insert(7, 1, &payload(3 * 1024, 4)).is_none());
        assert_eq!(t.take(7), Some(payload(1024, 3)));
        // Replacement blocked by a more-important resident: same guarantee.
        let mut t = tier(2);
        assert!(t.insert(1, 0, &payload(1024, 1)).is_some()); // interactive, 1 seg
        assert!(t.insert(7, 2, &payload(1024, 2)).is_some()); // batch, 1 seg — pool full
        assert!(t.insert(7, 2, &payload(2 * 1024, 9)).is_none(), "would need to evict id 1");
        assert_eq!(t.take(7), Some(payload(1024, 2)), "old snapshot must survive");
    }

    #[test]
    fn replacing_an_id_keeps_one_resident() {
        let mut t = tier(4);
        assert!(t.insert(5, 1, &payload(1024, 1)).is_some());
        assert!(t.insert(5, 1, &payload(2048, 9)).is_some());
        assert_eq!(t.n_residents(), 1);
        assert_eq!(t.take(5), Some(payload(2048, 9)));
        assert_eq!(t.reserved_bytes(), 0);
    }

    // -- frame-granular behavior ------------------------------------------

    fn frames3(core: &[u8], win_a: &[u8], win_b: &[u8]) -> Vec<(Vec<u8>, FrameKind)> {
        vec![
            (core.to_vec(), FrameKind::Required),
            (win_a.to_vec(), FrameKind::Droppable),
            (win_b.to_vec(), FrameKind::Droppable),
        ]
    }

    fn as_refs(fs: &[(Vec<u8>, FrameKind)]) -> Vec<(&[u8], FrameKind)> {
        fs.iter().map(|(p, k)| (p.as_slice(), *k)).collect()
    }

    #[test]
    fn framed_round_trip_preserves_every_frame() {
        let mut t = tier(8);
        let fs = frames3(&payload(1500, 1), &payload(800, 2), &payload(900, 3));
        let receipt = t.insert_frames(9, 1, &as_refs(&fs)).expect("insert");
        assert_eq!(receipt.stored_bytes, 1500 + 800 + 900);
        assert_eq!(receipt.dropped_frames, 0);
        assert_eq!(t.resident_bytes_of(9), Some(1500 + 800 + 900));
        let got = t.take_frames(9).expect("take");
        assert!(got.is_full());
        for (want, have) in fs.iter().zip(&got.frames) {
            assert_eq!(have.as_ref().unwrap(), &want.0);
        }
        assert_eq!(t.stats.partial_hits, 0);
        assert_eq!(t.reserved_bytes(), 0);
    }

    #[test]
    fn pressure_drops_droppable_frames_before_whole_residents() {
        let mut t = tier(4);
        // Resident 1: 1 required + 2 droppable segments — fills 3 of 4.
        let fs = frames3(&payload(1024, 1), &payload(1024, 2), &payload(1024, 3));
        assert!(t.insert_frames(1, 1, &as_refs(&fs)).is_some());
        // A 3-segment insert must drop resident 1's window frames (last
        // first), not evict it.
        assert!(t.insert(2, 1, &payload(3 * 1024, 9)).is_some());
        assert!(t.contains(1), "resident must survive as partial");
        assert!(t.is_partial(1));
        assert_eq!(t.stats.frame_evictions, 2);
        assert_eq!(t.stats.evicted_frame_bytes, 2 * 1024);
        assert_eq!(t.stats.evictions, 0);
        assert_eq!(t.resident_bytes_of(1), Some(1024), "only the core remains");
        let got = t.take_frames(1).expect("partial take");
        assert!(!got.is_full());
        assert_eq!(got.frames[0].as_deref(), Some(payload(1024, 1).as_slice()));
        assert_eq!(got.frames[1], None);
        assert_eq!(got.frames[2], None);
        assert_eq!(t.stats.partial_hits, 1);
    }

    #[test]
    fn degraded_insert_stores_required_frames_only() {
        let mut t = tier(2);
        // Required fits, the full set does not: degrade instead of refuse.
        let fs = frames3(&payload(1024, 1), &payload(1024, 2), &payload(1024, 3));
        let receipt = t.insert_frames(5, 1, &as_refs(&fs)).expect("degraded insert");
        assert_eq!(receipt.dropped_frames, 2);
        assert_eq!(receipt.stored_bytes, 1024);
        assert!(t.is_partial(5));
        assert_eq!(t.stats.insert_dropped_frames, 2);
        let got = t.take_frames(5).expect("take");
        assert_eq!(got.frames[0].as_deref(), Some(payload(1024, 1).as_slice()));
        assert!(got.frames[1].is_none() && got.frames[2].is_none());
    }

    #[test]
    fn whole_take_refuses_partial_residents() {
        let mut t = tier(2);
        let fs = frames3(&payload(1024, 1), &payload(1024, 2), &payload(512, 3));
        assert!(t.insert_frames(6, 1, &as_refs(&fs)).is_some()); // degraded
        assert!(t.is_partial(6));
        assert_eq!(t.take(6), None, "monolithic take must not hand back holes");
        assert!(!t.contains(6));
        assert_eq!(t.stats.misses, 1);
    }

    #[test]
    fn frame_drops_never_touch_more_important_residents() {
        let mut t = tier(3);
        let fs = frames3(&payload(1024, 1), &payload(1024, 2), &payload(1024, 3));
        assert!(t.insert_frames(1, 0, &as_refs(&fs)).is_some()); // interactive
        // Batch insert: cannot drop interactive windows, must refuse.
        assert!(t.insert_frames(2, 2, &as_refs(&fs)).is_none());
        assert!(!t.is_partial(1), "interactive frames must be untouched");
        assert_eq!(t.stats.frame_evictions, 0);
    }

    // -- refcount pinning and shared reads --------------------------------

    #[test]
    fn insert_receipt_reports_stored_bytes() {
        let mut t = tier(4);
        let r = t.insert(11, 1, &payload(1500, 2)).expect("insert");
        assert_eq!(r.stored_bytes, 1500);
        assert_eq!(r.dropped_frames, 0);
    }

    #[test]
    fn pinned_residents_are_exempt_from_eviction() {
        let mut t = tier(2);
        assert!(t.insert(1, 2, &payload(2 * 1024, 1)).is_some()); // batch fills pool
        assert!(t.retain(1));
        assert_eq!(t.refs(1), 1);
        // Even interactive work cannot displace a pinned resident —
        // not whole, not frame by frame.
        assert!(t.insert(2, 0, &payload(1024, 2)).is_none());
        assert!(!t.may_accept(0), "only pinned bytes left: nothing evictable");
        assert!(t.release(1));
        assert_eq!(t.refs(1), 0);
        // Released back to zero refs, it rejoins ordinary LRU eviction.
        assert!(t.insert(2, 0, &payload(1024, 2)).is_some());
        assert!(!t.contains(1) && t.contains(2));
    }

    #[test]
    fn pinned_droppable_frames_survive_pressure() {
        let mut t = tier(4);
        let fs = frames3(&payload(1024, 1), &payload(1024, 2), &payload(1024, 3));
        assert!(t.insert_frames(1, 2, &as_refs(&fs)).is_some()); // 3 of 4 segs
        assert!(t.retain(1));
        // Needs 2 segments; only 1 is free and the rest are pinned.
        assert!(t.insert(2, 0, &payload(2 * 1024, 9)).is_none());
        assert!(!t.is_partial(1), "pinned windows must not be dropped");
        assert_eq!(t.stats.frame_evictions, 0);
    }

    #[test]
    fn retain_and_release_report_missing_residents() {
        let mut t = tier(2);
        assert!(!t.retain(9));
        assert!(!t.release(9));
        assert_eq!(t.refs(9), 0);
    }

    #[test]
    fn peek_reads_without_consuming() {
        let mut t = tier(4);
        let p = payload(1500, 5);
        assert!(t.insert(3, 1, &p).is_some());
        assert_eq!(t.peek(3), Some(p.clone()));
        assert_eq!(t.peek(3), Some(p.clone()), "peek must not consume");
        assert!(t.contains(3));
        assert_eq!(t.take(3), Some(p));
        assert_eq!(t.peek(3), None, "taken residents are gone");
    }

    #[test]
    fn peek_refuses_partial_residents() {
        let mut t = tier(2);
        let fs = frames3(&payload(1024, 1), &payload(1024, 2), &payload(512, 3));
        assert!(t.insert_frames(6, 1, &as_refs(&fs)).is_some()); // degraded
        assert!(t.is_partial(6));
        assert_eq!(t.peek(6), None, "peek must not hand back holes");
        assert!(t.contains(6), "peek never removes");
    }
}
