//! Lossless snapshot serialization of quantized KV-cache state.
//!
//! A snapshot is a self-contained little-endian byte image of a
//! [`HeadCache`] (or a whole [`Sequence`]): the sink and recent fp windows,
//! whichever quantized segment variant the method uses — packed codes,
//! `GroupParams`, and the planar `scales[]`/`zeffs[]` runtime planes — plus
//! the per-channel key norm and the method configuration itself. Every f32
//! travels as its IEEE bit pattern (`to_bits`/`from_bits`), so the round
//! trip is *bit*-exact, NaN payloads included: `restore(snapshot(c)) == c`
//! under the derived `PartialEq`, and `snapshot(restore(b)) == b` byte for
//! byte. That exactness is what lets the scheduler's offload preemption
//! promise that a restored sequence decodes identically to one that was
//! never offloaded (asserted in `tests/offload_preemption.rs`).
//!
//! The format is internal to this crate (it ferries caches between the live
//! engine and the [`super::tier::WarmTier`], and could ferry them to disk or
//! a remote host later); a magic/version header rejects foreign bytes
//! instead of misinterpreting them.
//!
//! ## Per-layer frames
//!
//! Alongside the monolithic sequence image, [`snapshot_sequence_frames`]
//! splits a sequence into independent byte frames: one *meta* frame (tokens,
//! prefill boundary, last logits) and, per [`LayerCache`], a *core* frame
//! (quantized segments + norms — the state that is expensive to recompute)
//! and a *windows* frame (the fp sink/recent rows — cheap to recompute for a
//! prefill-only sequence, and the bulk of the bytes at f32 vs 2–4-bit
//! codes). The warm tier stores the frames individually, so it can evict a
//! resident's window frames under pressure while keeping the cores;
//! [`restore_sequence_frames`] reports which layers came back without
//! windows so the engine can rebuild them
//! (`Engine::rebuild_windows`). Frame serialization is embarrassingly
//! parallel per layer; [`snapshot_sequence_frames_on`] fans it out over the
//! worker pool with byte-identical output.
//!
//! ## Shared prefixes (format v2)
//!
//! A sequence borrowing a shared prefix image (`HeadCache::shared_k` /
//! `shared_v`, see [`super::prefix`]) serializes through
//! `HeadCache::merged` on the monolithic and default framed paths, so its
//! snapshot bytes are *identical* to a sequence that quantized the same
//! tokens privately — sharing is invisible to the wire format. The offload
//! path can instead use [`snapshot_sequence_frames_by_ref`], whose core
//! frames carry a per-head kind byte: inline heads embed the full core as
//! before, by-reference heads embed the 64-bit prefix-store entry hash plus
//! only their private state. Restoring those frames
//! ([`restore_sequence_frames_with`]) resolves each hash back to its
//! pinned [`PrefixImage`] — the borrower kept its pin across the offload,
//! so the image cannot have been evicted underneath it.

use crate::cache::layer::LayerCache;
use crate::cache::manager::{HeadCache, KeySegment, ValSegment};
use crate::cache::store::prefix::{entry_hash, PrefixImage};
use crate::cache::segments::{
    FpSegment, InnerKeySegment, InnerValSegment, OuterKeySegment, OuterValSegment,
    TurboKeySegment, TurboValSegment,
};
use crate::cache::window::{RecentWindow, SinkWindow};
use crate::coordinator::engine::Sequence;
use crate::quant::group::Mode;
use crate::quant::norm::ChannelNorm;
use crate::quant::turbo::{Rotation, TurboToken};
use crate::quant::{GroupParams, Grouping, MethodConfig, QuantMethod};
use anyhow::{anyhow, Result};
use std::sync::Arc;

/// Header magic of a single-head snapshot ("IQHC").
const MAGIC_HEAD: u32 = 0x4951_4843;
/// Header magic of a full-sequence snapshot ("IQSQ").
const MAGIC_SEQ: u32 = 0x4951_5351;
/// Header magic of a sequence meta frame ("IQSM").
const MAGIC_META: u32 = 0x4951_534D;
/// Header magic of a layer core frame ("IQLC").
const MAGIC_LAYER_CORE: u32 = 0x4951_4C43;
/// Header magic of a layer windows frame ("IQLW").
const MAGIC_LAYER_WIN: u32 = 0x4951_4C57;
/// Header magic of a shared prefix image ("IQPX").
const MAGIC_PREFIX: u32 = 0x4951_5058;
/// Format version; bump on any layout change. v2: layer-core frames carry a
/// per-head kind byte (inline vs. prefix-store reference) and the prefix
/// image format exists.
const VERSION: u8 = 2;

/// Layer-core head kind: the full core is embedded in the frame.
const CORE_INLINE: u8 = 0;
/// Layer-core head kind: the head borrows a shared prefix image — the frame
/// carries its entry hash plus only the private state.
const CORE_BY_REF: u8 = 1;

// ---------------------------------------------------------------------------
// primitive writer / reader
// ---------------------------------------------------------------------------

#[derive(Default)]
struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn usz(&mut self, v: usize) {
        self.u64(v as u64);
    }
    fn f32(&mut self, v: f32) {
        self.u32(v.to_bits());
    }
    fn bytes(&mut self, b: &[u8]) {
        self.usz(b.len());
        self.buf.extend_from_slice(b);
    }
    fn f32s(&mut self, xs: &[f32]) {
        self.usz(xs.len());
        for &x in xs {
            self.f32(x);
        }
    }
    fn i32s(&mut self, xs: &[i32]) {
        self.usz(xs.len());
        for &x in xs {
            self.u32(x as u32);
        }
    }
    fn params(&mut self, ps: &[GroupParams]) {
        self.usz(ps.len());
        for p in ps {
            self.u16(p.scale);
            self.u16(p.zero);
        }
    }
}

struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(b: &'a [u8]) -> Reader<'a> {
        Reader { b, pos: 0 }
    }
    fn remaining(&self) -> usize {
        self.b.len() - self.pos
    }
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.remaining() {
            return Err(anyhow!("snapshot truncated at byte {} (need {n} more)", self.pos));
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn usz(&mut self) -> Result<usize> {
        Ok(self.u64()? as usize)
    }
    /// Element count prefix, validated against the bytes actually left so a
    /// corrupt length cannot trigger a huge allocation.
    fn count(&mut self, elem_bytes: usize) -> Result<usize> {
        let n = self.usz()?;
        if n.checked_mul(elem_bytes).map_or(true, |total| total > self.remaining()) {
            return Err(anyhow!("snapshot length {n} exceeds remaining payload"));
        }
        Ok(n)
    }
    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }
    fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.count(1)?;
        Ok(self.take(n)?.to_vec())
    }
    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.count(4)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.f32()?);
        }
        Ok(out)
    }
    fn i32s(&mut self) -> Result<Vec<i32>> {
        let n = self.count(4)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.u32()? as i32);
        }
        Ok(out)
    }
    fn params(&mut self) -> Result<Vec<GroupParams>> {
        let n = self.count(4)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let scale = self.u16()?;
            let zero = self.u16()?;
            out.push(GroupParams { scale, zero });
        }
        Ok(out)
    }
    fn done(&self) -> Result<()> {
        if self.remaining() != 0 {
            return Err(anyhow!("{} trailing bytes after snapshot payload", self.remaining()));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// enum tags
// ---------------------------------------------------------------------------

fn mode_tag(m: Mode) -> u8 {
    match m {
        Mode::Sym => 0,
        Mode::Asym => 1,
        Mode::Hybrid => 2,
    }
}

fn mode_from(tag: u8) -> Result<Mode> {
    match tag {
        0 => Ok(Mode::Sym),
        1 => Ok(Mode::Asym),
        2 => Ok(Mode::Hybrid),
        t => Err(anyhow!("bad quantization mode tag {t}")),
    }
}

fn grouping_tag(g: Grouping) -> u8 {
    match g {
        Grouping::Inner => 0,
        Grouping::Outer => 1,
    }
}

fn grouping_from(tag: u8) -> Result<Grouping> {
    match tag {
        0 => Ok(Grouping::Inner),
        1 => Ok(Grouping::Outer),
        t => Err(anyhow!("bad grouping tag {t}")),
    }
}

fn write_cfg(w: &mut Writer, cfg: &MethodConfig) {
    let midx = QuantMethod::ALL
        .iter()
        .position(|m| *m == cfg.method)
        .expect("method present in QuantMethod::ALL") as u8;
    w.u8(midx);
    w.usz(cfg.group_size);
    w.usz(cfg.w_sink);
    w.usz(cfg.w_recent);
    w.u8(cfg.key_bits);
    w.u8(cfg.val_bits);
    w.u8(mode_tag(cfg.key_mode));
    w.u8(mode_tag(cfg.val_mode));
    w.u8(grouping_tag(cfg.key_grouping));
    w.u8(grouping_tag(cfg.val_grouping));
    w.u8(cfg.key_norm as u8);
    w.u8(cfg.turbo as u8);
}

fn read_cfg(r: &mut Reader) -> Result<MethodConfig> {
    let midx = r.u8()? as usize;
    let method = *QuantMethod::ALL
        .get(midx)
        .ok_or_else(|| anyhow!("bad quant method tag {midx}"))?;
    Ok(MethodConfig {
        method,
        group_size: r.usz()?,
        w_sink: r.usz()?,
        w_recent: r.usz()?,
        key_bits: r.u8()?,
        val_bits: r.u8()?,
        key_mode: mode_from(r.u8()?)?,
        val_mode: mode_from(r.u8()?)?,
        key_grouping: grouping_from(r.u8()?)?,
        val_grouping: grouping_from(r.u8()?)?,
        key_norm: r.u8()? != 0,
        turbo: r.u8()? != 0,
    })
}

// ---------------------------------------------------------------------------
// windows / segments
// ---------------------------------------------------------------------------

fn write_sink(w: &mut Writer, s: &SinkWindow) {
    w.usz(s.d_h);
    w.f32s(&s.rows);
    w.usz(s.capacity);
}

fn read_sink(r: &mut Reader) -> Result<SinkWindow> {
    Ok(SinkWindow { d_h: r.usz()?, rows: r.f32s()?, capacity: r.usz()? })
}

fn write_recent(w: &mut Writer, s: &RecentWindow) {
    // The buffer is serialized verbatim, dead prefix included: the derived
    // `PartialEq` on RecentWindow compares `data` and `start` exactly, and
    // compaction bounds the dead prefix to at most the live length.
    w.usz(s.d_h);
    w.f32s(&s.data);
    w.usz(s.start);
}

fn read_recent(r: &mut Reader) -> Result<RecentWindow> {
    Ok(RecentWindow { d_h: r.usz()?, data: r.f32s()?, start: r.usz()? })
}

fn write_turbo_tokens(w: &mut Writer, tokens: &[TurboToken]) {
    w.usz(tokens.len());
    for t in tokens {
        w.bytes(&t.codes);
        w.f32(t.norm);
    }
}

fn read_turbo_tokens(r: &mut Reader) -> Result<Vec<TurboToken>> {
    // ≥ 13 bytes each on the wire (length prefix + norm), so /8 is a safe
    // allocation bound.
    let n = r.count(8)?;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let codes = r.bytes()?;
        let norm = r.f32()?;
        out.push(TurboToken { codes, norm });
    }
    Ok(out)
}

const SEG_FP: u8 = 0;
const SEG_INNER: u8 = 1;
const SEG_OUTER: u8 = 2;
const SEG_TURBO: u8 = 3;

fn write_key_segment(w: &mut Writer, seg: &KeySegment) {
    match seg {
        KeySegment::Fp(s) => {
            w.u8(SEG_FP);
            w.usz(s.d_h);
            w.f32s(&s.rows);
        }
        KeySegment::Inner(s) => {
            w.u8(SEG_INNER);
            w.usz(s.d_h);
            w.u8(s.bits);
            w.u8(mode_tag(s.mode));
            w.bytes(&s.codes);
            w.params(&s.params);
            w.f32s(&s.scales);
            w.f32s(&s.zeffs);
            w.usz(s.n_tokens);
        }
        KeySegment::Outer(s) => {
            w.u8(SEG_OUTER);
            w.usz(s.d_h);
            w.u8(s.bits);
            w.u8(mode_tag(s.mode));
            w.bytes(&s.codes);
            w.params(&s.params);
            w.f32s(&s.scales);
            w.f32s(&s.zeffs);
            w.usz(s.n_chunks);
        }
        KeySegment::Turbo(s) => {
            w.u8(SEG_TURBO);
            w.usz(s.d_h);
            w.u8(s.bits);
            w.f32s(&s.rotation.signs);
            write_turbo_tokens(w, &s.tokens);
        }
    }
}

fn read_key_segment(r: &mut Reader) -> Result<KeySegment> {
    match r.u8()? {
        SEG_FP => Ok(KeySegment::Fp(FpSegment { d_h: r.usz()?, rows: r.f32s()? })),
        SEG_INNER => Ok(KeySegment::Inner(InnerKeySegment {
            d_h: r.usz()?,
            bits: r.u8()?,
            mode: mode_from(r.u8()?)?,
            codes: r.bytes()?,
            params: r.params()?,
            scales: r.f32s()?,
            zeffs: r.f32s()?,
            n_tokens: r.usz()?,
        })),
        SEG_OUTER => Ok(KeySegment::Outer(OuterKeySegment {
            d_h: r.usz()?,
            bits: r.u8()?,
            mode: mode_from(r.u8()?)?,
            codes: r.bytes()?,
            params: r.params()?,
            scales: r.f32s()?,
            zeffs: r.f32s()?,
            n_chunks: r.usz()?,
        })),
        SEG_TURBO => Ok(KeySegment::Turbo(TurboKeySegment {
            d_h: r.usz()?,
            bits: r.u8()?,
            rotation: Rotation { signs: r.f32s()? },
            tokens: read_turbo_tokens(r)?,
        })),
        t => Err(anyhow!("bad key segment tag {t}")),
    }
}

fn write_val_segment(w: &mut Writer, seg: &ValSegment) {
    match seg {
        ValSegment::Fp(s) => {
            w.u8(SEG_FP);
            w.usz(s.d_h);
            w.f32s(&s.rows);
        }
        ValSegment::Inner(s) => {
            w.u8(SEG_INNER);
            w.usz(s.d_h);
            w.u8(s.bits);
            w.u8(mode_tag(s.mode));
            w.bytes(&s.codes);
            w.params(&s.params);
            w.f32s(&s.scales);
            w.f32s(&s.zeffs);
            w.usz(s.n_chunks);
        }
        ValSegment::Outer(s) => {
            w.u8(SEG_OUTER);
            w.usz(s.d_h);
            w.u8(s.bits);
            w.u8(mode_tag(s.mode));
            w.bytes(&s.codes);
            w.params(&s.params);
            w.f32s(&s.scales);
            w.f32s(&s.zeffs);
            w.usz(s.n_tokens);
        }
        ValSegment::Turbo(s) => {
            w.u8(SEG_TURBO);
            w.usz(s.d_h);
            w.u8(s.bits);
            w.f32s(&s.rotation.signs);
            write_turbo_tokens(w, &s.tokens);
        }
    }
}

fn read_val_segment(r: &mut Reader) -> Result<ValSegment> {
    match r.u8()? {
        SEG_FP => Ok(ValSegment::Fp(FpSegment { d_h: r.usz()?, rows: r.f32s()? })),
        SEG_INNER => Ok(ValSegment::Inner(InnerValSegment {
            d_h: r.usz()?,
            bits: r.u8()?,
            mode: mode_from(r.u8()?)?,
            codes: r.bytes()?,
            params: r.params()?,
            scales: r.f32s()?,
            zeffs: r.f32s()?,
            n_chunks: r.usz()?,
        })),
        SEG_OUTER => Ok(ValSegment::Outer(OuterValSegment {
            d_h: r.usz()?,
            bits: r.u8()?,
            mode: mode_from(r.u8()?)?,
            codes: r.bytes()?,
            params: r.params()?,
            scales: r.f32s()?,
            zeffs: r.f32s()?,
            n_tokens: r.usz()?,
        })),
        SEG_TURBO => Ok(ValSegment::Turbo(TurboValSegment {
            d_h: r.usz()?,
            bits: r.u8()?,
            rotation: Rotation { signs: r.f32s()? },
            tokens: read_turbo_tokens(r)?,
        })),
        t => Err(anyhow!("bad val segment tag {t}")),
    }
}

// ---------------------------------------------------------------------------
// head cache / sequence
// ---------------------------------------------------------------------------

fn write_head_body(w: &mut Writer, hc: &HeadCache) {
    // Shared-prefix borrowers serialize their merged view: the snapshot of
    // a sharing sequence is byte-identical to its private-copy twin.
    if hc.shared_k.is_some() || hc.shared_v.is_some() {
        return write_head_body(w, &hc.merged());
    }
    write_cfg(w, &hc.cfg);
    w.usz(hc.d_h);
    write_sink(w, &hc.sink_k);
    write_sink(w, &hc.sink_v);
    write_recent(w, &hc.recent_k);
    write_recent(w, &hc.recent_v);
    write_key_segment(w, &hc.qk);
    write_val_segment(w, &hc.qv);
    w.f32s(&hc.norm.scale);
    w.f32s(&hc.norm.inv_scale);
    w.usz(hc.n_tokens);
}

fn read_head_body(r: &mut Reader) -> Result<HeadCache> {
    let cfg = read_cfg(r)?;
    let d_h = r.usz()?;
    let sink_k = read_sink(r)?;
    let sink_v = read_sink(r)?;
    let recent_k = read_recent(r)?;
    let recent_v = read_recent(r)?;
    let qk = read_key_segment(r)?;
    let qv = read_val_segment(r)?;
    let scale = r.f32s()?;
    let inv_scale = r.f32s()?;
    let n_tokens = r.usz()?;
    Ok(HeadCache {
        cfg,
        d_h,
        sink_k,
        sink_v,
        recent_k,
        recent_v,
        shared_k: None,
        shared_v: None,
        qk,
        qv,
        norm: ChannelNorm { scale, inv_scale },
        n_tokens,
    })
}

/// Serialize one [`HeadCache`] into a self-contained byte image.
pub fn snapshot_head(hc: &HeadCache) -> Vec<u8> {
    let mut w = Writer::default();
    w.u32(MAGIC_HEAD);
    w.u8(VERSION);
    write_head_body(&mut w, hc);
    w.buf
}

/// Reconstruct a [`HeadCache`] from [`snapshot_head`] bytes. The result is
/// bit-identical to the snapshotted cache (`==` under the derived
/// `PartialEq`), so decoding on it matches the never-offloaded path exactly.
pub fn restore_head(bytes: &[u8]) -> Result<HeadCache> {
    let mut r = Reader::new(bytes);
    if r.u32()? != MAGIC_HEAD {
        return Err(anyhow!("not a head-cache snapshot (bad magic)"));
    }
    let v = r.u8()?;
    if v != VERSION {
        return Err(anyhow!("unsupported head snapshot version {v}"));
    }
    let hc = read_head_body(&mut r)?;
    r.done()?;
    Ok(hc)
}

/// Serialize a whole live [`Sequence`] — token history, prefill boundary,
/// last-step logits, and every per-(layer, head) cache — into one byte
/// image. This is the monolithic form used by benches and tests; the
/// scheduler's offload path uses the framed form
/// ([`snapshot_sequence_frames`]) so the warm tier can hold layers
/// individually.
pub fn snapshot_sequence(seq: &Sequence) -> Vec<u8> {
    let mut w = Writer::default();
    w.u32(MAGIC_SEQ);
    w.u8(VERSION);
    w.u64(seq.id);
    w.i32s(&seq.tokens);
    w.usz(seq.n_prefill);
    w.f32s(&seq.last_logits);
    w.usz(seq.caches.len());
    for layer in &seq.caches {
        w.usz(layer.n_heads());
        for hc in layer.heads() {
            write_head_body(&mut w, hc);
        }
    }
    w.buf
}

/// Reconstruct a [`Sequence`] from [`snapshot_sequence`] bytes. The restored
/// sequence resumes decoding exactly where the snapshot left off.
pub fn restore_sequence(bytes: &[u8]) -> Result<Sequence> {
    let mut r = Reader::new(bytes);
    if r.u32()? != MAGIC_SEQ {
        return Err(anyhow!("not a sequence snapshot (bad magic)"));
    }
    let v = r.u8()?;
    if v != VERSION {
        return Err(anyhow!("unsupported sequence snapshot version {v}"));
    }
    let id = r.u64()?;
    let tokens = r.i32s()?;
    let n_prefill = r.usz()?;
    let last_logits = r.f32s()?;
    let n_layers = r.count(1)?;
    let mut caches = Vec::with_capacity(n_layers);
    for _ in 0..n_layers {
        let n_heads = r.count(1)?;
        let mut layer = Vec::with_capacity(n_heads);
        for _ in 0..n_heads {
            layer.push(read_head_body(&mut r)?);
        }
        caches.push(LayerCache::from_heads(layer));
    }
    r.done()?;
    Ok(Sequence { id, tokens, caches, n_prefill, last_logits })
}

// ---------------------------------------------------------------------------
// per-layer frames
// ---------------------------------------------------------------------------

/// Everything in a [`HeadCache`] except the fp windows: config, quantized
/// segments, norm, token count. The windows are serialized (and restorable)
/// separately so the warm tier can drop them under pressure. Writes exactly
/// the head's *own* segments — shared-aware callers pick between this
/// (by-reference, private state only) and the merged view.
fn write_head_core_raw(w: &mut Writer, hc: &HeadCache) {
    write_cfg(w, &hc.cfg);
    w.usz(hc.d_h);
    write_key_segment(w, &hc.qk);
    write_val_segment(w, &hc.qv);
    w.f32s(&hc.norm.scale);
    w.f32s(&hc.norm.inv_scale);
    w.usz(hc.n_tokens);
}

/// [`write_head_core_raw`] through the merged view for shared-prefix
/// borrowers, so inline cores are byte-identical to the private-copy path.
fn write_head_core(w: &mut Writer, hc: &HeadCache) {
    if hc.shared_k.is_some() || hc.shared_v.is_some() {
        write_head_core_raw(w, &hc.merged());
    } else {
        write_head_core_raw(w, hc);
    }
}

/// Core counterpart of [`read_head_body`]: the returned cache carries
/// *empty* fp windows — the caller must install a windows frame
/// ([`read_head_windows_into`]) or rebuild them
/// (`HeadCache::rebuild_windows`) before the cache is usable.
fn read_head_core(r: &mut Reader) -> Result<HeadCache> {
    let cfg = read_cfg(r)?;
    let d_h = r.usz()?;
    let qk = read_key_segment(r)?;
    let qv = read_val_segment(r)?;
    let scale = r.f32s()?;
    let inv_scale = r.f32s()?;
    let n_tokens = r.usz()?;
    Ok(HeadCache {
        sink_k: SinkWindow::new(d_h, cfg.w_sink),
        sink_v: SinkWindow::new(d_h, cfg.w_sink),
        recent_k: RecentWindow::new(d_h),
        recent_v: RecentWindow::new(d_h),
        shared_k: None,
        shared_v: None,
        cfg,
        d_h,
        qk,
        qv,
        norm: ChannelNorm { scale, inv_scale },
        n_tokens,
    })
}

/// Read one head of a layer-core frame: the kind byte, then either an
/// inline core or an entry hash plus private core resolved against the
/// prefix store ([`restore_sequence_frames_with`]).
fn read_head_core_entry(
    r: &mut Reader,
    resolver: &dyn Fn(u64) -> Option<Arc<PrefixImage>>,
) -> Result<HeadCache> {
    match r.u8()? {
        CORE_INLINE => read_head_core(r),
        CORE_BY_REF => {
            let entry = r.u64()?;
            let mut hc = read_head_core(r)?;
            let img = resolver(entry).ok_or_else(|| {
                anyhow!("snapshot references prefix image {entry:#018x} not resident in the store")
            })?;
            hc.shared_k = Some(img.qk.clone());
            hc.shared_v = Some(img.qv.clone());
            Ok(hc)
        }
        t => Err(anyhow!("bad layer-core head kind {t}")),
    }
}

fn write_head_windows(w: &mut Writer, hc: &HeadCache) {
    write_sink(w, &hc.sink_k);
    write_sink(w, &hc.sink_v);
    write_recent(w, &hc.recent_k);
    write_recent(w, &hc.recent_v);
}

fn read_head_windows_into(r: &mut Reader, hc: &mut HeadCache) -> Result<()> {
    hc.sink_k = read_sink(r)?;
    hc.sink_v = read_sink(r)?;
    hc.recent_k = read_recent(r)?;
    hc.recent_v = read_recent(r)?;
    Ok(())
}

/// One layer's pair of snapshot frames (see [`SequenceFrames`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerFrames {
    /// Required frame: quantized segments, norms, config, token count.
    pub core: Vec<u8>,
    /// Droppable frame: the fp sink/recent windows.
    pub windows: Vec<u8>,
}

/// A sequence snapshot split into independently storable frames: one meta
/// frame plus a core/windows pair per layer. Byte-wise, `meta` + each
/// layer's `core` and `windows` together carry exactly the state of the
/// monolithic [`snapshot_sequence`] image.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SequenceFrames {
    /// Sequence metadata: id, token history, prefill boundary, last logits.
    pub meta: Vec<u8>,
    /// Per-layer frame pairs, in layer order.
    pub layers: Vec<LayerFrames>,
}

impl SequenceFrames {
    /// Total serialized bytes across every frame.
    pub fn total_bytes(&self) -> usize {
        self.meta.len()
            + self.layers.iter().map(|l| l.core.len() + l.windows.len()).sum::<usize>()
    }
}

fn write_meta_frame(seq: &Sequence) -> Vec<u8> {
    let mut w = Writer::default();
    w.u32(MAGIC_META);
    w.u8(VERSION);
    w.u64(seq.id);
    w.i32s(&seq.tokens);
    w.usz(seq.n_prefill);
    w.f32s(&seq.last_logits);
    w.usz(seq.caches.len());
    w.buf
}

fn write_layer_core_frame(lc: &LayerCache) -> Vec<u8> {
    let mut w = Writer::default();
    w.u32(MAGIC_LAYER_CORE);
    w.u8(VERSION);
    w.usz(lc.n_heads());
    for hc in lc.heads() {
        w.u8(CORE_INLINE);
        write_head_core(&mut w, hc);
    }
    w.buf
}

/// Core frame variant whose shared-prefix heads are serialized *by
/// reference*: the prefix-store entry hash plus only the private state,
/// instead of the merged image. Heads without a borrowed prefix are inline
/// as usual. `base` is the prefix base hash the borrowing sequence was
/// admitted under; `layer` its index.
fn write_layer_core_frame_by_ref(lc: &LayerCache, base: u64, layer: usize) -> Vec<u8> {
    let mut w = Writer::default();
    w.u32(MAGIC_LAYER_CORE);
    w.u8(VERSION);
    w.usz(lc.n_heads());
    for (h, hc) in lc.heads().iter().enumerate() {
        if hc.shared_k.is_some() || hc.shared_v.is_some() {
            w.u8(CORE_BY_REF);
            w.u64(entry_hash(base, layer, h));
            write_head_core_raw(&mut w, hc);
        } else {
            w.u8(CORE_INLINE);
            write_head_core(&mut w, hc);
        }
    }
    w.buf
}

fn write_layer_windows_frame(lc: &LayerCache) -> Vec<u8> {
    let mut w = Writer::default();
    w.u32(MAGIC_LAYER_WIN);
    w.u8(VERSION);
    w.usz(lc.n_heads());
    for hc in lc.heads() {
        write_head_windows(&mut w, hc);
    }
    w.buf
}

fn check_header(r: &mut Reader, magic: u32, what: &str) -> Result<()> {
    if r.u32()? != magic {
        return Err(anyhow!("not a {what} frame (bad magic)"));
    }
    let v = r.u8()?;
    if v != VERSION {
        return Err(anyhow!("unsupported {what} frame version {v}"));
    }
    Ok(())
}

/// Split a live [`Sequence`] into per-layer snapshot frames (serial form;
/// see [`snapshot_sequence_frames_on`] for the pooled fan-out).
pub fn snapshot_sequence_frames(seq: &Sequence) -> SequenceFrames {
    SequenceFrames {
        meta: write_meta_frame(seq),
        layers: seq
            .caches
            .iter()
            .map(|lc| LayerFrames {
                core: write_layer_core_frame(lc),
                windows: write_layer_windows_frame(lc),
            })
            .collect(),
    }
}

/// [`snapshot_sequence_frames`] for a sequence borrowing the shared prefix
/// stored under `base`: shared heads are framed by reference (entry hash +
/// private state), so an offloaded borrower's warm-tier resident holds only
/// its incremental bytes. Restore with [`restore_sequence_frames_with`] and
/// the store's resolver; the borrower's pins must outlive the offload.
pub fn snapshot_sequence_frames_by_ref(seq: &Sequence, base: u64) -> SequenceFrames {
    SequenceFrames {
        meta: write_meta_frame(seq),
        layers: seq
            .caches
            .iter()
            .enumerate()
            .map(|(l, lc)| LayerFrames {
                core: write_layer_core_frame_by_ref(lc, base, l),
                windows: write_layer_windows_frame(lc),
            })
            .collect(),
    }
}

/// [`snapshot_sequence_frames`], with the per-layer frame serialization
/// fanned out over `pool` — each layer's core+windows pair is one job
/// writing into its own slot, and the frames are read-only over the
/// sequence, so the scheduler can serialize an offload victim without
/// holding the driver thread for the whole image. Byte-identical to the
/// serial form at any worker count (asserted in the tests).
pub fn snapshot_sequence_frames_on(
    seq: &Sequence,
    pool: &crate::util::threadpool::ThreadPool,
) -> SequenceFrames {
    use crate::util::threadpool::Job;
    let meta = write_meta_frame(seq);
    let mut slots: Vec<Option<LayerFrames>> = (0..seq.caches.len()).map(|_| None).collect();
    {
        let jobs: Vec<Job> = seq
            .caches
            .iter()
            .zip(slots.iter_mut())
            .map(|(lc, slot)| {
                let job: Job = Box::new(move |_scratch: &mut Vec<f32>| {
                    *slot = Some(LayerFrames {
                        core: write_layer_core_frame(lc),
                        windows: write_layer_windows_frame(lc),
                    });
                });
                job
            })
            .collect();
        pool.run(jobs);
    }
    SequenceFrames {
        meta,
        layers: slots.into_iter().map(|s| s.expect("layer frame job filled its slot")).collect(),
    }
}

/// Reassemble a [`Sequence`] from its meta frame and per-layer frames, as
/// handed back by the warm tier. A layer's windows frame may be `None`
/// (evicted under pressure): its heads come back with *empty* fp windows
/// and the layer's index is reported in the second tuple element — the
/// caller must rebuild those windows (`Engine::rebuild_windows`) before the
/// sequence decodes. With every windows frame present the result is
/// bit-identical to the snapshotted sequence.
pub fn restore_sequence_frames(
    meta: &[u8],
    layers: &[(&[u8], Option<&[u8]>)],
) -> Result<(Sequence, Vec<usize>)> {
    restore_sequence_frames_with(meta, layers, &|_| None)
}

/// [`restore_sequence_frames`] with a prefix-store resolver for frames
/// written by [`snapshot_sequence_frames_by_ref`]: each by-reference head's
/// entry hash is resolved to its pinned [`PrefixImage`] and re-borrowed.
/// Fails if any referenced image cannot be resolved (which the scheduler
/// rules out by holding the borrower's pins across the offload). Inline
/// frames never invoke the resolver, so `restore_sequence_frames` is this
/// with a resolver that always misses.
pub fn restore_sequence_frames_with(
    meta: &[u8],
    layers: &[(&[u8], Option<&[u8]>)],
    resolver: &dyn Fn(u64) -> Option<Arc<PrefixImage>>,
) -> Result<(Sequence, Vec<usize>)> {
    let mut r = Reader::new(meta);
    check_header(&mut r, MAGIC_META, "sequence meta")?;
    let id = r.u64()?;
    let tokens = r.i32s()?;
    let n_prefill = r.usz()?;
    let last_logits = r.f32s()?;
    let n_layers = r.usz()?;
    r.done()?;
    if n_layers != layers.len() {
        return Err(anyhow!(
            "sequence meta expects {n_layers} layer frames, got {}",
            layers.len()
        ));
    }

    let mut caches = Vec::with_capacity(n_layers);
    let mut missing_windows = Vec::new();
    for (l, (core, windows)) in layers.iter().enumerate() {
        let mut cr = Reader::new(core);
        check_header(&mut cr, MAGIC_LAYER_CORE, "layer core")?;
        let n_heads = cr.count(1)?;
        let mut heads = Vec::with_capacity(n_heads);
        for _ in 0..n_heads {
            heads.push(read_head_core_entry(&mut cr, resolver)?);
        }
        cr.done()?;
        match windows {
            Some(wb) => {
                let mut wr = Reader::new(wb);
                check_header(&mut wr, MAGIC_LAYER_WIN, "layer windows")?;
                let wn = wr.count(1)?;
                if wn != n_heads {
                    return Err(anyhow!(
                        "layer {l}: windows frame has {wn} heads, core has {n_heads}"
                    ));
                }
                for hc in heads.iter_mut() {
                    read_head_windows_into(&mut wr, hc)?;
                }
                wr.done()?;
            }
            None => missing_windows.push(l),
        }
        caches.push(LayerCache::from_heads(heads));
    }
    Ok((Sequence { id, tokens, caches, n_prefill, last_logits }, missing_windows))
}

// ---------------------------------------------------------------------------
// prefix images
// ---------------------------------------------------------------------------

/// Serialized configuration identity (crate-internal): the prefix store
/// hashes these bytes into its content address, so any configuration field
/// that changes quantized bytes also rekeys the prefix.
pub(crate) fn cfg_bytes(cfg: &MethodConfig) -> Vec<u8> {
    let mut w = Writer::default();
    write_cfg(&mut w, cfg);
    w.buf
}

/// Serialize one shared [`PrefixImage`] into a self-contained byte image —
/// the prefix store's budget-accounting twin of the live `Arc`.
pub fn snapshot_prefix_image(img: &PrefixImage) -> Vec<u8> {
    let mut w = Writer::default();
    w.u32(MAGIC_PREFIX);
    w.u8(VERSION);
    w.usz(img.d_h);
    w.usz(img.prefix_len);
    write_key_segment(&mut w, &img.qk);
    write_val_segment(&mut w, &img.qv);
    w.f32s(&img.norm.scale);
    w.f32s(&img.norm.inv_scale);
    w.buf
}

/// Reconstruct a [`PrefixImage`] from [`snapshot_prefix_image`] bytes,
/// bit-identical to the serialized image.
pub fn restore_prefix_image(bytes: &[u8]) -> Result<PrefixImage> {
    let mut r = Reader::new(bytes);
    if r.u32()? != MAGIC_PREFIX {
        return Err(anyhow!("not a prefix image (bad magic)"));
    }
    let v = r.u8()?;
    if v != VERSION {
        return Err(anyhow!("unsupported prefix image version {v}"));
    }
    let d_h = r.usz()?;
    let prefix_len = r.usz()?;
    let qk = Arc::new(read_key_segment(&mut r)?);
    let qv = Arc::new(read_val_segment(&mut r)?);
    let scale = r.f32s()?;
    let inv_scale = r.f32s()?;
    r.done()?;
    Ok(PrefixImage { d_h, prefix_len, qk, qv, norm: ChannelNorm { scale, inv_scale } })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ptest::normal_vec;
    use crate::util::rng::Rng;

    fn build(m: QuantMethod, n: usize, seed: u64) -> HeadCache {
        let d_h = 64;
        let mut rng = Rng::new(seed);
        let keys = normal_vec(&mut rng, n * d_h, 1.0, 0.02);
        let vals = normal_vec(&mut rng, n * d_h, 1.0, 0.02);
        HeadCache::from_prefill(m.config(), d_h, &keys, &vals)
    }

    #[test]
    fn head_round_trip_is_bit_exact_for_every_method() {
        for m in QuantMethod::ALL {
            let hc = build(m, 300, 11);
            let bytes = snapshot_head(&hc);
            let back = restore_head(&bytes).expect("restore");
            assert_eq!(back, hc, "{m:?} snapshot round trip diverged");
            assert_eq!(snapshot_head(&back), bytes, "{m:?} re-serialization diverged");
        }
    }

    #[test]
    fn restored_cache_keeps_decoding_identically() {
        let d_h = 64;
        let mut rng = Rng::new(17);
        let mut hc = build(QuantMethod::InnerQBase, 250, 17);
        let bytes = snapshot_head(&hc);
        let mut back = restore_head(&bytes).expect("restore");
        // Append past a value-eviction boundary on both and attend.
        for _ in 0..40 {
            let k = normal_vec(&mut rng, d_h, 1.0, 0.0);
            let v = normal_vec(&mut rng, d_h, 1.0, 0.0);
            hc.append(&k, &v);
            back.append(&k, &v);
        }
        assert_eq!(back, hc);
        let q = normal_vec(&mut rng, d_h, 1.0, 0.0);
        let (mut o1, mut o2) = (vec![0f32; d_h], vec![0f32; d_h]);
        let mut scratch = Vec::new();
        hc.attend(&q, &mut o1, &mut scratch);
        back.attend(&q, &mut o2, &mut scratch);
        let b1: Vec<u32> = o1.iter().map(|v| v.to_bits()).collect();
        let b2: Vec<u32> = o2.iter().map(|v| v.to_bits()).collect();
        assert_eq!(b1, b2, "restore-then-attend must be bit-identical");
    }

    fn build_sequence(n_layers: usize, n_heads: usize, n: usize, seed: u64) -> Sequence {
        let d_h = 64;
        let mut rng = Rng::new(seed);
        let caches = (0..n_layers)
            .map(|_| {
                LayerCache::from_heads(
                    (0..n_heads)
                        .map(|_| {
                            let keys = normal_vec(&mut rng, n * d_h, 1.0, 0.02);
                            let vals = normal_vec(&mut rng, n * d_h, 1.0, 0.02);
                            HeadCache::from_prefill(
                                QuantMethod::InnerQBase.config(),
                                d_h,
                                &keys,
                                &vals,
                            )
                        })
                        .collect(),
                )
            })
            .collect();
        Sequence {
            id: 42,
            tokens: (0..n as i32).collect(),
            caches,
            n_prefill: n,
            last_logits: normal_vec(&mut rng, 25, 1.0, 0.0),
        }
    }

    #[test]
    fn sequence_frames_round_trip_bit_exact() {
        let seq = build_sequence(3, 2, 220, 0xF4A3);
        let mono = snapshot_sequence(&seq);
        let frames = snapshot_sequence_frames(&seq);
        let layer_refs: Vec<(&[u8], Option<&[u8]>)> = frames
            .layers
            .iter()
            .map(|l| (l.core.as_slice(), Some(l.windows.as_slice())))
            .collect();
        let (back, missing) = restore_sequence_frames(&frames.meta, &layer_refs).expect("restore");
        assert!(missing.is_empty());
        assert_eq!(
            snapshot_sequence(&back),
            mono,
            "framed round trip must carry exactly the monolithic state"
        );
        assert_eq!(
            snapshot_sequence_frames(&back),
            frames,
            "re-serialized frames must be byte-identical"
        );
        assert!(frames.total_bytes() > 0);
    }

    #[test]
    fn missing_window_frames_are_reported_not_fatal() {
        let seq = build_sequence(3, 2, 200, 0xF4A4);
        let frames = snapshot_sequence_frames(&seq);
        let layer_refs: Vec<(&[u8], Option<&[u8]>)> = frames
            .layers
            .iter()
            .enumerate()
            .map(|(l, f)| (f.core.as_slice(), (l != 1).then_some(f.windows.as_slice())))
            .collect();
        let (back, missing) = restore_sequence_frames(&frames.meta, &layer_refs).expect("restore");
        assert_eq!(missing, vec![1]);
        // Layer 1 came back with empty windows but its quantized state and
        // token count intact; the other layers are bit-exact.
        assert_eq!(back.caches[1].head(0).sink_k.len(), 0);
        assert_eq!(back.caches[1].head(0).len(), seq.caches[1].head(0).len());
        assert_eq!(back.caches[0], seq.caches[0]);
        assert_eq!(back.caches[2], seq.caches[2]);
    }

    #[test]
    fn pooled_frame_serialization_is_byte_identical() {
        use crate::util::threadpool::ThreadPool;
        let seq = build_sequence(4, 2, 180, 0xF4A5);
        let serial = snapshot_sequence_frames(&seq);
        for workers in [1usize, 2, 4] {
            let pool = ThreadPool::new(workers);
            let pooled = snapshot_sequence_frames_on(&seq, &pool);
            assert_eq!(pooled, serial, "workers={workers}");
        }
    }

    #[test]
    fn frame_headers_reject_wrong_kinds() {
        let seq = build_sequence(1, 1, 150, 0xF4A6);
        let frames = snapshot_sequence_frames(&seq);
        // Core bytes where windows are expected (and vice versa) must fail.
        let swapped: Vec<(&[u8], Option<&[u8]>)> =
            vec![(frames.layers[0].windows.as_slice(), Some(frames.layers[0].core.as_slice()))];
        assert!(restore_sequence_frames(&frames.meta, &swapped).is_err());
        // Meta frame with a mismatched layer count must fail.
        assert!(restore_sequence_frames(&frames.meta, &[]).is_err());
    }

    #[test]
    fn corrupt_or_foreign_bytes_are_rejected() {
        let hc = build(QuantMethod::InnerQBase, 150, 5);
        let bytes = snapshot_head(&hc);
        assert!(restore_head(&bytes[..bytes.len() - 3]).is_err(), "truncation");
        assert!(restore_head(&[0u8; 16]).is_err(), "bad magic");
        let mut extra = bytes.clone();
        extra.push(0);
        assert!(restore_head(&extra).is_err(), "trailing bytes");
        assert!(restore_sequence(&bytes).is_err(), "head bytes are not a sequence");
    }

    // -- shared prefixes ---------------------------------------------------

    #[test]
    fn prefix_image_round_trip_is_bit_exact() {
        let d_h = 64;
        for (i, m) in QuantMethod::ALL.iter().enumerate() {
            let mut rng = Rng::new(0x9A0 + i as u64);
            let keys = normal_vec(&mut rng, 180 * d_h, 1.0, 0.02);
            let vals = normal_vec(&mut rng, 180 * d_h, 1.0, 0.02);
            let mut donor = HeadCache::from_prefill_split_norm(m.config(), d_h, &keys, &vals, 180);
            let (qk, qv) = donor.split_off_prefix();
            let img = PrefixImage { d_h, prefix_len: 180, qk, qv, norm: donor.norm.clone() };
            let bytes = snapshot_prefix_image(&img);
            let back = restore_prefix_image(&bytes).expect("restore");
            assert_eq!(back, img, "{m:?} prefix image round trip diverged");
            assert_eq!(snapshot_prefix_image(&back), bytes, "{m:?} re-serialization diverged");
        }
        assert!(restore_prefix_image(&[1, 2, 3]).is_err(), "garbage must be rejected");
    }

    /// A 2-layer, 2-head sequence borrowing shared prefix images, plus the
    /// resolver map a prefix store would provide for it.
    fn build_shared_sequence(
        base: u64,
        n: usize,
        prefix: usize,
        seed: u64,
    ) -> (Sequence, std::collections::BTreeMap<u64, Arc<PrefixImage>>) {
        let d_h = 64;
        let cfg = QuantMethod::InnerQBase.config();
        let mut rng = Rng::new(seed);
        let mut resolver = std::collections::BTreeMap::new();
        let caches: Vec<LayerCache> = (0..2usize)
            .map(|l| {
                LayerCache::from_heads(
                    (0..2usize)
                        .map(|h| {
                            let keys = normal_vec(&mut rng, n * d_h, 1.0, 0.02);
                            let vals = normal_vec(&mut rng, n * d_h, 1.0, 0.02);
                            let mut donor = HeadCache::from_prefill_split_norm(
                                cfg,
                                d_h,
                                &keys[..prefix * d_h],
                                &vals[..prefix * d_h],
                                prefix,
                            );
                            let (qk, qv) = donor.split_off_prefix();
                            resolver.insert(
                                super::entry_hash(base, l, h),
                                Arc::new(PrefixImage {
                                    d_h,
                                    prefix_len: prefix,
                                    qk: qk.clone(),
                                    qv: qv.clone(),
                                    norm: donor.norm.clone(),
                                }),
                            );
                            HeadCache::from_shared_prefix(
                                cfg,
                                d_h,
                                &keys,
                                &vals,
                                prefix,
                                qk,
                                qv,
                                donor.norm.clone(),
                            )
                        })
                        .collect(),
                )
            })
            .collect();
        let seq = Sequence {
            id: 77,
            tokens: (0..n as i32).collect(),
            caches,
            n_prefill: n,
            last_logits: normal_vec(&mut rng, 25, 1.0, 0.0),
        };
        (seq, resolver)
    }

    #[test]
    fn shared_sequences_serialize_identically_to_merged_state_by_default() {
        let base = 0xF00D;
        let (seq, _) = build_shared_sequence(base, 260, 192, 0xF4A7);
        // Materialize the private-copy twin through merged().
        let twin = Sequence {
            id: seq.id,
            tokens: seq.tokens.clone(),
            caches: seq
                .caches
                .iter()
                .map(|lc| LayerCache::from_heads(lc.heads().iter().map(|h| h.merged()).collect()))
                .collect(),
            n_prefill: seq.n_prefill,
            last_logits: seq.last_logits.clone(),
        };
        assert_eq!(
            snapshot_sequence(&seq),
            snapshot_sequence(&twin),
            "monolithic snapshot must hide sharing"
        );
        assert_eq!(
            snapshot_sequence_frames(&seq),
            snapshot_sequence_frames(&twin),
            "default frames must hide sharing"
        );
    }

    #[test]
    fn by_ref_frames_resolve_back_to_shared_state() {
        let base = 0xBA5E;
        let (seq, resolver) = build_shared_sequence(base, 260, 192, 0xF4A8);
        let by_ref = snapshot_sequence_frames_by_ref(&seq, base);
        let inline = snapshot_sequence_frames(&seq);
        for (l, (b, i)) in by_ref.layers.iter().zip(&inline.layers).enumerate() {
            assert!(
                b.core.len() < i.core.len(),
                "layer {l}: by-ref core ({}) should be smaller than inline ({})",
                b.core.len(),
                i.core.len()
            );
        }
        let layer_refs: Vec<(&[u8], Option<&[u8]>)> = by_ref
            .layers
            .iter()
            .map(|l| (l.core.as_slice(), Some(l.windows.as_slice())))
            .collect();
        // Without a resolver the reference cannot be satisfied.
        assert!(restore_sequence_frames(&by_ref.meta, &layer_refs).is_err());
        let (back, missing) =
            restore_sequence_frames_with(&by_ref.meta, &layer_refs, &|e| resolver.get(&e).cloned())
                .expect("resolved restore");
        assert!(missing.is_empty());
        assert_eq!(back.caches, seq.caches, "restored borrower must match bit-for-bit");
        assert_eq!(
            snapshot_sequence(&back),
            snapshot_sequence(&seq),
            "restored borrower serializes like the original"
        );
    }
}
