//! Content-addressed, refcounted, copy-on-write store of shared prefix
//! images.
//!
//! Production chat/agent traffic repeats the same system prompt and
//! few-shot prefix across requests; today each sequence quantizes and
//! budgets a private copy of those tokens. InnerQ makes the prefix state a
//! pure function of the prefix tokens: the per-channel key norm is computed
//! over the prefix rows alone (`HeadCache::from_prefill_split_norm`), the
//! quantizers consume rows in a fixed position-independent cadence, and the
//! resulting quantized middle segments are immutable once written. So two
//! requests with the same `(prefix tokens, MethodConfig)` produce *the same
//! bytes* per `(layer, head)` — and those bytes can be stored once and
//! borrowed by every sequence.
//!
//! [`PrefixStore`] keys each per-(layer, head) [`PrefixImage`] by a rolling
//! FNV-1a hash chained over the method configuration and the prefix token
//! ids ([`prefix_base_hash`] / [`extend_hash`]), mixed with the layer and
//! head indices ([`entry_hash`]). Inserts dedup on the hash; lookups hand
//! out `Arc` clones of the immutable image (copy-on-write: a borrowing
//! sequence appends only to its own private segments, never to the image).
//!
//! Byte budgeting and eviction reuse the segcache machinery: every image is
//! also serialized into an internal [`WarmTier`] resident (one required
//! frame, entry hash as resident id), and the tier's pooled-segment budget
//! is the store's budget. While a sequence borrows an image the resident is
//! pinned ([`WarmTier::retain`]) and exempt from eviction; once every
//! borrower releases, the resident rejoins LRU order — shared prefixes are
//! evict-last, destroyed only when unreferenced and the budget needs the
//! room. The live `Arc` map is swept against the tier after every insert so
//! both views always agree on what is resident.

use crate::cache::manager::{KeySegment, ValSegment};
use crate::cache::store::snapshot::{cfg_bytes, snapshot_prefix_image};
use crate::cache::store::tier::WarmTier;
use crate::quant::norm::ChannelNorm;
use crate::quant::MethodConfig;
use std::collections::BTreeMap;
use std::sync::Arc;

/// Pooled segment size of the store's internal tier. Prefix images are a
/// few KiB per (layer, head) at 2–4-bit codes, so 1 KiB segments keep the
/// final-segment slack per image small.
pub const PREFIX_SEG_BYTES: usize = 1024;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Content address of a prefix: FNV-1a over the serialized method
/// configuration, then chained over the prefix token ids. The same tokens
/// under a different configuration hash differently — a different method,
/// bit width, or window size produces different bytes, so they must never
/// alias.
pub fn prefix_base_hash(cfg: &MethodConfig, tokens: &[i32]) -> u64 {
    let mut h = fnv(FNV_OFFSET, &cfg_bytes(cfg));
    for &t in tokens {
        h = extend_hash(h, t);
    }
    h
}

/// Extend a rolling prefix hash by one token — `prefix_base_hash` of
/// `tokens + [t]` equals `extend_hash(prefix_base_hash(tokens), t)`, so a
/// multi-turn conversation can grow its address incrementally.
pub fn extend_hash(h: u64, token: i32) -> u64 {
    fnv(h, &(token as u32).to_le_bytes())
}

/// Per-(layer, head) store key derived from a prefix base hash. Every
/// entry of one prefix shares the base; the layer/head mix keeps the
/// per-head images individually addressable in the tier.
pub fn entry_hash(base: u64, layer: usize, head: usize) -> u64 {
    let h = fnv(base, &(layer as u32).to_le_bytes());
    fnv(h, &(head as u32).to_le_bytes())
}

/// One immutable quantized prefix image for one (layer, head): the
/// middle-segment bytes produced by quantizing the prefix rows, plus the
/// prefix-derived per-channel key norm. Sequences borrow it via `Arc`
/// (`HeadCache::shared_k` / `shared_v`) and never mutate it.
#[derive(Debug, Clone, PartialEq)]
pub struct PrefixImage {
    /// Head dimension.
    pub d_h: usize,
    /// Prefix length in tokens (the fork boundary; more than the segment
    /// lengths, which exclude the sink/recent windows).
    pub prefix_len: usize,
    /// Quantized key run of the prefix middle.
    pub qk: Arc<KeySegment>,
    /// Quantized value run of the prefix middle.
    pub qv: Arc<ValSegment>,
    /// Per-channel key norm computed over the prefix rows.
    pub norm: ChannelNorm,
}

impl PrefixImage {
    /// Heap bytes of the quantized runs — what one borrowing sequence
    /// *avoids* owning (matches `HeadCache::shared_bytes`).
    pub fn bytes(&self) -> usize {
        self.qk.bytes() + self.qv.bytes()
    }
}

/// Monotonic prefix-store counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PrefixStoreStats {
    /// Entry lookups that found a resident image and pinned it.
    pub hits: u64,
    /// Entry lookups that found nothing.
    pub misses: u64,
    /// New images stored (dedup hits excluded).
    pub inserts: u64,
    /// Inserts that hit an already-resident image (content dedup).
    pub dedup_hits: u64,
    /// Inserts refused by the budget (only pinned residents in the way, or
    /// the image exceeds the whole pool).
    pub insert_rejected: u64,
    /// Unreferenced residents evicted to make room.
    pub evictions: u64,
    /// Pins released by retiring sequences.
    pub released: u64,
}

/// Content-addressed store of [`PrefixImage`]s with refcount-aware LRU
/// eviction (see the module docs for the design).
#[derive(Debug)]
pub struct PrefixStore {
    /// Resident images by entry hash, kept in lockstep with `tier`.
    live: BTreeMap<u64, Arc<PrefixImage>>,
    /// Serialized twins of `live`: budget accounting, LRU order, pins.
    tier: WarmTier,
    /// Hit/miss/eviction counters.
    pub stats: PrefixStoreStats,
}

impl PrefixStore {
    /// A store holding at most `budget_bytes` of pooled image bytes. A zero
    /// budget yields a store that refuses every insert — sharing degrades
    /// to the private-copy path with no numerics change.
    pub fn new(budget_bytes: usize) -> PrefixStore {
        PrefixStore {
            live: BTreeMap::new(),
            tier: WarmTier::new(budget_bytes, PREFIX_SEG_BYTES),
            stats: PrefixStoreStats::default(),
        }
    }

    /// Total pooled budget in bytes.
    pub fn budget_bytes(&self) -> usize {
        self.tier.budget_bytes()
    }

    /// Exact serialized bytes of every resident image.
    pub fn resident_bytes(&self) -> usize {
        self.tier.resident_bytes()
    }

    /// Number of resident images (entries, not prefixes).
    pub fn n_images(&self) -> usize {
        self.live.len()
    }

    /// Number of resident images currently pinned by at least one borrower.
    /// Exposed for the admin stats plane and the cancellation tests: when no
    /// sequence is live or offloaded this must be 0 (residency may persist
    /// for future hits, pins must not).
    pub fn pinned_images(&self) -> usize {
        let tier = &self.tier;
        tier.resident_ids().filter(|&id| tier.refs(id) > 0).count()
    }

    /// True if an image is resident under `entry` (pinned or not).
    pub fn contains(&self, entry: u64) -> bool {
        self.live.contains_key(&entry)
    }

    /// Heap bytes a borrower of `entry` would avoid owning, without
    /// touching refcounts or recency — the admission-estimate probe.
    pub fn probe(&self, entry: u64) -> Option<usize> {
        self.live.get(&entry).map(|img| img.bytes())
    }

    /// Borrow the image under `entry`, pinning its resident against
    /// eviction. Every `acquire` must be paired with a [`PrefixStore::release`].
    pub fn acquire(&mut self, entry: u64) -> Option<Arc<PrefixImage>> {
        match self.live.get(&entry) {
            Some(img) => {
                let pinned = self.tier.retain(entry);
                debug_assert!(pinned, "live map and tier out of sync on {entry:#x}");
                self.stats.hits += 1;
                Some(img.clone())
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Store `img` under `entry` and borrow it (pinned, like
    /// [`PrefixStore::acquire`]). Content-addressed dedup: when `entry` is
    /// already resident the existing image is borrowed instead and `img` is
    /// dropped. Returns `None` when the budget refuses the insert — only
    /// pinned residents stood in the way, or the image exceeds the pool —
    /// in which case the caller keeps a private copy.
    pub fn insert(&mut self, entry: u64, img: PrefixImage) -> Option<Arc<PrefixImage>> {
        if self.live.contains_key(&entry) {
            self.stats.dedup_hits += 1;
            return self.acquire(entry);
        }
        let bytes = snapshot_prefix_image(&img);
        if self.tier.insert(entry, 0, &bytes).is_none() {
            self.stats.insert_rejected += 1;
            return None;
        }
        // The insert may have evicted unpinned residents; drop their Arcs
        // so the live map never outlives the budget accounting.
        let before = self.live.len();
        let tier = &self.tier;
        self.live.retain(|h, _| tier.contains(*h));
        self.stats.evictions += (before - self.live.len()) as u64;
        let pinned = self.tier.retain(entry);
        debug_assert!(pinned);
        let arc = Arc::new(img);
        self.live.insert(entry, arc.clone());
        self.stats.inserts += 1;
        Some(arc)
    }

    /// Drop one pin on `entry` (a borrowing sequence retired). The image
    /// stays resident for future hits until LRU pressure evicts it.
    pub fn release(&mut self, entry: u64) {
        if self.tier.release(entry) {
            self.stats.released += 1;
        }
    }

    /// Resolve `entry` to its image without pinning — the snapshot-restore
    /// resolver for by-reference frames, whose borrower already holds a pin
    /// from before it was offloaded.
    pub fn image(&self, entry: u64) -> Option<Arc<PrefixImage>> {
        self.live.get(&entry).cloned()
    }

    /// Round-trip check used by tests: deserialize the tier's serialized
    /// twin of `entry` (`None` when not resident).
    #[cfg(test)]
    fn image_from_bytes(&self, entry: u64) -> Option<PrefixImage> {
        let bytes = self.tier.peek(entry)?;
        crate::cache::store::snapshot::restore_prefix_image(&bytes).ok()
    }

    // -- grouped operations over one prefix's (layer, head) grid ----------

    /// True when every entry of `base`'s `n_layers x n_heads` grid is
    /// resident; the per-sequence shared byte total in that case.
    pub fn probe_set(&self, base: u64, n_layers: usize, n_heads: usize) -> Option<usize> {
        let mut total = 0usize;
        for l in 0..n_layers {
            for h in 0..n_heads {
                total += self.probe(entry_hash(base, l, h))?;
            }
        }
        Some(total)
    }

    /// Borrow the full grid of `base`, pinning every entry — the prefill
    /// hit path. All-or-nothing: `None` (and no pins taken) unless every
    /// entry is resident. Outer Vec is layers, inner is heads.
    pub fn acquire_set(
        &mut self,
        base: u64,
        n_layers: usize,
        n_heads: usize,
    ) -> Option<Vec<Vec<Arc<PrefixImage>>>> {
        if self.probe_set(base, n_layers, n_heads).is_none() {
            self.stats.misses += 1;
            return None;
        }
        let grid = (0..n_layers)
            .map(|l| {
                (0..n_heads)
                    .map(|h| self.acquire(entry_hash(base, l, h)).expect("probed resident"))
                    .collect()
            })
            .collect();
        Some(grid)
    }

    /// Store the full grid of `base` and borrow it — the prefill miss path.
    /// All-or-nothing: when any insert is refused, every pin this call took
    /// is released again and `None` is returned (already-stored images stay
    /// resident for future attempts); the caller falls back to a private
    /// copy. Outer Vec is layers, inner is heads.
    pub fn insert_set(
        &mut self,
        base: u64,
        images: Vec<Vec<PrefixImage>>,
    ) -> Option<Vec<Vec<Arc<PrefixImage>>>> {
        let mut grid: Vec<Vec<Arc<PrefixImage>>> = Vec::with_capacity(images.len());
        for (l, layer) in images.into_iter().enumerate() {
            let mut row = Vec::with_capacity(layer.len());
            for (h, img) in layer.into_iter().enumerate() {
                match self.insert(entry_hash(base, l, h), img) {
                    Some(arc) => row.push(arc),
                    None => {
                        for (rl, done) in grid.iter().enumerate() {
                            for rh in 0..done.len() {
                                self.release(entry_hash(base, rl, rh));
                            }
                        }
                        for rh in 0..row.len() {
                            self.release(entry_hash(base, l, rh));
                        }
                        return None;
                    }
                }
            }
            grid.push(row);
        }
        Some(grid)
    }

    /// Release every pin of `base`'s grid — sequence retirement.
    pub fn release_set(&mut self, base: u64, n_layers: usize, n_heads: usize) {
        for l in 0..n_layers {
            for h in 0..n_heads {
                self.release(entry_hash(base, l, h));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::manager::HeadCache;
    use crate::util::ptest::normal_vec;
    use crate::util::rng::Rng;
    use crate::QuantMethod;

    fn image(m: QuantMethod, n: usize, seed: u64) -> PrefixImage {
        let d_h = 32;
        let mut rng = Rng::new(seed);
        let keys = normal_vec(&mut rng, n * d_h, 1.0, 0.02);
        let vals = normal_vec(&mut rng, n * d_h, 1.0, 0.02);
        let mut hc = HeadCache::from_prefill_split_norm(m.config(), d_h, &keys, &vals, n);
        let (qk, qv) = hc.split_off_prefix();
        PrefixImage { d_h, prefix_len: n, qk, qv, norm: hc.norm.clone() }
    }

    #[test]
    fn hashes_are_deterministic_and_sensitive() {
        let cfg = QuantMethod::InnerQBase.config();
        let toks: Vec<i32> = (1..40).collect();
        let a = prefix_base_hash(&cfg, &toks);
        assert_eq!(a, prefix_base_hash(&cfg, &toks), "same inputs, same hash");
        let mut other = toks.clone();
        other[7] += 1;
        assert_ne!(a, prefix_base_hash(&cfg, &other), "token change must rekey");
        assert_ne!(
            a,
            prefix_base_hash(&QuantMethod::InnerQTurbo.config(), &toks),
            "config change must rekey"
        );
        // Rolling extension matches the from-scratch hash.
        let grown = extend_hash(a, 99);
        let mut full = toks.clone();
        full.push(99);
        assert_eq!(grown, prefix_base_hash(&cfg, &full));
        // Layer/head mixing separates entries of one prefix.
        assert_ne!(entry_hash(a, 0, 0), entry_hash(a, 0, 1));
        assert_ne!(entry_hash(a, 0, 0), entry_hash(a, 1, 0));
    }

    #[test]
    fn insert_dedup_acquire_release_lifecycle() {
        let mut s = PrefixStore::new(64 * 1024);
        let img = image(QuantMethod::InnerQBase, 160, 3);
        let e = entry_hash(0xAB, 0, 0);
        let a1 = s.insert(e, img.clone()).expect("insert");
        assert_eq!(s.stats.inserts, 1);
        assert_eq!(s.probe(e), Some(img.bytes()));
        // Re-inserting the same content dedups onto the same allocation.
        let a2 = s.insert(e, img.clone()).expect("dedup insert");
        assert!(Arc::ptr_eq(&a1, &a2));
        assert_eq!(s.stats.dedup_hits, 1);
        assert_eq!(s.n_images(), 1);
        // A third borrower via acquire.
        let a3 = s.acquire(e).expect("acquire");
        assert!(Arc::ptr_eq(&a1, &a3));
        s.release(e);
        s.release(e);
        s.release(e);
        assert_eq!(s.stats.released, 3);
        assert!(s.contains(e), "released images stay warm for future hits");
        assert!(s.acquire(entry_hash(0xCD, 0, 0)).is_none());
        assert_eq!(s.stats.misses, 1);
    }

    #[test]
    fn serialized_twin_round_trips_bit_exact() {
        let mut s = PrefixStore::new(64 * 1024);
        for (i, m) in [QuantMethod::InnerQBase, QuantMethod::InnerQTurbo].iter().enumerate() {
            let img = image(*m, 200, 7 + i as u64);
            let e = entry_hash(0x11, i, 0);
            s.insert(e, img.clone()).expect("insert");
            let back = s.image_from_bytes(e).expect("tier twin");
            assert_eq!(back, img, "{m:?} image must round-trip bit-exact");
            s.release(e);
        }
    }

    #[test]
    fn unpinned_lru_residents_evict_under_budget_pressure() {
        // Budget fits roughly one image at a time.
        let a = image(QuantMethod::InnerQBase, 160, 1);
        let mut s = PrefixStore::new(2 * a.bytes());
        let ea = entry_hash(1, 0, 0);
        let eb = entry_hash(2, 0, 0);
        s.insert(ea, a).expect("insert a");
        s.release(ea); // refs -> 0: evictable
        s.insert(eb, image(QuantMethod::InnerQBase, 160, 2)).expect("insert b");
        assert!(!s.contains(ea), "LRU unpinned image must give way");
        assert!(s.contains(eb));
        assert_eq!(s.stats.evictions, 1);
        assert!(s.probe(ea).is_none());
    }

    #[test]
    fn pinned_residents_refuse_inserts_instead_of_evicting() {
        let a = image(QuantMethod::InnerQBase, 160, 1);
        let mut s = PrefixStore::new(2 * a.bytes());
        let ea = entry_hash(1, 0, 0);
        let eb = entry_hash(2, 0, 0);
        s.insert(ea, a).expect("insert a"); // pinned by the insert
        assert!(s.insert(eb, image(QuantMethod::InnerQBase, 160, 2)).is_none());
        assert_eq!(s.stats.insert_rejected, 1);
        assert!(s.contains(ea), "pinned image must survive");
        assert!(!s.contains(eb));
        // Releasing the pin makes the next attempt succeed.
        s.release(ea);
        assert!(s.insert(eb, image(QuantMethod::InnerQBase, 160, 2)).is_some());
    }

    #[test]
    fn grouped_set_operations_cover_the_grid() {
        let mut s = PrefixStore::new(256 * 1024);
        let (n_layers, n_heads) = (2usize, 2usize);
        let base = 0xBEEF;
        let images: Vec<Vec<PrefixImage>> = (0..n_layers)
            .map(|l| {
                (0..n_heads)
                    .map(|h| image(QuantMethod::InnerQBase, 160, (l * n_heads + h) as u64))
                    .collect()
            })
            .collect();
        let per_seq: usize =
            images.iter().flatten().map(|i| i.bytes()).sum();
        assert!(s.acquire_set(base, n_layers, n_heads).is_none(), "miss before insert");
        let grid = s.insert_set(base, images).expect("insert grid");
        assert_eq!(grid.len(), n_layers);
        assert_eq!(s.n_images(), n_layers * n_heads);
        assert_eq!(s.probe_set(base, n_layers, n_heads), Some(per_seq));
        // A second request borrows the same grid.
        let again = s.acquire_set(base, n_layers, n_heads).expect("hit");
        assert!(Arc::ptr_eq(&grid[1][1], &again[1][1]));
        s.release_set(base, n_layers, n_heads);
        s.release_set(base, n_layers, n_heads);
        assert!(s.probe_set(base, n_layers, n_heads).is_some(), "stay warm after release");
        // A partial grid is not a hit.
        assert!(s.probe_set(0xDEAD, n_layers, n_heads).is_none());
    }

    #[test]
    fn failed_grid_insert_rolls_back_its_pins() {
        let one = image(QuantMethod::InnerQBase, 160, 9);
        let bytes = one.bytes();
        // Room for about two entries; a 2x2 grid cannot fit.
        let mut s = PrefixStore::new(2 * bytes + bytes / 2);
        let images: Vec<Vec<PrefixImage>> = (0..2)
            .map(|l| (0..2).map(|h| image(QuantMethod::InnerQBase, 160, (l * 2 + h) as u64)).collect())
            .collect();
        assert!(s.insert_set(0x77, images).is_none());
        // Whatever was stored before the failure is unpinned again, so a
        // small follow-up insert can evict it rather than being refused.
        let e = entry_hash(0x88, 0, 0);
        assert!(s.insert(e, one).is_some(), "rolled-back pins must not wedge the store");
    }
}
