//! Tiered cache store: snapshot serialization plus the segcache-style warm
//! tier behind offload preemption.
//!
//! InnerQ's compressed segments are the cheapest bytes in the system to
//! move: a preempted sequence's quantized middle is already 4–8× smaller
//! than fp16, so recompute-style preemption (drop the cache, re-prefill
//! later) throws away exactly the work quantization paid for. This module
//! gives the scheduler the alternative:
//!
//! * [`snapshot`] — bit-exact serialize/restore of a [`crate::cache::HeadCache`],
//!   a whole live [`crate::coordinator::Sequence`], or a sequence split into
//!   per-layer frames (meta + per-layer core/windows pairs) so the tier can
//!   hold layers individually;
//! * [`tier`] — a pooled fixed-segment warm store ([`WarmTier`]) with a
//!   free list, its own byte budget, LRU-with-priority eviction — refined to
//!   frame granularity: droppable (fp-window) frames of a victim go first,
//!   whole residents only after — and hit/miss/eviction counters, shaped
//!   after pelikan's segcache.
//!
//! The scheduler's `Preemption::Offload` mode parks victims here and
//! restores them (cheap memcpy + deserialize) instead of re-prefilling them
//! (expensive recompute); a partially-evicted resident restores its
//! quantized middle from the tier and recomputes only the fp windows.
//! `workload::replay`'s cost model prices both so the overload harness can
//! answer offload-vs-recompute per quant method.
//!
//! * [`prefix`] — the content-addressed, refcounted, copy-on-write store of
//!   shared quantized prefix images ([`PrefixStore`]): the same
//!   `(prefix tokens, MethodConfig)` quantizes to the same bytes, so many
//!   sequences borrow one immutable image per (layer, head) and own only
//!   their private suffix. Its byte budget and refcount-aware (evict-last)
//!   LRU ride on the same [`WarmTier`] machinery.

pub mod prefix;
pub mod snapshot;
pub mod tier;

pub use prefix::{
    entry_hash, extend_hash, prefix_base_hash, PrefixImage, PrefixStore, PrefixStoreStats,
};
pub use snapshot::{
    restore_head, restore_prefix_image, restore_sequence, restore_sequence_frames,
    restore_sequence_frames_with, snapshot_head, snapshot_prefix_image, snapshot_sequence,
    snapshot_sequence_frames, snapshot_sequence_frames_by_ref, snapshot_sequence_frames_on,
    LayerFrames, SequenceFrames,
};
pub use tier::{FrameKind, InsertReceipt, TakenFrames, TierStats, WarmTier, DEFAULT_SEG_BYTES};
