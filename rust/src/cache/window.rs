//! High-precision token windows (§4.2).
//!
//! `SinkWindow` pins the first `w_sink` tokens (attention sinks) in full
//! precision for the lifetime of the sequence. `RecentWindow` is a FIFO of
//! the most recent tokens; evictions from its front are what the quantizers
//! consume. Both store f32 rows (the FP16-storage stand-in).

/// Fixed window over the first tokens of the sequence.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SinkWindow {
    /// Head dimension.
    pub d_h: usize,
    /// Token-major f32 rows (oldest first).
    pub rows: Vec<f32>,
    // Crate-visible so `cache::store::snapshot` can round-trip the window
    // field-for-field (derived `PartialEq` compares capacity too).
    pub(crate) capacity: usize,
}

impl SinkWindow {
    /// An empty window holding at most `capacity` tokens.
    pub fn new(d_h: usize, capacity: usize) -> SinkWindow {
        SinkWindow { d_h, rows: Vec::with_capacity(capacity * d_h), capacity }
    }
    /// Tokens currently held.
    pub fn len(&self) -> usize {
        self.rows.len() / self.d_h.max(1)
    }
    /// True when the window has reached capacity.
    pub fn is_full(&self) -> bool {
        self.len() >= self.capacity
    }
    /// Push a token if the window still has room; returns false when full.
    pub fn try_push(&mut self, row: &[f32]) -> bool {
        if self.is_full() || self.capacity == 0 {
            return false;
        }
        debug_assert_eq!(row.len(), self.d_h);
        self.rows.extend_from_slice(row);
        true
    }
    /// FP16-storage-equivalent bytes held (2 bytes per number).
    pub fn bytes(&self) -> usize {
        self.rows.len() * 2
    }
}

/// FIFO window over the most recent tokens, with amortized O(1) front pops.
#[derive(Debug, Clone, PartialEq)]
pub struct RecentWindow {
    /// Head dimension.
    pub d_h: usize,
    // Crate-visible (not pub) so `cache::store::snapshot` can serialize the
    // buffer verbatim — including the dead prefix before `start`, which the
    // derived `PartialEq` compares — without exposing the ring internals.
    pub(crate) data: Vec<f32>,
    /// Index (in rows) of the logical front.
    pub(crate) start: usize,
}

impl RecentWindow {
    /// An empty window.
    pub fn new(d_h: usize) -> RecentWindow {
        RecentWindow { d_h, data: Vec::new(), start: 0 }
    }
    /// Tokens currently held.
    pub fn len(&self) -> usize {
        self.data.len() / self.d_h - self.start
    }
    /// True when no live tokens remain.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
    /// Append one token row at the back.
    pub fn push(&mut self, row: &[f32]) {
        debug_assert_eq!(row.len(), self.d_h);
        self.data.extend_from_slice(row);
    }
    /// Contiguous view of the live rows (oldest first).
    pub fn rows(&self) -> &[f32] {
        &self.data[self.start * self.d_h..]
    }
    /// Pop `n` rows from the front, passing them to `consume` as one
    /// contiguous token-major slice (oldest first).
    pub fn pop_front<F: FnOnce(&[f32])>(&mut self, n: usize, consume: F) {
        assert!(n <= self.len(), "pop {n} > len {}", self.len());
        let lo = self.start * self.d_h;
        consume(&self.data[lo..lo + n * self.d_h]);
        self.start += n;
        // Compact when more than half the buffer is dead.
        if self.start * self.d_h * 2 > self.data.len() {
            self.data.drain(..self.start * self.d_h);
            self.start = 0;
        }
    }
    /// FP16-storage-equivalent bytes held (2 bytes per number).
    pub fn bytes(&self) -> usize {
        self.len() * self.d_h * 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(d_h: usize, v: f32) -> Vec<f32> {
        vec![v; d_h]
    }

    #[test]
    fn sink_fills_then_rejects() {
        let mut s = SinkWindow::new(4, 2);
        assert!(s.try_push(&row(4, 1.0)));
        assert!(s.try_push(&row(4, 2.0)));
        assert!(!s.try_push(&row(4, 3.0)));
        assert_eq!(s.len(), 2);
        assert_eq!(s.rows[4], 2.0);
    }

    #[test]
    fn zero_capacity_sink_rejects_all() {
        let mut s = SinkWindow::new(4, 0);
        assert!(!s.try_push(&row(4, 1.0)));
    }

    #[test]
    fn recent_fifo_order() {
        let mut r = RecentWindow::new(2);
        for i in 0..5 {
            r.push(&row(2, i as f32));
        }
        assert_eq!(r.len(), 5);
        r.pop_front(2, |rows| {
            assert_eq!(rows, &[0.0, 0.0, 1.0, 1.0]);
        });
        assert_eq!(r.len(), 3);
        assert_eq!(r.rows()[0], 2.0);
        // push after pop keeps order
        r.push(&row(2, 9.0));
        r.pop_front(3, |rows| {
            assert_eq!(rows[0], 2.0);
            assert_eq!(rows[4], 4.0);
        });
        assert_eq!(r.len(), 1);
        assert_eq!(r.rows(), &[9.0, 9.0]);
    }

    #[test]
    fn compaction_preserves_contents() {
        let mut r = RecentWindow::new(1);
        for i in 0..100 {
            r.push(&[i as f32]);
        }
        for i in 0..90 {
            r.pop_front(1, |rows| assert_eq!(rows[0], i as f32));
        }
        assert_eq!(r.len(), 10);
        assert_eq!(r.rows()[0], 90.0);
    }

    #[test]
    #[should_panic]
    fn over_pop_panics() {
        let mut r = RecentWindow::new(1);
        r.push(&[1.0]);
        r.pop_front(2, |_| {});
    }
}
