//! Cache memory pool: byte accounting and admission control across
//! sequences. The scheduler consults the pool before admitting a prefill;
//! under pressure it picks a preemption victim itself (policy-dependent —
//! see `coordinator::scheduler::Policy`) and releases the victim's
//! reservation here (vLLM-style recompute preemption, simplified to fit
//! the paper's single-node setting).

use std::collections::BTreeMap;

/// Outcome of an admission request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// Reserved: the estimate fits the free budget.
    Admitted,
    /// Not enough budget even if everything else were evicted.
    TooLarge,
    /// Over budget: the scheduler must evict live work (or park) first.
    Pressure,
}

/// Byte-accounting admission controller over all live sequences' caches.
#[derive(Debug)]
pub struct CachePool {
    /// Total cache budget shared by every live sequence.
    pub budget_bytes: usize,
    used: BTreeMap<u64, usize>, // seq id -> bytes
}

impl CachePool {
    /// An empty pool with the given byte budget.
    pub fn new(budget_bytes: usize) -> CachePool {
        CachePool { budget_bytes, used: BTreeMap::new() }
    }

    /// Bytes currently reserved across all sequences.
    pub fn used_bytes(&self) -> usize {
        self.used.values().sum()
    }

    /// Remaining admissible bytes (0 when over budget).
    pub fn free_bytes(&self) -> usize {
        self.budget_bytes.saturating_sub(self.used_bytes())
    }

    /// Ids holding a reservation, oldest (lowest) first.
    pub fn ids(&self) -> impl Iterator<Item = u64> + '_ {
        self.used.keys().copied()
    }

    /// Number of sequences holding a reservation.
    pub fn n_reserved(&self) -> usize {
        self.used.len()
    }

    /// Try to admit a sequence expected to need `est_bytes`.
    pub fn admit(&mut self, seq: u64, est_bytes: usize) -> Admission {
        if est_bytes > self.budget_bytes {
            return Admission::TooLarge;
        }
        if est_bytes <= self.free_bytes() {
            self.used.insert(seq, est_bytes);
            Admission::Admitted
        } else {
            Admission::Pressure
        }
    }

    /// Youngest (highest-id) reservation — the FIFO policy's preferred
    /// preemption victim (the scheduler makes the actual choice from its
    /// live list; see `coordinator::scheduler::Policy`).
    pub fn youngest(&self) -> Option<u64> {
        self.used.keys().next_back().copied()
    }

    /// Update a sequence's live byte count (caches grow during decode).
    pub fn update(&mut self, seq: u64, bytes: usize) {
        if let Some(b) = self.used.get_mut(&seq) {
            *b = bytes;
        }
    }

    /// Drop a sequence's reservation (no-op if absent).
    pub fn release(&mut self, seq: u64) {
        self.used.remove(&seq);
    }

    /// True when live growth has pushed usage past the budget.
    pub fn over_budget(&self) -> bool {
        self.used_bytes() > self.budget_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admit_until_full_then_pressure() {
        let mut p = CachePool::new(1000);
        assert_eq!(p.admit(1, 400), Admission::Admitted);
        assert_eq!(p.admit(2, 400), Admission::Admitted);
        assert_eq!(p.admit(3, 400), Admission::Pressure);
        assert_eq!(p.admit(4, 2000), Admission::TooLarge);
        p.release(1);
        assert_eq!(p.admit(3, 400), Admission::Admitted);
    }

    #[test]
    fn growth_tracking_and_preemption_order() {
        let mut p = CachePool::new(1000);
        p.admit(1, 100);
        p.admit(2, 100);
        p.update(1, 600);
        p.update(2, 500);
        assert!(p.over_budget());
        assert_eq!(p.youngest(), Some(2), "youngest sequence is the victim");
        p.release(2);
        assert!(!p.over_budget());
    }

    #[test]
    fn free_bytes_never_underflows() {
        let mut p = CachePool::new(100);
        p.admit(1, 100);
        p.update(1, 150);
        assert_eq!(p.free_bytes(), 0);
    }
}
