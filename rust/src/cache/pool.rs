//! Cache memory pool: byte accounting and admission control across
//! sequences. The scheduler consults the pool before admitting a prefill;
//! under pressure it picks a preemption victim itself (policy-dependent —
//! see `coordinator::scheduler::Policy`) and releases the victim's
//! reservation here (vLLM-style recompute preemption, simplified to fit
//! the paper's single-node setting).

use std::collections::BTreeMap;

/// Outcome of an admission request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// Reserved: the estimate fits the free budget.
    Admitted,
    /// The sequence already holds a reservation. Re-admitting used to
    /// silently overwrite the old byte count (leaking accounting when the
    /// stale figure was larger); now the pool refuses and the caller must
    /// either [`CachePool::release`] first or reconcile via
    /// [`CachePool::resize`].
    AlreadyReserved,
    /// Not enough budget even if everything else were evicted.
    TooLarge,
    /// Over budget: the scheduler must evict live work (or park) first.
    Pressure,
}

/// Byte-accounting admission controller over all live sequences' caches.
#[derive(Debug)]
pub struct CachePool {
    /// Total cache budget shared by every live sequence.
    pub budget_bytes: usize,
    used: BTreeMap<u64, usize>, // seq id -> bytes
}

impl CachePool {
    /// An empty pool with the given byte budget.
    pub fn new(budget_bytes: usize) -> CachePool {
        CachePool { budget_bytes, used: BTreeMap::new() }
    }

    /// Bytes currently reserved across all sequences.
    pub fn used_bytes(&self) -> usize {
        self.used.values().sum()
    }

    /// Remaining admissible bytes (0 when over budget).
    pub fn free_bytes(&self) -> usize {
        self.budget_bytes.saturating_sub(self.used_bytes())
    }

    /// Ids holding a reservation, oldest (lowest) first.
    pub fn ids(&self) -> impl Iterator<Item = u64> + '_ {
        self.used.keys().copied()
    }

    /// Number of sequences holding a reservation.
    pub fn n_reserved(&self) -> usize {
        self.used.len()
    }

    /// Bytes currently reserved by one sequence, if it holds a reservation.
    pub fn reserved(&self, seq: u64) -> Option<usize> {
        self.used.get(&seq).copied()
    }

    /// Try to admit a sequence expected to need `est_bytes`. A sequence
    /// already holding a reservation is refused ([`Admission::AlreadyReserved`])
    /// instead of silently replacing its byte count.
    pub fn admit(&mut self, seq: u64, est_bytes: usize) -> Admission {
        if self.used.contains_key(&seq) {
            return Admission::AlreadyReserved;
        }
        if est_bytes > self.budget_bytes {
            return Admission::TooLarge;
        }
        if est_bytes <= self.free_bytes() {
            self.used.insert(seq, est_bytes);
            Admission::Admitted
        } else {
            Admission::Pressure
        }
    }

    /// Youngest (highest-id) reservation — the FIFO policy's preferred
    /// preemption victim (the scheduler makes the actual choice from its
    /// live list; see `coordinator::scheduler::Policy`).
    pub fn youngest(&self) -> Option<u64> {
        self.used.keys().next_back().copied()
    }

    /// Reconcile a sequence's reservation with its *measured* byte count
    /// (the scheduler calls this every decode step so estimates converge to
    /// actual cache growth). Returns false — with the pool unchanged — when
    /// the sequence holds no reservation; the caller should treat that as a
    /// bookkeeping bug, not create one implicitly.
    pub fn resize(&mut self, seq: u64, bytes: usize) -> bool {
        match self.used.get_mut(&seq) {
            Some(b) => {
                *b = bytes;
                true
            }
            None => false,
        }
    }

    /// Drop a sequence's reservation (no-op if absent).
    pub fn release(&mut self, seq: u64) {
        self.used.remove(&seq);
    }

    /// True when live growth has pushed usage past the budget.
    pub fn over_budget(&self) -> bool {
        self.used_bytes() > self.budget_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admit_until_full_then_pressure() {
        let mut p = CachePool::new(1000);
        assert_eq!(p.admit(1, 400), Admission::Admitted);
        assert_eq!(p.admit(2, 400), Admission::Admitted);
        assert_eq!(p.admit(3, 400), Admission::Pressure);
        assert_eq!(p.admit(4, 2000), Admission::TooLarge);
        p.release(1);
        assert_eq!(p.admit(3, 400), Admission::Admitted);
    }

    #[test]
    fn growth_tracking_and_preemption_order() {
        let mut p = CachePool::new(1000);
        p.admit(1, 100);
        p.admit(2, 100);
        assert!(p.resize(1, 600));
        assert!(p.resize(2, 500));
        assert!(p.over_budget());
        assert_eq!(p.youngest(), Some(2), "youngest sequence is the victim");
        p.release(2);
        assert!(!p.over_budget());
    }

    #[test]
    fn free_bytes_never_underflows() {
        let mut p = CachePool::new(100);
        p.admit(1, 100);
        assert!(p.resize(1, 150));
        assert_eq!(p.free_bytes(), 0);
    }

    #[test]
    fn re_admission_is_refused_not_overwritten() {
        // Regression: a second admit for a held id used to replace the byte
        // count, silently leaking whatever the first reservation tracked.
        let mut p = CachePool::new(1000);
        assert_eq!(p.admit(1, 400), Admission::Admitted);
        assert_eq!(p.admit(1, 10), Admission::AlreadyReserved);
        assert_eq!(p.used_bytes(), 400, "refused re-admission must not touch accounting");
        // Even an over-budget re-admission reports AlreadyReserved, not
        // TooLarge — the caller must release or resize explicitly.
        assert_eq!(p.admit(1, 5000), Admission::AlreadyReserved);
        p.release(1);
        assert_eq!(p.admit(1, 10), Admission::Admitted);
    }

    #[test]
    fn resize_requires_an_existing_reservation() {
        let mut p = CachePool::new(1000);
        assert!(!p.resize(9, 100), "resize must not create reservations");
        assert_eq!(p.used_bytes(), 0);
        p.admit(9, 50);
        assert!(p.resize(9, 100));
        assert_eq!(p.used_bytes(), 100);
    }
}
