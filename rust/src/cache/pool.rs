//! Cache memory pool: byte accounting and admission control across
//! sequences. The scheduler consults the pool before admitting a prefill and
//! preempts the youngest sequence under pressure (vLLM-style recompute
//! preemption, simplified to fit the paper's single-node setting).

use std::collections::BTreeMap;

/// Outcome of an admission request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    Admitted,
    /// Not enough budget even if everything else were evicted.
    TooLarge,
    /// Needs `preempt` sequences evicted first (by id, youngest first).
    Pressure,
}

#[derive(Debug)]
pub struct CachePool {
    pub budget_bytes: usize,
    used: BTreeMap<u64, usize>, // seq id -> bytes
}

impl CachePool {
    pub fn new(budget_bytes: usize) -> CachePool {
        CachePool { budget_bytes, used: BTreeMap::new() }
    }

    pub fn used_bytes(&self) -> usize {
        self.used.values().sum()
    }

    pub fn free_bytes(&self) -> usize {
        self.budget_bytes.saturating_sub(self.used_bytes())
    }

    /// Try to admit a sequence expected to need `est_bytes`.
    pub fn admit(&mut self, seq: u64, est_bytes: usize) -> Admission {
        if est_bytes > self.budget_bytes {
            return Admission::TooLarge;
        }
        if est_bytes <= self.free_bytes() {
            self.used.insert(seq, est_bytes);
            Admission::Admitted
        } else {
            Admission::Pressure
        }
    }

    /// Youngest (highest-id) sequence, the preemption victim.
    pub fn youngest(&self) -> Option<u64> {
        self.used.keys().next_back().copied()
    }

    /// Update a sequence's live byte count (caches grow during decode).
    pub fn update(&mut self, seq: u64, bytes: usize) {
        if let Some(b) = self.used.get_mut(&seq) {
            *b = bytes;
        }
    }

    pub fn release(&mut self, seq: u64) {
        self.used.remove(&seq);
    }

    pub fn over_budget(&self) -> bool {
        self.used_bytes() > self.budget_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admit_until_full_then_pressure() {
        let mut p = CachePool::new(1000);
        assert_eq!(p.admit(1, 400), Admission::Admitted);
        assert_eq!(p.admit(2, 400), Admission::Admitted);
        assert_eq!(p.admit(3, 400), Admission::Pressure);
        assert_eq!(p.admit(4, 2000), Admission::TooLarge);
        p.release(1);
        assert_eq!(p.admit(3, 400), Admission::Admitted);
    }

    #[test]
    fn growth_tracking_and_preemption_order() {
        let mut p = CachePool::new(1000);
        p.admit(1, 100);
        p.admit(2, 100);
        p.update(1, 600);
        p.update(2, 500);
        assert!(p.over_budget());
        assert_eq!(p.youngest(), Some(2), "youngest sequence is the victim");
        p.release(2);
        assert!(!p.over_budget());
    }

    #[test]
    fn free_bytes_never_underflows() {
        let mut p = CachePool::new(100);
        p.admit(1, 100);
        p.update(1, 150);
        assert_eq!(p.free_bytes(), 0);
    }
}
