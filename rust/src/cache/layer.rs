//! Per-layer cache ownership: one [`LayerCache`] owns one transformer
//! layer's per-KV-head quantized caches.
//!
//! Before this refactor a `Sequence` held a monolithic
//! `Vec<Vec<HeadCache>>`, which forced the decode loop into
//! per-layer phase barriers (all appends on the driver, then all attends
//! behind a pool barrier) and forced whole-sequence snapshot/offload
//! granularity. Making the *layer* the unit of ownership gives:
//!
//! * **Split borrows for pipelined decode.** `Sequence::caches` is
//!   `Vec<LayerCache>`; [`LayerCache::heads_mut`] exposes the layer's heads
//!   as a slice, so the engine can collect disjoint `&mut HeadCache`
//!   handles across every (sequence, layer, head) up front and hand each
//!   one to its own fused append+attend job — layer *l*'s attention jobs
//!   and any other layer's append/quantize jobs can be in flight
//!   simultaneously with no aliasing, checked by the borrow checker rather
//!   than by convention.
//! * **Per-layer snapshot frames.** `cache::store::snapshot` serializes a
//!   sequence as one frame per `LayerCache`, so the warm tier can hold — and
//!   partially evict — individual layers of an offloaded sequence.
//!
//! [`step_fanout`] is the fused decode-step job shape: one job per
//! (sequence, KV head) that appends the step's K/V row *and then* attends,
//! replacing the old split (serial driver appends, then a barriered
//! attention fan-out). Per head the operation order is unchanged, so results
//! are bit-identical to the barriered path at any worker count.

use crate::cache::manager::HeadCache;
use crate::quant::MethodConfig;
use crate::util::threadpool::Job;

/// One layer's per-KV-head quantized caches plus its append/attend state.
/// The owning [`crate::coordinator::engine::Sequence`] holds one per layer.
#[derive(Debug, PartialEq)]
pub struct LayerCache {
    heads: Vec<HeadCache>,
}

impl LayerCache {
    /// An empty layer cache with `n_heads` fresh per-head caches.
    pub fn new(cfg: MethodConfig, d_h: usize, n_heads: usize) -> LayerCache {
        LayerCache { heads: (0..n_heads).map(|_| HeadCache::new(cfg, d_h)).collect() }
    }

    /// Wrap already-built head caches (the prefill fan-out path).
    pub fn from_heads(heads: Vec<HeadCache>) -> LayerCache {
        LayerCache { heads }
    }

    /// Number of KV heads in this layer.
    pub fn n_heads(&self) -> usize {
        self.heads.len()
    }

    /// Shared view of the layer's head caches (attention reads).
    pub fn heads(&self) -> &[HeadCache] {
        &self.heads
    }

    /// Split-borrow accessor: the layer's head caches as one mutable slice,
    /// so callers can carve disjoint `&mut HeadCache` handles (via
    /// `iter_mut` / `split_at_mut`) and keep several heads' append/attend
    /// work in flight concurrently without aliasing.
    pub fn heads_mut(&mut self) -> &mut [HeadCache] {
        &mut self.heads
    }

    /// One head's cache.
    pub fn head(&self, h: usize) -> &HeadCache {
        &self.heads[h]
    }

    /// One head's cache, mutably.
    pub fn head_mut(&mut self, h: usize) -> &mut HeadCache {
        &mut self.heads[h]
    }

    /// Total cache bytes across the layer's heads.
    pub fn bytes(&self) -> usize {
        self.heads.iter().map(|h| h.bytes()).sum()
    }

    /// Tokens stored (all heads of a layer hold the same count).
    pub fn len(&self) -> usize {
        self.heads.first().map_or(0, |h| h.len())
    }

    /// True when the layer holds no tokens.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One head's fused decode-step body: append this step's K/V row into the
/// head's cache (windows absorb it; evictions quantize at the method's
/// cadence), then attend the head's `rep` query vectors into `out`
/// (`rep * d_h` f32). This is the single definition of the per-head step —
/// the engine's pipelined decode, [`step_fanout`], and the pipeline
/// determinism tests all run heads through here so they cannot drift apart.
pub fn head_step(
    head: &mut HeadCache,
    k_row: &[f32],
    v_row: &[f32],
    q_rows: &[f32],
    out: &mut [f32],
    scratch: &mut Vec<f32>,
) {
    let d_h = head.d_h;
    debug_assert_eq!(k_row.len(), d_h);
    debug_assert_eq!(v_row.len(), d_h);
    debug_assert_eq!(out.len() % d_h, 0);
    debug_assert_eq!(q_rows.len(), out.len());
    head.append(k_row, v_row);
    let rep = out.len() / d_h;
    for r in 0..rep {
        head.attend(&q_rows[r * d_h..(r + 1) * d_h], &mut out[r * d_h..(r + 1) * d_h], scratch);
    }
}

/// Build one layer's fused decode-step fan-out: one job per (sequence, KV
/// head), in the same sequence-major order as `attention_fanout`. Job `c`
/// appends K/V row `c` (`k`/`v` are `count * d_h`, row-major) into its own
/// `&mut HeadCache` and then attends query heads `c*rep .. (c+1)*rep` of `q`
/// into its disjoint `rep * d_h` slice of `ctx`.
///
/// Compared to the barriered path (serial appends on the driver, then an
/// attention fan-out), the fused jobs let one head's quantize-on-evict work
/// overlap every other head's attention — the decode-scaling bench and
/// `tests/decode_pipeline.rs` assert the results stay bit-identical.
pub fn step_fanout<'a>(
    heads: Vec<&'a mut HeadCache>,
    k: &'a [f32],
    v: &'a [f32],
    q: &'a [f32],
    ctx: &'a mut [f32],
    rep: usize,
    d_h: usize,
) -> Vec<Job<'a>> {
    let count = heads.len();
    debug_assert!(k.len() >= count * d_h);
    debug_assert!(v.len() >= count * d_h);
    debug_assert!(q.len() >= count * rep * d_h);
    let mut jobs: Vec<Job<'a>> = Vec::with_capacity(count);
    let mut chunks = ctx.chunks_mut(rep * d_h);
    for (c, head) in heads.into_iter().enumerate() {
        let out_chunk = chunks.next().expect("one rep*d_h ctx chunk per head");
        jobs.push(Box::new(move |scratch: &mut Vec<f32>| {
            head_step(
                head,
                &k[c * d_h..(c + 1) * d_h],
                &v[c * d_h..(c + 1) * d_h],
                &q[c * rep * d_h..(c + 1) * rep * d_h],
                out_chunk,
                scratch,
            );
        }));
    }
    jobs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QuantMethod;
    use crate::util::ptest::normal_vec;
    use crate::util::rng::Rng;
    use crate::util::threadpool::ThreadPool;

    fn build_layer(cfg: MethodConfig, d_h: usize, n_heads: usize, n: usize, rng: &mut Rng) -> LayerCache {
        LayerCache::from_heads(
            (0..n_heads)
                .map(|_| {
                    let keys = normal_vec(rng, n * d_h, 1.0, 0.02);
                    let vals = normal_vec(rng, n * d_h, 1.0, 0.02);
                    HeadCache::from_prefill(cfg, d_h, &keys, &vals)
                })
                .collect(),
        )
    }

    #[test]
    fn layer_cache_accessors_agree() {
        let cfg = QuantMethod::InnerQBase.config();
        let mut rng = Rng::new(3);
        let lc = build_layer(cfg, 64, 3, 200, &mut rng);
        assert_eq!(lc.n_heads(), 3);
        assert_eq!(lc.len(), 200);
        assert!(!lc.is_empty());
        assert_eq!(lc.bytes(), lc.heads().iter().map(|h| h.bytes()).sum::<usize>());
        assert_eq!(lc.head(1), &lc.heads()[1]);
    }

    /// The fused append+attend fan-out must be bit-identical to the split
    /// path (all appends first, then all attends) at any worker count —
    /// the core pipelined-decode determinism contract, at the unit level.
    #[test]
    fn fused_step_matches_split_path_bit_for_bit() {
        let d_h = 64;
        let rep = 2;
        let n_heads = 4;
        let n_seq = 3;
        let n = 300; // past the high-precision windows: quantized appends
        let cfg = QuantMethod::InnerQBase.config();

        let build = |seed: u64| -> Vec<LayerCache> {
            let mut rng = Rng::new(seed);
            (0..n_seq).map(|_| build_layer(cfg, d_h, n_heads, n, &mut rng)).collect()
        };
        let mut rng = Rng::new(99);
        let count = n_seq * n_heads;
        let k = normal_vec(&mut rng, count * d_h, 1.0, 0.0);
        let v = normal_vec(&mut rng, count * d_h, 1.0, 0.0);
        let q = normal_vec(&mut rng, count * rep * d_h, 1.0, 0.0);

        // Split reference: serial appends, then serial attends.
        let mut split = build(7);
        let mut want_ctx = vec![0f32; count * rep * d_h];
        {
            let mut scratch = Vec::new();
            for (c, head) in split.iter_mut().flat_map(|l| l.heads_mut().iter_mut()).enumerate() {
                head.append(&k[c * d_h..(c + 1) * d_h], &v[c * d_h..(c + 1) * d_h]);
            }
            for (c, head) in split.iter().flat_map(|l| l.heads().iter()).enumerate() {
                for r in 0..rep {
                    let qb = (c * rep + r) * d_h;
                    head.attend(
                        &q[qb..qb + d_h],
                        &mut want_ctx[qb..qb + d_h],
                        &mut scratch,
                    );
                }
            }
        }

        for workers in [1usize, 2, 4, 8] {
            let mut fused = build(7);
            let mut ctx = vec![0f32; count * rep * d_h];
            {
                let pool = ThreadPool::new(workers);
                let heads: Vec<&mut HeadCache> =
                    fused.iter_mut().flat_map(|l| l.heads_mut().iter_mut()).collect();
                pool.run(step_fanout(heads, &k, &v, &q, &mut ctx, rep, d_h));
            }
            assert_eq!(ctx, want_ctx, "workers={workers}: ctx diverged");
            assert_eq!(fused, split, "workers={workers}: cache state diverged");
        }
    }
}
