//! Per-head quantized KV-cache manager: ties together the sink window, the
//! recent window, the quantized segments, per-channel key normalization, and
//! the method-specific eviction→quantize policy (§4.2, §4.4, Fig. 2).
//!
//! Token partition at any time (in global generation order):
//!
//! ```text
//!   [ sink (fp) | quantized segment | recent (fp) ]
//! ```
//!
//! The key and value stores evict on different cadences (InnerQ quantizes
//! one key per step but 32 values every 32 steps; KIVI is mirrored), so the
//! K and V partitions have *independent* quantized/recent boundaries; the
//! attention entry point handles both splits.

use crate::cache::segments::*;
use crate::cache::window::{RecentWindow, SinkWindow};
use crate::kernels::gemv_fp;
use crate::kernels::softmax::softmax_scaled;
use crate::obs;
use crate::quant::norm::ChannelNorm;
use crate::quant::{Grouping, MethodConfig};
use crate::util::threadpool::Job;
use std::sync::Arc;

/// Build one decode step's attention fan-out: `caches` yields one
/// `&HeadCache` per (sequence, KV head) in sequence-major order, and job
/// `c` attends query heads `c*rep .. (c+1)*rep` of `q` into its disjoint
/// `rep * d_h` slice of `ctx` (so `ctx` must hold at least
/// `count(caches) * rep * d_h` f32). This is the single definition of the
/// fan-out shape — the engine, the decode-scaling bench, and the
/// determinism tests all build their jobs here so they cannot drift apart.
pub fn attention_fanout<'a>(
    caches: impl IntoIterator<Item = &'a HeadCache>,
    q: &'a [f32],
    ctx: &'a mut [f32],
    rep: usize,
    d_h: usize,
) -> Vec<Job<'a>> {
    let mut jobs: Vec<Job<'a>> = Vec::new();
    let mut chunks = ctx.chunks_mut(rep * d_h);
    for (c, cache) in caches.into_iter().enumerate() {
        let out_chunk = chunks.next().expect("one rep*d_h ctx chunk per cache");
        jobs.push(Box::new(move |scratch: &mut Vec<f32>| {
            for r in 0..rep {
                let qb = (c * rep + r) * d_h;
                cache.attend(
                    &q[qb..qb + d_h],
                    &mut out_chunk[r * d_h..(r + 1) * d_h],
                    scratch,
                );
            }
        }));
    }
    jobs
}

/// Build the prefill bulk-quantization fan-out: one job per KV head. Each
/// job calls its `gather` closure for the head's token-major `(keys, vals)`
/// rows, quantizes them, and writes the finished [`HeadCache`] into its
/// disjoint slot. The gather runs *inside* the job so peak extra memory is
/// one head copy per in-flight worker, not the whole prompt KV at once (the
/// engine gathers strided rows out of the shared prefill tensors). This is
/// the single definition of the prefill job shape — the engine's prefill
/// path and the prefill-determinism test both build their jobs here,
/// mirroring [`attention_fanout`] for decode. Each head's quantization is
/// independent and internally sequential (unchanged FP order), so results
/// are byte-identical across worker counts.
pub fn prefill_fanout<'a, F>(
    cfg: MethodConfig,
    d_h: usize,
    gathers: Vec<F>,
    slots: &'a mut [Option<HeadCache>],
) -> Vec<Job<'a>>
where
    F: FnOnce() -> (Vec<f32>, Vec<f32>) + Send + 'a,
{
    assert_eq!(gathers.len(), slots.len(), "one output slot per head");
    gathers
        .into_iter()
        .zip(slots.iter_mut())
        .map(|(gather, slot)| {
            let job: Job<'a> = Box::new(move |_scratch: &mut Vec<f32>| {
                let (keys, vals) = gather();
                *slot = Some(HeadCache::from_prefill(cfg, d_h, &keys, &vals));
            });
            job
        })
        .collect()
}

/// Unified key-segment dispatch.
#[derive(Debug, Clone, PartialEq)]
pub enum KeySegment {
    /// Unquantized f32 rows (BaselineFp16).
    Fp(FpSegment),
    /// InnerQ layout: groups along the GEMV reduction axis.
    Inner(InnerKeySegment),
    /// KIVI layout: groups along the GEMV output axis.
    Outer(OuterKeySegment),
    /// TurboQuant rotated codebook coding.
    Turbo(TurboKeySegment),
}

impl KeySegment {
    /// Tokens stored in this segment.
    pub fn len(&self) -> usize {
        match self {
            KeySegment::Fp(s) => s.len(),
            KeySegment::Inner(s) => s.len(),
            KeySegment::Outer(s) => s.len(),
            KeySegment::Turbo(s) => s.len(),
        }
    }
    /// How many tokens the quantizer consumes per eviction.
    pub fn evict_batch(&self) -> usize {
        match self {
            // Per-channel (outer) key grouping needs a full group of tokens.
            KeySegment::Outer(_) => 32,
            _ => 1,
        }
    }
    /// Packed payload bytes of the segment.
    pub fn bytes(&self) -> usize {
        match self {
            KeySegment::Fp(s) => s.bytes(),
            KeySegment::Inner(s) => s.bytes(),
            KeySegment::Outer(s) => s.bytes(),
            KeySegment::Turbo(s) => s.bytes(),
        }
    }
    /// Quantize-append `n x d_h` token-major rows (n == evict_batch or bulk
    /// multiples of it during prefill).
    pub fn append(&mut self, rows: &[f32], d_h: usize) {
        match self {
            KeySegment::Fp(s) => {
                for r in rows.chunks_exact(d_h) {
                    s.append_token(r);
                }
            }
            KeySegment::Inner(s) => {
                for r in rows.chunks_exact(d_h) {
                    s.append_token(r);
                }
            }
            KeySegment::Outer(s) => {
                for chunk in rows.chunks_exact(32 * d_h) {
                    s.append_chunk(chunk);
                }
            }
            KeySegment::Turbo(s) => {
                for r in rows.chunks_exact(d_h) {
                    s.append_token(r);
                }
            }
        }
    }
    /// Fused dequant-GEMV scores of `q` against every stored key.
    pub fn scores(&self, q: &[f32], d_h: usize, scratch: &mut [f32], out: &mut [f32]) {
        match self {
            KeySegment::Fp(s) => gemv_fp::qk_fp(q, &s.rows, d_h, out),
            KeySegment::Inner(s) => s.scores(q, out),
            KeySegment::Outer(s) => s.scores(q, scratch, out),
            KeySegment::Turbo(s) => s.scores(q, out),
        }
    }
    /// An owned segment holding this segment's tokens followed by `own`'s.
    /// Because every layout appends position-independently, the result is
    /// byte-identical to a single segment built over the concatenated
    /// history — the materialization step behind shared-prefix snapshots.
    pub fn merged_with(&self, own: &KeySegment) -> KeySegment {
        let mut out = self.clone();
        match (&mut out, own) {
            (KeySegment::Fp(a), KeySegment::Fp(b)) => a.extend_from(b),
            (KeySegment::Inner(a), KeySegment::Inner(b)) => a.extend_from(b),
            (KeySegment::Outer(a), KeySegment::Outer(b)) => a.extend_from(b),
            (KeySegment::Turbo(a), KeySegment::Turbo(b)) => a.extend_from(b),
            _ => panic!("mismatched key segment layouts in shared-prefix merge"),
        }
        out
    }
}

/// Unified value-segment dispatch.
#[derive(Debug, Clone, PartialEq)]
pub enum ValSegment {
    /// Unquantized f32 rows (BaselineFp16).
    Fp(FpSegment),
    /// InnerQ layout: groups along the GEMV reduction axis.
    Inner(InnerValSegment),
    /// KIVI layout: groups along the GEMV output axis.
    Outer(OuterValSegment),
    /// TurboQuant rotated codebook coding.
    Turbo(TurboValSegment),
}

impl ValSegment {
    /// Tokens stored in this segment.
    pub fn len(&self) -> usize {
        match self {
            ValSegment::Fp(s) => s.len(),
            ValSegment::Inner(s) => s.len(),
            ValSegment::Outer(s) => s.len(),
            ValSegment::Turbo(s) => s.len(),
        }
    }
    /// How many tokens the quantizer consumes per eviction.
    pub fn evict_batch(&self) -> usize {
        match self {
            // Per-channel (inner) value grouping needs a full group of tokens.
            ValSegment::Inner(_) => 32,
            _ => 1,
        }
    }
    /// Packed payload bytes of the segment.
    pub fn bytes(&self) -> usize {
        match self {
            ValSegment::Fp(s) => s.bytes(),
            ValSegment::Inner(s) => s.bytes(),
            ValSegment::Outer(s) => s.bytes(),
            ValSegment::Turbo(s) => s.bytes(),
        }
    }
    /// Quantize-append `n x d_h` token-major rows (n == evict_batch or bulk multiples of it during prefill).
    pub fn append(&mut self, rows: &[f32], d_h: usize) {
        match self {
            ValSegment::Fp(s) => {
                for r in rows.chunks_exact(d_h) {
                    s.append_token(r);
                }
            }
            ValSegment::Inner(s) => {
                for chunk in rows.chunks_exact(32 * d_h) {
                    s.append_chunk(chunk);
                }
            }
            ValSegment::Outer(s) => {
                for r in rows.chunks_exact(d_h) {
                    s.append_token(r);
                }
            }
            ValSegment::Turbo(s) => {
                for r in rows.chunks_exact(d_h) {
                    s.append_token(r);
                }
            }
        }
    }
    /// `out[c] += Σ p_t · v_t[c]` over the segment's tokens.
    pub fn accumulate(&self, p: &[f32], d_h: usize, out: &mut [f32]) {
        match self {
            ValSegment::Fp(s) => gemv_fp::pv_fp(p, &s.rows, d_h, out),
            ValSegment::Inner(s) => s.accumulate(p, out),
            ValSegment::Outer(s) => s.accumulate(p, out),
            ValSegment::Turbo(s) => {
                let mut acc = vec![0f32; d_h];
                s.accumulate_rotated(p, &mut acc);
                s.finalize_into(acc, out);
            }
        }
    }
    /// An owned segment holding this segment's tokens followed by `own`'s
    /// (see [`KeySegment::merged_with`]).
    pub fn merged_with(&self, own: &ValSegment) -> ValSegment {
        let mut out = self.clone();
        match (&mut out, own) {
            (ValSegment::Fp(a), ValSegment::Fp(b)) => a.extend_from(b),
            (ValSegment::Inner(a), ValSegment::Inner(b)) => a.extend_from(b),
            (ValSegment::Outer(a), ValSegment::Outer(b)) => a.extend_from(b),
            (ValSegment::Turbo(a), ValSegment::Turbo(b)) => a.extend_from(b),
            _ => panic!("mismatched value segment layouts in shared-prefix merge"),
        }
        out
    }
}

/// KV cache for one attention (KV) head of one sequence. `PartialEq`
/// compares the full quantized state (codes, params, planar planes,
/// windows) — the prefill-determinism tests use it to assert byte-identical
/// construction across worker counts.
///
/// Ownership is split in two tiers. The *borrowed* tier (`shared_k` /
/// `shared_v`) is an immutable, refcounted image of the quantized middle of
/// a shared prompt prefix, handed out by the content-addressed prefix store
/// — many sequences point at the same bytes and none may mutate them. The
/// *owned* tier is everything private to this sequence: the fp sink/recent
/// windows and the post-fork quantized groups in `qk`/`qv`, which grow as
/// the recent window evicts. Attention iterates shared-then-private block
/// runs without copying; [`HeadCache::merged`] materializes the unified
/// view (used by snapshots so shared and private paths serialize
/// byte-identically).
#[derive(Debug, PartialEq)]
pub struct HeadCache {
    /// Quantization method configuration.
    pub cfg: MethodConfig,
    /// Head dimension.
    pub d_h: usize,
    /// Full-precision attention-sink keys (first `w_sink` tokens).
    pub sink_k: SinkWindow,
    /// Full-precision attention-sink values.
    pub sink_v: SinkWindow,
    /// Full-precision recent keys awaiting eviction.
    pub recent_k: RecentWindow,
    /// Full-precision recent values awaiting eviction.
    pub recent_v: RecentWindow,
    /// Borrowed quantized key run of the shared prompt prefix, attended
    /// *before* `qk`. Immutable: eviction never appends here.
    pub shared_k: Option<Arc<KeySegment>>,
    /// Borrowed quantized value run of the shared prompt prefix.
    pub shared_v: Option<Arc<ValSegment>>,
    /// Quantized middle of the key partition (private / post-fork groups).
    pub qk: KeySegment,
    /// Quantized middle of the value partition (private / post-fork groups).
    pub qv: ValSegment,
    /// Per-channel key normalization folded into quantized scores.
    pub norm: ChannelNorm,
    pub(crate) n_tokens: usize,
}

fn make_key_segment(cfg: &MethodConfig, d_h: usize, seed: u64) -> KeySegment {
    if !cfg.is_quantized() {
        KeySegment::Fp(FpSegment::new(d_h))
    } else if cfg.turbo {
        KeySegment::Turbo(TurboKeySegment::new(d_h, cfg.key_bits, seed))
    } else {
        match cfg.key_grouping {
            Grouping::Inner => KeySegment::Inner(InnerKeySegment::new(d_h, cfg.key_bits, cfg.key_mode)),
            Grouping::Outer => KeySegment::Outer(OuterKeySegment::new(d_h, cfg.key_bits, cfg.key_mode)),
        }
    }
}

fn make_val_segment(cfg: &MethodConfig, d_h: usize, seed: u64) -> ValSegment {
    if !cfg.is_quantized() {
        ValSegment::Fp(FpSegment::new(d_h))
    } else if cfg.turbo {
        ValSegment::Turbo(TurboValSegment::new(d_h, cfg.val_bits, seed))
    } else {
        match cfg.val_grouping {
            Grouping::Inner => ValSegment::Inner(InnerValSegment::new(d_h, cfg.val_bits, cfg.val_mode)),
            Grouping::Outer => ValSegment::Outer(OuterValSegment::new(d_h, cfg.val_bits, cfg.val_mode)),
        }
    }
}

impl HeadCache {
    /// An empty cache for one KV head under `cfg`.
    pub fn new(cfg: MethodConfig, d_h: usize) -> HeadCache {
        // Distinct rotation seeds for K and V (shared across heads is fine —
        // the rotation is data-oblivious).
        HeadCache {
            sink_k: SinkWindow::new(d_h, cfg.w_sink),
            sink_v: SinkWindow::new(d_h, cfg.w_sink),
            recent_k: RecentWindow::new(d_h),
            recent_v: RecentWindow::new(d_h),
            shared_k: None,
            shared_v: None,
            qk: make_key_segment(&cfg, d_h, 0x5eed_0001),
            qv: make_val_segment(&cfg, d_h, 0x5eed_0002),
            norm: ChannelNorm::identity(d_h),
            cfg,
            d_h,
            n_tokens: 0,
        }
    }

    /// Initialize from prefill keys/values (`n x d_h`, token-major).
    /// Computes the per-channel key norm over the prefill keys (§4.3), then
    /// applies Eq. 15: sink window, bulk-quantized middle, recent window.
    pub fn from_prefill(cfg: MethodConfig, d_h: usize, keys: &[f32], vals: &[f32]) -> HeadCache {
        assert_eq!(keys.len(), vals.len());
        assert_eq!(keys.len() % d_h, 0);
        let mut hc = HeadCache::new(cfg, d_h);
        if cfg.key_norm {
            hc.norm = ChannelNorm::from_prefill_keys(keys, d_h);
        }
        for (k, v) in keys.chunks_exact(d_h).zip(vals.chunks_exact(d_h)) {
            hc.append(k, v);
        }
        hc
    }

    /// Tokens stored in this segment.
    pub fn len(&self) -> usize {
        self.n_tokens
    }

    /// Initialize like [`HeadCache::from_prefill`], but compute the
    /// per-channel key norm over only the first `norm_tokens` rows (the
    /// shared-prefix boundary) instead of the whole prompt. This is the
    /// *numerics* contract of prefix sharing: the prefix state becomes a
    /// deterministic function of the prefix tokens alone, so the same rows
    /// produce the same quantized bytes in every sequence regardless of
    /// what follows the boundary — and regardless of whether the bytes end
    /// up shared (store hit/miss) or privately owned (sharing disabled).
    pub fn from_prefill_split_norm(
        cfg: MethodConfig,
        d_h: usize,
        keys: &[f32],
        vals: &[f32],
        norm_tokens: usize,
    ) -> HeadCache {
        assert_eq!(keys.len(), vals.len());
        assert_eq!(keys.len() % d_h, 0);
        assert!(norm_tokens * d_h <= keys.len());
        let mut hc = HeadCache::new(cfg, d_h);
        if cfg.key_norm {
            let nb = if norm_tokens > 0 { norm_tokens * d_h } else { keys.len() };
            hc.norm = ChannelNorm::from_prefill_keys(&keys[..nb], d_h);
        }
        for (k, v) in keys.chunks_exact(d_h).zip(vals.chunks_exact(d_h)) {
            hc.append(k, v);
        }
        hc
    }

    /// Move the quantized middle into immutable shared images, leaving this
    /// cache referencing them as its borrowed tier with fresh (empty)
    /// private segments on top. Called at the shared-prefix fork point —
    /// after the prefix rows were appended, before any tail rows — so the
    /// returned images are exactly the prefix's quantized bytes. Must not
    /// be called on a cache that already borrows a prefix.
    pub fn split_off_prefix(&mut self) -> (Arc<KeySegment>, Arc<ValSegment>) {
        assert!(
            self.shared_k.is_none() && self.shared_v.is_none(),
            "cache already borrows a shared prefix"
        );
        let qk = std::mem::replace(&mut self.qk, make_key_segment(&self.cfg, self.d_h, 0x5eed_0001));
        let qv = std::mem::replace(&mut self.qv, make_val_segment(&self.cfg, self.d_h, 0x5eed_0002));
        let sk = Arc::new(qk);
        let sv = Arc::new(qv);
        self.shared_k = Some(sk.clone());
        self.shared_v = Some(sv.clone());
        (sk, sv)
    }

    /// Initialize from a shared-prefix store hit: install the borrowed
    /// quantized images and the prefix-derived norm, rebuild the fp windows
    /// by replaying the prefix rows' push/evict cadence (bit-identical to
    /// the miss path's windows — see [`HeadCache::rebuild_windows`]), then
    /// append the unshared tail rows through the normal eviction policy.
    /// `keys`/`vals` are the full prompt rows; `prefix_len` marks the fork.
    pub fn from_shared_prefix(
        cfg: MethodConfig,
        d_h: usize,
        keys: &[f32],
        vals: &[f32],
        prefix_len: usize,
        shared_k: Arc<KeySegment>,
        shared_v: Arc<ValSegment>,
        norm: ChannelNorm,
    ) -> HeadCache {
        assert_eq!(keys.len(), vals.len());
        assert!(prefix_len * d_h <= keys.len());
        let mut hc = HeadCache::new(cfg, d_h);
        hc.norm = norm;
        hc.shared_k = Some(shared_k);
        hc.shared_v = Some(shared_v);
        hc.n_tokens = prefix_len;
        hc.rebuild_windows(&keys[..prefix_len * d_h], &vals[..prefix_len * d_h]);
        for (k, v) in keys[prefix_len * d_h..]
            .chunks_exact(d_h)
            .zip(vals[prefix_len * d_h..].chunks_exact(d_h))
        {
            hc.append(k, v);
        }
        hc
    }

    /// Tokens held in the borrowed (shared-prefix) key run.
    pub fn shared_key_len(&self) -> usize {
        self.shared_k.as_ref().map_or(0, |s| s.len())
    }

    /// Tokens held in the borrowed (shared-prefix) value run.
    pub fn shared_val_len(&self) -> usize {
        self.shared_v.as_ref().map_or(0, |s| s.len())
    }

    /// Bytes of the borrowed shared images (charged once, store-side, no
    /// matter how many sequences reference them).
    pub fn shared_bytes(&self) -> usize {
        self.shared_k.as_ref().map_or(0, |s| s.bytes())
            + self.shared_v.as_ref().map_or(0, |s| s.bytes())
    }

    /// An owned, unshared copy with the borrowed and private quantized runs
    /// materialized into single segments — byte-identical state to a cache
    /// that never shared (the snapshot layer serializes through this, so a
    /// shared-prefix sequence and its private-copy twin produce identical
    /// snapshot bytes).
    pub fn merged(&self) -> HeadCache {
        let qk = match &self.shared_k {
            Some(sk) => sk.merged_with(&self.qk),
            None => self.qk.clone(),
        };
        let qv = match &self.shared_v {
            Some(sv) => sv.merged_with(&self.qv),
            None => self.qv.clone(),
        };
        HeadCache {
            cfg: self.cfg,
            d_h: self.d_h,
            sink_k: self.sink_k.clone(),
            sink_v: self.sink_v.clone(),
            recent_k: self.recent_k.clone(),
            recent_v: self.recent_v.clone(),
            shared_k: None,
            shared_v: None,
            qk,
            qv,
            norm: self.norm.clone(),
            n_tokens: self.n_tokens,
        }
    }

    /// Rebuild the fp sink/recent windows from recomputed rows, leaving the
    /// quantized segments untouched. `keys`/`vals` must be the *full*
    /// token-major row history (`n_tokens x d_h`) — in practice a fresh
    /// prefill pass over the same tokens, which is deterministic and so
    /// reproduces the original rows bit-for-bit.
    ///
    /// This is the partial-eviction restore path: the warm tier may drop a
    /// snapshot's fp-window frames (they dominate snapshot bytes at f32 vs
    /// 2–4-bit codes) while keeping the quantized middle; restore then
    /// replays the exact window push/evict sequence of the original appends
    /// — same sink fill, same recent-window pops at the segments' eviction
    /// cadence, same ring compaction — so the rebuilt windows are
    /// bit-identical to the snapshotted ones, internal buffer state
    /// included (asserted in `tests/decode_pipeline.rs`).
    pub fn rebuild_windows(&mut self, keys: &[f32], vals: &[f32]) {
        let d_h = self.d_h;
        assert_eq!(keys.len(), vals.len());
        assert_eq!(
            keys.len(),
            self.n_tokens * d_h,
            "window rebuild needs every stored token's rows"
        );
        self.sink_k = SinkWindow::new(d_h, self.cfg.w_sink);
        self.sink_v = SinkWindow::new(d_h, self.cfg.w_sink);
        self.recent_k = RecentWindow::new(d_h);
        self.recent_v = RecentWindow::new(d_h);
        let kb = self.qk.evict_batch();
        let vb = self.qv.evict_batch();
        for (k, v) in keys.chunks_exact(d_h).zip(vals.chunks_exact(d_h)) {
            if self.sink_k.try_push(k) {
                let ok = self.sink_v.try_push(v);
                debug_assert!(ok);
                continue;
            }
            self.recent_k.push(k);
            self.recent_v.push(v);
            // Mirror `evict()`'s pop cadence exactly, discarding the popped
            // rows (their quantized form is already in qk/qv).
            while self.recent_k.len() >= self.cfg.w_recent + kb {
                self.recent_k.pop_front(kb, |_| {});
            }
            while self.recent_v.len() >= self.cfg.w_recent + vb {
                self.recent_v.pop_front(vb, |_| {});
            }
        }
        debug_assert_eq!(
            self.sink_k.len() + self.shared_key_len() + self.qk.len() + self.recent_k.len(),
            self.n_tokens
        );
        debug_assert_eq!(
            self.sink_v.len() + self.shared_val_len() + self.qv.len() + self.recent_v.len(),
            self.n_tokens
        );
    }

    /// Bytes owned by this sequence (FP16-equivalent for the windows).
    /// Borrowed shared-prefix images are excluded — they are charged once
    /// by the prefix store, not per referencing sequence (see
    /// [`HeadCache::shared_bytes`]).
    pub fn bytes(&self) -> usize {
        self.sink_k.bytes()
            + self.sink_v.bytes()
            + self.recent_k.bytes()
            + self.recent_v.bytes()
            + self.qk.bytes()
            + self.qv.bytes()
    }

    /// Append one token's key/value and run the eviction policy.
    pub fn append(&mut self, k: &[f32], v: &[f32]) {
        self.n_tokens += 1;
        if self.sink_k.try_push(k) {
            let ok = self.sink_v.try_push(v);
            debug_assert!(ok);
            return;
        }
        self.recent_k.push(k);
        self.recent_v.push(v);
        self.evict();
    }

    fn evict(&mut self) {
        let d_h = self.d_h;
        let t_evict = obs::start();
        let mut rows_quantized = 0usize;
        // Keys: pop evict_batch rows whenever the window exceeds w_recent by
        // at least one batch.
        let kb = self.qk.evict_batch();
        while self.recent_k.len() >= self.cfg.w_recent + kb {
            rows_quantized += kb;
            let qk = &mut self.qk;
            let norm = &self.norm;
            let use_norm = self.cfg.key_norm;
            self.recent_k.pop_front(kb, |rows| {
                if use_norm {
                    let mut buf = rows.to_vec();
                    for r in buf.chunks_exact_mut(d_h) {
                        norm.apply_key(r);
                    }
                    qk.append(&buf, d_h);
                } else {
                    qk.append(rows, d_h);
                }
            });
        }
        let vb = self.qv.evict_batch();
        while self.recent_v.len() >= self.cfg.w_recent + vb {
            rows_quantized += vb;
            let qv = &mut self.qv;
            self.recent_v.pop_front(vb, |rows| qv.append(rows, d_h));
        }
        if rows_quantized > 0 {
            obs::span(
                obs::SpanKind::QuantEvict,
                rows_quantized as u64,
                t_evict,
                rows_quantized as u64,
                0,
            );
        }
    }

    /// Full decode attention for one query head vector against this cache
    /// (Eq. 3–5 with the Fig. 2 merge). `out` receives the context vector.
    ///
    /// Takes `&self` with externally-owned `scratch` (resized to
    /// `n_tokens + d_h` f32 as needed) precisely so the engine's worker pool
    /// can attend over disjoint heads concurrently: the caches are only
    /// read here, each worker brings its own scratch arena, and all
    /// mutation (`append`) stays on the driver thread between fan-outs.
    pub fn attend(&self, q: &[f32], out: &mut [f32], scratch: &mut Vec<f32>) {
        let n = self.n_tokens;
        let d_h = self.d_h;
        debug_assert_eq!(q.len(), d_h);
        debug_assert_eq!(out.len(), d_h);
        scratch.clear();
        scratch.resize(n + d_h, 0.0);
        let (scores, kscratch) = scratch.split_at_mut(n);

        // ---- scores over the K partition ----
        // The quantized middle is a shared-then-private run: the borrowed
        // prefix image first (if any), then this sequence's own groups.
        // Every token scores independently, so the split run is
        // bit-identical to one unified segment.
        let ws = self.sink_k.len();
        let nsk = self.shared_key_len();
        let nqk = self.qk.len();
        let nrk = self.recent_k.len();
        debug_assert_eq!(ws + nsk + nqk + nrk, n);
        gemv_fp::qk_fp(q, &self.sink_k.rows, d_h, &mut scores[..ws]);
        if nsk + nqk > 0 {
            // Fold the per-channel norm into the query for the quantized
            // span (keys were normalized at insertion).
            let qn: Option<Vec<f32>> = if self.cfg.key_norm {
                let mut qn = q.to_vec();
                self.norm.apply_query(&mut qn);
                Some(qn)
            } else {
                None
            };
            let qq: &[f32] = qn.as_deref().unwrap_or(q);
            if let Some(sk) = &self.shared_k {
                if nsk > 0 {
                    sk.scores(qq, d_h, kscratch, &mut scores[ws..ws + nsk]);
                }
            }
            if nqk > 0 {
                self.qk.scores(qq, d_h, kscratch, &mut scores[ws + nsk..ws + nsk + nqk]);
            }
        }
        gemv_fp::qk_fp(q, self.recent_k.rows(), d_h, &mut scores[ws + nsk + nqk..]);

        // ---- softmax over all tokens ----
        softmax_scaled(scores, 1.0 / (d_h as f32).sqrt());

        // ---- context over the V partition (independent boundaries) ----
        let nsv = self.shared_val_len();
        let nqv = self.qv.len();
        let nrv = self.recent_v.len();
        debug_assert_eq!(ws + nsv + nqv + nrv, n);
        for o in out.iter_mut() {
            *o = 0.0;
        }
        gemv_fp::pv_fp(&scores[..ws], &self.sink_v.rows, d_h, out);
        match (&self.shared_v, &self.qv) {
            // Turbo accumulates in the rotated basis and un-rotates once;
            // splitting that across two independent `accumulate` calls would
            // run the (linear but floating-point) FWHT twice and diverge
            // from the unified segment. Share one rotated accumulator
            // across both runs and finalize once instead.
            (Some(sv), ValSegment::Turbo(own)) if nsv > 0 => {
                let shared = match &**sv {
                    ValSegment::Turbo(s) => s,
                    _ => panic!("mismatched value segment layouts in shared-prefix attend"),
                };
                let mut acc = vec![0f32; d_h];
                shared.accumulate_rotated(&scores[ws..ws + nsv], &mut acc);
                if nqv > 0 {
                    own.accumulate_rotated(&scores[ws + nsv..ws + nsv + nqv], &mut acc);
                }
                own.finalize_into(acc, out);
            }
            _ => {
                if let Some(sv) = &self.shared_v {
                    if nsv > 0 {
                        sv.accumulate(&scores[ws..ws + nsv], d_h, out);
                    }
                }
                if nqv > 0 {
                    self.qv.accumulate(&scores[ws + nsv..ws + nsv + nqv], d_h, out);
                }
            }
        }
        gemv_fp::pv_fp(&scores[ws + nsv + nqv..], self.recent_v.rows(), d_h, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QuantMethod;
    use crate::util::ptest::normal_vec;
    use crate::util::rng::Rng;
    use crate::util::stats::rel_l2;

    fn reference_attention(q: &[f32], keys: &[f32], vals: &[f32], d_h: usize) -> Vec<f32> {
        let n = keys.len() / d_h;
        let mut s = vec![0f32; n];
        gemv_fp::qk_fp(q, keys, d_h, &mut s);
        softmax_scaled(&mut s, 1.0 / (d_h as f32).sqrt());
        let mut out = vec![0f32; d_h];
        gemv_fp::pv_fp(&s, vals, d_h, &mut out);
        out
    }

    fn run_method(m: QuantMethod, n_prefill: usize, n_decode: usize, seed: u64) -> (f32, usize) {
        let d_h = 64;
        let mut rng = Rng::new(seed);
        let keys = normal_vec(&mut rng, (n_prefill + n_decode) * d_h, 1.0, 0.02);
        let vals = normal_vec(&mut rng, (n_prefill + n_decode) * d_h, 1.0, 0.02);
        let cfg = m.config();
        let mut hc = HeadCache::from_prefill(
            cfg,
            d_h,
            &keys[..n_prefill * d_h],
            &vals[..n_prefill * d_h],
        );
        for t in n_prefill..n_prefill + n_decode {
            hc.append(&keys[t * d_h..(t + 1) * d_h], &vals[t * d_h..(t + 1) * d_h]);
        }
        let q = normal_vec(&mut rng, d_h, 1.0, 0.0);
        let mut out = vec![0f32; d_h];
        let mut scratch = Vec::new();
        hc.attend(&q, &mut out, &mut scratch);
        let want = reference_attention(&q, &keys, &vals, d_h);
        (rel_l2(&out, &want), hc.len())
    }

    #[test]
    fn baseline_is_exact() {
        let (err, n) = run_method(QuantMethod::BaselineFp16, 200, 50, 1);
        assert_eq!(n, 250);
        assert!(err < 1e-5, "baseline err {err}");
    }

    #[test]
    fn all_methods_approximate_reference() {
        // Random (structure-free) data is the worst case for quantized
        // attention: score noise is amplified exponentially by softmax. The
        // bounds below are sanity rails against egregious breakage; exact
        // plumbing correctness is covered by the grid tests that follow and
        // fidelity ordering by the eval harness (Table 1).
        for (m, tol) in [
            (QuantMethod::InnerQBase, 0.8),
            (QuantMethod::InnerQHybrid, 1.0),
            (QuantMethod::InnerQSmall, 1.2),
            (QuantMethod::Kivi, 1.2),
            (QuantMethod::KiviSink, 1.2),
            (QuantMethod::TurboQuant, 1.0),
        ] {
            let (err, n) = run_method(m, 300, 77, 2);
            assert_eq!(n, 377);
            assert!(err < tol, "{m:?} err {err} > {tol}");
            assert!(err.is_finite());
        }
    }

    /// Build token rows whose values are exactly representable under 3-bit
    /// symmetric quantization with per-token (inner) groups: each group gets
    /// values from {0, ±s, ±2s, ±3s} with both ±3s present, so amax/qmax = s
    /// exactly (and s is f16-exact).
    fn grid_rows_sym3(rng: &mut Rng, n: usize, d_h: usize) -> Vec<f32> {
        let s = 0.5f32;
        let mut out = Vec::with_capacity(n * d_h);
        for _ in 0..n {
            for g in 0..d_h / 32 {
                let _ = g;
                let mut vals: Vec<f32> = (0..32)
                    .map(|_| (rng.next_range(7) as i32 - 3) as f32 * s)
                    .collect();
                vals[0] = 3.0 * s; // pin amax so the scale is exactly s
                vals[1] = -3.0 * s;
                out.extend(vals);
            }
        }
        out
    }

    #[test]
    fn innerq_grid_data_is_exact_end_to_end() {
        // With grid-representable data (and key-norm off), the quantized
        // path must reproduce the FP attention bit-for-bit (up to f32
        // accumulation order): this pins the whole plumbing — windows,
        // eviction cadence, segment layouts, partition splits, merge.
        let mut cfg = QuantMethod::InnerQBase.config();
        cfg.key_norm = false; // sqrt-norms would leave the grid
        let d_h = 64;
        let mut rng = Rng::new(31);
        let n = 400;
        let keys = grid_rows_sym3(&mut rng, n, d_h);
        // value grid: inner grouping for V is per-channel over token groups
        // of 32; make every value the same per channel within each 32-token
        // block so each group is constant => asym would also be exact, and
        // sym represents {0,±s..} exactly. Simpler: reuse the same grid —
        // groups are columns of the 32-token chunk, whose values are drawn
        // from the same representable set but amax may be < 3s; quantization
        // is still exact because every value is a multiple of s and
        // amax/qmax divides s... that only holds when amax = 3s, so pin
        // columns the same way via transpose-aware construction below.
        let mut vals = vec![0f32; n * d_h];
        for t in 0..n {
            for c in 0..d_h {
                vals[t * d_h + c] = (((t + c) % 7) as i32 - 3) as f32 * 0.5;
            }
        }
        // ^ every 32-token column window contains both ±1.5 (period 7 < 32),
        //   so each V group's amax is exactly 3s.
        let q = normal_vec(&mut rng, d_h, 1.0, 0.0);
        let mut hc = HeadCache::from_prefill(cfg, d_h, &keys, &vals);
        let mut out = vec![0f32; d_h];
        let mut scratch = Vec::new();
        hc.attend(&q, &mut out, &mut scratch);
        let want = reference_attention(&q, &keys, &vals, d_h);
        let err = rel_l2(&out, &want);
        assert!(err < 2e-4, "grid-exact InnerQ err {err}");
    }

    #[test]
    fn partitions_account_for_every_token() {
        for m in QuantMethod::ALL {
            let cfg = m.config();
            let d_h = 64;
            let mut rng = Rng::new(5);
            let mut hc = HeadCache::new(cfg, d_h);
            for t in 0..500 {
                let k = normal_vec(&mut rng, d_h, 1.0, 0.0);
                let v = normal_vec(&mut rng, d_h, 1.0, 0.0);
                hc.append(&k, &v);
                let nk = hc.sink_k.len() + hc.qk.len() + hc.recent_k.len();
                let nv = hc.sink_v.len() + hc.qv.len() + hc.recent_v.len();
                assert_eq!(nk, t + 1, "{m:?} K partition at {t}");
                assert_eq!(nv, t + 1, "{m:?} V partition at {t}");
                // recent window bounded by w_recent + batch - 1
                assert!(hc.recent_k.len() < cfg.w_recent + hc.qk.evict_batch());
                assert!(hc.recent_v.len() < cfg.w_recent + hc.qv.evict_batch());
            }
        }
    }

    #[test]
    fn innerq_eviction_cadence() {
        // InnerQ: one key per step, 32 values every 32 steps (§5.3).
        let cfg = QuantMethod::InnerQBase.config();
        let d_h = 64;
        let mut rng = Rng::new(6);
        let mut hc = HeadCache::new(cfg, d_h);
        // fill sink + recent exactly
        for _ in 0..(cfg.w_sink + cfg.w_recent) {
            let k = normal_vec(&mut rng, d_h, 1.0, 0.0);
            hc.append(&k.clone(), &k);
        }
        assert_eq!(hc.qk.len(), 0);
        assert_eq!(hc.qv.len(), 0);
        let mut key_evictions = 0;
        let mut val_evictions = Vec::new();
        for t in 0..96 {
            let k = normal_vec(&mut rng, d_h, 1.0, 0.0);
            hc.append(&k.clone(), &k);
            if hc.qk.len() > key_evictions {
                key_evictions = hc.qk.len();
                assert_eq!(hc.qk.len(), t + 1, "keys evict one per step");
            }
            val_evictions.push(hc.qv.len());
        }
        // values move in jumps of 32
        assert_eq!(*val_evictions.last().unwrap(), 96);
        assert!(val_evictions.iter().all(|&v| v % 32 == 0));
    }

    #[test]
    fn kivi_eviction_cadence_mirrored() {
        let cfg = QuantMethod::Kivi.config();
        let d_h = 64;
        let mut rng = Rng::new(7);
        let mut hc = HeadCache::new(cfg, d_h);
        for _ in 0..cfg.w_recent {
            let k = normal_vec(&mut rng, d_h, 1.0, 0.0);
            hc.append(&k.clone(), &k);
        }
        for t in 0..64 {
            let k = normal_vec(&mut rng, d_h, 1.0, 0.0);
            hc.append(&k.clone(), &k);
            assert_eq!(hc.qv.len(), t + 1, "KIVI evicts one value per step");
            assert_eq!(hc.qk.len() % 32, 0, "KIVI evicts keys in groups");
        }
    }

    #[test]
    fn key_norm_does_not_break_scores() {
        // Sanity rail: normalization must not blow the output up (score
        // preservation is tested exactly in quant::norm; here we run it
        // through the full eviction + attend pipeline).
        let (err_with, _) = run_method(QuantMethod::InnerQBase, 400, 10, 9);
        assert!(err_with.is_finite());
        assert!(err_with < 0.8, "with norm {err_with}");
    }

    #[test]
    fn head_cache_is_shareable_across_workers() {
        // The decode worker pool sends `&HeadCache` into jobs on other
        // threads; this pins the auto-trait requirement at compile time so
        // a future RefCell/Rc in any segment fails here, not in the engine.
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<HeadCache>();
    }

    #[test]
    fn concurrent_attend_matches_serial_bit_for_bit() {
        use crate::util::threadpool::ThreadPool;
        // 8 sequences x 2 heads of real InnerQ caches, fanned out exactly
        // like Engine::decode_step. Any worker count must reproduce the
        // serial context buffer byte-for-byte (disjoint outputs, unchanged
        // FP reduction order).
        let d_h = 64;
        let cfg = QuantMethod::InnerQBase.config();
        let mut rng = Rng::new(77);
        let n_seq = 8;
        let n_heads = 2;
        let n_tokens = 300; // past the high-precision windows
        let caches: Vec<Vec<HeadCache>> = (0..n_seq)
            .map(|_| {
                (0..n_heads)
                    .map(|_| {
                        let keys = normal_vec(&mut rng, n_tokens * d_h, 1.0, 0.02);
                        let vals = normal_vec(&mut rng, n_tokens * d_h, 1.0, 0.02);
                        HeadCache::from_prefill(cfg, d_h, &keys, &vals)
                    })
                    .collect()
            })
            .collect();
        let q = normal_vec(&mut rng, n_seq * n_heads * d_h, 1.0, 0.0);

        let run = |workers: usize| -> Vec<f32> {
            let pool = ThreadPool::new(workers);
            let mut ctx = vec![0f32; n_seq * n_heads * d_h];
            {
                let heads = caches.iter().flat_map(|s| s.iter());
                pool.run(attention_fanout(heads, &q, &mut ctx, 1, d_h));
            }
            ctx
        };

        let serial = run(1);
        assert!(serial.iter().all(|v| v.is_finite()));
        assert!(serial.iter().any(|&v| v != 0.0));
        for workers in [2usize, 4, 8] {
            assert_eq!(run(workers), serial, "workers={workers} diverged");
        }
    }

    #[test]
    fn parallel_prefill_matches_serial_byte_for_byte() {
        use crate::util::threadpool::ThreadPool;
        // Mirror of `concurrent_attend_matches_serial_bit_for_bit` for the
        // prefill bulk-quantization fan-out: building the caches through the
        // pool at any worker count must produce state (codes, params, planar
        // planes, windows, norms) identical to the serial build.
        let d_h = 64;
        let n_tokens = 300;
        let mut rng = Rng::new(91);
        for m in [QuantMethod::InnerQBase, QuantMethod::Kivi, QuantMethod::TurboQuant] {
            let cfg = m.config();
            let heads: Vec<(Vec<f32>, Vec<f32>)> = (0..12)
                .map(|_| {
                    (
                        normal_vec(&mut rng, n_tokens * d_h, 1.0, 0.02),
                        normal_vec(&mut rng, n_tokens * d_h, 1.0, 0.02),
                    )
                })
                .collect();
            let run = |workers: usize| -> Vec<HeadCache> {
                let pool = ThreadPool::new(workers);
                let mut slots: Vec<Option<HeadCache>> = (0..heads.len()).map(|_| None).collect();
                let gathers: Vec<_> = heads
                    .iter()
                    .map(|(k, v)| move || (k.clone(), v.clone()))
                    .collect();
                pool.run(prefill_fanout(cfg, d_h, gathers, &mut slots));
                slots.into_iter().map(|s| s.expect("slot filled")).collect()
            };
            let serial = run(1);
            assert!(serial.iter().all(|hc| hc.len() == n_tokens));
            for workers in [2usize, 4, 8] {
                assert_eq!(run(workers), serial, "{m:?} workers={workers} diverged");
            }
        }
    }

    #[test]
    fn shared_prefix_split_is_bit_identical_to_private_copy() {
        // The three construction paths of a prefix-boundary prefill —
        // private copy (sharing off), store miss (build + split), and store
        // hit (borrow + window rebuild) — must agree bit-for-bit: same
        // materialized state, same attention output. This is the per-head
        // core of the PR's bit-exactness contract.
        let d_h = 64;
        for m in QuantMethod::ALL {
            if m == QuantMethod::BaselineFp16 {
                continue; // nothing quantized to share
            }
            let cfg = m.config();
            for (n, prefix) in [(200usize, 160usize), (300, 192), (260, 224)] {
                let mut rng = Rng::new(0x9e1f ^ (n * 7 + prefix) as u64);
                let keys = normal_vec(&mut rng, n * d_h, 1.0, 0.02);
                let vals = normal_vec(&mut rng, n * d_h, 1.0, 0.02);

                // Sharing off: one owned cache, prefix-derived norm.
                let private = HeadCache::from_prefill_split_norm(cfg, d_h, &keys, &vals, prefix);

                // Store miss: build the prefix, split it into shared
                // images, then append the tail on top.
                let mut miss =
                    HeadCache::from_prefill_split_norm(
                        cfg,
                        d_h,
                        &keys[..prefix * d_h],
                        &vals[..prefix * d_h],
                        prefix,
                    );
                let (sk, sv) = miss.split_off_prefix();
                for (k, v) in keys[prefix * d_h..]
                    .chunks_exact(d_h)
                    .zip(vals[prefix * d_h..].chunks_exact(d_h))
                {
                    miss.append(k, v);
                }

                // Store hit: borrow the miss path's images.
                let hit = HeadCache::from_shared_prefix(
                    cfg,
                    d_h,
                    &keys,
                    &vals,
                    prefix,
                    sk,
                    sv,
                    miss.norm.clone(),
                );

                assert_eq!(miss, hit, "{m:?} n={n} p={prefix}: hit/miss state diverged");
                assert_eq!(
                    miss.merged(),
                    private,
                    "{m:?} n={n} p={prefix}: materialized shared state diverged"
                );
                assert_eq!(hit.len(), private.len());

                let q = normal_vec(&mut rng, d_h, 1.0, 0.0);
                let mut scratch = Vec::new();
                let mut out_private = vec![0f32; d_h];
                let mut out_miss = vec![0f32; d_h];
                let mut out_hit = vec![0f32; d_h];
                private.attend(&q, &mut out_private, &mut scratch);
                miss.attend(&q, &mut out_miss, &mut scratch);
                hit.attend(&q, &mut out_hit, &mut scratch);
                let bits = |o: &[f32]| o.iter().map(|v| v.to_bits()).collect::<Vec<u32>>();
                assert_eq!(
                    bits(&out_miss),
                    bits(&out_private),
                    "{m:?} n={n} p={prefix}: shared attend diverged from private"
                );
                assert_eq!(bits(&out_hit), bits(&out_private));

                // And the split must keep agreeing through further decode.
                let mut a = private;
                let mut b = hit;
                for t in 0..40 {
                    let k = normal_vec(&mut rng, d_h, 1.0, 0.02);
                    let v = normal_vec(&mut rng, d_h, 1.0, 0.02);
                    a.append(&k, &v);
                    b.append(&k, &v);
                    a.attend(&q, &mut out_private, &mut scratch);
                    b.attend(&q, &mut out_hit, &mut scratch);
                    assert_eq!(
                        bits(&out_hit),
                        bits(&out_private),
                        "{m:?} n={n} p={prefix}: decode step {t} diverged"
                    );
                }
                assert_eq!(b.merged(), a);
            }
        }
    }

    #[test]
    fn rebuilt_windows_are_bit_identical() {
        // Replaying the window push/evict sequence from the same rows must
        // reproduce the original windows exactly — internal ring state
        // included (the snapshot layer compares `data`/`start` verbatim).
        let d_h = 64;
        for m in [QuantMethod::InnerQBase, QuantMethod::Kivi] {
            for n in [40usize, 128, 131, 160, 223] {
                let cfg = m.config();
                let mut rng = Rng::new(0xFEED ^ n as u64);
                let keys = normal_vec(&mut rng, n * d_h, 1.0, 0.02);
                let vals = normal_vec(&mut rng, n * d_h, 1.0, 0.02);
                let want = HeadCache::from_prefill(cfg, d_h, &keys, &vals);
                let mut got = HeadCache::from_prefill(cfg, d_h, &keys, &vals);
                // Wreck the windows, then rebuild them from the rows.
                got.sink_k = SinkWindow::new(d_h, cfg.w_sink);
                got.sink_v = SinkWindow::new(d_h, cfg.w_sink);
                got.recent_k = RecentWindow::new(d_h);
                got.recent_v = RecentWindow::new(d_h);
                got.rebuild_windows(&keys, &vals);
                assert_eq!(got, want, "{m:?} n={n}: rebuilt windows diverged");
            }
        }
    }

    #[test]
    fn short_sequences_stay_in_windows() {
        // Sequences shorter than w_sink + w_recent never quantize anything,
        // so every method is exact there.
        for m in QuantMethod::ALL {
            if m == QuantMethod::BaselineFp16 {
                continue;
            }
            let (err, _) = run_method(m, 64, 10, 11);
            assert!(err < 1e-4, "{m:?} short-seq err {err}");
        }
    }
}
