//! InnerQ: hardware-aware, tuning-free KV-cache quantization for LLM serving.
//!
//! This crate is the Layer-3 (coordinator + native hot path) of a three-layer
//! reproduction of the InnerQ paper:
//!
//! * Layer 1 — Pallas kernels (build-time Python, `python/compile/kernels/`)
//! * Layer 2 — JAX model lowered to HLO artifacts (`python/compile/model.py`)
//! * Layer 3 — this crate: the serving coordinator, the quantized KV-cache
//!   manager, and the fused dequantize-GEMV kernels that are the paper's
//!   hardware contribution.
//!
//! Python never runs on the request path: `make artifacts` lowers the model
//! once to `artifacts/*.hlo.txt`, and the Rust binary loads them via PJRT.
//!
//! See `src/ARCHITECTURE.md` for the module map and a request's life-cycle
//! walkthrough, and `kernels/DESIGN.md` for the kernel layout/blocking
//! rationale.

// The public serving surface (coordinator, cache, workload, util) is fully
// documented; modules still awaiting their rustdoc pass opt out explicitly
// below — shrink that list as passes land, don't grow it.
#![warn(missing_docs)]

pub mod util;
pub mod cache;
pub mod kernels;
pub mod coordinator;
pub mod eval;
#[allow(missing_docs)]
pub mod exp;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod server;
pub mod simulator;
pub mod workload;

pub use quant::QuantMethod;
