//! InnerQ: hardware-aware, tuning-free KV-cache quantization for LLM serving.
//!
//! This crate is the Layer-3 (coordinator + native hot path) of a three-layer
//! reproduction of the InnerQ paper:
//!
//! * Layer 1 — Pallas kernels (build-time Python, `python/compile/kernels/`)
//! * Layer 2 — JAX model lowered to HLO artifacts (`python/compile/model.py`)
//! * Layer 3 — this crate: the serving coordinator, the quantized KV-cache
//!   manager, and the fused dequantize-GEMV kernels that are the paper's
//!   hardware contribution.
//!
//! Python never runs on the request path: `make artifacts` lowers the model
//! once to `artifacts/*.hlo.txt`, and the Rust binary loads them via PJRT.
//!
//! See `src/ARCHITECTURE.md` for the module map and a request's life-cycle
//! walkthrough, and `kernels/DESIGN.md` for the kernel layout/blocking
//! rationale.

// Every public module is documented; the warn applies crate-wide with no
// opt-outs left. Keep it that way — new public items ship with rustdoc.
#![warn(missing_docs)]

pub mod util;
pub mod cache;
pub mod kernels;
pub mod coordinator;
pub mod eval;
pub mod exp;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod server;
pub mod simulator;
pub mod workload;

pub use quant::QuantMethod;
