//! InnerQ: hardware-aware, tuning-free KV-cache quantization for LLM serving.
//!
//! This crate is the Layer-3 (coordinator + native hot path) of a three-layer
//! reproduction of the InnerQ paper:
//!
//! * Layer 1 — Pallas kernels (build-time Python, `python/compile/kernels/`)
//! * Layer 2 — JAX model lowered to HLO artifacts (`python/compile/model.py`)
//! * Layer 3 — this crate: the serving coordinator, the quantized KV-cache
//!   manager, and the fused dequantize-GEMV kernels that are the paper's
//!   hardware contribution.
//!
//! Python never runs on the request path: `make artifacts` lowers the model
//! once to `artifacts/*.hlo.txt`, and the Rust binary loads them via PJRT.

pub mod util;
pub mod cache;
pub mod kernels;
pub mod coordinator;
pub mod eval;
pub mod exp;
pub mod quant;
pub mod runtime;
pub mod server;
pub mod simulator;
pub mod workload;

pub use quant::QuantMethod;
