//! InnerQ CLI — the leader entrypoint.
//!
//! ```text
//! innerq serve       [--method M] [--addr HOST:PORT] [--artifacts DIR] [--workers N]
//!                    [--replicas N] [--router round-robin|least-loaded|affinity]
//!                    [--io-workers N] [--admin-port PORT] [--trace-out PATH]
//!                    [--budget BYTES] [--policy fifo|slo]
//!                    [--preemption recompute|offload] [--warm-budget BYTES]
//!                    [--pipeline barrier|overlap] [--isa auto|scalar|avx2|avx512|neon]
//!                    [--prefix-share on|off] [--prefix-budget BYTES]
//! innerq generate    --prompt "a=13;?a=" [--method M] [--max-new N] [--workers N]
//!                    [--pipeline barrier|overlap] [--isa auto|scalar|avx2|avx512|neon]
//!                    [--trace-out PATH]
//! innerq serve-trace [--trace timed|multi-turn] [--sessions N]
//!                    [--replicas N] [--router round-robin|least-loaded|affinity]
//!                    [--arrival poisson|bursty|ramp|batch] [--rate R] [--requests N]
//!                    [--seed S] [--budget BYTES] [--policy fifo|slo] [--workers N]
//!                    [--preemption recompute|offload] [--warm-budget BYTES]
//!                    [--pipeline barrier|overlap] [--isa auto|scalar|avx2|avx512|neon]
//!                    [--prefix-share on|off] [--prefix-budget BYTES]
//!                    [--method M] [--interactive FRAC] [--deadline-ms D]
//!                    [--cost-model PATH] [--json PATH] [--trace-out PATH] [--fake]
//! innerq exp         table1|table2|table3|table7|fig5|msparsity|simulate|all
//! innerq info        [--artifacts DIR]
//! ```
//!
//! `--trace-out PATH` arms the wall-clock tracing plane (`innerq::obs`) for
//! the whole run and writes a Chrome trace-event JSON file (loadable in
//! `chrome://tracing` / Perfetto) on exit. Tracing never changes output
//! bytes; a live server can also be traced ad hoc via the admin `trace
//! <secs>` command without this flag.
//!
//! `--isa` pins the dispatch arm of the fused dequant-GEMV kernels (default
//! `auto`: the widest arm the host supports — AVX-512/AVX2 on x86_64, NEON
//! on aarch64). Every arm is bit-identical, so this only changes throughput;
//! the `INNERQ_ISA` env var does the same for test binaries. An unsupported
//! arm is an error listing what the host does support.
//!
//! `--workers N` sizes the decode-attention worker pool (default 1 = the
//! serial baseline; the driver thread counts as one worker).
//!
//! `--replicas N` (default 1) runs N full data-parallel scheduler replicas
//! — each with its own engine, worker pool, cache budget, warm tier, and
//! prefix store — behind a `--router` policy (default `affinity`): requests
//! land where their offload snapshot or shared-prefix bytes already live,
//! falling back to least-loaded (`coordinator::fleet`). For `serve-trace`
//! this switches to the fleet replay harness with per-replica virtual
//! clocks.
//!
//! `--pipeline overlap` (the default) runs each decode step as one task
//! graph of fused append+attend jobs chained between driver-only PJRT
//! stages; `barrier` retains the phase-barriered loop as the bit-exactness
//! oracle — both produce byte-identical results at any worker count.
//!
//! `--preemption offload` parks preemption victims' quantized caches in the
//! segcache-style warm tier (`cache::store`) and restores them on
//! readmission instead of re-prefilling (default: recompute, which discards
//! them); `--warm-budget` sizes that tier (default 8x the cache budget).
//!
//! `--prefix-share off` disables the content-addressed copy-on-write prefix
//! store (default: on), under which requests that declare a shared prompt
//! prefix borrow one immutable quantized image per (layer, head) instead of
//! requantizing it — admission then charges only the private suffix, which
//! is what raises concurrency at a fixed `--budget`. `--prefix-budget`
//! sizes the store (default: the cache budget); sharing never changes
//! output bytes, only accounting. The `--trace multi-turn` family (with
//! `--sessions N`) generates the chat-style workload this pays off on.
//!
//! `serve-trace` replays a timed synthetic trace through the scheduler on a
//! virtual clock and prints p50/p90/p99 TTFT and end-to-end latency — the
//! overload harness (see `workload::replay`). With `--fake` (or when the
//! artifacts directory is missing) it runs against the synthetic fake-model
//! artifacts, so it works without `make artifacts`.
//!
//! (clap is not in the offline vendor set; flags are parsed by hand.)

use anyhow::{anyhow, Result};
use innerq::coordinator::{PipelineMode, Policy, Preemption, Request, Scheduler};
use innerq::runtime::Manifest;
use innerq::workload::replay::{replay, replay_fleet, CostModel};
use innerq::workload::trace::{
    generate_multi_turn, generate_timed, Arrival, MultiTurnTraceConfig, TimedTraceConfig,
};
use innerq::{exp, QuantMethod};

struct Args {
    cmd: String,
    flags: std::collections::HashMap<String, String>,
}

fn parse_args() -> Args {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().cloned().unwrap_or_else(|| "help".into());
    let mut flags = std::collections::HashMap::new();
    let mut i = 1;
    // `exp <name>` positional
    if cmd == "exp" && argv.len() > 1 && !argv[1].starts_with("--") {
        flags.insert("name".to_string(), argv[1].clone());
        i = 2;
    }
    while i < argv.len() {
        if let Some(key) = argv[i].strip_prefix("--") {
            // A following "--flag" is the next flag, not this one's value,
            // so boolean flags like `--fake` compose with anything.
            let val = match argv.get(i + 1) {
                Some(v) if !v.starts_with("--") => {
                    i += 1;
                    v.clone()
                }
                _ => String::new(),
            };
            flags.insert(key.to_string(), val);
        }
        i += 1;
    }
    Args { cmd, flags }
}

impl Args {
    fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }
    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

fn load_manifest(args: &Args) -> Result<Manifest> {
    Manifest::load(args.get("artifacts", "artifacts"))
}

fn method(args: &Args) -> Result<QuantMethod> {
    let name = args.get("method", "innerq_base");
    QuantMethod::parse(&name).ok_or_else(|| {
        anyhow!(
            "unknown method '{name}'; one of: {}",
            QuantMethod::ALL.map(|m| m.name()).join(", ")
        )
    })
}

fn policy(args: &Args) -> Result<Policy> {
    let name = args.get("policy", "fifo");
    Policy::parse(&name).ok_or_else(|| anyhow!("unknown policy '{name}'; one of: fifo, slo"))
}

fn preemption(args: &Args) -> Result<Preemption> {
    let name = args.get("preemption", "recompute");
    Preemption::parse(&name)
        .ok_or_else(|| anyhow!("unknown preemption mode '{name}'; one of: recompute, offload"))
}

fn pipeline(args: &Args) -> Result<PipelineMode> {
    let name = args.get("pipeline", "overlap");
    PipelineMode::parse(&name)
        .ok_or_else(|| anyhow!("unknown pipeline mode '{name}'; one of: barrier, overlap"))
}

/// `--replicas N` (default 1): how many data-parallel scheduler replicas to
/// run behind the router.
fn replicas_flag(args: &Args) -> Result<usize> {
    let n: usize = args.get("replicas", "1").parse()?;
    if n == 0 {
        return Err(anyhow!("--replicas must be >= 1"));
    }
    Ok(n)
}

/// `--router NAME` (default affinity — with one replica every policy places
/// identically, so the default only matters at `--replicas >= 2`).
fn router_flag(name: &str) -> Result<Box<dyn innerq::coordinator::RouterPolicy + Send>> {
    innerq::coordinator::parse_router(name).ok_or_else(|| {
        anyhow!("unknown router '{name}'; one of: round-robin, least-loaded, affinity")
    })
}

/// Apply `--isa` (kernel dispatch-arm override) and return the arm that is
/// now active, for the startup banner. `--isa auto` (or no flag) keeps
/// runtime detection / `INNERQ_ISA`.
fn apply_isa(args: &Args) -> Result<innerq::kernels::dispatch::Isa> {
    use innerq::kernels::dispatch;
    if args.has("isa") {
        let sel = dispatch::Isa::parse(&args.get("isa", "auto")).map_err(|e| anyhow!(e))?;
        dispatch::set_active(sel).map_err(|e| anyhow!(e))?;
    }
    Ok(dispatch::active())
}

/// Apply the shared scheduling flags (`--policy`, `--preemption`,
/// `--warm-budget`, `--pipeline`, `--prefix-share`, `--prefix-budget`) to a
/// freshly built scheduler.
fn configure_sched(sched: &mut Scheduler, args: &Args) -> Result<()> {
    sched.set_policy(policy(args)?);
    sched.set_preemption(preemption(args)?);
    sched.set_pipeline(pipeline(args)?);
    if args.has("warm-budget") {
        sched.set_warm_budget(args.get("warm-budget", "0").parse()?);
    }
    if args.has("prefix-share") {
        match args.get("prefix-share", "on").as_str() {
            "on" | "" | "true" => sched.set_prefix_share(true),
            "off" | "false" => sched.set_prefix_share(false),
            other => return Err(anyhow!("--prefix-share takes on|off, got '{other}'")),
        }
    }
    // Must come after any share toggle: replacing the store drops whatever
    // images (there are none before serving) the old one held.
    if args.has("prefix-budget") {
        sched.set_prefix_budget(args.get("prefix-budget", "0").parse()?);
    }
    Ok(())
}

/// Arm process-lifetime tracing when `--trace-out PATH` is present. The
/// returned guard must stay alive until [`write_trace_out`] has drained the
/// recorder, so ring events cannot race a disabled plane.
fn trace_out_guard(args: &Args) -> Result<Option<(innerq::obs::TraceGuard, String)>> {
    if !args.has("trace-out") {
        return Ok(None);
    }
    let path = args.get("trace-out", "");
    if path.is_empty() {
        return Err(anyhow!("--trace-out needs a file path"));
    }
    Ok(Some((innerq::obs::TraceGuard::arm(), path)))
}

/// Drain everything the run recorded and write it as Chrome trace JSON.
fn write_trace_out(
    recorder: &std::sync::Mutex<innerq::obs::recorder::Recorder>,
    path: &str,
) -> Result<()> {
    let mut rec = recorder.lock().unwrap_or_else(|e| e.into_inner());
    rec.drain();
    let n = rec.len();
    std::fs::write(path, rec.chrome_trace(None).dump())?;
    eprintln!("[trace] wrote {n} spans to {path}");
    Ok(())
}

/// Build the replay scheduler for `serve-trace`: real artifacts when
/// available, the synthetic fake model under `--fake` or as a fallback.
fn trace_scheduler(args: &Args, budget: usize, workers: usize) -> Result<Scheduler> {
    let m = method(args)?;
    let manifest = if args.has("fake") {
        None
    } else {
        match load_manifest(args) {
            Ok(man) => Some(man),
            Err(e) => {
                eprintln!(
                    "[serve-trace] artifacts not loadable ({e}); falling back to the fake model \
                     (pass --artifacts DIR for the real one)"
                );
                None
            }
        }
    };
    let manifest = match manifest {
        Some(man) => man,
        None => {
            let dir = innerq::util::fakemodel::write_fake_artifacts("serve_trace", '7');
            Manifest::load(&dir)?
        }
    };
    let mut engine = innerq::coordinator::Engine::new(manifest, m.config())?;
    engine.set_workers(workers);
    let mut sched = Scheduler::new(engine, budget);
    configure_sched(&mut sched, args)?;
    Ok(sched)
}

fn main() -> Result<()> {
    let args = parse_args();
    match args.cmd.as_str() {
        "serve" => {
            let isa = apply_isa(&args)?;
            let traced = trace_out_guard(&args)?;
            let manifest = load_manifest(&args)?;
            let m = method(&args)?;
            let workers: usize = args.get("workers", "1").parse()?;
            let budget: usize = args.get("budget", &(1usize << 30).to_string()).parse()?;
            let n_replicas = replicas_flag(&args)?;
            let router_name = args.get("router", "affinity");
            let router = router_flag(&router_name)?;
            eprintln!("[serve] loading {} stages ...", manifest.artifacts.len());
            // Data-parallel replicas: each gets its own engine (same
            // artifacts), worker pool, cache budget, warm tier, and prefix
            // store; the router places each request on exactly one.
            let mut replicas = Vec::with_capacity(n_replicas);
            for _ in 0..n_replicas {
                let mut engine =
                    innerq::coordinator::Engine::new(manifest.clone(), m.config())?;
                engine.set_workers(workers);
                let mut sched = Scheduler::new(engine, budget);
                configure_sched(&mut sched, &args)?;
                replicas.push(sched);
            }
            let fleet = innerq::coordinator::Fleet::new(replicas, router);
            let addr = args.get("addr", "127.0.0.1:7071");
            // Staged front-end shape: N IO workers polling non-blocking
            // sockets, plus an optional admin/metrics listener on its own
            // port (same host as --addr).
            let io_workers: usize = args.get("io-workers", "2").parse()?;
            let admin_port = args.get("admin-port", "");
            let admin_addr = if admin_port.is_empty() {
                None
            } else {
                let host = addr.rsplit_once(':').map(|(h, _)| h).unwrap_or("127.0.0.1");
                Some(format!("{host}:{admin_port}"))
            };
            eprintln!(
                "[serve] method={} addr={addr} replicas={n_replicas} router={} \
                 workers={workers} io-workers={io_workers} policy={:?} preemption={} \
                 pipeline={} isa={isa}",
                m.name(),
                fleet.router_name(),
                fleet.replica(0).policy(),
                fleet.replica(0).preemption().name(),
                fleet.replica(0).engine.pipeline().name()
            );
            let recorder = fleet.replica(0).obs.clone();
            innerq::server::serve_fleet(
                fleet,
                &addr,
                innerq::server::ServerConfig { io_workers, admin_addr },
                std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false)),
                |b| {
                    eprintln!("[serve] listening on {}", b.data);
                    if let Some(a) = b.admin {
                        eprintln!("[serve] admin stats on {a}");
                    }
                },
            )?;
            if let Some((guard, path)) = traced {
                write_trace_out(&recorder, &path)?;
                drop(guard);
            }
            Ok(())
        }
        "generate" => {
            let isa = apply_isa(&args)?;
            let traced = trace_out_guard(&args)?;
            let manifest = load_manifest(&args)?;
            let m = method(&args)?;
            let prompt = args.get("prompt", "a=13;b=88;?a=");
            let max_new: usize = args.get("max-new", "16").parse()?;
            let workers: usize = args.get("workers", "1").parse()?;
            let mut engine = innerq::coordinator::Engine::new(manifest, m.config())?;
            engine.set_workers(workers);
            engine.set_pipeline(pipeline(&args)?);
            let mut sched = Scheduler::new(engine, 1 << 30);
            sched.submit(Request::new(0, &prompt, max_new));
            let done = sched.run_to_completion()?;
            let c = &done[0];
            println!("{prompt}{}", c.text);
            eprintln!(
                "[generate] method={} isa={isa} ttft={}us total={}us tokens={}",
                m.name(),
                c.ttft_us,
                c.total_us,
                c.n_generated
            );
            if let Some((guard, path)) = traced {
                write_trace_out(&sched.obs, &path)?;
                drop(guard);
            }
            Ok(())
        }
        "serve-trace" => {
            let isa = apply_isa(&args)?;
            let traced = trace_out_guard(&args)?;
            let rate: f64 = args.get("rate", "200").parse()?;
            let arrival_name = args.get("arrival", "poisson");
            let arrival = Arrival::parse(&arrival_name, rate)
                .ok_or_else(|| anyhow!("unknown arrival process '{arrival_name}'"))?;
            let n_requests: usize = args.get("requests", "64").parse()?;
            let seed: u64 = args.get("seed", "7").parse()?;
            let workers: usize = args.get("workers", "1").parse()?;
            let budget: usize = args.get("budget", &(1usize << 20).to_string()).parse()?;
            // Priority mix: --interactive FRAC of requests are interactive
            // (the rest standard), with an optional per-request deadline.
            let interactive: f64 = args.get("interactive", "0").parse()?;
            let deadline_ms: f64 = args.get("deadline-ms", "0").parse()?;
            let deadline = (deadline_ms > 0.0).then(|| (deadline_ms * 1e3) as u64);
            let cfg = TimedTraceConfig {
                n_requests,
                arrival,
                priority_mix: [interactive.clamp(0.0, 1.0), 1.0 - interactive.clamp(0.0, 1.0), 0.0],
                deadlines_us: [deadline, deadline, deadline],
                seed,
                ..TimedTraceConfig::default()
            };
            // Trace family: the default independent-prompt stream, or the
            // chat-style multi-turn family whose sessions share a prefix
            // (the workload the prefix store exists for).
            let family = args.get("trace", "timed");
            let trace = match family.as_str() {
                "timed" => generate_timed(&cfg),
                "multi-turn" => generate_multi_turn(&MultiTurnTraceConfig {
                    base: cfg,
                    n_sessions: args.get("sessions", "4").parse()?,
                    ..MultiTurnTraceConfig::default()
                }),
                other => {
                    return Err(anyhow!(
                        "unknown trace family '{other}'; one of: timed, multi-turn"
                    ))
                }
            };
            let n_replicas = replicas_flag(&args)?;
            let router_name = args.get("router", "affinity");
            // Replay cost coefficients: the built-in defaults, or a
            // calibration file produced by ci/calibrate_cost_model.py from
            // real bench numbers.
            let cost = match args.get("cost-model", "").as_str() {
                "" => CostModel::default(),
                path => CostModel::load(path).map_err(|e| anyhow!("--cost-model {path}: {e}"))?,
            };
            let json_path = args.get("json", "");
            let banner = |sched: &Scheduler| {
                eprintln!(
                    "[serve-trace] trace={family} arrival={} rate={rate} requests={n_requests} \
                     budget={budget} policy={:?} preemption={} workers={workers} seed={seed} \
                     prefix-share={} isa={isa}",
                    arrival.name(),
                    sched.policy(),
                    sched.preemption().name(),
                    if sched.prefix_share() { "on" } else { "off" }
                );
            };
            if n_replicas > 1 {
                // Fleet replay: per-replica virtual clocks behind the
                // router; the report carries per-replica and aggregate
                // numbers (see workload::replay::replay_fleet).
                let mut replicas = Vec::with_capacity(n_replicas);
                for _ in 0..n_replicas {
                    replicas.push(trace_scheduler(&args, budget, workers)?);
                }
                let mut fleet =
                    innerq::coordinator::Fleet::new(replicas, router_flag(&router_name)?);
                banner(fleet.replica(0));
                eprintln!("[serve-trace] fleet: replicas={n_replicas} router={router_name}");
                let report = replay_fleet(&mut fleet, &trace, &cost)?;
                println!("== serve-trace fleet report ==");
                report.print_summary();
                if !json_path.is_empty() {
                    std::fs::write(&json_path, report.to_json().dump())?;
                    eprintln!("[serve-trace] wrote {json_path}");
                }
                if let Some((guard, path)) = traced {
                    write_trace_out(&fleet.replica(0).obs, &path)?;
                    drop(guard);
                }
                return Ok(());
            }
            let mut sched = trace_scheduler(&args, budget, workers)?;
            banner(&sched);
            let report = replay(&mut sched, &trace, &cost)?;
            if report.metrics.prefix_hits > 0 {
                eprintln!(
                    "[serve-trace] prefix store: {} hits, {} KiB borrowed instead of requantized",
                    report.metrics.prefix_hits,
                    report.metrics.prefix_bytes_shared / 1024
                );
            }
            println!("== serve-trace report ==");
            report.print_summary();
            if !json_path.is_empty() {
                std::fs::write(&json_path, report.to_json().dump())?;
                eprintln!("[serve-trace] wrote {json_path}");
            }
            if let Some((guard, path)) = traced {
                write_trace_out(&sched.obs, &path)?;
                drop(guard);
            }
            Ok(())
        }
        "exp" => {
            let name = args.get("name", "all");
            let needs_model = !matches!(name.as_str(), "table3" | "simulate");
            let manifest = if needs_model { Some(load_manifest(&args)?) } else { None };
            match name.as_str() {
                "table1" => {
                    exp::table1(manifest.as_ref().unwrap())?;
                }
                "table2" => {
                    exp::table2(manifest.as_ref().unwrap())?;
                }
                "table3" => exp::table3(),
                "table7" => exp::table7(manifest.as_ref().unwrap())?,
                "fig5" => exp::fig5(manifest.as_ref().unwrap())?,
                "msparsity" => exp::msparsity(manifest.as_ref().unwrap())?,
                "simulate" => exp::simulate(),
                "all" => {
                    exp::table3();
                    exp::simulate();
                    let m = manifest.as_ref().unwrap();
                    exp::table1(m)?;
                    exp::table7(m)?;
                    exp::msparsity(m)?;
                    exp::fig5(m)?;
                    exp::table2(m)?;
                }
                other => return Err(anyhow!("unknown experiment '{other}'")),
            }
            Ok(())
        }
        "info" => {
            let manifest = load_manifest(&args)?;
            println!("model: {:?}", manifest.model);
            println!("charset: {:?}", manifest.charset);
            println!("decode batches: {:?}", manifest.decode_batches);
            println!("prefill buckets: {:?}", manifest.prefill_buckets);
            println!("artifacts: {}", manifest.artifacts.len());
            println!("final train loss: {:.4}", manifest.final_train_loss);
            Ok(())
        }
        _ => {
            eprintln!(
                "usage: innerq <serve|generate|serve-trace|exp|info> [flags]\n\
                 \n  serve       --method M --addr HOST:PORT --artifacts DIR --workers N\
                 \n              --replicas N --router round-robin|least-loaded|affinity\
                 \n              --io-workers N --admin-port PORT --trace-out PATH\
                 \n              --budget BYTES --policy fifo|slo\
                 \n              --preemption recompute|offload --warm-budget BYTES\
                 \n              --pipeline barrier|overlap --isa auto|scalar|avx2|avx512|neon\
                 \n              --prefix-share on|off --prefix-budget BYTES\
                 \n  generate    --prompt S --method M --max-new N --workers N\
                 \n              --pipeline barrier|overlap --isa auto|scalar|avx2|avx512|neon\
                 \n              --trace-out PATH\
                 \n  serve-trace --trace timed|multi-turn --sessions N\
                 \n              --replicas N --router round-robin|least-loaded|affinity\
                 \n              --arrival poisson|bursty|ramp|batch --rate R --requests N\
                 \n              --seed S --budget BYTES --policy fifo|slo --workers N\
                 \n              --preemption recompute|offload --warm-budget BYTES\
                 \n              --pipeline barrier|overlap --isa auto|scalar|avx2|avx512|neon\
                 \n              --prefix-share on|off --prefix-budget BYTES\
                 \n              --interactive FRAC --deadline-ms D --cost-model PATH\
                 \n              --json PATH --trace-out PATH --fake\
                 \n  exp         table1|table2|table3|table7|fig5|msparsity|simulate|all\
                 \n  info        --artifacts DIR\n\
                 \nmethods: {}",
                QuantMethod::ALL.map(|m| m.name()).join(", ")
            );
            Ok(())
        }
    }
}
