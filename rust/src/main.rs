//! InnerQ CLI — the leader entrypoint.
//!
//! ```text
//! innerq serve   [--method M] [--addr HOST:PORT] [--artifacts DIR] [--workers N]
//! innerq generate --prompt "a=13;?a=" [--method M] [--max-new N] [--workers N]
//! innerq exp      table1|table2|table3|table7|fig5|msparsity|simulate|all
//! innerq info     [--artifacts DIR]
//! ```
//!
//! `--workers N` sizes the decode-attention worker pool (default 1 = the
//! serial baseline; the driver thread counts as one worker).
//!
//! (clap is not in the offline vendor set; flags are parsed by hand.)

use anyhow::{anyhow, Result};
use innerq::coordinator::{Request, Scheduler};
use innerq::runtime::Manifest;
use innerq::{exp, QuantMethod};
use std::time::Instant;

struct Args {
    cmd: String,
    flags: std::collections::HashMap<String, String>,
}

fn parse_args() -> Args {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().cloned().unwrap_or_else(|| "help".into());
    let mut flags = std::collections::HashMap::new();
    let mut i = 1;
    // `exp <name>` positional
    if cmd == "exp" && argv.len() > 1 && !argv[1].starts_with("--") {
        flags.insert("name".to_string(), argv[1].clone());
        i = 2;
    }
    while i < argv.len() {
        if let Some(key) = argv[i].strip_prefix("--") {
            let val = argv.get(i + 1).cloned().unwrap_or_default();
            flags.insert(key.to_string(), val);
            i += 2;
        } else {
            i += 1;
        }
    }
    Args { cmd, flags }
}

impl Args {
    fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }
}

fn load_manifest(args: &Args) -> Result<Manifest> {
    Manifest::load(args.get("artifacts", "artifacts"))
}

fn method(args: &Args) -> Result<QuantMethod> {
    let name = args.get("method", "innerq_base");
    QuantMethod::parse(&name).ok_or_else(|| {
        anyhow!(
            "unknown method '{name}'; one of: {}",
            QuantMethod::ALL.map(|m| m.name()).join(", ")
        )
    })
}

fn main() -> Result<()> {
    let args = parse_args();
    match args.cmd.as_str() {
        "serve" => {
            let manifest = load_manifest(&args)?;
            let m = method(&args)?;
            let workers: usize = args.get("workers", "1").parse()?;
            eprintln!("[serve] loading {} stages ...", manifest.artifacts.len());
            let mut engine = innerq::coordinator::Engine::new(manifest, m.config())?;
            engine.set_workers(workers);
            let sched = Scheduler::new(engine, 1 << 30);
            let addr = args.get("addr", "127.0.0.1:7071");
            eprintln!("[serve] method={} addr={addr} workers={workers}", m.name());
            innerq::server::serve(
                sched,
                &addr,
                std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false)),
                |a| eprintln!("[serve] listening on {a}"),
            )
        }
        "generate" => {
            let manifest = load_manifest(&args)?;
            let m = method(&args)?;
            let prompt = args.get("prompt", "a=13;b=88;?a=");
            let max_new: usize = args.get("max-new", "16").parse()?;
            let workers: usize = args.get("workers", "1").parse()?;
            let mut engine = innerq::coordinator::Engine::new(manifest, m.config())?;
            engine.set_workers(workers);
            let mut sched = Scheduler::new(engine, 1 << 30);
            sched.submit(Request {
                id: 0,
                prompt: prompt.clone(),
                max_new_tokens: max_new,
                temperature: None,
                arrived: Instant::now(),
            });
            let done = sched.run_to_completion()?;
            let c = &done[0];
            println!("{prompt}{}", c.text);
            eprintln!(
                "[generate] method={} ttft={}us total={}us tokens={}",
                m.name(),
                c.ttft_us,
                c.total_us,
                c.n_generated
            );
            Ok(())
        }
        "exp" => {
            let name = args.get("name", "all");
            let needs_model = !matches!(name.as_str(), "table3" | "simulate");
            let manifest = if needs_model { Some(load_manifest(&args)?) } else { None };
            match name.as_str() {
                "table1" => {
                    exp::table1(manifest.as_ref().unwrap())?;
                }
                "table2" => {
                    exp::table2(manifest.as_ref().unwrap())?;
                }
                "table3" => exp::table3(),
                "table7" => exp::table7(manifest.as_ref().unwrap())?,
                "fig5" => exp::fig5(manifest.as_ref().unwrap())?,
                "msparsity" => exp::msparsity(manifest.as_ref().unwrap())?,
                "simulate" => exp::simulate(),
                "all" => {
                    exp::table3();
                    exp::simulate();
                    let m = manifest.as_ref().unwrap();
                    exp::table1(m)?;
                    exp::table7(m)?;
                    exp::msparsity(m)?;
                    exp::fig5(m)?;
                    exp::table2(m)?;
                }
                other => return Err(anyhow!("unknown experiment '{other}'")),
            }
            Ok(())
        }
        "info" => {
            let manifest = load_manifest(&args)?;
            println!("model: {:?}", manifest.model);
            println!("charset: {:?}", manifest.charset);
            println!("decode batches: {:?}", manifest.decode_batches);
            println!("prefill buckets: {:?}", manifest.prefill_buckets);
            println!("artifacts: {}", manifest.artifacts.len());
            println!("final train loss: {:.4}", manifest.final_train_loss);
            Ok(())
        }
        _ => {
            eprintln!(
                "usage: innerq <serve|generate|exp|info> [flags]\n\
                 \n  serve    --method M --addr HOST:PORT --artifacts DIR --workers N\
                 \n  generate --prompt S --method M --max-new N --workers N\
                 \n  exp      table1|table2|table3|table7|fig5|msparsity|simulate|all\
                 \n  info     --artifacts DIR\n\
                 \nmethods: {}",
                QuantMethod::ALL.map(|m| m.name()).join(", ")
            );
            Ok(())
        }
    }
}
