//! Serving request traces over corpus prompts.
//!
//! Two generators live here:
//!
//! * [`generate`] — the legacy fixed batch (no arrival times), kept for the
//!   serving examples;
//! * [`generate_timed`] — timed traces for the overload harness
//!   ([`crate::workload::replay`]): Poisson / bursty / ramp arrival
//!   processes, heavy-tailed prompt and output length mixes, and a
//!   per-request priority class + deadline, all deterministic per seed.
//!
//! Arrival timestamps are *virtual microseconds*; the replay driver feeds
//! them to the scheduler on its virtual clock, so a trace replays
//! identically regardless of wall-clock speed or worker count.

use crate::coordinator::request::{Priority, Request};
use crate::util::rng::Rng;
use crate::workload::corpus::CorpusGen;

/// Configuration of the legacy fixed-batch trace ([`generate`]).
#[derive(Debug, Clone, Copy)]
pub struct TraceConfig {
    /// Number of requests.
    pub n_requests: usize,
    /// Variables per document (controls prompt length).
    pub n_vars: usize,
    /// Recall queries appended per document.
    pub n_queries: usize,
    /// Generation budget per request.
    pub max_new_tokens: usize,
    /// Trace seed (prompts are deterministic per seed).
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig { n_requests: 16, n_vars: 24, n_queries: 4, max_new_tokens: 48, seed: 7 }
    }
}

/// Generate a request trace. Prompts end right after a '?name=' query stem so
/// the served generation must recall from the cache.
pub fn generate(cfg: TraceConfig) -> Vec<Request> {
    let mut rng = Rng::new(cfg.seed);
    let mut gen = CorpusGen::new(cfg.seed ^ 0xabcd);
    (0..cfg.n_requests)
        .map(|i| {
            let doc = gen.document(cfg.n_vars, cfg.n_queries);
            // cut at the first query stem: "...;?x="
            let cut = doc.text.find('?').map(|p| p + 3).unwrap_or(doc.text.len());
            let _ = rng.next_u64();
            Request::new(i as u64, &doc.text[..cut], cfg.max_new_tokens)
        })
        .collect()
}

/// Arrival process of a timed trace, in requests per *virtual* second.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Arrival {
    /// Everything arrives at t = 0 (the legacy closed batch).
    Batch,
    /// Memoryless arrivals: exponential inter-arrival gaps at `rate_rps`.
    Poisson {
        /// Mean arrival rate, requests per virtual second.
        rate_rps: f64,
    },
    /// Bursts of `burst` simultaneous arrivals; burst instants are Poisson
    /// at `rate_rps / burst`, so the long-run rate still equals `rate_rps`.
    Bursty {
        /// Mean arrival rate, requests per virtual second.
        rate_rps: f64,
        /// Requests arriving together at each burst instant.
        burst: usize,
    },
    /// Rate ramps linearly from `start_rps` to `end_rps` across the trace —
    /// the overload shape: the tail of the trace arrives faster than the
    /// system drains.
    Ramp {
        /// Arrival rate at the first request.
        start_rps: f64,
        /// Arrival rate at the last request.
        end_rps: f64,
    },
}

impl Arrival {
    /// Parse a CLI arrival spec: a process name plus the `--rate` value
    /// (`ramp` reads `rate` as the *end* rate, starting from a tenth of it;
    /// `bursty` uses bursts of 8).
    pub fn parse(name: &str, rate_rps: f64) -> Option<Arrival> {
        match name {
            "batch" => Some(Arrival::Batch),
            "poisson" => Some(Arrival::Poisson { rate_rps }),
            "bursty" => Some(Arrival::Bursty { rate_rps, burst: 8 }),
            "ramp" => Some(Arrival::Ramp { start_rps: rate_rps / 10.0, end_rps: rate_rps }),
            _ => None,
        }
    }

    /// Stable name for reports.
    pub fn name(&self) -> &'static str {
        match self {
            Arrival::Batch => "batch",
            Arrival::Poisson { .. } => "poisson",
            Arrival::Bursty { .. } => "bursty",
            Arrival::Ramp { .. } => "ramp",
        }
    }
}

/// One request plus its virtual arrival time.
#[derive(Debug, Clone)]
pub struct TimedRequest {
    /// Virtual arrival timestamp in microseconds (nondecreasing).
    pub arrival_us: u64,
    /// The request itself (priority and deadline already set).
    pub req: Request,
}

/// Configuration of a timed overload trace ([`generate_timed`]).
#[derive(Debug, Clone, Copy)]
pub struct TimedTraceConfig {
    /// Number of requests.
    pub n_requests: usize,
    /// Arrival process over virtual time.
    pub arrival: Arrival,
    /// Uniform range of variables per prompt (controls prompt length; one
    /// assignment is ~5 characters).
    pub vars_range: (usize, usize),
    /// Recall queries per document (the prompt is cut at the first).
    pub n_queries: usize,
    /// Uniform range of the per-request generation budget.
    pub max_new_range: (usize, usize),
    /// Probability that a request is a heavy-tail outlier: its prompt vars
    /// double (capped at `vars_cap`) and its generation budget quadruples
    /// (capped at `max_new_cap`). 0 disables the tail.
    pub tail_prob: f64,
    /// Prompt-size cap for tail outliers.
    pub vars_cap: usize,
    /// Generation-budget cap for tail outliers.
    pub max_new_cap: usize,
    /// Sampling weights for [interactive, standard, batch] priority
    /// classes; all-zero means every request is standard.
    pub priority_mix: [f64; 3],
    /// Per-class relative deadline in virtual microseconds
    /// ([interactive, standard, batch]); `None` never expires.
    pub deadlines_us: [Option<u64>; 3],
    /// Trace seed: prompts, lengths, classes, and arrival gaps are all
    /// deterministic functions of it.
    pub seed: u64,
}

impl Default for TimedTraceConfig {
    fn default() -> Self {
        TimedTraceConfig {
            n_requests: 64,
            arrival: Arrival::Poisson { rate_rps: 100.0 },
            vars_range: (4, 16),
            n_queries: 1,
            max_new_range: (8, 32),
            tail_prob: 0.1,
            vars_cap: 20,
            max_new_cap: 96,
            priority_mix: [0.0, 1.0, 0.0],
            deadlines_us: [None, None, None],
            seed: 7,
        }
    }
}

/// Inter-arrival gap before request `i` of `n` under `arrival`, in virtual
/// microseconds (shared by every timed trace family).
fn arrival_gap_us(rng: &mut Rng, arrival: Arrival, i: usize, n: usize) -> u64 {
    match arrival {
        Arrival::Batch => 0,
        Arrival::Poisson { rate_rps } => exp_gap_us(rng, rate_rps),
        Arrival::Bursty { rate_rps, burst } => {
            let burst = burst.max(1);
            if i % burst == 0 {
                exp_gap_us(rng, rate_rps / burst as f64)
            } else {
                0
            }
        }
        Arrival::Ramp { start_rps, end_rps } => {
            let f = if n > 1 { i as f64 / (n - 1) as f64 } else { 0.0 };
            exp_gap_us(rng, start_rps + (end_rps - start_rps) * f)
        }
    }
}

/// Exponential inter-arrival gap at `rate_rps`, in virtual microseconds.
fn exp_gap_us(rng: &mut Rng, rate_rps: f64) -> u64 {
    if rate_rps <= 0.0 {
        return 0;
    }
    // u in [0,1); 1-u in (0,1] keeps ln finite.
    let u = rng.next_f64();
    (-(1.0 - u).ln() / rate_rps * 1e6).round() as u64
}

fn uniform_in(rng: &mut Rng, (lo, hi): (usize, usize)) -> usize {
    if hi <= lo {
        return lo;
    }
    lo + rng.next_range(hi - lo + 1)
}

fn sample_priority(rng: &mut Rng, mix: &[f64; 3]) -> Priority {
    let total: f64 = mix.iter().filter(|w| w.is_finite() && **w > 0.0).sum();
    if total <= 0.0 {
        return Priority::Standard;
    }
    let mut u = rng.next_f64() * total;
    for (w, p) in mix.iter().zip(Priority::ALL) {
        if w.is_finite() && *w > 0.0 {
            u -= w;
            if u <= 0.0 {
                return p;
            }
        }
    }
    Priority::Batch
}

/// Generate a timed trace: deterministic per seed, arrivals nondecreasing.
///
/// The three random streams (arrival gaps, request shapes, corpus text) are
/// seeded independently so changing e.g. the arrival process does not
/// reshuffle the prompts.
pub fn generate_timed(cfg: &TimedTraceConfig) -> Vec<TimedRequest> {
    let mut arrive_rng = Rng::new(cfg.seed ^ 0x00a1_17ee);
    let mut shape_rng = Rng::new(cfg.seed ^ 0x5a5a_0001);
    let mut gen = CorpusGen::new(cfg.seed ^ 0xabcd);
    let n = cfg.n_requests;
    let mut now_us = 0u64;
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        // --- arrival ---
        let gap = arrival_gap_us(&mut arrive_rng, cfg.arrival, i, n);
        now_us = now_us.saturating_add(gap);

        // --- shape: lengths, class, deadline ---
        let mut vars = uniform_in(&mut shape_rng, cfg.vars_range);
        let mut max_new = uniform_in(&mut shape_rng, cfg.max_new_range);
        let is_tail = shape_rng.next_f64() < cfg.tail_prob;
        if is_tail {
            vars = (vars * 2).min(cfg.vars_cap.max(1));
            max_new = (max_new * 4).min(cfg.max_new_cap.max(1));
        }
        let priority = sample_priority(&mut shape_rng, &cfg.priority_mix);
        let deadline_us = cfg.deadlines_us[priority.level() as usize];

        // --- prompt ---
        let doc = gen.document(vars.max(1), cfg.n_queries.max(1));
        let cut = doc.text.find('?').map(|p| p + 3).unwrap_or(doc.text.len());
        let mut req = Request::new(i as u64, &doc.text[..cut], max_new.max(1));
        req.priority = priority;
        req.deadline_us = deadline_us;
        out.push(TimedRequest { arrival_us: now_us, req });
    }
    out
}

/// Configuration of the multi-turn / shared-prefix trace family
/// ([`generate_multi_turn`]): `n_sessions` conversations, each with a fixed
/// session prefix (system prompt + earlier turns) repeated *verbatim* by
/// every one of its requests, followed by a fresh per-turn suffix ending in
/// a recall query. Requests round-robin across sessions, so each session's
/// prefix recurs `~n_requests / n_sessions` times — the workload where a
/// content-addressed prefix store turns duplicated quantization work and
/// duplicated cache bytes into shared ones.
#[derive(Debug, Clone, Copy)]
pub struct MultiTurnTraceConfig {
    /// Base timed-trace shape: arrivals, per-turn suffix length
    /// (`vars_range`), generation budgets, priorities, deadlines, seed.
    pub base: TimedTraceConfig,
    /// Number of distinct sessions (≥ 1).
    pub n_sessions: usize,
    /// Variables in each session's shared prefix (~5 characters each;
    /// controls `Request::prefix_len`).
    pub prefix_vars: usize,
}

impl Default for MultiTurnTraceConfig {
    fn default() -> Self {
        MultiTurnTraceConfig {
            // Short per-turn suffixes: the shared prefix dominates the
            // prompt, as in a chat session with a long system prompt.
            base: TimedTraceConfig { vars_range: (2, 6), ..TimedTraceConfig::default() },
            n_sessions: 4,
            prefix_vars: 10,
        }
    }
}

/// Generate a multi-turn trace. Deterministic per seed; arrivals, shapes,
/// and corpus text use the same independent streams as [`generate_timed`],
/// plus a fourth stream for the session prefixes, so e.g. changing
/// `n_sessions` does not reshuffle arrival times. Every prompt fits the
/// 128-token fake-model prefill bucket; `Request::prefix_len` is set to the
/// session prefix length (tokens == characters under the corpus charset).
pub fn generate_multi_turn(cfg: &MultiTurnTraceConfig) -> Vec<TimedRequest> {
    let base = &cfg.base;
    let mut arrive_rng = Rng::new(base.seed ^ 0x00a1_17ee);
    let mut shape_rng = Rng::new(base.seed ^ 0x5a5a_0001);
    let mut gen = CorpusGen::new(base.seed ^ 0xabcd);
    let mut session_gen = CorpusGen::new(base.seed ^ 0x5e55_10f5);
    let n_sessions = cfg.n_sessions.max(1);
    // A session prefix is assignments only (cut *before* the query stem):
    // the per-turn suffix carries the query, so the prefix is a pure
    // context block every turn extends.
    let prefixes: Vec<String> = (0..n_sessions)
        .map(|_| {
            let doc = session_gen.document(cfg.prefix_vars.max(1), 1);
            let cut = doc.text.find('?').unwrap_or(doc.text.len());
            doc.text[..cut].to_string()
        })
        .collect();
    let n = base.n_requests;
    let mut now_us = 0u64;
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let gap = arrival_gap_us(&mut arrive_rng, base.arrival, i, n);
        now_us = now_us.saturating_add(gap);

        let prefix = &prefixes[i % n_sessions];
        let mut vars = uniform_in(&mut shape_rng, base.vars_range);
        let mut max_new = uniform_in(&mut shape_rng, base.max_new_range);
        if shape_rng.next_f64() < base.tail_prob {
            vars = (vars * 2).min(base.vars_cap.max(1));
            max_new = (max_new * 4).min(base.max_new_cap.max(1));
        }
        // Keep the whole prompt inside the 128-token prefill bucket: one
        // assignment is ~6 characters worst-case, plus the 3-char query
        // stem the cut keeps.
        let budget = 128usize.saturating_sub(prefix.len() + 4);
        vars = vars.clamp(1, (budget / 6).max(1));
        let priority = sample_priority(&mut shape_rng, &base.priority_mix);
        let deadline_us = base.deadlines_us[priority.level() as usize];

        let doc = gen.document(vars, base.n_queries.max(1));
        let cut = doc.text.find('?').map(|p| p + 3).unwrap_or(doc.text.len());
        let prompt = format!("{}{}", prefix, &doc.text[..cut]);
        let mut req = Request::new(i as u64, prompt, max_new.max(1));
        req.prefix_len = prefix.len();
        req.priority = priority;
        req.deadline_us = deadline_us;
        out.push(TimedRequest { arrival_us: now_us, req });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prompts_end_with_query_stem() {
        let reqs = generate(TraceConfig::default());
        assert_eq!(reqs.len(), 16);
        for r in &reqs {
            assert!(r.prompt.contains('='));
            let tail: Vec<char> = r.prompt.chars().rev().take(3).collect();
            assert_eq!(tail[0], '=', "prompt should end at '?x=': {}", r.prompt);
            assert_eq!(tail[2], '?');
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(TraceConfig::default());
        let b = generate(TraceConfig::default());
        assert_eq!(a[3].prompt, b[3].prompt);
    }

    fn timed_key(t: &TimedRequest) -> (u64, u64, String, usize, u8, Option<u64>) {
        (
            t.arrival_us,
            t.req.id,
            t.req.prompt.clone(),
            t.req.max_new_tokens,
            t.req.priority.level(),
            t.req.deadline_us,
        )
    }

    #[test]
    fn timed_trace_is_deterministic_and_monotone() {
        let cfg = TimedTraceConfig::default();
        let a = generate_timed(&cfg);
        let b = generate_timed(&cfg);
        assert_eq!(a.len(), cfg.n_requests);
        assert_eq!(
            a.iter().map(timed_key).collect::<Vec<_>>(),
            b.iter().map(timed_key).collect::<Vec<_>>()
        );
        for w in a.windows(2) {
            assert!(w[0].arrival_us <= w[1].arrival_us, "arrivals must be nondecreasing");
        }
        let c = generate_timed(&TimedTraceConfig { seed: 8, ..cfg });
        assert_ne!(
            a.iter().map(timed_key).collect::<Vec<_>>(),
            c.iter().map(timed_key).collect::<Vec<_>>(),
            "different seeds must differ"
        );
    }

    #[test]
    fn poisson_rate_is_approximately_respected() {
        let cfg = TimedTraceConfig {
            n_requests: 512,
            arrival: Arrival::Poisson { rate_rps: 1000.0 },
            tail_prob: 0.0,
            ..TimedTraceConfig::default()
        };
        let trace = generate_timed(&cfg);
        let span_s = trace.last().unwrap().arrival_us as f64 * 1e-6;
        let rate = (cfg.n_requests - 1) as f64 / span_s;
        assert!(
            (rate - 1000.0).abs() < 200.0,
            "empirical rate {rate:.0} rps far from 1000"
        );
    }

    #[test]
    fn bursty_arrivals_share_instants() {
        let cfg = TimedTraceConfig {
            n_requests: 64,
            arrival: Arrival::Bursty { rate_rps: 400.0, burst: 8 },
            ..TimedTraceConfig::default()
        };
        let trace = generate_timed(&cfg);
        for chunk in trace.chunks(8) {
            assert!(chunk.iter().all(|t| t.arrival_us == chunk[0].arrival_us));
        }
    }

    #[test]
    fn ramp_accelerates() {
        let cfg = TimedTraceConfig {
            n_requests: 300,
            arrival: Arrival::Ramp { start_rps: 20.0, end_rps: 2000.0 },
            tail_prob: 0.0,
            ..TimedTraceConfig::default()
        };
        let trace = generate_timed(&cfg);
        let t = |i: usize| trace[i].arrival_us as f64;
        let first_half = t(150) - t(0);
        let second_half = t(299) - t(150);
        assert!(
            second_half < first_half,
            "ramp tail should arrive faster: {first_half} vs {second_half}"
        );
    }

    #[test]
    fn priority_mix_and_deadlines_apply() {
        let cfg = TimedTraceConfig {
            n_requests: 300,
            priority_mix: [1.0, 1.0, 1.0],
            deadlines_us: [Some(5_000), None, Some(1_000_000)],
            ..TimedTraceConfig::default()
        };
        let trace = generate_timed(&cfg);
        let mut seen = [0usize; 3];
        for t in &trace {
            let lvl = t.req.priority.level() as usize;
            seen[lvl] += 1;
            assert_eq!(t.req.deadline_us, cfg.deadlines_us[lvl]);
        }
        for (lvl, &count) in seen.iter().enumerate() {
            assert!(count > 50, "class {lvl} undersampled: {count}/300");
        }
    }

    #[test]
    fn multi_turn_trace_shares_session_prefixes() {
        let cfg = MultiTurnTraceConfig::default();
        let trace = generate_multi_turn(&cfg);
        assert_eq!(trace.len(), cfg.base.n_requests);
        let again = generate_multi_turn(&cfg);
        assert_eq!(
            trace.iter().map(timed_key).collect::<Vec<_>>(),
            again.iter().map(timed_key).collect::<Vec<_>>()
        );
        assert_eq!(
            trace.iter().map(|t| t.req.prefix_len).collect::<Vec<_>>(),
            again.iter().map(|t| t.req.prefix_len).collect::<Vec<_>>()
        );
        for t in &trace {
            assert!(t.req.prefix_len > 0, "every request declares a prefix");
            assert!(t.req.prefix_len < t.req.prompt.len(), "suffix is never empty");
            assert!(
                t.req.prompt.len() <= 128,
                "prompt of {} chars overflows the 128-token bucket",
                t.req.prompt.len()
            );
            let tail: Vec<char> = t.req.prompt.chars().rev().take(3).collect();
            assert_eq!(tail[0], '=', "prompt should end at '?x=': {}", t.req.prompt);
            assert_eq!(tail[2], '?');
        }
        // Round-robin sessions: same session index, same shared prefix —
        // and the sessions are pairwise distinct.
        let n_s = cfg.n_sessions;
        for (i, t) in trace.iter().enumerate() {
            let first = &trace[i % n_s];
            assert_eq!(
                &t.req.prompt[..t.req.prefix_len],
                &first.req.prompt[..first.req.prefix_len],
                "request {i} must repeat its session's prefix"
            );
        }
        let distinct: std::collections::BTreeSet<&str> = trace
            .iter()
            .take(n_s)
            .map(|t| &t.req.prompt[..t.req.prefix_len])
            .collect();
        assert_eq!(distinct.len(), n_s, "session prefixes must be distinct");
    }

    #[test]
    fn prompts_fit_the_largest_fake_prefill_bucket() {
        // The overload bench replays against the fake model, whose largest
        // prefill bucket is 128 tokens; the default timed config must never
        // emit a prompt that cannot prefill there.
        let cfg = TimedTraceConfig { n_requests: 256, ..TimedTraceConfig::default() };
        for t in generate_timed(&cfg) {
            assert!(
                t.req.prompt.len() <= 128,
                "prompt of {} chars overflows the 128-token bucket",
                t.req.prompt.len()
            );
        }
    }
}
