//! Serving request traces: Poisson-ish arrivals over corpus prompts, used by
//! the serving examples and the throughput/latency harness.

use crate::coordinator::request::Request;
use crate::util::rng::Rng;
use crate::workload::corpus::CorpusGen;
use std::time::Instant;

#[derive(Debug, Clone, Copy)]
pub struct TraceConfig {
    pub n_requests: usize,
    /// Variables per document (controls prompt length).
    pub n_vars: usize,
    pub n_queries: usize,
    pub max_new_tokens: usize,
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig { n_requests: 16, n_vars: 24, n_queries: 4, max_new_tokens: 48, seed: 7 }
    }
}

/// Generate a request trace. Prompts end right after a '?name=' query stem so
/// the served generation must recall from the cache.
pub fn generate(cfg: TraceConfig) -> Vec<Request> {
    let mut rng = Rng::new(cfg.seed);
    let mut gen = CorpusGen::new(cfg.seed ^ 0xabcd);
    (0..cfg.n_requests)
        .map(|i| {
            let doc = gen.document(cfg.n_vars, cfg.n_queries);
            // cut at the first query stem: "...;?x="
            let cut = doc.text.find('?').map(|p| p + 3).unwrap_or(doc.text.len());
            let _ = rng.next_u64();
            Request {
                id: i as u64,
                prompt: doc.text[..cut].to_string(),
                max_new_tokens: cfg.max_new_tokens,
                temperature: None,
                arrived: Instant::now(),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prompts_end_with_query_stem() {
        let reqs = generate(TraceConfig::default());
        assert_eq!(reqs.len(), 16);
        for r in &reqs {
            assert!(r.prompt.contains('='));
            let tail: Vec<char> = r.prompt.chars().rev().take(3).collect();
            assert_eq!(tail[0], '=', "prompt should end at '?x=': {}", r.prompt);
            assert_eq!(tail[2], '?');
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(TraceConfig::default());
        let b = generate(TraceConfig::default());
        assert_eq!(a[3].prompt, b[3].prompt);
    }
}
