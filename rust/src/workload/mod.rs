//! Workload generation and replay: the synthetic corpus (shared grammar
//! with `python/compile/corpus.py`), serving request traces with timed
//! arrival processes, and the virtual-clock overload replay harness.

pub mod corpus;
pub mod replay;
pub mod trace;
