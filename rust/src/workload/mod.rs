//! Workload generation: the synthetic corpus (shared grammar with
//! `python/compile/corpus.py`) and serving request traces.

pub mod corpus;
pub mod trace;
