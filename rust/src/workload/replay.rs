//! Trace replay on a virtual clock: the overload harness.
//!
//! [`replay`] feeds a timed trace ([`crate::workload::trace::generate_timed`])
//! to a [`Scheduler`], advancing a virtual clock from a deterministic
//! [`CostModel`] instead of wall time: each tick costs what the scheduler
//! *did* that tick (prefill tokens, decode step, batched sequences). Because
//! every input to the clock is a deterministic counter — and the engine's
//! worker-pool fan-out is byte-identical at any worker count — replaying the
//! same trace twice produces byte-identical [`ReplayReport`]s, including
//! across different `--workers` values. That turns tail-latency numbers into
//! something CI can diff, not just eyeball.
//!
//! Per-request TTFT / TPOT / end-to-end latency are reconstructed from the
//! scheduler's [`SchedEvent`] stream and aggregated into exact
//! [`LatencyHistogram`]s, overall and per priority class.
//!
//! [`replay_fleet`] scales the same harness to a data-parallel [`Fleet`]:
//! each replica runs on its own virtual clock (replicas are concurrent
//! machines, not a longer serial one), arrivals are routed by the fleet's
//! [`crate::coordinator::fleet::RouterPolicy`] at the instant every busy
//! replica has caught up to them, and the result is a [`FleetReplayReport`]
//! with per-replica [`ReplayReport`]s plus fleet aggregates — deterministic
//! under the contract documented on that type.

use crate::coordinator::fleet::Fleet;
use crate::coordinator::request::{Priority, SchedEvent, StepMetrics};
use crate::coordinator::Scheduler;
use crate::util::json::Json;
use crate::util::stats::{LatencyHistogram, Percentiles};
use crate::workload::trace::TimedRequest;
use anyhow::Result;
use std::collections::HashMap;

/// Virtual-time cost of one scheduler tick, as a linear model over what the
/// tick executed. The defaults are loosely calibrated to the fused-kernel
/// decode path (tens of microseconds of fixed overhead, prefill dominated
/// by bulk quantization, decode by the attention fan-out); the absolute
/// scale only shifts where "overload" begins — the *relative* tail behavior
/// across rates, budgets, and methods is what the harness measures.
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// Fixed scheduler overhead per tick.
    pub tick_overhead_us: u64,
    /// Prefill cost per prompt token (QKV stages + bulk quantization).
    pub prefill_us_per_token: u64,
    /// Fixed cost of a decode step (PJRT stage dispatch).
    pub decode_step_us: u64,
    /// Marginal decode cost per batched sequence (attention + sampling).
    pub decode_us_per_seq: u64,
    /// Offload-preemption cost per KiB of snapshot serialized into the warm
    /// tier. Hand-calibrated to ~1 GB/s of serialize-plus-copy (host memcpy
    /// runs far faster, the byte-level encoder dominates); like the other
    /// coefficients it awaits wall-clock calibration on real hardware. This
    /// is the term that lets the harness answer offload-vs-recompute: a
    /// restore pays `restore_us_per_kib x snapshot-KiB` while a recompute
    /// pays `prefill_us_per_token x prompt-tokens` again — so harder
    /// compression (smaller snapshots) tilts the trade toward offload.
    pub offload_us_per_kib: u64,
    /// Restore cost per KiB of snapshot deserialized from the warm tier.
    pub restore_us_per_kib: u64,
    /// Virtual time credited back per KiB of quantized prefix bytes a tick
    /// *borrowed* from the prefix store instead of quantizing privately
    /// (`prefill_us_per_token` prices the full prefill including bulk
    /// quantization; a prefix hit skips that work for the shared rows).
    /// The credit never drives a tick below its fixed overhead.
    pub prefix_saving_us_per_kib: u64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            tick_overhead_us: 20,
            prefill_us_per_token: 10,
            decode_step_us: 100,
            decode_us_per_seq: 50,
            offload_us_per_kib: 1,
            restore_us_per_kib: 1,
            prefix_saving_us_per_kib: 2,
        }
    }
}

impl CostModel {
    /// Build a cost model from a JSON object whose keys are the coefficient
    /// field names (`tick_overhead_us`, `prefill_us_per_token`,
    /// `decode_step_us`, `decode_us_per_seq`, `offload_us_per_kib`,
    /// `restore_us_per_kib`, `prefix_saving_us_per_kib`). Missing keys keep
    /// their [`Default`] value, so a calibration file may override only the
    /// coefficients it actually measured; unknown keys are rejected so a
    /// typo'd coefficient name fails loudly instead of silently keeping the
    /// default.
    pub fn from_json(v: &Json) -> Result<Self, String> {
        let obj = v.as_obj().ok_or("cost model must be a JSON object")?;
        let mut m = CostModel::default();
        for (k, val) in obj {
            let n = val
                .as_f64()
                .ok_or_else(|| format!("coefficient '{k}' must be a number"))?;
            if !(n.is_finite() && n >= 0.0) {
                return Err(format!("coefficient '{k}' must be a non-negative number"));
            }
            let n = n as u64;
            match k.as_str() {
                "tick_overhead_us" => m.tick_overhead_us = n,
                "prefill_us_per_token" => m.prefill_us_per_token = n,
                "decode_step_us" => m.decode_step_us = n,
                "decode_us_per_seq" => m.decode_us_per_seq = n,
                "offload_us_per_kib" => m.offload_us_per_kib = n,
                "restore_us_per_kib" => m.restore_us_per_kib = n,
                "prefix_saving_us_per_kib" => m.prefix_saving_us_per_kib = n,
                other => return Err(format!("unknown cost-model coefficient '{other}'")),
            }
        }
        Ok(m)
    }

    /// Load a cost model from a JSON file (e.g. one produced by
    /// `ci/calibrate_cost_model.py` from real bench numbers).
    pub fn load(path: &str) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        Self::from_json(&Json::parse(&text)?)
    }

    /// Virtual microseconds consumed by a tick with the given deltas.
    fn tick_cost(
        &self,
        d_prefill_tokens: u64,
        d_decode_steps: u64,
        d_batched: u64,
        d_offload_bytes: u64,
        d_restore_bytes: u64,
        d_prefix_shared_bytes: u64,
    ) -> u64 {
        let cost = self.tick_overhead_us
            + d_prefill_tokens * self.prefill_us_per_token
            + d_decode_steps * self.decode_step_us
            + d_batched * self.decode_us_per_seq
            + d_offload_bytes * self.offload_us_per_kib / 1024
            + d_restore_bytes * self.restore_us_per_kib / 1024;
        let credit = d_prefix_shared_bytes * self.prefix_saving_us_per_kib / 1024;
        cost.saturating_sub(credit).max(self.tick_overhead_us)
    }
}

/// Terminal outcome of one replayed request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    /// Completed normally.
    Ok,
    /// Failed terminally without a deadline (unencodable, over budget,
    /// unsatisfiable under pressure, prefill failure).
    Rejected,
    /// Deadline passed before completion.
    Expired,
}

impl Outcome {
    /// Stable name for reports.
    pub fn name(self) -> &'static str {
        match self {
            Outcome::Ok => "ok",
            Outcome::Rejected => "rejected",
            Outcome::Expired => "expired",
        }
    }
}

/// Per-request timeline reconstructed from the scheduler event stream, all
/// timestamps in virtual microseconds.
#[derive(Debug, Clone)]
pub struct RequestRecord {
    /// Request id.
    pub id: u64,
    /// Priority class the request carried.
    pub priority: Priority,
    /// Trace arrival time.
    pub arrival_us: u64,
    /// End of the tick in which the request was (first) admitted and its
    /// first token sampled; `None` if it never got that far. TTFT is
    /// `admitted_us - arrival_us`.
    pub admitted_us: Option<u64>,
    /// End of the tick in which the request reached a terminal state.
    pub finished_us: Option<u64>,
    /// Generated tokens (0 unless [`Outcome::Ok`]).
    pub n_generated: usize,
    /// Completion text (empty unless [`Outcome::Ok`]). Deterministic for a
    /// fixed trace, like everything else here — this is the oracle the
    /// socket-vs-replay tests and `benches/server_loadgen.rs` compare the
    /// real staged server's per-request output bytes against.
    pub text: String,
    /// Times the request was preempted out of the decode batch (recompute
    /// re-queues and offload snapshots both count).
    pub preemptions: u32,
    /// Preemptions whose cache was snapshotted into the warm tier.
    pub offloads: u32,
    /// Readmissions served by deserializing the snapshot (no re-prefill).
    pub restores: u32,
    /// Admissions that borrowed the request's whole prefix image set from
    /// the prefix store (can exceed 1 if the request was recompute-preempted
    /// and hit again on re-prefill).
    pub prefix_hits: u32,
    /// Terminal outcome (`None` only mid-replay).
    pub outcome: Option<Outcome>,
}

impl RequestRecord {
    /// Time-to-first-token, if the request was admitted.
    pub fn ttft_us(&self) -> Option<u64> {
        self.admitted_us.map(|t| t - self.arrival_us)
    }

    /// End-to-end latency, if the request reached a terminal state.
    pub fn e2e_us(&self) -> Option<u64> {
        self.finished_us.map(|t| t - self.arrival_us)
    }

    /// Mean time per output token after the first, for completed requests
    /// that generated at least one token.
    pub fn tpot_us(&self) -> Option<u64> {
        match (self.outcome, self.admitted_us, self.finished_us) {
            (Some(Outcome::Ok), Some(a), Some(f)) if self.n_generated > 0 => {
                Some((f - a) / self.n_generated as u64)
            }
            _ => None,
        }
    }
}

/// Latency aggregates for one slice of the trace (overall or one class).
#[derive(Debug, Clone, Default)]
pub struct LatencySlice {
    /// TTFT over every admitted request in the slice.
    pub ttft: LatencyHistogram,
    /// End-to-end latency over completed ([`Outcome::Ok`]) requests.
    pub e2e: LatencyHistogram,
    /// Per-output-token latency over completed requests.
    pub tpot: LatencyHistogram,
}

impl LatencySlice {
    fn add(&mut self, r: &RequestRecord) {
        if let Some(t) = r.ttft_us() {
            self.ttft.record(t);
        }
        if r.outcome == Some(Outcome::Ok) {
            if let Some(t) = r.e2e_us() {
                self.e2e.record(t);
            }
            if let Some(t) = r.tpot_us() {
                self.tpot.record(t);
            }
        }
    }
}

/// Everything a replay produced: per-request timelines, scheduler counters,
/// and the virtual span. Aggregates are computed on demand so callers can
/// slice however they like; [`ReplayReport::to_json`] is the canonical
/// machine-readable form (and the byte-identity determinism artifact).
#[derive(Debug, Clone)]
pub struct ReplayReport {
    /// One record per trace request, in trace order.
    pub records: Vec<RequestRecord>,
    /// Scheduler ticks executed.
    pub ticks: u64,
    /// Virtual time at which the last request reached a terminal state.
    pub end_us: u64,
    /// Final scheduler counters.
    pub metrics: StepMetrics,
}

impl ReplayReport {
    /// Count of records with the given outcome.
    pub fn count(&self, o: Outcome) -> usize {
        self.records.iter().filter(|r| r.outcome == Some(o)).count()
    }

    /// Latency aggregates over the whole trace.
    pub fn overall(&self) -> LatencySlice {
        let mut s = LatencySlice::default();
        for r in &self.records {
            s.add(r);
        }
        s
    }

    /// Latency aggregates for one priority class.
    pub fn class(&self, p: Priority) -> LatencySlice {
        let mut s = LatencySlice::default();
        for r in self.records.iter().filter(|r| r.priority == p) {
            s.add(r);
        }
        s
    }

    /// Completed requests per virtual second.
    pub fn throughput_rps(&self) -> f64 {
        if self.end_us == 0 {
            return 0.0;
        }
        self.count(Outcome::Ok) as f64 / (self.end_us as f64 * 1e-6)
    }

    /// Generated tokens per virtual second.
    pub fn gen_tokens_per_s(&self) -> f64 {
        if self.end_us == 0 {
            return 0.0;
        }
        let toks: usize = self.records.iter().map(|r| r.n_generated).sum();
        toks as f64 / (self.end_us as f64 * 1e-6)
    }

    fn percentiles_json(p: &Percentiles) -> Json {
        Json::obj(vec![
            ("count", Json::Num(p.count as f64)),
            ("mean_us", Json::Num(p.mean_us as f64)),
            ("p50_us", Json::Num(p.p50_us as f64)),
            ("p90_us", Json::Num(p.p90_us as f64)),
            ("p99_us", Json::Num(p.p99_us as f64)),
            ("max_us", Json::Num(p.max_us as f64)),
        ])
    }

    fn slice_json(s: &LatencySlice) -> Json {
        Json::obj(vec![
            ("ttft", Self::percentiles_json(&s.ttft.summary())),
            ("e2e", Self::percentiles_json(&s.e2e.summary())),
            ("tpot", Self::percentiles_json(&s.tpot.summary())),
        ])
    }

    /// Canonical machine-readable report. Deliberately excludes anything
    /// that may differ between equivalent runs (wall time, worker count),
    /// so two replays of the same trace compare byte-for-byte equal.
    pub fn to_json(&self) -> Json {
        let records: Vec<Json> = self
            .records
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("id", Json::Num(r.id as f64)),
                    ("class", Json::str(r.priority.name())),
                    ("arrival_us", Json::Num(r.arrival_us as f64)),
                    (
                        "admitted_us",
                        r.admitted_us.map_or(Json::Null, |v| Json::Num(v as f64)),
                    ),
                    (
                        "finished_us",
                        r.finished_us.map_or(Json::Null, |v| Json::Num(v as f64)),
                    ),
                    ("n_generated", Json::Num(r.n_generated as f64)),
                    ("text", Json::str(&r.text)),
                    ("preemptions", Json::Num(r.preemptions as f64)),
                    ("offloads", Json::Num(r.offloads as f64)),
                    ("restores", Json::Num(r.restores as f64)),
                    ("prefix_hits", Json::Num(r.prefix_hits as f64)),
                    (
                        "outcome",
                        r.outcome.map_or(Json::Null, |o| Json::str(o.name())),
                    ),
                ])
            })
            .collect();
        let per_class: Vec<(&str, Json)> = Priority::ALL
            .iter()
            .map(|&p| (p.name(), Self::slice_json(&self.class(p))))
            .collect();
        Json::obj(vec![
            ("harness", Json::str("trace_replay")),
            ("n_requests", Json::Num(self.records.len() as f64)),
            ("completed", Json::Num(self.count(Outcome::Ok) as f64)),
            ("rejected", Json::Num(self.count(Outcome::Rejected) as f64)),
            ("expired", Json::Num(self.count(Outcome::Expired) as f64)),
            ("preemptions", Json::Num(self.metrics.preemptions as f64)),
            ("offloads", Json::Num(self.metrics.offloads as f64)),
            ("offload_bytes", Json::Num(self.metrics.offload_bytes as f64)),
            ("restores", Json::Num(self.metrics.restores as f64)),
            ("restore_bytes", Json::Num(self.metrics.restore_bytes as f64)),
            ("offload_lost", Json::Num(self.metrics.offload_lost as f64)),
            (
                "window_frames_dropped",
                Json::Num(self.metrics.window_frames_dropped as f64),
            ),
            ("window_rebuilds", Json::Num(self.metrics.window_rebuilds as f64)),
            ("bypass_admissions", Json::Num(self.metrics.bypass_admissions as f64)),
            ("prefix_hits", Json::Num(self.metrics.prefix_hits as f64)),
            (
                "prefix_bytes_shared",
                Json::Num(self.metrics.prefix_bytes_shared as f64),
            ),
            ("ticks", Json::Num(self.ticks as f64)),
            ("virtual_us", Json::Num(self.end_us as f64)),
            ("throughput_rps", Json::Num(self.throughput_rps())),
            ("gen_tokens_per_s", Json::Num(self.gen_tokens_per_s())),
            ("overall", Self::slice_json(&self.overall())),
            ("per_class", Json::obj(per_class)),
            ("records", Json::Arr(records)),
        ])
    }

    /// Human-readable summary on stdout: counts, throughput, and
    /// p50/p90/p99 TTFT + end-to-end latency, overall and per class.
    pub fn print_summary(&self) {
        let ms = |us: u64| us as f64 / 1e3;
        println!(
            "requests {:>5}   completed {}   rejected {}   expired {}   preemptions {} \
             (offloaded {} / restored {} / lost {})",
            self.records.len(),
            self.count(Outcome::Ok),
            self.count(Outcome::Rejected),
            self.count(Outcome::Expired),
            self.metrics.preemptions,
            self.metrics.offloads,
            self.metrics.restores,
            self.metrics.offload_lost,
        );
        println!(
            "virtual time {:.1} ms over {} ticks   throughput {:.1} req/s   {:.0} gen tok/s",
            ms(self.end_us),
            self.ticks,
            self.throughput_rps(),
            self.gen_tokens_per_s(),
        );
        let line = |label: &str, s: &LatencySlice| {
            let t = s.ttft.summary();
            let e = s.e2e.summary();
            println!(
                "{label:<14} ttft p50/p90/p99 {:>8.2}/{:>8.2}/{:>8.2} ms   e2e p50/p90/p99 {:>8.2}/{:>8.2}/{:>8.2} ms   (n={})",
                ms(t.p50_us), ms(t.p90_us), ms(t.p99_us),
                ms(e.p50_us), ms(e.p90_us), ms(e.p99_us),
                e.count,
            );
        };
        line("overall", &self.overall());
        for p in Priority::ALL {
            let s = self.class(p);
            if !s.ttft.is_empty() || !s.e2e.is_empty() {
                line(p.name(), &s);
            }
        }
    }
}

/// Replay a timed trace through `sched` on a virtual clock.
///
/// The driver submits each request once its arrival time is reached, runs
/// one scheduler tick, prices the tick with `cost`, and advances the clock;
/// when the scheduler goes idle it jumps to the next arrival. Deadlines
/// count from trace arrival time (consistent with TTFT), even when a
/// request is ingested at the end of a long tick. The scheduler should be
/// freshly constructed (its
/// policy and workers already set); its event recording is enabled for the
/// duration and disabled again before returning.
pub fn replay(
    sched: &mut Scheduler,
    trace: &[TimedRequest],
    cost: &CostModel,
) -> Result<ReplayReport> {
    sched.record_events(true);
    sched.done.clear();
    let mut records: Vec<RequestRecord> = trace.iter().map(blank_record).collect();
    let idx_of: HashMap<u64, usize> =
        trace.iter().enumerate().map(|(i, t)| (t.req.id, i)).collect();

    let mut now = 0u64;
    let mut next = 0usize; // next trace arrival
    let mut ticks = 0u64;
    let mut prev = sched.metrics;
    let mut last_terminal_us = 0u64;
    loop {
        while next < trace.len() && trace[next].arrival_us <= now {
            // Anchor the submission (and so any deadline) at the trace
            // arrival time, consistent with how TTFT/e2e are measured.
            sched.submit_at(trace[next].req.clone(), trace[next].arrival_us);
            next += 1;
        }
        sched.set_now(now);
        let worked = sched.tick()?;
        if worked {
            ticks += 1;
            let m = sched.metrics;
            let dt = cost.tick_cost(
                m.prefill_tokens - prev.prefill_tokens,
                m.decode_steps - prev.decode_steps,
                m.batched_seqs - prev.batched_seqs,
                m.offload_bytes - prev.offload_bytes,
                m.restore_bytes - prev.restore_bytes,
                m.prefix_bytes_shared - prev.prefix_bytes_shared,
            );
            prev = m;
            now = now.saturating_add(dt.max(1));
        }
        for ev in sched.take_events() {
            let Some(&ri) = idx_of.get(&ev.id()) else { continue };
            apply_event(&mut records[ri], ev, now, &mut last_terminal_us);
        }
        for c in sched.done.drain(..) {
            if c.error.is_none() {
                if let Some(&ri) = idx_of.get(&c.id) {
                    records[ri].text = c.text;
                }
            }
        }
        if !worked {
            if next < trace.len() {
                now = now.max(trace[next].arrival_us);
            } else {
                break;
            }
        }
    }
    sched.record_events(false);
    Ok(ReplayReport { records, ticks, end_us: last_terminal_us, metrics: sched.metrics })
}

/// A fresh record for one trace request, before any events land.
fn blank_record(t: &TimedRequest) -> RequestRecord {
    RequestRecord {
        id: t.req.id,
        priority: t.req.priority,
        arrival_us: t.arrival_us,
        admitted_us: None,
        finished_us: None,
        n_generated: 0,
        text: String::new(),
        preemptions: 0,
        offloads: 0,
        restores: 0,
        prefix_hits: 0,
        outcome: None,
    }
}

/// Fold one scheduler event into its request's record, stamping terminal
/// transitions at virtual time `now`. Shared by the single-scheduler and
/// fleet replay drivers so both reconstruct timelines identically.
fn apply_event(r: &mut RequestRecord, ev: SchedEvent, now: u64, last_terminal_us: &mut u64) {
    match ev {
        SchedEvent::Submitted { .. } => {}
        SchedEvent::Admitted { .. } => {
            if r.admitted_us.is_none() {
                r.admitted_us = Some(now);
            }
        }
        SchedEvent::Preempted { .. } => r.preemptions += 1,
        SchedEvent::Offloaded { .. } => {
            r.preemptions += 1;
            r.offloads += 1;
        }
        SchedEvent::Restored { .. } => r.restores += 1,
        SchedEvent::PrefixHit { .. } => r.prefix_hits += 1,
        // The fallback re-prefill shows up as a second Admitted.
        SchedEvent::OffloadLost { .. } => {}
        SchedEvent::Rejected { .. } => {
            r.outcome = Some(Outcome::Rejected);
            r.finished_us = Some(now);
            *last_terminal_us = now;
        }
        SchedEvent::Expired { .. } => {
            r.outcome = Some(Outcome::Expired);
            r.finished_us = Some(now);
            *last_terminal_us = now;
        }
        SchedEvent::Finished { n_generated, .. } => {
            r.outcome = Some(Outcome::Ok);
            r.finished_us = Some(now);
            r.n_generated = n_generated;
            *last_terminal_us = now;
        }
        // Cancellation is a live-server concept (client disconnect);
        // a replayed trace has no client to hang up, so this never
        // fires here.
        SchedEvent::Cancelled { .. } => {}
    }
}

/// Everything a fleet replay produced: one [`ReplayReport`] per replica
/// (index = replica id) plus fleet-level aggregates.
///
/// ## Determinism contract
///
/// For a fixed trace, router policy, and replica count,
/// [`FleetReplayReport::to_json`] is byte-identical across *worker* counts
/// — each replica's engine fan-out is byte-identical at any pool size, and
/// everything else here is virtual-clock arithmetic.
///
/// Across *replica* counts, latency cannot be invariant (placement changes
/// queueing), so the replica-count contract is narrower:
/// [`FleetReplayReport::outcomes_json`] — per-request terminal outcome,
/// completion text, and generated-token count, sorted by id, with no
/// replica or latency fields — is byte-identical across replica counts for
/// the deadline-free greedy traces the generators emit by default, where
/// placement can change *when* a request runs but never *what* it
/// produces. `benches/fleet_scaling.rs` and `tests/fleet_router.rs` assert
/// both halves.
#[derive(Debug, Clone)]
pub struct FleetReplayReport {
    /// Per-replica reports; index is the replica id.
    pub replicas: Vec<ReplayReport>,
    /// Scheduler counters summed across replicas.
    pub metrics: StepMetrics,
    /// Router policy name ([`Fleet::router_name`]).
    pub router: &'static str,
    /// Snapshots the router migrated between warm tiers.
    pub migrations: u64,
    /// Bytes those migrations copied.
    pub migrated_bytes: u64,
}

impl FleetReplayReport {
    /// Ticks executed across all replicas.
    pub fn ticks(&self) -> u64 {
        self.replicas.iter().map(|r| r.ticks).sum()
    }

    /// Virtual time at which the last replica retired its last request.
    pub fn end_us(&self) -> u64 {
        self.replicas.iter().map(|r| r.end_us).max().unwrap_or(0)
    }

    /// Completed requests across the fleet.
    pub fn completed(&self) -> usize {
        self.replicas.iter().map(|r| r.count(Outcome::Ok)).sum()
    }

    /// Requests replayed across the fleet.
    pub fn n_requests(&self) -> usize {
        self.replicas.iter().map(|r| r.records.len()).sum()
    }

    /// Completed requests per virtual second. Replicas run concurrently,
    /// so the denominator is the *latest* per-replica end time, not the
    /// sum — this is the number that should scale with replica count.
    pub fn throughput_rps(&self) -> f64 {
        let end = self.end_us();
        if end == 0 {
            return 0.0;
        }
        self.completed() as f64 / (end as f64 * 1e-6)
    }

    /// The replica-count-invariant sub-document: per-request terminal
    /// outcome, text, and token count, sorted by id. Deliberately excludes
    /// every placement-dependent field (replica, latency, tick counts) —
    /// see the type-level determinism contract.
    pub fn outcomes_json(&self) -> Json {
        let mut rows: Vec<&RequestRecord> =
            self.replicas.iter().flat_map(|r| r.records.iter()).collect();
        rows.sort_by_key(|r| r.id);
        let rows: Vec<Json> = rows
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("id", Json::Num(r.id as f64)),
                    ("text", Json::str(&r.text)),
                    ("n_generated", Json::Num(r.n_generated as f64)),
                    (
                        "outcome",
                        r.outcome.map_or(Json::Null, |o| Json::str(o.name())),
                    ),
                ])
            })
            .collect();
        Json::Arr(rows)
    }

    /// Canonical machine-readable fleet report: fleet aggregates, the
    /// replica-count-invariant `outcomes` block, and the full per-replica
    /// [`ReplayReport::to_json`] documents. Byte-identical across worker
    /// counts for a fixed (trace, router, replica count).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("harness", Json::str("fleet_replay")),
            ("router", Json::str(self.router)),
            ("n_replicas", Json::Num(self.replicas.len() as f64)),
            ("n_requests", Json::Num(self.n_requests() as f64)),
            ("completed", Json::Num(self.completed() as f64)),
            ("migrations", Json::Num(self.migrations as f64)),
            ("migrated_bytes", Json::Num(self.migrated_bytes as f64)),
            ("prefill_tokens", Json::Num(self.metrics.prefill_tokens as f64)),
            ("restores", Json::Num(self.metrics.restores as f64)),
            ("restore_bytes", Json::Num(self.metrics.restore_bytes as f64)),
            ("prefix_hits", Json::Num(self.metrics.prefix_hits as f64)),
            (
                "prefix_bytes_shared",
                Json::Num(self.metrics.prefix_bytes_shared as f64),
            ),
            ("ticks", Json::Num(self.ticks() as f64)),
            ("virtual_us", Json::Num(self.end_us() as f64)),
            ("throughput_rps", Json::Num(self.throughput_rps())),
            ("outcomes", self.outcomes_json()),
            (
                "replicas",
                Json::Arr(self.replicas.iter().map(|r| r.to_json()).collect()),
            ),
        ])
    }

    /// Human-readable summary: fleet totals, then one line per replica.
    pub fn print_summary(&self) {
        let ms = |us: u64| us as f64 / 1e3;
        println!(
            "fleet [{}] x{}   requests {}   completed {}   migrations {} ({} KiB)   \
             virtual time {:.1} ms   throughput {:.1} req/s",
            self.router,
            self.replicas.len(),
            self.n_requests(),
            self.completed(),
            self.migrations,
            self.migrated_bytes / 1024,
            ms(self.end_us()),
            self.throughput_rps(),
        );
        for (i, r) in self.replicas.iter().enumerate() {
            let e = r.overall().e2e.summary();
            println!(
                "  replica {i}: {} req, {} ok, {} ticks, {:.1} ms, prefix hits {}, \
                 e2e p50/p99 {:.2}/{:.2} ms",
                r.records.len(),
                r.count(Outcome::Ok),
                r.ticks,
                ms(r.end_us),
                r.metrics.prefix_hits,
                ms(e.p50_us),
                ms(e.p99_us),
            );
        }
    }
}

/// Replay a timed trace through a [`Fleet`] on per-replica virtual clocks.
///
/// Each replica advances its own clock from the same deterministic
/// [`CostModel`] — replicas are independent machines, so their clocks run
/// concurrently, not summed. The driver always advances the
/// furthest-behind replica that still has pending work (ties to the lowest
/// index) until every busy replica has reached the next trace arrival;
/// only then is the arrival routed, so the router observes each replica's
/// state as of the arrival instant no matter how the interleaving is
/// scheduled — which is what makes placement (and the whole report)
/// deterministic. An idle replica's clock jumps forward when a request is
/// routed to it, exactly like the single-scheduler driver.
pub fn replay_fleet(
    fleet: &mut Fleet,
    trace: &[TimedRequest],
    cost: &CostModel,
) -> Result<FleetReplayReport> {
    let n_r = fleet.n();
    let mut now = vec![0u64; n_r];
    let mut ticks = vec![0u64; n_r];
    let mut last_terminal = vec![0u64; n_r];
    let mut records: Vec<Vec<RequestRecord>> = vec![Vec::new(); n_r];
    // id -> (home replica, index into its record list); the router fixes a
    // request's home at submission and it never moves (offload migration
    // re-homes *snapshots*, which happens before the request is submitted).
    let mut home: HashMap<u64, (usize, usize)> = HashMap::new();
    let mut prev: Vec<StepMetrics> = (0..n_r)
        .map(|i| {
            let s = fleet.replica_mut(i);
            s.record_events(true);
            s.done.clear();
            s.metrics
        })
        .collect();

    let mut next = 0usize; // next trace arrival
    loop {
        let horizon = trace.get(next).map(|t| t.arrival_us);
        let runnable = (0..n_r)
            .filter(|&i| fleet.replica(i).pending() > 0)
            .filter(|&i| horizon.map_or(true, |h| now[i] < h))
            .min_by_key(|&i| (now[i], i));
        if let Some(i) = runnable {
            let s = fleet.replica_mut(i);
            s.set_now(now[i]);
            let worked = s.tick()?;
            // `pending() > 0` means tick always does work; guard against
            // a livelock anyway if that invariant ever drifts.
            debug_assert!(worked, "a replica with pending work must tick");
            if worked {
                ticks[i] += 1;
                let m = s.metrics;
                let dt = cost.tick_cost(
                    m.prefill_tokens - prev[i].prefill_tokens,
                    m.decode_steps - prev[i].decode_steps,
                    m.batched_seqs - prev[i].batched_seqs,
                    m.offload_bytes - prev[i].offload_bytes,
                    m.restore_bytes - prev[i].restore_bytes,
                    m.prefix_bytes_shared - prev[i].prefix_bytes_shared,
                );
                prev[i] = m;
                now[i] = now[i].saturating_add(dt.max(1));
            } else {
                now[i] = horizon.unwrap_or(now[i]);
            }
            for ev in fleet.replica_mut(i).take_events() {
                if let Some(&(rep, ri)) = home.get(&ev.id()) {
                    apply_event(&mut records[rep][ri], ev, now[i], &mut last_terminal[i]);
                }
            }
            for c in fleet.replica_mut(i).done.drain(..) {
                if c.error.is_none() {
                    if let Some(&(rep, ri)) = home.get(&c.id) {
                        records[rep][ri].text = c.text;
                    }
                }
            }
            continue;
        }
        // Every busy replica has caught up to the next arrival: route it
        // (anchoring deadlines at the trace arrival time, like the
        // single-scheduler driver), or finish if the trace is drained.
        let Some(t) = trace.get(next) else { break };
        next += 1;
        let dest = fleet.submit_at(t.req.clone(), t.arrival_us);
        now[dest] = now[dest].max(t.arrival_us);
        records[dest].push(blank_record(t));
        home.insert(t.req.id, (dest, records[dest].len() - 1));
        // The Submitted event this enqueued drains on dest's next tick.
    }
    for i in 0..n_r {
        fleet.replica_mut(i).record_events(false);
    }
    let replicas: Vec<ReplayReport> = (0..n_r)
        .map(|i| ReplayReport {
            records: std::mem::take(&mut records[i]),
            ticks: ticks[i],
            end_us: last_terminal[i],
            metrics: fleet.replica(i).metrics,
        })
        .collect();
    Ok(FleetReplayReport {
        replicas,
        metrics: fleet.aggregate_metrics(),
        router: fleet.router_name(),
        migrations: fleet.migrations,
        migrated_bytes: fleet.migrated_bytes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_model_from_json_overrides_only_named_coefficients() {
        let v = Json::parse(r#"{"decode_step_us": 250, "tick_overhead_us": 7}"#).unwrap();
        let m = CostModel::from_json(&v).unwrap();
        let d = CostModel::default();
        assert_eq!(m.decode_step_us, 250);
        assert_eq!(m.tick_overhead_us, 7);
        assert_eq!(m.prefill_us_per_token, d.prefill_us_per_token);
        assert_eq!(m.prefix_saving_us_per_kib, d.prefix_saving_us_per_kib);
    }

    #[test]
    fn cost_model_from_json_rejects_bad_input() {
        for src in [
            r#"{"decode_step_usx": 1}"#, // typo'd key
            r#"{"decode_step_us": "fast"}"#,
            r#"{"decode_step_us": -1}"#,
            r#"[1,2,3]"#,
        ] {
            let v = Json::parse(src).unwrap();
            assert!(CostModel::from_json(&v).is_err(), "accepted: {src}");
        }
    }
}
