//! Rust port of the variable-recall corpus grammar
//! (`python/compile/corpus.py`): single-letter variables, reassignment with
//! latest-binding-wins, recall queries at the end. Used by the eval harness
//! (Tables 1/2/7, Fig. 5) and the serving trace generator.

use crate::util::rng::Rng;

/// The model's character set: variable names, digits, and grammar marks.
pub const CHARSET: &str = "abcdefghij0123456789=;?.";
/// Distinct variable names in the grammar.
pub const N_NAMES: usize = 10;

/// One generated document plus its ground truth.
#[derive(Debug, Clone)]
pub struct Document {
    /// The document text.
    pub text: String,
    /// Index of the first query ('?') character.
    pub query_start: usize,
    /// (name, value) pairs queried, in order.
    pub queries: Vec<(char, String)>,
}

/// Deterministic corpus generator.
pub struct CorpusGen {
    rng: Rng,
}

impl CorpusGen {
    /// A generator with its own deterministic stream.
    pub fn new(seed: u64) -> CorpusGen {
        CorpusGen { rng: Rng::new(seed) }
    }

    /// `n_assign` (re)assignments followed by `n_queries` recall queries.
    /// The first `N_NAMES` assignments cover each name once.
    pub fn document(&mut self, n_assign: usize, n_queries: usize) -> Document {
        let names: Vec<char> = CHARSET.chars().take(N_NAMES).collect();
        let mut values: Vec<Option<String>> = vec![None; N_NAMES];
        let mut text = String::new();
        for i in 0..n_assign {
            let idx = if i < N_NAMES { i } else { self.rng.next_range(N_NAMES) };
            let val = format!("{:02}", self.rng.next_range(100));
            text.push(names[idx]);
            text.push('=');
            text.push_str(&val);
            text.push(';');
            values[idx] = Some(val);
        }
        let query_start = text.len();
        let assigned: Vec<usize> =
            (0..N_NAMES).filter(|&i| values[i].is_some()).collect();
        let mut queries = Vec::with_capacity(n_queries);
        for qi in 0..n_queries {
            let idx = assigned[self.rng.next_range(assigned.len())];
            let val = values[idx].clone().unwrap();
            text.push('?');
            text.push(names[idx]);
            text.push('=');
            text.push_str(&val);
            text.push(if qi + 1 == n_queries { '.' } else { ';' });
            queries.push((names[idx], val));
        }
        Document { text, query_start, queries }
    }
}

/// Token positions whose next-token prediction is a queried value digit:
/// (position, target_token) with logits at `position` predicting
/// `position+1`. Mirrors `corpus.query_positions` (token streams include a
/// leading BOS, so caller passes tokens *with* BOS).
pub fn query_positions(tokens: &[i32], charset: &str) -> Vec<(usize, i32)> {
    let q = charset.chars().position(|c| c == '?').unwrap() as i32 + 1;
    let eq = charset.chars().position(|c| c == '=').unwrap() as i32 + 1;
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < tokens.len() {
        if tokens[i] == q && i + 4 < tokens.len() && tokens[i + 2] == eq {
            out.push((i + 2, tokens[i + 3]));
            out.push((i + 3, tokens[i + 4]));
            i += 5;
        } else {
            i += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn document_structure() {
        let mut g = CorpusGen::new(1);
        let d = g.document(30, 8);
        assert_eq!(d.queries.len(), 8);
        assert!(d.text.ends_with('.'));
        assert_eq!(&d.text[d.query_start..d.query_start + 1], "?");
        // every query's value matches the last assignment before queries
        let body = &d.text[..d.query_start];
        for (name, val) in &d.queries {
            let last = body
                .match_indices(&format!("{name}="))
                .last()
                .map(|(p, _)| &body[p + 2..p + 4])
                .unwrap();
            assert_eq!(last, val, "query {name}");
        }
    }

    #[test]
    fn charset_matches_python() {
        // Guard against drift with python/compile/corpus.py.
        assert_eq!(CHARSET, "abcdefghij0123456789=;?.");
        assert_eq!(CHARSET.len(), 24);
    }

    #[test]
    fn document_length_scales() {
        let mut g = CorpusGen::new(2);
        let small = g.document(30, 4).text.len();
        let big = g.document(500, 4).text.len();
        assert!(big > 2400 && small < 200, "small {small} big {big}");
    }

    #[test]
    fn query_positions_found() {
        let mut g = CorpusGen::new(3);
        let d = g.document(12, 5);
        // encode with the rust charset (BOS prepended like the engine does)
        let mut toks = vec![0i32];
        for c in d.text.chars() {
            toks.push(CHARSET.chars().position(|x| x == c).unwrap() as i32 + 1);
        }
        let qs = query_positions(&toks, CHARSET);
        assert_eq!(qs.len(), 10); // 2 digits per query
        for (p, target) in qs {
            assert_eq!(toks[p + 1], target);
        }
    }

    #[test]
    fn deterministic() {
        let a = CorpusGen::new(42).document(20, 3);
        let b = CorpusGen::new(42).document(20, 3);
        assert_eq!(a.text, b.text);
    }
}
