//! Teacher-forced evaluation over corpus documents for one quant method.

use crate::coordinator::Engine;
use crate::quant::MethodConfig;
use crate::runtime::Manifest;
use crate::workload::corpus::{query_positions, CorpusGen};
use anyhow::Result;

/// Shape of one evaluation run: how many documents, how long each is, and
/// how many recall queries are scored per document.
#[derive(Debug, Clone, Copy)]
pub struct EvalConfig {
    /// Documents evaluated (each scored independently, metrics pooled).
    pub n_docs: usize,
    /// Assignments (`a=07;`) per document — controls context length.
    pub n_assign: usize,
    /// Recall queries (`?a=07`) teacher-forced at the end of each document.
    pub n_queries: usize,
    /// Corpus RNG seed; equal seeds generate identical documents, which is
    /// what lets a method run reuse the baseline run's logits.
    pub seed: u64,
}

impl Default for EvalConfig {
    fn default() -> Self {
        // ~150-token contexts: past the 128-token high-precision window, so
        // the quantized segment is actually exercised (Table 1 scale).
        EvalConfig { n_docs: 8, n_assign: 40, n_queries: 10, seed: 2026 }
    }
}

/// Pooled metrics for one method over an evaluation run (the rows of the
/// paper-substitute quality tables).
#[derive(Debug, Clone, Default)]
pub struct EvalResult {
    /// [`crate::QuantMethod::name`] of the evaluated configuration.
    pub method: String,
    /// Mean NLL of ground-truth value digits.
    pub nll: f64,
    /// Greedy recall accuracy on value digits.
    pub accuracy: f64,
    /// Top-1 agreement with the baseline run (1.0 for the baseline itself).
    pub agreement: f64,
    /// Mean KL(baseline || method) over value-digit logits.
    pub kl: f64,
    /// Scored value-digit positions pooled across all documents.
    pub n_positions: usize,
    /// Mean sparsity of the hybrid mask M (fraction symmetric), if any.
    pub m_sparsity: Option<f64>,
}

/// Teacher-force one document through the decode path, returning the logits
/// at every query-digit position.
fn run_document(
    engine: &Engine,
    tokens: &[i32],
    positions: &[(usize, i32)],
) -> Result<Vec<Vec<f32>>> {
    // Prefill everything before the first query position; decode the rest.
    let first_q = positions.first().map(|&(p, _)| p).unwrap_or(tokens.len() - 1);
    let split = first_q.max(1).min(tokens.len() - 1);
    let mut seq = engine.prefill(&tokens[..split])?;
    let mut out = Vec::with_capacity(positions.len());
    let mut pi = 0usize;
    // position split-1 logits predict token[split]
    while pi < positions.len() && positions[pi].0 == split - 1 {
        out.push(seq.last_logits.clone());
        pi += 1;
    }
    for t in split..tokens.len() {
        engine.decode_step(&mut [&mut seq], &[tokens[t]])?;
        while pi < positions.len() && positions[pi].0 == t {
            out.push(seq.last_logits.clone());
            pi += 1;
        }
    }
    debug_assert_eq!(out.len(), positions.len());
    Ok(out)
}

fn kl_divergence(p_logits: &[f32], q_logits: &[f32]) -> f64 {
    let lsm = |l: &[f32]| -> Vec<f64> {
        let m = l.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b)) as f64;
        let lse = m + l.iter().map(|&v| ((v as f64) - m).exp()).sum::<f64>().ln();
        l.iter().map(|&v| v as f64 - lse).collect()
    };
    let lp = lsm(p_logits);
    let lq = lsm(q_logits);
    lp.iter().zip(&lq).map(|(&a, &b)| a.exp() * (a - b)).sum()
}

/// Evaluate one method against a baseline engine over `cfg.n_docs` documents.
/// `baseline_logits`: pass None to compute the baseline itself; Some(ref)
/// to reuse logits from the baseline run (same seed => same documents).
pub fn evaluate(
    manifest: &Manifest,
    method_cfg: MethodConfig,
    cfg: EvalConfig,
    baseline_logits: Option<&[Vec<Vec<f32>>]>,
) -> Result<(EvalResult, Vec<Vec<Vec<f32>>>)> {
    let engine = Engine::new(manifest.clone(), method_cfg)?;
    let mut gen = CorpusGen::new(cfg.seed);
    let mut res = EvalResult {
        method: method_cfg.method.name().to_string(),
        ..Default::default()
    };
    let mut all_logits = Vec::with_capacity(cfg.n_docs);
    let (mut nll, mut acc, mut agree, mut kl, mut n) = (0.0, 0.0, 0.0, 0.0, 0usize);
    for d in 0..cfg.n_docs {
        let doc = gen.document(cfg.n_assign, cfg.n_queries);
        let mut tokens = vec![manifest.bos];
        tokens.extend(manifest.encode(&doc.text)?);
        let positions = query_positions(&tokens, &manifest.charset);
        let logits = run_document(&engine, &tokens, &positions)?;
        for (i, (&(_, target), l)) in positions.iter().zip(&logits).enumerate() {
            nll += -(Engine::log_prob(l, target) as f64);
            let pred = Engine::argmax(l);
            acc += (pred == target) as u8 as f64;
            if let Some(base) = baseline_logits {
                let bl = &base[d][i];
                agree += (pred == Engine::argmax(bl)) as u8 as f64;
                kl += kl_divergence(bl, l);
            } else {
                agree += 1.0;
            }
            n += 1;
        }
        all_logits.push(logits);
    }
    res.nll = nll / n as f64;
    res.accuracy = acc / n as f64;
    res.agreement = agree / n as f64;
    res.kl = kl / n as f64;
    res.n_positions = n;
    Ok((res, all_logits))
}

/// Pretty-print a block of results as an aligned table.
pub fn print_table(title: &str, rows: &[EvalResult]) {
    println!("\n== {title} ==");
    println!(
        "{:<16} {:>8} {:>8} {:>10} {:>10} {:>6}",
        "method", "NLL", "acc%", "agree%", "KL", "n"
    );
    for r in rows {
        println!(
            "{:<16} {:>8.4} {:>8.1} {:>10.1} {:>10.4} {:>6}",
            r.method,
            r.nll,
            r.accuracy * 100.0,
            r.agreement * 100.0,
            r.kl,
            r.n_positions
        );
    }
}
