//! Quality-evaluation harness: the substitute for the paper's few-shot task
//! scores (Tables 1, 2, 7; Fig. 5). See DESIGN.md substitutions.
//!
//! Protocol per document: the assignment context is prefilled (Eq. 15
//! bulk-quantization path), then the query section is teacher-forced through
//! the decode path, recording for each queried value digit:
//!
//! * NLL of the ground-truth digit;
//! * greedy-prediction correctness (recall accuracy — the task metric);
//! * top-1 agreement and logit KL against the FP16-baseline run on the
//!   *same* document (cache-fidelity metrics, meaningful at any length).

pub mod harness;

pub use harness::{evaluate, EvalConfig, EvalResult};
