//! Wall-clock tracing and profiling plane.
//!
//! Always compiled in, off by default, and built so that the act of
//! observing the system cannot perturb it:
//!
//! * **Disabled cost**: one relaxed atomic load per emission site
//!   ([`enabled`]). Every emission helper checks it first and returns.
//! * **Enabled cost**: a timestamp read plus a handful of atomic ops to
//!   push a fixed-size [`SpanEvent`] into the emitting thread's private
//!   [`EventRing`] lane. Rings are drop-oldest and never block
//!   ([`crate::util::ring`]), so a slow (or absent) drainer loses events —
//!   counted, never waited for.
//! * **Byte identity**: tracing reads clocks and writes rings; it takes no
//!   locks on the data path and never feeds back into scheduling, so
//!   decode output with tracing on is byte-identical to tracing off
//!   (asserted in `tests/observability.rs`).
//!
//! ## Lanes
//!
//! Each emitting thread lazily claims a private ring lane the first time it
//! emits while tracing is enabled (a thread-local holds the lane index;
//! lanes are recycled through a free list when threads exit). One producer
//! per ring keeps the producer path contention-free; the drainer — the
//! scheduler driver, via [`recorder::Recorder::drain`] once per loop — pops
//! every lane behind the recorder's own mutex.
//!
//! ## Clock
//!
//! All timestamps are microseconds since a process-wide epoch (the first
//! instant the plane is touched), so spans from every thread share one
//! timeline and export directly as Chrome trace-event `ts` values.
//!
//! ## Enabling
//!
//! Tracing turns on while at least one [`TraceGuard`] is live: the admin
//! `trace <secs>` command holds one for its window, `--trace-out` holds one
//! for the process lifetime, and tests arm their own.

pub mod export;
pub mod recorder;

use crate::util::ring::EventRing;
use std::cell::Cell;
use std::sync::atomic::{AtomicU32, AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Maximum concurrent emitting threads with private lanes; later threads
/// emit nothing (drivers, IO workers and pool workers together stay far
/// below this).
const MAX_LANES: usize = 64;

/// Per-lane ring capacity in events. Lanes are allocated lazily on first
/// use, so an untraced process never pays for them.
const RING_CAP: usize = 2048;

/// Count of live [`TraceGuard`]s. Tracing is on while > 0.
static TRACERS: AtomicUsize = AtomicUsize::new(0);

/// The process-wide trace epoch; all span timestamps are relative to it.
static EPOCH: OnceLock<Instant> = OnceLock::new();

/// The lane rings, allocated together on first emission or drain.
static LANES: OnceLock<Vec<EventRing<SpanEvent>>> = OnceLock::new();

/// Next never-used lane index (monotonic; bounded use by [`MAX_LANES`]).
static NEXT_LANE: AtomicU32 = AtomicU32::new(0);

/// Lanes returned by exited threads, reused before minting new ones.
static FREE_LANES: Mutex<Vec<u32>> = Mutex::new(Vec::new());

/// Thread-local lane sentinel: not yet assigned.
const LANE_UNSET: u32 = u32::MAX;
/// Thread-local lane sentinel: lanes exhausted, this thread emits nothing.
const LANE_NONE: u32 = u32::MAX - 1;

/// Thread-local lane slot whose drop returns the lane for reuse.
struct LaneCell(Cell<u32>);

impl Drop for LaneCell {
    fn drop(&mut self) {
        let v = self.0.get();
        if v < MAX_LANES as u32 {
            let mut free = FREE_LANES.lock().unwrap_or_else(|e| e.into_inner());
            free.push(v);
        }
    }
}

thread_local! {
    static LANE: LaneCell = const { LaneCell(Cell::new(LANE_UNSET)) };
}

/// Whether any tracer is live. One relaxed load — the entire disabled-path
/// cost of every emission site.
#[inline]
pub fn enabled() -> bool {
    TRACERS.load(Ordering::Relaxed) > 0
}

/// RAII handle that keeps tracing enabled while it lives.
pub struct TraceGuard(());

impl TraceGuard {
    /// Enable tracing until the guard drops. Guards nest: tracing stays on
    /// while any guard is live.
    pub fn arm() -> TraceGuard {
        // Pin the epoch before the first event so no span predates it.
        let _ = epoch();
        TRACERS.fetch_add(1, Ordering::Relaxed);
        TraceGuard(())
    }
}

impl Drop for TraceGuard {
    fn drop(&mut self) {
        TRACERS.fetch_sub(1, Ordering::Relaxed);
    }
}

fn epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

/// Microseconds since the trace epoch.
#[inline]
pub fn now_us() -> u64 {
    epoch().elapsed().as_micros() as u64
}

/// Convert an [`Instant`] captured elsewhere (e.g. a request's arrival
/// time) to microseconds since the trace epoch; instants before the epoch
/// clamp to 0.
pub fn epoch_us_of(t: Instant) -> u64 {
    t.checked_duration_since(epoch()).map(|d| d.as_micros() as u64).unwrap_or(0)
}

/// What a span measured. Every kind carries the same fixed payload
/// (`id`, two `u64` args, an optional static tag); [`SpanKind::arg_names`]
/// documents what the args mean per kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum SpanKind {
    /// Queue residency: submission to admission (or terminal failure).
    /// `id` = request id.
    Queued,
    /// One prefill (private, shared-hit, or publishing). `id` = request id.
    Prefill,
    /// One scheduler decode step over the live batch. `id` = step ordinal.
    DecodeStep,
    /// Whole-request lifecycle span, arrival to terminal state; the tag is
    /// the terminal outcome (`ok`/`rejected`/`expired`/`cancelled`).
    /// `id` = request id.
    Request,
    /// Driver-side QKV PJRT stage for one decode step and layer.
    StageQkv,
    /// Driver-side output-projection PJRT stage for one layer.
    StageOut,
    /// Driver-side LM-head stage ending a decode step.
    StageHead,
    /// One fused append+attend job for one (sequence, KV head); the tag is
    /// the active kernel ISA arm. `id` = batch sequence index.
    AttnJob,
    /// Quantize-on-evict of one fp-window block into the packed middle.
    /// `id` = rows quantized (no request identity at this depth).
    QuantEvict,
    /// Offload-preemption snapshot serialization. `id` = request id.
    Snapshot,
    /// Warm-tier restore deserialization. `id` = request id.
    Restore,
    /// Prefix-store probe at prefill. `id` = prefix content hash (the
    /// engine has no request identity); `b` encodes the outcome
    /// (0 private/refused, 1 hit, 2 published).
    PrefixProbe,
    /// Warm-tier segment insertion. `id` = request id.
    TierInsert,
    /// Warm-tier frame retrieval. `id` = request id.
    TierTake,
    /// IO-worker ingress: bytes parsed into one submitted request.
    /// `id` = connection id (driver request ids are not assigned yet).
    Ingress,
    /// IO-worker egress: one flush of a connection's buffered response
    /// bytes. `id` = conn id; `b` = bytes written.
    Egress,
    /// One full scheduler driver-loop tick, idle ticks included (an idle
    /// tick is a pure-overhead sample: `b == 0`). In a fleet the tag is the
    /// replica ([`replica_tag`]). `id` = live batch size at entry.
    DriverTick,
}

impl SpanKind {
    /// Every kind, for exporters and tests.
    pub const ALL: [SpanKind; 17] = [
        SpanKind::Queued,
        SpanKind::Prefill,
        SpanKind::DecodeStep,
        SpanKind::Request,
        SpanKind::StageQkv,
        SpanKind::StageOut,
        SpanKind::StageHead,
        SpanKind::AttnJob,
        SpanKind::QuantEvict,
        SpanKind::Snapshot,
        SpanKind::Restore,
        SpanKind::PrefixProbe,
        SpanKind::TierInsert,
        SpanKind::TierTake,
        SpanKind::Ingress,
        SpanKind::Egress,
        SpanKind::DriverTick,
    ];

    /// Stable span name (Chrome trace `name`, Prometheus `stage` label).
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Queued => "queued",
            SpanKind::Prefill => "prefill",
            SpanKind::DecodeStep => "decode_step",
            SpanKind::Request => "request",
            SpanKind::StageQkv => "stage_qkv",
            SpanKind::StageOut => "stage_out",
            SpanKind::StageHead => "stage_head",
            SpanKind::AttnJob => "attn_job",
            SpanKind::QuantEvict => "quant_evict",
            SpanKind::Snapshot => "snapshot",
            SpanKind::Restore => "restore",
            SpanKind::PrefixProbe => "prefix_probe",
            SpanKind::TierInsert => "tier_insert",
            SpanKind::TierTake => "tier_take",
            SpanKind::Ingress => "ingress",
            SpanKind::Egress => "egress",
            SpanKind::DriverTick => "driver_tick",
        }
    }

    /// Chrome trace category.
    pub fn cat(self) -> &'static str {
        match self {
            SpanKind::Queued | SpanKind::Prefill | SpanKind::Request => "request",
            SpanKind::DecodeStep | SpanKind::DriverTick => "driver",
            SpanKind::StageQkv | SpanKind::StageOut | SpanKind::StageHead => "stage",
            SpanKind::AttnJob => "job",
            SpanKind::QuantEvict
            | SpanKind::Snapshot
            | SpanKind::Restore
            | SpanKind::PrefixProbe => "cache",
            SpanKind::TierInsert | SpanKind::TierTake => "store",
            SpanKind::Ingress | SpanKind::Egress => "io",
        }
    }

    /// Names of the two `u64` args (`a`, `b`) for trace-export labeling.
    pub fn arg_names(self) -> (&'static str, &'static str) {
        match self {
            SpanKind::Queued => ("priority", "aux"),
            SpanKind::Prefill => ("tokens", "shared_bytes"),
            SpanKind::DecodeStep => ("batch", "aux"),
            SpanKind::Request => ("priority", "generated"),
            SpanKind::StageQkv | SpanKind::StageOut | SpanKind::StageHead => ("layer", "batch"),
            SpanKind::AttnJob => ("layer", "head"),
            SpanKind::QuantEvict => ("rows", "aux"),
            SpanKind::Snapshot | SpanKind::Restore => ("bytes", "aux"),
            SpanKind::PrefixProbe => ("bytes", "outcome"),
            SpanKind::TierInsert | SpanKind::TierTake => ("bytes", "aux"),
            SpanKind::Ingress => ("conn", "bytes"),
            SpanKind::Egress => ("conn", "bytes"),
            SpanKind::DriverTick => ("live", "worked"),
        }
    }
}

/// Static replica tags for span annotation (`span_tag` takes a
/// `&'static str`). Replicas beyond the table clamp to the last entry —
/// fleet sizes that large are not a supported configuration anyway.
pub fn replica_tag(replica: usize) -> &'static str {
    const TAGS: [&str; 16] = [
        "r0", "r1", "r2", "r3", "r4", "r5", "r6", "r7", "r8", "r9", "r10", "r11", "r12", "r13",
        "r14", "r15",
    ];
    TAGS[replica.min(TAGS.len() - 1)]
}

/// One completed span, as pushed into a lane ring. Fixed-size and `Copy`
/// so rings never allocate or drop.
#[derive(Debug, Clone, Copy)]
pub struct SpanEvent {
    /// What was measured.
    pub kind: SpanKind,
    /// Kind-specific identity (usually the request id).
    pub id: u64,
    /// Start, microseconds since the trace epoch.
    pub start_us: u64,
    /// Duration in microseconds.
    pub dur_us: u64,
    /// The emitting thread's lane (Chrome trace `tid`).
    pub lane: u32,
    /// First kind-specific arg (see [`SpanKind::arg_names`]).
    pub a: u64,
    /// Second kind-specific arg.
    pub b: u64,
    /// Optional static annotation (terminal outcome, ISA arm, ...).
    pub tag: Option<&'static str>,
}

fn rings() -> &'static Vec<EventRing<SpanEvent>> {
    LANES.get_or_init(|| (0..MAX_LANES).map(|_| EventRing::new(RING_CAP)).collect())
}

/// This thread's lane index, claiming one on first use.
fn lane() -> u32 {
    LANE.with(|cell| {
        let v = cell.0.get();
        if v != LANE_UNSET {
            return v;
        }
        let l = alloc_lane();
        cell.0.set(l);
        l
    })
}

fn alloc_lane() -> u32 {
    {
        let mut free = FREE_LANES.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(l) = free.pop() {
            return l;
        }
    }
    let n = NEXT_LANE.fetch_add(1, Ordering::Relaxed);
    if (n as usize) < MAX_LANES {
        n
    } else {
        LANE_NONE
    }
}

fn emit(mut ev: SpanEvent) {
    let lane = lane();
    if lane == LANE_NONE {
        return;
    }
    ev.lane = lane;
    rings()[lane as usize].push(ev);
}

/// Begin timing a span: returns the start timestamp, or 0 when tracing is
/// disabled (the matching [`span`] call is then a no-op). The timestamp is
/// clamped to ≥ 1 so 0 stays unambiguous.
#[inline]
pub fn start() -> u64 {
    if !enabled() {
        return 0;
    }
    now_us().max(1)
}

/// Close a span opened by [`start`] and emit it. No-op when `t0 == 0`
/// (tracing was off at the start) or tracing has turned off since.
#[inline]
pub fn span(kind: SpanKind, id: u64, t0: u64, a: u64, b: u64) {
    if t0 == 0 || !enabled() {
        return;
    }
    emit(SpanEvent {
        kind,
        id,
        start_us: t0,
        dur_us: now_us().saturating_sub(t0),
        lane: 0,
        a,
        b,
        tag: None,
    });
}

/// [`span`] with a static tag (terminal outcome, ISA arm, ...).
#[inline]
pub fn span_tag(kind: SpanKind, id: u64, t0: u64, a: u64, b: u64, tag: &'static str) {
    if t0 == 0 || !enabled() {
        return;
    }
    emit(SpanEvent {
        kind,
        id,
        start_us: t0,
        dur_us: now_us().saturating_sub(t0),
        lane: 0,
        a,
        b,
        tag: Some(tag),
    });
}

/// Emit a span with explicit endpoints (epoch-relative microseconds) — for
/// lifecycle spans whose start predates the emission site, e.g. a request
/// span stamped from its arrival instant at terminal time.
#[inline]
pub fn mark(
    kind: SpanKind,
    id: u64,
    start_us: u64,
    end_us: u64,
    a: u64,
    b: u64,
    tag: Option<&'static str>,
) {
    if !enabled() {
        return;
    }
    emit(SpanEvent {
        kind,
        id,
        start_us,
        dur_us: end_us.saturating_sub(start_us),
        lane: 0,
        a,
        b,
        tag,
    });
}

/// Drain every lane ring into `out`; returns events lost since the last
/// drain. Callers serialize through the recorder's mutex.
pub(crate) fn drain_events(out: &mut Vec<SpanEvent>) -> u64 {
    let Some(rings) = LANES.get() else {
        return 0; // Nothing was ever emitted; don't allocate the lanes.
    };
    let n = (NEXT_LANE.load(Ordering::Relaxed) as usize).min(MAX_LANES);
    let mut lost = 0;
    for ring in &rings[..n] {
        while let Some(ev) = ring.pop() {
            out.push(ev);
        }
        lost += ring.take_lost();
    }
    lost
}

#[cfg(test)]
mod tests {
    use super::*;

    // These unit tests share the process-global tracing state with nothing
    // else in the lib test binary (no other lib test arms tracing), but
    // serialize against each other anyway.
    static GATE: Mutex<()> = Mutex::new(());

    fn drain_all() -> Vec<SpanEvent> {
        let mut out = Vec::new();
        drain_events(&mut out);
        out
    }

    #[test]
    fn disabled_emission_is_a_no_op() {
        let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
        drain_all();
        assert!(!enabled());
        assert_eq!(start(), 0);
        span(SpanKind::DecodeStep, 1, 0, 0, 0);
        span(SpanKind::DecodeStep, 1, 123, 0, 0); // stale t0, tracing off
        mark(SpanKind::Request, 1, 10, 20, 0, 0, None);
        assert!(drain_all().is_empty());
    }

    #[test]
    fn armed_spans_round_trip() {
        let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
        drain_all();
        let guard = TraceGuard::arm();
        assert!(enabled());
        let t0 = start();
        assert!(t0 > 0);
        span_tag(SpanKind::AttnJob, 7, t0, 3, 5, "scalar");
        mark(SpanKind::Request, 9, 100, 250, 1, 4, Some("ok"));
        drop(guard);
        assert!(!enabled());
        let evs = drain_all();
        assert_eq!(evs.len(), 2);
        let attn = evs.iter().find(|e| e.kind == SpanKind::AttnJob).unwrap();
        assert_eq!((attn.id, attn.a, attn.b, attn.tag), (7, 3, 5, Some("scalar")));
        let req = evs.iter().find(|e| e.kind == SpanKind::Request).unwrap();
        assert_eq!((req.start_us, req.dur_us, req.tag), (100, 150, Some("ok")));
    }

    #[test]
    fn kind_tables_are_total() {
        for k in SpanKind::ALL {
            assert!(!k.name().is_empty());
            assert!(!k.cat().is_empty());
            let (a, b) = k.arg_names();
            assert!(!a.is_empty() && !b.is_empty());
        }
        // Names are unique (they key the per-stage histograms).
        let mut names: Vec<_> = SpanKind::ALL.iter().map(|k| k.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), SpanKind::ALL.len());
    }
}
