//! The flight recorder: a bounded in-memory buffer of drained span events
//! plus live per-stage duration histograms.
//!
//! One recorder is owned (behind `Arc<Mutex<..>>`) by the scheduler and
//! drained by whatever drives it — the serve loop, the replay harness, a
//! bench — once per driver iteration ([`Recorder::drain`] pops every lane
//! ring). The admin plane locks the same recorder to answer `metrics` and
//! `trace` without touching the data path.

use crate::obs::{self, SpanEvent};
use crate::util::json::Json;
use crate::util::stats::LatencyHistogram;
use std::collections::{BTreeMap, VecDeque};

/// Default flight-recorder capacity in events. At serving event rates this
/// is seconds-to-minutes of trailing window; the buffer evicts oldest.
pub const DEFAULT_CAP: usize = 65_536;

/// Bounded event buffer + per-stage duration histograms. See module docs.
pub struct Recorder {
    cap: usize,
    buf: VecDeque<SpanEvent>,
    stages: BTreeMap<&'static str, LatencyHistogram>,
    lost: u64,
}

impl Default for Recorder {
    fn default() -> Recorder {
        Recorder::new()
    }
}

impl Recorder {
    /// A recorder with the default capacity ([`DEFAULT_CAP`]).
    pub fn new() -> Recorder {
        Recorder::with_capacity(DEFAULT_CAP)
    }

    /// A recorder keeping at most `cap` trailing events (histograms and the
    /// lost counter are unbounded-cheap and never evicted).
    pub fn with_capacity(cap: usize) -> Recorder {
        Recorder {
            cap: cap.max(1),
            buf: VecDeque::new(),
            stages: BTreeMap::new(),
            lost: 0,
        }
    }

    /// Pop every lane ring into the buffer, fold durations into the
    /// per-stage histograms, and evict past capacity. Returns how many
    /// events arrived. Cheap when idle (empty rings, one atomic per lane).
    pub fn drain(&mut self) -> usize {
        let mut fresh = Vec::new();
        self.lost += obs::drain_events(&mut fresh);
        let n = fresh.len();
        for ev in fresh {
            self.stages
                .entry(ev.kind.name())
                .or_insert_with(LatencyHistogram::new)
                .record(ev.dur_us);
            self.buf.push_back(ev);
        }
        while self.buf.len() > self.cap {
            self.buf.pop_front();
            self.lost += 1;
        }
        n
    }

    /// Buffered events, oldest first.
    pub fn events(&self) -> impl Iterator<Item = &SpanEvent> {
        self.buf.iter()
    }

    /// Number of buffered events.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True when nothing is buffered.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Events lost end to end: dropped or lapped in the rings, plus evicted
    /// from this buffer. Monotonic until [`Recorder::clear`].
    pub fn lost(&self) -> u64 {
        self.lost
    }

    /// Per-stage duration histograms, keyed by [`obs::SpanKind::name`].
    pub fn stages(&self) -> &BTreeMap<&'static str, LatencyHistogram> {
        &self.stages
    }

    /// Forget everything (buffer, histograms, lost counter). Tests use this
    /// to isolate runs sharing the process-global rings.
    pub fn clear(&mut self) {
        self.buf.clear();
        self.stages.clear();
        self.lost = 0;
    }

    /// Export the buffered events as Chrome trace-event JSON
    /// (`chrome://tracing` / Perfetto loadable). With `window_us`, only
    /// spans that end within the trailing window are included.
    pub fn chrome_trace(&self, window_us: Option<u64>) -> Json {
        let cutoff = window_us.map(|w| obs::now_us().saturating_sub(w));
        let mut events: Vec<&SpanEvent> = self
            .buf
            .iter()
            .filter(|e| match cutoff {
                Some(c) => e.start_us.saturating_add(e.dur_us) >= c,
                None => true,
            })
            .collect();
        events.sort_by_key(|e| (e.start_us, e.lane, e.id));
        crate::obs::export::chrome_trace(events)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::SpanKind;

    #[test]
    fn capacity_evicts_oldest_and_counts_lost() {
        let mut r = Recorder::with_capacity(2);
        // Bypass the global rings: feed the buffer directly through the
        // same code path drain uses.
        for i in 0..5u64 {
            let ev = SpanEvent {
                kind: SpanKind::DecodeStep,
                id: i,
                start_us: 10 * i,
                dur_us: 1,
                lane: 0,
                a: 0,
                b: 0,
                tag: None,
            };
            r.stages
                .entry(ev.kind.name())
                .or_insert_with(LatencyHistogram::new)
                .record(ev.dur_us);
            r.buf.push_back(ev);
            while r.buf.len() > r.cap {
                r.buf.pop_front();
                r.lost += 1;
            }
        }
        assert_eq!(r.len(), 2);
        assert_eq!(r.lost(), 3);
        let ids: Vec<u64> = r.events().map(|e| e.id).collect();
        assert_eq!(ids, vec![3, 4]);
        assert_eq!(r.stages()["decode_step"].count(), 5);
        r.clear();
        assert!(r.is_empty());
        assert_eq!(r.lost(), 0);
    }
}
