//! Trace exporters: Chrome trace-event JSON and Prometheus text exposition.
//!
//! Both formats are produced from the same [`Recorder`] state — the Chrome
//! trace from the buffered span events (one complete `ph: "X"` event per
//! span, lanes as `tid`s), the Prometheus page from the admin stats
//! snapshot (every counter/gauge the `stats` command already exposes) plus
//! the per-stage duration histograms as summaries.

use crate::obs::recorder::Recorder;
use crate::obs::SpanEvent;
use crate::util::json::Json;
use std::fmt::Write as _;

/// Build a Chrome trace-event JSON document (the "JSON object format":
/// `{"traceEvents": [...]}`, loadable in `chrome://tracing` and Perfetto)
/// from complete span events. Timestamps are already microseconds, the
/// unit the format specifies for `ts`/`dur`.
pub fn chrome_trace<'a>(events: impl IntoIterator<Item = &'a SpanEvent>) -> Json {
    let trace_events: Vec<Json> = events
        .into_iter()
        .map(|e| {
            let (a_name, b_name) = e.kind.arg_names();
            let mut args = vec![
                ("id", Json::Num(e.id as f64)),
                (a_name, Json::Num(e.a as f64)),
                (b_name, Json::Num(e.b as f64)),
            ];
            if let Some(tag) = e.tag {
                args.push(("tag", Json::str(tag)));
            }
            Json::obj(vec![
                ("ph", Json::str("X")),
                ("pid", Json::Num(1.0)),
                ("tid", Json::Num(e.lane as f64)),
                ("ts", Json::Num(e.start_us as f64)),
                ("dur", Json::Num(e.dur_us as f64)),
                ("name", Json::str(e.kind.name())),
                ("cat", Json::str(e.kind.cat())),
                ("args", Json::obj(args)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("displayTimeUnit", Json::str("ms")),
        ("traceEvents", Json::Arr(trace_events)),
    ])
}

/// Render one Prometheus metric line set (`# HELP`, `# TYPE`, sample) for a
/// plain gauge.
fn gauge(out: &mut String, name: &str, help: &str, value: u64) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} gauge");
    let _ = writeln!(out, "{name} {value}");
}

/// Build the Prometheus text-exposition page: every `(name, value)` pair of
/// the admin stats snapshot as `innerq_<name>`, the per-stage span-duration
/// histograms as summaries, and the tracing plane's own meta-series.
///
/// All snapshot series are typed `gauge` — the scrape-side distinction
/// between the monotonic counters and the instantaneous gauges in the
/// snapshot is documented per series name in `ARCHITECTURE.md`, and `gauge`
/// is the type that is never wrong for a value that can be reset by a
/// server restart.
pub fn prometheus(rec: &Recorder, snapshot: &[(String, u64)]) -> String {
    let mut out = String::new();
    for (name, value) in snapshot {
        gauge(
            &mut out,
            &format!("innerq_{name}"),
            &format!("Admin stats field {name}."),
            *value,
        );
    }
    if !rec.stages().is_empty() {
        let _ = writeln!(
            out,
            "# HELP innerq_stage_duration_us Span duration in microseconds by stage."
        );
        let _ = writeln!(out, "# TYPE innerq_stage_duration_us summary");
        for (stage, hist) in rec.stages() {
            let s = hist.summary();
            for (q, v) in
                [("0.5", s.p50_us), ("0.9", s.p90_us), ("0.99", s.p99_us)]
            {
                let _ = writeln!(
                    out,
                    "innerq_stage_duration_us{{stage=\"{stage}\",quantile=\"{q}\"}} {v}"
                );
            }
            let _ = writeln!(
                out,
                "innerq_stage_duration_us_sum{{stage=\"{stage}\"}} {}",
                hist.sum_us()
            );
            let _ = writeln!(
                out,
                "innerq_stage_duration_us_count{{stage=\"{stage}\"}} {}",
                hist.count()
            );
        }
    }
    gauge(
        &mut out,
        "innerq_trace_enabled",
        "1 while a tracer (admin trace window or --trace-out) is live.",
        crate::obs::enabled() as u64,
    );
    gauge(
        &mut out,
        "innerq_trace_buffered_events",
        "Span events currently held by the flight recorder.",
        rec.len() as u64,
    );
    gauge(
        &mut out,
        "innerq_trace_events_lost",
        "Span events lost end to end (ring overwrites plus recorder eviction).",
        rec.lost(),
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::SpanKind;

    fn ev(kind: SpanKind, id: u64, start: u64, dur: u64) -> SpanEvent {
        SpanEvent { kind, id, start_us: start, dur_us: dur, lane: 3, a: 1, b: 2, tag: None }
    }

    #[test]
    fn chrome_trace_shape() {
        let events = [
            ev(SpanKind::Prefill, 1, 100, 50),
            SpanEvent { tag: Some("ok"), ..ev(SpanKind::Request, 1, 90, 400) },
        ];
        let doc = chrome_trace(events.iter());
        let parsed = Json::parse(&doc.dump()).unwrap();
        let tes = parsed.get("traceEvents").as_arr().unwrap();
        assert_eq!(tes.len(), 2);
        for te in tes {
            assert_eq!(te.get("ph").as_str(), Some("X"));
            assert_eq!(te.get("pid").as_f64(), Some(1.0));
            assert!(te.get("ts").as_f64().is_some());
            assert!(te.get("dur").as_f64().is_some());
            assert!(te.get("name").as_str().is_some());
            assert!(te.get("cat").as_str().is_some());
            assert!(te.get("args").as_obj().is_some());
        }
        let req = tes.iter().find(|t| t.get("name").as_str() == Some("request")).unwrap();
        assert_eq!(req.get("args").get("tag").as_str(), Some("ok"));
        assert_eq!(req.get("args").get("id").as_f64(), Some(1.0));
        // Single line: the admin `trace` command replies with one line.
        assert!(!doc.dump().contains('\n'));
    }

    #[test]
    fn prometheus_page_is_well_formed() {
        let rec = Recorder::new();
        let snap = vec![("decode_steps".to_string(), 42u64), ("pending".to_string(), 0u64)];
        let page = prometheus(&rec, &snap);
        assert!(page.contains("# TYPE innerq_decode_steps gauge\n"));
        assert!(page.contains("\ninnerq_decode_steps 42\n"));
        assert!(page.contains("innerq_trace_enabled 0\n"));
        for line in page.lines() {
            assert!(!line.trim().is_empty());
            if line.starts_with('#') {
                let mut parts = line.splitn(4, ' ');
                assert_eq!(parts.next(), Some("#"));
                assert!(matches!(parts.next(), Some("HELP") | Some("TYPE")));
                assert!(parts.next().unwrap().starts_with("innerq_"));
            } else {
                let (series, value) = line.rsplit_once(' ').unwrap();
                assert!(series.starts_with("innerq_"));
                assert!(value.parse::<f64>().is_ok(), "bad value in {line:?}");
            }
        }
    }
}
