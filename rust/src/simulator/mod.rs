//! Analytical GPU memory-traffic model of the fused dequant-GEMV kernels.
//!
//! The paper's latency results (Table 4, measured on a Jetson Xavier NX)
//! are driven by DRAM traffic and by how scale factors map onto a warp's
//! lanes: inner-dimension grouping lets all 32 lanes of a warp share one
//! scale register (one load per group), while outer-dimension grouping makes
//! each lane load its own scale per row chunk (§4.4, Fig. 1). This module
//! reproduces the *shape* of those tables from first principles:
//!
//! `t = max(bytes_moved / BW, flops / F) + scale_loads * t_load + overhead`
//!
//! It is the cross-check that our CPU measurements and the paper's GPU
//! measurements order the methods the same way, and the vehicle for the
//! DESIGN.md §Hardware-Adaptation discussion.

use crate::quant::{Grouping, MethodConfig, QuantMethod};

/// Jetson-Xavier-NX-flavoured machine model (order-of-magnitude; the model
/// predicts ratios, not absolute microseconds).
#[derive(Debug, Clone, Copy)]
pub struct GpuModel {
    /// Effective DRAM bandwidth, bytes/us.
    pub bw_bytes_per_us: f64,
    /// FMA throughput, flops/us.
    pub flops_per_us: f64,
    /// Per-element unpack/dequant ALU cost for quantized codes, us.
    pub dequant_alu_us: f64,
    /// Cost of one per-lane scale/zero load, us (amortized; inner grouping
    /// issues one per *group*, outer grouping one per *element*).
    pub scale_load_us: f64,
    /// Cost of one shared-memory codebook lookup, us (amortized).
    pub lut_access_us: f64,
    /// Fixed kernel launch + tail overhead, us.
    pub launch_us: f64,
}

impl Default for GpuModel {
    fn default() -> Self {
        // Calibrated on the paper's own Table 4 (Jetson Xavier NX):
        // FP16 @32768 = 9516 us over 134 MB  => ~14.1 GB/s effective GEMV
        // bandwidth; the KIVI-vs-InnerQ gap at equal traffic pins the
        // per-lane scale-load cost; TurboQuant's residual pins the LUT cost.
        GpuModel {
            bw_bytes_per_us: 14_100.0,
            flops_per_us: 1_700_000.0,
            dequant_alu_us: 1.5e-5,
            scale_load_us: 1.12e-5,
            lut_access_us: 1.16e-5,
            launch_us: 18.0,
        }
    }
}

/// Attention-GEMV geometry for one layer (Llama-3.1-8B in Table 4).
#[derive(Debug, Clone, Copy)]
pub struct Geometry {
    /// Cache length in tokens.
    pub n_tokens: usize,
    /// Head dimension.
    pub d_h: usize,
    /// Number of KV heads (the cache side of GQA).
    pub n_kv_heads: usize,
    /// Number of query heads (flops scale with these, bytes do not).
    pub n_q_heads: usize,
}

impl Geometry {
    /// The Llama-3.1-8B attention geometry used throughout Table 4.
    pub fn llama31_8b(n_tokens: usize) -> Geometry {
        Geometry { n_tokens, d_h: 128, n_kv_heads: 8, n_q_heads: 32 }
    }
}

/// Predicted latency of the key-cache fused kernel (Eq. 3) in µs.
pub fn key_kernel_us(m: &GpuModel, g: &Geometry, cfg: &MethodConfig) -> f64 {
    kernel_us(m, g, cfg.key_bits, cfg.key_grouping, cfg.key_has_zeros(), cfg, false)
}

/// Predicted latency of the value-cache fused kernel (Eq. 5) in µs.
pub fn value_kernel_us(m: &GpuModel, g: &Geometry, cfg: &MethodConfig) -> f64 {
    kernel_us(m, g, cfg.val_bits, cfg.val_grouping, cfg.val_has_zeros(), cfg, true)
}

fn kernel_us(
    m: &GpuModel,
    g: &Geometry,
    bits: u8,
    grouping: Grouping,
    has_zeros: bool,
    cfg: &MethodConfig,
    _is_value: bool,
) -> f64 {
    let elems = (g.n_tokens * g.d_h * g.n_kv_heads) as f64;
    let group = cfg.group_size as f64;

    // Bytes moved from DRAM: codes + per-group metadata (+ f32 norms for
    // turbo), matching the Table 3 accounting.
    let code_bytes = elems * bits as f64 / 8.0;
    let meta_bytes = if cfg.turbo {
        elems * 0.25 / 8.0 // f32 norms: 0.25 bits per element (Table 3)
    } else if !cfg.is_quantized() {
        0.0
    } else {
        let scale = elems / group * 2.0;
        let zeros = if has_zeros { elems / group * 2.0 } else { 0.0 };
        scale + zeros
    };
    let bytes = if cfg.is_quantized() { code_bytes + meta_bytes } else { elems * 2.0 };

    // FMA work: GQA reuses the cache row for n_q/n_kv queries while it is
    // resident, so flops scale with n_q but bytes do not.
    let flops = 2.0 * elems * (g.n_q_heads / g.n_kv_heads) as f64;

    // Scale-load penalty: how many *per-lane* scale register loads the warp
    // issues. Inner grouping: one per group, shared by the whole warp.
    // Outer grouping: one per element lane (no reuse across the warp).
    let factor = if has_zeros { 2.0 } else { 1.0 };
    let scale_loads = if !cfg.is_quantized() || cfg.turbo {
        0.0
    } else {
        match grouping {
            Grouping::Inner => elems / group * factor,
            Grouping::Outer => elems * factor,
        }
    };
    // TurboQuant: every dequantized element is a shared-memory table lookup.
    let lut = if cfg.turbo { elems } else { 0.0 };
    // Unpacking sub-byte codes costs ALU work regardless of grouping.
    let dequant = if cfg.is_quantized() { elems * m.dequant_alu_us } else { 0.0 };

    let stream = (bytes / m.bw_bytes_per_us).max(flops / m.flops_per_us);
    stream + dequant + scale_loads * m.scale_load_us + lut * m.lut_access_us + m.launch_us
}

/// A full Table-4-shaped prediction: (key_us, value_us, total_us).
pub fn table4_row(m: &GpuModel, method: QuantMethod, n_tokens: usize) -> (f64, f64, f64) {
    let g = Geometry::llama31_8b(n_tokens);
    let cfg = method.config();
    let k = key_kernel_us(m, &g, &cfg);
    let v = value_kernel_us(m, &g, &cfg);
    (k, v, k + v)
}

#[cfg(test)]
mod tests {
    use super::*;

    const LENGTHS: [usize; 7] = [512, 1024, 2048, 4096, 8192, 16384, 32768];

    #[test]
    fn innerq_beats_kivi_at_every_length() {
        let m = GpuModel::default();
        for n in LENGTHS {
            let (_, _, kivi) = table4_row(&m, QuantMethod::Kivi, n);
            let (_, _, base) = table4_row(&m, QuantMethod::InnerQBase, n);
            assert!(base < kivi, "n={n}: innerq {base:.0} vs kivi {kivi:.0}");
        }
    }

    #[test]
    fn speedups_match_paper_shape_at_32k() {
        // Paper Table 4 @32768: FP16 9516, KIVI 4331, Turbo 4046,
        // InnerQ_Base 3276 -> speedups 2.9x vs FP16, 1.32x vs KIVI,
        // 1.23x vs Turbo. The model should land in the same bands.
        let m = GpuModel::default();
        let (_, _, fp) = table4_row(&m, QuantMethod::BaselineFp16, 32768);
        let (_, _, kivi) = table4_row(&m, QuantMethod::Kivi, 32768);
        let (_, _, turbo) = table4_row(&m, QuantMethod::TurboQuant, 32768);
        let (_, _, base) = table4_row(&m, QuantMethod::InnerQBase, 32768);
        let s_fp = fp / base;
        let s_kivi = kivi / base;
        let s_turbo = turbo / base;
        assert!((2.0..4.5).contains(&s_fp), "vs fp16 {s_fp:.2}");
        assert!((1.1..1.6).contains(&s_kivi), "vs kivi {s_kivi:.2}");
        assert!((1.05..1.5).contains(&s_turbo), "vs turbo {s_turbo:.2}");
    }

    #[test]
    fn speedup_grows_with_sequence_length() {
        // §5.3: the speedup over FP16 "steadily rises as the sequence grows"
        // (launch overhead amortizes away).
        let m = GpuModel::default();
        let s = |n| {
            let (_, _, fp) = table4_row(&m, QuantMethod::BaselineFp16, n);
            let (_, _, b) = table4_row(&m, QuantMethod::InnerQBase, n);
            fp / b
        };
        assert!(s(32768) > s(4096));
        assert!(s(4096) > s(512));
    }

    #[test]
    fn variant_ordering_on_value_cache() {
        // Table 4 value rows: Small <= Hybrid <= Base.
        let m = GpuModel::default();
        let g = Geometry::llama31_8b(8192);
        let v = |q: QuantMethod| value_kernel_us(&m, &g, &q.config());
        assert!(v(QuantMethod::InnerQSmall) <= v(QuantMethod::InnerQHybrid) + 1e-9);
        assert!(v(QuantMethod::InnerQHybrid) <= v(QuantMethod::InnerQBase) + 1e-9);
    }

    #[test]
    fn latency_roughly_linear_in_tokens() {
        let m = GpuModel::default();
        let (_, _, a) = table4_row(&m, QuantMethod::InnerQBase, 8192);
        let (_, _, b) = table4_row(&m, QuantMethod::InnerQBase, 16384);
        let ratio = b / a;
        assert!((1.7..2.2).contains(&ratio), "ratio {ratio}");
    }
}
